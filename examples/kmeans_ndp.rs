//! K-means on the NDP system — the paper's Fig 7 running example, end to
//! end: CODA's compile-time analysis of the Fig-7 kernel IR decides the
//! placement (features localized via Eq 2/3, centroids distributed), the
//! simulator measures the memory-system win, and real Lloyd iterations run
//! through the AOT `kmeans_assign` artifact (MXU-shaped Pallas distance
//! kernel) until inertia converges.
//!
//! ```sh
//! make artifacts && cargo run --release --example kmeans_ndp
//! ```

use coda::analysis::analyze_kernel;
use coda::config::SystemConfig;
use coda::coordinator::{Coordinator, Mechanism};
use coda::report::pct;
use coda::rng::Rng;
use coda::runtime::{Arg, Runtime};
use coda::workloads::dense::kmeans;

const N: usize = 4096; // must match python/compile/model.py KM_N
const F: usize = 8; // KM_F
const K: usize = 8; // KM_K

fn main() -> coda::Result<()> {
    println!("== K-means (Fig 7) on the NDP system ==\n");
    let mut cfg = SystemConfig::default();
    cfg.stack_capacity = 256 << 20;

    // --- 1. Compile-time analysis of the Fig-7 kernel --------------------
    let wl = kmeans(&cfg);
    let ir = wl.ir.as_ref().expect("kmeans ships IR");
    let patterns = analyze_kernel(ir, &wl.env);
    println!("compile-time analysis (LLVM-pass analog):");
    for (obj, p) in &patterns {
        println!("  {}: {:?}", wl.trace.objects[*obj as usize].name, p);
    }

    // --- 2. Memory-system comparison -------------------------------------
    let coord = Coordinator::new(cfg.clone());
    let fgp = coord.run(&wl, Mechanism::FgpOnly)?;
    let coda = coord.run(&wl, Mechanism::Coda)?;
    println!(
        "\nsimulated memory system: speedup {:.2}x, remote {} -> {}\n",
        coda.speedup_over(&fgp),
        pct(fgp.accesses.remote_fraction()),
        pct(coda.accesses.remote_fraction()),
    );

    // --- 3. Real Lloyd iterations through PJRT ---------------------------
    let mut rt = Runtime::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))?;
    let exe = rt.load("kmeans_assign")?;
    // Synthetic clustered points: K true centers + noise.
    let mut rng = Rng::new(42);
    let mut centers = vec![0.0f32; K * F];
    for c in centers.iter_mut() {
        *c = (rng.f32() - 0.5) * 20.0;
    }
    let mut points = vec![0.0f32; N * F];
    for i in 0..N {
        let c = (i % K) * F;
        for f in 0..F {
            points[i * F + f] = centers[c + f] + rng.normal() as f32;
        }
    }
    // Init centroids from the first K points (deliberately bad start).
    let mut centroids = points[..K * F].to_vec();
    let mut last_inertia = f32::INFINITY;
    for it in 0..25 {
        let out = exe.run(&[
            Arg::F32(&points, &[N, F]),
            Arg::F32(&centroids, &[K, F]),
        ])?;
        let (_assign, new_centroids, inertia) = (&out[0], &out[1], out[2][0]);
        println!("  iter {it:>2}: inertia {inertia:.4}");
        assert!(
            inertia <= last_inertia * 1.0001,
            "Lloyd inertia must not increase: {inertia} > {last_inertia}"
        );
        let moved: f32 = new_centroids
            .iter()
            .zip(&centroids)
            .map(|(a, b)| (a - b).abs())
            .sum();
        centroids = new_centroids.clone();
        last_inertia = inertia;
        if moved < 1e-4 {
            println!("converged after {} iterations", it + 1);
            break;
        }
    }
    // The fit must be tight: noise is unit-variance in F=8 dims, so the
    // converged mean squared distance should be near F (within 2x).
    assert!(
        last_inertia < 2.0 * F as f32,
        "inertia {last_inertia} did not reach the noise floor"
    );
    println!("\nkmeans_ndp OK (final inertia {last_inertia:.3}, noise floor ~{F})");
    Ok(())
}
