//! Multiprogrammed NDP (§6.5): four applications, one per memory stack,
//! under FGP-Only vs per-stack CGP placement — the scenario where
//! fine-grain interleaving *guarantees* remote traffic and the dual-mode
//! hardware eliminates it.
//!
//! ```sh
//! cargo run --release --example multiprogram
//! ```

use coda::config::SystemConfig;
use coda::multiprog::{run_mix, Mix, MixPlacement};
use coda::report::{f2, pct, Table};
use coda::workloads::suite;

fn main() -> coda::Result<()> {
    println!("== Multiprogrammed workloads (Fig 12 scenario) ==\n");
    let mut cfg = SystemConfig::default();
    cfg.stack_capacity = 256 << 20;

    let mixes: [[&str; 4]; 4] = [
        ["BFS", "KM", "CC", "TC"],    // one per category
        ["PR", "NN", "MG", "HS3D"],
        ["DC", "SPMV", "DWT", "HS"],
        ["SSSP", "MM", "GC", "NW"],
    ];

    let mut t = Table::new(&["mix", "FGP cycles", "CGP cycles", "speedup", "FGP remote", "CGP remote"]);
    for names in &mixes {
        let apps: Vec<_> = names
            .iter()
            .map(|n| suite::build(n, &cfg))
            .collect::<coda::Result<Vec<_>>>()?;
        let mix = Mix {
            apps: apps.iter().map(|a| a.as_ref()).collect(),
        };
        let (_, fgp) = run_mix(&cfg, &mix, MixPlacement::FgpOnly)?;
        let (_, cgp) = run_mix(&cfg, &mix, MixPlacement::CgpLocal)?;
        t.row(&[
            names.join("+"),
            format!("{:.0}", fgp.cycles),
            format!("{:.0}", cgp.cycles),
            f2(fgp.cycles / cgp.cycles),
            pct(fgp.accesses.remote_fraction()),
            pct(cgp.accesses.remote_fraction()),
        ]);
    }
    println!("{}", t.render());
    println!("CGP-per-stack placement eliminates cross-stack traffic that FGP-Only");
    println!("hardware cannot avoid when multiple applications share the system.");
    Ok(())
}
