//! End-to-end validation (DESIGN.md §6): PageRank on a real synthetic
//! web graph, exercising all three layers together:
//!
//!  * **L3** — the CODA coordinator places the graph's objects (dual-mode
//!    address mapping + Eq 2/3), steers thread-blocks with the affinity
//!    scheduler, and simulates the NDP memory system (CODA vs FGP-Only).
//!  * **runtime** — every rank sweep is *actually executed* through the
//!    AOT-compiled JAX/Pallas artifact on the PJRT CPU client.
//!  * **L1** — the sweep inside that artifact is the Pallas
//!    gather-reduce kernel, previously validated against ref.py.
//!
//! The computed ranks are cross-checked against a pure-Rust PageRank, and
//! the run is recorded in EXPERIMENTS.md.
//!
//! ```sh
//! make artifacts && cargo run --release --example pagerank_e2e
//! ```

use coda::config::SystemConfig;
use coda::coordinator::{Coordinator, Mechanism};
use coda::report::pct;
use coda::runtime::{run_pagerank, Runtime};
use coda::workloads::graph::{CsrGraph, GraphSpec};
use coda::workloads::graphs::pagerank_on;

const V: usize = 8192; // must match python/compile/model.py PR_V
const K: usize = 16; // must match PR_K
const DAMPING: f32 = 0.85;

/// Build a padded in-neighbor table (V x K) from a CSR out-edge graph.
fn in_neighbor_table(g: &CsrGraph) -> (Vec<i32>, Vec<f32>, Vec<f32>) {
    let mut in_nbrs: Vec<Vec<i32>> = vec![Vec::new(); V];
    let mut out_deg = vec![0u32; V];
    for src in 0..V {
        for &dst in g.neighbors(src) {
            if in_nbrs[dst as usize].len() < K {
                in_nbrs[dst as usize].push(src as i32);
                out_deg[src] += 1;
            }
        }
    }
    let mut idx = vec![0i32; V * K];
    let mut mask = vec![0.0f32; V * K];
    for v in 0..V {
        for (k, &n) in in_nbrs[v].iter().enumerate() {
            idx[v * K + k] = n;
            mask[v * K + k] = 1.0;
        }
    }
    let inv_deg: Vec<f32> = out_deg
        .iter()
        .map(|&d| if d > 0 { 1.0 / d as f32 } else { 0.0 })
        .collect();
    (idx, mask, inv_deg)
}

/// Pure-Rust oracle sweep.
fn rust_sweep(ranks: &[f32], inv_deg: &[f32], idx: &[i32], mask: &[f32]) -> Vec<f32> {
    let mut out = vec![(1.0 - DAMPING) / V as f32; V];
    for v in 0..V {
        let mut acc = 0.0f32;
        for k in 0..K {
            let n = idx[v * K + k] as usize;
            acc += ranks[n] * inv_deg[n] * mask[v * K + k];
        }
        out[v] += DAMPING * acc;
    }
    out
}

fn main() -> coda::Result<()> {
    println!("== PageRank end-to-end: CODA placement + PJRT compute ==\n");
    let mut cfg = SystemConfig::default();
    cfg.stack_capacity = 256 << 20;

    // --- 1. The graph (a real small web-graph-shaped input) -------------
    let g = CsrGraph::generate(&GraphSpec {
        num_vertices: V,
        avg_degree: 12.0,
        degree_cv: 0.6,
        locality: 0.9,
        window: 256,
        seed: 0xE2E,
    });
    println!(
        "graph: {} vertices, {} edges, degree CV {:.2}",
        g.num_vertices,
        g.num_edges(),
        g.degree_cv()
    );

    // --- 2. NDP memory-system evaluation: CODA vs FGP-Only ---------------
    let coord = Coordinator::new(cfg.clone());
    let wl = pagerank_on(g.clone(), &cfg);
    let fgp = coord.run(&wl, Mechanism::FgpOnly)?;
    let coda = coord.run(&wl, Mechanism::Coda)?;
    println!(
        "\nsimulated memory system:\n  FGP-Only : {:>12.0} cycles, remote {}\n  CODA     : {:>12.0} cycles, remote {}\n  speedup {:.2}x, remote-access reduction {}",
        fgp.cycles,
        pct(fgp.accesses.remote_fraction()),
        coda.cycles,
        pct(coda.accesses.remote_fraction()),
        coda.speedup_over(&fgp),
        pct(coda.remote_reduction_over(&fgp)),
    );

    // --- 3. Real compute through the AOT artifact ------------------------
    let mut rt = Runtime::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))?;
    let (idx, mask, inv_deg) = in_neighbor_table(&g);
    let exe = rt.load("pagerank_update")?;
    let mut ranks = vec![1.0f32 / V as f32; V];
    let mut oracle = ranks.clone();
    let mut iters = 0;
    let t0 = std::time::Instant::now();
    loop {
        let next = run_pagerank(exe, &ranks, &inv_deg, &idx, &mask, V, K)?;
        let next_oracle = rust_sweep(&oracle, &inv_deg, &idx, &mask);
        // Cross-check PJRT output against the Rust oracle every sweep.
        let max_err = next
            .iter()
            .zip(&next_oracle)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_err < 1e-5, "PJRT vs Rust oracle diverged: {max_err}");
        let delta: f32 = next.iter().zip(&ranks).map(|(a, b)| (a - b).abs()).sum();
        ranks = next;
        oracle = next_oracle;
        iters += 1;
        if delta < 1e-6 || iters >= 100 {
            println!(
                "\nPJRT compute ({}): converged after {iters} sweeps (L1 delta {delta:.2e}), {:.1} ms/sweep, max |PJRT - oracle| < 1e-5",
                rt.platform(),
                t0.elapsed().as_secs_f64() * 1e3 / iters as f64
            );
            break;
        }
    }
    let mass: f32 = ranks.iter().sum();
    let mut top: Vec<(usize, f32)> = ranks.iter().cloned().enumerate().collect();
    top.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    println!("rank mass = {mass:.4}; top vertices: {:?}", &top[..5]);
    // With dangling-edge truncation mass stays close to but below 1.
    assert!(mass > 0.5 && mass <= 1.01, "rank mass {mass} out of range");
    println!("\npagerank_e2e OK");
    Ok(())
}
