//! Quickstart: build one benchmark, compare CODA against every baseline,
//! and (if `make artifacts` has run) execute a real AOT-compiled kernel
//! through the PJRT runtime.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use coda::config::SystemConfig;
use coda::coordinator::{Coordinator, Mechanism};
use coda::report::{f2, pct, Table};
use coda::runtime::Runtime;
use coda::workloads::suite;

fn main() -> coda::Result<()> {
    let mut cfg = SystemConfig::default();
    cfg.stack_capacity = 256 << 20; // plenty for the demo workload
    let coord = Coordinator::new(cfg.clone());

    println!("== CODA quickstart: PageRank on a 98K-vertex graph ==\n");
    let wl = suite::build("PR", &cfg)?;
    println!(
        "workload: {} ({} thread-blocks, {} accesses, {} objects)\n",
        wl.name,
        wl.trace.num_blocks(),
        wl.total_accesses(),
        wl.trace.objects.len()
    );

    let mechs = [
        Mechanism::FgpOnly,
        Mechanism::CgpOnly,
        Mechanism::CgpFta,
        Mechanism::MigrationFta,
        Mechanism::Coda,
    ];
    let reports = coord.compare(&wl, &mechs)?;
    let base = reports[0].clone();
    let mut t = Table::new(&["mechanism", "speedup", "remote%", "remote-reduction"]);
    for r in &reports {
        t.row(&[
            r.mechanism.clone(),
            f2(r.speedup_over(&base)),
            pct(r.accesses.remote_fraction()),
            pct(r.remote_reduction_over(&base)),
        ]);
    }
    println!("{}", t.render());

    // The AOT compute path: run one real PageRank sweep through PJRT.
    let mut rt = Runtime::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))?;
    if rt.artifact_exists("pagerank_update") {
        const V: usize = 8192;
        const K: usize = 16;
        let ranks = vec![1.0f32 / V as f32; V];
        let inv_deg = vec![1.0f32 / K as f32; V];
        // Ring graph neighbor table.
        let mut nbr = vec![0i32; V * K];
        for v in 0..V {
            for k in 0..K {
                nbr[v * K + k] = ((v + k + 1) % V) as i32;
            }
        }
        let mask = vec![1.0f32; V * K];
        let exe = rt.load("pagerank_update")?;
        let out = coda::runtime::run_pagerank(exe, &ranks, &inv_deg, &nbr, &mask, V, K)?;
        let sum: f32 = out.iter().sum();
        println!(
            "PJRT sweep on {}: |ranks|_1 = {:.6} (expect 1.0)\n",
            rt.platform(),
            sum
        );
    } else {
        println!("(artifacts not built; run `make artifacts` to see the PJRT path)");
    }
    Ok(())
}
