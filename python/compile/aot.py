"""AOT export: lower every Layer-2 graph to HLO *text* artifacts.

HLO text (not a serialized ``HloModuleProto``) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which the rust side's
XLA (xla_extension 0.5.1) rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly. Lowered with
``return_tuple=True`` so the rust loader unwraps a tuple uniformly.

Run once via ``make artifacts``; never on the request path.
"""

import argparse
import pathlib

import jax
from jax._src.lib import xla_client as xc

from .model import artifact_specs


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_all(out_dir: pathlib.Path) -> None:
    out_dir.mkdir(parents=True, exist_ok=True)
    for name, fn, args in artifact_specs():
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        path = out_dir / f"{name}.hlo.txt"
        path.write_text(text)
        print(f"wrote {path} ({len(text)} chars)")


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    args = parser.parse_args()
    export_all(pathlib.Path(args.out_dir))


if __name__ == "__main__":
    main()
