"""Layer-1 Pallas kernels (build-time only; lowered to HLO once).

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot execute
real-TPU Mosaic custom-calls, so interpret mode is the correctness target
and real-TPU efficiency is estimated analytically (DESIGN.md §7).
"""

from .gather_reduce import pagerank_update_kernel
from .kmeans_assign import kmeans_assign_kernel, kmeans_update_centroids
from .hotspot_step import hotspot_step_kernel

__all__ = [
    "pagerank_update_kernel",
    "kmeans_assign_kernel",
    "kmeans_update_centroids",
    "hotspot_step_kernel",
]
