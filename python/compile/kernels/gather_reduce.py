"""PageRank neighbor gather+reduce as a Pallas kernel.

The NDP hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's
thread-block owning a contiguous vertex slice becomes a Pallas grid step
owning a VMEM-resident row tile. The rank vector — CODA's *shared* (FGP)
object — stays whole in every grid step (it is broadcast, like the paper's
fine-grain interleaved pages), while the per-tile neighbor index/mask
arrays — CODA's *exclusive* (CGP) objects — are blocked so each grid step
only stages its own slice, the BlockSpec analog of Eq 2/3 placement.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows of the vertex tile each grid step owns (the "thread-block").
TILE_V = 256


def _kernel(ranks_ref, inv_deg_ref, nbr_ref, mask_ref, o_ref, *, damping):
    """One vertex tile: new_rank = (1-d)/V + d * sum_k contrib(nbr_k)."""
    ranks = ranks_ref[...]            # (V,)  shared, whole
    inv_deg = inv_deg_ref[...]        # (V,)  shared, whole
    nbr = nbr_ref[...]                # (TILE_V, K) exclusive tile
    mask = mask_ref[...]              # (TILE_V, K) exclusive tile
    v_total = ranks.shape[0]
    contrib = ranks[nbr] * inv_deg[nbr] * mask
    acc = jnp.sum(contrib, axis=1)
    o_ref[...] = (1.0 - damping) / v_total + damping * acc


@functools.partial(jax.jit, static_argnames=("damping",))
def pagerank_update_kernel(ranks, inv_deg, nbr_idx, nbr_mask, damping=0.85):
    """One PageRank sweep.

    Args:
      ranks:    f32[V]    current ranks (shared object).
      inv_deg:  f32[V]    1/out_degree per vertex (0 for sinks).
      nbr_idx:  i32[V,K]  padded in-neighbor ids (exclusive object).
      nbr_mask: f32[V,K]  1.0 for real edges, 0.0 for padding.
    Returns:
      f32[V] updated ranks.
    """
    v, k = nbr_idx.shape
    assert v % TILE_V == 0, f"V={v} must be a multiple of {TILE_V}"
    grid = (v // TILE_V,)
    return pl.pallas_call(
        functools.partial(_kernel, damping=damping),
        grid=grid,
        in_specs=[
            pl.BlockSpec((v,), lambda i: (0,)),            # ranks: whole
            pl.BlockSpec((v,), lambda i: (0,)),            # inv_deg: whole
            pl.BlockSpec((TILE_V, k), lambda i: (i, 0)),   # nbr tile
            pl.BlockSpec((TILE_V, k), lambda i: (i, 0)),   # mask tile
        ],
        out_specs=pl.BlockSpec((TILE_V,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((v,), jnp.float32),
        interpret=True,
    )(ranks, inv_deg, nbr_idx, nbr_mask)
