"""Hotspot 5-point stencil step as a Pallas kernel.

Each grid step owns a row band of the temperature grid (the paper's
thread-block tile); north/south halo rows are staged by overlapping block
reads — the VMEM analog of the halo accesses that make stencils "sharing"
workloads in Table 2.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_H = 64


def _kernel(t_ref, p_ref, o_ref, *, alpha, beta):
    # The whole padded grid is staged; this step's band (plus halo rows) is
    # carved out with a dynamic slice at the step's row offset.
    i = pl.program_id(0)
    t_full = t_ref[...]  # (H + 2, W)
    t = jax.lax.dynamic_slice(
        t_full, (i * TILE_H, 0), (TILE_H + 2, t_full.shape[1])
    )
    p = p_ref[...]  # (TILE_H, W)
    center = t[1:-1, :]
    north = t[:-2, :]
    south = t[2:, :]
    east = jnp.concatenate([center[:, 1:], center[:, -1:]], axis=1)
    west = jnp.concatenate([center[:, :1], center[:, :-1]], axis=1)
    o_ref[...] = center + alpha * (north + south + east + west - 4.0 * center) + beta * p


@functools.partial(jax.jit, static_argnames=("alpha", "beta"))
def hotspot_step_kernel(temp, power, alpha=0.1, beta=0.05):
    """One stencil time step.

    Args:
      temp:  f32[H, W] temperature grid (boundary rows are clamped).
      power: f32[H, W] power dissipation.
    Returns:
      f32[H, W] next temperature.
    """
    h, w = temp.shape
    assert h % TILE_H == 0
    grid = (h // TILE_H,)
    # Pad with clamped boundary rows so every band has a halo.
    padded = jnp.concatenate([temp[:1, :], temp, temp[-1:, :]], axis=0)
    return pl.pallas_call(
        functools.partial(_kernel, alpha=alpha, beta=beta),
        grid=grid,
        in_specs=[
            # Overlapping bands: block i covers rows [i*TILE_H, i*TILE_H +
            # TILE_H + 2) of the padded array. Element-level index_map with
            # unblocked overlap is awkward in older pallas; we pass the
            # whole padded array and slice per step instead.
            pl.BlockSpec((h + 2, w), lambda i: (0, 0)),
            pl.BlockSpec((TILE_H, w), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_H, w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, w), jnp.float32),
        interpret=True,
    )(padded, power)
