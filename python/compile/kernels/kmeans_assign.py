"""K-means assignment as an MXU-shaped Pallas kernel.

Hardware adaptation of the paper's Fig-7 K-means kernel: instead of the
CUDA per-thread feature loop, distances are computed as a matmul
(-2 * X @ C^T, the MXU-friendly form), tiled so each grid step holds one
point tile (CODA-exclusive, CGP) in VMEM while the centroid table
(CODA-shared, FGP) is broadcast to every step.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_N = 256


def _kernel(x_ref, c_ref, dist_ref, assign_ref):
    x = x_ref[...]          # (TILE_N, F) exclusive tile
    c = c_ref[...]          # (K, F)      shared
    x2 = jnp.sum(x * x, axis=1, keepdims=True)          # (TILE_N, 1)
    c2 = jnp.sum(c * c, axis=1)[None, :]                # (1, K)
    # The MXU product: (TILE_N, F) @ (F, K).
    xc = jnp.dot(x, c.T, preferred_element_type=jnp.float32)
    d2 = x2 - 2.0 * xc + c2                             # (TILE_N, K)
    dist_ref[...] = d2
    assign_ref[...] = jnp.argmin(d2, axis=1).astype(jnp.int32)


@jax.jit
def kmeans_assign_kernel(points, centroids):
    """Squared distances + nearest-centroid assignment.

    Args:
      points:    f32[N, F]
      centroids: f32[K, F]
    Returns:
      (f32[N, K] squared distances, i32[N] assignments)
    """
    n, f = points.shape
    k, f2 = centroids.shape
    assert f == f2 and n % TILE_N == 0
    grid = (n // TILE_N,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_N, f), lambda i: (i, 0)),
            pl.BlockSpec((k, f), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((TILE_N, k), lambda i: (i, 0)),
            pl.BlockSpec((TILE_N,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, k), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
        ],
        interpret=True,
    )(points, centroids)


@functools.partial(jax.jit, static_argnames=("k",))
def kmeans_update_centroids(points, assignments, k):
    """Centroid recomputation (plain jnp; bandwidth-bound scatter-add)."""
    one_hot = jax.nn.one_hot(assignments, k, dtype=points.dtype)  # (N, K)
    sums = one_hot.T @ points                                     # (K, F)
    counts = jnp.sum(one_hot, axis=0)[:, None]                    # (K, 1)
    return sums / jnp.maximum(counts, 1.0)
