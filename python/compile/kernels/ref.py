"""Pure-jnp oracles for the Pallas kernels — the correctness ground truth.

Every kernel in this package must match its oracle to float32 tolerance;
pytest + hypothesis enforce it (python/tests/).
"""

import jax
import jax.numpy as jnp


def pagerank_update_ref(ranks, inv_deg, nbr_idx, nbr_mask, damping=0.85):
    """Reference PageRank sweep (dense gather formulation)."""
    v = ranks.shape[0]
    contrib = ranks[nbr_idx] * inv_deg[nbr_idx] * nbr_mask
    return (1.0 - damping) / v + damping * jnp.sum(contrib, axis=1)


def kmeans_assign_ref(points, centroids):
    """Reference distances + assignment (explicit broadcast form)."""
    diff = points[:, None, :] - centroids[None, :, :]  # (N, K, F)
    d2 = jnp.sum(diff * diff, axis=2)                  # (N, K)
    return d2, jnp.argmin(d2, axis=1).astype(jnp.int32)


def kmeans_update_centroids_ref(points, assignments, k):
    """Reference centroid update via segment_sum."""
    sums = jax.ops.segment_sum(points, assignments, num_segments=k)
    counts = jax.ops.segment_sum(
        jnp.ones((points.shape[0],), points.dtype), assignments, num_segments=k
    )
    return sums / jnp.maximum(counts, 1.0)[:, None]


def hotspot_step_ref(temp, power, alpha=0.1, beta=0.05):
    """Reference stencil with clamped (replicated) boundaries."""
    north = jnp.concatenate([temp[:1, :], temp[:-1, :]], axis=0)
    south = jnp.concatenate([temp[1:, :], temp[-1:, :]], axis=0)
    west = jnp.concatenate([temp[:, :1], temp[:, :-1]], axis=1)
    east = jnp.concatenate([temp[:, 1:], temp[:, -1:]], axis=1)
    return temp + alpha * (north + south + east + west - 4.0 * temp) + beta * power


def pagerank_full_ref(nbr_idx, nbr_mask, out_deg, iters, damping=0.85):
    """Multi-iteration PageRank from a uniform start (e2e validation)."""
    v = nbr_idx.shape[0]
    ranks = jnp.full((v,), 1.0 / v, jnp.float32)
    inv_deg = jnp.where(out_deg > 0, 1.0 / jnp.maximum(out_deg, 1), 0.0).astype(
        jnp.float32
    )
    for _ in range(iters):
        ranks = pagerank_update_ref(ranks, inv_deg, nbr_idx, nbr_mask, damping)
    return ranks
