"""Layer-2 JAX compute graphs: the per-thread-block-batch functions the
Rust coordinator executes through PJRT. Each calls the Layer-1 Pallas
kernel so everything lowers into one HLO module per artifact.

The L3 coordinator owns iteration loops (the paper's runtime owns kernel
relaunch); these graphs are single sweeps over statically-shaped batches.
Rank buffers are donated on the rust side by re-feeding outputs.
"""

import jax
import jax.numpy as jnp

from .kernels import (
    hotspot_step_kernel,
    kmeans_assign_kernel,
    kmeans_update_centroids,
    pagerank_update_kernel,
)

# Artifact shapes — must match the constants in examples/*.rs.
PR_V = 8192          # vertices
PR_K = 16            # padded in-degree
KM_N = 4096          # points
KM_F = 8             # features
KM_K = 8             # clusters
HS_H = 128           # grid rows
HS_W = 128           # grid cols


def pagerank_update(ranks, inv_deg, nbr_idx, nbr_mask):
    """One damped PageRank sweep over the whole graph."""
    return (pagerank_update_kernel(ranks, inv_deg, nbr_idx, nbr_mask),)


def kmeans_assign(points, centroids):
    """Assignment step + fused centroid update (one Lloyd iteration)."""
    d2, assign = kmeans_assign_kernel(points, centroids)
    new_centroids = kmeans_update_centroids(points, assign, KM_K)
    # Mean intra-cluster distance: the convergence metric rust logs.
    inertia = jnp.mean(jnp.min(d2, axis=1))
    return assign.astype(jnp.float32), new_centroids, inertia[None]


def hotspot_step(temp, power):
    """One stencil time step."""
    return (hotspot_step_kernel(temp, power),)


def artifact_specs():
    """(name, fn, example_args) for every artifact `aot.py` exports."""
    f32 = jnp.float32
    i32 = jnp.int32
    return [
        (
            "pagerank_update",
            pagerank_update,
            (
                jax.ShapeDtypeStruct((PR_V,), f32),
                jax.ShapeDtypeStruct((PR_V,), f32),
                jax.ShapeDtypeStruct((PR_V, PR_K), i32),
                jax.ShapeDtypeStruct((PR_V, PR_K), f32),
            ),
        ),
        (
            "kmeans_assign",
            kmeans_assign,
            (
                jax.ShapeDtypeStruct((KM_N, KM_F), f32),
                jax.ShapeDtypeStruct((KM_K, KM_F), f32),
            ),
        ),
        (
            "hotspot_step",
            hotspot_step,
            (
                jax.ShapeDtypeStruct((HS_H, HS_W), f32),
                jax.ShapeDtypeStruct((HS_H, HS_W), f32),
            ),
        ),
    ]
