"""Kernel-vs-oracle correctness: the core L1 signal.

Hypothesis sweeps input values and (where the kernel allows) shapes; every
Pallas kernel must match its pure-jnp reference to float32 tolerance.
"""

import hypothesis
import hypothesis.extra.numpy as hnp
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import (
    hotspot_step_kernel,
    kmeans_assign_kernel,
    kmeans_update_centroids,
    pagerank_update_kernel,
)
from compile.kernels import ref

SETTINGS = hypothesis.settings(
    max_examples=25, deadline=None, derandomize=True,
    suppress_health_check=[hypothesis.HealthCheck.too_slow],
)

f32s = st.floats(-100.0, 100.0, width=32, allow_nan=False, allow_infinity=False)


def graph_inputs(v, k, seed):
    rng = np.random.default_rng(seed)
    nbr = rng.integers(0, v, size=(v, k)).astype(np.int32)
    mask = (rng.random((v, k)) < 0.7).astype(np.float32)
    out_deg = np.maximum(mask.sum(axis=1), 1).astype(np.float32)
    inv_deg = (1.0 / out_deg).astype(np.float32)
    ranks = rng.random(v).astype(np.float32)
    ranks /= ranks.sum()
    return ranks, inv_deg, nbr, mask


class TestPageRankKernel:
    @pytest.mark.parametrize("v,k", [(256, 4), (512, 8), (1024, 16)])
    def test_matches_ref_across_shapes(self, v, k):
        ranks, inv_deg, nbr, mask = graph_inputs(v, k, seed=v + k)
        got = pagerank_update_kernel(ranks, inv_deg, nbr, mask)
        want = ref.pagerank_update_ref(ranks, inv_deg, nbr, mask)
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-7)

    @SETTINGS
    @hypothesis.given(seed=st.integers(0, 2**31 - 1), damping=st.floats(0.0, 1.0))
    def test_matches_ref_random_values(self, seed, damping):
        ranks, inv_deg, nbr, mask = graph_inputs(256, 8, seed)
        got = pagerank_update_kernel(ranks, inv_deg, nbr, mask, damping=damping)
        want = ref.pagerank_update_ref(ranks, inv_deg, nbr, mask, damping=damping)
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-7)

    def test_rank_mass_conserved_on_regular_graph(self):
        # On a d-regular graph with no sinks, total rank mass stays 1.
        v, k = 512, 4
        rng = np.random.default_rng(0)
        nbr = rng.integers(0, v, size=(v, k)).astype(np.int32)
        mask = np.ones((v, k), np.float32)
        inv_deg = np.full(v, 1.0 / k, np.float32)
        ranks = np.full(v, 1.0 / v, np.float32)
        out = pagerank_update_kernel(ranks, inv_deg, nbr, mask)
        # Mass conservation holds when in-edges are a permutation of
        # out-edges; for random graphs it holds in expectation. Use a ring
        # graph (exact permutation) for the exact check.
        ring = np.stack([(np.arange(v) + i + 1) % v for i in range(k)], 1).astype(
            np.int32
        )
        out = pagerank_update_kernel(ranks, inv_deg, ring, mask)
        np.testing.assert_allclose(float(jnp.sum(out)), 1.0, rtol=1e-5)

    def test_full_iteration_converges(self):
        v, k = 256, 8
        ranks, inv_deg, nbr, mask = graph_inputs(v, k, seed=7)
        out_deg = (1.0 / inv_deg).astype(np.float32)
        want = ref.pagerank_full_ref(nbr, mask, out_deg, iters=20)
        got = jnp.full((v,), 1.0 / v, jnp.float32)
        for _ in range(20):
            got = pagerank_update_kernel(got, inv_deg, nbr, mask)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-7)


class TestKmeansKernel:
    @pytest.mark.parametrize("n,f,k", [(256, 4, 4), (512, 8, 8), (1024, 2, 16)])
    def test_matches_ref_across_shapes(self, n, f, k):
        rng = np.random.default_rng(n + f + k)
        pts = rng.normal(size=(n, f)).astype(np.float32)
        cen = rng.normal(size=(k, f)).astype(np.float32)
        d2, assign = kmeans_assign_kernel(pts, cen)
        d2_ref, assign_ref = ref.kmeans_assign_ref(pts, cen)
        np.testing.assert_allclose(d2, d2_ref, rtol=1e-4, atol=1e-4)
        np.testing.assert_array_equal(assign, assign_ref)

    @SETTINGS
    @hypothesis.given(
        pts=hnp.arrays(np.float32, (256, 4), elements=f32s),
        cen=hnp.arrays(np.float32, (8, 4), elements=f32s),
    )
    def test_matches_ref_random_values(self, pts, cen):
        hypothesis.assume(np.isfinite(pts).all() and np.isfinite(cen).all())
        d2, _ = kmeans_assign_kernel(pts, cen)
        d2_ref, _ = ref.kmeans_assign_ref(pts, cen)
        np.testing.assert_allclose(d2, d2_ref, rtol=1e-3, atol=1e-2)

    def test_distances_nonnegative_up_to_rounding(self):
        rng = np.random.default_rng(3)
        pts = rng.normal(size=(512, 8)).astype(np.float32) * 50
        cen = rng.normal(size=(8, 8)).astype(np.float32) * 50
        d2, _ = kmeans_assign_kernel(pts, cen)
        assert float(jnp.min(d2)) > -1e-2

    def test_centroid_update_matches_ref(self):
        rng = np.random.default_rng(11)
        pts = rng.normal(size=(512, 8)).astype(np.float32)
        assign = rng.integers(0, 8, size=512).astype(np.int32)
        got = kmeans_update_centroids(pts, assign, 8)
        want = ref.kmeans_update_centroids_ref(pts, assign, 8)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    def test_lloyd_inertia_decreases(self):
        rng = np.random.default_rng(5)
        pts = rng.normal(size=(512, 4)).astype(np.float32)
        cen = pts[:8].copy()
        inertias = []
        for _ in range(5):
            d2, assign = kmeans_assign_kernel(pts, cen)
            inertias.append(float(jnp.mean(jnp.min(d2, axis=1))))
            cen = np.asarray(kmeans_update_centroids(pts, assign, 8))
        assert inertias == sorted(inertias, reverse=True) or inertias[-1] <= inertias[0]


class TestHotspotKernel:
    @pytest.mark.parametrize("h,w", [(64, 64), (128, 128), (128, 64)])
    def test_matches_ref_across_shapes(self, h, w):
        rng = np.random.default_rng(h + w)
        temp = rng.random((h, w)).astype(np.float32) * 80
        power = rng.random((h, w)).astype(np.float32)
        got = hotspot_step_kernel(temp, power)
        want = ref.hotspot_step_ref(temp, power)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    @SETTINGS
    @hypothesis.given(
        temp=hnp.arrays(np.float32, (64, 64), elements=f32s),
        power=hnp.arrays(np.float32, (64, 64), elements=f32s),
        alpha=st.floats(0.0, 0.25),
    )
    def test_matches_ref_random_values(self, temp, power, alpha):
        got = hotspot_step_kernel(temp, power, alpha=alpha)
        want = ref.hotspot_step_ref(temp, power, alpha=alpha)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)

    def test_uniform_grid_is_fixed_point_without_power(self):
        temp = np.full((64, 64), 42.0, np.float32)
        power = np.zeros((64, 64), np.float32)
        out = hotspot_step_kernel(temp, power, beta=0.0)
        np.testing.assert_allclose(out, temp, rtol=1e-6)
