"""L2 model shape checks and AOT export round-trip (HLO text emission)."""

import pathlib
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.aot import export_all, to_hlo_text


def test_pagerank_update_shapes():
    v, k = model.PR_V, model.PR_K
    out = model.pagerank_update(
        jnp.full((v,), 1.0 / v, jnp.float32),
        jnp.full((v,), 0.25, jnp.float32),
        jnp.zeros((v, k), jnp.int32),
        jnp.zeros((v, k), jnp.float32),
    )
    assert len(out) == 1 and out[0].shape == (v,)


def test_kmeans_assign_shapes():
    pts = jnp.zeros((model.KM_N, model.KM_F), jnp.float32)
    cen = jnp.zeros((model.KM_K, model.KM_F), jnp.float32)
    assign, new_cen, inertia = model.kmeans_assign(pts, cen)
    assert assign.shape == (model.KM_N,)
    assert new_cen.shape == (model.KM_K, model.KM_F)
    assert inertia.shape == (1,)


def test_hotspot_step_shapes():
    t = jnp.zeros((model.HS_H, model.HS_W), jnp.float32)
    (out,) = model.hotspot_step(t, t)
    assert out.shape == (model.HS_H, model.HS_W)


def test_artifact_specs_cover_all_models():
    names = [name for name, _, _ in model.artifact_specs()]
    assert names == ["pagerank_update", "kmeans_assign", "hotspot_step"]


def test_hlo_text_is_parseable_entry_module():
    _, fn, args = model.artifact_specs()[2]  # hotspot: fastest to lower
    text = to_hlo_text(jax.jit(fn).lower(*args))
    assert "ENTRY" in text and "HloModule" in text
    # return_tuple: the root must be a tuple.
    assert "tuple(" in text or "(f32[" in text


def test_export_all_writes_files():
    with tempfile.TemporaryDirectory() as d:
        out = pathlib.Path(d)
        export_all(out)
        for name, _, _ in model.artifact_specs():
            p = out / f"{name}.hlo.txt"
            assert p.exists() and p.stat().st_size > 1000, name


def test_pagerank_artifact_numerics_vs_ref():
    """The exact function exported to rust matches the oracle."""
    from compile.kernels import ref

    v, k = model.PR_V, model.PR_K
    rng = np.random.default_rng(0)
    nbr = rng.integers(0, v, size=(v, k)).astype(np.int32)
    mask = (rng.random((v, k)) < 0.5).astype(np.float32)
    inv_deg = np.full(v, 1.0 / k, np.float32)
    ranks = np.full(v, 1.0 / v, np.float32)
    (got,) = model.pagerank_update(ranks, inv_deg, nbr, mask)
    want = ref.pagerank_update_ref(ranks, inv_deg, nbr, mask)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-8)
