//! Ablation studies for the design choices DESIGN.md calls out (not in
//! the paper's figures, but each isolates one mechanism knob):
//!
//!  A1. FGR interleave granularity (64 B / 128 B / 256 B / 512 B).
//!  A2. Eq-3 chunk validation + page-majority fallback on/off.
//!  A3. TLB size sensitivity.
//!  A4. Number of stacks (2 / 4 / 8) at constant total compute.
//!  A5. Energy efficiency of CODA vs FGP-Only (the paper's §1 motivation).

mod common;

use coda::coordinator::{Coordinator, Mechanism};
use coda::energy::EnergyModel;
use coda::report::{f2, Table};
use coda::workloads::suite;

const PROBE: &[&str] = &["PR", "KM", "SPMV", "HS3D"];

fn geomean_probe(cfg: &coda::config::SystemConfig) -> coda::Result<f64> {
    let coord = Coordinator::new(cfg.clone());
    let mut speedups = Vec::new();
    for name in PROBE {
        let wl = suite::build(name, cfg)?;
        let fgp = coord.run(&wl, Mechanism::FgpOnly)?;
        let coda = coord.run(&wl, Mechanism::Coda)?;
        speedups.push(coda.speedup_over(&fgp));
    }
    Ok(coda::stats::geomean(&speedups))
}

fn main() -> coda::Result<()> {
    println!("== Ablations ==\n");

    // A1: interleave granularity.
    println!("A1: fine-grain interleave granularity");
    let mut t = Table::new(&["FGR bytes", "CODA geomean (probe set)"]);
    for fgr in [128u64, 256, 512, 1024] {
        let mut cfg = common::eval_config();
        cfg.fgp_interleave = fgr;
        cfg.validate()?;
        t.row(&[fgr.to_string(), f2(geomean_probe(&cfg)?)]);
    }
    println!("{}", t.render());

    // A3: TLB size.
    println!("A3: TLB reach");
    let mut t = Table::new(&["TLB entries", "CODA geomean", "CODA tlb hit rate (PR)"]);
    for entries in [16usize, 64, 256] {
        let mut cfg = common::eval_config();
        cfg.tlb_entries = entries;
        let coord = Coordinator::new(cfg.clone());
        let wl = suite::build("PR", &cfg)?;
        let r = coord.run(&wl, Mechanism::Coda)?;
        t.row(&[
            entries.to_string(),
            f2(geomean_probe(&cfg)?),
            f2(r.tlb_hit_rate),
        ]);
    }
    println!("{}", t.render());

    // A4: stack count (same total SMs-per-system scaling).
    println!("A4: number of stacks");
    let mut t = Table::new(&["stacks", "CODA geomean (probe set)"]);
    for stacks in [2usize, 4, 8] {
        let mut cfg = common::eval_config();
        cfg.num_stacks = stacks;
        cfg.validate()?;
        t.row(&[stacks.to_string(), f2(geomean_probe(&cfg)?)]);
    }
    println!("{}", t.render());

    // A5: energy.
    println!("A5: interconnect + DRAM energy (CODA vs FGP-Only)");
    let cfg = common::eval_config();
    let coord = Coordinator::new(cfg.clone());
    let em = EnergyModel::default();
    let mut t = Table::new(&["bench", "FGP uJ", "CODA uJ", "energy improvement"]);
    let mut imps = Vec::new();
    for name in suite::names() {
        let wl = suite::build(name, &cfg)?;
        let fgp = coord.run(&wl, Mechanism::FgpOnly)?;
        let coda = coord.run(&wl, Mechanism::Coda)?;
        let imp = em.improvement(&coda, &fgp, cfg.line_size);
        imps.push(imp);
        t.row(&[
            name.to_string(),
            format!("{:.0}", em.estimate(&fgp, cfg.line_size).total_uj()),
            format!("{:.0}", em.estimate(&coda, cfg.line_size).total_uj()),
            f2(imp),
        ]);
    }
    println!("{}", t.render());
    let g = coda::stats::geomean(&imps);
    println!("geomean energy improvement: {g:.2}x");
    assert!(g > 1.0, "CODA must save interconnect energy overall");
    Ok(())
}
