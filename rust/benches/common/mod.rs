//! Shared helpers for the figure-regeneration benches.
#![allow(dead_code)]

use coda::config::SystemConfig;
use coda::coordinator::{Coordinator, Mechanism};
use coda::stats::RunReport;
use coda::workloads::suite;

/// The evaluation config: Table 1 with a per-category quick toggle.
pub fn eval_config() -> SystemConfig {
    let mut cfg = SystemConfig::default();
    // Lazy allocator means the 8 GB stacks cost nothing; keep Table 1.
    if std::env::var("CODA_BENCH_FAST").is_ok() {
        cfg.stack_capacity = 256 << 20;
    }
    cfg
}

/// Run one benchmark under several mechanisms.
pub fn run_mechs(
    name: &str,
    cfg: &SystemConfig,
    mechs: &[Mechanism],
) -> coda::Result<Vec<RunReport>> {
    let wl = suite::build(name, cfg)?;
    let coord = Coordinator::new(cfg.clone());
    coord.compare(&wl, mechs)
}

/// Geometric-mean speedup of `mech` over FGP-Only across a set of names.
pub fn geomean_speedup(
    names: &[&str],
    cfg: &SystemConfig,
    mech: Mechanism,
) -> coda::Result<f64> {
    let mut speedups = Vec::new();
    for name in names {
        let rs = run_mechs(name, cfg, &[Mechanism::FgpOnly, mech])?;
        speedups.push(rs[1].speedup_over(&rs[0]));
    }
    Ok(coda::stats::geomean(&speedups))
}
