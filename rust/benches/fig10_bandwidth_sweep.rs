//! Figure 10: speedup sensitivity to the Remote-network bandwidth
//! (16 / 32 / 64 / 128 / 256 GB/s). The paper's shape: CODA's benefit
//! shrinks as remote links get faster but stays positive even at 256 GB/s
//! (8%, up to 23%). The sweep runs under both DRAM timing backends — the
//! shape must survive bank-level row-buffer/refresh fidelity.

mod common;

use coda::config::MemBackendKind;
use coda::coordinator::Mechanism;
use coda::report::{f2, Table};
use coda::workloads::suite;

fn main() -> coda::Result<()> {
    println!("== Figure 10: sensitivity to remote bandwidth ==\n");
    let names = suite::names();
    for backend in [MemBackendKind::FixedLatency, MemBackendKind::BankLevel] {
        println!("-- DRAM backend: {backend} --");
        let mut t = Table::new(&["remote GB/s", "CODA geomean speedup", "max"]);
        let mut prev = f64::INFINITY;
        for bw in [16.0, 32.0, 64.0, 128.0, 256.0] {
            let mut cfg = common::eval_config();
            cfg.remote_bw_gbs = bw;
            cfg.mem_backend = backend;
            let mut speedups = Vec::new();
            for name in &names {
                let rs =
                    common::run_mechs(name, &cfg, &[Mechanism::FgpOnly, Mechanism::Coda])?;
                speedups.push(rs[1].speedup_over(&rs[0]));
            }
            let g = coda::stats::geomean(&speedups);
            let max = speedups.iter().cloned().fold(0.0, f64::max);
            t.row(&[format!("{bw}"), f2(g), f2(max)]);
            assert!(
                g <= prev * 1.05,
                "benefit must shrink (roughly monotonically) as remote BW grows \
                 (backend {backend})"
            );
            prev = g;
        }
        println!("{}", t.render());
    }
    println!("shape check: benefit decreases with remote bandwidth under both backends");
    Ok(())
}
