//! Figure 11: PageRank speedup vs graph regularity. Four graphs sorted by
//! coefficient of variation of edges-per-block (sigma/mu, §6.4); regular
//! graphs benefit most (paper: 55% regular vs 5% irregular), and CODA
//! never degrades.

mod common;

use coda::analysis::graph_regularity;
use coda::coordinator::{Coordinator, Mechanism};
use coda::report::{f2, Table};
use coda::workloads::graph::{CsrGraph, GraphSpec};
use coda::workloads::graphs::pagerank_on;

fn main() -> coda::Result<()> {
    let cfg = common::eval_config();
    println!("== Figure 11: PageRank vs graph regularity ==\n");
    let coord = Coordinator::new(cfg.clone());
    let specs = [
        ("regular (road-like)", GraphSpec::regular(98_304, 8.0, 11)),
        ("mild (web-like)", GraphSpec::irregular(98_304, 8.0, 0.5, 12)),
        ("skewed (social-like)", GraphSpec::irregular(98_304, 8.0, 1.0, 13)),
        ("power-law (hub-heavy)", GraphSpec::irregular(98_304, 8.0, 2.5, 14)),
    ];
    let mut t = Table::new(&["graph", "degree CV", "edges/block CV", "CODA speedup"]);
    let mut speedups = Vec::new();
    for (label, spec) in specs {
        let g = CsrGraph::generate(&spec);
        let (_, _, cv_block) = graph_regularity(&g.degrees(), 1024);
        let wl = pagerank_on(g.clone(), &cfg);
        let fgp = coord.run(&wl, Mechanism::FgpOnly)?;
        let coda = coord.run(&wl, Mechanism::Coda)?;
        let s = coda.speedup_over(&fgp);
        t.row(&[
            label.to_string(),
            f2(g.degree_cv()),
            f2(cv_block),
            f2(s),
        ]);
        assert!(s > 0.97, "CODA must not degrade performance in any case");
        speedups.push(s);
    }
    println!("{}", t.render());
    assert!(
        speedups[0] > speedups[3],
        "regular graphs must benefit more than irregular ones"
    );
    println!("shape check: benefit decreases with irregularity; never below 1x");
    Ok(())
}
