//! Figure 12: multiprogrammed mixes (one application per stack) —
//! CGP-Only per-stack placement vs FGP-Only. The paper's claim: CGP
//! hardware outperforms FGP-Only for every mix, because FGP makes every
//! application's traffic cross-stack by construction.

mod common;

use coda::multiprog::{run_mix, Mix, MixPlacement};
use coda::report::{f2, pct, Table};
use coda::workloads::suite;

fn main() -> coda::Result<()> {
    let cfg = common::eval_config();
    println!("== Figure 12: multiprogrammed workloads ==\n");
    let mixes: [[&str; 4]; 4] = [
        ["BFS", "KM", "CC", "TC"],
        ["PR", "NN", "MG", "HS3D"],
        ["DC", "SPMV", "DWT", "HS"],
        ["SSSP", "MM", "GC", "NW"],
    ];
    let mut t = Table::new(&["mix", "CGP/FGP speedup", "FGP remote", "CGP remote"]);
    for names in &mixes {
        let apps: Vec<_> = names
            .iter()
            .map(|n| suite::build(n, &cfg))
            .collect::<coda::Result<Vec<_>>>()?;
        let mix = Mix {
            apps: apps.iter().map(|a| a.as_ref()).collect(),
        };
        let (_, fgp) = run_mix(&cfg, &mix, MixPlacement::FgpOnly)?;
        let (_, cgp) = run_mix(&cfg, &mix, MixPlacement::CgpLocal)?;
        let s = fgp.cycles / cgp.cycles;
        t.row(&[
            names.join("+"),
            f2(s),
            pct(fgp.accesses.remote_fraction()),
            pct(cgp.accesses.remote_fraction()),
        ]);
        assert!(s > 1.0, "CGP-Only must outperform FGP-Only for all mixes");
    }
    println!("{}", t.render());
    Ok(())
}
