//! Figure 13: host-processor performance under fine- vs coarse-grain
//! interleaving. The paper's shape: FGP-Only outperforms CGP-Only by
//! ~1.48x for host execution — the reason dual-mode (not CGP-everywhere)
//! is the right design.

mod common;

use coda::host::run_host_sweep;
use coda::placement::{cgp_only_plan, PlacementPlan};
use coda::report::{f2, Table};
use coda::sim::map_objects;
use coda::workloads::suite;

fn main() -> coda::Result<()> {
    let cfg = common::eval_config();
    println!("== Figure 13: host-side interleaving granularity ==\n");
    let mut t = Table::new(&["bench", "FGP cycles", "CGP cycles", "FGP/CGP speedup"]);
    let mut speedups = Vec::new();
    for name in suite::names() {
        let wl = suite::build(name, &cfg)?;
        let n = wl.trace.objects.len();
        let (mut vm_f, base_f, _, _) = map_objects(&cfg, &wl.trace, &PlacementPlan::all_fgp(n))?;
        let (mut vm_c, base_c, _, _) = map_objects(&cfg, &wl.trace, &cgp_only_plan(n, &cfg))?;
        let r_f = run_host_sweep(&cfg, &wl.trace, &mut vm_f, &base_f);
        let r_c = run_host_sweep(&cfg, &wl.trace, &mut vm_c, &base_c);
        let s = r_c.cycles / r_f.cycles;
        speedups.push(s);
        t.row(&[
            name.to_string(),
            format!("{:.0}", r_f.cycles),
            format!("{:.0}", r_c.cycles),
            f2(s),
        ]);
    }
    println!("{}", t.render());
    let g = coda::stats::geomean(&speedups);
    println!("\ngeomean FGP-over-CGP speedup for host execution: {g:.2}x (paper: 1.48x)");
    assert!(g > 1.2, "host must prefer fine-grain interleaving");
    Ok(())
}
