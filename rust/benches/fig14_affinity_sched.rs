//! Figure 14: performance impact of affinity-based work scheduling alone
//! (FGP-Only + Affinity vs FGP-Only). The paper's shape: virtually no
//! impact anywhere except SAD, whose 61 thread-blocks cannot balance 16
//! SMs across 4 stacks. Also evaluates the §4.3.1 work-stealing extension
//! the paper sketches.

mod common;

use coda::coordinator::Mechanism;
use coda::report::{f2, Table};
use coda::workloads::suite;

fn main() -> coda::Result<()> {
    let cfg = common::eval_config();
    println!("== Figure 14: affinity-scheduling impact (FGP placement) ==\n");
    let mut t = Table::new(&["bench", "FGP+Affinity / FGP", "FGP+Stealing / FGP"]);
    let mut sad_ratio = 1.0;
    let mut others = Vec::new();
    for name in suite::names() {
        let rs = common::run_mechs(
            name,
            &cfg,
            &[Mechanism::FgpOnly, Mechanism::FgpAffinity],
        )?;
        let ratio = rs[1].speedup_over(&rs[0]);
        // Work-stealing on top of affinity (placement still FGP).
        let wl = suite::build(name, &cfg)?;
        let coord = coda::coordinator::Coordinator::new(cfg.clone());
        let plan = coda::placement::PlacementPlan::all_fgp(wl.trace.objects.len());
        let (mut vm, bases, _, _) = coda::sim::map_objects(&cfg, &wl.trace, &plan)?;
        let steal = coda::sim::KernelRun {
            cfg: &cfg,
            trace: &wl.trace,
            vm: &mut vm,
            obj_base: &bases,
            policy: coda::sched::Policy::AffinityStealing,
            migrate_on_first_touch: false,
        }
        .run();
        let _ = coord;
        let steal_ratio = rs[0].cycles / steal.cycles;
        t.row(&[name.to_string(), f2(ratio), f2(steal_ratio)]);
        if name == "SAD" {
            sad_ratio = ratio;
        } else {
            others.push(ratio);
        }
    }
    println!("{}", t.render());
    let min_other = others.iter().cloned().fold(f64::INFINITY, f64::min);
    println!(
        "\nnon-SAD minimum ratio: {min_other:.2} (paper: ~1.0); SAD: {sad_ratio:.2} (paper: degraded)"
    );
    assert!(min_other > 0.9, "non-SAD benchmarks must be virtually unaffected");
    assert!(
        sad_ratio < min_other,
        "SAD (61 blocks) must suffer the most from restricted scheduling"
    );
    Ok(())
}
