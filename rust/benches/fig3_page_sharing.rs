//! Figure 3 + Table 2: distribution of memory pages by the number of
//! thread-blocks that access each page, and the derived workload
//! categories, for all 20 benchmarks.

mod common;

use coda::report::{pct, Table};
use coda::sched::affinity_stack;
use coda::trace::{classify, sharing_histogram};
use coda::workloads::suite;

fn main() -> coda::Result<()> {
    let cfg = common::eval_config();
    println!("== Figure 3: page-sharing distribution ==\n");
    let mut t = Table::new(&[
        "bench", "pages", "1 TB", "2 TBs", "3-16", ">16", "~all", "1-stack", "category",
        "paper",
    ]);
    let mut matches = 0;
    for (name, paper_cat) in suite::ALL {
        let wl = suite::build(name, &cfg)?;
        let h = sharing_histogram(&wl.trace, cfg.page_size, |b| affinity_stack(b, &cfg));
        let f = h.fractions();
        let got = classify(&h);
        if got == *paper_cat {
            matches += 1;
        }
        t.row(&[
            name.to_string(),
            h.total.to_string(),
            pct(f[0]),
            pct(f[1]),
            pct(f[2]),
            pct(f[3]),
            pct(f[4]),
            pct(h.one_stack as f64 / h.total.max(1) as f64),
            got.to_string(),
            paper_cat.to_string(),
        ]);
    }
    println!("{}", t.render());
    println!("Table 2 category agreement: {matches}/20");
    assert_eq!(matches, 20, "all categories must match Table 2");
    Ok(())
}
