//! Figure 8: speedup of CODA over FGP-Only, CGP-Only, and the idealized
//! first-touch allocation (CGP-Only+FTA), for all 20 benchmarks — plus the
//! footnote-6 migration-based FTA variant and the per-category averages
//! (§6.1: block-exclusive 1.56x, core-exclusive 1.13x, sharing 1.29x;
//! headline geomean 1.31x).

mod common;

use coda::config::MemBackendKind;
use coda::coordinator::Mechanism;
use coda::report::{f2, pct, Table};
use coda::stats::geomean;
use coda::trace::Category;
use coda::workloads::suite;

fn main() -> coda::Result<()> {
    let cfg = common::eval_config();
    println!("== Figure 8: speedup over FGP-Only ==\n");
    let mechs = [
        Mechanism::FgpOnly,
        Mechanism::CgpOnly,
        Mechanism::CgpFta,
        Mechanism::MigrationFta,
        Mechanism::Coda,
    ];
    let mut t = Table::new(&["bench", "CGP-Only", "CGP+FTA", "Migr-FTA", "CODA", "category"]);
    let mut per_cat: std::collections::HashMap<Category, Vec<f64>> = Default::default();
    let mut coda_all = Vec::new();
    for (name, cat) in suite::ALL {
        let rs = common::run_mechs(name, &cfg, &mechs)?;
        let base = &rs[0];
        let coda = rs[4].speedup_over(base);
        per_cat.entry(*cat).or_default().push(coda);
        coda_all.push(coda);
        t.row(&[
            name.to_string(),
            f2(rs[1].speedup_over(base)),
            f2(rs[2].speedup_over(base)),
            f2(rs[3].speedup_over(base)),
            f2(coda),
            cat.to_string(),
        ]);
    }
    println!("{}", t.render());
    println!("\nper-category CODA geomean (paper: block-excl 1.56x, core-excl 1.13x, sharing 1.29x):");
    for (cat, v) in &per_cat {
        println!("  {:<16} {:.2}x (n={})", cat.to_string(), geomean(v), v.len());
    }
    let headline = geomean(&coda_all);
    println!("\nheadline CODA geomean: {headline:.3}x (paper: 1.31x)");
    assert!(headline > 1.1, "CODA must clearly beat the baseline");

    // Rerun the FGP vs CODA comparison under the bank-level DRAM backend:
    // higher-fidelity row-buffer/refresh timing must not change the
    // conclusion, only the absolute numbers (and it surfaces the
    // per-backend stats: row-hit rate, bank conflicts, refresh stalls).
    println!("\n== Figure 8 addendum: bank-level DRAM backend ==\n");
    let mut bank_cfg = common::eval_config();
    bank_cfg.mem_backend = MemBackendKind::BankLevel;
    let mut t = Table::new(&[
        "bench",
        "CODA (bank)",
        "row-hit%",
        "bank conflicts",
        "refresh stalls",
    ]);
    let mut bank_all = Vec::new();
    for (name, _) in suite::ALL {
        let rs = common::run_mechs(name, &bank_cfg, &[Mechanism::FgpOnly, Mechanism::Coda])?;
        let s = rs[1].speedup_over(&rs[0]);
        bank_all.push(s);
        t.row(&[
            name.to_string(),
            f2(s),
            pct(rs[1].row_hit_rate),
            rs[1].bank_conflicts.to_string(),
            rs[1].refresh_stalls.to_string(),
        ]);
    }
    println!("{}", t.render());
    let bank_headline = geomean(&bank_all);
    println!("bank-level CODA geomean: {bank_headline:.3}x (fixed: {headline:.3}x)");
    assert!(
        bank_headline > 1.05,
        "CODA must still beat FGP-Only under bank-level DRAM timing"
    );
    Ok(())
}
