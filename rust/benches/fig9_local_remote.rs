//! Figure 9: local vs remote data-access split, FGP-Only vs CODA, plus the
//! §6.2 per-category remote-reduction aggregates (paper: 47% block-excl,
//! 34% core-excl, 32% sharing; 38% overall).

mod common;

use coda::coordinator::Mechanism;
use coda::report::{pct, Table};
use coda::stats::mean;
use coda::trace::Category;
use coda::workloads::suite;

fn main() -> coda::Result<()> {
    let cfg = common::eval_config();
    println!("== Figure 9: local vs remote accesses ==\n");
    let mut t = Table::new(&[
        "bench", "FGP local", "FGP remote", "CODA local", "CODA remote", "reduction",
    ]);
    let mut per_cat: std::collections::HashMap<Category, Vec<f64>> = Default::default();
    let mut all = Vec::new();
    for (name, cat) in suite::ALL {
        let rs = common::run_mechs(name, &cfg, &[Mechanism::FgpOnly, Mechanism::Coda])?;
        let red = rs[1].remote_reduction_over(&rs[0]);
        per_cat.entry(*cat).or_default().push(red);
        all.push(red);
        t.row(&[
            name.to_string(),
            pct(rs[0].accesses.local_fraction()),
            pct(rs[0].accesses.remote_fraction()),
            pct(rs[1].accesses.local_fraction()),
            pct(rs[1].accesses.remote_fraction()),
            pct(red),
        ]);
    }
    println!("{}", t.render());
    println!("\nper-category mean remote reduction (paper: 47%/34%/32%):");
    for (cat, v) in &per_cat {
        println!("  {:<16} {}", cat.to_string(), pct(mean(v)));
    }
    println!("\noverall mean remote reduction: {} (paper: 38%)", pct(mean(&all)));
    Ok(())
}
