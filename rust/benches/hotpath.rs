//! Hot-path micro-benchmarks (§Performance in docs/ARCHITECTURE.md):
//! address mapping, TLB lookup, scheduler pick, event-driven simulation
//! throughput, and PJRT sweep latency.
//!
//! Besides the console table, the run emits `BENCH_hotpath.json` (path
//! overridable via `CODA_BENCH_JSON`) — the machine-readable perf
//! trajectory every hot-path PR records its before/after numbers from.
//! The headline series are the two full-run simulator benches, whose
//! `ops_per_sec` is simulated accesses per second.

mod common;

use coda::addr::{AddressMapper, Granularity};
use coda::coordinator::{Coordinator, Mechanism};
use coda::harness::{black_box, Bencher};
use coda::sched::{Policy, Scheduler};
use coda::session::Session;
use coda::spec::{ArrivalKind, ArrivalSpec, ExperimentSpec, WorkloadSel};
use coda::vm::{Pte, Tlb};
use coda::workloads::suite;

fn main() -> coda::Result<()> {
    let cfg = common::eval_config();
    let mut b = Bencher::new();

    println!("== hot-path micro-benchmarks ==\n");

    // Address mapping: THE per-access operation.
    let mapper = AddressMapper::new(&cfg);
    let n_ops = 1_000_000u64;
    let r = b.bench_n("addr::stack_of x1M (fgp+cgp mix)", n_ops as f64, || {
        let mut acc = 0usize;
        for i in 0..n_ops {
            let a = i.wrapping_mul(0x9E3779B97F4A7C15) & 0xFFFF_FFFF;
            let g = if i & 1 == 0 {
                Granularity::Fgp
            } else {
                Granularity::Cgp
            };
            acc = acc.wrapping_add(mapper.stack_of(a, g));
        }
        black_box(acc)
    });
    println!(
        "  -> {:.2} ns/op ({:.0} M ops/s)\n",
        r.mean_ns / n_ops as f64,
        r.throughput(n_ops as f64) / 1e6
    );

    // TLB lookup/fill mix.
    let mut tlb = Tlb::new(cfg.tlb_entries);
    let r = b.bench_n("tlb::lookup+fill x100K", 100_000.0, || {
        let mut acc = 0u64;
        for i in 0..100_000u64 {
            let vpn = (i * 7) & 0x3FF;
            match tlb.lookup(vpn) {
                Some(p) => acc = acc.wrapping_add(p.ppn),
                None => tlb.fill(
                    vpn,
                    Pte {
                        ppn: vpn,
                        granularity: Granularity::Fgp,
                        huge: false,
                    },
                ),
            }
        }
        black_box(acc)
    });
    println!("  -> {:.2} ns/op\n", r.mean_ns / 100_000.0);

    // Scheduler pick throughput.
    let r = b.bench_n("sched::next_for full drain (96K blocks)", 96_000.0, || {
        let mut s = Scheduler::new(Policy::Affinity, 96_000, &cfg);
        let mut n = 0u32;
        'outer: loop {
            for stack in 0..cfg.num_stacks {
                match s.next_for(stack) {
                    Some(_) => n += 1,
                    None => {
                        if s.empty() {
                            break 'outer;
                        }
                    }
                }
            }
        }
        black_box(n)
    });
    println!("  -> {:.1} ns/pick\n", r.mean_ns / 96_000.0);

    // End-to-end simulator throughput on a mid-size workload.
    let wl = suite::build("KM", &cfg)?;
    let accesses = wl.total_accesses();
    let coord = Coordinator::new(cfg.clone());
    let r = b.bench_n("sim: KM full run (CODA)", accesses as f64, || {
        coord.run(&wl, Mechanism::Coda).unwrap().cycles
    });
    println!(
        "  -> {:.1} ns/access, {:.2} M simulated accesses/s\n",
        r.mean_ns / accesses as f64,
        r.throughput(accesses as f64) / 1e6
    );

    let wl = suite::build("PR", &cfg)?;
    let accesses = wl.total_accesses();
    let r = b.bench_n("sim: PR full run (FGP-Only)", accesses as f64, || {
        coord.run(&wl, Mechanism::FgpOnly).unwrap().cycles
    });
    println!(
        "  -> {:.1} ns/access, {:.2} M simulated accesses/s\n",
        r.mean_ns / accesses as f64,
        r.throughput(accesses as f64) / 1e6
    );

    // Sharded-engine speedup: one multi-stack open-loop service stream,
    // sequential vs one shard per stack (`shard_stacks` 1 vs 0/auto).
    // Same spec both ways, so the pair is a direct parallel-efficiency
    // read on this machine.
    let wl = suite::build("KM", &cfg)?;
    let requests = 16u64;
    let svc_spec = |shards: &str| {
        let mut spec = ExperimentSpec::shared(
            vec![(WorkloadSel::Prebuilt(&wl), 0.0)],
            coda::multiprog::MixPlacement::CgpLocal,
            Policy::Affinity,
            coda::sched::FairnessPolicy::Fcfs,
        );
        spec.output.baselines = coda::spec::Baselines::None;
        spec.arrivals = Some(ArrivalSpec {
            kind: ArrivalKind::Trace,
            interarrivals: vec![500.0],
            requests: Some(requests),
            ..ArrivalSpec::default()
        });
        spec.overrides.push(("shard_stacks".into(), shards.into()));
        spec
    };
    let accesses = (wl.total_accesses() * requests) as f64;
    for (label, shards) in [("shard_stacks=1", "1"), ("shard_stacks=auto", "0")] {
        let r = b.bench_n(&format!("sim: KM service x{requests} ({label})"), accesses, || {
            Session::new(cfg.clone(), svc_spec(shards))
                .unwrap()
                .run()
                .unwrap()
                .run
                .cycles
        });
        println!(
            "  -> {:.1} ns/access, {:.2} M simulated accesses/s\n",
            r.mean_ns / accesses,
            r.throughput(accesses) / 1e6
        );
    }

    // PJRT artifact sweep latency (the runtime hot path), if built.
    let mut rt = coda::runtime::Runtime::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))?;
    if rt.artifact_exists("pagerank_update") {
        const V: usize = 8192;
        const K: usize = 16;
        let ranks = vec![1.0f32 / V as f32; V];
        let inv_deg = vec![1.0f32 / K as f32; V];
        let nbr: Vec<i32> = (0..V * K).map(|i| ((i / K + i % K + 1) % V) as i32).collect();
        let mask = vec![1.0f32; V * K];
        let exe = rt.load("pagerank_update")?;
        let flops = (V * K * 3) as f64; // mul+mul+add per edge slot
        let r = b.bench_n("pjrt: pagerank_update sweep (8192x16)", flops, || {
            coda::runtime::run_pagerank(exe, &ranks, &inv_deg, &nbr, &mask, V, K).unwrap()
        });
        println!(
            "  -> {:.2} ms/sweep, {:.2} GFLOP/s effective\n",
            r.mean_ns / 1e6,
            flops / r.mean_ns
        );
    }

    // Record the perf trajectory for this machine/commit.
    let path = b.write_json("BENCH_hotpath.json")?;
    println!("perf trajectory -> {path}");
    Ok(())
}
