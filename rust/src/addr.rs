//! Dual-mode address mapping (§4.2 of the paper).
//!
//! Each OS page carries a granularity bit: **FGP** (fine-grain page) stripes
//! the page across all memory stacks at `fgp_interleave` bytes, improving
//! processor-memory interface utilization for host / shared data; **CGP**
//! (coarse-grain page) places the entire page in a single stack, which is
//! what NDP-private data wants. Only the *mapping* of physical address to
//! stack changes — never the physical address itself — so caches, coherence,
//! and virtual address translation are untouched.
//!
//! With `N` stacks, FGP selects the stack from the interleave-granularity
//! bits of the address; CGP selects it from the lowest bits of the physical
//! page number (PPN). Because one FGP occupies `page_size / N` bytes in each
//! of the `N` stacks, converting a page between modes affects `N` aligned
//! consecutive pages at once — a **page-group** (§4.2, Fig 6).
//!
//! The module also implements the paper's §7.1 (complex / XOR address
//! mappings) and §7.2 (large pages) extensions.

use crate::config::SystemConfig;

/// A virtual address: what workload traces and the engine's access streams
/// carry. Crossing to the physical side requires [`crate::vm::VirtualMemory`]
/// translation — the newtype pair makes that boundary type-checked instead
/// of a comment. The payload stays `pub` so address arithmetic that is
/// genuinely bit-level (page masks, VPN shifts) can reach the raw `u64`
/// explicitly rather than through accessor noise.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct VirtualAddress(pub u64);

/// A physical address: what the mapper, the DRAM backends and the stack
/// routing consume. Produced only by translation (or by tests/benches that
/// model physical streams directly — `From<u64>` keeps those ergonomic).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct PhysicalAddress(pub u64);

impl From<u64> for VirtualAddress {
    #[inline]
    fn from(v: u64) -> Self {
        Self(v)
    }
}

impl From<VirtualAddress> for u64 {
    #[inline]
    fn from(v: VirtualAddress) -> u64 {
        v.0
    }
}

impl std::ops::Add<u64> for VirtualAddress {
    type Output = Self;
    /// Byte offset within a mapped object (`base + offset`): offsetting a
    /// virtual address yields a virtual address.
    #[inline]
    fn add(self, rhs: u64) -> Self {
        Self(self.0 + rhs)
    }
}

impl From<u64> for PhysicalAddress {
    #[inline]
    fn from(p: u64) -> Self {
        Self(p)
    }
}

impl From<PhysicalAddress> for u64 {
    #[inline]
    fn from(p: PhysicalAddress) -> u64 {
        p.0
    }
}

impl std::ops::Add<u64> for PhysicalAddress {
    type Output = Self;
    #[inline]
    fn add(self, rhs: u64) -> Self {
        Self(self.0 + rhs)
    }
}

/// Page granularity mode: the PTE/TLB/cache-line granularity bit.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Granularity {
    /// Fine-grain: page striped across all stacks (the default, as today).
    Fgp,
    /// Coarse-grain: entire page resident in one stack (NDP-private data).
    Cgp,
}

/// The dual-mode address mapper. Cheap to copy; used on every simulated
/// memory access, so everything is shift/mask arithmetic.
#[derive(Clone, Copy, Debug)]
pub struct AddressMapper {
    stack_shift_fgp: u32,
    stack_shift_cgp: u32,
    stack_mask: u64,
    page_shift: u32,
    /// Optional XOR-fold of higher address bits into the stack-selection
    /// bits (§7.1 complex mappings; DRAMA-style channel hashing).
    xor_fold: bool,
}

impl AddressMapper {
    pub fn new(cfg: &SystemConfig) -> Self {
        assert!(cfg.num_stacks.is_power_of_two());
        Self {
            stack_shift_fgp: cfg.fgp_interleave.trailing_zeros(),
            stack_shift_cgp: cfg.page_size.trailing_zeros(),
            stack_mask: cfg.num_stacks as u64 - 1,
            page_shift: cfg.page_size.trailing_zeros(),
            xor_fold: false,
        }
    }

    /// Enable the §7.1 XOR-folded ("complex") mapping variant: stack bits
    /// are XORed with a higher-order bit window, the scheme used by modern
    /// memory controllers to spread conflict patterns. CODA's dual-mode
    /// mechanism still works because the *same* fold is applied in both
    /// modes (bits are swapped, not consumed).
    pub fn with_xor_fold(mut self, enable: bool) -> Self {
        self.xor_fold = enable;
        self
    }

    /// Number of stacks this mapper selects among.
    #[inline]
    pub fn num_stacks(&self) -> usize {
        (self.stack_mask + 1) as usize
    }

    /// Physical page number of a physical address.
    #[inline]
    pub fn ppn(&self, paddr: u64) -> u64 {
        paddr >> self.page_shift
    }

    #[inline]
    fn fold(&self, base: u64, addr: u64) -> u64 {
        if self.xor_fold {
            // Fold a disjoint higher window (above the page bits) into the
            // selection, mirroring channel-hash XOR schemes.
            (base ^ (addr >> (self.page_shift + 9))) & self.stack_mask
        } else {
            base & self.stack_mask
        }
    }

    /// Which stack a physical address maps to, given the page's granularity
    /// bit. This is THE hot operation: every simulated memory request calls
    /// it once. Accepts anything convertible to [`PhysicalAddress`] (the
    /// newtype or a raw `u64`), so typed engine code and bit-level tests
    /// share one entry point.
    #[inline]
    pub fn stack_of(&self, paddr: impl Into<PhysicalAddress>, g: Granularity) -> usize {
        let paddr = paddr.into().0;
        let raw = match g {
            Granularity::Fgp => paddr >> self.stack_shift_fgp,
            Granularity::Cgp => paddr >> self.stack_shift_cgp,
        };
        self.fold(raw, paddr) as usize
    }

    /// For a CGP, the stack is a pure function of the PPN.
    #[inline]
    pub fn stack_of_ppn_cgp(&self, ppn: u64) -> usize {
        self.fold(ppn, ppn << self.page_shift) as usize
    }

    /// Number of stack-selection bits (`log2(num_stacks)`).
    #[inline]
    fn stack_bits(&self) -> u32 {
        (self.stack_mask + 1).trailing_zeros()
    }

    #[inline]
    fn shift_for(&self, g: Granularity) -> u32 {
        match g {
            Granularity::Fgp => self.stack_shift_fgp,
            Granularity::Cgp => self.stack_shift_cgp,
        }
    }

    /// Split a physical address into `(stack, stack-local offset)` under a
    /// granularity: the local offset is the address with the
    /// stack-selection bits removed, i.e. the byte position inside the
    /// owning stack's share of the address space. [`Self::compose`] is the
    /// exact inverse; together they witness that dual-mode decode is a
    /// bijection (no two physical bytes alias one stack-local byte).
    #[inline]
    pub fn decompose(&self, paddr: impl Into<PhysicalAddress>, g: Granularity) -> (usize, u64) {
        let paddr = paddr.into().0;
        let shift = self.shift_for(g);
        let stack = self.stack_of(paddr, g);
        let low = paddr & ((1u64 << shift) - 1);
        let high = (paddr >> shift) >> self.stack_bits();
        (stack, (high << shift) | low)
    }

    /// Inverse of [`Self::decompose`]: rebuild the physical address that
    /// maps to `stack` at stack-local offset `local`.
    #[inline]
    pub fn compose(&self, stack: usize, local: u64, g: Granularity) -> u64 {
        let shift = self.shift_for(g);
        let low = local & ((1u64 << shift) - 1);
        let high = local >> shift;
        // Address with the stack-selection bits zeroed; all bits the XOR
        // fold sources live above the selection window, so they are already
        // final here and the fold can be inverted exactly.
        let base = ((high << self.stack_bits()) << shift) | low;
        let fold_src = if self.xor_fold {
            (base >> (self.page_shift + 9)) & self.stack_mask
        } else {
            0
        };
        let raw = (stack as u64 ^ fold_src) & self.stack_mask;
        base | (raw << shift)
    }

    /// Page-group index of a PPN: groups of `N` aligned consecutive pages
    /// convert FGP<->CGP together (§4.2).
    #[inline]
    pub fn page_group(&self, ppn: u64) -> u64 {
        ppn / (self.stack_mask + 1)
    }

    /// First PPN of a page-group.
    #[inline]
    pub fn group_base_ppn(&self, group: u64) -> u64 {
        group * (self.stack_mask + 1)
    }

    /// Bytes of a given FGP page resident in each stack
    /// (`page_size / num_stacks`).
    pub fn fgp_bytes_per_stack(&self, cfg: &SystemConfig) -> u64 {
        cfg.page_size / cfg.num_stacks as u64
    }
}

/// Large-page variant (§7.2): identical math at 2 MB granularity. We expose
/// it as a separate constructor so the page-management layer can mix 4 KB
/// and 2 MB regions.
pub fn large_page_mapper(cfg: &SystemConfig) -> AddressMapper {
    let mut large = cfg.clone();
    large.page_size = 2 << 20;
    AddressMapper::new(&large)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> SystemConfig {
        SystemConfig::default()
    }

    #[test]
    fn fgp_stripes_at_interleave_granularity() {
        let m = AddressMapper::new(&cfg());
        // 128-byte stripes round-robin over 4 stacks.
        for chunk in 0..16u64 {
            let addr = chunk * 128;
            assert_eq!(m.stack_of(addr, Granularity::Fgp), (chunk % 4) as usize);
        }
        // All bytes within one stripe land in the same stack.
        for b in 0..128u64 {
            assert_eq!(m.stack_of(b, Granularity::Fgp), 0);
            assert_eq!(m.stack_of(128 + b, Granularity::Fgp), 1);
        }
    }

    #[test]
    fn cgp_keeps_whole_page_in_one_stack() {
        let m = AddressMapper::new(&cfg());
        for page in 0..8u64 {
            let base = page * 4096;
            let s0 = m.stack_of(base, Granularity::Cgp);
            assert_eq!(s0, (page % 4) as usize, "PPN low bits select the stack");
            for off in [0u64, 1, 127, 128, 2048, 4095] {
                assert_eq!(m.stack_of(base + off, Granularity::Cgp), s0);
            }
        }
    }

    #[test]
    fn paper_fig5_bit_positions() {
        // Paper example: 4 stacks, 4KB pages -> CGP uses bits [13:12].
        // (The paper's FGP example uses bits [11:10], i.e. 1KB stripes; our
        // default FGR is the evaluated 128 B -> bits [8:7].)
        let m = AddressMapper::new(&cfg());
        let addr = 0b11_0000_0000_0000u64; // bits 13:12 = 0b11
        assert_eq!(m.stack_of(addr, Granularity::Cgp), 3);
        let addr = 0b1_1000_0000u64; // bits 8:7 = 0b11
        assert_eq!(m.stack_of(addr, Granularity::Fgp), 3);
    }

    #[test]
    fn fgp_page_touches_every_stack_equally() {
        let c = cfg();
        let m = AddressMapper::new(&c);
        let mut counts = vec![0u64; c.num_stacks];
        let base = 7 * c.page_size;
        for off in (0..c.page_size).step_by(c.fgp_interleave as usize) {
            counts[m.stack_of(base + off, Granularity::Fgp)] += 1;
        }
        let per = c.page_size / c.fgp_interleave / c.num_stacks as u64;
        assert!(counts.iter().all(|&n| n == per), "{counts:?}");
    }

    #[test]
    fn page_group_math() {
        let m = AddressMapper::new(&cfg());
        assert_eq!(m.page_group(0), 0);
        assert_eq!(m.page_group(3), 0);
        assert_eq!(m.page_group(4), 1);
        assert_eq!(m.group_base_ppn(1), 4);
        // The 4 pages of one group map CGP onto 4 distinct stacks, i.e. a
        // group provides exactly one page of capacity per stack -- the
        // space-conservation property of Fig 6.
        let stacks: Vec<usize> = (4..8).map(|p| m.stack_of_ppn_cgp(p)).collect();
        let mut sorted = stacks.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2, 3]);
    }

    #[test]
    fn eight_stacks() {
        let mut c = cfg();
        c.num_stacks = 8;
        c.fgp_interleave = 128; // 128*8=1024 <= 4096 ok
        c.validate().unwrap();
        let m = AddressMapper::new(&c);
        for chunk in 0..32u64 {
            assert_eq!(m.stack_of(chunk * 128, Granularity::Fgp), (chunk % 8) as usize);
        }
        assert_eq!(m.page_group(15), 1);
    }

    #[test]
    fn xor_fold_preserves_page_residency() {
        // §7.1: under the complex mapping, a CGP must still be fully
        // resident in a single stack.
        let m = AddressMapper::new(&cfg()).with_xor_fold(true);
        for page in 0..64u64 {
            let base = page * 4096;
            let s = m.stack_of(base, Granularity::Cgp);
            for off in [1u64, 129, 1024, 4095] {
                assert_eq!(m.stack_of(base + off, Granularity::Cgp), s);
            }
        }
    }

    #[test]
    fn xor_fold_still_balances_fgp() {
        let c = cfg();
        let m = AddressMapper::new(&c).with_xor_fold(true);
        let mut counts = vec![0u64; c.num_stacks];
        for off in (0..(1u64 << 22)).step_by(c.fgp_interleave as usize) {
            counts[m.stack_of(off, Granularity::Fgp)] += 1;
        }
        let total: u64 = counts.iter().sum();
        for &n in &counts {
            let share = n as f64 / total as f64;
            assert!((share - 0.25).abs() < 0.01, "{counts:?}");
        }
    }

    #[test]
    fn decompose_compose_roundtrip() {
        for fold in [false, true] {
            let m = AddressMapper::new(&cfg()).with_xor_fold(fold);
            for g in [Granularity::Fgp, Granularity::Cgp] {
                for addr in [0u64, 1, 127, 128, 4095, 4096, 0xDEAD_BEEF, 1 << 33] {
                    let (s, off) = m.decompose(addr, g);
                    assert_eq!(s, m.stack_of(addr, g));
                    assert_eq!(m.compose(s, off, g), addr, "fold={fold} {g:?} {addr:#x}");
                }
            }
        }
    }

    #[test]
    fn compose_targets_requested_stack() {
        let m = AddressMapper::new(&cfg());
        for stack in 0..4usize {
            for local in [0u64, 100, 5000, 1 << 20] {
                for g in [Granularity::Fgp, Granularity::Cgp] {
                    let addr = m.compose(stack, local, g);
                    assert_eq!(m.stack_of(addr, g), stack);
                    assert_eq!(m.decompose(addr, g), (stack, local));
                }
            }
        }
    }

    #[test]
    fn large_page_mapper_uses_bits_22_21() {
        // §7.2: for 2MB pages, bits [22:21] select the stack in CGP mode.
        let m = large_page_mapper(&cfg());
        let addr = 0b11u64 << 21;
        assert_eq!(m.stack_of(addr, Granularity::Cgp), 3);
        let s = m.stack_of(5 * (2 << 20), Granularity::Cgp);
        for off in [0u64, 4096, 1 << 20, (2 << 20) - 1] {
            assert_eq!(m.stack_of(5 * (2 << 20) + off, Granularity::Cgp), s);
        }
    }
}
