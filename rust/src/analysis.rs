//! The compiler/profiler substrate of §4.3.2.
//!
//! The paper implements an LLVM `FunctionPass` that walks every
//! `GetElementPtrInst` in a GPU kernel and symbolically checks whether the
//! index expression has a **runtime-constant stride between two consecutive
//! thread-blocks**, using only kernel-invocation constants (parameters,
//! block/grid dimensions, global constants), the thread index, the block
//! index, and local loop indices. We reproduce that decision procedure over
//! a small kernel IR: each static memory access is an index [`Expr`]; the
//! analyzer normalizes it to an affine form
//!
//! ```text
//!   index = s_b * blockIdx + s_t * threadIdx + sum_i s_i * loop_i + k
//! ```
//!
//! with symbolic (parameter-dependent) coefficients. If normalization
//! succeeds, the inter-block stride `s_b` and the per-block footprint `B`
//! are runtime constants computable before launch — the object is
//! **regular** and a CGP-placement candidate. If the expression contains a
//! data-dependent term (pointer chasing, CSR neighbor lists), the object is
//! **irregular** and falls back to the trace profiler, exactly as the paper
//! falls back to profiler-assisted estimation for input-dependent patterns.

use crate::trace::KernelTrace;
use std::collections::HashMap;

/// Index expressions of the kernel IR (the analog of LLVM GEP index
/// computation trees).
#[derive(Clone, Debug, PartialEq)]
pub enum Expr {
    /// Integer literal.
    Const(i64),
    /// Kernel-invocation constant (parameter, e.g. `nfeatures`).
    Param(&'static str),
    /// Flattened block index (`blockIdx.y * gridDim.x + blockIdx.x`).
    BlockIdx,
    /// `blockDim.x` (threads per block) — an invocation constant.
    BlockDim,
    /// Thread index within the block.
    ThreadIdx,
    /// A kernel-local loop induction variable with extent `Expr`.
    Loop(u32, Box<Expr>),
    /// A value loaded from memory (data-dependent; kills regularity).
    Indirect,
    Add(Box<Expr>, Box<Expr>),
    Sub(Box<Expr>, Box<Expr>),
    Mul(Box<Expr>, Box<Expr>),
    Div(Box<Expr>, Box<Expr>),
    Rem(Box<Expr>, Box<Expr>),
}

impl Expr {
    pub fn add(a: Expr, b: Expr) -> Expr {
        Expr::Add(Box::new(a), Box::new(b))
    }
    pub fn mul(a: Expr, b: Expr) -> Expr {
        Expr::Mul(Box::new(a), Box::new(b))
    }
    pub fn sub(a: Expr, b: Expr) -> Expr {
        Expr::Sub(Box::new(a), Box::new(b))
    }

    /// The canonical global thread id `blockIdx * blockDim + threadIdx`
    /// (the `pid` of the paper's Fig 7 K-means snippet).
    pub fn pid() -> Expr {
        Expr::add(Expr::mul(Expr::BlockIdx, Expr::BlockDim), Expr::ThreadIdx)
    }
}

/// A symbolic constant: `coeff * product(params) + ...` represented as a
/// polynomial over parameters. Multiplication of two parameter-dependent
/// terms is allowed (e.g. `nfeatures * blockDim`); anything involving
/// blockIdx/threadIdx is tracked separately by [`LinForm`].
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SymConst {
    /// monomial (sorted param list) -> integer coefficient.
    terms: HashMap<Vec<&'static str>, i64>,
}

impl SymConst {
    pub fn zero() -> Self {
        Self::default()
    }

    pub fn constant(c: i64) -> Self {
        let mut s = Self::default();
        if c != 0 {
            s.terms.insert(Vec::new(), c);
        }
        s
    }

    pub fn param(p: &'static str) -> Self {
        let mut s = Self::default();
        s.terms.insert(vec![p], 1);
        s
    }

    pub fn is_zero(&self) -> bool {
        self.terms.is_empty()
    }

    /// As a plain integer if parameter-free.
    pub fn as_const(&self) -> Option<i64> {
        if self.terms.is_empty() {
            return Some(0);
        }
        if self.terms.len() == 1 {
            let empty: Vec<&'static str> = Vec::new();
            if let Some(c) = self.terms.get(&empty) {
                return Some(*c);
            }
        }
        None
    }

    pub fn add(&self, other: &Self) -> Self {
        let mut out = self.clone();
        for (m, c) in &other.terms {
            let e = out.terms.entry(m.clone()).or_insert(0);
            *e += c;
            if *e == 0 {
                out.terms.remove(m);
            }
        }
        out
    }

    pub fn neg(&self) -> Self {
        let mut out = self.clone();
        for c in out.terms.values_mut() {
            *c = -*c;
        }
        out
    }

    pub fn mul(&self, other: &Self) -> Self {
        let mut out = Self::default();
        for (m1, c1) in &self.terms {
            for (m2, c2) in &other.terms {
                let mut m = m1.clone();
                m.extend(m2.iter().copied());
                m.sort_unstable();
                let e = out.terms.entry(m).or_insert(0);
                *e += c1 * c2;
                if *e == 0 {
                    // normalize away cancelled monomials lazily
                }
            }
        }
        out.terms.retain(|_, c| *c != 0);
        out
    }

    /// Evaluate with a parameter environment.
    pub fn eval(&self, env: &ParamEnv) -> i64 {
        self.terms
            .iter()
            .map(|(m, c)| c * m.iter().map(|p| env.get(p)).product::<i64>())
            .sum()
    }
}

/// Runtime values of kernel-invocation constants.
#[derive(Clone, Debug, Default)]
pub struct ParamEnv {
    vals: HashMap<&'static str, i64>,
    pub block_dim: i64,
}

impl ParamEnv {
    pub fn new(block_dim: i64) -> Self {
        Self {
            vals: HashMap::new(),
            block_dim,
        }
    }

    pub fn with(mut self, name: &'static str, v: i64) -> Self {
        self.vals.insert(name, v);
        self
    }

    pub fn get(&self, name: &str) -> i64 {
        if name == "__blockDim" {
            return self.block_dim;
        }
        *self
            .vals
            .get(name)
            .unwrap_or_else(|| panic!("unbound kernel parameter {name}"))
    }
}

/// Affine normal form over (blockIdx, threadIdx, loop vars).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LinForm {
    pub block: SymConst,
    pub thread: SymConst,
    /// loop var id -> (coefficient, extent as SymConst)
    pub loops: Vec<(u32, SymConst, SymConst)>,
    pub konst: SymConst,
}

impl LinForm {
    fn constant(s: SymConst) -> Self {
        Self {
            konst: s,
            ..Default::default()
        }
    }

    fn is_const(&self) -> bool {
        self.block.is_zero() && self.thread.is_zero() && self.loops.is_empty()
    }

    fn add(&self, o: &Self) -> Self {
        let mut loops = self.loops.clone();
        for (id, c, ext) in &o.loops {
            if let Some(e) = loops.iter_mut().find(|(i, _, _)| i == id) {
                e.1 = e.1.add(c);
            } else {
                loops.push((*id, c.clone(), ext.clone()));
            }
        }
        loops.retain(|(_, c, _)| !c.is_zero());
        Self {
            block: self.block.add(&o.block),
            thread: self.thread.add(&o.thread),
            loops,
            konst: self.konst.add(&o.konst),
        }
    }

    fn neg(&self) -> Self {
        Self {
            block: self.block.neg(),
            thread: self.thread.neg(),
            loops: self
                .loops
                .iter()
                .map(|(i, c, e)| (*i, c.neg(), e.clone()))
                .collect(),
            konst: self.konst.neg(),
        }
    }

    /// Multiply by a pure symbolic constant.
    fn scale(&self, s: &SymConst) -> Self {
        Self {
            block: self.block.mul(s),
            thread: self.thread.mul(s),
            loops: self
                .loops
                .iter()
                .map(|(i, c, e)| (*i, c.mul(s), e.clone()))
                .collect(),
            konst: self.konst.mul(s),
        }
    }
}

/// Result of normalizing one index expression.
#[derive(Clone, Debug, PartialEq)]
pub enum IndexForm {
    /// Affine in (blockIdx, threadIdx, loops) with symbolic coefficients.
    Affine(LinForm),
    /// Contains data-dependent or non-affine terms.
    Irregular,
}

/// Normalize an expression to affine form (the GEP walk).
pub fn normalize(e: &Expr) -> IndexForm {
    use IndexForm::*;
    match e {
        Expr::Const(c) => Affine(LinForm::constant(SymConst::constant(*c))),
        Expr::Param(p) => Affine(LinForm::constant(SymConst::param(p))),
        Expr::BlockDim => Affine(LinForm::constant(SymConst::param("__blockDim"))),
        Expr::BlockIdx => Affine(LinForm {
            block: SymConst::constant(1),
            ..Default::default()
        }),
        Expr::ThreadIdx => Affine(LinForm {
            thread: SymConst::constant(1),
            ..Default::default()
        }),
        Expr::Loop(id, extent) => match normalize(extent) {
            Affine(f) if f.is_const() => Affine(LinForm {
                loops: vec![(*id, SymConst::constant(1), f.konst)],
                ..Default::default()
            }),
            _ => Irregular,
        },
        Expr::Indirect => Irregular,
        Expr::Add(a, b) => match (normalize(a), normalize(b)) {
            (Affine(x), Affine(y)) => Affine(x.add(&y)),
            _ => Irregular,
        },
        Expr::Sub(a, b) => match (normalize(a), normalize(b)) {
            (Affine(x), Affine(y)) => Affine(x.add(&y.neg())),
            _ => Irregular,
        },
        Expr::Mul(a, b) => match (normalize(a), normalize(b)) {
            (Affine(x), Affine(y)) if y.is_const() => Affine(x.scale(&y.konst)),
            (Affine(x), Affine(y)) if x.is_const() => Affine(y.scale(&x.konst)),
            _ => Irregular,
        },
        // Division/modulo of a pure constant by a pure constant stays
        // symbolic-constant only when exact at runtime; we conservatively
        // treat any div/rem with non-constant operands as irregular (the
        // paper's analysis does the same: such indices are not
        // runtime-constant-strided).
        Expr::Div(a, b) | Expr::Rem(a, b) => match (normalize(a), normalize(b)) {
            (Affine(x), Affine(y)) if x.is_const() && y.is_const() => {
                // Cannot fold symbolically without values; keep as irregular
                // unless both are literal integers.
                match (x.konst.as_const(), y.konst.as_const()) {
                    (Some(xa), Some(yb)) if yb != 0 => {
                        let v = if matches!(e, Expr::Div(_, _)) {
                            xa / yb
                        } else {
                            xa % yb
                        };
                        Affine(LinForm::constant(SymConst::constant(v)))
                    }
                    _ => Irregular,
                }
            }
            _ => Irregular,
        },
    }
}

/// One static memory access in a kernel: `object[index] (elem_size bytes)`.
#[derive(Clone, Debug)]
pub struct AccessExpr {
    pub object: u16,
    pub index: Expr,
    pub elem_size: u32,
}

/// The kernel IR: what the compiler pass sees.
#[derive(Clone, Debug)]
pub struct KernelIr {
    pub name: String,
    pub accesses: Vec<AccessExpr>,
}

/// Per-object outcome of the compile-time analysis.
#[derive(Clone, Debug, PartialEq)]
pub enum ObjectPattern {
    /// Runtime-constant inter-block stride; `B` = per-block footprint bytes,
    /// `stride` = bytes between block b and b+1's footprints.
    Regular { stride: i64, footprint: i64 },
    /// Same data accessed by every block (block coefficient zero).
    BlockInvariant { footprint: i64 },
    /// Data-dependent or non-affine (falls back to the profiler).
    Irregular,
}

/// Run the compile-time analysis for a kernel over all its objects,
/// evaluating symbolic results with the launch-time parameter values (this
/// is the "insert instructions in the host code to compute the stride at
/// runtime" step of §4.3.2).
pub fn analyze_kernel(ir: &KernelIr, env: &ParamEnv) -> HashMap<u16, ObjectPattern> {
    let mut per_obj: HashMap<u16, Vec<(&AccessExpr, IndexForm)>> = HashMap::new();
    for a in &ir.accesses {
        per_obj.entry(a.object).or_default().push((a, normalize(&a.index)));
    }
    let mut out = HashMap::new();
    for (obj, forms) in per_obj {
        let mut pattern: Option<ObjectPattern> = None;
        for (acc, form) in forms {
            let p = match form {
                IndexForm::Irregular => ObjectPattern::Irregular,
                IndexForm::Affine(f) => {
                    let stride_elems = f.block.eval(env);
                    // Footprint: index range within one block (threadIdx in
                    // [0, blockDim), each loop var in [0, extent)).
                    let thread_span = f.thread.eval(env).abs() * (env.block_dim - 1).max(0);
                    let loop_span: i64 = f
                        .loops
                        .iter()
                        .map(|(_, c, ext)| c.eval(env).abs() * (ext.eval(env) - 1).max(0))
                        .sum();
                    let footprint =
                        (thread_span + loop_span + 1) * acc.elem_size as i64;
                    if stride_elems == 0 {
                        ObjectPattern::BlockInvariant { footprint }
                    } else {
                        ObjectPattern::Regular {
                            stride: stride_elems * acc.elem_size as i64,
                            footprint,
                        }
                    }
                }
            };
            // Merge across the object's accesses: any irregularity poisons;
            // regular accesses merge by taking the max footprint & stride
            // (multiple strided views of the same array, e.g. in/out).
            pattern = Some(match (pattern.take(), p) {
                (None, p) => p,
                (Some(ObjectPattern::Irregular), _) | (_, ObjectPattern::Irregular) => {
                    ObjectPattern::Irregular
                }
                (
                    Some(ObjectPattern::Regular {
                        stride: s1,
                        footprint: f1,
                    }),
                    ObjectPattern::Regular {
                        stride: s2,
                        footprint: f2,
                    },
                ) => {
                    if s1 == s2 {
                        ObjectPattern::Regular {
                            stride: s1,
                            footprint: f1.max(f2),
                        }
                    } else {
                        // Conflicting strides: not a single runtime-constant
                        // block stride.
                        ObjectPattern::Irregular
                    }
                }
                (
                    Some(ObjectPattern::BlockInvariant { footprint: f1 }),
                    ObjectPattern::BlockInvariant { footprint: f2 },
                ) => ObjectPattern::BlockInvariant {
                    footprint: f1.max(f2),
                },
                // Mixed invariant + strided views -> shared by all blocks.
                (Some(ObjectPattern::BlockInvariant { footprint }), _)
                | (Some(_), ObjectPattern::BlockInvariant { footprint }) => {
                    ObjectPattern::BlockInvariant { footprint }
                }
            });
        }
        out.insert(obj, pattern.unwrap());
    }
    out
}

// ---------------------------------------------------------------------------
// Profiler fallback (§4.3.2: "profiler-assisted techniques ... for the case
// where the access pattern is input-dependent")
// ---------------------------------------------------------------------------

/// Per-page profile: traffic and the dominant affinity stack.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PageProfile {
    pub page: u64,
    pub traffic: u32,
    pub majority_stack: usize,
    pub majority_share: f64,
}

/// Profile-derived estimate for one object.
#[derive(Clone, Debug, PartialEq)]
pub struct ProfiledPattern {
    /// Mean distinct bytes touched per thread-block.
    pub mean_footprint: f64,
    /// Traffic-weighted fraction of the object's accesses that land on
    /// pages without a dominant affinity stack (the fraction localization
    /// cannot help).
    pub cross_stack_fraction: f64,
    /// Whether per-block footprints look contiguous & strided.
    pub looks_strided: bool,
    /// Estimated per-block stride in bytes (valid if `looks_strided`).
    pub stride_estimate: f64,
    /// Per-page traffic + majority stack (placement validation and the
    /// page-majority fallback).
    pub pages: Vec<PageProfile>,
}

/// Per-page access accounting: exact per-stack touch counts (stacks are
/// few — 4 to 16 — so a small inline array suffices).
#[derive(Clone, Debug)]
struct PageCounts {
    counts: [u32; 16],
}

impl PageCounts {
    fn new(stack: usize) -> Self {
        let mut counts = [0u32; 16];
        counts[stack & 15] = 1;
        Self { counts }
    }

    fn touch(&mut self, stack: usize) {
        self.counts[stack & 15] += 1;
    }

    fn total(&self) -> u32 {
        self.counts.iter().sum()
    }

    fn majority_share(&self) -> f64 {
        *self.counts.iter().max().unwrap() as f64 / self.total().max(1) as f64
    }
}

/// A page is considered localizable when one stack issues at least this
/// share of its accesses.
const MAJORITY_SHARE: f64 = 0.60;

/// Run the trace profiler over a (sample) kernel trace. The profiler
/// "performs a similar examination as the compile-time analysis" (§4.3.2)
/// but on observed addresses: per block it records the footprint interval,
/// then checks inter-block stride consistency (median-based, robust to
/// boundary halos) and traffic-weighted cross-stack page sharing under the
/// affinity schedule.
pub fn profile_trace(
    trace: &KernelTrace,
    page_size: u64,
    affinity: impl Fn(u32) -> usize,
) -> HashMap<u16, ProfiledPattern> {
    struct ObjAgg {
        per_block: HashMap<u32, (u64, u64, u64)>, // block -> (min, max, count)
        pages: HashMap<u64, PageCounts>,
    }
    let mut objs: HashMap<u16, ObjAgg> = HashMap::new();
    for b in &trace.blocks {
        let stack = affinity(b.block_id);
        for a in &b.accesses {
            let agg = objs.entry(a.obj).or_insert_with(|| ObjAgg {
                per_block: HashMap::new(),
                pages: HashMap::new(),
            });
            let e = agg
                .per_block
                .entry(b.block_id)
                .or_insert((u64::MAX, 0, 0));
            e.0 = e.0.min(a.offset);
            e.1 = e.1.max(a.offset);
            e.2 += 1;
            agg.pages
                .entry(a.offset / page_size)
                .and_modify(|p| p.touch(stack))
                .or_insert_with(|| PageCounts::new(stack));
        }
    }
    let mut out = HashMap::new();
    for (obj, agg) in objs {
        let mut blocks: Vec<(u32, u64, u64)> = agg
            .per_block
            .iter()
            .map(|(b, (lo, hi, _))| (*b, *lo, *hi))
            .collect();
        blocks.sort_unstable_by_key(|x| x.0);
        let footprints: Vec<f64> = blocks
            .iter()
            .map(|(_, lo, hi)| (hi - lo) as f64 + 1.0)
            .collect();
        let mean_footprint =
            footprints.iter().sum::<f64>() / footprints.len().max(1) as f64;
        // Stride estimate: median of consecutive blocks' min-offset diffs;
        // strided if >=80% of diffs are within 5% of the median (robust to
        // halo reads and row-boundary jumps that poison a mean/stddev test).
        let mut strided = false;
        let mut stride = 0.0;
        if blocks.len() >= 2 {
            let mut diffs: Vec<f64> = blocks
                .windows(2)
                .map(|w| w[1].1 as f64 - w[0].1 as f64)
                .collect();
            let mut sorted = diffs.clone();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let median = sorted[sorted.len() / 2];
            if median > 0.0 {
                let tol = 0.05 * median.max(1.0);
                let within = diffs.iter().filter(|d| (*d - median).abs() <= tol).count();
                strided = within as f64 >= 0.8 * diffs.len() as f64;
                stride = median;
            }
            diffs.clear();
        }
        // Traffic-weighted cross-stack fraction + per-page majorities.
        let mut cross_traffic = 0u64;
        let mut total_traffic = 0u64;
        let mut pages = Vec::with_capacity(agg.pages.len());
        for (pg, p) in &agg.pages {
            let total = p.total();
            let share = p.majority_share();
            total_traffic += total as u64;
            if share < MAJORITY_SHARE {
                cross_traffic += total as u64;
            }
            let majority_stack = p
                .counts
                .iter()
                .enumerate()
                .max_by_key(|(_, c)| **c)
                .map(|(s, _)| s)
                .unwrap_or(0);
            pages.push(PageProfile {
                page: *pg,
                traffic: total,
                majority_stack,
                majority_share: share,
            });
        }
        pages.sort_unstable_by_key(|p| p.page);
        let cross = cross_traffic as f64 / total_traffic.max(1) as f64;
        out.insert(
            obj,
            ProfiledPattern {
                mean_footprint,
                cross_stack_fraction: cross,
                looks_strided: strided,
                stride_estimate: stride,
                pages,
            },
        );
    }
    out
}

/// Estimate the graph-regularity statistics of §6.4 from basic graph
/// properties: mean edges per block (mu), its standard deviation (sigma),
/// and the coefficient of variation sigma/mu used to predict CODA's
/// effectiveness before kernel invocation.
pub fn graph_regularity(degrees: &[u32], threads_per_block: usize) -> (f64, f64, f64) {
    if degrees.is_empty() || threads_per_block == 0 {
        return (0.0, 0.0, 0.0);
    }
    let per_block: Vec<f64> = degrees
        .chunks(threads_per_block)
        .map(|c| c.iter().map(|&d| d as f64).sum())
        .collect();
    let mu = crate::stats::mean(&per_block);
    let sigma = crate::stats::stddev(&per_block);
    (mu, sigma, if mu == 0.0 { 0.0 } else { sigma / mu })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{Access, BlockTrace, ObjectDesc};

    /// The paper's Fig 7 K-means kernel:
    /// `in[pid * nfeatures + i]`, i in [0, nfeatures).
    fn kmeans_in_access() -> AccessExpr {
        AccessExpr {
            object: 0,
            index: Expr::add(
                Expr::mul(Expr::pid(), Expr::Param("nfeatures")),
                Expr::Loop(0, Box::new(Expr::Param("nfeatures"))),
            ),
            elem_size: 4,
        }
    }

    #[test]
    fn kmeans_fig7_regular_with_paper_b_value() {
        // Paper: "blockDim.x * nfeatures * sizeof(float) is the B value".
        let ir = KernelIr {
            name: "kmeans".into(),
            accesses: vec![kmeans_in_access()],
        };
        let env = ParamEnv::new(256).with("nfeatures", 34);
        let res = analyze_kernel(&ir, &env);
        match res[&0] {
            ObjectPattern::Regular { stride, footprint } => {
                assert_eq!(stride, 256 * 34 * 4, "block stride = blockDim*nfeatures*4");
                // footprint spans the whole block's elements:
                // threadIdx span (255 * 34) + loop span (33) + 1 elements.
                assert_eq!(footprint, (255 * 34 + 33 + 1) * 4);
                // B is within one element of blockDim*nfeatures*4.
                assert!((footprint - 256 * 34 * 4).abs() <= 4);
            }
            ref p => panic!("expected regular, got {p:?}"),
        }
    }

    #[test]
    fn kmeans_out_transposed_is_irregular() {
        // Fig 7's out[i*npoints + pid]: loop coefficient = npoints, thread
        // coefficient 1 -> affine and strided by blockDim elements. The
        // paper treats this as analyzable too (stride blockDim * 4).
        let ir = KernelIr {
            name: "kmeans_out".into(),
            accesses: vec![AccessExpr {
                object: 1,
                index: Expr::add(
                    Expr::mul(
                        Expr::Loop(0, Box::new(Expr::Param("nfeatures"))),
                        Expr::Param("npoints"),
                    ),
                    Expr::pid(),
                ),
                elem_size: 4,
            }],
        };
        let env = ParamEnv::new(256).with("nfeatures", 34).with("npoints", 10000);
        let res = analyze_kernel(&ir, &env);
        match res[&1] {
            ObjectPattern::Regular { stride, .. } => assert_eq!(stride, 256 * 4),
            ref p => panic!("expected regular, got {p:?}"),
        }
    }

    #[test]
    fn indirect_access_is_irregular() {
        // CSR neighbor access: data[col_index[j]] — data-dependent.
        let ir = KernelIr {
            name: "spmv".into(),
            accesses: vec![AccessExpr {
                object: 0,
                index: Expr::Indirect,
                elem_size: 8,
            }],
        };
        let env = ParamEnv::new(128);
        assert_eq!(analyze_kernel(&ir, &env)[&0], ObjectPattern::Irregular);
    }

    #[test]
    fn block_invariant_detected() {
        // A lookup table indexed only by threadIdx: same pages for every
        // block -> shared -> FGP.
        let ir = KernelIr {
            name: "lut".into(),
            accesses: vec![AccessExpr {
                object: 3,
                index: Expr::ThreadIdx,
                elem_size: 4,
            }],
        };
        let env = ParamEnv::new(64);
        match analyze_kernel(&ir, &env)[&3] {
            ObjectPattern::BlockInvariant { footprint } => assert_eq!(footprint, 64 * 4),
            ref p => panic!("{p:?}"),
        }
    }

    #[test]
    fn conflicting_strides_poison() {
        let a1 = AccessExpr {
            object: 0,
            index: Expr::mul(Expr::BlockIdx, Expr::Const(100)),
            elem_size: 4,
        };
        let a2 = AccessExpr {
            object: 0,
            index: Expr::mul(Expr::BlockIdx, Expr::Const(7)),
            elem_size: 4,
        };
        let ir = KernelIr {
            name: "conflict".into(),
            accesses: vec![a1, a2],
        };
        let env = ParamEnv::new(32);
        assert_eq!(analyze_kernel(&ir, &env)[&0], ObjectPattern::Irregular);
    }

    #[test]
    fn div_rem_folding() {
        assert_eq!(
            normalize(&Expr::Div(Box::new(Expr::Const(10)), Box::new(Expr::Const(3)))),
            IndexForm::Affine(LinForm::constant(SymConst::constant(3)))
        );
        assert_eq!(
            normalize(&Expr::Rem(Box::new(Expr::BlockIdx), Box::new(Expr::Const(4)))),
            IndexForm::Irregular
        );
    }

    #[test]
    fn profiler_detects_strided_partitioning() {
        // Blocks 0..8 each touch a contiguous 4KB slice of object 0.
        let blocks = (0..8u32)
            .map(|b| BlockTrace {
                block_id: b,
                accesses: (0..32u64)
                    .map(|i| Access {
                        obj: 0,
                        offset: b as u64 * 4096 + i * 128,
                        write: false,
                    })
                    .collect(),
            })
            .collect();
        let t = KernelTrace {
            name: "p".into(),
            threads_per_block: 64,
            objects: vec![ObjectDesc {
                name: "o".into(),
                bytes: 8 * 4096,
            }],
            blocks,
        };
        let prof = profile_trace(&t, 4096, |b| (b / 2) as usize % 4);
        let p = &prof[&0];
        assert!(p.looks_strided);
        assert!((p.stride_estimate - 4096.0).abs() < 1.0);
        assert_eq!(p.cross_stack_fraction, 0.0);
        assert!((p.mean_footprint - (31.0 * 128.0 + 1.0)).abs() < 1.0);
    }

    #[test]
    fn profiler_detects_shared_object() {
        // Every block touches the same page.
        let blocks = (0..8u32)
            .map(|b| BlockTrace {
                block_id: b,
                accesses: vec![Access {
                    obj: 0,
                    offset: 0,
                    write: false,
                }],
            })
            .collect();
        let t = KernelTrace {
            name: "s".into(),
            threads_per_block: 64,
            objects: vec![ObjectDesc {
                name: "o".into(),
                bytes: 4096,
            }],
            blocks,
        };
        let prof = profile_trace(&t, 4096, |b| b as usize % 4);
        let p = &prof[&0];
        assert!(p.cross_stack_fraction > 0.99);
        assert!(!p.looks_strided);
    }

    #[test]
    fn graph_regularity_cv() {
        let regular = vec![4u32; 1024];
        let (_, _, cv) = graph_regularity(&regular, 64);
        assert!(cv < 1e-9);
        let mut skewed = vec![1u32; 1024];
        skewed[0] = 10_000;
        let (_, _, cv2) = graph_regularity(&skewed, 64);
        assert!(cv2 > 1.0);
    }
}
