//! Command-line parsing (the `clap` crate is not vendored in this
//! environment; this is a small, conventional GNU-style parser: positional
//! subcommand, `--flag`, `--key value` / `--key=value`).

use anyhow::bail;
use std::collections::HashMap;

/// Option names the `coda` CLI accepts with a value (`--opt value` /
/// `--opt=value`). Kept here so the binary and tests agree on the set:
/// `tests/cli_opts.rs` scans `main.rs` and fails if an option it consumes
/// is missing here (an unregistered `--opt value` silently parses as a
/// flag followed by a positional — the bug class behind the historical
/// `sweep --key/--values` fix).
pub const VALUE_OPTS: &[&str] = &[
    "mechanism",
    "config",
    "set",
    "mem-backend",
    "placement",
    "policy",
    "fairness",
    "stagger",
    "host",
    "host-mlp",
    "host-passes",
    "key",
    "values",
    "baselines",
    "threads",
    "topology",
];

/// Parsed command line.
#[derive(Debug, Default)]
pub struct Args {
    pub command: Option<String>,
    pub positional: Vec<String>,
    pub options: HashMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]`. `value_opts` lists option names that take a value.
    pub fn parse(argv: &[String], value_opts: &[&str]) -> crate::Result<Self> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if value_opts.contains(&name) {
                    i += 1;
                    if i >= argv.len() {
                        bail!("--{name} expects a value");
                    }
                    out.options.insert(name.to_string(), argv[i].clone());
                } else {
                    out.flags.push(name.to_string());
                }
            } else if out.command.is_none() {
                out.command = Some(a.clone());
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> crate::Result<T> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("bad value for --{name}: {v}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_options_flags() {
        let a = Args::parse(
            &argv(&["run", "PR", "--mechanism", "coda", "--json", "--set=seed=7"]),
            &["mechanism"],
        )
        .unwrap();
        assert_eq!(a.command.as_deref(), Some("run"));
        assert_eq!(a.positional, vec!["PR"]);
        assert_eq!(a.opt("mechanism"), Some("coda"));
        assert!(a.has_flag("json"));
        assert_eq!(a.opt("set"), Some("seed=7"));
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(&argv(&["run", "--mechanism"]), &["mechanism"]).is_err());
    }

    #[test]
    fn mem_backend_flag_takes_a_value() {
        let a = Args::parse(
            &argv(&["run", "PR", "--mem-backend", "bank"]),
            VALUE_OPTS,
        )
        .unwrap();
        assert_eq!(a.opt("mem-backend"), Some("bank"));
        assert!(a.flags.is_empty());
    }

    #[test]
    fn mix_options_take_values() {
        let a = Args::parse(
            &argv(&[
                "mix", "NN,KM", "--placement", "cgp", "--fairness", "rr", "--stagger", "5000",
                "--policy", "affinity",
            ]),
            VALUE_OPTS,
        )
        .unwrap();
        assert_eq!(a.command.as_deref(), Some("mix"));
        assert_eq!(a.positional, vec!["NN,KM"]);
        assert_eq!(a.opt("placement"), Some("cgp"));
        assert_eq!(a.opt("fairness"), Some("rr"));
        assert_eq!(a.opt("policy"), Some("affinity"));
        assert_eq!(a.opt_parse("stagger", 0.0f64).unwrap(), 5000.0);
        assert!(a.flags.is_empty());
    }

    #[test]
    fn hostmix_options_take_values() {
        let a = Args::parse(
            &argv(&[
                "hostmix", "NN,KM", "--host", "DC", "--host-mlp", "32", "--host-passes", "2",
                "--placement", "cgp",
            ]),
            VALUE_OPTS,
        )
        .unwrap();
        assert_eq!(a.command.as_deref(), Some("hostmix"));
        assert_eq!(a.positional, vec!["NN,KM"]);
        assert_eq!(a.opt("host"), Some("DC"));
        assert_eq!(a.opt("host-mlp"), Some("32"));
        assert_eq!(a.opt("host-passes"), Some("2"));
        assert!(a.flags.is_empty());
    }

    #[test]
    fn sweep_options_take_values() {
        let a = Args::parse(
            &argv(&["sweep", "PR", "--key", "remote_bw_gbs", "--values", "16,32"]),
            VALUE_OPTS,
        )
        .unwrap();
        assert_eq!(a.opt("key"), Some("remote_bw_gbs"));
        assert_eq!(a.opt("values"), Some("16,32"));
        assert_eq!(a.positional, vec!["PR"]);
    }

    #[test]
    fn opt_parse_defaults_and_errors() {
        let a = Args::parse(&argv(&["x", "--n", "5"]), &["n"]).unwrap();
        assert_eq!(a.opt_parse("n", 1usize).unwrap(), 5);
        assert_eq!(a.opt_parse("missing", 9usize).unwrap(), 9);
        let b = Args::parse(&argv(&["x", "--n", "zzz"]), &["n"]).unwrap();
        assert!(b.opt_parse::<usize>("n", 1).is_err());
    }
}
