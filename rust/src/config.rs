//! System configuration (Table 1 of the paper) and a minimal TOML-subset
//! loader so deployments can override any field from a file or `key=value`
//! CLI overrides without a `serde`/`toml` dependency (not vendored here).
//!
//! The defaults reproduce the paper's evaluated system: 4 HBM2 stacks of
//! 8 GB, 4 SMs per stack, 256 GB/s internal bandwidth per stack, 128 GB/s
//! aggregate host bandwidth, 16 GB/s remote bandwidth, 128 B fine-grain
//! interleaving and 4 KB pages.

use anyhow::{bail, Context};
use std::collections::BTreeMap;

/// One `key = value` assignment from TOML-subset text, tagged with the
/// innermost `[section]` / `[[section]]` header above it.
///
/// The shared grammar (used by [`SystemConfig::from_toml_str`], which
/// ignores sections, and by [`crate::spec::ExperimentSpec::from_toml_str`],
/// which does not): one assignment per line, `#` starts a comment,
/// `[name]` and `[[name]]` headers open a section. Every header occurrence
/// bumps that section's `instance` counter, which is how `[[kernel]]`
/// array-of-tables entries are told apart.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TomlItem {
    /// 1-based source line of the assignment.
    pub lineno: usize,
    /// Enclosing section name (empty before any header).
    pub section: String,
    /// 0-based occurrence index of the enclosing section's header.
    pub instance: usize,
    pub key: String,
    /// Trimmed, with one level of surrounding double quotes removed.
    pub value: String,
}

/// Strip a `#` comment, ignoring `#` inside a double-quoted span (the
/// subset has no escaped quotes, so a simple quote toggle is exact).
fn strip_comment(raw: &str) -> &str {
    let mut in_quotes = false;
    for (i, c) in raw.char_indices() {
        match c {
            '"' => in_quotes = !in_quotes,
            '#' if !in_quotes => return &raw[..i],
            _ => {}
        }
    }
    raw
}

/// Remove exactly one level of surrounding double quotes, if present.
fn unquote(v: &str) -> &str {
    v.strip_prefix('"')
        .and_then(|s| s.strip_suffix('"'))
        .unwrap_or(v)
}

/// A `[section]` / `[[section]]` header occurrence. Emitted even for
/// key-less tables, so schemas can reject truncated array entries
/// instead of silently dropping them.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TomlSection {
    /// 1-based source line of the header.
    pub lineno: usize,
    pub name: String,
    /// 0-based occurrence index of this name's headers.
    pub instance: usize,
}

/// A tokenized TOML-subset document: every section header plus every
/// `key = value` assignment.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct TomlDoc {
    pub sections: Vec<TomlSection>,
    pub items: Vec<TomlItem>,
}

impl TomlDoc {
    /// How many headers open section `name` (counts key-less tables too).
    pub fn section_count(&self, name: &str) -> usize {
        self.sections.iter().filter(|s| s.name == name).count()
    }
}

/// Parse TOML-subset text into its tokenized form. This is the one
/// tokenizer behind every `.toml` the project reads; richer schemas
/// (the experiment spec) interpret the section tags.
/// Values may not contain double quotes (there is no escape syntax);
/// an interior quote is a hard error rather than silent corruption.
pub fn parse_toml_subset(text: &str) -> crate::Result<TomlDoc> {
    let mut doc = TomlDoc::default();
    let mut section = String::new();
    let mut instance = 0usize;
    let mut counts: BTreeMap<String, usize> = BTreeMap::new();
    for (i, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if line.starts_with('[') && line.ends_with(']') {
            let name = line
                .trim_matches(|c| c == '[' || c == ']')
                .trim()
                .to_string();
            let n = counts.entry(name.clone()).or_insert(0);
            instance = *n;
            *n += 1;
            doc.sections.push(TomlSection {
                lineno: i + 1,
                name: name.clone(),
                instance,
            });
            section = name;
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .with_context(|| format!("line {}: expected key = value", i + 1))?;
        let value = unquote(v.trim());
        if value.contains('"') {
            bail!(
                "line {}: double quotes are not allowed inside values \
                 (the TOML subset has no escape syntax)",
                i + 1
            );
        }
        doc.items.push(TomlItem {
            lineno: i + 1,
            section: section.clone(),
            instance,
            key: k.trim().to_string(),
            value: value.to_string(),
        });
    }
    Ok(doc)
}

/// Which DRAM timing backend serves memory accesses (see [`crate::mem`]).
///
/// * [`MemBackendKind::FixedLatency`] — the original channel model: open-row
///   hit/miss latency plus channel-bus occupancy. Cheap and adequate for the
///   paper's headline comparisons.
/// * [`MemBackendKind::BankLevel`] — per-bank state: row-buffer
///   hit/miss/conflict timing, bank-busy queuing, bank-group column-command
///   gaps, and periodic refresh windows. DRAMsim-class fidelity at model
///   cost; changes absolute cycle counts but must never change access
///   *counts* (enforced by `tests/backends.rs`).
/// * [`MemBackendKind::CycleAccurate`] — explicit ACT/PRE/RD/WR command
///   scheduling per channel: FR-FCFS write drain, tRAS/tRRD/tFAW rank
///   constraints, per-rank staggered refresh, and an open/closed row
///   policy. Every emitted command is replayed through the
///   [`crate::mem::protocol`] legality checker in debug/test builds.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum MemBackendKind {
    /// Open-row channel model with fixed hit/miss service latency.
    #[default]
    FixedLatency,
    /// Bank-level model: per-bank row state, conflicts, refresh.
    BankLevel,
    /// Command-level model: FR-FCFS, full JEDEC-style timing, checker.
    CycleAccurate,
}

impl MemBackendKind {
    /// Parse a CLI/config spelling; `None` for unknown names.
    pub fn parse(s: &str) -> Option<Self> {
        match s.trim() {
            "fixed" | "fixed-latency" | "fixed_latency" => Some(Self::FixedLatency),
            "bank" | "bank-level" | "bank_level" => Some(Self::BankLevel),
            "cycle" | "cycle-accurate" | "cycle_accurate" => Some(Self::CycleAccurate),
            _ => None,
        }
    }
}

impl std::fmt::Display for MemBackendKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Self::FixedLatency => "fixed",
            Self::BankLevel => "bank",
            Self::CycleAccurate => "cycle",
        })
    }
}

/// Row-buffer management policy for the cycle-accurate backend.
///
/// * `Open` — rows stay activated after a column command; a later access
///   to the same row is a row hit, a different row pays PRE + ACT.
/// * `Closed` — every column command carries auto-precharge, so every
///   access re-activates (no row hits, but no conflicts either).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum DramRowPolicy {
    /// Leave rows open after access (row-buffer locality pays off).
    #[default]
    Open,
    /// Auto-precharge after every column command.
    Closed,
}

impl DramRowPolicy {
    /// Parse a CLI/config spelling; `None` for unknown names.
    pub fn parse(s: &str) -> Option<Self> {
        match s.trim() {
            "open" => Some(Self::Open),
            "closed" | "close" => Some(Self::Closed),
            _ => None,
        }
    }
}

impl std::fmt::Display for DramRowPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Self::Open => "open",
            Self::Closed => "closed",
        })
    }
}

/// Parse an `on`/`off` switch value (`true`/`false` accepted as aliases).
fn parse_on_off(s: &str) -> Option<bool> {
    match s.trim() {
        "on" | "true" => Some(true),
        "off" | "false" => Some(false),
        _ => None,
    }
}

/// Serialize an `on`/`off` switch value (round-trips [`parse_on_off`]).
fn fmt_on_off(v: bool) -> String {
    String::from(if v { "on" } else { "off" })
}

/// Can a set-associative TLB hold *exactly* `entries` translations with at
/// most `max_ways` ways (sets must be a power of two)? This is the
/// representability contract of [`crate::vm::Tlb::with_ways`]; config
/// validation rejects sizes the structure would otherwise have to round.
pub fn tlb_size_representable(entries: usize, max_ways: usize) -> bool {
    let entries = entries.max(1);
    let max_ways = max_ways.clamp(1, entries);
    (1..=max_ways).any(|w| entries % w == 0 && (entries / w).is_power_of_two())
}

/// Full system configuration. All bandwidths are aggregate GB/s; the
/// simulator converts to bytes/cycle at `sm_clock_ghz`.
#[derive(Clone, Debug, PartialEq)]
pub struct SystemConfig {
    // --- topology -------------------------------------------------------
    /// Number of memory stacks (power of two).
    pub num_stacks: usize,
    /// SMs on each stack's logic layer.
    pub sms_per_stack: usize,
    /// Thread-blocks resident per SM (occupancy bound).
    pub blocks_per_sm: usize,
    /// HBM capacity per stack in bytes.
    pub stack_capacity: u64,

    // --- clocks ---------------------------------------------------------
    /// SM clock; the simulator's cycle domain.
    pub sm_clock_ghz: f64,

    // --- interleaving ---------------------------------------------------
    /// Fine-grain interleaving granularity in bytes (FGP stripe).
    pub fgp_interleave: u64,
    /// OS page size (CGP granularity).
    pub page_size: u64,

    // --- bandwidths (GB/s, aggregate) ------------------------------------
    /// Internal bandwidth available to the SMs within one stack.
    pub local_bw_gbs: f64,
    /// Aggregate host-processor <-> stacks bandwidth.
    pub host_bw_gbs: f64,
    /// Aggregate stack <-> stack (remote) bandwidth.
    pub remote_bw_gbs: f64,

    // --- latencies (ns, unloaded) ----------------------------------------
    /// Local crossbar + TSV latency.
    pub local_latency_ns: f64,
    /// Host SerDes + link latency.
    pub host_latency_ns: f64,
    /// Remote link latency per hop (SerDes + routing).
    pub remote_latency_ns: f64,

    // --- stack-to-stack fabric (see [`crate::net`]) -----------------------
    /// Fabric shape: `full` (degenerate single-hop switch, the frozen
    /// default), `line`, `ring`, or `mesh`.
    pub topology: crate::net::TopologyKind,
    /// Mesh column count; `0` picks the near-square factorisation of
    /// `num_stacks`. Must divide `num_stacks` when set.
    pub mesh_cols: usize,
    /// Per-hop latency of line/ring/mesh channels (ns). The degenerate
    /// fabric keeps using `remote_latency_ns`.
    pub hop_latency_ns: f64,
    /// Per-directed-link bandwidth of line/ring/mesh channels (GB/s);
    /// `0` = the frozen per-port share `remote_bw_gbs / num_stacks`.
    pub link_bw_gbs: f64,
    /// Window length (SM cycles) for per-link peak-throughput tracking
    /// on multi-hop fabrics.
    pub net_window_cycles: f64,
    /// DRAM service latency (row hit).
    pub dram_hit_ns: f64,
    /// DRAM service latency (row miss: precharge + activate + CAS).
    pub dram_miss_ns: f64,

    // --- memory organization ---------------------------------------------
    /// HBM channels per stack.
    pub channels_per_stack: usize,
    /// Banks per channel (row-buffer locality model).
    pub banks_per_channel: usize,
    /// DRAM row (page) size in bytes per bank.
    pub row_size: u64,

    // --- DRAM timing backend ---------------------------------------------
    /// Which DRAM timing backend serves accesses (see [`crate::mem`]).
    pub mem_backend: MemBackendKind,
    /// Bank groups per channel (bank-level backend; power of two).
    pub bank_groups_per_channel: usize,
    /// Row-to-column delay tRCD (ns, bank-level backend).
    pub dram_trcd_ns: f64,
    /// Precharge time tRP (ns, bank-level backend).
    pub dram_trp_ns: f64,
    /// Column access strobe latency tCL (ns, bank-level backend).
    pub dram_tcl_ns: f64,
    /// Column-command gap within one bank group, tCCD_L (ns).
    pub dram_tccd_l_ns: f64,
    /// Column-command gap across bank groups, tCCD_S (ns).
    pub dram_tccd_s_ns: f64,
    /// Refresh interval tREFI (ns): an all-bank refresh starts every tREFI.
    pub dram_trefi_ns: f64,
    /// Refresh cycle time tRFC (ns): the bank-unavailable window.
    pub dram_trfc_ns: f64,

    // --- cycle-accurate backend only --------------------------------------
    /// Row active time tRAS (ns): minimum ACT-to-PRE gap on one bank.
    pub dram_tras_ns: f64,
    /// ACT-to-ACT gap between banks of one rank, tRRD (ns).
    pub dram_trrd_ns: f64,
    /// Four-activate window tFAW (ns): at most 4 ACTs per rank per window.
    pub dram_tfaw_ns: f64,
    /// Ranks per channel (power of two dividing `banks_per_channel`).
    pub dram_ranks_per_channel: usize,
    /// Row-buffer management policy: `open` or `closed`.
    pub dram_row_policy: DramRowPolicy,
    /// Write-queue high watermark: reaching it forces a drain.
    pub dram_wq_high: usize,
    /// Write-queue low watermark: a forced drain stops here.
    pub dram_wq_low: usize,
    /// FR-FCFS aging cap (ns): a request older than this is served
    /// before any younger row hit (starvation freedom).
    pub dram_age_cap_ns: f64,

    // --- caches / TLB ------------------------------------------------------
    /// Cache line size in bytes (memory request granularity).
    pub line_size: u64,
    /// SM L1 TLB entries.
    pub tlb_entries: usize,
    /// TLB miss penalty (page-walk) in ns.
    pub tlb_miss_ns: f64,

    // --- hierarchical address translation (see [`crate::xlate`]) -----------
    /// Per-SM split L1 TLB entries for each page size. `0` keeps the frozen
    /// legacy model (one flat TLB per SM + `tlb_miss_ns` per miss); any
    /// positive value activates the hierarchical L1/L2/PTW pipeline.
    pub tlb_l1_entries: usize,
    /// Maximum associativity of the split L1 TLBs.
    pub tlb_l1_ways: usize,
    /// Per-SM unified L2 TLB entries (hierarchical model only).
    pub tlb_l2_entries: usize,
    /// Maximum associativity of the unified L2 TLB.
    pub tlb_l2_ways: usize,
    /// L2 TLB hit latency in ns (hierarchical model only).
    pub tlb_l2_hit_ns: f64,
    /// Concurrent page-table-walker slots shared by all SMs. A walk that
    /// finds every slot busy queues behind the earliest-free one; those
    /// queue cycles are reported separately from walk service cycles.
    pub ptw_slots: usize,
    /// Latency of one page-table level reference in ns. A base-page walk
    /// touches 4 levels; a huge-page walk terminates one level early (3).
    pub ptw_level_ns: f64,
    /// Promote contiguous same-stack CGP regions to 2 MB huge-page frames
    /// (`on`/`off`). FGP-interleaved ranges always stay at base pages —
    /// a stripe round spans stacks, which a single frame cannot.
    pub huge_pages: bool,
    /// Flush a time-shared SM's TLBs whenever the scheduler hands it to a
    /// different app (`on` models per-address-space translations; `off`
    /// keeps the frozen shared-TLB behavior).
    pub tlb_flush_on_switch: bool,
    /// Per-SM L1 hit rate model knob: fraction of accesses filtered before
    /// the memory system (the paper's 32KB L1 + 1MB L2/stack). Workload
    /// generators emit post-L1 traffic; this filters a further L2 fraction.
    pub l2_hit_rate: f64,
    /// L2 hit latency in ns.
    pub l2_hit_ns: f64,

    // --- execution model ----------------------------------------------------
    /// Outstanding memory requests per thread-block (warp-level MLP).
    pub mlp_per_block: usize,
    /// Compute cycles between consecutive memory accesses of a block.
    pub compute_cycles_per_access: u64,

    // --- multi-kernel scheduling ---------------------------------------------
    /// Default inter-app arbitration for multi-kernel mixes (see
    /// [`crate::sched::FairnessPolicy`]; CLI `--fairness fcfs|rr|least`).
    pub mix_fairness: crate::sched::FairnessPolicy,
    /// Default launch stagger for multi-kernel mixes: app `i` arrives at
    /// `i * mix_stagger_cycles` SM cycles (CLI `--stagger N`).
    pub mix_stagger_cycles: f64,

    // --- concurrent host traffic (CHoNDA-style co-location) ------------------
    /// Outstanding host requests per issue window — the host-intensity
    /// knob (an aggressive OoO core + MLP prefetchers; the legacy
    /// `HOST_MLP` window semantics). `0` disables host traffic entirely,
    /// making `coda hostmix` degenerate to the NDP-only run.
    pub host_mlp: usize,
    /// Sweeps the host stream makes over its working set; more passes
    /// sustain host pressure for longer NDP kernels. `0` disables host
    /// traffic.
    pub host_passes: u64,
    /// Fraction of host cache lines resident in host-local DDR instead of
    /// the stacks (deterministic per line). Those accesses never touch
    /// the host ports or stack DRAM — CHoNDA's host-side memory.
    pub host_ddr_fraction: f64,
    /// Aggregate bandwidth of the host-local DDR (GB/s).
    pub host_ddr_bw_gbs: f64,
    /// Channels of the host-local DDR (it reuses the stack backend model
    /// selected by `mem_backend`, scaled to these parameters).
    pub host_ddr_channels: usize,

    // --- orchestration -------------------------------------------------------
    /// Worker threads for the orchestration layer (run-alone baselines,
    /// `[sweep]` expansion — see [`crate::par`]): `0` = one per available
    /// core, `1` = the plain sequential path (no threads spawned), `N` =
    /// cap at N. Simulated results are independent of this value —
    /// parallelism shapes wall-clock time only
    /// (`tests/parallel_equiv.rs` locks that in). CLI: `--threads N`.
    pub sim_threads: usize,
    /// Shards for intra-run parallel simulation (see [`crate::shard`]):
    /// the engine partitions its state by home stack and runs shards on
    /// scoped threads under conservative time-window synchronization.
    /// `1` (the default) is the sequential engine — the bit-exactness
    /// oracle; `0` = one shard per stack, capped at the available cores;
    /// `N` caps the shard count at N. Degenerate setups (a single stack,
    /// zero fabric lookahead, hierarchical TLBs, first-touch migration)
    /// always lower to the sequential engine regardless of this knob.
    pub shard_stacks: usize,

    // --- misc ----------------------------------------------------------------
    /// Global PRNG seed for workload synthesis.
    pub seed: u64,
}

impl Default for SystemConfig {
    fn default() -> Self {
        Self {
            num_stacks: 4,
            sms_per_stack: 4,
            blocks_per_sm: 6,
            stack_capacity: 8 << 30,
            sm_clock_ghz: 2.0,
            fgp_interleave: 128,
            page_size: 4096,
            local_bw_gbs: 256.0,
            host_bw_gbs: 128.0,
            remote_bw_gbs: 16.0,
            local_latency_ns: 20.0,
            host_latency_ns: 60.0,
            remote_latency_ns: 120.0,
            topology: crate::net::TopologyKind::FullyConnected,
            mesh_cols: 0,
            hop_latency_ns: 30.0,
            link_bw_gbs: 0.0,
            net_window_cycles: 8192.0,
            dram_hit_ns: 15.0,
            dram_miss_ns: 45.0,
            channels_per_stack: 8,
            banks_per_channel: 16,
            row_size: 2048,
            mem_backend: MemBackendKind::FixedLatency,
            bank_groups_per_channel: 4,
            dram_trcd_ns: 14.0,
            dram_trp_ns: 14.0,
            dram_tcl_ns: 14.0,
            dram_tccd_l_ns: 3.0,
            dram_tccd_s_ns: 1.0,
            dram_trefi_ns: 3900.0,
            dram_trfc_ns: 260.0,
            dram_tras_ns: 33.0,
            dram_trrd_ns: 4.0,
            dram_tfaw_ns: 15.0,
            dram_ranks_per_channel: 1,
            dram_row_policy: DramRowPolicy::Open,
            dram_wq_high: 32,
            dram_wq_low: 16,
            dram_age_cap_ns: 2000.0,
            line_size: 128,
            tlb_entries: 64,
            tlb_miss_ns: 200.0,
            tlb_l1_entries: 0,
            tlb_l1_ways: 4,
            tlb_l2_entries: 512,
            tlb_l2_ways: 8,
            tlb_l2_hit_ns: 8.0,
            ptw_slots: 8,
            ptw_level_ns: 50.0,
            huge_pages: false,
            tlb_flush_on_switch: false,
            l2_hit_rate: 0.30,
            l2_hit_ns: 5.0,
            mlp_per_block: 32,
            compute_cycles_per_access: 440,
            mix_fairness: crate::sched::FairnessPolicy::Fcfs,
            mix_stagger_cycles: 0.0,
            host_mlp: crate::host::HOST_MLP,
            host_passes: 1,
            host_ddr_fraction: 0.0,
            host_ddr_bw_gbs: 64.0,
            host_ddr_channels: 2,
            sim_threads: 0,
            shard_stacks: 1,
            seed: 0xC0DA,
        }
    }
}

impl SystemConfig {
    /// Total SMs in the NDP system.
    pub fn total_sms(&self) -> usize {
        self.num_stacks * self.sms_per_stack
    }

    /// `N_blocks_per_stack` from the paper's Eq (1): thread-blocks that run
    /// concurrently in one memory stack.
    pub fn blocks_per_stack(&self) -> usize {
        self.sms_per_stack * self.blocks_per_sm
    }

    /// Pages per page-group: an FGP stripes across all stacks, so groups of
    /// `num_stacks` consecutive pages convert FGP<->CGP together (§4.2).
    pub fn page_group_len(&self) -> usize {
        self.num_stacks
    }

    /// Cycles per nanosecond in the SM clock domain.
    pub fn cycles_per_ns(&self) -> f64 {
        self.sm_clock_ghz
    }

    /// Convert an aggregate GB/s figure to bytes per SM cycle.
    pub fn gbs_to_bytes_per_cycle(&self, gbs: f64) -> f64 {
        gbs / self.sm_clock_ghz
    }

    /// Validate invariants the rest of the system relies on.
    pub fn validate(&self) -> crate::Result<()> {
        if !self.num_stacks.is_power_of_two() {
            bail!("num_stacks must be a power of two, got {}", self.num_stacks);
        }
        if !self.page_size.is_power_of_two() || !self.fgp_interleave.is_power_of_two() {
            bail!("page_size and fgp_interleave must be powers of two");
        }
        if self.fgp_interleave * self.num_stacks as u64 > self.page_size {
            bail!(
                "one FGP stripe round ({} B x {} stacks) must fit in a page ({} B)",
                self.fgp_interleave,
                self.num_stacks,
                self.page_size
            );
        }
        if self.line_size > self.fgp_interleave {
            bail!("line_size must not exceed fgp_interleave");
        }
        if !(0.0..=1.0).contains(&self.l2_hit_rate) {
            bail!("l2_hit_rate must be in [0,1]");
        }
        if self.mlp_per_block == 0 || self.blocks_per_sm == 0 || self.sms_per_stack == 0 {
            bail!("mlp_per_block, blocks_per_sm, sms_per_stack must be positive");
        }
        if self.bank_groups_per_channel == 0
            || !self.bank_groups_per_channel.is_power_of_two()
            || self.bank_groups_per_channel > self.banks_per_channel
        {
            bail!(
                "bank_groups_per_channel must be a power of two <= banks_per_channel, got {}",
                self.bank_groups_per_channel
            );
        }
        for (name, v) in [
            ("dram_trcd_ns", self.dram_trcd_ns),
            ("dram_trp_ns", self.dram_trp_ns),
            ("dram_tcl_ns", self.dram_tcl_ns),
            ("dram_tccd_l_ns", self.dram_tccd_l_ns),
            ("dram_tccd_s_ns", self.dram_tccd_s_ns),
            ("dram_trefi_ns", self.dram_trefi_ns),
            ("dram_trfc_ns", self.dram_trfc_ns),
            ("dram_tras_ns", self.dram_tras_ns),
            ("dram_trrd_ns", self.dram_trrd_ns),
            ("dram_tfaw_ns", self.dram_tfaw_ns),
            ("dram_age_cap_ns", self.dram_age_cap_ns),
        ] {
            if v.is_nan() || v <= 0.0 {
                bail!("{name} must be positive, got {v}");
            }
        }
        if self.dram_trfc_ns >= self.dram_trefi_ns {
            bail!("dram_trfc_ns must be smaller than dram_trefi_ns");
        }
        if self.dram_ranks_per_channel == 0
            || !self.dram_ranks_per_channel.is_power_of_two()
            || self.dram_ranks_per_channel > self.banks_per_channel
            || self.banks_per_channel % self.dram_ranks_per_channel != 0
        {
            bail!(
                "dram_ranks_per_channel must be a power of two dividing \
                 banks_per_channel, got {}",
                self.dram_ranks_per_channel
            );
        }
        if self.dram_wq_high == 0 || self.dram_wq_low >= self.dram_wq_high {
            bail!(
                "dram write-queue watermarks need 0 <= low < high, got low={} high={}",
                self.dram_wq_low,
                self.dram_wq_high
            );
        }
        if !self.mix_stagger_cycles.is_finite() || self.mix_stagger_cycles < 0.0 {
            bail!(
                "mix_stagger_cycles must be a non-negative real, got {}",
                self.mix_stagger_cycles
            );
        }
        if !self.host_ddr_fraction.is_finite() || !(0.0..=1.0).contains(&self.host_ddr_fraction) {
            bail!(
                "host_ddr_fraction must be in [0,1], got {}",
                self.host_ddr_fraction
            );
        }
        if !self.host_ddr_bw_gbs.is_finite() || self.host_ddr_bw_gbs <= 0.0 {
            bail!(
                "host_ddr_bw_gbs must be positive, got {}",
                self.host_ddr_bw_gbs
            );
        }
        if self.host_ddr_channels == 0 {
            bail!("host_ddr_channels must be positive");
        }
        if self.mesh_cols > 0
            && (self.mesh_cols > self.num_stacks || self.num_stacks % self.mesh_cols != 0)
        {
            bail!(
                "mesh_cols must divide num_stacks ({} does not tile {})",
                self.mesh_cols,
                self.num_stacks
            );
        }
        if !self.hop_latency_ns.is_finite() || self.hop_latency_ns < 0.0 {
            bail!(
                "hop_latency_ns must be a non-negative real, got {}",
                self.hop_latency_ns
            );
        }
        if !self.link_bw_gbs.is_finite() || self.link_bw_gbs < 0.0 {
            bail!(
                "link_bw_gbs must be non-negative (0 = auto), got {}",
                self.link_bw_gbs
            );
        }
        if !self.net_window_cycles.is_finite() || self.net_window_cycles <= 0.0 {
            bail!(
                "net_window_cycles must be positive, got {}",
                self.net_window_cycles
            );
        }
        if self.tlb_entries == 0 {
            bail!("tlb_entries must be positive");
        }
        // The legacy TLB is built with up to 4 ways; reject sizes it could
        // only satisfy by rounding the capacity up (e.g. 48 -> 64).
        if !tlb_size_representable(self.tlb_entries, 4) {
            bail!(
                "tlb_entries = {} is not representable as ways x power-of-two \
                 sets with <= 4 ways; pick e.g. 32, 48, 64 or 96",
                self.tlb_entries
            );
        }
        if self.tlb_l1_ways == 0 || self.tlb_l2_ways == 0 {
            bail!("tlb_l1_ways and tlb_l2_ways must be positive");
        }
        if self.tlb_l1_entries > 0 {
            if !tlb_size_representable(self.tlb_l1_entries, self.tlb_l1_ways) {
                bail!(
                    "tlb_l1_entries = {} is not representable as ways x \
                     power-of-two sets with <= {} ways",
                    self.tlb_l1_entries,
                    self.tlb_l1_ways
                );
            }
            if self.tlb_l2_entries == 0
                || !tlb_size_representable(self.tlb_l2_entries, self.tlb_l2_ways)
            {
                bail!(
                    "tlb_l2_entries = {} is not representable as ways x \
                     power-of-two sets with <= {} ways",
                    self.tlb_l2_entries,
                    self.tlb_l2_ways
                );
            }
            if self.ptw_slots == 0 {
                bail!("ptw_slots must be positive when the hierarchical TLB is on");
            }
            if !self.ptw_level_ns.is_finite() || self.ptw_level_ns <= 0.0 {
                bail!("ptw_level_ns must be positive, got {}", self.ptw_level_ns);
            }
            if !self.tlb_l2_hit_ns.is_finite() || self.tlb_l2_hit_ns < 0.0 {
                bail!(
                    "tlb_l2_hit_ns must be a non-negative real, got {}",
                    self.tlb_l2_hit_ns
                );
            }
        }
        if self.huge_pages {
            let huge = crate::vm::HUGE_PAGE_BYTES;
            if self.page_size > huge || huge % self.page_size != 0 {
                bail!(
                    "huge_pages = on requires page_size ({}) to divide the \
                     2 MB huge-frame size",
                    self.page_size
                );
            }
            if huge / self.page_size < self.num_stacks as u64 {
                bail!(
                    "huge_pages = on requires at least num_stacks base pages \
                     per 2 MB frame (page_size {} x {} stacks does not fit)",
                    self.page_size,
                    self.num_stacks
                );
            }
        }
        Ok(())
    }

    /// Apply a single `key = value` override (used by both the TOML-subset
    /// loader and `--set` CLI flags).
    pub fn set(&mut self, key: &str, value: &str) -> crate::Result<()> {
        let v = value.trim().trim_matches('"');
        macro_rules! parse {
            ($field:ident, $ty:ty) => {
                self.$field = v
                    .parse::<$ty>()
                    .with_context(|| format!("bad value for {key}: {v}"))?
            };
        }
        match key {
            "num_stacks" => parse!(num_stacks, usize),
            "sms_per_stack" => parse!(sms_per_stack, usize),
            "blocks_per_sm" => parse!(blocks_per_sm, usize),
            "stack_capacity" => parse!(stack_capacity, u64),
            "sm_clock_ghz" => parse!(sm_clock_ghz, f64),
            "fgp_interleave" => parse!(fgp_interleave, u64),
            "page_size" => parse!(page_size, u64),
            "local_bw_gbs" => parse!(local_bw_gbs, f64),
            "host_bw_gbs" => parse!(host_bw_gbs, f64),
            "remote_bw_gbs" => parse!(remote_bw_gbs, f64),
            "local_latency_ns" => parse!(local_latency_ns, f64),
            "host_latency_ns" => parse!(host_latency_ns, f64),
            "remote_latency_ns" => parse!(remote_latency_ns, f64),
            "topology" => {
                self.topology = crate::net::TopologyKind::parse(v).ok_or_else(|| {
                    anyhow::anyhow!("bad value for {key}: {v} (expected full|line|ring|mesh)")
                })?
            }
            "mesh_cols" => parse!(mesh_cols, usize),
            "hop_latency_ns" => parse!(hop_latency_ns, f64),
            "link_bw_gbs" => parse!(link_bw_gbs, f64),
            "net_window_cycles" => parse!(net_window_cycles, f64),
            "dram_hit_ns" => parse!(dram_hit_ns, f64),
            "dram_miss_ns" => parse!(dram_miss_ns, f64),
            "channels_per_stack" => parse!(channels_per_stack, usize),
            "banks_per_channel" => parse!(banks_per_channel, usize),
            "row_size" => parse!(row_size, u64),
            "mem_backend" => {
                self.mem_backend = MemBackendKind::parse(v).ok_or_else(|| {
                    anyhow::anyhow!("bad value for {key}: {v} (expected fixed|bank|cycle)")
                })?
            }
            "bank_groups_per_channel" => parse!(bank_groups_per_channel, usize),
            "dram_trcd_ns" => parse!(dram_trcd_ns, f64),
            "dram_trp_ns" => parse!(dram_trp_ns, f64),
            "dram_tcl_ns" => parse!(dram_tcl_ns, f64),
            "dram_tccd_l_ns" => parse!(dram_tccd_l_ns, f64),
            "dram_tccd_s_ns" => parse!(dram_tccd_s_ns, f64),
            "dram_trefi_ns" => parse!(dram_trefi_ns, f64),
            "dram_trfc_ns" => parse!(dram_trfc_ns, f64),
            "dram_tras_ns" => parse!(dram_tras_ns, f64),
            "dram_trrd_ns" => parse!(dram_trrd_ns, f64),
            "dram_tfaw_ns" => parse!(dram_tfaw_ns, f64),
            "dram_ranks_per_channel" => parse!(dram_ranks_per_channel, usize),
            "dram_row_policy" => {
                self.dram_row_policy = DramRowPolicy::parse(v).ok_or_else(|| {
                    anyhow::anyhow!("bad value for {key}: {v} (expected open|closed)")
                })?
            }
            "dram_wq_high" => parse!(dram_wq_high, usize),
            "dram_wq_low" => parse!(dram_wq_low, usize),
            "dram_age_cap_ns" => parse!(dram_age_cap_ns, f64),
            "line_size" => parse!(line_size, u64),
            "tlb_entries" => parse!(tlb_entries, usize),
            "tlb_miss_ns" => parse!(tlb_miss_ns, f64),
            "tlb_l1_entries" => parse!(tlb_l1_entries, usize),
            "tlb_l1_ways" => parse!(tlb_l1_ways, usize),
            "tlb_l2_entries" => parse!(tlb_l2_entries, usize),
            "tlb_l2_ways" => parse!(tlb_l2_ways, usize),
            "tlb_l2_hit_ns" => parse!(tlb_l2_hit_ns, f64),
            "ptw_slots" => parse!(ptw_slots, usize),
            "ptw_level_ns" => parse!(ptw_level_ns, f64),
            "huge_pages" => {
                self.huge_pages = parse_on_off(v).ok_or_else(|| {
                    anyhow::anyhow!("bad value for {key}: {v} (expected on|off)")
                })?
            }
            "tlb_flush_on_switch" => {
                self.tlb_flush_on_switch = parse_on_off(v).ok_or_else(|| {
                    anyhow::anyhow!("bad value for {key}: {v} (expected on|off)")
                })?
            }
            "l2_hit_rate" => parse!(l2_hit_rate, f64),
            "l2_hit_ns" => parse!(l2_hit_ns, f64),
            "mlp_per_block" => parse!(mlp_per_block, usize),
            "compute_cycles_per_access" => parse!(compute_cycles_per_access, u64),
            "mix_fairness" => {
                self.mix_fairness =
                    crate::sched::FairnessPolicy::parse(v).ok_or_else(|| {
                        anyhow::anyhow!("bad value for {key}: {v} (expected fcfs|rr|least)")
                    })?
            }
            "mix_stagger_cycles" => parse!(mix_stagger_cycles, f64),
            "host_mlp" => parse!(host_mlp, usize),
            "host_passes" => parse!(host_passes, u64),
            "host_ddr_fraction" => parse!(host_ddr_fraction, f64),
            "host_ddr_bw_gbs" => parse!(host_ddr_bw_gbs, f64),
            "host_ddr_channels" => parse!(host_ddr_channels, usize),
            "sim_threads" => parse!(sim_threads, usize),
            "shard_stacks" => parse!(shard_stacks, usize),
            "seed" => parse!(seed, u64),
            _ => bail!("unknown config key: {key}"),
        }
        Ok(())
    }

    /// Load from TOML-subset text: `key = value` lines, `#` comments,
    /// optional `[section]` headers (ignored — the namespace is flat).
    pub fn from_toml_str(text: &str) -> crate::Result<Self> {
        let mut cfg = Self::default();
        for item in parse_toml_subset(text)?.items {
            cfg.set(&item.key, &item.value)
                .with_context(|| format!("line {}", item.lineno))?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Load a config file.
    pub fn from_file(path: &str) -> crate::Result<Self> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading config {path}"))?;
        Self::from_toml_str(&text)
    }

    /// Serialize to TOML-subset text (round-trips through
    /// [`Self::from_toml_str`]).
    pub fn to_toml_string(&self) -> String {
        let mut out = String::from("# CODA system configuration (Table 1)\n");
        let kv: BTreeMap<&str, String> = [
            ("num_stacks", self.num_stacks.to_string()),
            ("sms_per_stack", self.sms_per_stack.to_string()),
            ("blocks_per_sm", self.blocks_per_sm.to_string()),
            ("stack_capacity", self.stack_capacity.to_string()),
            ("sm_clock_ghz", self.sm_clock_ghz.to_string()),
            ("fgp_interleave", self.fgp_interleave.to_string()),
            ("page_size", self.page_size.to_string()),
            ("local_bw_gbs", self.local_bw_gbs.to_string()),
            ("host_bw_gbs", self.host_bw_gbs.to_string()),
            ("remote_bw_gbs", self.remote_bw_gbs.to_string()),
            ("local_latency_ns", self.local_latency_ns.to_string()),
            ("host_latency_ns", self.host_latency_ns.to_string()),
            ("remote_latency_ns", self.remote_latency_ns.to_string()),
            ("topology", self.topology.to_string()),
            ("mesh_cols", self.mesh_cols.to_string()),
            ("hop_latency_ns", self.hop_latency_ns.to_string()),
            ("link_bw_gbs", self.link_bw_gbs.to_string()),
            ("net_window_cycles", self.net_window_cycles.to_string()),
            ("dram_hit_ns", self.dram_hit_ns.to_string()),
            ("dram_miss_ns", self.dram_miss_ns.to_string()),
            ("channels_per_stack", self.channels_per_stack.to_string()),
            ("banks_per_channel", self.banks_per_channel.to_string()),
            ("row_size", self.row_size.to_string()),
            ("mem_backend", self.mem_backend.to_string()),
            (
                "bank_groups_per_channel",
                self.bank_groups_per_channel.to_string(),
            ),
            ("dram_trcd_ns", self.dram_trcd_ns.to_string()),
            ("dram_trp_ns", self.dram_trp_ns.to_string()),
            ("dram_tcl_ns", self.dram_tcl_ns.to_string()),
            ("dram_tccd_l_ns", self.dram_tccd_l_ns.to_string()),
            ("dram_tccd_s_ns", self.dram_tccd_s_ns.to_string()),
            ("dram_trefi_ns", self.dram_trefi_ns.to_string()),
            ("dram_trfc_ns", self.dram_trfc_ns.to_string()),
            ("dram_tras_ns", self.dram_tras_ns.to_string()),
            ("dram_trrd_ns", self.dram_trrd_ns.to_string()),
            ("dram_tfaw_ns", self.dram_tfaw_ns.to_string()),
            (
                "dram_ranks_per_channel",
                self.dram_ranks_per_channel.to_string(),
            ),
            ("dram_row_policy", self.dram_row_policy.to_string()),
            ("dram_wq_high", self.dram_wq_high.to_string()),
            ("dram_wq_low", self.dram_wq_low.to_string()),
            ("dram_age_cap_ns", self.dram_age_cap_ns.to_string()),
            ("line_size", self.line_size.to_string()),
            ("tlb_entries", self.tlb_entries.to_string()),
            ("tlb_miss_ns", self.tlb_miss_ns.to_string()),
            ("tlb_l1_entries", self.tlb_l1_entries.to_string()),
            ("tlb_l1_ways", self.tlb_l1_ways.to_string()),
            ("tlb_l2_entries", self.tlb_l2_entries.to_string()),
            ("tlb_l2_ways", self.tlb_l2_ways.to_string()),
            ("tlb_l2_hit_ns", self.tlb_l2_hit_ns.to_string()),
            ("ptw_slots", self.ptw_slots.to_string()),
            ("ptw_level_ns", self.ptw_level_ns.to_string()),
            ("huge_pages", fmt_on_off(self.huge_pages)),
            ("tlb_flush_on_switch", fmt_on_off(self.tlb_flush_on_switch)),
            ("l2_hit_rate", self.l2_hit_rate.to_string()),
            ("l2_hit_ns", self.l2_hit_ns.to_string()),
            ("mlp_per_block", self.mlp_per_block.to_string()),
            (
                "compute_cycles_per_access",
                self.compute_cycles_per_access.to_string(),
            ),
            ("mix_fairness", self.mix_fairness.to_string()),
            ("mix_stagger_cycles", self.mix_stagger_cycles.to_string()),
            ("host_mlp", self.host_mlp.to_string()),
            ("host_passes", self.host_passes.to_string()),
            ("host_ddr_fraction", self.host_ddr_fraction.to_string()),
            ("host_ddr_bw_gbs", self.host_ddr_bw_gbs.to_string()),
            ("host_ddr_channels", self.host_ddr_channels.to_string()),
            ("sim_threads", self.sim_threads.to_string()),
            ("shard_stacks", self.shard_stacks.to_string()),
            ("seed", self.seed.to_string()),
        ]
        .into_iter()
        .collect();
        for (k, v) in kv {
            out.push_str(&format!("{k} = {v}\n"));
        }
        out
    }

    /// A scaled-down preset for fast unit tests (64 MB stacks).
    pub fn test_small() -> Self {
        Self {
            stack_capacity: 64 << 20,
            ..Self::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_table1() {
        let c = SystemConfig::default();
        assert_eq!(c.num_stacks, 4);
        assert_eq!(c.sms_per_stack, 4);
        assert_eq!(c.stack_capacity, 8 << 30);
        assert_eq!(c.local_bw_gbs, 256.0);
        assert_eq!(c.host_bw_gbs, 128.0);
        assert_eq!(c.remote_bw_gbs, 16.0);
        assert_eq!(c.fgp_interleave, 128);
        assert_eq!(c.page_size, 4096);
        assert!(c.validate().is_ok());
    }

    #[test]
    fn blocks_per_stack_eq1_example() {
        // Paper: "if one memory stack has four SMs and each of which can run
        // six thread-blocks, N_blocks_per_stack is 24."
        let c = SystemConfig::default();
        assert_eq!(c.blocks_per_stack(), 24);
    }

    #[test]
    fn toml_roundtrip() {
        let c = SystemConfig::default();
        let text = c.to_toml_string();
        let c2 = SystemConfig::from_toml_str(&text).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn toml_overrides_and_comments() {
        let text = "# comment\n[network]\nremote_bw_gbs = 64.0 # inline\nnum_stacks = 8\n";
        let c = SystemConfig::from_toml_str(text).unwrap();
        assert_eq!(c.remote_bw_gbs, 64.0);
        assert_eq!(c.num_stacks, 8);
    }

    #[test]
    fn rejects_unknown_key() {
        assert!(SystemConfig::from_toml_str("nope = 1\n").is_err());
    }

    #[test]
    fn toml_subset_items_carry_sections_and_instances() {
        let text = "top = 1\n[a]\nx = \"q\"\n[[k]]\nw = 1\n[[k]]\nw = 2 # c\n[a]\ny = 3\n";
        let doc = parse_toml_subset(text).unwrap();
        let tags: Vec<(&str, usize, &str, &str)> = doc
            .items
            .iter()
            .map(|i| (i.section.as_str(), i.instance, i.key.as_str(), i.value.as_str()))
            .collect();
        assert_eq!(
            tags,
            vec![
                ("", 0, "top", "1"),
                ("a", 0, "x", "q"),
                ("k", 0, "w", "1"),
                ("k", 1, "w", "2"),
                ("a", 1, "y", "3"),
            ]
        );
        assert_eq!(doc.items[0].lineno, 1);
        assert_eq!(doc.items[4].lineno, 9);
        assert_eq!(doc.section_count("k"), 2);
        assert_eq!(doc.section_count("a"), 2);
        assert_eq!(doc.section_count("nope"), 0);
        assert!(parse_toml_subset("no equals sign\n").is_err());
    }

    #[test]
    fn toml_subset_quote_handling() {
        // Key-less headers are still recorded.
        let doc = parse_toml_subset("[a]\n[[k]]\n").unwrap();
        assert!(doc.items.is_empty());
        assert_eq!(doc.section_count("a"), 1);
        assert_eq!(doc.section_count("k"), 1);
        // '#' inside a quoted value is content, not a comment.
        let doc = parse_toml_subset("x = \"a#b\" # real comment\n").unwrap();
        assert_eq!(doc.items[0].value, "a#b");
        // Exactly one level of quotes is stripped; interior quotes error
        // (serialize→parse must never silently corrupt a value).
        assert!(parse_toml_subset("x = \"a\"b\"\n").is_err());
        assert!(parse_toml_subset("x = a\"b\n").is_err());
    }

    #[test]
    fn rejects_non_pow2_stacks() {
        let mut c = SystemConfig::default();
        c.num_stacks = 3;
        assert!(c.validate().is_err());
    }

    #[test]
    fn rejects_stripe_overflow() {
        let mut c = SystemConfig::default();
        c.fgp_interleave = 2048; // 2048*4 > 4096
        assert!(c.validate().is_err());
    }

    #[test]
    fn set_rejects_garbage_value() {
        let mut c = SystemConfig::default();
        assert!(c.set("num_stacks", "four").is_err());
    }

    #[test]
    fn mem_backend_parses_and_roundtrips() {
        let mut c = SystemConfig::default();
        assert_eq!(c.mem_backend, MemBackendKind::FixedLatency);
        c.set("mem_backend", "bank").unwrap();
        assert_eq!(c.mem_backend, MemBackendKind::BankLevel);
        c.set("mem_backend", "fixed-latency").unwrap();
        assert_eq!(c.mem_backend, MemBackendKind::FixedLatency);
        c.set("mem_backend", "cycle").unwrap();
        assert_eq!(c.mem_backend, MemBackendKind::CycleAccurate);
        c.set("mem_backend", "cycle-accurate").unwrap();
        assert_eq!(c.mem_backend, MemBackendKind::CycleAccurate);
        assert_eq!(c.mem_backend.to_string(), "cycle");
        assert!(c.set("mem_backend", "dramsim9000").is_err());
        let text = "mem_backend = bank\ndram_trfc_ns = 130.0\n";
        let c2 = SystemConfig::from_toml_str(text).unwrap();
        assert_eq!(c2.mem_backend, MemBackendKind::BankLevel);
        assert_eq!(c2.dram_trfc_ns, 130.0);
        let c3 = SystemConfig::from_toml_str("mem_backend = cycle\n").unwrap();
        assert_eq!(c3.mem_backend, MemBackendKind::CycleAccurate);
    }

    #[test]
    fn cycle_knobs_parse_and_validate() {
        let mut c = SystemConfig::default();
        assert_eq!(c.dram_ranks_per_channel, 1);
        assert_eq!(c.dram_row_policy, DramRowPolicy::Open);
        c.set("dram_tras_ns", "30").unwrap();
        c.set("dram_trrd_ns", "5").unwrap();
        c.set("dram_tfaw_ns", "20").unwrap();
        c.set("dram_ranks_per_channel", "2").unwrap();
        c.set("dram_row_policy", "closed").unwrap();
        c.set("dram_wq_high", "64").unwrap();
        c.set("dram_wq_low", "8").unwrap();
        c.set("dram_age_cap_ns", "1000").unwrap();
        assert!(c.validate().is_ok());
        assert_eq!(c.dram_row_policy, DramRowPolicy::Closed);
        assert_eq!(c.dram_row_policy.to_string(), "closed");
        assert!(c.set("dram_row_policy", "ajar").is_err());
        // Ranks must be a power of two dividing banks_per_channel (16).
        c.dram_ranks_per_channel = 3;
        assert!(c.validate().is_err());
        c.dram_ranks_per_channel = 32;
        assert!(c.validate().is_err());
        c.dram_ranks_per_channel = 4;
        assert!(c.validate().is_ok());
        // Watermarks: low strictly below high, high positive.
        c.dram_wq_low = 64;
        assert!(c.validate().is_err());
        c.dram_wq_low = 0;
        assert!(c.validate().is_ok());
        c.dram_wq_high = 0;
        assert!(c.validate().is_err());
        c.dram_wq_high = 32;
        c.dram_age_cap_ns = 0.0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn mix_knobs_parse_and_validate() {
        let mut c = SystemConfig::default();
        assert_eq!(c.mix_fairness, crate::sched::FairnessPolicy::Fcfs);
        c.set("mix_fairness", "rr").unwrap();
        assert_eq!(c.mix_fairness, crate::sched::FairnessPolicy::RoundRobin);
        assert!(c.set("mix_fairness", "lottery").is_err());
        c.set("mix_stagger_cycles", "5000").unwrap();
        assert_eq!(c.mix_stagger_cycles, 5000.0);
        assert!(c.validate().is_ok());
        c.mix_stagger_cycles = -1.0;
        assert!(c.validate().is_err());
        c.mix_stagger_cycles = f64::NAN;
        assert!(c.validate().is_err());
    }

    #[test]
    fn host_knobs_parse_and_validate() {
        let mut c = SystemConfig::default();
        assert_eq!(c.host_mlp, crate::host::HOST_MLP);
        assert_eq!(c.host_passes, 1);
        assert_eq!(c.host_ddr_fraction, 0.0);
        c.set("host_mlp", "16").unwrap();
        c.set("host_passes", "4").unwrap();
        c.set("host_ddr_fraction", "0.5").unwrap();
        c.set("host_ddr_bw_gbs", "32").unwrap();
        c.set("host_ddr_channels", "4").unwrap();
        assert!(c.validate().is_ok());
        assert_eq!(c.host_mlp, 16);
        assert_eq!(c.host_passes, 4);
        assert_eq!(c.host_ddr_fraction, 0.5);
        // Zero intensity is legal (it disables host traffic)...
        c.set("host_mlp", "0").unwrap();
        assert!(c.validate().is_ok());
        // ...but the DDR parameters must stay sane.
        c.host_ddr_fraction = 1.5;
        assert!(c.validate().is_err());
        c.host_ddr_fraction = f64::NAN;
        assert!(c.validate().is_err());
        c.host_ddr_fraction = 0.5;
        c.host_ddr_bw_gbs = 0.0;
        assert!(c.validate().is_err());
        c.host_ddr_bw_gbs = 64.0;
        c.host_ddr_channels = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn sim_threads_parses_and_defaults_to_auto() {
        let mut c = SystemConfig::default();
        assert_eq!(c.sim_threads, 0); // 0 = one thread per core
        c.set("sim_threads", "4").unwrap();
        assert_eq!(c.sim_threads, 4);
        assert!(c.validate().is_ok());
        assert!(c.set("sim_threads", "many").is_err());
        let c2 = SystemConfig::from_toml_str("sim_threads = 1\n").unwrap();
        assert_eq!(c2.sim_threads, 1);
    }

    #[test]
    fn shard_stacks_parses_and_defaults_to_sequential() {
        let mut c = SystemConfig::default();
        assert_eq!(c.shard_stacks, 1); // 1 = the sequential engine
        c.set("shard_stacks", "0").unwrap(); // 0 = one shard per stack
        assert_eq!(c.shard_stacks, 0);
        assert!(c.validate().is_ok());
        assert!(c.set("shard_stacks", "auto").is_err());
        let c2 = SystemConfig::from_toml_str("shard_stacks = 2\n").unwrap();
        assert_eq!(c2.shard_stacks, 2);
    }

    #[test]
    fn topology_knobs_parse_and_validate() {
        use crate::net::TopologyKind;
        let mut c = SystemConfig::default();
        assert_eq!(c.topology, TopologyKind::FullyConnected);
        c.set("topology", "line").unwrap();
        assert_eq!(c.topology, TopologyKind::Line);
        c.set("topology", "ring").unwrap();
        assert_eq!(c.topology, TopologyKind::Ring);
        c.set("topology", "mesh").unwrap();
        assert_eq!(c.topology, TopologyKind::Mesh2d);
        c.set("topology", "full").unwrap();
        assert_eq!(c.topology, TopologyKind::FullyConnected);
        assert!(c.set("topology", "torus").is_err());
        c.set("mesh_cols", "2").unwrap();
        c.set("hop_latency_ns", "25").unwrap();
        c.set("link_bw_gbs", "8").unwrap();
        c.set("net_window_cycles", "4096").unwrap();
        assert!(c.validate().is_ok());
        // mesh_cols must tile num_stacks (4).
        c.mesh_cols = 3;
        assert!(c.validate().is_err());
        c.mesh_cols = 8;
        assert!(c.validate().is_err());
        c.mesh_cols = 0;
        assert!(c.validate().is_ok());
        c.hop_latency_ns = -1.0;
        assert!(c.validate().is_err());
        c.hop_latency_ns = 30.0;
        c.link_bw_gbs = f64::NAN;
        assert!(c.validate().is_err());
        c.link_bw_gbs = 0.0;
        c.net_window_cycles = 0.0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn xlate_knobs_parse_and_validate() {
        let mut c = SystemConfig::default();
        // Defaults keep the frozen legacy model off the hierarchical path.
        assert_eq!(c.tlb_l1_entries, 0);
        assert!(!c.huge_pages);
        assert!(!c.tlb_flush_on_switch);
        assert!(c.validate().is_ok());
        c.set("tlb_l1_entries", "48").unwrap();
        c.set("tlb_l1_ways", "3").unwrap();
        c.set("tlb_l2_entries", "1024").unwrap();
        c.set("tlb_l2_ways", "8").unwrap();
        c.set("tlb_l2_hit_ns", "6").unwrap();
        c.set("ptw_slots", "4").unwrap();
        c.set("ptw_level_ns", "40").unwrap();
        c.set("huge_pages", "on").unwrap();
        c.set("tlb_flush_on_switch", "on").unwrap();
        assert!(c.validate().is_ok());
        assert!(c.huge_pages);
        assert!(c.tlb_flush_on_switch);
        c.set("huge_pages", "off").unwrap();
        assert!(!c.huge_pages);
        assert!(c.set("huge_pages", "maybe").is_err());
        // Non-representable sizes are rejected up front, not rounded.
        c.tlb_l1_entries = 7;
        assert!(c.validate().is_err());
        c.tlb_l1_entries = 48;
        c.tlb_l2_entries = 7;
        assert!(c.validate().is_err());
        c.tlb_l2_entries = 512;
        c.ptw_slots = 0;
        assert!(c.validate().is_err());
        c.ptw_slots = 8;
        c.ptw_level_ns = 0.0;
        assert!(c.validate().is_err());
        c.ptw_level_ns = 50.0;
        assert!(c.validate().is_ok());
        // Legacy budget is honored too (satellite: 48 must not become 64).
        let mut c = SystemConfig::default();
        c.tlb_entries = 48;
        assert!(c.validate().is_ok());
        c.tlb_entries = 7;
        assert!(c.validate().is_err());
        c.tlb_entries = 0;
        assert!(c.validate().is_err());
        // Huge pages need whole base pages per 2 MB frame.
        let mut c = SystemConfig::default();
        c.huge_pages = true;
        assert!(c.validate().is_ok());
        c.page_size = 4 << 20;
        c.fgp_interleave = 128;
        assert!(c.validate().is_err());
    }

    #[test]
    fn rejects_bad_bank_timing() {
        let mut c = SystemConfig::default();
        c.bank_groups_per_channel = 3;
        assert!(c.validate().is_err());
        let mut c = SystemConfig::default();
        c.dram_trcd_ns = 0.0;
        assert!(c.validate().is_err());
        let mut c = SystemConfig::default();
        c.dram_trfc_ns = c.dram_trefi_ns;
        assert!(c.validate().is_err());
        let mut c = SystemConfig::default();
        c.dram_tras_ns = -1.0;
        assert!(c.validate().is_err());
        let mut c = SystemConfig::default();
        c.dram_tfaw_ns = f64::NAN;
        assert!(c.validate().is_err());
    }
}
