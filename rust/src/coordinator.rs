//! The CODA coordinator: the end-to-end runtime that ties the pieces
//! together the way the paper's system does.
//!
//! For a kernel launch it (1) runs the compile-time symbolic analysis when
//! the workload ships IR, (2) profiles a trace sample for the irregular
//! objects, (3) builds the placement plan (Eq 2/3 or a baseline), (4) maps
//! the objects into virtual memory through the page-group-aware allocator,
//! and (5) simulates execution under the matching scheduling policy. The
//! same coordinator drives every baseline so comparisons are
//! apples-to-apples.
//!
//! Since the experiment-API redesign the pipeline itself lives in
//! [`crate::session`]: every `run_*` method here constructs the matching
//! [`crate::spec::ExperimentSpec`] and lowers it through a
//! [`Session`], so the coordinator is a convenience facade over the one
//! declarative entry point (and is proven cycle-identical to the
//! pre-redesign code by `tests/spec_equiv.rs`).

use crate::config::SystemConfig;
use crate::placement::PlacementPlan;
use crate::sched::Policy;
use crate::session::Session;
use crate::spec::{ExperimentSpec, WorkloadSel};
use crate::stats::RunReport;
use crate::workloads::BuiltWorkload;

/// The mechanisms of §6 (Fig 8/14 plus the footnote-6 migration variant
/// and the work-stealing extension of §4.3.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mechanism {
    /// Baseline: everything fine-grain interleaved, blocks to any SM.
    FgpOnly,
    /// Every page coarse-grain, circular stack order, blocks to any SM.
    CgpOnly,
    /// CGP with oracle first-touch page placement + affinity schedule.
    CgpFta,
    /// Pages migrate to the first-touching stack at runtime.
    MigrationFta,
    /// The paper's mechanism: analysis-driven placement + affinity.
    Coda,
    /// Fig 14's isolation: FGP data placement but affinity scheduling.
    FgpAffinity,
    /// CODA with the work-stealing scheduler extension.
    CodaStealing,
}

impl Mechanism {
    /// Every mechanism, in the paper's presentation order.
    pub const ALL: [Mechanism; 7] = [
        Mechanism::FgpOnly,
        Mechanism::CgpOnly,
        Mechanism::CgpFta,
        Mechanism::MigrationFta,
        Mechanism::Coda,
        Mechanism::FgpAffinity,
        Mechanism::CodaStealing,
    ];

    /// Parse a CLI/spec spelling; `None` for unknown names.
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s.trim() {
            "fgp" | "fgp-only" => Mechanism::FgpOnly,
            "cgp" | "cgp-only" => Mechanism::CgpOnly,
            "fta" => Mechanism::CgpFta,
            "migrate" => Mechanism::MigrationFta,
            "coda" => Mechanism::Coda,
            "fgp-affinity" => Mechanism::FgpAffinity,
            "steal" => Mechanism::CodaStealing,
            _ => return None,
        })
    }

    /// Canonical CLI/spec spelling (round-trips through [`Self::parse`];
    /// [`Self::name`] is the human-facing report label instead).
    pub fn key(&self) -> &'static str {
        match self {
            Mechanism::FgpOnly => "fgp",
            Mechanism::CgpOnly => "cgp",
            Mechanism::CgpFta => "fta",
            Mechanism::MigrationFta => "migrate",
            Mechanism::Coda => "coda",
            Mechanism::FgpAffinity => "fgp-affinity",
            Mechanism::CodaStealing => "steal",
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Mechanism::FgpOnly => "FGP-Only",
            Mechanism::CgpOnly => "CGP-Only",
            Mechanism::CgpFta => "CGP-Only+FTA",
            Mechanism::MigrationFta => "Migration-FTA",
            Mechanism::Coda => "CODA",
            Mechanism::FgpAffinity => "FGP-Only+Affinity",
            Mechanism::CodaStealing => "CODA+Stealing",
        }
    }

    /// Scheduling policy each mechanism uses.
    pub fn policy(&self) -> Policy {
        match self {
            Mechanism::FgpOnly | Mechanism::CgpOnly => Policy::Baseline,
            Mechanism::CodaStealing => Policy::AffinityStealing,
            _ => Policy::Affinity,
        }
    }
}

/// The coordinator.
pub struct Coordinator {
    cfg: SystemConfig,
}

impl Coordinator {
    pub fn new(cfg: SystemConfig) -> Self {
        Self { cfg }
    }

    pub fn config(&self) -> &SystemConfig {
        &self.cfg
    }

    /// Select the DRAM timing backend for subsequent runs (builder style;
    /// equivalent to setting `mem_backend` in the config up front).
    pub fn with_mem_backend(mut self, kind: crate::config::MemBackendKind) -> Self {
        self.cfg.mem_backend = kind;
        self
    }

    /// Build the placement plan a mechanism uses for a workload
    /// (delegates to [`crate::session::plan_for_mechanism`], which owns
    /// the analysis/profiler pipeline since the experiment-API redesign).
    pub fn plan_for(&self, wl: &BuiltWorkload, mech: Mechanism) -> PlacementPlan {
        crate::session::plan_for_mechanism(&self.cfg, wl, mech)
    }

    /// Run one workload under one mechanism.
    ///
    /// A thin wrapper since the experiment-API redesign: it builds the
    /// single-kernel [`ExperimentSpec`] and lowers it through
    /// [`Session`], which owns the plan/fallback/mapping pipeline
    /// (including §6.4's no-degradation guarantee).
    /// `tests/spec_equiv.rs` proves this wrapper cycle-identical to the
    /// frozen pre-spec implementation.
    pub fn run(&self, wl: &BuiltWorkload, mech: Mechanism) -> crate::Result<RunReport> {
        let spec = ExperimentSpec::kernel(WorkloadSel::Prebuilt(wl), mech);
        Ok(Session::new(self.cfg.clone(), spec)?.run()?.run)
    }

    /// Run a workload under several mechanisms (sharing the generated
    /// trace), returning reports in the same order.
    pub fn compare(
        &self,
        wl: &BuiltWorkload,
        mechs: &[Mechanism],
    ) -> crate::Result<Vec<RunReport>> {
        mechs.iter().map(|m| self.run(wl, *m)).collect()
    }

    /// Run a multiprogrammed mix (§6.5 / Fig 12 shape: one app per
    /// stack, all launched together) under this coordinator's config.
    pub fn run_mix(
        &self,
        apps: &[&BuiltWorkload],
        placement: crate::multiprog::MixPlacement,
    ) -> crate::Result<(Vec<f64>, RunReport)> {
        let mix = crate::multiprog::Mix {
            apps: apps.to_vec(),
        };
        crate::multiprog::run_mix(&self.cfg, &mix, placement)
    }

    /// Run a multi-kernel mix with time-shared SMs: `launches` pairs each
    /// workload with its arrival time (cycles); the mix may hold more
    /// kernels than stacks. Uses the config's `mix_fairness`.
    pub fn run_multi(
        &self,
        launches: &[(&BuiltWorkload, f64)],
        placement: crate::multiprog::MixPlacement,
        policy: Policy,
    ) -> crate::Result<RunReport> {
        let mix = crate::multiprog::MultiMix {
            launches: launches
                .iter()
                .map(|&(app, arrival)| crate::multiprog::KernelLaunch { app, arrival })
                .collect(),
        };
        crate::multiprog::run_multi(&self.cfg, &mix, placement, policy, self.cfg.mix_fairness)
    }

    /// Run a CHoNDA-style co-run: the NDP `launches` (possibly empty)
    /// concurrently with a host request stream sweeping `host`'s objects
    /// at the config's host intensity (`host_mlp`/`host_passes`). Uses
    /// the config's `mix_fairness`.
    pub fn run_hostmix(
        &self,
        launches: &[(&BuiltWorkload, f64)],
        host: Option<&BuiltWorkload>,
        placement: crate::multiprog::MixPlacement,
        policy: Policy,
    ) -> crate::Result<RunReport> {
        let mix = crate::multiprog::MultiMix {
            launches: launches
                .iter()
                .map(|&(app, arrival)| crate::multiprog::KernelLaunch { app, arrival })
                .collect(),
        };
        crate::multiprog::run_hostmix(
            &self.cfg,
            &mix,
            host,
            placement,
            policy,
            self.cfg.mix_fairness,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::suite;

    fn cfg() -> SystemConfig {
        SystemConfig::test_small()
    }

    #[test]
    fn coda_beats_fgp_on_block_exclusive() {
        let c = cfg();
        let coord = Coordinator::new(c.clone());
        let wl = suite::build("DC", &c).unwrap();
        let fgp = coord.run(&wl, Mechanism::FgpOnly).unwrap();
        let coda = coord.run(&wl, Mechanism::Coda).unwrap();
        assert!(
            coda.speedup_over(&fgp) > 1.05,
            "speedup {}",
            coda.speedup_over(&fgp)
        );
        assert!(coda.remote_reduction_over(&fgp) > 0.3);
    }

    #[test]
    fn coda_never_slower_than_fgp_on_sharing() {
        // §6.4: "CODA does not degrade performance in any case" — sharing
        // objects stay FGP, so the plan degenerates to the baseline's.
        let c = cfg();
        let coord = Coordinator::new(c.clone());
        let wl = suite::build("HS3D", &c).unwrap();
        let fgp = coord.run(&wl, Mechanism::FgpOnly).unwrap();
        let coda = coord.run(&wl, Mechanism::Coda).unwrap();
        assert!(coda.speedup_over(&fgp) > 0.9);
    }

    #[test]
    fn coda_uses_cgp_for_exclusive_fgp_for_shared() {
        let c = cfg();
        let coord = Coordinator::new(c.clone());
        let wl = suite::build("KM", &c).unwrap();
        let plan = coord.plan_for(&wl, Mechanism::Coda);
        use crate::placement::Placement;
        // features (obj 0) localized; clusters (obj 2) distributed.
        assert!(matches!(plan.per_object[0], Placement::Cgp { .. }));
        assert_eq!(plan.per_object[2], Placement::Fgp);
    }

    #[test]
    fn all_mechanisms_run_on_one_workload() {
        let c = cfg();
        let coord = Coordinator::new(c.clone());
        let wl = suite::build("NN", &c).unwrap();
        for m in [
            Mechanism::FgpOnly,
            Mechanism::CgpOnly,
            Mechanism::CgpFta,
            Mechanism::MigrationFta,
            Mechanism::Coda,
            Mechanism::FgpAffinity,
            Mechanism::CodaStealing,
        ] {
            let r = coord.run(&wl, m).unwrap();
            assert!(r.cycles > 0.0, "{}", m.name());
            assert_eq!(
                r.accesses.ndp_total() + r.accesses.l2_hits,
                wl.total_accesses(),
                "{}",
                m.name()
            );
        }
    }

    #[test]
    fn mem_backend_threads_through_reports() {
        let c = cfg();
        let wl = suite::build("NN", &c).unwrap();
        let fixed = Coordinator::new(c.clone())
            .run(&wl, Mechanism::FgpOnly)
            .unwrap();
        let bank = Coordinator::new(c.clone())
            .with_mem_backend(crate::config::MemBackendKind::BankLevel)
            .run(&wl, Mechanism::FgpOnly)
            .unwrap();
        assert_eq!(fixed.accesses, bank.accesses);
        assert_eq!(fixed.mem_backend, "fixed");
        assert_eq!(bank.mem_backend, "bank");
    }

    #[test]
    fn reports_are_deterministic() {
        let c = cfg();
        let coord = Coordinator::new(c.clone());
        let wl = suite::build("KM", &c).unwrap();
        let a = coord.run(&wl, Mechanism::Coda).unwrap();
        let b = coord.run(&wl, Mechanism::Coda).unwrap();
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.accesses, b.accesses);
    }
}
