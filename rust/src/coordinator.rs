//! The CODA coordinator: the end-to-end runtime that ties the pieces
//! together the way the paper's system does.
//!
//! For a kernel launch it (1) runs the compile-time symbolic analysis when
//! the workload ships IR, (2) profiles a trace sample for the irregular
//! objects, (3) builds the placement plan (Eq 2/3 or a baseline), (4) maps
//! the objects into virtual memory through the page-group-aware allocator,
//! and (5) simulates execution under the matching scheduling policy. The
//! same coordinator drives every baseline so comparisons are
//! apples-to-apples.

use crate::analysis::{analyze_kernel, profile_trace, ObjectPattern};
use crate::config::SystemConfig;
use crate::placement::{self, PlacementPlan};
use crate::sched::{affinity_stack, Policy};
use crate::sim::{map_objects, KernelRun};
use crate::stats::RunReport;
use crate::workloads::BuiltWorkload;
use std::collections::HashMap;

/// The mechanisms of §6 (Fig 8/14 plus the footnote-6 migration variant
/// and the work-stealing extension of §4.3.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mechanism {
    /// Baseline: everything fine-grain interleaved, blocks to any SM.
    FgpOnly,
    /// Every page coarse-grain, circular stack order, blocks to any SM.
    CgpOnly,
    /// CGP with oracle first-touch page placement + affinity schedule.
    CgpFta,
    /// Pages migrate to the first-touching stack at runtime.
    MigrationFta,
    /// The paper's mechanism: analysis-driven placement + affinity.
    Coda,
    /// Fig 14's isolation: FGP data placement but affinity scheduling.
    FgpAffinity,
    /// CODA with the work-stealing scheduler extension.
    CodaStealing,
}

impl Mechanism {
    pub fn name(&self) -> &'static str {
        match self {
            Mechanism::FgpOnly => "FGP-Only",
            Mechanism::CgpOnly => "CGP-Only",
            Mechanism::CgpFta => "CGP-Only+FTA",
            Mechanism::MigrationFta => "Migration-FTA",
            Mechanism::Coda => "CODA",
            Mechanism::FgpAffinity => "FGP-Only+Affinity",
            Mechanism::CodaStealing => "CODA+Stealing",
        }
    }

    /// Scheduling policy each mechanism uses.
    pub fn policy(&self) -> Policy {
        match self {
            Mechanism::FgpOnly | Mechanism::CgpOnly => Policy::Baseline,
            Mechanism::CodaStealing => Policy::AffinityStealing,
            _ => Policy::Affinity,
        }
    }
}

/// The coordinator.
pub struct Coordinator {
    cfg: SystemConfig,
}

impl Coordinator {
    pub fn new(cfg: SystemConfig) -> Self {
        Self { cfg }
    }

    pub fn config(&self) -> &SystemConfig {
        &self.cfg
    }

    /// Select the DRAM timing backend for subsequent runs (builder style;
    /// equivalent to setting `mem_backend` in the config up front).
    pub fn with_mem_backend(mut self, kind: crate::config::MemBackendKind) -> Self {
        self.cfg.mem_backend = kind;
        self
    }

    /// Build the placement plan a mechanism uses for a workload.
    pub fn plan_for(&self, wl: &BuiltWorkload, mech: Mechanism) -> PlacementPlan {
        let n = wl.trace.objects.len();
        match mech {
            Mechanism::FgpOnly | Mechanism::FgpAffinity => PlacementPlan::all_fgp(n),
            Mechanism::CgpOnly => placement::cgp_only_plan(n, &self.cfg),
            Mechanism::CgpFta => placement::fta_plan(&wl.trace, &self.cfg),
            Mechanism::MigrationFta => placement::migration_fta_plan(n),
            Mechanism::Coda | Mechanism::CodaStealing => {
                // Compile-time analysis where IR exists...
                let compile: HashMap<u16, ObjectPattern> = wl
                    .ir
                    .as_ref()
                    .map(|ir| analyze_kernel(ir, &wl.env))
                    .unwrap_or_default();
                // ...profiler for the rest (§4.3.2's fallback). The
                // profiler sees a trace sample, as a real profiling run
                // would.
                let cfg = &self.cfg;
                let profile =
                    profile_trace(&wl.trace, cfg.page_size, |b| affinity_stack(b, cfg));
                placement::coda_plan(n, &compile, &profile, cfg)
            }
        }
    }

    /// Fraction of a workload's accesses that land on objects the plan
    /// localizes (CGP or page-overridden).
    fn localizable_traffic(&self, wl: &BuiltWorkload, plan: &PlacementPlan) -> f64 {
        let mut per_obj = vec![0u64; wl.trace.objects.len()];
        for b in &wl.trace.blocks {
            for a in &b.accesses {
                per_obj[a.obj as usize] += 1;
            }
        }
        let total: u64 = per_obj.iter().sum();
        let localized: u64 = per_obj
            .iter()
            .enumerate()
            .filter(|(o, _)| {
                !matches!(plan.per_object[*o], crate::placement::Placement::Fgp)
            })
            .map(|(_, n)| *n)
            .sum();
        if total == 0 {
            0.0
        } else {
            localized as f64 / total as f64
        }
    }

    /// Run one workload under one mechanism.
    pub fn run(&self, wl: &BuiltWorkload, mech: Mechanism) -> crate::Result<RunReport> {
        let mut plan = self.plan_for(wl, mech);
        let mut policy = mech.policy();
        // §6.4's no-degradation guarantee: when nothing meaningful is
        // localizable, CODA's plan degenerates to the baseline's — all-FGP
        // placement with unrestricted scheduling — so sharing-dominated
        // workloads behave exactly like FGP-Only.
        if matches!(mech, Mechanism::Coda | Mechanism::CodaStealing)
            && self.localizable_traffic(wl, &plan) < 0.05
        {
            plan = PlacementPlan::all_fgp(wl.trace.objects.len());
            policy = crate::sched::Policy::Baseline;
        }
        let (mut vm, bases, cgp_pages, fgp_pages) = map_objects(&self.cfg, &wl.trace, &plan)?;
        let mut report = KernelRun {
            cfg: &self.cfg,
            trace: &wl.trace,
            vm: &mut vm,
            obj_base: &bases,
            policy,
            migrate_on_first_touch: plan.migrate_on_first_touch,
        }
        .run();
        report.mechanism = mech.name().into();
        report.cgp_pages = cgp_pages;
        report.fgp_pages = fgp_pages;
        Ok(report)
    }

    /// Run a workload under several mechanisms (sharing the generated
    /// trace), returning reports in the same order.
    pub fn compare(
        &self,
        wl: &BuiltWorkload,
        mechs: &[Mechanism],
    ) -> crate::Result<Vec<RunReport>> {
        mechs.iter().map(|m| self.run(wl, *m)).collect()
    }

    /// Run a multiprogrammed mix (§6.5 / Fig 12 shape: one app per
    /// stack, all launched together) under this coordinator's config.
    pub fn run_mix(
        &self,
        apps: &[&BuiltWorkload],
        placement: crate::multiprog::MixPlacement,
    ) -> crate::Result<(Vec<f64>, RunReport)> {
        let mix = crate::multiprog::Mix {
            apps: apps.to_vec(),
        };
        crate::multiprog::run_mix(&self.cfg, &mix, placement)
    }

    /// Run a multi-kernel mix with time-shared SMs: `launches` pairs each
    /// workload with its arrival time (cycles); the mix may hold more
    /// kernels than stacks. Uses the config's `mix_fairness`.
    pub fn run_multi(
        &self,
        launches: &[(&BuiltWorkload, f64)],
        placement: crate::multiprog::MixPlacement,
        policy: Policy,
    ) -> crate::Result<RunReport> {
        let mix = crate::multiprog::MultiMix {
            launches: launches
                .iter()
                .map(|&(app, arrival)| crate::multiprog::KernelLaunch { app, arrival })
                .collect(),
        };
        crate::multiprog::run_multi(&self.cfg, &mix, placement, policy, self.cfg.mix_fairness)
    }

    /// Run a CHoNDA-style co-run: the NDP `launches` (possibly empty)
    /// concurrently with a host request stream sweeping `host`'s objects
    /// at the config's host intensity (`host_mlp`/`host_passes`). Uses
    /// the config's `mix_fairness`.
    pub fn run_hostmix(
        &self,
        launches: &[(&BuiltWorkload, f64)],
        host: Option<&BuiltWorkload>,
        placement: crate::multiprog::MixPlacement,
        policy: Policy,
    ) -> crate::Result<RunReport> {
        let mix = crate::multiprog::MultiMix {
            launches: launches
                .iter()
                .map(|&(app, arrival)| crate::multiprog::KernelLaunch { app, arrival })
                .collect(),
        };
        crate::multiprog::run_hostmix(
            &self.cfg,
            &mix,
            host,
            placement,
            policy,
            self.cfg.mix_fairness,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::suite;

    fn cfg() -> SystemConfig {
        SystemConfig::test_small()
    }

    #[test]
    fn coda_beats_fgp_on_block_exclusive() {
        let c = cfg();
        let coord = Coordinator::new(c.clone());
        let wl = suite::build("DC", &c).unwrap();
        let fgp = coord.run(&wl, Mechanism::FgpOnly).unwrap();
        let coda = coord.run(&wl, Mechanism::Coda).unwrap();
        assert!(
            coda.speedup_over(&fgp) > 1.05,
            "speedup {}",
            coda.speedup_over(&fgp)
        );
        assert!(coda.remote_reduction_over(&fgp) > 0.3);
    }

    #[test]
    fn coda_never_slower_than_fgp_on_sharing() {
        // §6.4: "CODA does not degrade performance in any case" — sharing
        // objects stay FGP, so the plan degenerates to the baseline's.
        let c = cfg();
        let coord = Coordinator::new(c.clone());
        let wl = suite::build("HS3D", &c).unwrap();
        let fgp = coord.run(&wl, Mechanism::FgpOnly).unwrap();
        let coda = coord.run(&wl, Mechanism::Coda).unwrap();
        assert!(coda.speedup_over(&fgp) > 0.9);
    }

    #[test]
    fn coda_uses_cgp_for_exclusive_fgp_for_shared() {
        let c = cfg();
        let coord = Coordinator::new(c.clone());
        let wl = suite::build("KM", &c).unwrap();
        let plan = coord.plan_for(&wl, Mechanism::Coda);
        use crate::placement::Placement;
        // features (obj 0) localized; clusters (obj 2) distributed.
        assert!(matches!(plan.per_object[0], Placement::Cgp { .. }));
        assert_eq!(plan.per_object[2], Placement::Fgp);
    }

    #[test]
    fn all_mechanisms_run_on_one_workload() {
        let c = cfg();
        let coord = Coordinator::new(c.clone());
        let wl = suite::build("NN", &c).unwrap();
        for m in [
            Mechanism::FgpOnly,
            Mechanism::CgpOnly,
            Mechanism::CgpFta,
            Mechanism::MigrationFta,
            Mechanism::Coda,
            Mechanism::FgpAffinity,
            Mechanism::CodaStealing,
        ] {
            let r = coord.run(&wl, m).unwrap();
            assert!(r.cycles > 0.0, "{}", m.name());
            assert_eq!(
                r.accesses.ndp_total() + r.accesses.l2_hits,
                wl.total_accesses(),
                "{}",
                m.name()
            );
        }
    }

    #[test]
    fn mem_backend_threads_through_reports() {
        let c = cfg();
        let wl = suite::build("NN", &c).unwrap();
        let fixed = Coordinator::new(c.clone())
            .run(&wl, Mechanism::FgpOnly)
            .unwrap();
        let bank = Coordinator::new(c.clone())
            .with_mem_backend(crate::config::MemBackendKind::BankLevel)
            .run(&wl, Mechanism::FgpOnly)
            .unwrap();
        assert_eq!(fixed.accesses, bank.accesses);
        assert_eq!(fixed.mem_backend, "fixed");
        assert_eq!(bank.mem_backend, "bank");
    }

    #[test]
    fn reports_are_deterministic() {
        let c = cfg();
        let coord = Coordinator::new(c.clone());
        let wl = suite::build("KM", &c).unwrap();
        let a = coord.run(&wl, Mechanism::Coda).unwrap();
        let b = coord.run(&wl, Mechanism::Coda).unwrap();
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.accesses, b.accesses);
    }
}
