//! Energy accounting — the paper's second motivating metric ("performance
//! **and energy efficiency**", §1). Off-chip SerDes crossings cost an
//! order of magnitude more energy per bit than on-stack TSV transfers, so
//! remote-access reduction translates directly into interconnect energy
//! savings; this module turns a [`RunReport`]'s traffic counters into
//! picojoule estimates.
//!
//! Coefficients follow the published NDP literature (HMC/HBM-era numbers
//! commonly used in the paper's citations [4, 39]):
//! DRAM core access ≈ 4 pJ/bit, TSV/on-stack link ≈ 0.1 pJ/bit, off-chip
//! SerDes link ≈ 2–6 pJ/bit per crossing. All are configurable.

use crate::stats::RunReport;

/// Energy coefficients in picojoules per bit.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EnergyModel {
    /// DRAM array access (activate + read/write amortized).
    pub dram_pj_per_bit: f64,
    /// On-stack TSV + crossbar transfer.
    pub local_pj_per_bit: f64,
    /// One off-chip SerDes crossing (remote links; two per hop-pair).
    pub serdes_pj_per_bit: f64,
    /// Host link crossing.
    pub host_pj_per_bit: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        Self {
            dram_pj_per_bit: 4.0,
            local_pj_per_bit: 0.1,
            serdes_pj_per_bit: 4.0,
            host_pj_per_bit: 2.0,
        }
    }
}

/// Energy breakdown of one run, in microjoules.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EnergyReport {
    pub dram_uj: f64,
    pub local_uj: f64,
    pub remote_uj: f64,
    pub host_uj: f64,
}

impl EnergyReport {
    pub fn total_uj(&self) -> f64 {
        self.dram_uj + self.local_uj + self.remote_uj + self.host_uj
    }
}

impl EnergyModel {
    /// Estimate interconnect + DRAM energy for a simulated run.
    ///
    /// `line_size` is the access granularity the counters were taken at.
    pub fn estimate(&self, r: &RunReport, line_size: u64) -> EnergyReport {
        let bits = |n: u64| (n * line_size * 8) as f64;
        let pj_to_uj = 1e-6;
        // Every served access pays DRAM + one local (on-stack) transfer at
        // the owning stack; remote accesses additionally pay the request
        // and response SerDes crossings (4 crossings: out+in each way).
        let dram_bits = bits(r.accesses.local + r.accesses.remote + r.accesses.host);
        let local_bits = bits(r.accesses.local + r.accesses.remote);
        let remote_bits = bits(r.accesses.remote) * 4.0;
        let host_bits = bits(r.accesses.host) * 2.0;
        EnergyReport {
            dram_uj: dram_bits * self.dram_pj_per_bit * pj_to_uj,
            local_uj: local_bits * self.local_pj_per_bit * pj_to_uj,
            remote_uj: remote_bits * self.serdes_pj_per_bit * pj_to_uj,
            host_uj: host_bits * self.host_pj_per_bit * pj_to_uj,
        }
    }

    /// Interconnect+DRAM energy-efficiency improvement of `run` over
    /// `baseline` (>1 means `run` uses less energy).
    pub fn improvement(&self, run: &RunReport, baseline: &RunReport, line_size: u64) -> f64 {
        let a = self.estimate(baseline, line_size).total_uj();
        let b = self.estimate(run, line_size).total_uj();
        if b == 0.0 {
            1.0
        } else {
            a / b
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::AccessStats;

    fn report(local: u64, remote: u64) -> RunReport {
        RunReport {
            accesses: AccessStats {
                local,
                remote,
                ..Default::default()
            },
            ..Default::default()
        }
    }

    #[test]
    fn remote_accesses_dominate_interconnect_energy() {
        let m = EnergyModel::default();
        let all_local = m.estimate(&report(1000, 0), 128);
        let all_remote = m.estimate(&report(0, 1000), 128);
        assert!(all_remote.remote_uj > 100.0 * all_local.remote_uj.max(1e-12));
        assert!(all_remote.total_uj() > 2.0 * all_local.total_uj());
        // DRAM energy is placement-invariant.
        assert!((all_local.dram_uj - all_remote.dram_uj).abs() < 1e-9);
    }

    #[test]
    fn improvement_tracks_remote_reduction() {
        let m = EnergyModel::default();
        let fgp = report(250, 750);
        let coda = report(950, 50);
        let imp = m.improvement(&coda, &fgp, 128);
        assert!(imp > 1.5, "improvement {imp}");
    }

    #[test]
    fn hand_computed_numbers() {
        let m = EnergyModel {
            dram_pj_per_bit: 1.0,
            local_pj_per_bit: 1.0,
            serdes_pj_per_bit: 1.0,
            host_pj_per_bit: 1.0,
        };
        // 1 local access of 128B = 1024 bits: 1024 pJ dram + 1024 pJ local.
        let e = m.estimate(&report(1, 0), 128);
        assert!((e.dram_uj - 1024.0 * 1e-6).abs() < 1e-12);
        assert!((e.local_uj - 1024.0 * 1e-6).abs() < 1e-12);
        assert_eq!(e.remote_uj, 0.0);
        // 1 remote access: dram + local at owner + 4 serdes crossings.
        let e = m.estimate(&report(0, 1), 128);
        assert!((e.remote_uj - 4.0 * 1024.0 * 1e-6).abs() < 1e-12);
    }

    #[test]
    fn zero_run_is_safe() {
        let m = EnergyModel::default();
        let e = m.estimate(&report(0, 0), 128);
        assert_eq!(e.total_uj(), 0.0);
        assert_eq!(m.improvement(&report(0, 0), &report(0, 0), 128), 1.0);
    }
}
