//! The shared discrete-event simulation core.
//!
//! Before this module existed, `sim::KernelRun::run` and
//! `multiprog::run_mix` each carried their own copy of the event loop —
//! one event heap, SM residency slots, the TLB walk, the dual-mode
//! address mapping, interconnect queuing, and per-stack `MemBackend`
//! dispatch. The copies could silently diverge, which is fatal for the
//! multiprogrammed results (§6.5): contention between co-running request
//! streams is exactly where placement policies earn or lose their wins,
//! so the engine arbitrating those streams must be single-sourced.
//!
//! [`Engine`] owns the event-loop physics; callers stay in charge of
//! *what* runs through a [`BlockSource`]: the source seeds the initial
//! SM residency, refills a slot whenever a block retires, and (for
//! multi-kernel scheduling) announces future kernel arrival times so the
//! engine can wake idle slots. `sim.rs` is the single-kernel adapter and
//! `session.rs` — the lowering layer behind the declarative
//! [`crate::spec::ExperimentSpec`] API — owns every multiprogrammed and
//! host-co-run dispatch; `tests/differential` locks in that the unified
//! loop is cycle-identical to the pre-refactor copies for every mechanism
//! under both DRAM backends, and `tests/spec_equiv.rs` extends the same
//! guarantee to the spec lowering.
//!
//! Besides NDP thread-blocks, the engine can co-run a **host-processor
//! request stream** ([`HostStream`], CHoNDA-style — arXiv 1908.06362):
//! an MLP-limited window of host requests injected through the per-stack
//! Host ports, contending with NDP accesses for interconnect slots and
//! per-stack DRAM dispatch inside the *same* event heap. With no host
//! stream attached (or `host_mlp = 0`) the engine executes exactly as
//! before — not one extra f64 operation — so NDP-only results stay
//! bit-identical; `tests/host_contention.rs` locks that in.
//!
//! The loop is written to be fast as well as single-sourced: per-access
//! DRAM dispatch goes through the statically-dispatched
//! [`crate::mem::MemBackendImpl`] (no vtable on the hot path), heap
//! entries are packed to 32 bytes and the heap is pre-sized to its
//! outstanding-event bound, window-invariant loads are hoisted out of
//! the access loop, and the host stream's object lookup is an O(1)
//! incremental cursor. Every one of these shapes wall-clock time only —
//! the differential, spec-equivalence and golden suites pin the
//! simulated results bit-exactly (see `docs/ARCHITECTURE.md`,
//! §Performance).

use crate::addr::{large_page_mapper, AddressMapper, Granularity, VirtualAddress};
use crate::config::SystemConfig;
use crate::gpu::{Sm, Topology};
use crate::mem::{self, MemBackend, MemBackendImpl, MemStats};
use crate::net::Interconnect;
use crate::stats::{AccessStats, LinkStat, RunReport, XlateStats};
use crate::trace::KernelTrace;
use crate::vm::VirtualMemory;
use crate::xlate::TranslationUnit;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Event key ordering by time (f64 bit-monotonic for non-negative reals),
/// tie-broken by sequence number for determinism.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct TimeKey(u64, u64);

impl TimeKey {
    /// The event time's raw `f64` bits (the sharded engine peeks at its
    /// next event time to publish conservative window bounds).
    #[inline]
    pub fn time_bits(self) -> u64 {
        self.0
    }
}

/// Build a heap key from an event time and a sequence number.
///
/// Rejects NaN and negative times in **every** build profile: the
/// `to_bits` ordering trick is only monotonic on non-negative reals, and
/// before this was a hard assert a NaN produced in a release build would
/// silently corrupt the heap order instead of failing loudly.
#[inline]
pub fn key(t: f64, seq: u64) -> TimeKey {
    assert!(
        t >= 0.0,
        "event time must be a non-negative real, got {t}"
    );
    TimeKey(t.to_bits(), seq)
}

/// Fast deterministic hash for the L2-filter decision (splitmix finalizer).
#[inline]
pub fn line_hash(x: u64) -> u64 {
    let mut z = x.wrapping_mul(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z ^ (z >> 31)
}

/// One application (kernel) the engine can execute blocks of.
#[derive(Clone, Copy, Debug)]
pub struct AppCtx<'a> {
    pub trace: &'a KernelTrace,
    /// Base virtual address of each of the app's objects (by `Access::obj`).
    pub obj_base: &'a [VirtualAddress],
}

/// A block scheduled by a [`BlockSource`]: which app, and which entry of
/// that app's `trace.blocks` (an index, not a `block_id`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BlockRef {
    pub app: u32,
    pub block: u32,
}

/// Supplies thread-blocks to the engine. This is the seam between the
/// shared event-loop physics and each caller's scheduling policy.
///
/// # Contract
///
/// The source owns *which block runs where*; the engine owns *when
/// everything happens*. The engine calls the three methods in a strict
/// pattern — [`seed`](Self::seed) exactly once before any event fires,
/// then [`refill`](Self::refill) every time a residency slot frees, and
/// [`next_arrival_after`](Self::next_arrival_after) whenever a slot
/// would otherwise idle forever — and a source must uphold:
///
/// * **Exactly-once dispatch.** Every unit of work is handed out at most
///   once across `seed` + `refill`; the engine never returns blocks. (A
///   source may dispatch the same *template* `BlockRef` once per logical
///   request — the service-mode stream does — because the engine keeps no
///   per-block state; "exactly once" is about never double-issuing the
///   same pending unit, not about `BlockRef` values being unique.)
/// * **Determinism.** Decisions may depend only on the call sequence and
///   `now` values, never on ambient state (clocks, randomness), or the
///   differential/golden suites break.
/// * **Arrival honesty.** `next_arrival_after(now)` must be strictly
///   greater than `now` and must not under-promise: if work will become
///   eligible at `t`, some call must eventually report a time `<= t`,
///   otherwise idle slots sleep through the arrival and blocks are lost.
///   Returning `None` means "no future work beyond what refill sees".
pub trait BlockSource {
    /// Seed the initial SM residency at t=0. Call `place(sm_id, slot,
    /// block)` once per occupied slot; the call order defines the event
    /// sequence order at t=0 (and therefore tie-breaking), so adapters
    /// reproduce their historical fill order here.
    fn seed(&mut self, topo: &Topology, place: &mut dyn FnMut(usize, usize, BlockRef));

    /// A residency slot on `sm` is free at `now`: return the next block
    /// for it, or `None` to leave the slot idle. `retired` names the block
    /// that just finished (`None` when the slot wakes on a kernel
    /// arrival rather than a retirement).
    fn refill(&mut self, sm: Sm, retired: Option<BlockRef>, now: f64) -> Option<BlockRef>;

    /// Earliest time strictly after `now` at which new work may arrive
    /// (staggered kernel launches, open-loop request streams). Idle slots
    /// re-arm on this; `None` (the default) means work never appears
    /// except at refill time. The engine re-polls after every retirement
    /// and supersedes a pending arrival event with an earlier-announced
    /// one, so a source may also report a synthetic just-after-now *wake*
    /// here when a completion readied work that idle slots should sweep
    /// (the service-mode stream does).
    fn next_arrival_after(&self, _now: f64) -> Option<f64> {
        None
    }

    /// An arrival event the source announced (via
    /// [`next_arrival_after`](Self::next_arrival_after)) is firing at
    /// `now`, before any slot is refilled. Sources that *generate* work
    /// over time (the service-mode request stream) admit everything due
    /// by `now` here, so `next_arrival_after` can keep its strictly-future
    /// contract even when every slot was busy at the promised time.
    /// Default: no-op (fixed mixes know their arrivals up front).
    fn on_arrival(&mut self, _now: f64) {}
}

/// A host-processor request stream co-running with the NDP kernels
/// (CHoNDA-style concurrent host + NDP execution).
///
/// The host sweeps `trace`'s objects line by line (the data a host-side
/// application streams through), `cfg.host_passes` times over, issuing
/// `cfg.host_mlp` requests per window: all requests of a window launch at
/// the same instant and the next window launches when the slowest
/// completes — the legacy `run_host_sweep` window semantics, now executed
/// inside the shared event heap so host and NDP traffic contend for host
/// ports, interconnect slots and per-stack DRAM dispatch. A per-line
/// deterministic hash diverts `cfg.host_ddr_fraction` of the lines to
/// host-local DDR (see [`crate::mem::make_host_ddr`]), which never
/// touches the stacks.
#[derive(Clone, Copy, Debug)]
pub struct HostStream<'a> {
    /// The host application's access footprint (objects are swept whole;
    /// block structure is ignored — the host is not a GPU).
    pub trace: &'a KernelTrace,
    /// Base virtual address of each object (by object index).
    pub obj_base: &'a [VirtualAddress],
}

/// Knobs distinguishing the historical callers. Both default to the
/// single-kernel (`sim.rs`) behaviour.
#[derive(Clone, Copy, Debug)]
pub struct EngineOptions {
    /// Apply the deterministic stack-level L2 filter (`sim.rs` semantics).
    /// The multiprogrammed path has never modelled the L2; flipping this
    /// on there would change its golden numbers.
    pub l2_filter: bool,
    /// Migrate FGP pages to the first-touching stack (migration-FTA).
    pub migrate_on_first_touch: bool,
}

impl Default for EngineOptions {
    fn default() -> Self {
        Self {
            l2_filter: true,
            migrate_on_first_touch: false,
        }
    }
}

/// Raw counters out of one engine run, before report shaping.
#[derive(Clone, Debug, Default)]
pub struct EngineRaw {
    pub stats: AccessStats,
    /// Completion time of the whole run (max over all events).
    pub end_time: f64,
    /// Completion time of each app's last event (0.0 if it never ran).
    pub app_end: Vec<f64>,
    pub mean_mem_latency: f64,
    pub tlb_hit_rate: f64,
    pub row_hit_rate: f64,
    pub stack_bytes: Vec<u64>,
    pub remote_bytes: u64,
    pub mem: MemStats,
    pub migrated_pages: u64,
    /// Completion time of the host request stream (0.0 without one).
    pub host_end: f64,
    /// Bytes delivered over the per-stack host ports.
    pub host_bytes: u64,
    /// Bytes served by host-local DDR.
    pub host_ddr_bytes: u64,
    /// Host-port transfers that queued behind a busy port.
    pub host_port_stalls: u64,
    /// Per-directed-link fabric counters (empty under the degenerate
    /// fully-connected fabric, whose reports are frozen).
    pub link_stats: Vec<LinkStat>,
    /// Hierarchical translation results (`None` under the frozen legacy
    /// flat-walk model, whose reports are byte-identical by construction).
    pub xlate: Option<XlateStats>,
    /// Shards the run executed on (0 from this sequential engine; the
    /// sharded engine fills these — see `crate::shard`).
    pub shard_stacks: u64,
    /// Conservative time windows (barrier rounds) a sharded run took.
    pub shard_windows: u64,
    /// Cross-shard mailbox messages a sharded run exchanged.
    pub shard_msgs: u64,
}

impl EngineRaw {
    /// Shape the raw counters into a [`RunReport`]; callers fill in the
    /// mechanism name and placement page counts.
    pub fn to_report(&self, cfg: &SystemConfig, workload: String) -> RunReport {
        RunReport {
            workload,
            mechanism: String::new(),
            // Whole-run makespan: the later of the NDP and host sides.
            // Without host traffic `host_end` is 0.0 and `max` returns
            // `end_time` bit-exactly (event times are non-negative).
            cycles: self.end_time.max(self.host_end),
            accesses: self.stats,
            stack_bytes: self.stack_bytes.clone(),
            remote_bytes: self.remote_bytes,
            mean_mem_latency: self.mean_mem_latency,
            tlb_hit_rate: self.tlb_hit_rate,
            row_hit_rate: self.row_hit_rate,
            mem_backend: cfg.mem_backend.to_string(),
            bank_conflicts: self.mem.row_conflicts,
            refresh_stalls: self.mem.refresh_stalls,
            dram_row_hits: self.mem.row_hits,
            dram_row_misses: self.mem.row_misses,
            dram_acts: self.mem.acts,
            dram_precharges: self.mem.precharges,
            dram_wq_stalls: self.mem.wq_stalls,
            dram_faw_stalls: self.mem.faw_stalls,
            cgp_pages: 0,
            fgp_pages: 0,
            migrated_pages: self.migrated_pages,
            app_cycles: Vec::new(),
            app_slowdown: Vec::new(),
            weighted_speedup: 0.0,
            host_cycles: self.host_end,
            host_slowdown: 0.0,
            ndp_slowdown: 0.0,
            host_bytes: self.host_bytes,
            host_ddr_bytes: self.host_ddr_bytes,
            host_port_stalls: self.host_port_stalls,
            host_bw_share: {
                let total: u64 = self.stack_bytes.iter().sum();
                if total == 0 {
                    0.0
                } else {
                    self.host_bytes as f64 / total as f64
                }
            },
            // Only multi-hop fabrics report link stats; their presence
            // is what keys the topology metadata (and the conditional
            // JSON emission) so degenerate reports stay byte-identical.
            topology: if self.link_stats.is_empty() {
                String::new()
            } else {
                cfg.topology.to_string()
            },
            net_window_cycles: if self.link_stats.is_empty() {
                0.0
            } else {
                cfg.net_window_cycles
            },
            link_stats: self.link_stats.clone(),
            service: None,
            xlate: self.xlate.clone(),
            shard_stacks: self.shard_stacks,
            shard_windows: self.shard_windows,
            shard_msgs: self.shard_msgs,
        }
    }
}

/// A heap event, packed into two words so one heap entry — `(TimeKey,
/// Ev)` — is exactly 32 bytes (two entries per cache line; the naive
/// five-field enum cost 40). The heap is the engine's hottest data
/// structure: every sift touches several entries, so entry size is paid
/// on every simulated window. Ordering beyond the `TimeKey` is never
/// consulted (the sequence number is unique) but the derive keeps the
/// heap total-ordered.
///
/// Encoding: word 0 is `app << 32 | block` for a block window, or one of
/// two tag values (`u64::MAX` = arrival, `u64::MAX - 1` = host window)
/// that a real `app` index — bounded by the apps vector — can never
/// produce. Word 1 carries `next << 32 | sm << 16 | slot` for windows
/// and the global line index for host windows. [`Engine::run`] asserts
/// the sm/slot fields fit their 16 bits up front.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
struct Ev(u64, u64);

/// Unpacked view of an [`Ev`] (what the old enum spelled directly).
enum EvKind {
    /// A resident block issues its next window of accesses.
    Window {
        app: u32,
        block: u32,
        next: u32,
        sm: u32,
        slot: u32,
    },
    /// A kernel arrival: sweep all idle residency slots for new work, in
    /// the same slot-major order as the t=0 seeding (so a late kernel's
    /// block→SM assignment matches the one it would get running alone).
    Arrival,
    /// The host stream issues its next window of `host_mlp` requests
    /// (`next` = global line index of the window's first request).
    HostWindow { next: u64 },
}

impl Ev {
    const ARRIVAL_TAG: u64 = u64::MAX;
    const HOST_TAG: u64 = u64::MAX - 1;

    const ARRIVAL: Ev = Ev(Self::ARRIVAL_TAG, 0);

    #[inline]
    fn window(app: u32, block: u32, next: u32, sm: u32, slot: u32) -> Ev {
        debug_assert!(sm < 1 << 16 && slot < 1 << 16, "sm/slot exceed 16 bits");
        debug_assert!(app < u32::MAX, "app index collides with the tag space");
        Ev(
            ((app as u64) << 32) | block as u64,
            ((next as u64) << 32) | ((sm as u64) << 16) | slot as u64,
        )
    }

    #[inline]
    fn host(next: u64) -> Ev {
        Ev(Self::HOST_TAG, next)
    }

    #[inline]
    fn kind(self) -> EvKind {
        match self.0 {
            Self::ARRIVAL_TAG => EvKind::Arrival,
            Self::HOST_TAG => EvKind::HostWindow { next: self.1 },
            w0 => EvKind::Window {
                app: (w0 >> 32) as u32,
                block: w0 as u32,
                next: (self.1 >> 32) as u32,
                sm: ((self.1 >> 16) & 0xFFFF) as u32,
                slot: (self.1 & 0xFFFF) as u32,
            },
        }
    }
}

/// The shared simulation core: one event heap over all SM residency
/// slots, routing every access through TLB → address map → local
/// crossbar / remote ports → the owning stack's DRAM backend.
pub struct Engine<'a> {
    pub cfg: &'a SystemConfig,
    pub apps: Vec<AppCtx<'a>>,
    pub vm: &'a mut VirtualMemory,
    pub opts: EngineOptions,
    /// Concurrent host request stream, if any (`None` = NDP only).
    pub host: Option<HostStream<'a>>,
}

/// Salt decorrelating the host-DDR line hash from the L2-filter hash
/// (both use [`line_hash`] on the line address). Public so the sharded
/// engine routes the exact same lines to host DDR.
pub const HOST_DDR_SALT: u64 = 0x5A17_C0DA_DD2A_2026;

impl<'a> Engine<'a> {
    /// Run to completion, pulling blocks from `source`.
    ///
    /// Generic over the source so concrete callers monomorphize the
    /// refill/arrival calls away; `&mut dyn BlockSource` still works
    /// (`?Sized`) for callers that only have a trait object.
    pub fn run<S: BlockSource + ?Sized>(self, source: &mut S) -> EngineRaw {
        let Engine {
            cfg,
            apps,
            vm,
            opts,
            host,
        } = self;
        let topo = Topology::new(cfg);
        let mapper = AddressMapper::new(cfg);
        let mut net = Interconnect::new(cfg);
        // DRAM timing is pluggable (fixed-latency vs bank-level); the
        // backend may only shape time, never which accesses occur. The
        // hot path holds the statically-dispatched form: per-access enum
        // dispatch instead of a vtable call (bit-identical timing — see
        // `mem::MemBackendImpl`).
        let mut stacks: Vec<MemBackendImpl> = mem::make_backends_impl(cfg);

        let cyc = cfg.cycles_per_ns();
        // Address translation lives behind one seam: the frozen legacy
        // flat-walk model by default, the hierarchical L1/L2/PTW pipeline
        // when `tlb_l1_entries > 0` (see `xlate.rs`).
        let mut xl = TranslationUnit::new(cfg, topo.sms.len(), cyc);
        // Promoted 2 MB frames route through the huge-frame mapper: one
        // frame lives whole on one stack (the allocator steered it), so
        // per-base-page CGP folding would misplace its pages.
        let huge_mapper = large_page_mapper(cfg);
        let flush_on_switch = cfg.tlb_flush_on_switch;
        let mut last_app: Vec<u32> = vec![u32::MAX; topo.sms.len()];
        let l2_threshold = (cfg.l2_hit_rate * u32::MAX as f64) as u64;
        let l2_hit_cycles = cfg.l2_hit_ns * cyc;
        let line = cfg.line_size;
        let page_shift = cfg.page_size.trailing_zeros();
        let mlp = cfg.mlp_per_block;
        let compute = cfg.compute_cycles_per_access as f64;

        // Host stream: precompute the per-object starting line (global
        // line index space, one pass), the lines per pass, and the total
        // line count across all passes. `None` disables host traffic
        // entirely — zero-intensity runs take the exact pre-host code
        // path, so NDP results stay bit-identical.
        let host = host.and_then(|h| {
            if cfg.host_mlp == 0 || cfg.host_passes == 0 {
                return None;
            }
            let mut starts = Vec::with_capacity(h.trace.objects.len());
            let mut acc = 0u64;
            for o in &h.trace.objects {
                starts.push(acc);
                acc += o.bytes.div_ceil(line);
            }
            let total = acc.saturating_mul(cfg.host_passes);
            if total == 0 {
                None
            } else {
                Some((h, starts, acc, total))
            }
        });
        // Scaled by 2^32 (not u32::MAX) so a fraction of exactly 1.0
        // admits every masked hash value.
        let host_ddr_threshold = (cfg.host_ddr_fraction * (1u64 << 32) as f64) as u64;
        let mut host_ddr: Option<MemBackendImpl> = if host.is_some() && host_ddr_threshold > 0 {
            Some(mem::make_host_ddr_impl(cfg))
        } else {
            None
        };
        let mut host_end = 0.0f64;
        // Incremental object cursor for the host stream: global line
        // indices arrive strictly sequentially (windows chain
        // contiguously and the within-pass index wraps to 0 at each pass
        // boundary), so the owning object only ever advances — an O(1)
        // cursor replaces the per-request binary search and lands on the
        // same object `partition_point` did.
        let mut host_obj: usize = 0;

        let mut stats = AccessStats::default();
        let mut migrated: u64 = 0;
        let mut migrated_pages: Vec<bool> = if opts.migrate_on_first_touch {
            vec![false; vm.mapped_pages() as usize]
        } else {
            Vec::new()
        };
        let mut latency_sum = 0.0f64;
        let mut latency_n: u64 = 0;
        let mut end_time = 0.0f64;
        let mut app_end = vec![0.0f64; apps.len()];
        let mut seq: u64 = 0;

        let slots_per_sm = cfg.blocks_per_sm;
        // The packed `Ev` carries sm/slot in 16 bits each; reject (once,
        // up front) the configurations that could silently truncate.
        assert!(
            topo.sms.len() < 1 << 16 && slots_per_sm < 1 << 16,
            "topology exceeds the packed event encoding (sm/slot must fit 16 bits)"
        );
        // At most one *live* event is outstanding per residency slot,
        // plus one arrival and one host window — but that is a hint, not
        // a bound: every service-mode completion wake that re-arms an
        // earlier arrival strands the superseded event in the heap until
        // its stale time pops (see the retirement re-arm below), and
        // nothing caps how many retirements can strand one each before
        // the first stale time passes. The doubled pre-size absorbs the
        // common case; `BinaryHeap` grows past it when a wake storm
        // strands more (`tests::heap_survives_arrival_supersede_storm`
        // pins that nothing is lost when it does).
        let mut heap: BinaryHeap<Reverse<(TimeKey, Ev)>> =
            BinaryHeap::with_capacity(topo.sms.len() * slots_per_sm * 2 + 2);
        let mut occupied = vec![false; topo.sms.len() * slots_per_sm];
        // Per-SM issue-bandwidth server: resident blocks share the SM's
        // execution resources, so their compute phases serialize.
        let mut sm_free: Vec<f64> = vec![0.0; topo.sms.len()];

        // Initial fill, in the source's dispatch order.
        source.seed(&topo, &mut |sm, slot, br| {
            debug_assert!(slot < slots_per_sm, "slot {slot} out of range");
            debug_assert!(!occupied[sm * slots_per_sm + slot], "slot seeded twice");
            occupied[sm * slots_per_sm + slot] = true;
            heap.push(Reverse((
                key(0.0, seq),
                Ev::window(br.app, br.block, 0, sm as u32, slot as u32),
            )));
            seq += 1;
        });
        // At most one arrival event is outstanding; `armed` holds its time.
        let mut armed: Option<f64> = None;
        if let Some(ta) = source.next_arrival_after(0.0) {
            if ta > 0.0 {
                heap.push(Reverse((key(ta, seq), Ev::ARRIVAL)));
                seq += 1;
                armed = Some(ta);
            }
        }
        // The host stream starts streaming at t=0, after the NDP seeds
        // (host windows are self-perpetuating: each schedules the next).
        if host.is_some() {
            heap.push(Reverse((key(0.0, seq), Ev::host(0))));
            seq += 1;
        }

        while let Some(Reverse((tk, ev))) = heap.pop() {
            let now = f64::from_bits(tk.0);
            let (app, block, next, sm, slot) = match ev.kind() {
                EvKind::Arrival => {
                    // An event superseded by an earlier re-arm (a service-
                    // mode completion wake) is inert: the authoritative
                    // chain re-armed past it, so firing it again would
                    // duplicate sweeps. `armed` always holds the exact
                    // bits of the live event's time, so equality is safe.
                    if armed != Some(now) {
                        continue;
                    }
                    armed = None;
                    source.on_arrival(now);
                    // Fill idle slots in the seeding order (slot-major).
                    for slot in 0..slots_per_sm {
                        for smo in &topo.sms {
                            if occupied[smo.id * slots_per_sm + slot] {
                                continue;
                            }
                            if let Some(br) = source.refill(*smo, None, now) {
                                occupied[smo.id * slots_per_sm + slot] = true;
                                heap.push(Reverse((
                                    key(now, seq),
                                    Ev::window(br.app, br.block, 0, smo.id as u32, slot as u32),
                                )));
                                seq += 1;
                            }
                        }
                    }
                    if let Some(ta) = source.next_arrival_after(now) {
                        if ta > now {
                            heap.push(Reverse((key(ta, seq), Ev::ARRIVAL)));
                            seq += 1;
                            armed = Some(ta);
                        }
                    }
                    continue;
                }
                EvKind::HostWindow { next } => {
                    let (hs, starts, per_pass, total) =
                        host.as_ref().expect("host event without a host stream");
                    // One window: up to `host_mlp` requests all issued at
                    // `now`; the stream stalls until the slowest drains
                    // (the legacy `run_host_sweep` window semantics).
                    let end_i = (next + cfg.host_mlp as u64).min(*total);
                    let mut window_done = 0.0f64;
                    for i in next..end_i {
                        let j = i % per_pass;
                        // Advance the cursor to the last object whose
                        // start line is <= j (what `partition_point` on
                        // `starts` computed, without the binary search);
                        // a new pass rewinds it to object 0.
                        if j == 0 {
                            host_obj = 0;
                        }
                        while host_obj + 1 < starts.len() && starts[host_obj + 1] <= j {
                            host_obj += 1;
                        }
                        let va = hs.obj_base[host_obj] + (j - starts[host_obj]) * line;
                        let done = if host_ddr_threshold > 0
                            && line_hash((va.0 / line) ^ HOST_DDR_SALT) & 0xFFFF_FFFF
                                < host_ddr_threshold
                        {
                            // Host-private line: served by host-local DDR,
                            // never touching the stacks.
                            stats.host_ddr += 1;
                            host_ddr
                                .as_mut()
                                .expect("host DDR backend")
                                .access(now, va.0, line)
                                .done
                        } else {
                            // The host's own MMU is not modelled (its
                            // translations are not the NDP SMs' problem),
                            // but its physical routing honors promoted
                            // huge frames like every other access.
                            let pte = vm
                                .pte_of(va)
                                .expect("host access beyond mapped object");
                            let paddr =
                                (pte.ppn << page_shift) | (va.0 & (cfg.page_size - 1));
                            let m = if pte.huge { &huge_mapper } else { &mapper };
                            let dst = m.stack_of(paddr, pte.granularity);
                            stats.host += 1;
                            let t1 = net.host_hop(now, dst, line);
                            stacks[dst].access(t1, paddr, line).done
                        };
                        window_done = window_done.max(done);
                        host_end = host_end.max(done);
                    }
                    if end_i < *total {
                        heap.push(Reverse((key(window_done.max(now), seq), Ev::host(end_i))));
                        seq += 1;
                    }
                    continue;
                }
                EvKind::Window {
                    app,
                    block,
                    next,
                    sm,
                    slot,
                } => (app, block, next, sm, slot),
            };

            let actx = &apps[app as usize];
            let smo = topo.sms[sm as usize];
            // A time-shared SM switching address spaces drops its
            // translations (opt-in; the frozen default shares them).
            if flush_on_switch && last_app[smo.id] != app {
                if last_app[smo.id] != u32::MAX {
                    xl.flush(smo.id);
                }
                last_app[smo.id] = app;
            }
            let blk = &actx.trace.blocks[block as usize];
            let begin = next as usize;
            let end = (begin + mlp).min(blk.accesses.len());
            // Loads invariant across the window, hoisted out of the
            // per-access loop (the optimizer cannot always prove the
            // indexed re-loads loop-invariant on its own).
            let obj_base = actx.obj_base;

            // Issue one window of accesses; the block stalls until the
            // slowest completes, then pays its compute debt.
            let mut window_done = now;
            for a in &blk.accesses[begin..end] {
                let va = obj_base[a.obj as usize] + a.offset;
                let vaddr = va.0;
                // Stack-level L2 filter (deterministic per line).
                if opts.l2_filter {
                    let vline = vaddr / line;
                    if line_hash(vline) & 0xFFFF_FFFF < l2_threshold {
                        stats.l2_hits += 1;
                        window_done = window_done.max(now + l2_hit_cycles);
                        continue;
                    }
                }
                // TLB + translation (legacy flat walk or the hierarchical
                // L1/L2/PTW pipeline — see `xlate.rs`).
                let vpn = vaddr >> page_shift;
                let (mut t, pte) = xl.access(smo.id, now, va, vm);
                let mut paddr = (pte.ppn << page_shift) | (vaddr & (cfg.page_size - 1));
                let mut gran = pte.granularity;
                let mut huge = pte.huge;
                // Migration-based first touch: the first NDP access to an
                // FGP page pulls the whole page into the toucher's stack.
                if opts.migrate_on_first_touch
                    && gran == Granularity::Fgp
                    && !migrated_pages[vpn as usize]
                {
                    migrated_pages[vpn as usize] = true;
                    if vm.migrate_to_cgp(va, smo.stack).is_ok() {
                        migrated += 1;
                        // Page copy: page_size bytes arrive over the remote
                        // ingress port (3/4 of the stripes are remote).
                        let copy_bytes =
                            cfg.page_size * (cfg.num_stacks as u64 - 1) / cfg.num_stacks as u64;
                        t = net.remote_hop(
                            t,
                            (smo.stack + 1) % cfg.num_stacks,
                            smo.stack,
                            copy_bytes,
                        );
                        let pte = vm.pte_of(va).unwrap();
                        xl.install(smo.id, va, pte);
                        paddr = (pte.ppn << page_shift) | (vaddr & (cfg.page_size - 1));
                        gran = pte.granularity;
                        huge = pte.huge;
                    }
                }
                // Promoted frames live whole on one stack: route them by
                // the huge-frame geometry, everything else as before.
                let m = if huge { &huge_mapper } else { &mapper };
                let dst = m.stack_of(paddr, gran);
                // The direction flag only matters to the cycle-accurate
                // backend's posted-write path; the other backends ignore
                // it, keeping their completion times bit-identical.
                let done = if dst == smo.stack {
                    stats.local += 1;
                    let t1 = net.local_hop(t, dst, line);
                    stacks[dst].access_rw(t1, paddr, line, a.write).done
                } else {
                    stats.remote += 1;
                    // Request out, serve at the owner, response back.
                    let t1 = net.remote_hop(t, smo.stack, dst, line);
                    let t2 = stacks[dst].access_rw(t1, paddr, line, a.write).done;
                    net.remote_hop(t2, dst, smo.stack, line)
                };
                latency_sum += done - now;
                latency_n += 1;
                window_done = window_done.max(done);
            }
            let issued = (end - begin) as f64;
            // Compute occupies the SM serially across its resident blocks.
            let c_start = window_done.max(sm_free[smo.id]);
            let t_next = c_start + compute * issued;
            sm_free[smo.id] = t_next;
            end_time = end_time.max(t_next);
            app_end[app as usize] = app_end[app as usize].max(t_next);

            if end < blk.accesses.len() {
                heap.push(Reverse((
                    key(t_next, seq),
                    Ev::window(app, block, end as u32, sm, slot),
                )));
                seq += 1;
            } else {
                // Block retires; ask the source for this slot's next block.
                match source.refill(smo, Some(BlockRef { app, block }), t_next) {
                    Some(br) => {
                        heap.push(Reverse((
                            key(t_next, seq),
                            Ev::window(br.app, br.block, 0, sm, slot),
                        )));
                        seq += 1;
                    }
                    None => {
                        occupied[sm as usize * slots_per_sm + slot as usize] = false;
                    }
                }
                // (Re-)arm the arrival event when none is pending, or when
                // the source now announces an *earlier* time than the armed
                // one — that is how a completion wake (service mode readying
                // a multi-block stage) sweeps idle slots instead of sleeping
                // behind a far-future generator arrival. Fixed mixes announce
                // static times that never move earlier, so for them the
                // supersede branch never fires and the event sequence is
                // unchanged. A superseded event stays in the heap; the
                // arrival handler drops it by its stale timestamp.
                if let Some(ta) = source.next_arrival_after(t_next) {
                    if ta > t_next && armed.map_or(true, |t| ta < t) {
                        heap.push(Reverse((key(ta, seq), Ev::ARRIVAL)));
                        seq += 1;
                        armed = Some(ta);
                    }
                }
            }
        }

        let (tlb_hits, tlb_total) = xl.hit_totals();
        let row_hit_rate = {
            let rates: Vec<f64> = stacks.iter().map(|s| s.row_hit_rate()).collect();
            crate::stats::mean(&rates)
        };
        let mut mem_stats = MemStats::default();
        for s in &stacks {
            mem_stats.add(&s.stats());
        }
        EngineRaw {
            stats,
            end_time,
            app_end,
            mean_mem_latency: if latency_n == 0 {
                0.0
            } else {
                latency_sum / latency_n as f64
            },
            tlb_hit_rate: if tlb_total == 0 {
                0.0
            } else {
                tlb_hits as f64 / tlb_total as f64
            },
            row_hit_rate,
            stack_bytes: stacks.iter().map(|s| s.bytes_served()).collect(),
            remote_bytes: net.remote_bytes(),
            mem: mem_stats,
            migrated_pages: migrated,
            host_end,
            host_bytes: net.host_bytes(),
            host_ddr_bytes: host_ddr.as_ref().map(|d| d.bytes_served()).unwrap_or(0),
            host_port_stalls: net.host_port_stalls(),
            link_stats: net.link_stats(),
            xlate: xl.stats(vm, end_time.max(host_end), topo.sms.len()),
            shard_stacks: 0,
            shard_windows: 0,
            shard_msgs: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn key_orders_by_time_then_seq() {
        assert!(key(1.0, 5) < key(2.0, 0));
        assert!(key(1.0, 0) < key(1.0, 1));
        assert!(key(0.0, 0) < key(f64::MIN_POSITIVE, 0));
        // Bit-monotonic over representative magnitudes.
        let times = [0.0, 1e-9, 0.5, 1.0, 1e6, 1e15, f64::MAX];
        for w in times.windows(2) {
            assert!(key(w[0], 0) < key(w[1], 0), "{} !< {}", w[0], w[1]);
        }
    }

    #[test]
    #[should_panic(expected = "non-negative real")]
    fn key_rejects_negative_time_in_all_profiles() {
        // A plain `debug_assert!` would let this through in release
        // builds, where f64 bit-ordering silently inverts for negatives.
        key(-1.0, 0);
    }

    #[test]
    #[should_panic(expected = "non-negative real")]
    fn key_rejects_nan_time_in_all_profiles() {
        key(f64::NAN, 0);
    }

    #[test]
    fn line_hash_is_deterministic_and_spread() {
        assert_eq!(line_hash(42), line_hash(42));
        // Crude avalanche check: neighbours land far apart.
        assert_ne!(line_hash(1) >> 32, line_hash(2) >> 32);
    }

    #[test]
    fn packed_event_round_trips_and_stays_small() {
        // The whole point of the packing: a heap entry is 32 bytes.
        assert_eq!(std::mem::size_of::<Ev>(), 16);
        assert_eq!(std::mem::size_of::<(TimeKey, Ev)>(), 32);
        for (app, block, next, sm, slot) in [
            (0u32, 0u32, 0u32, 0u32, 0u32),
            (3, 12345, 67890, 15, 5),
            (41, u32::MAX, u32::MAX, (1 << 16) - 1, (1 << 16) - 1),
        ] {
            match Ev::window(app, block, next, sm, slot).kind() {
                EvKind::Window {
                    app: a,
                    block: b,
                    next: n,
                    sm: s,
                    slot: l,
                } => {
                    assert_eq!((a, b, n, s, l), (app, block, next, sm, slot));
                }
                _ => panic!("window decoded as a tag event"),
            }
        }
        assert!(matches!(Ev::ARRIVAL.kind(), EvKind::Arrival));
        match Ev::host(u64::MAX / 3).kind() {
            EvKind::HostWindow { next } => assert_eq!(next, u64::MAX / 3),
            _ => panic!("host window decoded wrong"),
        }
    }

    /// A service-style source that re-arms an *earlier* far-future
    /// arrival after every retirement: each re-arm strands the superseded
    /// arrival event, so the stranded count grows with retirements — far
    /// past any slot-derived heap pre-size.
    struct WakeStorm {
        blocks: u32,
        next: u32,
    }

    impl BlockSource for WakeStorm {
        fn seed(&mut self, _topo: &Topology, place: &mut dyn FnMut(usize, usize, BlockRef)) {
            place(0, 0, BlockRef { app: 0, block: 0 });
            self.next = 1;
        }

        fn refill(&mut self, sm: Sm, _retired: Option<BlockRef>, _now: f64) -> Option<BlockRef> {
            if sm.id == 0 && self.next < self.blocks {
                let b = self.next;
                self.next += 1;
                Some(BlockRef { app: 0, block: b })
            } else {
                None
            }
        }

        fn next_arrival_after(&self, _now: f64) -> Option<f64> {
            // Strictly decreasing announcements: every retirement's
            // re-poll supersedes the armed arrival.
            Some(1e12 - self.next as f64)
        }
    }

    /// The heap pre-size is a fast-path hint, not a bound (see the
    /// capacity comment in [`Engine::run`]): strand more superseded
    /// arrivals than any slot-derived capacity and the heap must grow
    /// without losing a single event — every block still runs exactly
    /// once and the stale arrivals fire as inert no-ops.
    #[test]
    fn heap_survives_arrival_supersede_storm() {
        use crate::trace::{Access, BlockTrace, KernelTrace, ObjectDesc};

        let cfg = SystemConfig::default();
        let slots = Topology::new(&cfg).sms.len() * cfg.blocks_per_sm;
        let blocks = (2 * slots + 64) as u32;
        let trace = KernelTrace {
            name: "storm".into(),
            threads_per_block: 1,
            objects: vec![ObjectDesc {
                name: "o".into(),
                bytes: cfg.page_size,
            }],
            blocks: (0..blocks)
                .map(|i| BlockTrace {
                    block_id: i,
                    accesses: vec![Access {
                        obj: 0,
                        offset: 0,
                        write: false,
                    }],
                })
                .collect(),
        };
        let mut vm = VirtualMemory::new(&cfg);
        let base = vm.map_fgp(1).unwrap();
        let bases = [base];
        let mut source = WakeStorm { blocks, next: 0 };
        let raw = Engine {
            cfg: &cfg,
            apps: vec![AppCtx {
                trace: &trace,
                obj_base: &bases,
            }],
            vm: &mut vm,
            opts: EngineOptions {
                l2_filter: false,
                migrate_on_first_touch: false,
            },
            host: None,
        }
        .run(&mut source);
        assert_eq!(source.next, blocks, "every block must be dispatched");
        assert_eq!(
            raw.stats.local + raw.stats.remote,
            blocks as u64,
            "one access per block, none lost to stale arrival events"
        );
        assert!(raw.end_time > 0.0);
    }
}
