//! GPU execution-model types: kernels, thread-blocks, SM topology and
//! occupancy (§2.1–2.2).
//!
//! The programming model is the standard GPU one: the host launches a
//! kernel; the runtime distributes its thread-blocks over all SMs in the
//! system (here, the SMs on the logic layers of the memory stacks). Up to
//! `blocks_per_sm` thread-blocks are resident per SM.

use crate::config::SystemConfig;

/// A kernel launch descriptor (grid is flattened row-major as in Eq 1:
/// `blockIdx.y * gridDim.x + blockIdx.x`).
#[derive(Clone, Debug)]
pub struct KernelDesc {
    pub name: String,
    /// Total thread-blocks in the launch (flattened grid).
    pub num_blocks: u32,
    /// Threads per thread-block.
    pub threads_per_block: u32,
}

impl KernelDesc {
    pub fn new(name: impl Into<String>, num_blocks: u32, threads_per_block: u32) -> Self {
        Self {
            name: name.into(),
            num_blocks,
            threads_per_block,
        }
    }

    /// Flatten a 2-D block index row-major.
    pub fn flatten(block_x: u32, block_y: u32, grid_x: u32) -> u32 {
        block_y * grid_x + block_x
    }
}

/// A streaming multiprocessor on some stack's logic layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Sm {
    /// Global SM id, `0..total_sms`.
    pub id: usize,
    /// The memory stack whose logic layer hosts this SM.
    pub stack: usize,
}

/// The NDP compute topology: which SM lives on which stack.
#[derive(Clone, Debug)]
pub struct Topology {
    pub sms: Vec<Sm>,
    pub num_stacks: usize,
    pub sms_per_stack: usize,
    pub blocks_per_sm: usize,
}

impl Topology {
    pub fn new(cfg: &SystemConfig) -> Self {
        let sms = (0..cfg.total_sms())
            .map(|id| Sm {
                id,
                stack: id / cfg.sms_per_stack,
            })
            .collect();
        Self {
            sms,
            num_stacks: cfg.num_stacks,
            sms_per_stack: cfg.sms_per_stack,
            blocks_per_sm: cfg.blocks_per_sm,
        }
    }

    /// SMs resident on one stack.
    pub fn sms_of_stack(&self, stack: usize) -> impl Iterator<Item = &Sm> {
        self.sms.iter().filter(move |sm| sm.stack == stack)
    }

    /// `N_blocks_per_stack` (Eq 1 denominator).
    pub fn blocks_per_stack(&self) -> usize {
        self.sms_per_stack * self.blocks_per_sm
    }

    /// Maximum concurrently-resident thread-blocks in the whole system.
    pub fn system_capacity(&self) -> usize {
        self.sms.len() * self.blocks_per_sm
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn topology_matches_table1() {
        let t = Topology::new(&SystemConfig::default());
        assert_eq!(t.sms.len(), 16);
        assert_eq!(t.sms_of_stack(2).count(), 4);
        assert_eq!(t.sms[5].stack, 1);
        assert_eq!(t.blocks_per_stack(), 24);
        assert_eq!(t.system_capacity(), 96);
    }

    #[test]
    fn flatten_row_major() {
        assert_eq!(KernelDesc::flatten(3, 2, 10), 23);
    }
}
