//! Micro-benchmark harness (criterion is not vendored in this environment;
//! this module reproduces its core methodology: warmup, repeated timed
//! iterations, mean/stddev/throughput reporting, and a `black_box` to
//! defeat dead-code elimination).

use std::hint::black_box as std_black_box;
use std::time::Instant;

/// Re-export of the std black box for bench bodies.
pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

/// Result of one measured benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub stddev_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl BenchResult {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.mean_ns * 1e-9)
    }

    pub fn report(&self) -> String {
        format!(
            "{:<40} {:>12.1} ns/iter (+/- {:>8.1})  [{} iters]",
            self.name, self.mean_ns, self.stddev_ns, self.iters
        )
    }
}

/// A criterion-style bench runner.
pub struct Bencher {
    warmup_iters: u64,
    measure_iters: u64,
    pub results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    pub fn new() -> Self {
        // Env overrides let CI shrink the run.
        let warmup = std::env::var("CODA_BENCH_WARMUP")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(3);
        let iters = std::env::var("CODA_BENCH_ITERS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(10);
        Self {
            warmup_iters: warmup,
            measure_iters: iters,
            results: Vec::new(),
        }
    }

    pub fn with_iters(mut self, warmup: u64, measure: u64) -> Self {
        self.warmup_iters = warmup;
        self.measure_iters = measure;
        self
    }

    /// Time `f` and record the result under `name`.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        for _ in 0..self.warmup_iters {
            black_box(f());
        }
        let mut samples = Vec::with_capacity(self.measure_iters as usize);
        for _ in 0..self.measure_iters {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed().as_nanos() as f64);
        }
        let mean = crate::stats::mean(&samples);
        let sd = crate::stats::stddev(&samples);
        let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = samples.iter().cloned().fold(0.0, f64::max);
        let r = BenchResult {
            name: name.to_string(),
            iters: self.measure_iters,
            mean_ns: mean,
            stddev_ns: sd,
            min_ns: min,
            max_ns: max,
        };
        println!("{}", r.report());
        self.results.push(r);
        self.results.last().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut b = Bencher::new().with_iters(1, 3);
        let r = b.bench("spin", || {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(black_box(i));
            }
            acc
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.min_ns <= r.mean_ns && r.mean_ns <= r.max_ns);
        assert_eq!(b.results.len(), 1);
    }

    #[test]
    fn throughput_math() {
        let r = BenchResult {
            name: "t".into(),
            iters: 1,
            mean_ns: 1e9, // 1 second
            stddev_ns: 0.0,
            min_ns: 1e9,
            max_ns: 1e9,
        };
        assert!((r.throughput(100.0) - 100.0).abs() < 1e-9);
    }
}
