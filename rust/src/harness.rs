//! Micro-benchmark harness (criterion is not vendored in this environment;
//! this module reproduces its core methodology: warmup, repeated timed
//! iterations, mean/stddev/throughput reporting, and a `black_box` to
//! defeat dead-code elimination).
//!
//! Besides the human-readable table, a [`Bencher`] renders every recorded
//! result as machine-readable JSON (`BENCH_*.json`, the repo's perf
//! trajectory): per-bench mean/min/max ns plus ops/s for benches that
//! declared a work-item count via [`Bencher::bench_n`]. Each PR that
//! touches a hot path records the before/after numbers this emits, so
//! simulator throughput (simulated accesses per second) is tracked over
//! time instead of anecdotally.

use crate::report::Json;
use std::hint::black_box as std_black_box;
use std::time::Instant;

/// Re-export of the std black box for bench bodies.
pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

/// Result of one measured benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub stddev_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
    /// Work items (simulated accesses, ops, …) one iteration performs;
    /// `0.0` when the bench declared none. Set by [`Bencher::bench_n`]
    /// so the JSON trajectory can report throughput.
    pub items_per_iter: f64,
}

impl BenchResult {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.mean_ns * 1e-9)
    }

    /// Items per second from the recorded `items_per_iter` (0.0 when the
    /// bench declared no item count).
    pub fn ops_per_sec(&self) -> f64 {
        if self.items_per_iter > 0.0 {
            self.throughput(self.items_per_iter)
        } else {
            0.0
        }
    }

    pub fn report(&self) -> String {
        format!(
            "{:<40} {:>12.1} ns/iter (+/- {:>8.1})  [{} iters]",
            self.name, self.mean_ns, self.stddev_ns, self.iters
        )
    }
}

/// A criterion-style bench runner.
pub struct Bencher {
    warmup_iters: u64,
    measure_iters: u64,
    pub results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    pub fn new() -> Self {
        // Env overrides let CI shrink the run.
        let warmup = std::env::var("CODA_BENCH_WARMUP")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(3);
        let iters = std::env::var("CODA_BENCH_ITERS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(10);
        Self {
            warmup_iters: warmup,
            measure_iters: iters,
            results: Vec::new(),
        }
    }

    pub fn with_iters(mut self, warmup: u64, measure: u64) -> Self {
        self.warmup_iters = warmup;
        self.measure_iters = measure;
        self
    }

    /// Time `f` and record the result under `name`.
    pub fn bench<T>(&mut self, name: &str, f: impl FnMut() -> T) -> &BenchResult {
        self.bench_n(name, 0.0, f)
    }

    /// Time `f`, recording that each iteration performs `items` work
    /// items (simulated accesses, scheduler picks, …) so the JSON
    /// trajectory carries an ops/s figure alongside the raw timings.
    pub fn bench_n<T>(
        &mut self,
        name: &str,
        items: f64,
        mut f: impl FnMut() -> T,
    ) -> &BenchResult {
        for _ in 0..self.warmup_iters {
            black_box(f());
        }
        let mut samples = Vec::with_capacity(self.measure_iters as usize);
        for _ in 0..self.measure_iters {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed().as_nanos() as f64);
        }
        let mean = crate::stats::mean(&samples);
        let sd = crate::stats::stddev(&samples);
        let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = samples.iter().cloned().fold(0.0, f64::max);
        let r = BenchResult {
            name: name.to_string(),
            iters: self.measure_iters,
            mean_ns: mean,
            stddev_ns: sd,
            min_ns: min,
            max_ns: max,
            items_per_iter: items,
        };
        println!("{}", r.report());
        self.results.push(r);
        self.results.last().unwrap()
    }

    /// Render every recorded result as the `BENCH_*.json` trajectory
    /// schema: `{schema, warmup_iters, measure_iters, results: [{name,
    /// iters, mean_ns, stddev_ns, min_ns, max_ns, items_per_iter?,
    /// ops_per_sec?}]}` (the two throughput fields appear only for
    /// benches recorded through [`Self::bench_n`]).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.push("schema", Json::Str("coda-bench-v1".into()))
            .push("warmup_iters", Json::Num(self.warmup_iters as f64))
            .push("measure_iters", Json::Num(self.measure_iters as f64))
            .push(
                "results",
                Json::Arr(
                    self.results
                        .iter()
                        .map(|r| {
                            let mut ro = Json::obj();
                            ro.push("name", Json::Str(r.name.clone()))
                                .push("iters", Json::Num(r.iters as f64))
                                .push("mean_ns", Json::Num(r.mean_ns))
                                .push("stddev_ns", Json::Num(r.stddev_ns))
                                .push("min_ns", Json::Num(r.min_ns))
                                .push("max_ns", Json::Num(r.max_ns));
                            if r.items_per_iter > 0.0 {
                                ro.push("items_per_iter", Json::Num(r.items_per_iter))
                                    .push("ops_per_sec", Json::Num(r.ops_per_sec()));
                            }
                            ro
                        })
                        .collect(),
                ),
            );
        o
    }

    /// Write the JSON trajectory to `default_path` (a `CODA_BENCH_JSON`
    /// env var overrides the destination); returns the path written.
    pub fn write_json(&self, default_path: &str) -> std::io::Result<String> {
        let path =
            std::env::var("CODA_BENCH_JSON").unwrap_or_else(|_| default_path.to_string());
        std::fs::write(&path, self.to_json().render() + "\n")?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut b = Bencher::new().with_iters(1, 3);
        let r = b.bench("spin", || {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(black_box(i));
            }
            acc
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.min_ns <= r.mean_ns && r.mean_ns <= r.max_ns);
        assert_eq!(r.items_per_iter, 0.0);
        assert_eq!(b.results.len(), 1);
    }

    #[test]
    fn throughput_math() {
        let r = BenchResult {
            name: "t".into(),
            iters: 1,
            mean_ns: 1e9, // 1 second
            stddev_ns: 0.0,
            min_ns: 1e9,
            max_ns: 1e9,
            items_per_iter: 50.0,
        };
        assert!((r.throughput(100.0) - 100.0).abs() < 1e-9);
        assert!((r.ops_per_sec() - 50.0).abs() < 1e-9);
    }

    #[test]
    fn json_trajectory_is_valid_and_carries_throughput() {
        let mut b = Bencher::new().with_iters(0, 2);
        b.bench("plain", || black_box(1 + 1));
        b.bench_n("with-items", 1000.0, || black_box(2 + 2));
        let s = b.to_json().render();
        crate::report::validate_json(&s).unwrap();
        assert!(s.contains("\"schema\":\"coda-bench-v1\""));
        assert!(s.contains("\"name\":\"plain\""));
        assert!(s.contains("\"name\":\"with-items\""));
        assert!(s.contains("\"items_per_iter\":1000"));
        assert!(s.contains("\"ops_per_sec\":"));
        // The plain bench declared no items, so no throughput fields.
        let plain_obj = s.split("\"name\":\"plain\"").nth(1).unwrap();
        let plain_obj = &plain_obj[..plain_obj.find('}').unwrap()];
        assert!(!plain_obj.contains("ops_per_sec"));
    }

    #[test]
    fn write_json_emits_a_parseable_file() {
        let mut b = Bencher::new().with_iters(0, 1);
        b.bench_n("w", 10.0, || black_box(0));
        if std::env::var("CODA_BENCH_JSON").is_ok() {
            // An ambient override would redirect the write onto the
            // user's real trajectory file (which we would then delete);
            // validate the rendering only.
            crate::report::validate_json(&b.to_json().render()).unwrap();
            return;
        }
        let path = std::env::temp_dir().join("coda_bench_harness_test.json");
        let written = b.write_json(path.to_str().unwrap()).unwrap();
        let text = std::fs::read_to_string(&written).unwrap();
        crate::report::validate_json(text.trim()).unwrap();
        std::fs::remove_file(&written).ok();
    }
}
