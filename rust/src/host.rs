//! Host-processor execution model (§6.6, Fig 13).
//!
//! When an application runs on the host, its memory requests travel over
//! the per-stack Host ports. Fine-grain interleaving spreads a sequential
//! stream's concurrent requests over all stacks (full aggregate host
//! bandwidth); coarse-grain interleaving serializes each page's worth of
//! requests onto a single stack's port — which is why the paper keeps FGP
//! as the default and localizes selectively.

use crate::addr::AddressMapper;
use crate::config::SystemConfig;
use crate::mem::{self, MemBackend, MemStats};
use crate::net::Interconnect;
use crate::stats::RunReport;
use crate::trace::KernelTrace;
use crate::vm::VirtualMemory;

/// Outstanding host requests (an aggressive OoO core + MLP prefetchers).
const HOST_MLP: usize = 64;

/// Run a host-side streaming sweep over every object of `trace` (the data
/// the kernel would consume), with the objects mapped by `vm`.
/// Returns a report whose `cycles` reflect host execution time.
pub fn run_host_sweep(
    cfg: &SystemConfig,
    trace: &KernelTrace,
    vm: &VirtualMemory,
    obj_base: &[u64],
) -> RunReport {
    let mapper = AddressMapper::new(cfg);
    let mut net = Interconnect::new(cfg);
    let mut stacks: Vec<Box<dyn MemBackend>> = mem::make_backends(cfg);
    let line = cfg.line_size;
    let mut host_accesses = 0u64;
    let mut window: Vec<f64> = Vec::with_capacity(HOST_MLP);
    let mut now = 0.0f64;
    let mut end = 0.0f64;
    for (obj, desc) in trace.objects.iter().enumerate() {
        let lines = desc.bytes.div_ceil(line);
        for l in 0..lines {
            let vaddr = obj_base[obj] + l * line;
            let (paddr, gran) = vm.translate(vaddr).expect("mapped");
            let stack = mapper.stack_of(paddr, gran);
            let t1 = net.host_hop(now, stack, line);
            let done = stacks[stack].access(t1, paddr, line).done;
            host_accesses += 1;
            window.push(done);
            end = end.max(done);
            if window.len() == HOST_MLP {
                // The core stalls until the oldest window drains.
                now = window.iter().cloned().fold(0.0, f64::max).max(now);
                window.clear();
            }
        }
    }
    let mut mem_stats = MemStats::default();
    for s in &stacks {
        mem_stats.add(&s.stats());
    }
    RunReport {
        workload: trace.name.clone(),
        mechanism: "host".into(),
        cycles: end,
        accesses: crate::stats::AccessStats {
            host: host_accesses,
            ..Default::default()
        },
        stack_bytes: stacks.iter().map(|s| s.bytes_served()).collect(),
        remote_bytes: 0,
        mean_mem_latency: 0.0,
        tlb_hit_rate: 0.0,
        row_hit_rate: {
            let rates: Vec<f64> = stacks.iter().map(|s| s.row_hit_rate()).collect();
            crate::stats::mean(&rates)
        },
        mem_backend: cfg.mem_backend.to_string(),
        bank_conflicts: mem_stats.row_conflicts,
        refresh_stalls: mem_stats.refresh_stalls,
        cgp_pages: 0,
        fgp_pages: 0,
        migrated_pages: 0,
        ..Default::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::{cgp_only_plan, PlacementPlan};
    use crate::sim::map_objects;
    use crate::workloads::suite;

    /// Fig 13's claim: host execution favors FGP over CGP by a wide margin
    /// (paper: 1.48x across the suite).
    #[test]
    fn host_prefers_fine_grain() {
        let cfg = SystemConfig::test_small();
        let wl = suite::build("NN", &cfg).unwrap();
        let fgp_plan = PlacementPlan::all_fgp(wl.trace.objects.len());
        let cgp_plan = cgp_only_plan(wl.trace.objects.len(), &cfg);
        let (vm_f, base_f, _, _) = map_objects(&cfg, &wl.trace, &fgp_plan).unwrap();
        let (vm_c, base_c, _, _) = map_objects(&cfg, &wl.trace, &cgp_plan).unwrap();
        let r_f = run_host_sweep(&cfg, &wl.trace, &vm_f, &base_f);
        let r_c = run_host_sweep(&cfg, &wl.trace, &vm_c, &base_c);
        let speedup = r_c.cycles / r_f.cycles;
        assert!(
            speedup > 1.2,
            "FGP must beat CGP for host execution, got {speedup:.2}x"
        );
        // FGP balances stack traffic; CGP-sequential concentrates it.
        let r = RunReport {
            stack_bytes: r_f.stack_bytes.clone(),
            ..Default::default()
        };
        assert!(r.stack_imbalance() < 1.1);
    }

    #[test]
    fn host_access_count_matches_footprint() {
        let cfg = SystemConfig::test_small();
        let wl = suite::build("NN", &cfg).unwrap();
        let plan = PlacementPlan::all_fgp(wl.trace.objects.len());
        let (vm, base, _, _) = map_objects(&cfg, &wl.trace, &plan).unwrap();
        let r = run_host_sweep(&cfg, &wl.trace, &vm, &base);
        let lines: u64 = wl
            .trace
            .objects
            .iter()
            .map(|o| o.bytes.div_ceil(cfg.line_size))
            .sum();
        assert_eq!(r.accesses.host, lines);
    }
}
