//! Host-processor execution model (§6.6, Fig 13) and its CHoNDA bridge.
//!
//! When an application runs on the host, its memory requests travel over
//! the per-stack Host ports. Fine-grain interleaving spreads a sequential
//! stream's concurrent requests over all stacks (full aggregate host
//! bandwidth); coarse-grain interleaving serializes each page's worth of
//! requests onto a single stack's port — which is why the paper keeps FGP
//! as the default and localizes selectively.
//!
//! The sweep used to be a standalone sequential loop; it now executes as
//! a [`crate::engine::HostStream`] inside the shared event engine — the
//! same machinery that co-runs host traffic against NDP kernels in
//! [`crate::multiprog::run_hostmix`] — with [`run_host_sweep`] as the
//! degenerate host-alone case. `tests/host_contention.rs` keeps a frozen
//! copy of the pre-engine loop and proves this path reproduces it
//! bit-exactly under both DRAM backends.

use crate::addr::VirtualAddress;
use crate::config::SystemConfig;
use crate::session::Session;
use crate::spec::ExperimentSpec;
use crate::stats::RunReport;
use crate::trace::KernelTrace;
use crate::vm::VirtualMemory;

/// Outstanding host requests (an aggressive OoO core + MLP prefetchers).
/// This is the default for `SystemConfig::host_mlp`, the host-intensity
/// knob; the legacy sweep always used exactly this window.
pub const HOST_MLP: usize = 64;

/// Run a host-side streaming sweep over every object of `trace` (the data
/// the kernel would consume), with the objects mapped by `vm`.
/// Returns a report whose `cycles` reflect host execution time.
///
/// Uses `cfg.host_mlp` requests in flight (default [`HOST_MLP`], the
/// legacy window) and `cfg.host_passes` sweeps; a zero for either yields
/// an empty report, since it disables host traffic.
///
/// A thin wrapper since the experiment-API redesign: it builds the
/// host-alone [`ExperimentSpec`] and runs it through
/// [`Session::run_host_in`] over the caller's existing layout. The
/// lowering cannot fail for a host-only spec (the spec carries no
/// overrides and the caller's config is trusted as-is, exactly as the
/// pre-spec implementation did), so the signature stays infallible.
pub fn run_host_sweep(
    cfg: &SystemConfig,
    trace: &KernelTrace,
    vm: &mut VirtualMemory,
    obj_base: &[VirtualAddress],
) -> RunReport {
    let spec = ExperimentSpec::host_sweep(trace);
    Session::new(cfg.clone(), spec)
        .and_then(|s| s.run_host_in(vm, obj_base))
        .map(|r| r.run)
        .expect("host-alone spec lowering is infallible")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::{cgp_only_plan, PlacementPlan};
    use crate::sim::map_objects;
    use crate::workloads::suite;

    /// Fig 13's claim: host execution favors FGP over CGP by a wide margin
    /// (paper: 1.48x across the suite).
    #[test]
    fn host_prefers_fine_grain() {
        let cfg = SystemConfig::test_small();
        let wl = suite::build("NN", &cfg).unwrap();
        let fgp_plan = PlacementPlan::all_fgp(wl.trace.objects.len());
        let cgp_plan = cgp_only_plan(wl.trace.objects.len(), &cfg);
        let (mut vm_f, base_f, _, _) = map_objects(&cfg, &wl.trace, &fgp_plan).unwrap();
        let (mut vm_c, base_c, _, _) = map_objects(&cfg, &wl.trace, &cgp_plan).unwrap();
        let r_f = run_host_sweep(&cfg, &wl.trace, &mut vm_f, &base_f);
        let r_c = run_host_sweep(&cfg, &wl.trace, &mut vm_c, &base_c);
        let speedup = r_c.cycles / r_f.cycles;
        assert!(
            speedup > 1.2,
            "FGP must beat CGP for host execution, got {speedup:.2}x"
        );
        // FGP balances stack traffic; CGP-sequential concentrates it.
        let r = RunReport {
            stack_bytes: r_f.stack_bytes.clone(),
            ..Default::default()
        };
        assert!(r.stack_imbalance() < 1.1);
    }

    #[test]
    fn host_access_count_matches_footprint() {
        let cfg = SystemConfig::test_small();
        let wl = suite::build("NN", &cfg).unwrap();
        let plan = PlacementPlan::all_fgp(wl.trace.objects.len());
        let (mut vm, base, _, _) = map_objects(&cfg, &wl.trace, &plan).unwrap();
        let r = run_host_sweep(&cfg, &wl.trace, &mut vm, &base);
        let lines: u64 = wl
            .trace
            .objects
            .iter()
            .map(|o| o.bytes.div_ceil(cfg.line_size))
            .sum();
        assert_eq!(r.accesses.host, lines);
        assert_eq!(r.accesses.ndp_total(), 0, "no NDP side in a host sweep");
        assert_eq!(r.cycles, r.host_cycles);
    }

    #[test]
    fn zero_intensity_sweep_is_empty() {
        let mut cfg = SystemConfig::test_small();
        cfg.host_mlp = 0;
        let wl = suite::build("NN", &cfg).unwrap();
        let plan = PlacementPlan::all_fgp(wl.trace.objects.len());
        let (mut vm, base, _, _) = map_objects(&cfg, &wl.trace, &plan).unwrap();
        let r = run_host_sweep(&cfg, &wl.trace, &mut vm, &base);
        assert_eq!(r.accesses.host, 0);
        assert_eq!(r.cycles, 0.0);
    }

    #[test]
    fn extra_passes_sustain_traffic() {
        let cfg1 = SystemConfig::test_small();
        let mut cfg3 = SystemConfig::test_small();
        cfg3.host_passes = 3;
        let wl = suite::build("NN", &cfg1).unwrap();
        let plan = PlacementPlan::all_fgp(wl.trace.objects.len());
        let (mut vm, base, _, _) = map_objects(&cfg1, &wl.trace, &plan).unwrap();
        let r1 = run_host_sweep(&cfg1, &wl.trace, &mut vm, &base);
        let (mut vm3, base3, _, _) = map_objects(&cfg3, &wl.trace, &plan).unwrap();
        let r3 = run_host_sweep(&cfg3, &wl.trace, &mut vm3, &base3);
        assert_eq!(r3.accesses.host, 3 * r1.accesses.host);
        assert!(r3.cycles > r1.cycles);
    }
}
