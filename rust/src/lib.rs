//! # CODA — Co-location of Computation and Data for Near-Data Processing
//!
//! A full-system reproduction of *CODA: Enabling Co-location of Computation
//! and Data for Near-Data Processing* (Kim et al., 2017, DOI
//! 10.1145/3232521) as a three-layer Rust + JAX + Pallas stack.
//!
//! The paper targets a GPU-based NDP system: a host processor plus multiple
//! HBM stacks, each with SMs on its logic layer. Remote (stack-to-stack)
//! links are far slower than a stack's internal bandwidth, so near-data
//! execution only pays off when a thread-block's data is resident in the
//! stack where the thread-block runs. CODA contributes:
//!
//! 1. **Dual-mode address mapping** ([`addr`]): every OS page is either
//!    fine-grain interleaved across stacks (FGP) or localized to one stack
//!    (CGP), selected by a granularity bit carried in the PTE/TLB.
//! 2. **Compute–data co-location** ([`sched`], [`placement`], [`analysis`]):
//!    an affinity function steers thread-blocks to stacks, and a
//!    compiler/profiler analysis decides per memory object whether to
//!    localize (CGP) or distribute (FGP) it.
//!
//! This crate implements the complete evaluation substrate the paper ran on
//! (which used SST + MacSim + DRAMSim2): an NDP system model with
//! contention-aware link/DRAM timing ([`sim`], [`mem`], [`net`]), virtual
//! memory with page-group-aware allocation ([`vm`]), 20 benchmark workload
//! generators ([`workloads`]), the symbolic stride analysis ([`analysis`]),
//! all baselines (FGP-Only, CGP-Only, first-touch, migration), and a PJRT
//! runtime ([`runtime`]) that executes real AOT-compiled JAX/Pallas compute
//! on the request path of the end-to-end examples.
//!
//! ## DRAM timing backends
//!
//! Memory timing is a pluggable subsystem: every stack's DRAM is served by
//! a [`mem::MemBackend`], selected through
//! [`config::SystemConfig::mem_backend`] (CLI `--mem-backend
//! fixed|bank|cycle`):
//!
//! * `fixed` ([`mem::FixedLatency`]) — the original open-row channel model
//!   with fixed hit/miss service latency; cheap, and the default all
//!   golden numbers are locked against.
//! * `bank` ([`mem::BankLevel`]) — per-bank row-buffer state
//!   (hit/miss/conflict), bank-group column-command gaps, and periodic
//!   refresh windows; DRAMsim-class fidelity for sensitivity studies.
//! * `cycle` ([`mem::CycleAccurate`]) — explicit ACT/PRE/RD/WR command
//!   scheduling (tRCD/tRP/tRAS/tCCD/tRRD/tFAW), FR-FCFS posted-write
//!   draining, per-rank staggered refresh and an open/closed row policy,
//!   verified on every debug/test run by the [`mem::protocol`] legality
//!   checker.
//!
//! Backends may only shape time: placement, translation and scheduling
//! never observe them, so local/remote access *counts* are byte-identical
//! across backends (`tests/backends.rs` enforces this).
//!
//! ## Simulation engine
//!
//! The discrete-event substrate — event heap, SM residency slots, TLB
//! walk, interconnect queuing, per-stack backend dispatch — is
//! single-sourced in [`engine`]. The single-kernel path ([`sim`]) and the
//! multiprogrammed paths ([`multiprog`]) are thin adapters that plug a
//! [`engine::BlockSource`] into it; `tests/differential` proves both
//! adapters cycle-identical to the pre-refactor standalone loops.
//! [`multiprog::run_multi`] adds true multi-kernel scheduling on top:
//! more kernels than stacks, staggered arrivals, SM time-sharing under a
//! per-app fairness policy, and per-app slowdown / weighted-speedup
//! reporting. A single big run can itself execute in parallel: [`shard`]
//! partitions the engine by home stack under conservative-lookahead
//! windows (config `shard_stacks`; the sequential engine stays the
//! bit-exactness oracle and every degenerate case lowers back to it).
//!
//! ## Concurrent host + NDP execution (CHoNDA-style)
//!
//! The engine can co-run a host-processor request stream
//! ([`engine::HostStream`]) with the NDP kernels: an MLP-limited window
//! of host requests (`host_mlp`/`host_passes` in [`config`]) injected
//! through the per-stack host ports, contending with NDP traffic for
//! interconnect slots and DRAM dispatch — the scenario CHoNDA
//! (arXiv 1908.06362) studies. [`multiprog::run_hostmix`] (CLI:
//! `coda hostmix`) reports per-source bandwidth share, host and NDP
//! slowdowns vs run-alone, and host-port contention stalls; an optional
//! host-local DDR ([`mem::make_host_ddr`], `host_ddr_fraction`) absorbs
//! the host's private lines. Host-alone runs reproduce the legacy
//! [`host::run_host_sweep`] cycles bit-exactly, and zero host intensity
//! leaves NDP runs bit-identical (`tests/host_contention.rs`).
//!
//! ## The declarative experiment API
//!
//! Every scenario above is launched through one front door: a
//! serializable [`spec::ExperimentSpec`] describes the traffic sources
//! (NDP kernels with placement/mechanism/home/arrival, an optional host
//! stream with intensity overrides), system-config overrides, scheduling
//! and fairness policies, requested baselines, and an optional parameter
//! sweep; a [`session::Session`] lowers any spec into one shared-engine
//! run and returns a structured [`session::Report`] (a superset of
//! [`stats::RunReport`]). The classic entry points —
//! [`coordinator::Coordinator::run`], [`multiprog::run_mix`],
//! [`multiprog::run_multi`], [`multiprog::run_hostmix`],
//! [`host::run_host_sweep`] — are thin wrappers that construct a spec,
//! and `tests/spec_equiv.rs` proves each cycle-identical (bit-exact f64,
//! both DRAM backends) to its frozen pre-redesign implementation. Specs
//! round-trip through the project's TOML subset (`coda run <spec.toml>`;
//! examples under `examples/*.toml`).
//!
//! ## Quickstart
//!
//! ```no_run
//! use coda::config::SystemConfig;
//! use coda::coordinator::{Coordinator, Mechanism};
//! use coda::workloads::suite;
//!
//! let cfg = SystemConfig::default();
//! let wl = suite::build("PR", &cfg).unwrap();
//! let report = Coordinator::new(cfg).run(&*wl, Mechanism::Coda).unwrap();
//! println!("cycles={} remote={}", report.cycles, report.accesses.remote);
//! ```
//!
//! The same run, declaratively:
//!
//! ```no_run
//! use coda::config::SystemConfig;
//! use coda::coordinator::Mechanism;
//! use coda::session::Session;
//! use coda::spec::{ExperimentSpec, WorkloadSel};
//!
//! let spec = ExperimentSpec::kernel(WorkloadSel::named("PR").unwrap(), Mechanism::Coda);
//! let report = Session::new(SystemConfig::default(), spec).unwrap().run().unwrap();
//! println!("{}", report.to_json().render());
//! ```

// Style lints the long-form test suites trip constantly without adding
// signal; correctness lints stay on.
#![allow(clippy::field_reassign_with_default)]
#![allow(clippy::needless_range_loop)]

pub mod addr;
pub mod analysis;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod energy;
pub mod engine;
pub mod gpu;
pub mod harness;
pub mod host;
pub mod mem;
pub mod multiprog;
pub mod net;
pub mod par;
pub mod placement;
pub mod proptest_lite;
pub mod report;
pub mod rng;
pub mod runtime;
pub mod sched;
pub mod session;
pub mod shard;
pub mod sim;
pub mod spec;
pub mod stats;
pub mod trace;
pub mod vm;
pub mod workloads;
pub mod xlate;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
