//! The `coda` CLI: run benchmarks under any mechanism, classify workloads
//! (Fig 3 / Table 2), co-run host + NDP traffic, sweep parameters, dump
//! configs — and run any declarative experiment spec from a TOML file.
//!
//! ```text
//! coda run <BENCH>        [--mechanism coda|fgp|cgp|fta|migrate|fgp-affinity|steal]
//!                         [--mem-backend fixed|bank|cycle]
//!                         [--config file.toml] [--set key=value]... [--json]
//! coda run <SPEC.toml>    # declarative experiment spec (see examples/)
//! coda compare <BENCH>            # all mechanisms side by side
//! coda classify [BENCH]           # Fig-3 histogram + Table-2 category
//! coda suite [--mechanism ...]    # all 20 benchmarks
//! coda mix <B1,B2,...> [--placement fgp|cgp] [--policy affinity|baseline|steal]
//!                      [--fairness fcfs|rr|least] [--stagger CYCLES]
//!                      [--baselines auto|none|solo|host-split]
//!                      # multi-kernel mix; may name more apps than stacks
//! coda hostmix <B1,..|-> [--host BENCH] [--host-mlp N] [--host-passes N]
//!                      # NDP kernels + a concurrent host request stream
//!                      # contending for the stacks; "-" = host alone
//! coda sweep <BENCH> [--key k --values v1,v2,...]
//! coda config                     # print the default config (Table 1)
//! coda help                       # full quickstart with examples
//! ```
//!
//! Every command is a thin builder over the same [`coda::spec`] →
//! [`coda::session`] pipeline; `coda run <spec.toml>` reproduces any of
//! them from a file alone.

use coda::cli::Args;
use coda::config::SystemConfig;
use coda::coordinator::{Coordinator, Mechanism};
use coda::report::{f2, pct, Json, Table};
use coda::sched::affinity_stack;
use coda::session::{self, Report, Session, SourceKind};
use coda::spec::{Baselines, ExperimentSpec, OutputFormat, SweepSpec, WorkloadSel};
use coda::stats::RunReport;
use coda::trace::{classify, sharing_histogram};
use coda::workloads::suite;

fn mechanism_of(name: &str) -> coda::Result<Mechanism> {
    Mechanism::parse(name).ok_or_else(|| anyhow::anyhow!("unknown mechanism {name}"))
}

fn load_config(args: &Args) -> coda::Result<SystemConfig> {
    let mut cfg = match args.opt("config") {
        Some(path) => SystemConfig::from_file(path)?,
        None => SystemConfig::default(),
    };
    // Repeated --set k=v is not supported by the flat map; accept
    // comma-separated pairs instead.
    if let Some(sets) = args.opt("set") {
        for pair in sets.split(',') {
            let (k, v) = pair
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("--set expects key=value, got {pair}"))?;
            cfg.set(k, v)?;
        }
    }
    // --mem-backend is sugar for --set mem_backend=... and wins over it.
    if let Some(backend) = args.opt("mem-backend") {
        cfg.set("mem_backend", backend)?;
    }
    // --threads is sugar for --set sim_threads=... and wins over it
    // (orchestration fan-out: 0 = one per core, 1 = sequential).
    if let Some(threads) = args.opt("threads") {
        cfg.set("sim_threads", threads)?;
    }
    // --topology is sugar for --set topology=... and wins over it.
    if let Some(topo) = args.opt("topology") {
        cfg.set("topology", topo)?;
    }
    cfg.validate()?;
    Ok(cfg)
}

/// The `--baselines` override shared by `run`, `mix` and `hostmix`.
fn baselines_opt(args: &Args) -> coda::Result<Option<Baselines>> {
    match args.opt("baselines") {
        None => Ok(None),
        Some(s) => Baselines::parse(s).map(Some).ok_or_else(|| {
            anyhow::anyhow!("unknown baselines {s} (expected auto|none|solo|host-split)")
        }),
    }
}

fn print_report(r: &RunReport, json: bool) {
    if json {
        println!("{}", Json::from(r).render());
    } else {
        println!(
            "{:<6} {:<18} cycles={:>14.0}  local={:<9} remote={:<9} remote%={:<6} cgp_pages={} migrated={}",
            r.workload,
            r.mechanism,
            r.cycles,
            r.accesses.local,
            r.accesses.remote,
            pct(r.accesses.remote_fraction()),
            r.cgp_pages,
            r.migrated_pages,
        );
    }
}

/// Render a session [`Report`]: the classic one-liner for single-kernel
/// runs, a per-source table plus summary footer for everything else.
fn print_spec_report(r: &Report, json: bool) {
    if json {
        println!("{}", r.to_json().render());
        return;
    }
    if let Some(name) = &r.spec_name {
        println!("# {name}");
    }
    if r.sources.len() == 1
        && r.sources[0].kind == SourceKind::Ndp
        && r.run.app_cycles.is_empty()
    {
        print_report(&r.run, false);
        return;
    }
    let mut t = Table::new(&["source", "home", "arrival", "cycles", "slowdown"]);
    for s in &r.sources {
        t.row(&[
            format!("{}:{}", s.kind, s.workload),
            s.home.map(|h| h.to_string()).unwrap_or_else(|| "-".into()),
            format!("{:.0}", s.arrival),
            format!("{:.0}", s.cycles),
            s.slowdown.map(f2).unwrap_or_else(|| "-".into()),
        ]);
    }
    println!("{}", t.render());
    let mut line = format!(
        "{} ({}): cycles={:.0} remote%={}",
        r.run.workload,
        r.run.mechanism,
        r.run.cycles,
        pct(r.run.accesses.remote_fraction()),
    );
    if !r.run.app_slowdown.is_empty() {
        line.push_str(&format!(" weighted_speedup={:.3}", r.run.weighted_speedup));
    }
    if r.run.accesses.host_total() > 0 || r.run.host_cycles > 0.0 {
        line.push_str(&format!(
            " ndp_slowdown={} host_bw_share={} port_stalls={} host_ddr={}",
            f2(r.run.ndp_slowdown),
            pct(r.run.host_bw_share),
            r.run.host_port_stalls,
            r.run.accesses.host_ddr,
        ));
    }
    println!("{line}");
    if let Some(s) = &r.run.service {
        println!(
            "service: {}/{} requests completed ({} incomplete) \
             offered_rate={:.6} achieved_rate={:.6}",
            s.requests_completed,
            s.requests_offered,
            s.requests_incomplete,
            s.offered_rate,
            s.achieved_rate,
        );
        println!(
            "response cycles: mean={:.0} p50={:.0} p99={:.0} p999={:.0} max={:.0}",
            s.mean_response, s.p50_response, s.p99_response, s.p999_response, s.max_response,
        );
    }
}

/// `coda run <SPEC.toml>`: load, lower and run a declarative experiment
/// spec (expanding its sweep section into one report per value). CLI
/// config options layer *under* the spec's `[system]` overrides.
fn cmd_run_spec(args: &Args, path: &str) -> coda::Result<()> {
    let base = load_config(args)?;
    let mut spec = ExperimentSpec::from_file(path)?;
    if let Some(b) = baselines_opt(args)? {
        spec.output.baselines = b;
    }
    let json = args.has_flag("json") || spec.output.format == OutputFormat::Json;
    for r in session::run_spec(&base, &spec)? {
        print_spec_report(&r, json);
    }
    Ok(())
}

fn cmd_run(args: &Args) -> coda::Result<()> {
    let arg = args
        .positional
        .first()
        .ok_or_else(|| anyhow::anyhow!("usage: coda run <BENCH|SPEC.toml>"))?;
    // A `.toml` argument takes the declarative spec path; anything else
    // is a benchmark name (the classic single-kernel command). The
    // suffix — not file existence — decides, so a stray file named like
    // a benchmark can never shadow it.
    if arg.ends_with(".toml") {
        return cmd_run_spec(args, arg);
    }
    let cfg = load_config(args)?;
    let mech = mechanism_of(args.opt("mechanism").unwrap_or("coda"))?;
    let mut spec = ExperimentSpec::kernel(WorkloadSel::named(arg)?, mech);
    if let Some(b) = baselines_opt(args)? {
        // Kernel dispatch runs no baselines; Session::new rejects a
        // request it would otherwise have to drop silently.
        spec.output.baselines = b;
    }
    let r = Session::new(cfg, spec)?.run()?;
    print_report(&r.run, args.has_flag("json"));
    Ok(())
}

fn cmd_compare(args: &Args) -> coda::Result<()> {
    let cfg = load_config(args)?;
    let bench = args
        .positional
        .first()
        .ok_or_else(|| anyhow::anyhow!("usage: coda compare <BENCH>"))?;
    let wl = suite::build(bench, &cfg)?;
    let coord = Coordinator::new(cfg);
    let mechs = [
        Mechanism::FgpOnly,
        Mechanism::CgpOnly,
        Mechanism::CgpFta,
        Mechanism::MigrationFta,
        Mechanism::Coda,
    ];
    let reports = coord.compare(&wl, &mechs)?;
    let base = &reports[0];
    let mut t = Table::new(&["mechanism", "cycles", "speedup", "remote%", "remote-reduction"]);
    for r in &reports {
        t.row(&[
            r.mechanism.clone(),
            format!("{:.0}", r.cycles),
            f2(r.speedup_over(base)),
            pct(r.accesses.remote_fraction()),
            pct(r.remote_reduction_over(base)),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_classify(args: &Args) -> coda::Result<()> {
    let cfg = load_config(args)?;
    let names: Vec<&str> = match args.positional.first() {
        Some(b) => vec![b.as_str()],
        None => suite::names(),
    };
    let mut t = Table::new(&["bench", "1 TB", "2 TBs", "3-16", ">16", "~all", "category"]);
    for name in names {
        let wl = suite::build(name, &cfg)?;
        let h = sharing_histogram(&wl.trace, cfg.page_size, |b| affinity_stack(b, &cfg));
        let f = h.fractions();
        t.row(&[
            name.into(),
            pct(f[0]),
            pct(f[1]),
            pct(f[2]),
            pct(f[3]),
            pct(f[4]),
            classify(&h).to_string(),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_plan(args: &Args) -> coda::Result<()> {
    let cfg = load_config(args)?;
    let bench = args
        .positional
        .first()
        .ok_or_else(|| anyhow::anyhow!("usage: coda plan <BENCH>"))?;
    let wl = suite::build(bench, &cfg)?;
    let coord = Coordinator::new(cfg.clone());
    let plan = coord.plan_for(&wl, Mechanism::Coda);
    let profile = coda::analysis::profile_trace(&wl.trace, cfg.page_size, |b| {
        affinity_stack(b, &cfg)
    });
    let mut t = Table::new(&[
        "obj", "name", "bytes", "placement", "cross%", "strided", "stride", "footprint",
    ]);
    for (i, o) in wl.trace.objects.iter().enumerate() {
        let p = profile.get(&(i as u16));
        t.row(&[
            i.to_string(),
            o.name.clone(),
            o.bytes.to_string(),
            format!("{:?}", plan.per_object[i]),
            p.map(|p| pct(p.cross_stack_fraction)).unwrap_or_default(),
            p.map(|p| p.looks_strided.to_string()).unwrap_or_default(),
            p.map(|p| format!("{:.0}", p.stride_estimate)).unwrap_or_default(),
            p.map(|p| format!("{:.0}", p.mean_footprint)).unwrap_or_default(),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_debug_pages(args: &Args) -> coda::Result<()> {
    let cfg = load_config(args)?;
    let bench = args
        .positional
        .first()
        .ok_or_else(|| anyhow::anyhow!("usage: coda debug-pages <BENCH> <OBJ>"))?;
    let obj: u16 = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow::anyhow!("usage: coda debug-pages <BENCH> <OBJ>"))?
        .parse()?;
    let wl = suite::build(bench, &cfg)?;
    // Recompute per-page per-stack counts exactly.
    use std::collections::HashMap;
    let mut pages: HashMap<u64, Vec<u64>> = HashMap::new();
    for b in &wl.trace.blocks {
        let s = affinity_stack(b.block_id, &cfg);
        for a in &b.accesses {
            if a.obj == obj {
                let e = pages
                    .entry(a.offset / cfg.page_size)
                    .or_insert_with(|| vec![0; cfg.num_stacks]);
                e[s] += 1;
            }
        }
    }
    let mut hist = [0usize; 10];
    let mut sample = Vec::new();
    for (pg, counts) in &pages {
        let total: u64 = counts.iter().sum();
        let share = *counts.iter().max().unwrap() as f64 / total.max(1) as f64;
        hist[((share * 10.0) as usize).min(9)] += 1;
        if sample.len() < 5 {
            sample.push((*pg, counts.clone()));
        }
    }
    println!("majority-share histogram (0.0-1.0 deciles): {hist:?}");
    for (pg, c) in sample {
        println!("page {pg}: {c:?}");
    }
    Ok(())
}

fn cmd_suite(args: &Args) -> coda::Result<()> {
    let cfg = load_config(args)?;
    let mech = mechanism_of(args.opt("mechanism").unwrap_or("coda"))?;
    let coord = Coordinator::new(cfg.clone());
    let json = args.has_flag("json");
    let mut speedups = Vec::new();
    for name in suite::names() {
        let wl = suite::build(name, &cfg)?;
        let base = coord.run(&wl, Mechanism::FgpOnly)?;
        let r = coord.run(&wl, mech)?;
        speedups.push(r.speedup_over(&base));
        print_report(&r, json);
    }
    if !json {
        println!("geomean speedup over FGP-Only: {:.3}", coda::stats::geomean(&speedups));
    }
    Ok(())
}

/// The placement/policy/fairness/stagger knobs `mix` and `hostmix` share.
fn mix_knobs(
    args: &Args,
    cfg: &SystemConfig,
) -> coda::Result<(
    coda::multiprog::MixPlacement,
    coda::sched::Policy,
    coda::sched::FairnessPolicy,
    f64,
)> {
    let placement_s = args.opt("placement").unwrap_or("cgp");
    let placement = coda::multiprog::MixPlacement::parse(placement_s)
        .ok_or_else(|| anyhow::anyhow!("unknown placement {placement_s} (expected fgp|cgp)"))?;
    let policy_s = args.opt("policy").unwrap_or("affinity");
    let policy = coda::sched::Policy::parse(policy_s).ok_or_else(|| {
        anyhow::anyhow!("unknown policy {policy_s} (expected affinity|baseline|steal)")
    })?;
    let fairness = match args.opt("fairness") {
        None => cfg.mix_fairness,
        Some(s) => coda::sched::FairnessPolicy::parse(s)
            .ok_or_else(|| anyhow::anyhow!("unknown fairness {s} (expected fcfs|rr|least)"))?,
    };
    let stagger: f64 = args.opt_parse("stagger", cfg.mix_stagger_cycles)?;
    anyhow::ensure!(
        stagger.is_finite() && stagger >= 0.0,
        "--stagger must be a non-negative real"
    );
    Ok((placement, policy, fairness, stagger))
}

fn cmd_mix(args: &Args) -> coda::Result<()> {
    let cfg = load_config(args)?;
    let benches = args
        .positional
        .first()
        .ok_or_else(|| anyhow::anyhow!("usage: coda mix <B1,B2,...> [--placement fgp|cgp]"))?;
    let (placement, policy, fairness, stagger) = mix_knobs(args, &cfg)?;
    let launches: Vec<(WorkloadSel<'static>, f64)> = benches
        .split(',')
        .enumerate()
        .map(|(i, n)| Ok((WorkloadSel::named(n.trim())?, i as f64 * stagger)))
        .collect::<coda::Result<_>>()?;
    let mut spec = ExperimentSpec::shared(launches, placement, policy, fairness);
    if let Some(b) = baselines_opt(args)? {
        spec.output.baselines = b;
    }
    let r = Session::new(cfg, spec)?.run()?;
    if args.has_flag("json") {
        println!("{}", r.to_json().render());
        return Ok(());
    }
    let mut t = Table::new(&["app", "home", "arrival", "response", "slowdown"]);
    for s in &r.sources {
        t.row(&[
            s.workload.clone(),
            s.home.map(|h| h.to_string()).unwrap_or_else(|| "-".into()),
            format!("{:.0}", s.arrival),
            format!("{:.0}", s.cycles),
            s.slowdown.map(f2).unwrap_or_else(|| "-".into()),
        ]);
    }
    println!("{}", t.render());
    println!(
        "{} ({}): cycles={:.0} remote%={} weighted_speedup={:.3}",
        r.run.workload,
        r.run.mechanism,
        r.run.cycles,
        pct(r.run.accesses.remote_fraction()),
        r.run.weighted_speedup
    );
    Ok(())
}

fn cmd_hostmix(args: &Args) -> coda::Result<()> {
    let mut cfg = load_config(args)?;
    // --host-mlp / --host-passes are sugar for the config keys.
    if let Some(v) = args.opt("host-mlp") {
        cfg.set("host_mlp", v)?;
    }
    if let Some(v) = args.opt("host-passes") {
        cfg.set("host_passes", v)?;
    }
    cfg.validate()?;
    let spec_arg = args.positional.first().ok_or_else(|| {
        anyhow::anyhow!(
            "usage: coda hostmix <B1,B2,...|-> [--host BENCH] [--host-mlp N] \
             [--host-passes N] [--placement fgp|cgp]"
        )
    })?;
    let ndp_names: Vec<&str> = if spec_arg.as_str() == "-" {
        Vec::new()
    } else {
        spec_arg.split(',').map(str::trim).collect()
    };
    // The host streams its own application's data; default to the first
    // NDP bench (host and NDP touching the same program's footprint).
    let host_name = args
        .opt("host")
        .or_else(|| ndp_names.first().copied())
        .ok_or_else(|| anyhow::anyhow!("host-alone hostmix needs --host BENCH"))?;
    let (placement, policy, fairness, stagger) = mix_knobs(args, &cfg)?;
    let launches: Vec<(WorkloadSel<'static>, f64)> = ndp_names
        .iter()
        .enumerate()
        .map(|(i, n)| Ok((WorkloadSel::named(n)?, i as f64 * stagger)))
        .collect::<coda::Result<_>>()?;
    let mut spec = ExperimentSpec::hostmix(
        launches,
        Some(WorkloadSel::named(host_name)?),
        placement,
        policy,
        fairness,
    );
    if let Some(b) = baselines_opt(args)? {
        spec.output.baselines = b;
    }
    let r = Session::new(cfg, spec)?.run()?;
    if args.has_flag("json") {
        println!("{}", r.to_json().render());
        return Ok(());
    }
    let mut t = Table::new(&["source", "home", "arrival", "cycles", "slowdown"]);
    for s in &r.sources {
        t.row(&[
            format!("{}:{}", s.kind, s.workload),
            s.home.map(|h| h.to_string()).unwrap_or_else(|| "-".into()),
            format!("{:.0}", s.arrival),
            format!("{:.0}", s.cycles),
            s.slowdown.map(f2).unwrap_or_else(|| "-".into()),
        ]);
    }
    println!("{}", t.render());
    println!(
        "{} ({}): cycles={:.0} ndp_slowdown={} host_bw_share={} port_stalls={} host_ddr={}",
        r.run.workload,
        r.run.mechanism,
        r.run.cycles,
        f2(r.run.ndp_slowdown),
        pct(r.run.host_bw_share),
        r.run.host_port_stalls,
        r.run.accesses.host_ddr,
    );
    Ok(())
}

fn cmd_sweep(args: &Args) -> coda::Result<()> {
    // coda sweep <BENCH> --key remote_bw_gbs --values 16,32,64,128,256
    let cfg0 = load_config(args)?;
    let bench = args
        .positional
        .first()
        .ok_or_else(|| anyhow::anyhow!("usage: coda sweep <BENCH> --key k --values v1,v2"))?;
    let key = args.opt("key").unwrap_or("remote_bw_gbs");
    let values = args.opt("values").unwrap_or("16,32,64,128,256");
    let sweep = SweepSpec {
        key: key.to_string(),
        values: values.split(',').map(|v| v.to_string()).collect(),
    };
    let baselines = baselines_opt(args)?;
    // Two sweeping specs — the FGP baseline and CODA — zipped per value.
    let run_all = |mech: Mechanism| -> coda::Result<Vec<Report>> {
        let mut spec = ExperimentSpec::kernel(WorkloadSel::named(bench)?, mech);
        spec.sweep = Some(sweep.clone());
        if let Some(b) = baselines {
            spec.output.baselines = b;
        }
        session::run_spec(&cfg0, &spec)
    };
    let fgp = run_all(Mechanism::FgpOnly)?;
    let coda_r = run_all(Mechanism::Coda)?;
    let mut t = Table::new(&[key, "FGP cycles", "CODA cycles", "speedup", "CODA remote%"]);
    for ((v, f), c) in sweep.values.iter().zip(&fgp).zip(&coda_r) {
        t.row(&[
            v.clone(),
            format!("{:.0}", f.run.cycles),
            format!("{:.0}", c.run.cycles),
            f2(c.run.speedup_over(&f.run)),
            pct(c.run.accesses.remote_fraction()),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_trace(args: &Args) -> coda::Result<()> {
    // coda trace record <BENCH> <FILE> | coda trace replay <FILE>
    let cfg = load_config(args)?;
    match (
        args.positional.first().map(|s| s.as_str()),
        args.positional.get(1),
        args.positional.get(2),
    ) {
        (Some("record"), Some(bench), Some(path)) => {
            let wl = suite::build(bench, &cfg)?;
            let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
            coda::trace::write_trace(&mut f, &wl.trace)?;
            println!(
                "recorded {} ({} blocks, {} accesses) -> {path}",
                bench,
                wl.trace.num_blocks(),
                wl.trace.total_accesses()
            );
        }
        (Some("replay"), Some(path), _) => {
            let mut f = std::io::BufReader::new(std::fs::File::open(path.as_str())?);
            let trace = coda::trace::read_trace(&mut f)?;
            let wl = coda::workloads::BuiltWorkload {
                name: "replay",
                category: coda::trace::Category::Sharing, // unknown; unused
                trace,
                ir: None,
                env: coda::analysis::ParamEnv::new(256),
            };
            let mech = mechanism_of(args.opt("mechanism").unwrap_or("coda"))?;
            let coord = Coordinator::new(cfg);
            let r = coord.run(&wl, mech)?;
            print_report(&r, args.has_flag("json"));
        }
        _ => anyhow::bail!("usage: coda trace record <BENCH> <FILE> | coda trace replay <FILE>"),
    }
    Ok(())
}

/// The quickstart the `help` command (and README) promise: every command
/// with one example invocation, plus the shape of a JSON report.
fn print_help() {
    println!(
        "coda — NDP simulator for CODA (co-location of computation and data)\n\
         \n\
         USAGE: coda <COMMAND> [OPTIONS]\n\
         \n\
         COMMANDS (one example each)\n\
         \x20 run <BENCH>          one benchmark under one mechanism\n\
         \x20                        coda run PR --mechanism coda --mem-backend bank --json\n\
         \x20 run <SPEC.toml>      a declarative experiment spec: kernels, host\n\
         \x20                      stream, config overrides, baselines, sweeps —\n\
         \x20                      every scenario below, from one file\n\
         \x20                        coda run examples/hostmix_nn_km.toml --json\n\
         \x20 compare <BENCH>      all mechanisms side by side\n\
         \x20                        coda compare KM\n\
         \x20 classify [BENCH]     Fig-3 page-sharing histogram + Table-2 category\n\
         \x20                        coda classify BFS\n\
         \x20 plan <BENCH>         per-object placement plan from CODA's analysis\n\
         \x20                        coda plan NN\n\
         \x20 suite                all 20 benchmarks under one mechanism\n\
         \x20                        coda suite --mechanism coda\n\
         \x20 mix <B1,B2,...>      multi-kernel NDP mix (more kernels than stacks OK)\n\
         \x20                        coda mix NN,KM,DC,HS --placement cgp --fairness rr\n\
         \x20 hostmix <B1,..|->    NDP kernels + concurrent host stream contending\n\
         \x20                      for the stacks (CHoNDA-style); \"-\" = host alone\n\
         \x20                        coda hostmix NN --host KM --host-mlp 64\n\
         \x20                        coda hostmix - --host NN   # legacy host sweep\n\
         \x20 sweep <BENCH>        sweep one config key\n\
         \x20                        coda sweep PR --key remote_bw_gbs --values 8,16,64\n\
         \x20 trace record|replay  record / replay a workload trace\n\
         \x20                        coda trace record PR pr.trace\n\
         \x20 config               print the default config (Table 1) as TOML\n\
         \x20                        coda config > system.toml\n\
         \x20 help                 this text\n\
         \n\
         COMMON OPTIONS\n\
         \x20 --mechanism coda|fgp|cgp|fta|migrate|fgp-affinity|steal\n\
         \x20 --mem-backend fixed|bank|cycle  DRAM timing backend\n\
         \x20 --config FILE  --set k=v,...    config file / inline overrides\n\
         \x20 --json                          machine-readable report\n\
         \x20 --baselines auto|none|solo|host-split   run-alone baseline policy\n\
         \x20                                 (none skips the extra runs — fast sweeps)\n\
         \x20 --threads N                     baseline/sweep fan-out threads\n\
         \x20                                 (0 = one per core, 1 = sequential;\n\
         \x20                                 results are thread-count independent)\n\
         \x20 --topology full|line|ring|mesh  stack-to-stack fabric (sugar for\n\
         \x20                                 --set topology=...; knobs: mesh_cols,\n\
         \x20                                 hop_latency_ns, link_bw_gbs,\n\
         \x20                                 net_window_cycles)\n\
         \x20 hostmix: --host BENCH --host-mlp N --host-passes N (host intensity)\n\
         \n\
         JSON REPORTS (--json) always carry: workload, mechanism, cycles\n\
         (simulated SM cycles), local/remote (NDP accesses by serving\n\
         stack), l2_hits, remote_fraction, remote_bytes, mean_mem_latency,\n\
         tlb_hit_rate, row_hit_rate, mem_backend, bank_conflicts,\n\
         refresh_stalls, cgp_pages/fgp_pages/migrated_pages (placement),\n\
         stack_bytes (per-stack DRAM bytes). Cycle-backend runs\n\
         (--mem-backend cycle) add dram_row_hits, dram_row_misses,\n\
         dram_acts, dram_precharges, dram_wq_stalls and dram_faw_stalls\n\
         (per-command counters). Mix runs add app_cycles,\n\
         app_slowdown, weighted_speedup; hostmix runs add host, host_ddr\n\
         (host accesses by destination), host_cycles, host_slowdown,\n\
         ndp_slowdown, host_bytes, host_ddr_bytes, host_port_stalls and\n\
         host_bw_share. Service specs (an [arrivals] section: open-loop\n\
         poisson/bursty/trace request streams, optional per-kernel after\n\
         edges) add requests_offered/completed/incomplete, offered_rate,\n\
         achieved_rate and mean/max/p50/p99/p999_response (streaming\n\
         percentiles over completed requests, fixed memory). Multi-hop\n\
         fabrics (--topology line|ring|mesh) add\n\
         topology, net_window_cycles and links (per directed link:\n\
         from/to/bytes/stalls/peak_window_bytes/peak_bytes_per_cycle).\n\
         Spec-driven runs add spec (the label) and sources\n\
         (per-source kind/workload/home/arrival/cycles/slowdown). Full\n\
         field descriptions: README.md; spec schema: examples/*.toml.\n\
         \n\
         benchmarks: {}",
        suite::names().join(" ")
    );
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(&argv, coda::cli::VALUE_OPTS) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let result = match args.command.as_deref() {
        Some("run") => cmd_run(&args),
        Some("compare") => cmd_compare(&args),
        Some("classify") => cmd_classify(&args),
        Some("plan") => cmd_plan(&args),
        Some("debug-pages") => cmd_debug_pages(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("trace") => cmd_trace(&args),
        Some("suite") => cmd_suite(&args),
        Some("mix") => cmd_mix(&args),
        Some("hostmix") => cmd_hostmix(&args),
        Some("config") => {
            print!("{}", SystemConfig::default().to_toml_string());
            Ok(())
        }
        Some("help") => {
            print_help();
            Ok(())
        }
        _ => {
            print_help();
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
