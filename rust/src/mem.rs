//! HBM stack timing model.
//!
//! Each stack contains `channels_per_stack` channels; each channel owns
//! `banks_per_channel` banks with an open-row policy. A request's service
//! time is row-hit or row-miss latency plus data-transfer occupancy on the
//! channel. Channels are modeled as busy-until servers, which captures the
//! bandwidth contention the paper's results hinge on (hot stacks queue,
//! spread traffic doesn't).
//!
//! The paper uses DRAMSim2 configured for HBM 2.0 (8 channels x 32 GB/s per
//! stack). We reproduce the same aggregate bandwidth and row-buffer
//! behaviour with a far cheaper model; DESIGN.md §2 argues why this
//! preserves the evaluation's shape.

use crate::config::SystemConfig;

/// One HBM channel: an open-row bank array plus a busy-until data bus.
#[derive(Clone, Debug)]
struct Channel {
    next_free: f64,
    open_rows: Vec<u64>, // per bank; u64::MAX = closed
    bytes_served: u64,
    row_hits: u64,
    row_misses: u64,
}

/// Per-stack HBM device model.
#[derive(Clone, Debug)]
pub struct HbmStack {
    channels: Vec<Channel>,
    chan_shift: u32,
    chan_mask: u64,
    bank_mask: u64,
    bank_shift: u32,
    row_shift: u32,
    hit_cycles: f64,
    miss_cycles: f64,
    bytes_per_cycle: f64,
}

/// Timing outcome of one DRAM access.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DramResult {
    /// Completion time (cycles).
    pub done: f64,
    /// Whether the access hit an open row.
    pub row_hit: bool,
}

impl HbmStack {
    pub fn new(cfg: &SystemConfig) -> Self {
        let n_chan = cfg.channels_per_stack.next_power_of_two();
        let per_chan_bw = cfg.gbs_to_bytes_per_cycle(cfg.local_bw_gbs) / n_chan as f64;
        Self {
            channels: vec![
                Channel {
                    next_free: 0.0,
                    open_rows: vec![u64::MAX; cfg.banks_per_channel],
                    bytes_served: 0,
                    row_hits: 0,
                    row_misses: 0,
                };
                n_chan
            ],
            // Channel bits sit right above the line bits so consecutive
            // lines spread across channels (standard HBM practice).
            chan_shift: cfg.line_size.trailing_zeros(),
            chan_mask: n_chan as u64 - 1,
            bank_shift: cfg.line_size.trailing_zeros() + (n_chan as u64).trailing_zeros(),
            bank_mask: cfg.banks_per_channel.next_power_of_two() as u64 - 1,
            row_shift: cfg.row_size.trailing_zeros(),
            hit_cycles: cfg.dram_hit_ns * cfg.cycles_per_ns(),
            miss_cycles: cfg.dram_miss_ns * cfg.cycles_per_ns(),
            bytes_per_cycle: per_chan_bw,
        }
    }

    /// Service one access of `bytes` at *stack-local* physical address
    /// `addr` arriving at time `now`.
    pub fn access(&mut self, now: f64, addr: u64, bytes: u64) -> DramResult {
        let chan_idx = ((addr >> self.chan_shift) & self.chan_mask) as usize;
        let bank_idx = ((addr >> self.bank_shift) & self.bank_mask) as usize;
        let row = addr >> self.row_shift;
        let chan = &mut self.channels[chan_idx];
        let row_hit = chan.open_rows[bank_idx] == row;
        let latency = if row_hit {
            chan.row_hits += 1;
            self.hit_cycles
        } else {
            chan.row_misses += 1;
            chan.open_rows[bank_idx] = row;
            self.miss_cycles
        };
        let start = now.max(chan.next_free);
        let occupancy = bytes as f64 / self.bytes_per_cycle;
        chan.next_free = start + occupancy;
        chan.bytes_served += bytes;
        DramResult {
            done: start + occupancy + latency,
            row_hit,
        }
    }

    /// Earliest time any channel could begin a new transfer (for
    /// backpressure estimates).
    pub fn earliest_free(&self) -> f64 {
        self.channels
            .iter()
            .map(|c| c.next_free)
            .fold(f64::INFINITY, f64::min)
    }

    pub fn bytes_served(&self) -> u64 {
        self.channels.iter().map(|c| c.bytes_served).sum()
    }

    pub fn row_hit_rate(&self) -> f64 {
        let hits: u64 = self.channels.iter().map(|c| c.row_hits).sum();
        let total: u64 = self
            .channels
            .iter()
            .map(|c| c.row_hits + c.row_misses)
            .sum();
        if total == 0 {
            0.0
        } else {
            hits as f64 / total as f64
        }
    }

    /// Busy-time utilization of the most loaded channel up to `now`.
    pub fn peak_channel_util(&self, now: f64) -> f64 {
        if now <= 0.0 {
            return 0.0;
        }
        self.channels
            .iter()
            .map(|c| (c.bytes_served as f64 / self.bytes_per_cycle) / now)
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> SystemConfig {
        SystemConfig::default()
    }

    #[test]
    fn row_hit_is_faster_than_miss() {
        let mut hbm = HbmStack::new(&cfg());
        let first = hbm.access(0.0, 0, 128);
        assert!(!first.row_hit);
        let second = hbm.access(first.done, 0, 128);
        assert!(second.row_hit);
        let miss_lat = first.done;
        let hit_lat = second.done - first.done;
        assert!(hit_lat < miss_lat);
    }

    #[test]
    fn consecutive_lines_spread_across_channels() {
        let c = cfg();
        let mut hbm = HbmStack::new(&c);
        // 8 consecutive lines hit 8 distinct channels -> no queuing: all
        // complete at the same time.
        let times: Vec<f64> = (0..8).map(|i| hbm.access(0.0, i * 128, 128).done).collect();
        assert!(times.windows(2).all(|w| (w[0] - w[1]).abs() < 1e-9));
    }

    #[test]
    fn same_channel_requests_queue() {
        let c = cfg();
        let mut hbm = HbmStack::new(&c);
        let stride = 128 * c.channels_per_stack as u64; // same channel
        let t1 = hbm.access(0.0, 0, 128).done;
        let t2 = hbm.access(0.0, stride * 16, 128).done; // different row too
        assert!(t2 > t1, "second access must queue behind the first");
    }

    #[test]
    fn aggregate_bandwidth_matches_config() {
        let c = cfg();
        let mut hbm = HbmStack::new(&c);
        // Saturate all channels with back-to-back row hits and measure.
        let mut done: f64 = 0.0;
        let n = 4096u64;
        for i in 0..n {
            let r = hbm.access(0.0, (i % 64) * 128, 128);
            done = done.max(r.done);
        }
        let bytes = (n * 128) as f64;
        let achieved = bytes / done; // bytes per cycle
        let peak = c.gbs_to_bytes_per_cycle(c.local_bw_gbs);
        assert!(
            achieved > 0.5 * peak && achieved <= peak * 1.01,
            "achieved {achieved:.1} vs peak {peak:.1} B/cy"
        );
    }

    #[test]
    fn stats_accumulate() {
        let mut hbm = HbmStack::new(&cfg());
        for i in 0..100u64 {
            hbm.access(i as f64, i * 128, 128);
        }
        assert_eq!(hbm.bytes_served(), 12800);
        assert!(hbm.row_hit_rate() >= 0.0);
        assert!(hbm.peak_channel_util(1000.0) > 0.0);
    }
}
