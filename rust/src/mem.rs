//! DRAM timing backends for the HBM stacks.
//!
//! Memory timing is a pluggable subsystem behind the [`MemBackend`] trait;
//! the backend is selected per run from
//! [`SystemConfig::mem_backend`](crate::config::SystemConfig) (CLI:
//! `--mem-backend fixed|bank|cycle`). Three backends ship:
//!
//! * [`FixedLatency`] — the original model. Each stack contains
//!   `channels_per_stack` channels; each channel owns `banks_per_channel`
//!   banks with an open-row policy. A request's service time is row-hit or
//!   row-miss latency plus data-transfer occupancy on the channel. Channels
//!   are busy-until servers, which captures the bandwidth contention the
//!   paper's results hinge on (hot stacks queue, spread traffic doesn't).
//!   The paper uses DRAMSim2 configured for HBM 2.0 (8 channels x 32 GB/s
//!   per stack); this model reproduces the same aggregate bandwidth and
//!   row-buffer behaviour far more cheaply (DESIGN.md §2 argues why that
//!   preserves the evaluation's shape).
//!
//! * [`BankLevel`] — DRAMsim-class per-bank state, for when the fixed model
//!   is the thing under test rather than the substrate: per-bank open rows
//!   and busy windows (row-buffer **hit / empty-miss / conflict** each get
//!   distinct tCL / tRCD+tCL / tRP+tRCD+tCL service times), bank-group
//!   column-command gaps (tCCD_L within a group, tCCD_S across), and
//!   periodic all-bank refresh windows (every tREFI the channel is blocked
//!   for tRFC and all rows close).
//!
//! * [`CycleAccurate`] — explicit command scheduling: every access is an
//!   ACT/PRE/RD/WR sequence subject to the full JEDEC-style constraint set
//!   (tRCD, tRP, tRAS, tCAS, tCCD_S/L, tRRD, tFAW), writes are posted into
//!   a per-channel FR-FCFS queue drained by high/low watermarks and an
//!   aging cap, refresh is staggered per rank, and the row policy is
//!   configurable (open/closed). In debug/test builds every emitted
//!   command is replayed through the [`protocol`] legality checker, which
//!   panics on any timing or state-machine violation — the model cannot
//!   silently drift from the protocol it claims to implement.
//!
//! All backends must agree on *which* accesses happen — placement and
//! translation never consult the timing model — so switching backends may
//! only move cycle counts, never local/remote access splits
//! (`tests/backends.rs` locks this in). Backends expose only an
//! execute-once-and-stall interface ([`MemBackend::access`] mutates state
//! and returns the completion time); there is deliberately no
//! side-effect-free "query the latency" entry point, which a stateful
//! command-level model could not answer honestly.

use crate::addr::PhysicalAddress;
use crate::config::{MemBackendKind, SystemConfig};

/// Timing outcome of one DRAM access.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DramResult {
    /// Completion time (cycles).
    pub done: f64,
    /// Whether the access hit an open row.
    pub row_hit: bool,
}

/// Aggregate counters every backend reports.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MemStats {
    /// Bytes served by the stack's DRAM.
    pub bytes_served: u64,
    /// Accesses that hit an open row.
    pub row_hits: u64,
    /// Accesses to a closed row (activate only).
    pub row_misses: u64,
    /// Accesses that had to close another open row first (bank-level
    /// backend only; the fixed model folds these into `row_misses`).
    pub row_conflicts: u64,
    /// Accesses delayed by an in-progress refresh window (bank-level only).
    pub refresh_stalls: u64,
    /// ACT commands issued (cycle-accurate backend only).
    pub acts: u64,
    /// Precharges, explicit PRE plus auto-precharge (cycle-accurate only).
    pub precharges: u64,
    /// Writes that stalled their requester on a forced write-queue drain
    /// (cycle-accurate only).
    pub wq_stalls: u64,
    /// ACTs delayed by the four-activate window tFAW (cycle-accurate only).
    pub faw_stalls: u64,
}

impl MemStats {
    /// Row-buffer hit rate over all serviced accesses.
    pub fn row_hit_rate(&self) -> f64 {
        let total = self.row_hits + self.row_misses + self.row_conflicts;
        if total == 0 {
            0.0
        } else {
            self.row_hits as f64 / total as f64
        }
    }

    /// Accumulate another stack's counters (suite-level reporting).
    pub fn add(&mut self, other: &MemStats) {
        self.bytes_served += other.bytes_served;
        self.row_hits += other.row_hits;
        self.row_misses += other.row_misses;
        self.row_conflicts += other.row_conflicts;
        self.refresh_stalls += other.refresh_stalls;
        self.acts += other.acts;
        self.precharges += other.precharges;
        self.wq_stalls += other.wq_stalls;
        self.faw_stalls += other.faw_stalls;
    }
}

/// A per-stack DRAM timing model. One instance models one stack; the
/// simulator owns `num_stacks` of them and routes each request to the
/// owning stack's backend.
///
/// # Contract: backends shape time, never behaviour
///
/// A backend decides **when** an access completes, never **whether** or
/// **where** one happens. Placement, address translation, scheduling and
/// the interconnect route requests without ever consulting the timing
/// model, so switching backends may move cycle counts but must leave
/// every access count — local/remote splits, per-stack byte totals,
/// migration decisions — bit-identical (`tests/backends.rs` and the
/// differential suite enforce this). A backend that leaked timing into
/// behaviour would make cross-backend comparisons meaningless.
///
/// Implementations must also be **deterministic** (same access sequence
/// in, same completion times out — the golden snapshots depend on it)
/// and must accept non-decreasing *per-caller* `now` values without
/// assuming global time ordering: concurrent request streams (multiple
/// SMs, the host port) interleave arbitrarily.
pub trait MemBackend {
    /// Service one access of `bytes` at *stack-local* physical address
    /// `addr` arriving at time `now`.
    fn access(&mut self, now: f64, addr: u64, bytes: u64) -> DramResult;

    /// Earliest time any channel could begin a new transfer (for
    /// backpressure estimates).
    fn earliest_free(&self) -> f64;

    /// Counters accumulated so far.
    fn stats(&self) -> MemStats;

    /// Which backend this is (reporting).
    fn kind(&self) -> MemBackendKind;

    /// Total bytes served (convenience over [`Self::stats`]).
    fn bytes_served(&self) -> u64 {
        self.stats().bytes_served
    }

    /// Row-buffer hit rate (convenience over [`Self::stats`]).
    fn row_hit_rate(&self) -> f64 {
        self.stats().row_hit_rate()
    }
}

/// Statically-dispatched backend for the engine's per-access hot path.
///
/// The [`MemBackend`] trait stays the extension seam (new backends — a
/// DRAMsim3 FFI bridge, say — still implement it, and the frozen
/// differential oracles keep consuming `Box<dyn MemBackend>`), but the
/// engine itself routes every access through this enum: a small branch
/// the optimizer can inline every arm of, instead of a vtable load +
/// indirect call per simulated access. Wrapping a backend in the enum
/// changes dispatch only — the arms run the exact same code as the boxed
/// form, so every completion time stays bit-identical (the differential
/// and golden suites pin this).
#[derive(Clone, Debug)]
pub enum MemBackendImpl {
    Fixed(FixedLatency),
    Bank(BankLevel),
    Cycle(CycleAccurate),
}

impl MemBackendImpl {
    /// Build the backend [`SystemConfig::mem_backend`] selects.
    pub fn new(cfg: &SystemConfig) -> Self {
        match cfg.mem_backend {
            MemBackendKind::FixedLatency => Self::Fixed(FixedLatency::new(cfg)),
            MemBackendKind::BankLevel => Self::Bank(BankLevel::new(cfg)),
            MemBackendKind::CycleAccurate => Self::Cycle(CycleAccurate::new(cfg)),
        }
    }

    /// Service one access (see [`MemBackend::access`]); enum dispatch.
    /// Accepts raw `u64` or the typed [`PhysicalAddress`] — the engine
    /// passes physical addresses by type, older callers pass words.
    #[inline]
    pub fn access(
        &mut self,
        now: f64,
        addr: impl Into<PhysicalAddress>,
        bytes: u64,
    ) -> DramResult {
        let addr = addr.into().0;
        match self {
            Self::Fixed(b) => b.access(now, addr, bytes),
            Self::Bank(b) => b.access(now, addr, bytes),
            Self::Cycle(b) => b.do_access(now, addr, bytes, false),
        }
    }

    /// Service one access with its read/write direction. `Fixed` and
    /// `Bank` time reads and writes identically, so those arms stay
    /// bit-identical to [`Self::access`]; only the cycle-accurate
    /// backend's posted-write path consumes the flag.
    #[inline]
    pub fn access_rw(
        &mut self,
        now: f64,
        addr: impl Into<PhysicalAddress>,
        bytes: u64,
        write: bool,
    ) -> DramResult {
        let addr = addr.into().0;
        match self {
            Self::Fixed(b) => b.access(now, addr, bytes),
            Self::Bank(b) => b.access(now, addr, bytes),
            Self::Cycle(b) => b.do_access(now, addr, bytes, write),
        }
    }
}

impl MemBackend for MemBackendImpl {
    fn access(&mut self, now: f64, addr: u64, bytes: u64) -> DramResult {
        MemBackendImpl::access(self, now, addr, bytes)
    }

    fn earliest_free(&self) -> f64 {
        match self {
            Self::Fixed(b) => b.earliest_free(),
            Self::Bank(b) => b.earliest_free(),
            Self::Cycle(b) => b.earliest_free(),
        }
    }

    fn stats(&self) -> MemStats {
        match self {
            Self::Fixed(b) => b.stats(),
            Self::Bank(b) => b.stats(),
            Self::Cycle(b) => b.stats(),
        }
    }

    fn kind(&self) -> MemBackendKind {
        match self {
            Self::Fixed(b) => b.kind(),
            Self::Bank(b) => b.kind(),
            Self::Cycle(b) => b.kind(),
        }
    }
}

/// Build the backend [`SystemConfig::mem_backend`] selects, for one stack.
pub fn make_backend(cfg: &SystemConfig) -> Box<dyn MemBackend> {
    match cfg.mem_backend {
        MemBackendKind::FixedLatency => Box::new(FixedLatency::new(cfg)),
        MemBackendKind::BankLevel => Box::new(BankLevel::new(cfg)),
        MemBackendKind::CycleAccurate => Box::new(CycleAccurate::new(cfg)),
    }
}

/// Build one backend per stack (the shape the frozen oracles consume).
pub fn make_backends(cfg: &SystemConfig) -> Vec<Box<dyn MemBackend>> {
    (0..cfg.num_stacks).map(|_| make_backend(cfg)).collect()
}

/// Build one statically-dispatched backend per stack (the shape the
/// engine's hot path consumes).
pub fn make_backends_impl(cfg: &SystemConfig) -> Vec<MemBackendImpl> {
    (0..cfg.num_stacks).map(|_| MemBackendImpl::new(cfg)).collect()
}

/// The stack config rescaled to the host-local DDR's parameters.
fn host_ddr_cfg(cfg: &SystemConfig) -> SystemConfig {
    let mut ddr_cfg = cfg.clone();
    ddr_cfg.local_bw_gbs = cfg.host_ddr_bw_gbs;
    ddr_cfg.channels_per_stack = cfg.host_ddr_channels;
    ddr_cfg
}

/// Build the host-local DDR timing model (CHoNDA-style host memory).
///
/// The host's DDR sits behind the same [`MemBackend`] seam as the
/// stacks — the kind selected by `cfg.mem_backend` — but scaled to DDR
/// parameters: `host_ddr_bw_gbs` aggregate bandwidth over
/// `host_ddr_channels` channels. Addresses handed to it are host-side
/// line addresses (the DDR owns its own address space; only timing and
/// byte accounting matter).
pub fn make_host_ddr(cfg: &SystemConfig) -> Box<dyn MemBackend> {
    make_backend(&host_ddr_cfg(cfg))
}

/// [`make_host_ddr`], statically dispatched (the engine's form).
pub fn make_host_ddr_impl(cfg: &SystemConfig) -> MemBackendImpl {
    MemBackendImpl::new(&host_ddr_cfg(cfg))
}

// ---------------------------------------------------------------------------
// FixedLatency: the original channel model, preserved exactly.
// ---------------------------------------------------------------------------

/// One HBM channel: an open-row bank array plus a busy-until data bus.
#[derive(Clone, Debug)]
struct Channel {
    next_free: f64,
    open_rows: Vec<u64>, // per bank; u64::MAX = closed
    bytes_served: u64,
    row_hits: u64,
    row_misses: u64,
}

/// The original per-stack HBM device model: open-row tracking with a fixed
/// hit/miss service latency and a busy-until channel bus.
#[derive(Clone, Debug)]
pub struct FixedLatency {
    channels: Vec<Channel>,
    chan_shift: u32,
    chan_mask: u64,
    bank_mask: u64,
    bank_shift: u32,
    row_shift: u32,
    hit_cycles: f64,
    miss_cycles: f64,
    bytes_per_cycle: f64,
}

/// Backwards-compatible name for the original model.
pub type HbmStack = FixedLatency;

impl FixedLatency {
    pub fn new(cfg: &SystemConfig) -> Self {
        let n_chan = cfg.channels_per_stack.next_power_of_two();
        let per_chan_bw = cfg.gbs_to_bytes_per_cycle(cfg.local_bw_gbs) / n_chan as f64;
        Self {
            channels: vec![
                Channel {
                    next_free: 0.0,
                    open_rows: vec![u64::MAX; cfg.banks_per_channel],
                    bytes_served: 0,
                    row_hits: 0,
                    row_misses: 0,
                };
                n_chan
            ],
            // Channel bits sit right above the line bits so consecutive
            // lines spread across channels (standard HBM practice).
            chan_shift: cfg.line_size.trailing_zeros(),
            chan_mask: n_chan as u64 - 1,
            bank_shift: cfg.line_size.trailing_zeros() + (n_chan as u64).trailing_zeros(),
            bank_mask: cfg.banks_per_channel.next_power_of_two() as u64 - 1,
            row_shift: cfg.row_size.trailing_zeros(),
            hit_cycles: cfg.dram_hit_ns * cfg.cycles_per_ns(),
            miss_cycles: cfg.dram_miss_ns * cfg.cycles_per_ns(),
            bytes_per_cycle: per_chan_bw,
        }
    }

    /// Busy-time utilization of the most loaded channel up to `now`.
    pub fn peak_channel_util(&self, now: f64) -> f64 {
        if now <= 0.0 {
            return 0.0;
        }
        self.channels
            .iter()
            .map(|c| (c.bytes_served as f64 / self.bytes_per_cycle) / now)
            .fold(0.0, f64::max)
    }
}

impl MemBackend for FixedLatency {
    fn access(&mut self, now: f64, addr: u64, bytes: u64) -> DramResult {
        let chan_idx = ((addr >> self.chan_shift) & self.chan_mask) as usize;
        let bank_idx = ((addr >> self.bank_shift) & self.bank_mask) as usize;
        let row = addr >> self.row_shift;
        let chan = &mut self.channels[chan_idx];
        let row_hit = chan.open_rows[bank_idx] == row;
        let latency = if row_hit {
            chan.row_hits += 1;
            self.hit_cycles
        } else {
            chan.row_misses += 1;
            chan.open_rows[bank_idx] = row;
            self.miss_cycles
        };
        let start = now.max(chan.next_free);
        let occupancy = bytes as f64 / self.bytes_per_cycle;
        chan.next_free = start + occupancy;
        chan.bytes_served += bytes;
        DramResult {
            done: start + occupancy + latency,
            row_hit,
        }
    }

    fn earliest_free(&self) -> f64 {
        self.channels
            .iter()
            .map(|c| c.next_free)
            .fold(f64::INFINITY, f64::min)
    }

    fn stats(&self) -> MemStats {
        let mut s = MemStats::default();
        for c in &self.channels {
            s.bytes_served += c.bytes_served;
            s.row_hits += c.row_hits;
            s.row_misses += c.row_misses;
        }
        s
    }

    fn kind(&self) -> MemBackendKind {
        MemBackendKind::FixedLatency
    }
}

// ---------------------------------------------------------------------------
// BankLevel: per-bank row state, conflicts, bank groups, refresh.
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
struct Bank {
    /// Currently open row; u64::MAX = precharged (closed).
    open_row: u64,
    /// Time the bank finishes its current row-cycle work.
    ready: f64,
    /// Last refresh window this bank observed (rows close across windows).
    refresh_epoch: u64,
}

#[derive(Clone, Debug)]
struct BankChannel {
    banks: Vec<Bank>,
    /// Data-bus busy-until time.
    bus_free: f64,
    /// Last column command issued on this channel: (bank group, start time).
    last_cmd: Option<(usize, f64)>,
    bytes_served: u64,
    row_hits: u64,
    row_misses: u64,
    row_conflicts: u64,
    refresh_stalls: u64,
}

/// Bank-level DRAM timing: distinguishes row-buffer hits, empty-row misses
/// and conflicts, serializes per-bank row cycles, enforces bank-group
/// column-command gaps, and blocks the channel during periodic refresh.
#[derive(Clone, Debug)]
pub struct BankLevel {
    channels: Vec<BankChannel>,
    chan_shift: u32,
    chan_mask: u64,
    bank_shift: u32,
    bank_mask: u64,
    bank_groups: usize,
    row_shift: u32,
    tcl: f64,
    trcd: f64,
    trp: f64,
    tccd_l: f64,
    tccd_s: f64,
    trefi: f64,
    trfc: f64,
    bytes_per_cycle: f64,
}

impl BankLevel {
    pub fn new(cfg: &SystemConfig) -> Self {
        let n_chan = cfg.channels_per_stack.next_power_of_two();
        let n_banks = cfg.banks_per_channel.next_power_of_two();
        let per_chan_bw = cfg.gbs_to_bytes_per_cycle(cfg.local_bw_gbs) / n_chan as f64;
        let cyc = cfg.cycles_per_ns();
        Self {
            channels: vec![
                BankChannel {
                    banks: vec![
                        Bank {
                            open_row: u64::MAX,
                            ready: 0.0,
                            refresh_epoch: 0,
                        };
                        n_banks
                    ],
                    bus_free: 0.0,
                    last_cmd: None,
                    bytes_served: 0,
                    row_hits: 0,
                    row_misses: 0,
                    row_conflicts: 0,
                    refresh_stalls: 0,
                };
                n_chan
            ],
            chan_shift: cfg.line_size.trailing_zeros(),
            chan_mask: n_chan as u64 - 1,
            bank_shift: cfg.line_size.trailing_zeros() + (n_chan as u64).trailing_zeros(),
            bank_mask: n_banks as u64 - 1,
            bank_groups: cfg.bank_groups_per_channel.min(n_banks),
            row_shift: cfg.row_size.trailing_zeros(),
            tcl: cfg.dram_tcl_ns * cyc,
            trcd: cfg.dram_trcd_ns * cyc,
            trp: cfg.dram_trp_ns * cyc,
            tccd_l: cfg.dram_tccd_l_ns * cyc,
            tccd_s: cfg.dram_tccd_s_ns * cyc,
            trefi: cfg.dram_trefi_ns * cyc,
            trfc: cfg.dram_trfc_ns * cyc,
            bytes_per_cycle: per_chan_bw,
        }
    }

    /// Bank group of a bank index (low bank bits, DDR-style).
    #[inline]
    fn group_of(&self, bank_idx: usize) -> usize {
        bank_idx % self.bank_groups
    }
}

impl MemBackend for BankLevel {
    fn access(&mut self, now: f64, addr: u64, bytes: u64) -> DramResult {
        let chan_idx = ((addr >> self.chan_shift) & self.chan_mask) as usize;
        let bank_idx = ((addr >> self.bank_shift) & self.bank_mask) as usize;
        let group = self.group_of(bank_idx);
        let row = addr >> self.row_shift;
        let (tccd_l, tccd_s) = (self.tccd_l, self.tccd_s);
        let chan = &mut self.channels[chan_idx];

        // The command can issue once the requester, the bank, and the data
        // bus are all available.
        let mut start = now.max(chan.banks[bank_idx].ready).max(chan.bus_free);
        // Bank-group column-command gap.
        if let Some((last_group, last_start)) = chan.last_cmd {
            let gap = if last_group == group { tccd_l } else { tccd_s };
            start = start.max(last_start + gap);
        }
        // Periodic all-bank refresh: every tREFI window opens with a tRFC
        // blackout during which no command issues; crossing a window closes
        // every row (refresh precharges the whole bank). Window 0 is exempt:
        // the simulation starts right after the initialization refresh.
        let epoch = (start / self.trefi) as u64;
        let bank = &mut chan.banks[bank_idx];
        if epoch > bank.refresh_epoch {
            bank.refresh_epoch = epoch;
            bank.open_row = u64::MAX;
        }
        if epoch > 0 {
            let refresh_end = epoch as f64 * self.trefi + self.trfc;
            if start < refresh_end {
                chan.refresh_stalls += 1;
                start = refresh_end;
            }
        }

        // Row-buffer state machine: hit / empty miss / conflict.
        let row_hit = bank.open_row == row;
        let latency = if row_hit {
            chan.row_hits += 1;
            self.tcl
        } else if bank.open_row == u64::MAX {
            chan.row_misses += 1;
            bank.open_row = row;
            self.trcd + self.tcl
        } else {
            chan.row_conflicts += 1;
            bank.open_row = row;
            self.trp + self.trcd + self.tcl
        };

        let occupancy = bytes as f64 / self.bytes_per_cycle;
        // The bank is tied up for its row cycle; the shared data bus only
        // for the burst, which is what lets other banks overlap.
        bank.ready = start + latency;
        chan.bus_free = start + occupancy;
        chan.last_cmd = Some((group, start));
        chan.bytes_served += bytes;
        DramResult {
            done: start + occupancy + latency,
            row_hit,
        }
    }

    fn earliest_free(&self) -> f64 {
        self.channels
            .iter()
            .map(|c| c.bus_free)
            .fold(f64::INFINITY, f64::min)
    }

    fn stats(&self) -> MemStats {
        let mut s = MemStats::default();
        for c in &self.channels {
            s.bytes_served += c.bytes_served;
            s.row_hits += c.row_hits;
            s.row_misses += c.row_misses;
            s.row_conflicts += c.row_conflicts;
            s.refresh_stalls += c.refresh_stalls;
        }
        s
    }

    fn kind(&self) -> MemBackendKind {
        MemBackendKind::BankLevel
    }
}

// ---------------------------------------------------------------------------
// protocol: JEDEC-style command-legality checking for CycleAccurate.
// ---------------------------------------------------------------------------

pub mod protocol {
    //! Streaming legality checker for the command sequences
    //! [`super::CycleAccurate`] emits.
    //!
    //! The checker replays every ACT/PRE/RD/WR against the JEDEC-style
    //! timing constraints and the per-bank row state machine, fully
    //! independently of the backend's scheduler: it shares only the pure
    //! helpers in this module ([`refresh_epoch`], [`blackout_end`],
    //! [`auto_pre_ready`]) that *define* the protocol, never the code that
    //! schedules against it. In debug/test builds the backend feeds it
    //! every command it issues and panics on the first violation, so a
    //! scheduling bug fails loudly instead of skewing results.

    use crate::config::SystemConfig;

    /// Comparison slack for timing inequalities. The backend and checker
    /// compute bounds from the same f64 command times, so exact
    /// comparisons would work; the epsilon guards against reassociated
    /// arithmetic under future refactors.
    const EPS: f64 = 1e-9;

    /// Geometry and timing parameters, all times in SM cycles.
    #[derive(Clone, Copy, Debug, PartialEq)]
    pub struct Params {
        /// Channels per stack (power of two).
        pub channels: usize,
        /// Ranks per channel.
        pub ranks: usize,
        /// Banks per channel (power of two).
        pub banks: usize,
        /// Bank groups per channel (group = bank % groups).
        pub bank_groups: usize,
        /// Row-to-column delay (ACT -> RD/WR).
        pub trcd: f64,
        /// Precharge time (PRE -> ACT).
        pub trp: f64,
        /// Minimum row-active time (ACT -> PRE).
        pub tras: f64,
        /// ACT-to-ACT gap between banks of one rank.
        pub trrd: f64,
        /// Four-activate window per rank.
        pub tfaw: f64,
        /// Column-command gap within one bank group.
        pub tccd_l: f64,
        /// Column-command gap across bank groups.
        pub tccd_s: f64,
        /// Refresh interval.
        pub trefi: f64,
        /// Refresh blackout length.
        pub trfc: f64,
        /// Command-bus gap between consecutive commands on one channel.
        pub cmd_gap: f64,
    }

    impl Params {
        /// Derive parameters from a system config, matching
        /// [`super::CycleAccurate::new`]'s geometry bit-for-bit (same
        /// `next_power_of_two` rounding, same cycle conversion).
        pub fn from_config(cfg: &SystemConfig) -> Self {
            let n_chan = cfg.channels_per_stack.next_power_of_two();
            let n_banks = cfg.banks_per_channel.next_power_of_two();
            let cyc = cfg.cycles_per_ns();
            Self {
                channels: n_chan,
                ranks: cfg.dram_ranks_per_channel.min(n_banks),
                banks: n_banks,
                bank_groups: cfg.bank_groups_per_channel.min(n_banks),
                trcd: cfg.dram_trcd_ns * cyc,
                trp: cfg.dram_trp_ns * cyc,
                tras: cfg.dram_tras_ns * cyc,
                trrd: cfg.dram_trrd_ns * cyc,
                tfaw: cfg.dram_tfaw_ns * cyc,
                tccd_l: cfg.dram_tccd_l_ns * cyc,
                tccd_s: cfg.dram_tccd_s_ns * cyc,
                trefi: cfg.dram_trefi_ns * cyc,
                trfc: cfg.dram_trfc_ns * cyc,
                cmd_gap: 1.0,
            }
        }

        /// Refresh stagger offset of rank `r`: rank windows are spread
        /// evenly across one tREFI.
        pub fn rank_offset(&self, rank: usize) -> f64 {
            rank as f64 * self.trefi / self.ranks as f64
        }
    }

    /// Refresh window index at time `t` for a rank whose windows start at
    /// `offset + k * trefi`. Window 0 is exempt from the blackout (the
    /// simulation starts right after the initialization refresh).
    pub fn refresh_epoch(trefi: f64, offset: f64, t: f64) -> u64 {
        if t <= offset {
            0
        } else {
            ((t - offset) / trefi) as u64
        }
    }

    /// End of window `epoch`'s tRFC blackout.
    pub fn blackout_end(trefi: f64, trfc: f64, offset: f64, epoch: u64) -> f64 {
        offset + epoch as f64 * trefi + trfc
    }

    /// Earliest next ACT after an auto-precharging column command at
    /// `t_col` on a row activated at `act_at`: the internal precharge may
    /// not start before tRAS is satisfied.
    pub fn auto_pre_ready(t_col: f64, act_at: f64, tras: f64, trp: f64) -> f64 {
        t_col.max(act_at + tras) + trp
    }

    /// One DRAM command as the backend emitted it.
    #[derive(Clone, Copy, Debug, PartialEq)]
    pub struct Command {
        /// Issue time (SM cycles).
        pub time: f64,
        pub channel: usize,
        pub bank: usize,
        pub kind: CmdKind,
    }

    #[derive(Clone, Copy, Debug, PartialEq)]
    pub enum CmdKind {
        /// Activate `row` on the bank.
        Act { row: u64 },
        /// Explicit precharge.
        Pre,
        /// Column read; `auto` = auto-precharge (RDA).
        Rd { row: u64, auto: bool },
        /// Column write; `auto` = auto-precharge (WRA).
        Wr { row: u64, auto: bool },
    }

    /// Why a command sequence is illegal.
    #[derive(Clone, Debug, PartialEq)]
    pub enum Violation {
        BadIndex { channel: usize, bank: usize },
        NonMonotone { at: f64, prev: f64 },
        RefreshBlackout { at: f64, until: f64 },
        ActOnOpenBank { at: f64 },
        ActBeforePrecharge { at: f64, ready: f64 },
        ActBeforeTrrd { at: f64, need: f64 },
        ActBeforeTfaw { at: f64, need: f64 },
        PreOnClosedBank { at: f64 },
        PreBeforeTras { at: f64, need: f64 },
        ColOnClosedBank { at: f64 },
        ColRowMismatch { at: f64, open: u64, want: u64 },
        ColBeforeTrcd { at: f64, need: f64 },
        ColBeforeCcd { at: f64, need: f64 },
    }

    impl std::fmt::Display for Violation {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            match self {
                Self::BadIndex { channel, bank } => {
                    write!(f, "command addresses channel {channel} bank {bank} out of range")
                }
                Self::NonMonotone { at, prev } => {
                    write!(f, "command at {at} violates the channel command bus (prev {prev})")
                }
                Self::RefreshBlackout { at, until } => {
                    write!(f, "command at {at} inside a refresh blackout ending {until}")
                }
                Self::ActOnOpenBank { at } => write!(f, "ACT at {at} on an open bank"),
                Self::ActBeforePrecharge { at, ready } => {
                    write!(f, "ACT at {at} before precharge completes at {ready}")
                }
                Self::ActBeforeTrrd { at, need } => {
                    write!(f, "ACT at {at} violates tRRD (earliest {need})")
                }
                Self::ActBeforeTfaw { at, need } => {
                    write!(f, "ACT at {at} violates tFAW (earliest {need})")
                }
                Self::PreOnClosedBank { at } => write!(f, "PRE at {at} on a closed bank"),
                Self::PreBeforeTras { at, need } => {
                    write!(f, "PRE at {at} violates tRAS (earliest {need})")
                }
                Self::ColOnClosedBank { at } => {
                    write!(f, "column command at {at} on a closed bank")
                }
                Self::ColRowMismatch { at, open, want } => {
                    write!(f, "column command at {at} to row {want} but row {open} is open")
                }
                Self::ColBeforeTrcd { at, need } => {
                    write!(f, "column command at {at} violates tRCD (earliest {need})")
                }
                Self::ColBeforeCcd { at, need } => {
                    write!(f, "column command at {at} violates tCCD (earliest {need})")
                }
            }
        }
    }

    #[derive(Clone, Debug)]
    struct CkBank {
        open_row: u64,
        act_at: f64,
        pre_ready: f64,
        epoch: u64,
    }

    #[derive(Clone, Debug)]
    struct CkRank {
        last_act: f64,
        /// Ring of the last four ACT times (tFAW window).
        faw: [f64; 4],
        faw_idx: usize,
    }

    #[derive(Clone, Debug)]
    struct CkChannel {
        last_time: Option<f64>,
        last_col: Option<(usize, f64)>,
        banks: Vec<CkBank>,
        ranks: Vec<CkRank>,
    }

    /// Streaming checker: feed it every command, in per-channel issue
    /// order, via [`Checker::check`].
    #[derive(Clone, Debug)]
    pub struct Checker {
        p: Params,
        channels: Vec<CkChannel>,
        /// Commands vetted so far (diagnostics; proves the checker ran).
        pub checked: u64,
    }

    impl Checker {
        pub fn new(p: Params) -> Self {
            let banks_per_rank = p.banks / p.ranks;
            debug_assert!(banks_per_rank * p.ranks == p.banks);
            Self {
                p,
                channels: vec![
                    CkChannel {
                        last_time: None,
                        last_col: None,
                        banks: vec![
                            CkBank {
                                open_row: u64::MAX,
                                act_at: f64::NEG_INFINITY,
                                pre_ready: 0.0,
                                epoch: 0,
                            };
                            p.banks
                        ],
                        ranks: vec![
                            CkRank {
                                last_act: f64::NEG_INFINITY,
                                faw: [f64::NEG_INFINITY; 4],
                                faw_idx: 0,
                            };
                            p.ranks
                        ],
                    };
                    p.channels
                ],
                checked: 0,
            }
        }

        /// Validate one command and advance the reference state machine.
        pub fn check(&mut self, cmd: Command) -> Result<(), Violation> {
            let p = self.p;
            if cmd.channel >= self.channels.len() || cmd.bank >= p.banks {
                return Err(Violation::BadIndex {
                    channel: cmd.channel,
                    bank: cmd.bank,
                });
            }
            let rank_idx = cmd.bank / (p.banks / p.ranks);
            let group = cmd.bank % p.bank_groups;
            let offset = p.rank_offset(rank_idx);
            let t = cmd.time;
            let ch = &mut self.channels[cmd.channel];
            if let Some(prev) = ch.last_time {
                if t < prev + p.cmd_gap - EPS {
                    return Err(Violation::NonMonotone { at: t, prev });
                }
            }
            // Refresh: crossing a window boundary closes the bank's row
            // (all-bank refresh precharges), and no command may issue
            // inside the window-opening tRFC blackout.
            let e = refresh_epoch(p.trefi, offset, t);
            if e > ch.banks[cmd.bank].epoch {
                ch.banks[cmd.bank].epoch = e;
                ch.banks[cmd.bank].open_row = u64::MAX;
            }
            if e > 0 {
                let until = blackout_end(p.trefi, p.trfc, offset, e);
                if t < until - EPS {
                    return Err(Violation::RefreshBlackout { at: t, until });
                }
            }
            match cmd.kind {
                CmdKind::Act { row } => {
                    let bank = &ch.banks[cmd.bank];
                    if bank.open_row != u64::MAX {
                        return Err(Violation::ActOnOpenBank { at: t });
                    }
                    if t < bank.pre_ready - EPS {
                        return Err(Violation::ActBeforePrecharge {
                            at: t,
                            ready: bank.pre_ready,
                        });
                    }
                    let rank = &ch.ranks[rank_idx];
                    let trrd_gate = rank.last_act + p.trrd;
                    if t < trrd_gate - EPS {
                        return Err(Violation::ActBeforeTrrd { at: t, need: trrd_gate });
                    }
                    // The oldest entry in the 4-slot ring is the ACT four
                    // activates ago: a fifth ACT within tFAW of it is illegal.
                    let faw_gate = rank.faw[rank.faw_idx] + p.tfaw;
                    if t < faw_gate - EPS {
                        return Err(Violation::ActBeforeTfaw { at: t, need: faw_gate });
                    }
                    let bank = &mut ch.banks[cmd.bank];
                    bank.open_row = row;
                    bank.act_at = t;
                    let rank = &mut ch.ranks[rank_idx];
                    rank.last_act = t;
                    rank.faw[rank.faw_idx] = t;
                    rank.faw_idx = (rank.faw_idx + 1) % 4;
                }
                CmdKind::Pre => {
                    let bank = &ch.banks[cmd.bank];
                    if bank.open_row == u64::MAX {
                        return Err(Violation::PreOnClosedBank { at: t });
                    }
                    let tras_gate = bank.act_at + p.tras;
                    if t < tras_gate - EPS {
                        return Err(Violation::PreBeforeTras { at: t, need: tras_gate });
                    }
                    let bank = &mut ch.banks[cmd.bank];
                    bank.open_row = u64::MAX;
                    bank.pre_ready = t + p.trp;
                }
                CmdKind::Rd { row, auto } | CmdKind::Wr { row, auto } => {
                    let bank = &ch.banks[cmd.bank];
                    if bank.open_row == u64::MAX {
                        return Err(Violation::ColOnClosedBank { at: t });
                    }
                    if bank.open_row != row {
                        return Err(Violation::ColRowMismatch {
                            at: t,
                            open: bank.open_row,
                            want: row,
                        });
                    }
                    let trcd_gate = bank.act_at + p.trcd;
                    if t < trcd_gate - EPS {
                        return Err(Violation::ColBeforeTrcd { at: t, need: trcd_gate });
                    }
                    if let Some((g, lt)) = ch.last_col {
                        let gap = if g == group { p.tccd_l } else { p.tccd_s };
                        if t < lt + gap - EPS {
                            return Err(Violation::ColBeforeCcd { at: t, need: lt + gap });
                        }
                    }
                    ch.last_col = Some((group, t));
                    if auto {
                        let act_at = ch.banks[cmd.bank].act_at;
                        let bank = &mut ch.banks[cmd.bank];
                        bank.open_row = u64::MAX;
                        bank.pre_ready = auto_pre_ready(t, act_at, p.tras, p.trp);
                    }
                }
            }
            ch.last_time = Some(t);
            self.checked += 1;
            Ok(())
        }
    }
}

// ---------------------------------------------------------------------------
// CycleAccurate: explicit command scheduling, FR-FCFS write drain, checker.
// ---------------------------------------------------------------------------

/// Command-bus gap between consecutive commands on one channel (cycles).
const CMD_GAP: f64 = 1.0;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum RowOutcome {
    Hit,
    Miss,
    Conflict,
}

#[derive(Clone, Debug)]
struct CycBank {
    /// Currently open row; u64::MAX = precharged (closed).
    open_row: u64,
    /// Issue time of the ACT that opened the current/last row.
    act_at: f64,
    /// Earliest time the next ACT may issue (precharge completion).
    pre_ready: f64,
    /// Last refresh window this bank observed.
    refresh_epoch: u64,
}

#[derive(Clone, Debug)]
struct CycRank {
    last_act: f64,
    /// Ring of the last four ACT times (tFAW window).
    faw: [f64; 4],
    faw_idx: usize,
}

#[derive(Clone, Debug)]
struct PendingWrite {
    arrival: f64,
    bank: usize,
    row: u64,
    bytes: u64,
}

#[derive(Clone, Debug)]
struct CycChannel {
    banks: Vec<CycBank>,
    ranks: Vec<CycRank>,
    /// Command-bus time: the next command issues at or after this.
    clock: f64,
    /// Data-bus busy-until time.
    bus_free: f64,
    /// Last column command: (bank group, issue time).
    last_col: Option<(usize, f64)>,
    /// Posted writes awaiting an FR-FCFS drain.
    wq: Vec<PendingWrite>,
    bytes_served: u64,
    row_hits: u64,
    row_misses: u64,
    row_conflicts: u64,
    refresh_stalls: u64,
    acts: u64,
    precharges: u64,
    wq_stalls: u64,
    faw_stalls: u64,
}

/// Timing/geometry bundle shared by the scheduler's free functions (kept
/// separate from the channel array so the borrow checker can split them).
#[derive(Clone, Debug)]
struct CycTiming {
    p: protocol::Params,
    tcl: f64,
    age_cap: f64,
    closed: bool,
    wq_high: usize,
    wq_low: usize,
    banks_per_rank: usize,
    bytes_per_cycle: f64,
}

/// Cycle-accurate DRAM timing: every access becomes an explicit
/// ACT/PRE/RD/WR command sequence scheduled against the full JEDEC-style
/// constraint set, with FR-FCFS posted-write draining, per-rank staggered
/// refresh and a configurable row policy.
///
/// Reads execute immediately (execute-once-and-stall: the call mutates
/// state and returns the completion time); writes are posted into a
/// per-channel queue and drained in FR-FCFS order — overdue writes
/// (older than `dram_age_cap_ns`) first, then row hits oldest-first,
/// then the oldest — when the high watermark forces a drain to the low
/// watermark or the aging cap fires. A forced drain stalls the requester
/// (`wq_stalls`). Write bytes are counted when posted, so byte totals
/// close even if the run ends with writes still queued (those never get
/// row-state classification).
///
/// In debug/test builds every emitted command is replayed through
/// [`protocol::Checker`]; a violation panics with the offending command.
#[derive(Clone, Debug)]
pub struct CycleAccurate {
    channels: Vec<CycChannel>,
    chan_shift: u32,
    chan_mask: u64,
    bank_shift: u32,
    bank_mask: u64,
    row_shift: u32,
    tim: CycTiming,
    checker: Option<protocol::Checker>,
    trace: Option<Vec<protocol::Command>>,
}

/// Schedule and commit the command sequence for one line transfer.
/// `count_bytes` is false when draining a posted write whose bytes were
/// already counted at accept time.
#[allow(clippy::too_many_arguments)]
fn cyc_serve(
    tim: &CycTiming,
    chan: &mut CycChannel,
    checker: &mut Option<protocol::Checker>,
    trace: &mut Option<Vec<protocol::Command>>,
    chan_idx: usize,
    now: f64,
    bank_idx: usize,
    row: u64,
    bytes: u64,
    write: bool,
    count_bytes: bool,
) -> DramResult {
    use protocol::{blackout_end, refresh_epoch};
    let p = &tim.p;
    let group = bank_idx % p.bank_groups;
    let rank_idx = bank_idx / tim.banks_per_rank;
    let offset = p.rank_offset(rank_idx);
    // Push a candidate command time out of its window's tRFC blackout
    // (tRFC < tREFI keeps the result inside the same window).
    let clear = |t: f64| -> f64 {
        let e = refresh_epoch(p.trefi, offset, t);
        if e == 0 {
            return t;
        }
        let end = blackout_end(p.trefi, p.trfc, offset, e);
        if t < end {
            end
        } else {
            t
        }
    };

    let mut floor = now.max(chan.clock);
    let mut refresh_stall = false;
    // The whole sequence is scheduled as pure arithmetic and committed
    // only once every command lands in the epoch the access was
    // classified under — a refresh boundary mid-sequence would have
    // closed the row underneath a PRE or column command.
    let (epoch, t_pre, t_act, t_col, outcome, faw_stall) = loop {
        let start = clear(floor);
        if start > floor {
            refresh_stall = true;
        }
        let e = refresh_epoch(p.trefi, offset, start);
        let bank = &chan.banks[bank_idx];
        // Effective bank state at epoch `e`: crossing a window closes the
        // row, and the bank is unavailable through the blackout.
        let crossed = e > bank.refresh_epoch;
        let open_row = if crossed { u64::MAX } else { bank.open_row };
        let pre_ready = if crossed {
            bank.pre_ready.max(blackout_end(p.trefi, p.trfc, offset, e))
        } else {
            bank.pre_ready
        };
        let hit = !tim.closed && open_row == row;
        let conflict = !tim.closed && !hit && open_row != u64::MAX;
        let mut cursor = start;
        // Explicit PRE closes a conflicting row (tRAS-gated).
        let t_pre = if conflict {
            let t = clear(cursor.max(bank.act_at + p.tras));
            cursor = t + CMD_GAP;
            Some(t)
        } else {
            None
        };
        // ACT opens the target row; the closed policy re-activates on
        // every access. Gated by precharge completion, tRRD, and tFAW.
        let mut faw_stall = false;
        let t_act = if !hit {
            let ready = t_pre.map_or(pre_ready, |tp| tp + p.trp);
            let rank = &chan.ranks[rank_idx];
            let base = cursor.max(ready).max(rank.last_act + p.trrd);
            let faw_gate = rank.faw[rank.faw_idx] + p.tfaw;
            faw_stall = faw_gate > base;
            let t = clear(base.max(faw_gate));
            cursor = t + CMD_GAP;
            Some(t)
        } else {
            None
        };
        let act_at = t_act.unwrap_or(bank.act_at);
        // Column command: tRCD after the activate, tCCD_L/S after the
        // channel's previous column command.
        let mut col = cursor.max(act_at + p.trcd);
        if let Some((g, lt)) = chan.last_col {
            let gap = if g == group { p.tccd_l } else { p.tccd_s };
            col = col.max(lt + gap);
        }
        let t_col = clear(col);
        if refresh_epoch(p.trefi, offset, t_col) > e {
            // Reschedule the whole sequence past the boundary it straddled.
            floor = offset + refresh_epoch(p.trefi, offset, t_col) as f64 * p.trefi;
            refresh_stall = true;
            continue;
        }
        let outcome = if hit {
            RowOutcome::Hit
        } else if conflict {
            RowOutcome::Conflict
        } else {
            RowOutcome::Miss
        };
        break (e, t_pre, t_act, t_col, outcome, faw_stall);
    };

    // Commit: emit the commands (checker + optional trace), then fold the
    // schedule back into bank/rank/channel state.
    let auto = tim.closed;
    let mut emit = |t: f64, kind: protocol::CmdKind| {
        let cmd = protocol::Command {
            time: t,
            channel: chan_idx,
            bank: bank_idx,
            kind,
        };
        if let Some(ck) = checker.as_mut() {
            if let Err(v) = ck.check(cmd) {
                panic!("DRAM protocol violation: {v} (cmd {cmd:?})");
            }
        }
        if let Some(tr) = trace.as_mut() {
            tr.push(cmd);
        }
    };
    if let Some(tp) = t_pre {
        emit(tp, protocol::CmdKind::Pre);
    }
    if let Some(ta) = t_act {
        emit(ta, protocol::CmdKind::Act { row });
    }
    emit(
        t_col,
        if write {
            protocol::CmdKind::Wr { row, auto }
        } else {
            protocol::CmdKind::Rd { row, auto }
        },
    );

    let act_at = t_act.unwrap_or(chan.banks[bank_idx].act_at);
    let bank = &mut chan.banks[bank_idx];
    bank.refresh_epoch = epoch.max(bank.refresh_epoch);
    bank.act_at = act_at;
    if tim.closed {
        bank.open_row = u64::MAX;
        bank.pre_ready = protocol::auto_pre_ready(t_col, act_at, p.tras, p.trp);
    } else {
        bank.open_row = row;
        if let Some(tp) = t_pre {
            bank.pre_ready = tp + p.trp;
        }
    }
    if let Some(ta) = t_act {
        let rank = &mut chan.ranks[rank_idx];
        rank.last_act = ta;
        rank.faw[rank.faw_idx] = ta;
        rank.faw_idx = (rank.faw_idx + 1) % 4;
        chan.acts += 1;
    }
    if t_pre.is_some() || tim.closed {
        chan.precharges += 1;
    }
    if faw_stall {
        chan.faw_stalls += 1;
    }
    if refresh_stall {
        chan.refresh_stalls += 1;
    }
    match outcome {
        RowOutcome::Hit => chan.row_hits += 1,
        RowOutcome::Miss => chan.row_misses += 1,
        RowOutcome::Conflict => chan.row_conflicts += 1,
    }
    chan.last_col = Some((group, t_col));
    chan.clock = t_col + CMD_GAP;
    if count_bytes {
        chan.bytes_served += bytes;
    }
    let data_start = (t_col + tim.tcl).max(chan.bus_free);
    let occupancy = bytes as f64 / tim.bytes_per_cycle;
    chan.bus_free = data_start + occupancy;
    DramResult {
        done: data_start + occupancy,
        row_hit: outcome == RowOutcome::Hit,
    }
}

/// Drain one posted write in FR-FCFS order: overdue (older than the aging
/// cap) oldest first, then row hits oldest first, then the oldest.
fn cyc_drain_one(
    tim: &CycTiming,
    chan: &mut CycChannel,
    checker: &mut Option<protocol::Checker>,
    trace: &mut Option<Vec<protocol::Command>>,
    chan_idx: usize,
    now: f64,
) -> DramResult {
    let mut best = 0usize;
    let mut best_key = (u8::MAX, f64::INFINITY);
    for (i, w) in chan.wq.iter().enumerate() {
        let overdue = w.arrival <= now - tim.age_cap;
        let row_hit = chan.banks[w.bank].open_row == w.row;
        let class = if overdue {
            0
        } else if row_hit {
            1
        } else {
            2
        };
        if class < best_key.0 || (class == best_key.0 && w.arrival < best_key.1) {
            best = i;
            best_key = (class, w.arrival);
        }
    }
    let w = chan.wq.remove(best);
    // A write can only be serviced once it has arrived; `now` may lag the
    // arrival because request streams interleave non-monotonically.
    let t = now.max(w.arrival);
    cyc_serve(
        tim, chan, checker, trace, chan_idx, t, w.bank, w.row, w.bytes, true, false,
    )
}

impl CycleAccurate {
    pub fn new(cfg: &SystemConfig) -> Self {
        let p = protocol::Params::from_config(cfg);
        let per_chan_bw = cfg.gbs_to_bytes_per_cycle(cfg.local_bw_gbs) / p.channels as f64;
        let cyc = cfg.cycles_per_ns();
        let tim = CycTiming {
            p,
            tcl: cfg.dram_tcl_ns * cyc,
            age_cap: cfg.dram_age_cap_ns * cyc,
            closed: cfg.dram_row_policy == crate::config::DramRowPolicy::Closed,
            wq_high: cfg.dram_wq_high,
            wq_low: cfg.dram_wq_low,
            banks_per_rank: p.banks / p.ranks,
            bytes_per_cycle: per_chan_bw,
        };
        Self {
            channels: vec![
                CycChannel {
                    banks: vec![
                        CycBank {
                            open_row: u64::MAX,
                            act_at: f64::NEG_INFINITY,
                            pre_ready: 0.0,
                            refresh_epoch: 0,
                        };
                        p.banks
                    ],
                    ranks: vec![
                        CycRank {
                            last_act: f64::NEG_INFINITY,
                            faw: [f64::NEG_INFINITY; 4],
                            faw_idx: 0,
                        };
                        p.ranks
                    ],
                    clock: 0.0,
                    bus_free: 0.0,
                    last_col: None,
                    wq: Vec::new(),
                    bytes_served: 0,
                    row_hits: 0,
                    row_misses: 0,
                    row_conflicts: 0,
                    refresh_stalls: 0,
                    acts: 0,
                    precharges: 0,
                    wq_stalls: 0,
                    faw_stalls: 0,
                };
                p.channels
            ],
            // Bit-for-bit the BankLevel/FixedLatency decode: channel bits
            // right above the line bits, bank bits above those, row = the
            // row_size-aligned frame (tests/dram_props.rs pins this).
            chan_shift: cfg.line_size.trailing_zeros(),
            chan_mask: p.channels as u64 - 1,
            bank_shift: cfg.line_size.trailing_zeros() + (p.channels as u64).trailing_zeros(),
            bank_mask: p.banks as u64 - 1,
            row_shift: cfg.row_size.trailing_zeros(),
            tim,
            // The legality checker rides along on every debug/test-profile
            // simulation; release builds drop it for speed.
            checker: if cfg!(debug_assertions) {
                Some(protocol::Checker::new(p))
            } else {
                None
            },
            trace: None,
        }
    }

    #[inline]
    fn decode(&self, addr: u64) -> (usize, usize, u64) {
        (
            ((addr >> self.chan_shift) & self.chan_mask) as usize,
            ((addr >> self.bank_shift) & self.bank_mask) as usize,
            addr >> self.row_shift,
        )
    }

    /// Execute one access. Reads stall until data returns; writes post
    /// into the channel's queue (and stall only on a forced drain).
    fn do_access(&mut self, now: f64, addr: u64, bytes: u64, write: bool) -> DramResult {
        // Aging sweep across every channel: no posted write may starve
        // past the cap no matter which channel this access targets.
        let cap = self.tim.age_cap;
        for ci in 0..self.channels.len() {
            while self.channels[ci]
                .wq
                .iter()
                .any(|w| w.arrival <= now - cap)
            {
                cyc_drain_one(
                    &self.tim,
                    &mut self.channels[ci],
                    &mut self.checker,
                    &mut self.trace,
                    ci,
                    now,
                );
            }
        }
        let (ci, bank, row) = self.decode(addr);
        if write {
            // Posted write: count bytes at accept so totals close even if
            // the run ends with writes still queued.
            self.channels[ci].wq.push(PendingWrite {
                arrival: now,
                bank,
                row,
                bytes,
            });
            self.channels[ci].bytes_served += bytes;
            if self.channels[ci].wq.len() >= self.tim.wq_high {
                // High watermark: drain to the low watermark, stalling the
                // requester for the duration.
                self.channels[ci].wq_stalls += 1;
                let mut end = now;
                while self.channels[ci].wq.len() > self.tim.wq_low {
                    let r = cyc_drain_one(
                        &self.tim,
                        &mut self.channels[ci],
                        &mut self.checker,
                        &mut self.trace,
                        ci,
                        now,
                    );
                    end = end.max(r.done);
                }
                return DramResult {
                    done: end,
                    row_hit: false,
                };
            }
            return DramResult {
                done: now,
                row_hit: false,
            };
        }
        cyc_serve(
            &self.tim,
            &mut self.channels[ci],
            &mut self.checker,
            &mut self.trace,
            ci,
            now,
            bank,
            row,
            bytes,
            false,
            true,
        )
    }

    /// Age of the oldest posted write still queued, measured at `now`
    /// (0.0 when the queues are empty). Test hook for the FR-FCFS
    /// starvation bound: after any access at `now`, this never exceeds
    /// the aging cap.
    pub fn max_queued_write_age(&self, now: f64) -> f64 {
        self.channels
            .iter()
            .flat_map(|c| c.wq.iter())
            .map(|w| (now - w.arrival).max(0.0))
            .fold(0.0, f64::max)
    }

    /// Record every subsequently emitted command (test hook: replay the
    /// trace through a fresh [`protocol::Checker`]).
    pub fn enable_recording(&mut self) {
        self.trace = Some(Vec::new());
    }

    /// Commands recorded since [`Self::enable_recording`].
    pub fn recorded(&self) -> &[protocol::Command] {
        self.trace.as_deref().unwrap_or(&[])
    }

    /// Commands vetted by the built-in checker (0 in release builds,
    /// where the checker is compiled out).
    pub fn commands_checked(&self) -> u64 {
        self.checker.as_ref().map_or(0, |c| c.checked)
    }

    /// The checker/scheduler parameter bundle (test hook: build an
    /// independent [`protocol::Checker`] with identical geometry).
    pub fn protocol_params(&self) -> protocol::Params {
        self.tim.p
    }
}

impl MemBackend for CycleAccurate {
    fn access(&mut self, now: f64, addr: u64, bytes: u64) -> DramResult {
        self.do_access(now, addr, bytes, false)
    }

    fn earliest_free(&self) -> f64 {
        self.channels
            .iter()
            .map(|c| c.bus_free)
            .fold(f64::INFINITY, f64::min)
    }

    fn stats(&self) -> MemStats {
        let mut s = MemStats::default();
        for c in &self.channels {
            s.bytes_served += c.bytes_served;
            s.row_hits += c.row_hits;
            s.row_misses += c.row_misses;
            s.row_conflicts += c.row_conflicts;
            s.refresh_stalls += c.refresh_stalls;
            s.acts += c.acts;
            s.precharges += c.precharges;
            s.wq_stalls += c.wq_stalls;
            s.faw_stalls += c.faw_stalls;
        }
        s
    }

    fn kind(&self) -> MemBackendKind {
        MemBackendKind::CycleAccurate
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> SystemConfig {
        SystemConfig::default()
    }

    fn bank_cfg() -> SystemConfig {
        let mut c = cfg();
        c.mem_backend = MemBackendKind::BankLevel;
        c
    }

    fn cycle_cfg() -> SystemConfig {
        let mut c = cfg();
        c.mem_backend = MemBackendKind::CycleAccurate;
        c
    }

    #[test]
    fn row_hit_is_faster_than_miss() {
        let mut hbm = FixedLatency::new(&cfg());
        let first = hbm.access(0.0, 0, 128);
        assert!(!first.row_hit);
        let second = hbm.access(first.done, 0, 128);
        assert!(second.row_hit);
        let miss_lat = first.done;
        let hit_lat = second.done - first.done;
        assert!(hit_lat < miss_lat);
    }

    #[test]
    fn consecutive_lines_spread_across_channels() {
        let c = cfg();
        let mut hbm = FixedLatency::new(&c);
        // 8 consecutive lines hit 8 distinct channels -> no queuing: all
        // complete at the same time.
        let times: Vec<f64> = (0..8).map(|i| hbm.access(0.0, i * 128, 128).done).collect();
        assert!(times.windows(2).all(|w| (w[0] - w[1]).abs() < 1e-9));
    }

    #[test]
    fn same_channel_requests_queue() {
        let c = cfg();
        let mut hbm = FixedLatency::new(&c);
        let stride = 128 * c.channels_per_stack as u64; // same channel
        let t1 = hbm.access(0.0, 0, 128).done;
        let t2 = hbm.access(0.0, stride * 16, 128).done; // different row too
        assert!(t2 > t1, "second access must queue behind the first");
    }

    #[test]
    fn aggregate_bandwidth_matches_config() {
        let c = cfg();
        let mut hbm = FixedLatency::new(&c);
        // Saturate all channels with back-to-back row hits and measure.
        let mut done: f64 = 0.0;
        let n = 4096u64;
        for i in 0..n {
            let r = hbm.access(0.0, (i % 64) * 128, 128);
            done = done.max(r.done);
        }
        let bytes = (n * 128) as f64;
        let achieved = bytes / done; // bytes per cycle
        let peak = c.gbs_to_bytes_per_cycle(c.local_bw_gbs);
        assert!(
            achieved > 0.5 * peak && achieved <= peak * 1.01,
            "achieved {achieved:.1} vs peak {peak:.1} B/cy"
        );
    }

    #[test]
    fn stats_accumulate() {
        let mut hbm = FixedLatency::new(&cfg());
        for i in 0..100u64 {
            hbm.access(i as f64, i * 128, 128);
        }
        assert_eq!(hbm.bytes_served(), 12800);
        assert!(hbm.row_hit_rate() >= 0.0);
        assert!(hbm.peak_channel_util(1000.0) > 0.0);
    }

    #[test]
    fn factory_dispatches_on_config() {
        let c = cfg();
        assert_eq!(make_backend(&c).kind(), MemBackendKind::FixedLatency);
        assert_eq!(make_backend(&bank_cfg()).kind(), MemBackendKind::BankLevel);
        assert_eq!(
            make_backend(&cycle_cfg()).kind(),
            MemBackendKind::CycleAccurate
        );
        assert_eq!(make_backends(&c).len(), c.num_stacks);
        assert_eq!(MemBackendImpl::new(&c).kind(), MemBackendKind::FixedLatency);
        assert_eq!(
            MemBackendImpl::new(&bank_cfg()).kind(),
            MemBackendKind::BankLevel
        );
        assert_eq!(
            MemBackendImpl::new(&cycle_cfg()).kind(),
            MemBackendKind::CycleAccurate
        );
        assert_eq!(make_backends_impl(&c).len(), c.num_stacks);
        assert_eq!(
            make_host_ddr_impl(&bank_cfg()).kind(),
            MemBackendKind::BankLevel
        );
        assert_eq!(
            make_host_ddr_impl(&cycle_cfg()).kind(),
            MemBackendKind::CycleAccurate
        );
    }

    /// Enum dispatch is a calling convention, not a model: driving the
    /// boxed and enum forms with the same request stream must produce
    /// bit-identical completion times and counters, for every kind.
    #[test]
    fn enum_dispatch_matches_boxed_dispatch_bit_exactly() {
        for c in [cfg(), bank_cfg(), cycle_cfg()] {
            let mut boxed = make_backend(&c);
            let mut inline = MemBackendImpl::new(&c);
            for i in 0..4096u64 {
                let addr = i.wrapping_mul(0x9E3779B97F4A7C15) & 0xFF_FFFF;
                let now = (i / 8) as f64;
                let a = boxed.access(now, addr, 128);
                let b = inline.access(now, addr, 128);
                assert_eq!(a.done.to_bits(), b.done.to_bits());
                assert_eq!(a.row_hit, b.row_hit);
            }
            assert_eq!(boxed.stats(), inline.stats());
            assert_eq!(
                boxed.earliest_free().to_bits(),
                inline.earliest_free().to_bits()
            );
        }
    }

    #[test]
    fn host_ddr_follows_backend_kind_and_is_slower_than_hbm() {
        let c = cfg();
        assert_eq!(make_host_ddr(&c).kind(), MemBackendKind::FixedLatency);
        assert_eq!(make_host_ddr(&bank_cfg()).kind(), MemBackendKind::BankLevel);
        // Saturating both with the same dense stream, the DDR (64 GB/s, 2
        // channels) must finish later than a stack's HBM (256 GB/s, 8).
        let mut hbm = make_backend(&c);
        let mut ddr = make_host_ddr(&c);
        let (mut t_hbm, mut t_ddr) = (0.0f64, 0.0f64);
        for i in 0..1024u64 {
            t_hbm = t_hbm.max(hbm.access(0.0, i * 128, 128).done);
            t_ddr = t_ddr.max(ddr.access(0.0, i * 128, 128).done);
        }
        assert!(t_ddr > t_hbm, "ddr {t_ddr} must be slower than hbm {t_hbm}");
    }

    // -- BankLevel ----------------------------------------------------------

    /// Same channel + bank, three row states: hit < empty miss < conflict.
    #[test]
    fn bank_level_orders_hit_miss_conflict() {
        let c = bank_cfg();
        let mut m = BankLevel::new(&c);
        // Row stride: one full row within the same bank. Row bits sit above
        // row_size; changing bit row_shift changes the row while the
        // channel/bank bits (low bits) stay 0.
        let row_stride = c.row_size;
        // Empty miss on a precharged bank.
        let miss = m.access(0.0, 0, 128);
        assert!(!miss.row_hit);
        let t0 = miss.done;
        // Hit on the now-open row. (Under the line-interleaved channel
        // layout, the lines of one row spread across channels, so a row hit
        // means re-touching the same line.)
        let hit = m.access(t0, 0, 128);
        assert!(hit.row_hit);
        let hit_lat = hit.done - t0;
        // Conflict: different row, same bank.
        let t1 = hit.done;
        let conf = m.access(t1, row_stride * 64, 128);
        assert!(!conf.row_hit);
        let conf_lat = conf.done - t1;
        let miss_lat = t0;
        assert!(
            hit_lat < miss_lat && miss_lat < conf_lat,
            "hit {hit_lat} < miss {miss_lat} < conflict {conf_lat}"
        );
        let s = m.stats();
        assert_eq!(s.row_hits, 1);
        assert_eq!(s.row_misses, 1);
        assert_eq!(s.row_conflicts, 1);
    }

    /// Two conflicting streams to different banks overlap; to one bank they
    /// serialize on the bank's row cycle.
    #[test]
    fn bank_level_exploits_bank_parallelism() {
        let c = bank_cfg();
        let bank_stride = 128 * (c.channels_per_stack as u64); // next bank, chan 0
        let row_stride = c.row_size * 1024; // far rows -> always conflict

        // Same bank, alternating rows: serial conflicts.
        let mut same = BankLevel::new(&c);
        let mut t_same: f64 = 0.0;
        for i in 0..8u64 {
            t_same = t_same.max(same.access(0.0, (i % 2) * row_stride, 128).done);
        }
        // Different banks, alternating rows per bank: conflicts overlap.
        let mut diff = BankLevel::new(&c);
        let mut t_diff: f64 = 0.0;
        for i in 0..8u64 {
            let addr = (i % 4) * bank_stride + (i % 2) * row_stride;
            t_diff = t_diff.max(diff.access(0.0, addr, 128).done);
        }
        assert!(
            t_diff < t_same,
            "bank-parallel {t_diff} must beat single-bank {t_same}"
        );
    }

    /// Accesses that land inside a refresh window are pushed past it and
    /// counted; rows do not survive a refresh.
    #[test]
    fn bank_level_refresh_blocks_and_closes_rows() {
        let c = bank_cfg();
        let cyc = c.cycles_per_ns();
        let trefi = c.dram_trefi_ns * cyc;
        let trfc = c.dram_trfc_ns * cyc;
        let mut m = BankLevel::new(&c);
        // Open row 0 well before the first refresh boundary.
        let first = m.access(0.0, 0, 128);
        assert!(!first.row_hit);
        // Arrive just inside the second window's blackout.
        let r = m.access(trefi + 1.0, 0, 128);
        assert!(!r.row_hit, "refresh must close the open row");
        assert!(
            r.done >= trefi + trfc,
            "access inside the blackout must wait it out: {} < {}",
            r.done,
            trefi + trfc
        );
        assert_eq!(m.stats().refresh_stalls, 1);
    }

    /// Same-bank-group back-to-back column commands pay tCCD_L > tCCD_S.
    #[test]
    fn bank_level_bank_group_gap() {
        let c = bank_cfg();
        assert!(c.dram_tccd_l_ns > c.dram_tccd_s_ns);
        let bank_stride = 128 * (c.channels_per_stack as u64);
        let groups = c.bank_groups_per_channel as u64;

        // Banks 0 and `groups` share group 0 (group = bank % groups).
        let mut same = BankLevel::new(&c);
        same.access(0.0, 0, 1); // negligible burst: isolates the gap
        let t_same = same.access(0.0, groups * bank_stride, 1).done;

        // Banks 0 and 1 are in different groups.
        let mut diff = BankLevel::new(&c);
        diff.access(0.0, 0, 1);
        let t_diff = diff.access(0.0, bank_stride, 1).done;
        assert!(
            t_same > t_diff,
            "same-group gap {t_same} must exceed cross-group {t_diff}"
        );
    }

    #[test]
    fn bank_level_is_deterministic() {
        let c = bank_cfg();
        let run = || {
            let mut m = BankLevel::new(&c);
            let mut acc = 0.0f64;
            for i in 0..4096u64 {
                let addr = i.wrapping_mul(0x9E3779B97F4A7C15) & 0xFF_FFFF;
                acc += m.access((i / 8) as f64, addr, 128).done;
            }
            (acc, m.stats())
        };
        let (a, sa) = run();
        let (b, sb) = run();
        assert_eq!(a, b);
        assert_eq!(sa, sb);
    }

    #[test]
    fn bank_level_tracks_bytes() {
        let c = bank_cfg();
        let mut m = BankLevel::new(&c);
        for i in 0..64u64 {
            m.access(i as f64 * 10.0, i * 128, 128);
        }
        assert_eq!(m.stats().bytes_served, 64 * 128);
        assert_eq!(
            m.stats().row_hits + m.stats().row_misses + m.stats().row_conflicts,
            64
        );
    }

    // -- CycleAccurate ------------------------------------------------------

    /// Same channel + bank, three row states: hit < empty miss < conflict,
    /// with the per-command counters to match.
    #[test]
    fn cycle_orders_hit_miss_conflict() {
        let c = cycle_cfg();
        let mut m = CycleAccurate::new(&c);
        let row_stride = c.row_size;
        let miss = m.do_access(0.0, 0, 128, false);
        assert!(!miss.row_hit);
        let t0 = miss.done;
        let hit = m.do_access(t0, 0, 128, false);
        assert!(hit.row_hit);
        let hit_lat = hit.done - t0;
        let t1 = hit.done;
        let conf = m.do_access(t1, row_stride * 64, 128, false);
        assert!(!conf.row_hit);
        let conf_lat = conf.done - t1;
        let miss_lat = t0;
        assert!(
            hit_lat < miss_lat && miss_lat < conf_lat,
            "hit {hit_lat} < miss {miss_lat} < conflict {conf_lat}"
        );
        let s = m.stats();
        assert_eq!(s.row_hits, 1);
        assert_eq!(s.row_misses, 1);
        assert_eq!(s.row_conflicts, 1);
        // Two row openings (miss + conflict), one explicit precharge
        // (closing the conflicting row).
        assert_eq!(s.acts, 2);
        assert_eq!(s.precharges, 1);
        assert_eq!(s.faw_stalls, 0);
    }

    /// Write bytes are counted when posted, so byte totals close even
    /// while writes sit in the queue; row classification only ever covers
    /// commands that actually issued.
    #[test]
    fn cycle_counts_posted_write_bytes_at_accept() {
        let c = cycle_cfg();
        let mut m = CycleAccurate::new(&c);
        for i in 0..32u64 {
            m.do_access(i as f64 * 100.0, i * 128, 128, false);
        }
        for i in 0..8u64 {
            let r = m.do_access(3200.0, i * 1024, 128, true);
            assert_eq!(r.done, 3200.0, "posted write must not stall below the mark");
        }
        let s = m.stats();
        assert_eq!(s.bytes_served, 40 * 128);
        assert_eq!(s.row_hits + s.row_misses + s.row_conflicts, 32);
        assert_eq!(s.wq_stalls, 0);
    }

    /// Writes post freely until the high watermark, then one forced drain
    /// stalls the requester and empties the queue down to the low mark.
    #[test]
    fn cycle_write_drain_honors_watermarks() {
        let c = cycle_cfg();
        let mut m = CycleAccurate::new(&c);
        // All writes target channel 0 (addr>>7 & 7 == 0 for 1 KiB strides).
        for i in 0..(c.dram_wq_high as u64 - 1) {
            let r = m.do_access(0.0, i * 1024, 128, true);
            assert_eq!(r.done, 0.0);
        }
        assert_eq!(m.stats().wq_stalls, 0);
        let r = m.do_access(0.0, (c.dram_wq_high as u64 - 1) * 1024, 128, true);
        assert!(r.done > 0.0, "the drain must stall the write that tripped it");
        let s = m.stats();
        assert_eq!(s.wq_stalls, 1);
        assert_eq!(
            m.channels.iter().map(|ch| ch.wq.len()).sum::<usize>(),
            c.dram_wq_low,
            "forced drain stops at the low watermark"
        );
        assert!(s.acts > 0 && s.row_hits + s.row_misses + s.row_conflicts > 0);
    }

    /// The aging sweep drains overdue writes on the next access to *any*
    /// channel, so no posted write outlives the cap unobserved.
    #[test]
    fn cycle_aging_cap_bounds_posted_write_age() {
        let c = cycle_cfg();
        let cap = c.dram_age_cap_ns * c.cycles_per_ns();
        let mut m = CycleAccurate::new(&c);
        m.do_access(0.0, 0, 128, true);
        assert_eq!(m.max_queued_write_age(0.0), 0.0);
        // Next access lands on a different channel well past the cap: the
        // sweep still retires the channel-0 write.
        let later = cap + 1.0;
        m.do_access(later, 7 * 128, 128, false);
        assert!(
            m.max_queued_write_age(later) <= cap,
            "an overdue write survived the aging sweep"
        );
        let s = m.stats();
        assert_eq!(s.wq_stalls, 0, "aging drains are not watermark stalls");
        assert_eq!(s.bytes_served, 2 * 128);
    }

    /// Closed row policy: every access re-activates, every column command
    /// auto-precharges, and nothing ever row-hits.
    #[test]
    fn cycle_closed_policy_reactivates_every_access() {
        let mut c = cycle_cfg();
        c.dram_row_policy = crate::config::DramRowPolicy::Closed;
        let mut m = CycleAccurate::new(&c);
        let mut t = 0.0;
        for _ in 0..8 {
            let r = m.do_access(t, 0, 128, false);
            assert!(!r.row_hit);
            t = r.done;
        }
        let s = m.stats();
        assert_eq!(s.row_hits, 0);
        assert_eq!(s.acts, 8);
        assert_eq!(s.precharges, 8);
    }

    /// Accesses that land inside a refresh blackout are pushed past it and
    /// counted; rows do not survive a refresh window crossing.
    #[test]
    fn cycle_refresh_blackout_defers_and_closes_rows() {
        let c = cycle_cfg();
        let cyc = c.cycles_per_ns();
        let trefi = c.dram_trefi_ns * cyc;
        let trfc = c.dram_trfc_ns * cyc;
        let mut m = CycleAccurate::new(&c);
        let first = m.do_access(0.0, 0, 128, false);
        assert!(!first.row_hit);
        let r = m.do_access(trefi + 1.0, 0, 128, false);
        assert!(!r.row_hit, "refresh must close the open row");
        assert!(
            r.done >= trefi + trfc,
            "access inside the blackout must wait it out: {} < {}",
            r.done,
            trefi + trfc
        );
        assert!(m.stats().refresh_stalls >= 1);
    }

    #[test]
    fn cycle_is_deterministic() {
        let c = cycle_cfg();
        let run = || {
            let mut m = CycleAccurate::new(&c);
            let mut acc = 0.0f64;
            for i in 0..4096u64 {
                let addr = i.wrapping_mul(0x9E3779B97F4A7C15) & 0xFF_FFFF;
                acc += m.do_access((i / 8) as f64, addr, 128, i % 5 == 0).done;
            }
            (acc, m.stats())
        };
        let (a, sa) = run();
        let (b, sb) = run();
        assert_eq!(a, b);
        assert_eq!(sa, sb);
    }

    /// The legality checker rides along on every debug/test-profile
    /// simulation (the tentpole's acceptance criterion); release builds
    /// compile it out.
    #[test]
    fn cycle_checker_vets_every_command_in_debug_builds() {
        let mut m = CycleAccurate::new(&cycle_cfg());
        for i in 0..64u64 {
            m.do_access(i as f64 * 50.0, i * 128, 128, i % 3 == 0);
        }
        if cfg!(debug_assertions) {
            assert!(
                m.commands_checked() >= 40,
                "checker must vet the emitted command stream in test builds"
            );
        } else {
            assert_eq!(m.commands_checked(), 0);
        }
    }
}
