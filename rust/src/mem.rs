//! DRAM timing backends for the HBM stacks.
//!
//! Memory timing is a pluggable subsystem behind the [`MemBackend`] trait;
//! the backend is selected per run from
//! [`SystemConfig::mem_backend`](crate::config::SystemConfig) (CLI:
//! `--mem-backend fixed|bank`). Two backends ship:
//!
//! * [`FixedLatency`] — the original model. Each stack contains
//!   `channels_per_stack` channels; each channel owns `banks_per_channel`
//!   banks with an open-row policy. A request's service time is row-hit or
//!   row-miss latency plus data-transfer occupancy on the channel. Channels
//!   are busy-until servers, which captures the bandwidth contention the
//!   paper's results hinge on (hot stacks queue, spread traffic doesn't).
//!   The paper uses DRAMSim2 configured for HBM 2.0 (8 channels x 32 GB/s
//!   per stack); this model reproduces the same aggregate bandwidth and
//!   row-buffer behaviour far more cheaply (DESIGN.md §2 argues why that
//!   preserves the evaluation's shape).
//!
//! * [`BankLevel`] — DRAMsim-class per-bank state, for when the fixed model
//!   is the thing under test rather than the substrate: per-bank open rows
//!   and busy windows (row-buffer **hit / empty-miss / conflict** each get
//!   distinct tCL / tRCD+tCL / tRP+tRCD+tCL service times), bank-group
//!   column-command gaps (tCCD_L within a group, tCCD_S across), and
//!   periodic all-bank refresh windows (every tREFI the channel is blocked
//!   for tRFC and all rows close).
//!
//! Both backends must agree on *which* accesses happen — placement and
//! translation never consult the timing model — so switching backends may
//! only move cycle counts, never local/remote access splits
//! (`tests/backends.rs` locks this in).

use crate::config::{MemBackendKind, SystemConfig};

/// Timing outcome of one DRAM access.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DramResult {
    /// Completion time (cycles).
    pub done: f64,
    /// Whether the access hit an open row.
    pub row_hit: bool,
}

/// Aggregate counters every backend reports.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MemStats {
    /// Bytes served by the stack's DRAM.
    pub bytes_served: u64,
    /// Accesses that hit an open row.
    pub row_hits: u64,
    /// Accesses to a closed row (activate only).
    pub row_misses: u64,
    /// Accesses that had to close another open row first (bank-level
    /// backend only; the fixed model folds these into `row_misses`).
    pub row_conflicts: u64,
    /// Accesses delayed by an in-progress refresh window (bank-level only).
    pub refresh_stalls: u64,
}

impl MemStats {
    /// Row-buffer hit rate over all serviced accesses.
    pub fn row_hit_rate(&self) -> f64 {
        let total = self.row_hits + self.row_misses + self.row_conflicts;
        if total == 0 {
            0.0
        } else {
            self.row_hits as f64 / total as f64
        }
    }

    /// Accumulate another stack's counters (suite-level reporting).
    pub fn add(&mut self, other: &MemStats) {
        self.bytes_served += other.bytes_served;
        self.row_hits += other.row_hits;
        self.row_misses += other.row_misses;
        self.row_conflicts += other.row_conflicts;
        self.refresh_stalls += other.refresh_stalls;
    }
}

/// A per-stack DRAM timing model. One instance models one stack; the
/// simulator owns `num_stacks` of them and routes each request to the
/// owning stack's backend.
///
/// # Contract: backends shape time, never behaviour
///
/// A backend decides **when** an access completes, never **whether** or
/// **where** one happens. Placement, address translation, scheduling and
/// the interconnect route requests without ever consulting the timing
/// model, so switching backends may move cycle counts but must leave
/// every access count — local/remote splits, per-stack byte totals,
/// migration decisions — bit-identical (`tests/backends.rs` and the
/// differential suite enforce this). A backend that leaked timing into
/// behaviour would make cross-backend comparisons meaningless.
///
/// Implementations must also be **deterministic** (same access sequence
/// in, same completion times out — the golden snapshots depend on it)
/// and must accept non-decreasing *per-caller* `now` values without
/// assuming global time ordering: concurrent request streams (multiple
/// SMs, the host port) interleave arbitrarily.
pub trait MemBackend {
    /// Service one access of `bytes` at *stack-local* physical address
    /// `addr` arriving at time `now`.
    fn access(&mut self, now: f64, addr: u64, bytes: u64) -> DramResult;

    /// Earliest time any channel could begin a new transfer (for
    /// backpressure estimates).
    fn earliest_free(&self) -> f64;

    /// Counters accumulated so far.
    fn stats(&self) -> MemStats;

    /// Which backend this is (reporting).
    fn kind(&self) -> MemBackendKind;

    /// Total bytes served (convenience over [`Self::stats`]).
    fn bytes_served(&self) -> u64 {
        self.stats().bytes_served
    }

    /// Row-buffer hit rate (convenience over [`Self::stats`]).
    fn row_hit_rate(&self) -> f64 {
        self.stats().row_hit_rate()
    }
}

/// Statically-dispatched backend for the engine's per-access hot path.
///
/// The [`MemBackend`] trait stays the extension seam (new backends — a
/// DRAMsim3 FFI bridge, say — still implement it, and the frozen
/// differential oracles keep consuming `Box<dyn MemBackend>`), but the
/// engine itself routes every access through this enum: a two-way branch
/// the optimizer can inline both arms of, instead of a vtable load +
/// indirect call per simulated access. Wrapping a backend in the enum
/// changes dispatch only — the arms run the exact same code as the boxed
/// form, so every completion time stays bit-identical (the differential
/// and golden suites pin this).
#[derive(Clone, Debug)]
pub enum MemBackendImpl {
    Fixed(FixedLatency),
    Bank(BankLevel),
}

impl MemBackendImpl {
    /// Build the backend [`SystemConfig::mem_backend`] selects.
    pub fn new(cfg: &SystemConfig) -> Self {
        match cfg.mem_backend {
            MemBackendKind::FixedLatency => Self::Fixed(FixedLatency::new(cfg)),
            MemBackendKind::BankLevel => Self::Bank(BankLevel::new(cfg)),
        }
    }

    /// Service one access (see [`MemBackend::access`]); enum dispatch.
    #[inline]
    pub fn access(&mut self, now: f64, addr: u64, bytes: u64) -> DramResult {
        match self {
            Self::Fixed(b) => b.access(now, addr, bytes),
            Self::Bank(b) => b.access(now, addr, bytes),
        }
    }
}

impl MemBackend for MemBackendImpl {
    fn access(&mut self, now: f64, addr: u64, bytes: u64) -> DramResult {
        MemBackendImpl::access(self, now, addr, bytes)
    }

    fn earliest_free(&self) -> f64 {
        match self {
            Self::Fixed(b) => b.earliest_free(),
            Self::Bank(b) => b.earliest_free(),
        }
    }

    fn stats(&self) -> MemStats {
        match self {
            Self::Fixed(b) => b.stats(),
            Self::Bank(b) => b.stats(),
        }
    }

    fn kind(&self) -> MemBackendKind {
        match self {
            Self::Fixed(b) => b.kind(),
            Self::Bank(b) => b.kind(),
        }
    }
}

/// Build the backend [`SystemConfig::mem_backend`] selects, for one stack.
pub fn make_backend(cfg: &SystemConfig) -> Box<dyn MemBackend> {
    match cfg.mem_backend {
        MemBackendKind::FixedLatency => Box::new(FixedLatency::new(cfg)),
        MemBackendKind::BankLevel => Box::new(BankLevel::new(cfg)),
    }
}

/// Build one backend per stack (the shape the frozen oracles consume).
pub fn make_backends(cfg: &SystemConfig) -> Vec<Box<dyn MemBackend>> {
    (0..cfg.num_stacks).map(|_| make_backend(cfg)).collect()
}

/// Build one statically-dispatched backend per stack (the shape the
/// engine's hot path consumes).
pub fn make_backends_impl(cfg: &SystemConfig) -> Vec<MemBackendImpl> {
    (0..cfg.num_stacks).map(|_| MemBackendImpl::new(cfg)).collect()
}

/// The stack config rescaled to the host-local DDR's parameters.
fn host_ddr_cfg(cfg: &SystemConfig) -> SystemConfig {
    let mut ddr_cfg = cfg.clone();
    ddr_cfg.local_bw_gbs = cfg.host_ddr_bw_gbs;
    ddr_cfg.channels_per_stack = cfg.host_ddr_channels;
    ddr_cfg
}

/// Build the host-local DDR timing model (CHoNDA-style host memory).
///
/// The host's DDR sits behind the same [`MemBackend`] seam as the
/// stacks — the kind selected by `cfg.mem_backend` — but scaled to DDR
/// parameters: `host_ddr_bw_gbs` aggregate bandwidth over
/// `host_ddr_channels` channels. Addresses handed to it are host-side
/// line addresses (the DDR owns its own address space; only timing and
/// byte accounting matter).
pub fn make_host_ddr(cfg: &SystemConfig) -> Box<dyn MemBackend> {
    make_backend(&host_ddr_cfg(cfg))
}

/// [`make_host_ddr`], statically dispatched (the engine's form).
pub fn make_host_ddr_impl(cfg: &SystemConfig) -> MemBackendImpl {
    MemBackendImpl::new(&host_ddr_cfg(cfg))
}

// ---------------------------------------------------------------------------
// FixedLatency: the original channel model, preserved exactly.
// ---------------------------------------------------------------------------

/// One HBM channel: an open-row bank array plus a busy-until data bus.
#[derive(Clone, Debug)]
struct Channel {
    next_free: f64,
    open_rows: Vec<u64>, // per bank; u64::MAX = closed
    bytes_served: u64,
    row_hits: u64,
    row_misses: u64,
}

/// The original per-stack HBM device model: open-row tracking with a fixed
/// hit/miss service latency and a busy-until channel bus.
#[derive(Clone, Debug)]
pub struct FixedLatency {
    channels: Vec<Channel>,
    chan_shift: u32,
    chan_mask: u64,
    bank_mask: u64,
    bank_shift: u32,
    row_shift: u32,
    hit_cycles: f64,
    miss_cycles: f64,
    bytes_per_cycle: f64,
}

/// Backwards-compatible name for the original model.
pub type HbmStack = FixedLatency;

impl FixedLatency {
    pub fn new(cfg: &SystemConfig) -> Self {
        let n_chan = cfg.channels_per_stack.next_power_of_two();
        let per_chan_bw = cfg.gbs_to_bytes_per_cycle(cfg.local_bw_gbs) / n_chan as f64;
        Self {
            channels: vec![
                Channel {
                    next_free: 0.0,
                    open_rows: vec![u64::MAX; cfg.banks_per_channel],
                    bytes_served: 0,
                    row_hits: 0,
                    row_misses: 0,
                };
                n_chan
            ],
            // Channel bits sit right above the line bits so consecutive
            // lines spread across channels (standard HBM practice).
            chan_shift: cfg.line_size.trailing_zeros(),
            chan_mask: n_chan as u64 - 1,
            bank_shift: cfg.line_size.trailing_zeros() + (n_chan as u64).trailing_zeros(),
            bank_mask: cfg.banks_per_channel.next_power_of_two() as u64 - 1,
            row_shift: cfg.row_size.trailing_zeros(),
            hit_cycles: cfg.dram_hit_ns * cfg.cycles_per_ns(),
            miss_cycles: cfg.dram_miss_ns * cfg.cycles_per_ns(),
            bytes_per_cycle: per_chan_bw,
        }
    }

    /// Busy-time utilization of the most loaded channel up to `now`.
    pub fn peak_channel_util(&self, now: f64) -> f64 {
        if now <= 0.0 {
            return 0.0;
        }
        self.channels
            .iter()
            .map(|c| (c.bytes_served as f64 / self.bytes_per_cycle) / now)
            .fold(0.0, f64::max)
    }
}

impl MemBackend for FixedLatency {
    fn access(&mut self, now: f64, addr: u64, bytes: u64) -> DramResult {
        let chan_idx = ((addr >> self.chan_shift) & self.chan_mask) as usize;
        let bank_idx = ((addr >> self.bank_shift) & self.bank_mask) as usize;
        let row = addr >> self.row_shift;
        let chan = &mut self.channels[chan_idx];
        let row_hit = chan.open_rows[bank_idx] == row;
        let latency = if row_hit {
            chan.row_hits += 1;
            self.hit_cycles
        } else {
            chan.row_misses += 1;
            chan.open_rows[bank_idx] = row;
            self.miss_cycles
        };
        let start = now.max(chan.next_free);
        let occupancy = bytes as f64 / self.bytes_per_cycle;
        chan.next_free = start + occupancy;
        chan.bytes_served += bytes;
        DramResult {
            done: start + occupancy + latency,
            row_hit,
        }
    }

    fn earliest_free(&self) -> f64 {
        self.channels
            .iter()
            .map(|c| c.next_free)
            .fold(f64::INFINITY, f64::min)
    }

    fn stats(&self) -> MemStats {
        let mut s = MemStats::default();
        for c in &self.channels {
            s.bytes_served += c.bytes_served;
            s.row_hits += c.row_hits;
            s.row_misses += c.row_misses;
        }
        s
    }

    fn kind(&self) -> MemBackendKind {
        MemBackendKind::FixedLatency
    }
}

// ---------------------------------------------------------------------------
// BankLevel: per-bank row state, conflicts, bank groups, refresh.
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
struct Bank {
    /// Currently open row; u64::MAX = precharged (closed).
    open_row: u64,
    /// Time the bank finishes its current row-cycle work.
    ready: f64,
    /// Last refresh window this bank observed (rows close across windows).
    refresh_epoch: u64,
}

#[derive(Clone, Debug)]
struct BankChannel {
    banks: Vec<Bank>,
    /// Data-bus busy-until time.
    bus_free: f64,
    /// Last column command issued on this channel: (bank group, start time).
    last_cmd: Option<(usize, f64)>,
    bytes_served: u64,
    row_hits: u64,
    row_misses: u64,
    row_conflicts: u64,
    refresh_stalls: u64,
}

/// Bank-level DRAM timing: distinguishes row-buffer hits, empty-row misses
/// and conflicts, serializes per-bank row cycles, enforces bank-group
/// column-command gaps, and blocks the channel during periodic refresh.
#[derive(Clone, Debug)]
pub struct BankLevel {
    channels: Vec<BankChannel>,
    chan_shift: u32,
    chan_mask: u64,
    bank_shift: u32,
    bank_mask: u64,
    bank_groups: usize,
    row_shift: u32,
    tcl: f64,
    trcd: f64,
    trp: f64,
    tccd_l: f64,
    tccd_s: f64,
    trefi: f64,
    trfc: f64,
    bytes_per_cycle: f64,
}

impl BankLevel {
    pub fn new(cfg: &SystemConfig) -> Self {
        let n_chan = cfg.channels_per_stack.next_power_of_two();
        let n_banks = cfg.banks_per_channel.next_power_of_two();
        let per_chan_bw = cfg.gbs_to_bytes_per_cycle(cfg.local_bw_gbs) / n_chan as f64;
        let cyc = cfg.cycles_per_ns();
        Self {
            channels: vec![
                BankChannel {
                    banks: vec![
                        Bank {
                            open_row: u64::MAX,
                            ready: 0.0,
                            refresh_epoch: 0,
                        };
                        n_banks
                    ],
                    bus_free: 0.0,
                    last_cmd: None,
                    bytes_served: 0,
                    row_hits: 0,
                    row_misses: 0,
                    row_conflicts: 0,
                    refresh_stalls: 0,
                };
                n_chan
            ],
            chan_shift: cfg.line_size.trailing_zeros(),
            chan_mask: n_chan as u64 - 1,
            bank_shift: cfg.line_size.trailing_zeros() + (n_chan as u64).trailing_zeros(),
            bank_mask: n_banks as u64 - 1,
            bank_groups: cfg.bank_groups_per_channel.min(n_banks),
            row_shift: cfg.row_size.trailing_zeros(),
            tcl: cfg.dram_tcl_ns * cyc,
            trcd: cfg.dram_trcd_ns * cyc,
            trp: cfg.dram_trp_ns * cyc,
            tccd_l: cfg.dram_tccd_l_ns * cyc,
            tccd_s: cfg.dram_tccd_s_ns * cyc,
            trefi: cfg.dram_trefi_ns * cyc,
            trfc: cfg.dram_trfc_ns * cyc,
            bytes_per_cycle: per_chan_bw,
        }
    }

    /// Bank group of a bank index (low bank bits, DDR-style).
    #[inline]
    fn group_of(&self, bank_idx: usize) -> usize {
        bank_idx % self.bank_groups
    }
}

impl MemBackend for BankLevel {
    fn access(&mut self, now: f64, addr: u64, bytes: u64) -> DramResult {
        let chan_idx = ((addr >> self.chan_shift) & self.chan_mask) as usize;
        let bank_idx = ((addr >> self.bank_shift) & self.bank_mask) as usize;
        let group = self.group_of(bank_idx);
        let row = addr >> self.row_shift;
        let (tccd_l, tccd_s) = (self.tccd_l, self.tccd_s);
        let chan = &mut self.channels[chan_idx];

        // The command can issue once the requester, the bank, and the data
        // bus are all available.
        let mut start = now.max(chan.banks[bank_idx].ready).max(chan.bus_free);
        // Bank-group column-command gap.
        if let Some((last_group, last_start)) = chan.last_cmd {
            let gap = if last_group == group { tccd_l } else { tccd_s };
            start = start.max(last_start + gap);
        }
        // Periodic all-bank refresh: every tREFI window opens with a tRFC
        // blackout during which no command issues; crossing a window closes
        // every row (refresh precharges the whole bank). Window 0 is exempt:
        // the simulation starts right after the initialization refresh.
        let epoch = (start / self.trefi) as u64;
        let bank = &mut chan.banks[bank_idx];
        if epoch > bank.refresh_epoch {
            bank.refresh_epoch = epoch;
            bank.open_row = u64::MAX;
        }
        if epoch > 0 {
            let refresh_end = epoch as f64 * self.trefi + self.trfc;
            if start < refresh_end {
                chan.refresh_stalls += 1;
                start = refresh_end;
            }
        }

        // Row-buffer state machine: hit / empty miss / conflict.
        let row_hit = bank.open_row == row;
        let latency = if row_hit {
            chan.row_hits += 1;
            self.tcl
        } else if bank.open_row == u64::MAX {
            chan.row_misses += 1;
            bank.open_row = row;
            self.trcd + self.tcl
        } else {
            chan.row_conflicts += 1;
            bank.open_row = row;
            self.trp + self.trcd + self.tcl
        };

        let occupancy = bytes as f64 / self.bytes_per_cycle;
        // The bank is tied up for its row cycle; the shared data bus only
        // for the burst, which is what lets other banks overlap.
        bank.ready = start + latency;
        chan.bus_free = start + occupancy;
        chan.last_cmd = Some((group, start));
        chan.bytes_served += bytes;
        DramResult {
            done: start + occupancy + latency,
            row_hit,
        }
    }

    fn earliest_free(&self) -> f64 {
        self.channels
            .iter()
            .map(|c| c.bus_free)
            .fold(f64::INFINITY, f64::min)
    }

    fn stats(&self) -> MemStats {
        let mut s = MemStats::default();
        for c in &self.channels {
            s.bytes_served += c.bytes_served;
            s.row_hits += c.row_hits;
            s.row_misses += c.row_misses;
            s.row_conflicts += c.row_conflicts;
            s.refresh_stalls += c.refresh_stalls;
        }
        s
    }

    fn kind(&self) -> MemBackendKind {
        MemBackendKind::BankLevel
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> SystemConfig {
        SystemConfig::default()
    }

    fn bank_cfg() -> SystemConfig {
        let mut c = cfg();
        c.mem_backend = MemBackendKind::BankLevel;
        c
    }

    #[test]
    fn row_hit_is_faster_than_miss() {
        let mut hbm = FixedLatency::new(&cfg());
        let first = hbm.access(0.0, 0, 128);
        assert!(!first.row_hit);
        let second = hbm.access(first.done, 0, 128);
        assert!(second.row_hit);
        let miss_lat = first.done;
        let hit_lat = second.done - first.done;
        assert!(hit_lat < miss_lat);
    }

    #[test]
    fn consecutive_lines_spread_across_channels() {
        let c = cfg();
        let mut hbm = FixedLatency::new(&c);
        // 8 consecutive lines hit 8 distinct channels -> no queuing: all
        // complete at the same time.
        let times: Vec<f64> = (0..8).map(|i| hbm.access(0.0, i * 128, 128).done).collect();
        assert!(times.windows(2).all(|w| (w[0] - w[1]).abs() < 1e-9));
    }

    #[test]
    fn same_channel_requests_queue() {
        let c = cfg();
        let mut hbm = FixedLatency::new(&c);
        let stride = 128 * c.channels_per_stack as u64; // same channel
        let t1 = hbm.access(0.0, 0, 128).done;
        let t2 = hbm.access(0.0, stride * 16, 128).done; // different row too
        assert!(t2 > t1, "second access must queue behind the first");
    }

    #[test]
    fn aggregate_bandwidth_matches_config() {
        let c = cfg();
        let mut hbm = FixedLatency::new(&c);
        // Saturate all channels with back-to-back row hits and measure.
        let mut done: f64 = 0.0;
        let n = 4096u64;
        for i in 0..n {
            let r = hbm.access(0.0, (i % 64) * 128, 128);
            done = done.max(r.done);
        }
        let bytes = (n * 128) as f64;
        let achieved = bytes / done; // bytes per cycle
        let peak = c.gbs_to_bytes_per_cycle(c.local_bw_gbs);
        assert!(
            achieved > 0.5 * peak && achieved <= peak * 1.01,
            "achieved {achieved:.1} vs peak {peak:.1} B/cy"
        );
    }

    #[test]
    fn stats_accumulate() {
        let mut hbm = FixedLatency::new(&cfg());
        for i in 0..100u64 {
            hbm.access(i as f64, i * 128, 128);
        }
        assert_eq!(hbm.bytes_served(), 12800);
        assert!(hbm.row_hit_rate() >= 0.0);
        assert!(hbm.peak_channel_util(1000.0) > 0.0);
    }

    #[test]
    fn factory_dispatches_on_config() {
        let c = cfg();
        assert_eq!(make_backend(&c).kind(), MemBackendKind::FixedLatency);
        assert_eq!(make_backend(&bank_cfg()).kind(), MemBackendKind::BankLevel);
        assert_eq!(make_backends(&c).len(), c.num_stacks);
        assert_eq!(MemBackendImpl::new(&c).kind(), MemBackendKind::FixedLatency);
        assert_eq!(
            MemBackendImpl::new(&bank_cfg()).kind(),
            MemBackendKind::BankLevel
        );
        assert_eq!(make_backends_impl(&c).len(), c.num_stacks);
        assert_eq!(
            make_host_ddr_impl(&bank_cfg()).kind(),
            MemBackendKind::BankLevel
        );
    }

    /// Enum dispatch is a calling convention, not a model: driving the
    /// boxed and enum forms with the same request stream must produce
    /// bit-identical completion times and counters, for both kinds.
    #[test]
    fn enum_dispatch_matches_boxed_dispatch_bit_exactly() {
        for c in [cfg(), bank_cfg()] {
            let mut boxed = make_backend(&c);
            let mut inline = MemBackendImpl::new(&c);
            for i in 0..4096u64 {
                let addr = i.wrapping_mul(0x9E3779B97F4A7C15) & 0xFF_FFFF;
                let now = (i / 8) as f64;
                let a = boxed.access(now, addr, 128);
                let b = inline.access(now, addr, 128);
                assert_eq!(a.done.to_bits(), b.done.to_bits());
                assert_eq!(a.row_hit, b.row_hit);
            }
            assert_eq!(boxed.stats(), inline.stats());
            assert_eq!(
                boxed.earliest_free().to_bits(),
                inline.earliest_free().to_bits()
            );
        }
    }

    #[test]
    fn host_ddr_follows_backend_kind_and_is_slower_than_hbm() {
        let c = cfg();
        assert_eq!(make_host_ddr(&c).kind(), MemBackendKind::FixedLatency);
        assert_eq!(make_host_ddr(&bank_cfg()).kind(), MemBackendKind::BankLevel);
        // Saturating both with the same dense stream, the DDR (64 GB/s, 2
        // channels) must finish later than a stack's HBM (256 GB/s, 8).
        let mut hbm = make_backend(&c);
        let mut ddr = make_host_ddr(&c);
        let (mut t_hbm, mut t_ddr) = (0.0f64, 0.0f64);
        for i in 0..1024u64 {
            t_hbm = t_hbm.max(hbm.access(0.0, i * 128, 128).done);
            t_ddr = t_ddr.max(ddr.access(0.0, i * 128, 128).done);
        }
        assert!(t_ddr > t_hbm, "ddr {t_ddr} must be slower than hbm {t_hbm}");
    }

    // -- BankLevel ----------------------------------------------------------

    /// Same channel + bank, three row states: hit < empty miss < conflict.
    #[test]
    fn bank_level_orders_hit_miss_conflict() {
        let c = bank_cfg();
        let mut m = BankLevel::new(&c);
        // Row stride: one full row within the same bank. Row bits sit above
        // row_size; changing bit row_shift changes the row while the
        // channel/bank bits (low bits) stay 0.
        let row_stride = c.row_size;
        // Empty miss on a precharged bank.
        let miss = m.access(0.0, 0, 128);
        assert!(!miss.row_hit);
        let t0 = miss.done;
        // Hit on the now-open row. (Under the line-interleaved channel
        // layout, the lines of one row spread across channels, so a row hit
        // means re-touching the same line.)
        let hit = m.access(t0, 0, 128);
        assert!(hit.row_hit);
        let hit_lat = hit.done - t0;
        // Conflict: different row, same bank.
        let t1 = hit.done;
        let conf = m.access(t1, row_stride * 64, 128);
        assert!(!conf.row_hit);
        let conf_lat = conf.done - t1;
        let miss_lat = t0;
        assert!(
            hit_lat < miss_lat && miss_lat < conf_lat,
            "hit {hit_lat} < miss {miss_lat} < conflict {conf_lat}"
        );
        let s = m.stats();
        assert_eq!(s.row_hits, 1);
        assert_eq!(s.row_misses, 1);
        assert_eq!(s.row_conflicts, 1);
    }

    /// Two conflicting streams to different banks overlap; to one bank they
    /// serialize on the bank's row cycle.
    #[test]
    fn bank_level_exploits_bank_parallelism() {
        let c = bank_cfg();
        let bank_stride = 128 * (c.channels_per_stack as u64); // next bank, chan 0
        let row_stride = c.row_size * 1024; // far rows -> always conflict

        // Same bank, alternating rows: serial conflicts.
        let mut same = BankLevel::new(&c);
        let mut t_same: f64 = 0.0;
        for i in 0..8u64 {
            t_same = t_same.max(same.access(0.0, (i % 2) * row_stride, 128).done);
        }
        // Different banks, alternating rows per bank: conflicts overlap.
        let mut diff = BankLevel::new(&c);
        let mut t_diff: f64 = 0.0;
        for i in 0..8u64 {
            let addr = (i % 4) * bank_stride + (i % 2) * row_stride;
            t_diff = t_diff.max(diff.access(0.0, addr, 128).done);
        }
        assert!(
            t_diff < t_same,
            "bank-parallel {t_diff} must beat single-bank {t_same}"
        );
    }

    /// Accesses that land inside a refresh window are pushed past it and
    /// counted; rows do not survive a refresh.
    #[test]
    fn bank_level_refresh_blocks_and_closes_rows() {
        let c = bank_cfg();
        let cyc = c.cycles_per_ns();
        let trefi = c.dram_trefi_ns * cyc;
        let trfc = c.dram_trfc_ns * cyc;
        let mut m = BankLevel::new(&c);
        // Open row 0 well before the first refresh boundary.
        let first = m.access(0.0, 0, 128);
        assert!(!first.row_hit);
        // Arrive just inside the second window's blackout.
        let r = m.access(trefi + 1.0, 0, 128);
        assert!(!r.row_hit, "refresh must close the open row");
        assert!(
            r.done >= trefi + trfc,
            "access inside the blackout must wait it out: {} < {}",
            r.done,
            trefi + trfc
        );
        assert_eq!(m.stats().refresh_stalls, 1);
    }

    /// Same-bank-group back-to-back column commands pay tCCD_L > tCCD_S.
    #[test]
    fn bank_level_bank_group_gap() {
        let c = bank_cfg();
        assert!(c.dram_tccd_l_ns > c.dram_tccd_s_ns);
        let bank_stride = 128 * (c.channels_per_stack as u64);
        let groups = c.bank_groups_per_channel as u64;

        // Banks 0 and `groups` share group 0 (group = bank % groups).
        let mut same = BankLevel::new(&c);
        same.access(0.0, 0, 1); // negligible burst: isolates the gap
        let t_same = same.access(0.0, groups * bank_stride, 1).done;

        // Banks 0 and 1 are in different groups.
        let mut diff = BankLevel::new(&c);
        diff.access(0.0, 0, 1);
        let t_diff = diff.access(0.0, bank_stride, 1).done;
        assert!(
            t_same > t_diff,
            "same-group gap {t_same} must exceed cross-group {t_diff}"
        );
    }

    #[test]
    fn bank_level_is_deterministic() {
        let c = bank_cfg();
        let run = || {
            let mut m = BankLevel::new(&c);
            let mut acc = 0.0f64;
            for i in 0..4096u64 {
                let addr = i.wrapping_mul(0x9E3779B97F4A7C15) & 0xFF_FFFF;
                acc += m.access((i / 8) as f64, addr, 128).done;
            }
            (acc, m.stats())
        };
        let (a, sa) = run();
        let (b, sb) = run();
        assert_eq!(a, b);
        assert_eq!(sa, sb);
    }

    #[test]
    fn bank_level_tracks_bytes() {
        let c = bank_cfg();
        let mut m = BankLevel::new(&c);
        for i in 0..64u64 {
            m.access(i as f64 * 10.0, i * 128, 128);
        }
        assert_eq!(m.stats().bytes_served, 64 * 128);
        assert_eq!(
            m.stats().row_hits + m.stats().row_misses + m.stats().row_conflicts,
            64
        );
    }
}
