//! Multiprogrammed workloads (§6.5, Fig 12).
//!
//! Several applications run concurrently, one pinned to each memory
//! stack's SMs. With FGP-Only hardware every application's pages spread
//! over all stacks — guaranteed remote traffic from everyone. With CGP
//! hardware, each application's pages can be allocated in its own stack
//! ("it is infeasible or difficult to reduce remote data accesses in the
//! presence of multiple workloads" otherwise).

use crate::addr::AddressMapper;
use crate::config::SystemConfig;
use crate::gpu::Topology;
use crate::mem::{self, MemBackend, MemStats};
use crate::net::Interconnect;
use crate::stats::{AccessStats, RunReport};
use crate::vm::{Tlb, VirtualMemory};
use crate::workloads::BuiltWorkload;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Placement style for a multiprogrammed run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MixPlacement {
    /// Every app's pages fine-grain interleaved over all stacks.
    FgpOnly,
    /// Every app's pages coarse-grain in its home stack.
    CgpLocal,
}

/// One application mix: up to `num_stacks` workloads, app `i` homed on
/// stack `i`.
pub struct Mix<'a> {
    pub apps: Vec<&'a BuiltWorkload>,
}

/// Simulate a mix; returns (per-app cycles, combined report).
pub fn run_mix(
    cfg: &SystemConfig,
    mix: &Mix<'_>,
    placement: MixPlacement,
) -> crate::Result<(Vec<f64>, RunReport)> {
    assert!(mix.apps.len() <= cfg.num_stacks);
    let topo = Topology::new(cfg);
    let mapper = AddressMapper::new(cfg);
    let mut net = Interconnect::new(cfg);
    let mut stacks: Vec<Box<dyn MemBackend>> = mem::make_backends(cfg);
    let mut tlbs: Vec<Tlb> = (0..topo.sms.len())
        .map(|_| Tlb::new(cfg.tlb_entries))
        .collect();

    // One shared physical memory, per-app virtual spaces.
    let mut vm = VirtualMemory::new(cfg);
    let mut app_bases: Vec<Vec<u64>> = Vec::new();
    for (home, app) in mix.apps.iter().enumerate() {
        let mut bases = Vec::new();
        for obj in &app.trace.objects {
            let pages = obj.bytes.div_ceil(cfg.page_size).max(1);
            let base = match placement {
                MixPlacement::FgpOnly => vm.map_fgp(pages)?,
                MixPlacement::CgpLocal => vm.map_cgp(pages, |_| home)?,
            };
            bases.push(base);
        }
        app_bases.push(bases);
    }

    // Per-app block queues; each app's blocks run on its home stack's SMs.
    let line = cfg.line_size;
    let cyc = cfg.cycles_per_ns();
    let page_shift = cfg.page_size.trailing_zeros();
    let tlb_miss_cycles = cfg.tlb_miss_ns * cyc;
    let mlp = cfg.mlp_per_block;
    let compute = cfg.compute_cycles_per_access as f64;

    let mut stats = AccessStats::default();
    let mut app_end = vec![0.0f64; mix.apps.len()];
    let mut seq = 0u64;
    // Events: (time_bits, seq, app, block_idx, next_access, sm_id).
    let mut heap: BinaryHeap<Reverse<(u64, u64, u32, u32, u32, u32)>> = BinaryHeap::new();
    let mut next_block: Vec<usize> = vec![0; mix.apps.len()];
    // Per-SM issue-bandwidth server (see sim.rs).
    let mut sm_free: Vec<f64> = vec![0.0; topo.sms.len()];

    // Seed each app's home-stack SM slots.
    for (app_idx, app) in mix.apps.iter().enumerate() {
        let sms: Vec<usize> = topo.sms_of_stack(app_idx).map(|s| s.id).collect();
        let capacity = sms.len() * cfg.blocks_per_sm;
        for slot in 0..capacity {
            if next_block[app_idx] >= app.trace.blocks.len() {
                break;
            }
            let b = next_block[app_idx];
            next_block[app_idx] += 1;
            heap.push(Reverse((
                0f64.to_bits(),
                seq,
                app_idx as u32,
                b as u32,
                0,
                sms[slot % sms.len()] as u32,
            )));
            seq += 1;
        }
    }

    while let Some(Reverse((tb, _, app_idx, block_idx, next_acc, sm_id))) = heap.pop() {
        let now = f64::from_bits(tb);
        let app = mix.apps[app_idx as usize];
        let home = app_idx as usize;
        let block = &app.trace.blocks[block_idx as usize];
        let begin = next_acc as usize;
        let endw = (begin + mlp).min(block.accesses.len());
        let mut window_done = now;
        for a in &block.accesses[begin..endw] {
            let vaddr = app_bases[home][a.obj as usize] + a.offset;
            let vpn = vaddr >> page_shift;
            let mut t = now;
            let pte = match tlbs[sm_id as usize].lookup(vpn) {
                Some(p) => p,
                None => {
                    t += tlb_miss_cycles;
                    let p = vm.pte_of(vaddr).expect("mapped");
                    tlbs[sm_id as usize].fill(vpn, p);
                    p
                }
            };
            let paddr = (pte.ppn << page_shift) | (vaddr & (cfg.page_size - 1));
            let dst = mapper.stack_of(paddr, pte.granularity);
            let done = if dst == home {
                stats.local += 1;
                let t1 = net.local_hop(t, dst, line);
                stacks[dst].access(t1, paddr, line).done
            } else {
                stats.remote += 1;
                let t1 = net.remote_hop(t, home, dst, line);
                let t2 = stacks[dst].access(t1, paddr, line).done;
                net.remote_hop(t2, dst, home, line)
            };
            window_done = window_done.max(done);
        }
        let c_start = window_done.max(sm_free[sm_id as usize]);
        let t_next = c_start + compute * (endw - begin) as f64;
        sm_free[sm_id as usize] = t_next;
        app_end[home] = app_end[home].max(t_next);
        if endw < block.accesses.len() {
            heap.push(Reverse((
                t_next.to_bits(),
                seq,
                app_idx,
                block_idx,
                endw as u32,
                sm_id,
            )));
            seq += 1;
        } else if next_block[home] < app.trace.blocks.len() {
            let b = next_block[home];
            next_block[home] += 1;
            heap.push(Reverse((t_next.to_bits(), seq, app_idx, b as u32, 0, sm_id)));
            seq += 1;
        }
    }

    let mut mem_stats = MemStats::default();
    for s in &stacks {
        mem_stats.add(&s.stats());
    }
    let report = RunReport {
        workload: mix
            .apps
            .iter()
            .map(|a| a.name)
            .collect::<Vec<_>>()
            .join("+"),
        mechanism: format!("{placement:?}"),
        cycles: app_end.iter().cloned().fold(0.0, f64::max),
        accesses: stats,
        stack_bytes: stacks.iter().map(|s| s.bytes_served()).collect(),
        remote_bytes: net.remote_bytes(),
        mem_backend: cfg.mem_backend.to_string(),
        bank_conflicts: mem_stats.row_conflicts,
        refresh_stalls: mem_stats.refresh_stalls,
        ..Default::default()
    };
    Ok((app_end, report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::suite;

    /// Fig 12's claim: CGP-local beats FGP-Only for every mix.
    #[test]
    fn cgp_local_beats_fgp_for_mixes() {
        let cfg = SystemConfig::test_small();
        let a = suite::build("NN", &cfg).unwrap();
        let b = suite::build("KM", &cfg).unwrap();
        let c = suite::build("DC", &cfg).unwrap();
        let d = suite::build("HS", &cfg).unwrap();
        let mix = Mix {
            apps: vec![&a, &b, &c, &d],
        };
        let (_, fgp) = run_mix(&cfg, &mix, MixPlacement::FgpOnly).unwrap();
        let (_, cgp) = run_mix(&cfg, &mix, MixPlacement::CgpLocal).unwrap();
        assert_eq!(cgp.accesses.remote, 0, "home placement removes remote");
        assert!(fgp.accesses.remote > 0);
        assert!(
            cgp.cycles < fgp.cycles,
            "cgp {} vs fgp {}",
            cgp.cycles,
            fgp.cycles
        );
    }

    #[test]
    fn per_app_times_reported() {
        let cfg = SystemConfig::test_small();
        let a = suite::build("NN", &cfg).unwrap();
        let b = suite::build("DC", &cfg).unwrap();
        let mix = Mix { apps: vec![&a, &b] };
        let (times, _) = run_mix(&cfg, &mix, MixPlacement::CgpLocal).unwrap();
        assert_eq!(times.len(), 2);
        assert!(times.iter().all(|&t| t > 0.0));
    }
}
