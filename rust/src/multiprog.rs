//! Multiprogrammed workloads (§6.5, Fig 12) and multi-kernel scheduling.
//!
//! Several applications run concurrently. With FGP-Only hardware every
//! application's pages spread over all stacks — guaranteed remote traffic
//! from everyone. With CGP hardware, each application's pages can be
//! allocated in its own stack ("it is infeasible or difficult to reduce
//! remote data accesses in the presence of multiple workloads" otherwise).
//!
//! Three entry points share the event-loop physics of [`crate::engine`]:
//!
//! * [`run_mix`] — the paper's Fig 12 shape: up to `num_stacks` apps, app
//!   `i` pinned to stack `i`'s SMs, all launched at t=0.
//! * [`run_multi`] — true multi-kernel scheduling: a mix may hold **more
//!   kernels than stacks** (homes wrap round-robin), kernels launch at
//!   staggered arrival times, and SMs are time-shared at block granularity
//!   under the block-level [`Policy`] plus a per-app [`FairnessPolicy`].
//!   The report carries per-app slowdown (response time vs running alone
//!   under the same placement) and weighted speedup (Σ T_alone/T_shared).
//! * [`run_hostmix`] — CHoNDA-style concurrent host + NDP execution: the
//!   NDP mix of `run_multi` co-runs with a host-processor request stream
//!   injected through the per-stack host ports, so both sides contend for
//!   interconnect slots and DRAM dispatch. The report adds per-source
//!   bandwidth share, host slowdown and NDP slowdown vs each side running
//!   alone on the same physical layout.
//!
//! All three are thin wrappers since the experiment-API redesign: each
//! constructs an [`ExperimentSpec`] (pinned / shared / hostmix shape) and
//! lowers it through [`crate::session::Session`], which owns the mapping,
//! dispatch and baseline machinery. `tests/spec_equiv.rs` keeps frozen
//! copies of the pre-spec implementations as oracles and proves these
//! wrappers cycle-identical (bit-exact f64) under both DRAM backends.

use crate::config::SystemConfig;
use crate::sched::{FairnessPolicy, Policy};
use crate::session::Session;
use crate::spec::{ExperimentSpec, WorkloadSel};
use crate::stats::RunReport;
use crate::workloads::BuiltWorkload;

/// Placement style for a multiprogrammed run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MixPlacement {
    /// Every app's pages fine-grain interleaved over all stacks.
    FgpOnly,
    /// Every app's pages coarse-grain in its home stack.
    CgpLocal,
}

impl MixPlacement {
    /// Parse a CLI spelling; `None` for unknown names.
    pub fn parse(s: &str) -> Option<Self> {
        match s.trim() {
            "fgp" | "fgp-only" => Some(Self::FgpOnly),
            "cgp" | "cgp-local" => Some(Self::CgpLocal),
            _ => None,
        }
    }
}

impl std::fmt::Display for MixPlacement {
    /// Canonical CLI/spec spelling (round-trips through
    /// [`MixPlacement::parse`]; report labels use the `Debug` form).
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Self::FgpOnly => "fgp",
            Self::CgpLocal => "cgp",
        })
    }
}

/// One application mix: up to `num_stacks` workloads, app `i` homed on
/// stack `i`.
pub struct Mix<'a> {
    pub apps: Vec<&'a BuiltWorkload>,
}

/// One kernel in a multi-kernel mix: the workload plus its launch time
/// (in SM cycles).
pub struct KernelLaunch<'a> {
    pub app: &'a BuiltWorkload,
    pub arrival: f64,
}

/// A multi-kernel mix: any number of kernels; app `i` is homed on stack
/// [`home_of`]`(i)`, so oversubscribed mixes time-share SMs.
pub struct MultiMix<'a> {
    pub launches: Vec<KernelLaunch<'a>>,
}

/// Home stack of app `i` in a mix: wraps round-robin over the stacks.
/// The single source of the rule — mapping, scheduling and the CLI's
/// reporting all go through here.
#[inline]
pub fn home_of(app_idx: usize, cfg: &SystemConfig) -> usize {
    app_idx % cfg.num_stacks
}

/// Simulate a mix; returns (per-app completion cycles, combined report).
pub fn run_mix(
    cfg: &SystemConfig,
    mix: &Mix<'_>,
    placement: MixPlacement,
) -> crate::Result<(Vec<f64>, RunReport)> {
    anyhow::ensure!(
        mix.apps.len() <= cfg.num_stacks,
        "run_mix pins one app per stack ({} apps > {} stacks); use run_multi \
         for oversubscribed mixes",
        mix.apps.len(),
        cfg.num_stacks
    );
    let spec = ExperimentSpec::pinned(
        mix.apps.iter().map(|&a| WorkloadSel::Prebuilt(a)).collect(),
        placement,
    );
    let report = Session::new(cfg.clone(), spec)?.run()?.run;
    Ok((report.app_cycles.clone(), report))
}

/// Simulate a multi-kernel mix with time-shared SMs.
///
/// The returned report's `app_cycles` are per-app **response times**
/// (completion − arrival), `app_slowdown` compares each against a
/// run-alone baseline under the same placement and physical layout, and
/// `weighted_speedup` is Σᵢ T_aloneᵢ / T_sharedᵢ (system throughput; N
/// for a mix with no contention, smaller when apps interfere).
pub fn run_multi(
    cfg: &SystemConfig,
    mix: &MultiMix<'_>,
    placement: MixPlacement,
    policy: Policy,
    fairness: FairnessPolicy,
) -> crate::Result<RunReport> {
    let spec = ExperimentSpec::shared(
        mix.launches
            .iter()
            .map(|l| (WorkloadSel::Prebuilt(l.app), l.arrival))
            .collect(),
        placement,
        policy,
        fairness,
    );
    Ok(Session::new(cfg.clone(), spec)?.run()?.run)
}

/// Simulate a CHoNDA-style co-run: an NDP mix (possibly empty) plus a
/// concurrent host request stream sweeping `host`'s objects.
///
/// The physical layout maps the NDP apps first — exactly as [`run_multi`]
/// would — then the host objects, fine-grain interleaved (FGP is the
/// host's preferred granularity, Fig 13). Because the host pages come
/// last, the NDP side's layout is byte-identical to its `run_multi`
/// layout, which is what makes the two degenerate cases exact:
///
/// * **Zero host intensity** (`host_mlp == 0`, `host_passes == 0`, or
///   `host = None`): the NDP run is cycle-identical (bit-exact f64) to
///   [`run_multi`]'s shared run.
/// * **Host alone** (empty `ndp` mix): the host stream reproduces the
///   legacy `host::run_host_sweep` cycles bit-exactly.
///
/// The report's host fields compare each side against itself running
/// alone **on the same physical layout**: `ndp_slowdown` is the NDP
/// makespan vs the mix without host traffic, `host_slowdown` the host
/// completion vs the stream without NDP kernels, `app_slowdown` /
/// `weighted_speedup` are per-app response times vs the host-free run
/// (so they isolate host interference, unlike [`run_multi`]'s solo-run
/// baselines which isolate app-vs-app interference), and `host_bw_share`
/// is the host's fraction of all bytes the stack DRAMs served.
pub fn run_hostmix(
    cfg: &SystemConfig,
    ndp: &MultiMix<'_>,
    host: Option<&BuiltWorkload>,
    placement: MixPlacement,
    policy: Policy,
    fairness: FairnessPolicy,
) -> crate::Result<RunReport> {
    let spec = ExperimentSpec::hostmix(
        ndp.launches
            .iter()
            .map(|l| (WorkloadSel::Prebuilt(l.app), l.arrival))
            .collect(),
        host.map(WorkloadSel::Prebuilt),
        placement,
        policy,
        fairness,
    );
    Ok(Session::new(cfg.clone(), spec)?.run()?.run)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::suite;

    /// Fig 12's claim: CGP-local beats FGP-Only for every mix.
    #[test]
    fn cgp_local_beats_fgp_for_mixes() {
        let cfg = SystemConfig::test_small();
        let a = suite::build("NN", &cfg).unwrap();
        let b = suite::build("KM", &cfg).unwrap();
        let c = suite::build("DC", &cfg).unwrap();
        let d = suite::build("HS", &cfg).unwrap();
        let mix = Mix {
            apps: vec![&a, &b, &c, &d],
        };
        let (_, fgp) = run_mix(&cfg, &mix, MixPlacement::FgpOnly).unwrap();
        let (_, cgp) = run_mix(&cfg, &mix, MixPlacement::CgpLocal).unwrap();
        assert_eq!(cgp.accesses.remote, 0, "home placement removes remote");
        assert!(fgp.accesses.remote > 0);
        assert!(
            cgp.cycles < fgp.cycles,
            "cgp {} vs fgp {}",
            cgp.cycles,
            fgp.cycles
        );
    }

    #[test]
    fn per_app_times_reported() {
        let cfg = SystemConfig::test_small();
        let a = suite::build("NN", &cfg).unwrap();
        let b = suite::build("DC", &cfg).unwrap();
        let mix = Mix { apps: vec![&a, &b] };
        let (times, report) = run_mix(&cfg, &mix, MixPlacement::CgpLocal).unwrap();
        assert_eq!(times.len(), 2);
        assert!(times.iter().all(|&t| t > 0.0));
        assert_eq!(report.app_cycles, times);
    }

    #[test]
    fn oversubscribed_mix_runs_to_completion() {
        // More kernels than stacks: homes wrap, SMs time-share.
        let cfg = SystemConfig::test_small();
        let built: Vec<_> = ["NN", "KM", "DC", "HS", "NN", "DC"]
            .iter()
            .map(|n| suite::build(n, &cfg).unwrap())
            .collect();
        let mix = MultiMix {
            launches: built
                .iter()
                .map(|b| KernelLaunch {
                    app: b,
                    arrival: 0.0,
                })
                .collect(),
        };
        let r = run_multi(
            &cfg,
            &mix,
            MixPlacement::CgpLocal,
            Policy::Affinity,
            FairnessPolicy::RoundRobin,
        )
        .unwrap();
        let total: u64 = built.iter().map(|b| b.total_accesses()).sum();
        assert_eq!(r.accesses.ndp_total(), total, "every block must execute");
        assert_eq!(r.app_cycles.len(), 6);
        assert_eq!(r.app_slowdown.len(), 6);
        assert!(r.app_cycles.iter().all(|&t| t > 0.0));
        assert!(r.app_slowdown.iter().all(|&s| s.is_finite() && s > 0.0));
        assert!(r.weighted_speedup > 0.0 && r.weighted_speedup <= 6.0 + 1e-9);
        // Stacks 0/1 host two apps each; someone must feel the sharing.
        assert!(
            r.app_slowdown.iter().any(|&s| s > 1.0 + 1e-9),
            "oversubscription must show up as slowdown: {:?}",
            r.app_slowdown
        );
    }

    #[test]
    fn rejects_bad_arrival_times() {
        let cfg = SystemConfig::test_small();
        let a = suite::build("NN", &cfg).unwrap();
        let mix = MultiMix {
            launches: vec![KernelLaunch {
                app: &a,
                arrival: -1.0,
            }],
        };
        assert!(run_multi(
            &cfg,
            &mix,
            MixPlacement::CgpLocal,
            Policy::Affinity,
            FairnessPolicy::Fcfs,
        )
        .is_err());
    }

    #[test]
    fn run_mix_rejects_more_apps_than_stacks() {
        let cfg = SystemConfig::test_small();
        let a = suite::build("NN", &cfg).unwrap();
        let app: &BuiltWorkload = &a;
        let mix = Mix {
            apps: vec![app; cfg.num_stacks + 1],
        };
        assert!(run_mix(&cfg, &mix, MixPlacement::CgpLocal).is_err());
    }

    #[test]
    fn placement_parse() {
        assert_eq!(MixPlacement::parse("fgp"), Some(MixPlacement::FgpOnly));
        assert_eq!(MixPlacement::parse("cgp"), Some(MixPlacement::CgpLocal));
        assert_eq!(MixPlacement::parse("x"), None);
        // Display round-trips through parse.
        for p in [MixPlacement::FgpOnly, MixPlacement::CgpLocal] {
            assert_eq!(MixPlacement::parse(&p.to_string()), Some(p));
        }
    }

    #[test]
    fn hostmix_rejects_empty_run() {
        let cfg = SystemConfig::test_small();
        let mix = MultiMix { launches: vec![] };
        assert!(run_hostmix(
            &cfg,
            &mix,
            None,
            MixPlacement::CgpLocal,
            Policy::Affinity,
            FairnessPolicy::Fcfs,
        )
        .is_err());
    }

    #[test]
    fn hostmix_host_alone_serves_every_line() {
        let cfg = SystemConfig::test_small();
        let h = suite::build("NN", &cfg).unwrap();
        let mix = MultiMix { launches: vec![] };
        let r = run_hostmix(
            &cfg,
            &mix,
            Some(&h),
            MixPlacement::CgpLocal,
            Policy::Affinity,
            FairnessPolicy::Fcfs,
        )
        .unwrap();
        let lines: u64 = h
            .trace
            .objects
            .iter()
            .map(|o| o.bytes.div_ceil(cfg.line_size))
            .sum();
        assert_eq!(r.accesses.host, lines);
        assert_eq!(r.accesses.ndp_total(), 0);
        assert!(r.cycles > 0.0);
        assert_eq!(r.cycles, r.host_cycles);
        assert!((r.host_bw_share - 1.0).abs() < 1e-12, "host owns all bytes");
        assert_eq!(r.host_slowdown, 1.0, "nothing contended with the host");
        assert_eq!(r.ndp_slowdown, 0.0, "no NDP side ran");
        assert_eq!(r.workload, "host:NN");
    }

    #[test]
    fn hostmix_contention_is_reported() {
        let cfg = SystemConfig::test_small();
        let a = suite::build("NN", &cfg).unwrap();
        let h = suite::build("KM", &cfg).unwrap();
        let mix = MultiMix {
            launches: vec![KernelLaunch {
                app: &a,
                arrival: 0.0,
            }],
        };
        let r = run_hostmix(
            &cfg,
            &mix,
            Some(&h),
            MixPlacement::CgpLocal,
            Policy::Affinity,
            FairnessPolicy::Fcfs,
        )
        .unwrap();
        assert!(r.accesses.host > 0 && r.accesses.ndp_total() > 0);
        assert!(r.host_bw_share > 0.0 && r.host_bw_share < 1.0);
        // The host's issue order is fixed, so NDP traffic can only delay
        // it; the NDP side additionally tolerates a hair of block→SM
        // reshuffle noise under the compute-heavy default config.
        assert!(
            r.ndp_slowdown >= 1.0 - 1e-3,
            "ndp slowdown {}",
            r.ndp_slowdown
        );
        assert!(r.host_slowdown >= 1.0, "host slowdown {}", r.host_slowdown);
        assert_eq!(r.app_cycles.len(), 1);
        assert_eq!(r.workload, "NN|host:KM");
    }
}
