//! Multiprogrammed workloads (§6.5, Fig 12) and multi-kernel scheduling.
//!
//! Several applications run concurrently. With FGP-Only hardware every
//! application's pages spread over all stacks — guaranteed remote traffic
//! from everyone. With CGP hardware, each application's pages can be
//! allocated in its own stack ("it is infeasible or difficult to reduce
//! remote data accesses in the presence of multiple workloads" otherwise).
//!
//! Three entry points share the event-loop physics of [`crate::engine`]:
//!
//! * [`run_mix`] — the paper's Fig 12 shape: up to `num_stacks` apps, app
//!   `i` pinned to stack `i`'s SMs, all launched at t=0. Cycle-identical
//!   to the pre-refactor standalone loop (`tests/differential` locks this
//!   in), and now also reports TLB/latency/row-hit statistics.
//! * [`run_multi`] — true multi-kernel scheduling: a mix may hold **more
//!   kernels than stacks** (homes wrap round-robin), kernels launch at
//!   staggered arrival times, and SMs are time-shared at block granularity
//!   under the block-level [`Policy`] plus a per-app [`FairnessPolicy`].
//!   The report carries per-app slowdown (response time vs running alone
//!   under the same placement) and weighted speedup (Σ T_alone/T_shared).
//! * [`run_hostmix`] — CHoNDA-style concurrent host + NDP execution: the
//!   NDP mix of `run_multi` co-runs with a host-processor request stream
//!   ([`HostStream`]) injected through the per-stack host ports, so both
//!   sides contend for interconnect slots and DRAM dispatch. The report
//!   adds per-source bandwidth share, host slowdown and NDP slowdown vs
//!   each side running alone on the same physical layout.

use crate::config::SystemConfig;
use crate::engine::{AppCtx, BlockRef, BlockSource, Engine, EngineOptions, EngineRaw, HostStream};
use crate::gpu::{Sm, Topology};
use crate::sched::{FairnessPolicy, Policy};
use crate::stats::{self, RunReport};
use crate::vm::VirtualMemory;
use crate::workloads::BuiltWorkload;
use std::collections::VecDeque;

/// Placement style for a multiprogrammed run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MixPlacement {
    /// Every app's pages fine-grain interleaved over all stacks.
    FgpOnly,
    /// Every app's pages coarse-grain in its home stack.
    CgpLocal,
}

impl MixPlacement {
    /// Parse a CLI spelling; `None` for unknown names.
    pub fn parse(s: &str) -> Option<Self> {
        match s.trim() {
            "fgp" | "fgp-only" => Some(Self::FgpOnly),
            "cgp" | "cgp-local" => Some(Self::CgpLocal),
            _ => None,
        }
    }
}

/// One application mix: up to `num_stacks` workloads, app `i` homed on
/// stack `i`.
pub struct Mix<'a> {
    pub apps: Vec<&'a BuiltWorkload>,
}

/// One kernel in a multi-kernel mix: the workload plus its launch time
/// (in SM cycles).
pub struct KernelLaunch<'a> {
    pub app: &'a BuiltWorkload,
    pub arrival: f64,
}

/// A multi-kernel mix: any number of kernels; app `i` is homed on stack
/// [`home_of`]`(i)`, so oversubscribed mixes time-share SMs.
pub struct MultiMix<'a> {
    pub launches: Vec<KernelLaunch<'a>>,
}

/// Home stack of app `i` in a mix: wraps round-robin over the stacks.
/// The single source of the rule — mapping, scheduling and the CLI's
/// reporting all go through here.
#[inline]
pub fn home_of(app_idx: usize, cfg: &SystemConfig) -> usize {
    app_idx % cfg.num_stacks
}

/// Map every app's objects into one shared physical memory (per-app
/// virtual bases), homing app `i` on stack `i % num_stacks`. Both the
/// joint run and the run-alone baselines use this, so physical layout —
/// and therefore bank/row behaviour — is identical between them.
fn map_mix(
    cfg: &SystemConfig,
    apps: &[&BuiltWorkload],
    placement: MixPlacement,
) -> crate::Result<(VirtualMemory, Vec<Vec<u64>>)> {
    let mut vm = VirtualMemory::new(cfg);
    let mut app_bases: Vec<Vec<u64>> = Vec::new();
    for (i, app) in apps.iter().enumerate() {
        let home = home_of(i, cfg);
        let mut bases = Vec::new();
        for obj in &app.trace.objects {
            let pages = obj.bytes.div_ceil(cfg.page_size).max(1);
            let base = match placement {
                MixPlacement::FgpOnly => vm.map_fgp(pages)?,
                MixPlacement::CgpLocal => vm.map_cgp(pages, |_| home)?,
            };
            bases.push(base);
        }
        app_bases.push(bases);
    }
    Ok((vm, app_bases))
}

/// [`BlockSource`] reproducing the historical `run_mix` dispatch exactly:
/// app `i`'s blocks run only on stack `i`'s SMs, in launch order, and a
/// retiring block's slot refills from the same app.
struct MixSource {
    next_block: Vec<usize>,
    num_blocks: Vec<usize>,
}

impl BlockSource for MixSource {
    fn seed(&mut self, topo: &Topology, place: &mut dyn FnMut(usize, usize, BlockRef)) {
        // Seed each app's home-stack SM slots.
        for app in 0..self.num_blocks.len() {
            let sms: Vec<usize> = topo.sms_of_stack(app).map(|s| s.id).collect();
            let capacity = sms.len() * topo.blocks_per_sm;
            for slot in 0..capacity {
                if self.next_block[app] >= self.num_blocks[app] {
                    break;
                }
                let b = self.next_block[app];
                self.next_block[app] += 1;
                place(
                    sms[slot % sms.len()],
                    slot / sms.len(),
                    BlockRef {
                        app: app as u32,
                        block: b as u32,
                    },
                );
            }
        }
    }

    fn refill(&mut self, _sm: Sm, retired: Option<BlockRef>, _now: f64) -> Option<BlockRef> {
        let app = retired?.app as usize;
        if self.next_block[app] < self.num_blocks[app] {
            let b = self.next_block[app];
            self.next_block[app] += 1;
            Some(BlockRef {
                app: app as u32,
                block: b as u32,
            })
        } else {
            None
        }
    }
}

/// Simulate a mix; returns (per-app completion cycles, combined report).
pub fn run_mix(
    cfg: &SystemConfig,
    mix: &Mix<'_>,
    placement: MixPlacement,
) -> crate::Result<(Vec<f64>, RunReport)> {
    anyhow::ensure!(
        mix.apps.len() <= cfg.num_stacks,
        "run_mix pins one app per stack ({} apps > {} stacks); use run_multi \
         for oversubscribed mixes",
        mix.apps.len(),
        cfg.num_stacks
    );
    let (mut vm, app_bases) = map_mix(cfg, &mix.apps, placement)?;
    let apps: Vec<AppCtx<'_>> = mix
        .apps
        .iter()
        .zip(&app_bases)
        .map(|(a, b)| AppCtx {
            trace: &a.trace,
            obj_base: b.as_slice(),
        })
        .collect();
    let mut source = MixSource {
        next_block: vec![0; mix.apps.len()],
        num_blocks: mix.apps.iter().map(|a| a.trace.blocks.len()).collect(),
    };
    let raw = Engine {
        cfg,
        apps,
        vm: &mut vm,
        opts: EngineOptions {
            // The multiprogrammed path has never modelled the L2 filter;
            // keeping it off preserves the historical cycle counts.
            l2_filter: false,
            migrate_on_first_touch: false,
        },
        host: None,
    }
    .run(&mut source);
    let mut report = raw.to_report(
        cfg,
        mix.apps
            .iter()
            .map(|a| a.name)
            .collect::<Vec<_>>()
            .join("+"),
    );
    report.mechanism = format!("{placement:?}");
    report.app_cycles = raw.app_end.clone();
    Ok((raw.app_end, report))
}

/// [`BlockSource`] for multi-kernel scheduling: per-app FIFO block
/// queues, arrival times, home stacks, and the fairness arbiter.
struct MultiKernelSource {
    queues: Vec<VecDeque<u32>>,
    arrival: Vec<f64>,
    home: Vec<usize>,
    policy: Policy,
    fairness: FairnessPolicy,
    issued: Vec<u64>,
    rr_cursor: usize,
}

impl MultiKernelSource {
    fn new(
        launches: &[(usize, f64)], // (num_blocks, arrival) per app
        cfg: &SystemConfig,
        policy: Policy,
        fairness: FairnessPolicy,
        only_app: Option<usize>,
    ) -> Self {
        let queues = launches
            .iter()
            .enumerate()
            .map(|(i, &(n, _))| {
                if only_app.is_some_and(|o| o != i) {
                    VecDeque::new()
                } else {
                    (0..n as u32).collect()
                }
            })
            .collect();
        Self {
            queues,
            arrival: launches.iter().map(|&(_, t)| t).collect(),
            home: (0..launches.len()).map(|i| home_of(i, cfg)).collect(),
            policy,
            fairness,
            issued: vec![0; launches.len()],
            rr_cursor: 0,
        }
    }

    /// Apps with pending blocks that have arrived by `now` and whose
    /// blocks may run on `stack` under the block-level policy.
    fn eligible(&self, stack: usize, now: f64) -> Vec<usize> {
        let arrived: Vec<usize> = (0..self.queues.len())
            .filter(|&i| !self.queues[i].is_empty() && self.arrival[i] <= now)
            .collect();
        match self.policy {
            Policy::Baseline => arrived,
            Policy::Affinity => arrived
                .into_iter()
                .filter(|&i| self.home[i] == stack)
                .collect(),
            Policy::AffinityStealing => {
                let homed: Vec<usize> = arrived
                    .iter()
                    .copied()
                    .filter(|&i| self.home[i] == stack)
                    .collect();
                if homed.is_empty() {
                    arrived
                } else {
                    homed
                }
            }
        }
    }

    fn pick(&mut self, stack: usize, now: f64) -> Option<BlockRef> {
        let elig = self.eligible(stack, now);
        if elig.is_empty() {
            return None;
        }
        let app = match self.fairness {
            FairnessPolicy::Fcfs => elig.into_iter().min_by(|&a, &b| {
                self.arrival[a]
                    .partial_cmp(&self.arrival[b])
                    .expect("arrival times are finite")
                    .then(a.cmp(&b))
            })?,
            FairnessPolicy::RoundRobin => {
                let n = self.queues.len();
                (1..=n)
                    .map(|k| (self.rr_cursor + k) % n)
                    .find(|i| elig.contains(i))?
            }
            FairnessPolicy::LeastIssued => elig.into_iter().min_by_key(|&i| (self.issued[i], i))?,
        };
        self.rr_cursor = app;
        self.issued[app] += 1;
        let block = self.queues[app].pop_front()?;
        Some(BlockRef {
            app: app as u32,
            block,
        })
    }
}

impl BlockSource for MultiKernelSource {
    fn seed(&mut self, topo: &Topology, place: &mut dyn FnMut(usize, usize, BlockRef)) {
        // Breadth-first over SMs, as in the single-kernel path; only
        // already-arrived apps participate at t=0.
        for slot in 0..topo.blocks_per_sm {
            for sm in &topo.sms {
                if let Some(br) = self.pick(sm.stack, 0.0) {
                    place(sm.id, slot, br);
                }
            }
        }
    }

    fn refill(&mut self, sm: Sm, _retired: Option<BlockRef>, now: f64) -> Option<BlockRef> {
        self.pick(sm.stack, now)
    }

    fn next_arrival_after(&self, now: f64) -> Option<f64> {
        self.queues
            .iter()
            .zip(&self.arrival)
            .filter(|(q, &t)| !q.is_empty() && t > now)
            .map(|(_, &t)| t)
            .fold(None, |m, t| {
                Some(match m {
                    None => t,
                    Some(m) => m.min(t),
                })
            })
    }
}

fn run_multi_inner(
    cfg: &SystemConfig,
    apps: &[&BuiltWorkload],
    arrivals: &[f64],
    only_app: Option<usize>,
    placement: MixPlacement,
    policy: Policy,
    fairness: FairnessPolicy,
) -> crate::Result<EngineRaw> {
    let (mut vm, app_bases) = map_mix(cfg, apps, placement)?;
    let app_ctxs: Vec<AppCtx<'_>> = apps
        .iter()
        .zip(&app_bases)
        .map(|(a, b)| AppCtx {
            trace: &a.trace,
            obj_base: b.as_slice(),
        })
        .collect();
    let launches: Vec<(usize, f64)> = apps
        .iter()
        .zip(arrivals)
        .map(|(a, &t)| (a.trace.blocks.len(), t))
        .collect();
    let mut source = MultiKernelSource::new(&launches, cfg, policy, fairness, only_app);
    Ok(Engine {
        cfg,
        apps: app_ctxs,
        vm: &mut vm,
        opts: EngineOptions {
            l2_filter: false,
            migrate_on_first_touch: false,
        },
        host: None,
    }
    .run(&mut source))
}

/// Simulate a multi-kernel mix with time-shared SMs.
///
/// The returned report's `app_cycles` are per-app **response times**
/// (completion − arrival), `app_slowdown` compares each against a
/// run-alone baseline under the same placement and physical layout, and
/// `weighted_speedup` is Σᵢ T_aloneᵢ / T_sharedᵢ (system throughput; N
/// for a mix with no contention, smaller when apps interfere).
pub fn run_multi(
    cfg: &SystemConfig,
    mix: &MultiMix<'_>,
    placement: MixPlacement,
    policy: Policy,
    fairness: FairnessPolicy,
) -> crate::Result<RunReport> {
    let apps: Vec<&BuiltWorkload> = mix.launches.iter().map(|l| l.app).collect();
    let arrivals: Vec<f64> = mix.launches.iter().map(|l| l.arrival).collect();
    for (i, &t) in arrivals.iter().enumerate() {
        anyhow::ensure!(
            t >= 0.0 && t.is_finite(),
            "arrival time of app {i} must be a non-negative real, got {t}"
        );
    }
    let shared = run_multi_inner(cfg, &apps, &arrivals, None, placement, policy, fairness)?;
    // Run-alone baselines: identical mapping (all apps' objects placed),
    // only app i's blocks execute, so the only delta is contention.
    let zero = vec![0.0; apps.len()];
    let mut solo = Vec::with_capacity(apps.len());
    for i in 0..apps.len() {
        let raw = run_multi_inner(cfg, &apps, &zero, Some(i), placement, policy, fairness)?;
        solo.push(raw.app_end[i]);
    }
    let resp: Vec<f64> = (0..apps.len())
        .map(|i| (shared.app_end[i] - arrivals[i]).max(0.0))
        .collect();
    let mut report = shared.to_report(
        cfg,
        apps.iter().map(|a| a.name).collect::<Vec<_>>().join("+"),
    );
    report.mechanism = format!("{placement:?}+{policy:?}+{fairness}");
    report.app_slowdown = stats::per_app_slowdown(&solo, &resp);
    report.weighted_speedup = stats::weighted_speedup(&solo, &resp);
    report.app_cycles = resp;
    Ok(report)
}

/// Simulate a CHoNDA-style co-run: an NDP mix (possibly empty) plus a
/// concurrent host request stream sweeping `host`'s objects.
///
/// The physical layout maps the NDP apps first — exactly as [`run_multi`]
/// would — then the host objects, fine-grain interleaved (FGP is the
/// host's preferred granularity, Fig 13). Because the host pages come
/// last, the NDP side's layout is byte-identical to its `run_multi`
/// layout, which is what makes the two degenerate cases exact:
///
/// * **Zero host intensity** (`host_mlp == 0`, `host_passes == 0`, or
///   `host = None`): the NDP run is cycle-identical (bit-exact f64) to
///   [`run_multi`]'s shared run.
/// * **Host alone** (empty `ndp` mix): the host stream reproduces the
///   legacy `host::run_host_sweep` cycles bit-exactly.
///
/// The report's host fields compare each side against itself running
/// alone **on the same physical layout**: `ndp_slowdown` is the NDP
/// makespan vs the mix without host traffic, `host_slowdown` the host
/// completion vs the stream without NDP kernels, `app_slowdown` /
/// `weighted_speedup` are per-app response times vs the host-free run
/// (so they isolate host interference, unlike [`run_multi`]'s solo-run
/// baselines which isolate app-vs-app interference), and `host_bw_share`
/// is the host's fraction of all bytes the stack DRAMs served.
pub fn run_hostmix(
    cfg: &SystemConfig,
    ndp: &MultiMix<'_>,
    host: Option<&BuiltWorkload>,
    placement: MixPlacement,
    policy: Policy,
    fairness: FairnessPolicy,
) -> crate::Result<RunReport> {
    let apps: Vec<&BuiltWorkload> = ndp.launches.iter().map(|l| l.app).collect();
    let arrivals: Vec<f64> = ndp.launches.iter().map(|l| l.arrival).collect();
    for (i, &t) in arrivals.iter().enumerate() {
        anyhow::ensure!(
            t >= 0.0 && t.is_finite(),
            "arrival time of app {i} must be a non-negative real, got {t}"
        );
    }
    anyhow::ensure!(
        host.is_some() || !apps.is_empty(),
        "hostmix needs a host stream, at least one NDP kernel, or both"
    );
    let host_active = host.is_some() && cfg.host_mlp > 0 && cfg.host_passes > 0;

    // Shared physical layout: NDP apps first (identical to run_multi's
    // layout), host objects after, fine-grain interleaved.
    let (mut vm, app_bases) = map_mix(cfg, &apps, placement)?;
    let host_bases: Vec<u64> = match host {
        Some(h) => {
            let mut bases = Vec::with_capacity(h.trace.objects.len());
            for obj in &h.trace.objects {
                let pages = obj.bytes.div_ceil(cfg.page_size).max(1);
                bases.push(vm.map_fgp(pages)?);
            }
            bases
        }
        None => Vec::new(),
    };
    let launches: Vec<(usize, f64)> = apps
        .iter()
        .zip(&arrivals)
        .map(|(a, &t)| (a.trace.blocks.len(), t))
        .collect();

    let exec = |with_ndp: bool, with_host: bool, vm: &mut VirtualMemory| -> EngineRaw {
        let app_ctxs: Vec<AppCtx<'_>> = if with_ndp {
            apps.iter()
                .zip(&app_bases)
                .map(|(a, b)| AppCtx {
                    trace: &a.trace,
                    obj_base: b.as_slice(),
                })
                .collect()
        } else {
            Vec::new()
        };
        let mut source = MultiKernelSource::new(
            if with_ndp { launches.as_slice() } else { &[] },
            cfg,
            policy,
            fairness,
            None,
        );
        let host_stream = if with_host {
            host.map(|h| HostStream {
                trace: &h.trace,
                obj_base: &host_bases,
            })
        } else {
            None
        };
        Engine {
            cfg,
            apps: app_ctxs,
            vm,
            opts: EngineOptions {
                l2_filter: false,
                migrate_on_first_touch: false,
            },
            host: host_stream,
        }
        .run(&mut source)
    };

    let shared = exec(!apps.is_empty(), host_active, &mut vm);
    // Run-alone baselines over the identical layout, only when both
    // sources actually ran (otherwise shared *is* the run-alone case).
    let both = host_active && !apps.is_empty();
    let ndp_alone = both.then(|| exec(true, false, &mut vm));
    let host_alone = both.then(|| exec(false, true, &mut vm));

    let resp: Vec<f64> = (0..apps.len())
        .map(|i| (shared.app_end[i] - arrivals[i]).max(0.0))
        .collect();
    let n = apps.len();
    let (ndp_slowdown, host_slowdown, app_slowdown, weighted) =
        match (&ndp_alone, &host_alone) {
            (Some(na), Some(ha)) => {
                let resp_alone: Vec<f64> = (0..n)
                    .map(|i| (na.app_end[i] - arrivals[i]).max(0.0))
                    .collect();
                let ndp_sd = if na.end_time > 0.0 {
                    shared.end_time / na.end_time
                } else {
                    1.0
                };
                let host_sd = if ha.host_end > 0.0 {
                    shared.host_end / ha.host_end
                } else {
                    1.0
                };
                (
                    ndp_sd,
                    host_sd,
                    stats::per_app_slowdown(&resp_alone, &resp),
                    stats::weighted_speedup(&resp_alone, &resp),
                )
            }
            // Only one source ran: nothing contended with it.
            _ => (
                if n > 0 { 1.0 } else { 0.0 },
                if host_active { 1.0 } else { 0.0 },
                vec![1.0; n],
                n as f64,
            ),
        };

    let ndp_names = apps.iter().map(|a| a.name).collect::<Vec<_>>().join("+");
    // Only label a host co-runner that actually streamed (zero intensity
    // must not claim a co-run it never executed).
    let workload = match (if host_active { host } else { None }, ndp_names.is_empty()) {
        (Some(h), true) => format!("host:{}", h.name),
        (Some(h), false) => format!("{ndp_names}|host:{}", h.name),
        (None, _) => ndp_names,
    };
    let mut report = shared.to_report(cfg, workload);
    report.mechanism = format!("hostmix:{placement:?}+{policy:?}+{fairness}");
    report.app_cycles = resp;
    report.app_slowdown = app_slowdown;
    report.weighted_speedup = weighted;
    report.ndp_slowdown = ndp_slowdown;
    report.host_slowdown = host_slowdown;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::suite;

    /// Fig 12's claim: CGP-local beats FGP-Only for every mix.
    #[test]
    fn cgp_local_beats_fgp_for_mixes() {
        let cfg = SystemConfig::test_small();
        let a = suite::build("NN", &cfg).unwrap();
        let b = suite::build("KM", &cfg).unwrap();
        let c = suite::build("DC", &cfg).unwrap();
        let d = suite::build("HS", &cfg).unwrap();
        let mix = Mix {
            apps: vec![&a, &b, &c, &d],
        };
        let (_, fgp) = run_mix(&cfg, &mix, MixPlacement::FgpOnly).unwrap();
        let (_, cgp) = run_mix(&cfg, &mix, MixPlacement::CgpLocal).unwrap();
        assert_eq!(cgp.accesses.remote, 0, "home placement removes remote");
        assert!(fgp.accesses.remote > 0);
        assert!(
            cgp.cycles < fgp.cycles,
            "cgp {} vs fgp {}",
            cgp.cycles,
            fgp.cycles
        );
    }

    #[test]
    fn per_app_times_reported() {
        let cfg = SystemConfig::test_small();
        let a = suite::build("NN", &cfg).unwrap();
        let b = suite::build("DC", &cfg).unwrap();
        let mix = Mix { apps: vec![&a, &b] };
        let (times, report) = run_mix(&cfg, &mix, MixPlacement::CgpLocal).unwrap();
        assert_eq!(times.len(), 2);
        assert!(times.iter().all(|&t| t > 0.0));
        assert_eq!(report.app_cycles, times);
    }

    #[test]
    fn oversubscribed_mix_runs_to_completion() {
        // More kernels than stacks: homes wrap, SMs time-share.
        let cfg = SystemConfig::test_small();
        let built: Vec<_> = ["NN", "KM", "DC", "HS", "NN", "DC"]
            .iter()
            .map(|n| suite::build(n, &cfg).unwrap())
            .collect();
        let mix = MultiMix {
            launches: built
                .iter()
                .map(|b| KernelLaunch {
                    app: b,
                    arrival: 0.0,
                })
                .collect(),
        };
        let r = run_multi(
            &cfg,
            &mix,
            MixPlacement::CgpLocal,
            Policy::Affinity,
            FairnessPolicy::RoundRobin,
        )
        .unwrap();
        let total: u64 = built.iter().map(|b| b.total_accesses()).sum();
        assert_eq!(r.accesses.ndp_total(), total, "every block must execute");
        assert_eq!(r.app_cycles.len(), 6);
        assert_eq!(r.app_slowdown.len(), 6);
        assert!(r.app_cycles.iter().all(|&t| t > 0.0));
        assert!(r.app_slowdown.iter().all(|&s| s.is_finite() && s > 0.0));
        assert!(r.weighted_speedup > 0.0 && r.weighted_speedup <= 6.0 + 1e-9);
        // Stacks 0/1 host two apps each; someone must feel the sharing.
        assert!(
            r.app_slowdown.iter().any(|&s| s > 1.0 + 1e-9),
            "oversubscription must show up as slowdown: {:?}",
            r.app_slowdown
        );
    }

    #[test]
    fn rejects_bad_arrival_times() {
        let cfg = SystemConfig::test_small();
        let a = suite::build("NN", &cfg).unwrap();
        let mix = MultiMix {
            launches: vec![KernelLaunch {
                app: &a,
                arrival: -1.0,
            }],
        };
        assert!(run_multi(
            &cfg,
            &mix,
            MixPlacement::CgpLocal,
            Policy::Affinity,
            FairnessPolicy::Fcfs,
        )
        .is_err());
    }

    #[test]
    fn run_mix_rejects_more_apps_than_stacks() {
        let cfg = SystemConfig::test_small();
        let a = suite::build("NN", &cfg).unwrap();
        let app: &BuiltWorkload = &a;
        let mix = Mix {
            apps: vec![app; cfg.num_stacks + 1],
        };
        assert!(run_mix(&cfg, &mix, MixPlacement::CgpLocal).is_err());
    }

    #[test]
    fn placement_parse() {
        assert_eq!(MixPlacement::parse("fgp"), Some(MixPlacement::FgpOnly));
        assert_eq!(MixPlacement::parse("cgp"), Some(MixPlacement::CgpLocal));
        assert_eq!(MixPlacement::parse("x"), None);
    }

    #[test]
    fn hostmix_rejects_empty_run() {
        let cfg = SystemConfig::test_small();
        let mix = MultiMix { launches: vec![] };
        assert!(run_hostmix(
            &cfg,
            &mix,
            None,
            MixPlacement::CgpLocal,
            Policy::Affinity,
            FairnessPolicy::Fcfs,
        )
        .is_err());
    }

    #[test]
    fn hostmix_host_alone_serves_every_line() {
        let cfg = SystemConfig::test_small();
        let h = suite::build("NN", &cfg).unwrap();
        let mix = MultiMix { launches: vec![] };
        let r = run_hostmix(
            &cfg,
            &mix,
            Some(&h),
            MixPlacement::CgpLocal,
            Policy::Affinity,
            FairnessPolicy::Fcfs,
        )
        .unwrap();
        let lines: u64 = h
            .trace
            .objects
            .iter()
            .map(|o| o.bytes.div_ceil(cfg.line_size))
            .sum();
        assert_eq!(r.accesses.host, lines);
        assert_eq!(r.accesses.ndp_total(), 0);
        assert!(r.cycles > 0.0);
        assert_eq!(r.cycles, r.host_cycles);
        assert!((r.host_bw_share - 1.0).abs() < 1e-12, "host owns all bytes");
        assert_eq!(r.host_slowdown, 1.0, "nothing contended with the host");
        assert_eq!(r.ndp_slowdown, 0.0, "no NDP side ran");
        assert_eq!(r.workload, "host:NN");
    }

    #[test]
    fn hostmix_contention_is_reported() {
        let cfg = SystemConfig::test_small();
        let a = suite::build("NN", &cfg).unwrap();
        let h = suite::build("KM", &cfg).unwrap();
        let mix = MultiMix {
            launches: vec![KernelLaunch {
                app: &a,
                arrival: 0.0,
            }],
        };
        let r = run_hostmix(
            &cfg,
            &mix,
            Some(&h),
            MixPlacement::CgpLocal,
            Policy::Affinity,
            FairnessPolicy::Fcfs,
        )
        .unwrap();
        assert!(r.accesses.host > 0 && r.accesses.ndp_total() > 0);
        assert!(r.host_bw_share > 0.0 && r.host_bw_share < 1.0);
        // The host's issue order is fixed, so NDP traffic can only delay
        // it; the NDP side additionally tolerates a hair of block→SM
        // reshuffle noise under the compute-heavy default config.
        assert!(
            r.ndp_slowdown >= 1.0 - 1e-3,
            "ndp slowdown {}",
            r.ndp_slowdown
        );
        assert!(r.host_slowdown >= 1.0, "host slowdown {}", r.host_slowdown);
        assert_eq!(r.app_cycles.len(), 1);
        assert_eq!(r.workload, "NN|host:KM");
    }
}
