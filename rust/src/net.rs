//! The three interconnects of the NDP system (§2.3):
//!
//! * **Local** — SMs to their own stack's HBM (crossbar + TSVs). Highest
//!   bandwidth, lowest latency.
//! * **Host** — host processor to each stack (the processor-centric
//!   topology of Kim et al.). Mid bandwidth.
//! * **Remote** — stack to stack, for NDP accesses to data resident
//!   elsewhere. Lowest bandwidth; the resource CODA exists to avoid.
//!
//! Each directional port is a busy-until server: a transfer occupies the
//! port for `bytes / bw` cycles and then experiences the propagation
//! latency. Queuing delay therefore emerges when traffic concentrates on a
//! port — exactly the congestion behaviour §6.2 discusses.

use crate::config::SystemConfig;

/// A single directional link/port with finite bandwidth.
#[derive(Clone, Debug)]
pub struct Link {
    bytes_per_cycle: f64,
    latency_cycles: f64,
    next_free: f64,
    bytes_sent: u64,
    transfers: u64,
    queued_cycles: f64,
    stalled: u64,
}

impl Link {
    pub fn new(bytes_per_cycle: f64, latency_cycles: f64) -> Self {
        assert!(bytes_per_cycle > 0.0);
        Self {
            bytes_per_cycle,
            latency_cycles,
            next_free: 0.0,
            bytes_sent: 0,
            transfers: 0,
            queued_cycles: 0.0,
            stalled: 0,
        }
    }

    /// Send `bytes` at time `now`; returns delivery completion time.
    ///
    /// This is the per-access interconnect step of the engine's hot path
    /// (one call for local accesses, three for remote round-trips):
    /// always inlined into the `*_hop` wrappers so the busy-until update
    /// never becomes an out-of-line call.
    #[inline(always)]
    pub fn transfer(&mut self, now: f64, bytes: u64) -> f64 {
        let start = now.max(self.next_free);
        if start > now {
            self.stalled += 1;
        }
        self.queued_cycles += start - now;
        let occupancy = bytes as f64 / self.bytes_per_cycle;
        self.next_free = start + occupancy;
        self.bytes_sent += bytes;
        self.transfers += 1;
        start + occupancy + self.latency_cycles
    }

    pub fn bytes_sent(&self) -> u64 {
        self.bytes_sent
    }

    /// Transfers that found the port busy and had to queue behind it
    /// (the port-contention stall count the hostmix report surfaces).
    pub fn stalls(&self) -> u64 {
        self.stalled
    }

    /// Mean queuing delay per transfer, in cycles.
    pub fn mean_queue_delay(&self) -> f64 {
        if self.transfers == 0 {
            0.0
        } else {
            self.queued_cycles / self.transfers as f64
        }
    }

    /// Utilization up to `now` (busy time / wall time).
    pub fn utilization(&self, now: f64) -> f64 {
        if now <= 0.0 {
            0.0
        } else {
            (self.bytes_sent as f64 / self.bytes_per_cycle) / now
        }
    }
}

/// The full interconnect: per-stack local crossbars, per-stack host ports,
/// and per-stack remote ports (ingress + egress).
#[derive(Clone, Debug)]
pub struct Interconnect {
    /// Per-stack local crossbar (SM <-> local HBM), full local bandwidth.
    pub local: Vec<Link>,
    /// Per-stack host port; the aggregate host bandwidth divides evenly.
    pub host: Vec<Link>,
    /// Per-stack remote egress ports.
    pub remote_out: Vec<Link>,
    /// Per-stack remote ingress ports.
    pub remote_in: Vec<Link>,
}

impl Interconnect {
    pub fn new(cfg: &SystemConfig) -> Self {
        let n = cfg.num_stacks;
        let cyc = cfg.cycles_per_ns();
        let local_bw = cfg.gbs_to_bytes_per_cycle(cfg.local_bw_gbs);
        let host_bw = cfg.gbs_to_bytes_per_cycle(cfg.host_bw_gbs) / n as f64;
        let remote_bw = cfg.gbs_to_bytes_per_cycle(cfg.remote_bw_gbs) / n as f64;
        Self {
            local: (0..n)
                .map(|_| Link::new(local_bw, cfg.local_latency_ns * cyc))
                .collect(),
            host: (0..n)
                .map(|_| Link::new(host_bw, cfg.host_latency_ns * cyc))
                .collect(),
            remote_out: (0..n)
                .map(|_| Link::new(remote_bw, cfg.remote_latency_ns * cyc))
                .collect(),
            remote_in: (0..n)
                .map(|_| Link::new(remote_bw, 0.0))
                .collect(),
        }
    }

    /// Deliver a local access: SM in `stack` to its own HBM. Returns the
    /// time the request reaches the DRAM controller.
    #[inline]
    pub fn local_hop(&mut self, now: f64, stack: usize, bytes: u64) -> f64 {
        self.local[stack].transfer(now, bytes)
    }

    /// Deliver a remote access from `src` stack to `dst` stack: egress at
    /// the source, ingress at the destination (two SerDes crossings).
    #[inline]
    pub fn remote_hop(&mut self, now: f64, src: usize, dst: usize, bytes: u64) -> f64 {
        debug_assert_ne!(src, dst);
        let t = self.remote_out[src].transfer(now, bytes);
        self.remote_in[dst].transfer(t, bytes)
    }

    /// Deliver a host access to `stack`.
    #[inline]
    pub fn host_hop(&mut self, now: f64, stack: usize, bytes: u64) -> f64 {
        self.host[stack].transfer(now, bytes)
    }

    /// Total bytes that crossed remote egress ports.
    pub fn remote_bytes(&self) -> u64 {
        self.remote_out.iter().map(|l| l.bytes_sent()).sum()
    }

    /// Total bytes delivered over the per-stack host ports.
    pub fn host_bytes(&self) -> u64 {
        self.host.iter().map(|l| l.bytes_sent()).sum()
    }

    /// Host-port transfers that queued behind a busy port (contention
    /// between the host stream and itself/other traffic on that stack).
    pub fn host_port_stalls(&self) -> u64 {
        self.host.iter().map(|l| l.stalls()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> SystemConfig {
        SystemConfig::default()
    }

    #[test]
    fn link_latency_and_occupancy() {
        let mut l = Link::new(2.0, 10.0); // 2 B/cy, 10cy latency
        let t = l.transfer(0.0, 100);
        assert_eq!(t, 50.0 + 10.0);
        // Second transfer queues behind the first's occupancy (not latency).
        let t2 = l.transfer(0.0, 100);
        assert_eq!(t2, 100.0 + 10.0);
        assert!(l.mean_queue_delay() > 0.0);
    }

    #[test]
    fn remote_is_slower_than_local() {
        let c = cfg();
        let mut net = Interconnect::new(&c);
        let t_local = net.local_hop(0.0, 0, 128);
        let t_remote = net.remote_hop(0.0, 0, 1, 128);
        assert!(
            t_remote > 4.0 * t_local,
            "remote {t_remote} vs local {t_local}"
        );
    }

    #[test]
    fn remote_port_congests() {
        let c = cfg();
        let mut net = Interconnect::new(&c);
        // Many concurrent remote transfers from stack 0 queue on its egress.
        let mut last = 0.0f64;
        for _ in 0..64 {
            last = net.remote_hop(0.0, 0, 1, 128);
        }
        let single = Interconnect::new(&c).remote_hop(0.0, 0, 1, 128);
        assert!(last > 8.0 * single, "queuing must accumulate: {last} vs single {single}");
    }

    #[test]
    fn bandwidth_ratios_match_config() {
        let c = cfg();
        let net = Interconnect::new(&c);
        // local : host-per-stack : remote-per-stack = 256 : 32 : 4 GB/s.
        let u = |l: &Link| l.bytes_per_cycle;
        assert!((u(&net.local[0]) / u(&net.host[0]) - 8.0).abs() < 1e-9);
        assert!((u(&net.host[0]) / u(&net.remote_out[0]) - 8.0).abs() < 1e-9);
    }

    #[test]
    fn utilization_accounting() {
        let mut l = Link::new(1.0, 0.0);
        l.transfer(0.0, 500);
        assert!((l.utilization(1000.0) - 0.5).abs() < 1e-9);
        assert_eq!(l.bytes_sent(), 500);
    }

    #[test]
    fn stall_counting() {
        let mut l = Link::new(1.0, 0.0);
        assert_eq!(l.stalls(), 0);
        l.transfer(0.0, 100); // port free: no stall
        assert_eq!(l.stalls(), 0);
        l.transfer(0.0, 100); // port busy until t=100: stalls
        assert_eq!(l.stalls(), 1);
        l.transfer(500.0, 100); // port free again by t=500
        assert_eq!(l.stalls(), 1);
    }

    #[test]
    fn host_port_accounting() {
        let c = cfg();
        let mut net = Interconnect::new(&c);
        assert_eq!(net.host_bytes(), 0);
        net.host_hop(0.0, 0, 128);
        net.host_hop(0.0, 1, 128);
        net.host_hop(0.0, 0, 128); // queues behind the first stack-0 hop
        assert_eq!(net.host_bytes(), 3 * 128);
        assert_eq!(net.host_port_stalls(), 1);
    }
}
