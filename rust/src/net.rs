//! The three interconnects of the NDP system (§2.3):
//!
//! * **Local** — SMs to their own stack's HBM (crossbar + TSVs). Highest
//!   bandwidth, lowest latency.
//! * **Host** — host processor to each stack (the processor-centric
//!   topology of Kim et al.). Mid bandwidth.
//! * **Remote** — stack to stack, for NDP accesses to data resident
//!   elsewhere. Lowest bandwidth; the resource CODA exists to avoid.
//!
//! The remote side is a route-aware **fabric**: a [`Topology`] enumerates
//! the directed links that physically exist and the route (link sequence)
//! a message from stack `s` to stack `d` crosses. Four topologies are
//! modelled — the degenerate fully-connected switch (the default, and
//! bit-exact to the original point-to-point model), a line, a ring with
//! shortest-direction routing, and a 2D mesh with XY dimension-order
//! routing.
//!
//! Each directional link/port is a busy-until server: a transfer occupies
//! the link for `bytes / bw` cycles and then experiences the propagation
//! latency. Queuing delay therefore emerges when traffic concentrates on
//! a link — exactly the congestion behaviour §6.2 discusses. A multi-hop
//! message advances hop by hop: each link on the route is reserved at the
//! time the previous hop delivered, so an in-flight message pays queuing
//! at every congested link it crosses, at the (future) instant it arrives
//! there.
//!
//! **Sender-stalls-locally invariant.** Only the *first* link on a route
//! is a sender-side resource (the local egress handoff). Once the message
//! has left the egress port, the fabric forwards it autonomously: queuing
//! on downstream links delays *this message*, never the sender's
//! subsequent injections, which contend only for the egress port again.
//! This mirrors event-heap forwarding — each hop is an event scheduled at
//! the previous hop's completion time — without materialising per-hop
//! heap entries on the engine's hot path.
//!
//! **Counter semantics.** Every fabric link counts bytes and stall events
//! (transfers that found the link busy). Multi-hop fabrics additionally
//! track *peak per-window throughput*: wall-clock time is cut into
//! windows of `net_window_cycles` cycles, each transfer's bytes are
//! attributed to the window containing its service *start* time, and the
//! busiest window is reported. Averages understate bursty-link pressure;
//! the peak is what exposes an all-to-one hotspot. Counters never feed
//! back into timing, so enabling them cannot perturb simulated cycles.

use crate::config::SystemConfig;
use crate::stats::LinkStat;

/// Which stack-to-stack fabric shape to simulate.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TopologyKind {
    /// Single-hop switch: per-stack egress + ingress ports, any-to-any.
    /// Bit-exact to the original point-to-point `Interconnect`.
    #[default]
    FullyConnected,
    /// Stacks in a row; messages traverse every intermediate stack.
    Line,
    /// Stacks in a cycle; routes take the shorter direction.
    Ring,
    /// 2D mesh with XY (column-first) dimension-order routing.
    Mesh2d,
}

impl TopologyKind {
    /// Parse the spelling used by `[topology] kind = ...`, `--topology`
    /// and the `topology` config key.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "full" | "fully-connected" | "fully_connected" => Some(Self::FullyConnected),
            "line" => Some(Self::Line),
            "ring" => Some(Self::Ring),
            "mesh" | "mesh2d" => Some(Self::Mesh2d),
            _ => None,
        }
    }
}

impl std::fmt::Display for TopologyKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Self::FullyConnected => "full",
            Self::Line => "line",
            Self::Ring => "ring",
            Self::Mesh2d => "mesh",
        })
    }
}

/// A single directional link/port with finite bandwidth.
#[derive(Clone, Debug)]
pub struct Link {
    bytes_per_cycle: f64,
    latency_cycles: f64,
    next_free: f64,
    bytes_sent: u64,
    transfers: u64,
    queued_cycles: f64,
    stalled: u64,
    /// Peak-throughput window length in cycles; 0.0 disables tracking
    /// (local/host/degenerate links pay nothing for the feature).
    window_cycles: f64,
    window_start: f64,
    window_bytes: u64,
    peak_window_bytes: u64,
}

impl Link {
    pub fn new(bytes_per_cycle: f64, latency_cycles: f64) -> Self {
        Self::with_window(bytes_per_cycle, latency_cycles, 0.0)
    }

    /// A link that additionally tracks its busiest `window_cycles`-cycle
    /// window (pass 0.0 to disable, identical to [`Link::new`]).
    pub fn with_window(bytes_per_cycle: f64, latency_cycles: f64, window_cycles: f64) -> Self {
        assert!(bytes_per_cycle > 0.0);
        Self {
            bytes_per_cycle,
            latency_cycles,
            next_free: 0.0,
            bytes_sent: 0,
            transfers: 0,
            queued_cycles: 0.0,
            stalled: 0,
            window_cycles,
            window_start: 0.0,
            window_bytes: 0,
            peak_window_bytes: 0,
        }
    }

    /// Send `bytes` at time `now`; returns delivery completion time.
    ///
    /// This is the per-access interconnect step of the engine's hot path
    /// (one call for local accesses, one per route hop for remote
    /// round-trips): always inlined into the `*_hop` wrappers so the
    /// busy-until update never becomes an out-of-line call. The timing
    /// arithmetic is frozen — window tracking below is counters-only and
    /// must never feed back into the returned time.
    #[inline(always)]
    pub fn transfer(&mut self, now: f64, bytes: u64) -> f64 {
        let start = now.max(self.next_free);
        if start > now {
            self.stalled += 1;
        }
        self.queued_cycles += start - now;
        let occupancy = bytes as f64 / self.bytes_per_cycle;
        self.next_free = start + occupancy;
        self.bytes_sent += bytes;
        self.transfers += 1;
        if self.window_cycles > 0.0 {
            // Attribute the whole transfer to the window containing its
            // service start. Route chaining hands links future
            // timestamps, so starts are not globally monotonic; a start
            // before the current window (possible when a now-time
            // transfer interleaves with a chained future one) is folded
            // into the current window — a deliberate approximation that
            // can only *under*state a past window's peak, never invent
            // load.
            if start >= self.window_start + self.window_cycles {
                let k = ((start - self.window_start) / self.window_cycles).floor();
                self.window_start += k * self.window_cycles;
                self.peak_window_bytes = self.peak_window_bytes.max(self.window_bytes);
                self.window_bytes = 0;
            }
            self.window_bytes += bytes;
        }
        start + occupancy + self.latency_cycles
    }

    pub fn bytes_sent(&self) -> u64 {
        self.bytes_sent
    }

    /// Transfers that found the port busy and had to queue behind it
    /// (the port-contention stall count the hostmix report surfaces).
    pub fn stalls(&self) -> u64 {
        self.stalled
    }

    /// Mean queuing delay per transfer, in cycles.
    pub fn mean_queue_delay(&self) -> f64 {
        if self.transfers == 0 {
            0.0
        } else {
            self.queued_cycles / self.transfers as f64
        }
    }

    /// Utilization up to `now` (busy time / wall time).
    pub fn utilization(&self, now: f64) -> f64 {
        if now <= 0.0 {
            0.0
        } else {
            (self.bytes_sent as f64 / self.bytes_per_cycle) / now
        }
    }

    /// Bytes of the busiest observed window (includes the still-open
    /// window); 0 when window tracking is disabled.
    pub fn peak_window_bytes(&self) -> u64 {
        self.peak_window_bytes.max(self.window_bytes)
    }
}

/// A directed link a [`Topology`] declares: endpoints plus physical
/// parameters. `from`/`to` are stack ids; the fully-connected switch uses
/// the pseudo-node id `num_stacks` for its central crossbar.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DirectedLink {
    pub from: usize,
    pub to: usize,
    pub bytes_per_cycle: f64,
    pub latency_cycles: f64,
}

/// A stack-to-stack fabric shape: which directed links exist, and which
/// sequence of them a message crosses. Routes are precomputed at
/// construction; lookups are allocation-free slices of link indices into
/// [`Topology::links`].
pub trait Topology {
    fn kind(&self) -> TopologyKind;
    /// Every directed link in the fabric; a link's id is its index here.
    fn links(&self) -> &[DirectedLink];
    /// The route from `from` to `to` as directed-link ids, in crossing
    /// order. Empty iff `from == to`.
    fn get_route(&self, from: usize, to: usize) -> &[u32];
}

/// Flattened `n*n` route table shared by every topology implementation.
#[derive(Clone, Debug)]
struct RouteTable {
    n: usize,
    offsets: Vec<u32>,
    hops: Vec<u32>,
}

impl RouteTable {
    /// Build from a per-pair route generator (called once per ordered
    /// pair; `from == to` pairs get empty routes).
    fn build(n: usize, mut route_of: impl FnMut(usize, usize) -> Vec<u32>) -> Self {
        let mut offsets = Vec::with_capacity(n * n + 1);
        let mut hops = Vec::new();
        for s in 0..n {
            for d in 0..n {
                offsets.push(hops.len() as u32);
                if s != d {
                    hops.extend(route_of(s, d));
                }
            }
        }
        offsets.push(hops.len() as u32);
        Self { n, offsets, hops }
    }

    #[inline]
    fn get(&self, from: usize, to: usize) -> &[u32] {
        let i = from * self.n + to;
        &self.hops[self.offsets[i] as usize..self.offsets[i + 1] as usize]
    }
}

/// Per-link parameters for the multi-hop fabrics: `link_bw_gbs` when set,
/// otherwise the frozen aggregate-divided-by-`n` per-port share; per-hop
/// latency from `hop_latency_ns`.
fn hop_params(cfg: &SystemConfig) -> (f64, f64) {
    let bw = if cfg.link_bw_gbs > 0.0 {
        cfg.gbs_to_bytes_per_cycle(cfg.link_bw_gbs)
    } else {
        cfg.gbs_to_bytes_per_cycle(cfg.remote_bw_gbs) / cfg.num_stacks as f64
    };
    (bw, cfg.hop_latency_ns * cfg.cycles_per_ns())
}

/// The degenerate single-hop switch: per-stack egress ports into a
/// central crossbar (pseudo-node `n`) and per-stack ingress ports out of
/// it. Link parameters and route order reproduce the original
/// point-to-point `Interconnect` exactly: egress carries the remote
/// latency, ingress is latency-free, both get the aggregate remote
/// bandwidth divided by `num_stacks`.
pub struct FullyConnected {
    links: Vec<DirectedLink>,
    routes: RouteTable,
}

impl FullyConnected {
    pub fn new(cfg: &SystemConfig) -> Self {
        let n = cfg.num_stacks;
        let cyc = cfg.cycles_per_ns();
        let remote_bw = cfg.gbs_to_bytes_per_cycle(cfg.remote_bw_gbs) / n as f64;
        let mut links = Vec::with_capacity(2 * n);
        for i in 0..n {
            // Egress of stack i (link id i).
            links.push(DirectedLink {
                from: i,
                to: n,
                bytes_per_cycle: remote_bw,
                latency_cycles: cfg.remote_latency_ns * cyc,
            });
        }
        for i in 0..n {
            // Ingress of stack i (link id n + i).
            links.push(DirectedLink {
                from: n,
                to: i,
                bytes_per_cycle: remote_bw,
                latency_cycles: 0.0,
            });
        }
        let routes = RouteTable::build(n, |s, d| vec![s as u32, (n + d) as u32]);
        Self { links, routes }
    }
}

impl Topology for FullyConnected {
    fn kind(&self) -> TopologyKind {
        TopologyKind::FullyConnected
    }
    fn links(&self) -> &[DirectedLink] {
        &self.links
    }
    fn get_route(&self, from: usize, to: usize) -> &[u32] {
        self.routes.get(from, to)
    }
}

/// Stacks in a row: bidirectional channels between neighbours, messages
/// traverse every intermediate stack.
pub struct Line {
    links: Vec<DirectedLink>,
    routes: RouteTable,
}

impl Line {
    pub fn new(cfg: &SystemConfig) -> Self {
        let n = cfg.num_stacks;
        let (bw, lat) = hop_params(cfg);
        let mut links = Vec::new();
        for i in 0..n.saturating_sub(1) {
            // Link id 2i: i -> i+1 (rightward); 2i+1: i+1 -> i (leftward).
            links.push(DirectedLink {
                from: i,
                to: i + 1,
                bytes_per_cycle: bw,
                latency_cycles: lat,
            });
            links.push(DirectedLink {
                from: i + 1,
                to: i,
                bytes_per_cycle: bw,
                latency_cycles: lat,
            });
        }
        let routes = RouteTable::build(n, |s, d| {
            let mut route = Vec::new();
            let mut u = s;
            while u != d {
                if d > u {
                    route.push(2 * u as u32);
                    u += 1;
                } else {
                    route.push(2 * (u - 1) as u32 + 1);
                    u -= 1;
                }
            }
            route
        });
        Self { links, routes }
    }
}

impl Topology for Line {
    fn kind(&self) -> TopologyKind {
        TopologyKind::Line
    }
    fn links(&self) -> &[DirectedLink] {
        &self.links
    }
    fn get_route(&self, from: usize, to: usize) -> &[u32] {
        self.routes.get(from, to)
    }
}

/// Stacks in a cycle: clockwise link id `i` is `i -> (i+1) % n`,
/// counter-clockwise id `n + i` is `i -> (i+n-1) % n`. Routes take the
/// shorter direction; ties go clockwise.
pub struct Ring {
    links: Vec<DirectedLink>,
    routes: RouteTable,
}

impl Ring {
    pub fn new(cfg: &SystemConfig) -> Self {
        let n = cfg.num_stacks;
        let (bw, lat) = hop_params(cfg);
        let mut links = Vec::new();
        if n > 1 {
            for i in 0..n {
                links.push(DirectedLink {
                    from: i,
                    to: (i + 1) % n,
                    bytes_per_cycle: bw,
                    latency_cycles: lat,
                });
            }
            for i in 0..n {
                links.push(DirectedLink {
                    from: i,
                    to: (i + n - 1) % n,
                    bytes_per_cycle: bw,
                    latency_cycles: lat,
                });
            }
        }
        let routes = RouteTable::build(n, |s, d| {
            let cw = (d + n - s) % n;
            let ccw = (s + n - d) % n;
            let mut route = Vec::new();
            let mut u = s;
            if cw <= ccw {
                for _ in 0..cw {
                    route.push(u as u32);
                    u = (u + 1) % n;
                }
            } else {
                for _ in 0..ccw {
                    route.push((n + u) as u32);
                    u = (u + n - 1) % n;
                }
            }
            route
        });
        Self { links, routes }
    }
}

impl Topology for Ring {
    fn kind(&self) -> TopologyKind {
        TopologyKind::Ring
    }
    fn links(&self) -> &[DirectedLink] {
        &self.links
    }
    fn get_route(&self, from: usize, to: usize) -> &[u32] {
        self.routes.get(from, to)
    }
}

/// 2D mesh, stack id = `row * cols + col`, with XY dimension-order
/// routing (column-first, then row) — deadlock-free and deterministic.
/// `mesh_cols = 0` picks the near-square factorisation.
pub struct Mesh2d {
    links: Vec<DirectedLink>,
    routes: RouteTable,
}

/// The widest column count `<= sqrt(n)` that divides `n` evenly.
pub fn mesh_auto_cols(n: usize) -> usize {
    let mut c = (n as f64).sqrt().floor() as usize;
    c = c.clamp(1, n);
    while n % c != 0 {
        c -= 1;
    }
    c
}

impl Mesh2d {
    pub fn new(cfg: &SystemConfig) -> Self {
        let n = cfg.num_stacks;
        let cols = if cfg.mesh_cols == 0 {
            mesh_auto_cols(n)
        } else {
            cfg.mesh_cols
        };
        assert!(
            cols >= 1 && cols <= n && n % cols == 0,
            "mesh_cols {cols} does not tile num_stacks {n}"
        );
        let rows = n / cols;
        let (bw, lat) = hop_params(cfg);
        let mut links = Vec::new();
        // Deterministic enumeration: row-major, east/west pair then
        // south/north pair.
        let mut adj = vec![u32::MAX; n * n];
        let mut push = |links: &mut Vec<DirectedLink>, adj: &mut Vec<u32>, a: usize, b: usize| {
            adj[a * n + b] = links.len() as u32;
            links.push(DirectedLink {
                from: a,
                to: b,
                bytes_per_cycle: bw,
                latency_cycles: lat,
            });
        };
        for r in 0..rows {
            for c in 0..cols {
                let u = r * cols + c;
                if c + 1 < cols {
                    push(&mut links, &mut adj, u, u + 1);
                    push(&mut links, &mut adj, u + 1, u);
                }
                if r + 1 < rows {
                    push(&mut links, &mut adj, u, u + cols);
                    push(&mut links, &mut adj, u + cols, u);
                }
            }
        }
        let routes = RouteTable::build(n, |s, d| {
            let (mut r0, mut c0) = (s / cols, s % cols);
            let (r1, c1) = (d / cols, d % cols);
            let mut route = Vec::new();
            while c0 != c1 {
                let next = if c1 > c0 { c0 + 1 } else { c0 - 1 };
                route.push(adj[(r0 * cols + c0) * n + (r0 * cols + next)]);
                c0 = next;
            }
            while r0 != r1 {
                let next = if r1 > r0 { r0 + 1 } else { r0 - 1 };
                route.push(adj[(r0 * cols + c0) * n + (next * cols + c0)]);
                r0 = next;
            }
            debug_assert!(route.iter().all(|&l| l != u32::MAX));
            route
        });
        Self { links, routes }
    }
}

impl Topology for Mesh2d {
    fn kind(&self) -> TopologyKind {
        TopologyKind::Mesh2d
    }
    fn links(&self) -> &[DirectedLink] {
        &self.links
    }
    fn get_route(&self, from: usize, to: usize) -> &[u32] {
        self.routes.get(from, to)
    }
}

/// Construct the topology selected by `cfg.topology`.
pub fn make_topology(cfg: &SystemConfig) -> Box<dyn Topology> {
    match cfg.topology {
        TopologyKind::FullyConnected => Box::new(FullyConnected::new(cfg)),
        TopologyKind::Line => Box::new(Line::new(cfg)),
        TopologyKind::Ring => Box::new(Ring::new(cfg)),
        TopologyKind::Mesh2d => Box::new(Mesh2d::new(cfg)),
    }
}

/// The full interconnect: per-stack local crossbars, per-stack host
/// ports, and the stack-to-stack fabric. The topology is consulted once
/// at construction and flattened into plain arrays (link servers + route
/// table), so the engine's hot path folds `Link::transfer` along a route
/// slice with no dynamic dispatch.
#[derive(Clone, Debug)]
pub struct Interconnect {
    /// Per-stack local crossbar (SM <-> local HBM), full local bandwidth.
    pub local: Vec<Link>,
    /// Per-stack host port; the aggregate host bandwidth divides evenly.
    pub host: Vec<Link>,
    kind: TopologyKind,
    num_stacks: usize,
    /// Static descriptors of the fabric's directed links (from topology).
    link_meta: Vec<DirectedLink>,
    /// Busy-until server per directed link, same indexing as `link_meta`.
    fabric: Vec<Link>,
    /// Flattened `n*n` routes: `route_hops[offsets[s*n+d]..offsets[s*n+d+1]]`.
    route_offsets: Vec<u32>,
    route_hops: Vec<u32>,
    /// Bytes injected into the fabric (one count per `remote_hop`, not
    /// per crossed link — the frozen `remote_bytes` definition).
    injected_bytes: u64,
}

impl Interconnect {
    pub fn new(cfg: &SystemConfig) -> Self {
        let n = cfg.num_stacks;
        let cyc = cfg.cycles_per_ns();
        let local_bw = cfg.gbs_to_bytes_per_cycle(cfg.local_bw_gbs);
        let host_bw = cfg.gbs_to_bytes_per_cycle(cfg.host_bw_gbs) / n as f64;
        let topo = make_topology(cfg);
        // Peak-window tracking is free to enable (counters only), but the
        // degenerate fabric skips it so the frozen hot path stays
        // branch-identical too.
        let window = if topo.kind() == TopologyKind::FullyConnected {
            0.0
        } else {
            cfg.net_window_cycles
        };
        let fabric = topo
            .links()
            .iter()
            .map(|d| Link::with_window(d.bytes_per_cycle, d.latency_cycles, window))
            .collect();
        let mut route_offsets = Vec::with_capacity(n * n + 1);
        let mut route_hops = Vec::new();
        for s in 0..n {
            for d in 0..n {
                route_offsets.push(route_hops.len() as u32);
                route_hops.extend_from_slice(topo.get_route(s, d));
            }
        }
        route_offsets.push(route_hops.len() as u32);
        Self {
            local: (0..n)
                .map(|_| Link::new(local_bw, cfg.local_latency_ns * cyc))
                .collect(),
            host: (0..n)
                .map(|_| Link::new(host_bw, cfg.host_latency_ns * cyc))
                .collect(),
            kind: topo.kind(),
            num_stacks: n,
            link_meta: topo.links().to_vec(),
            fabric,
            route_offsets,
            route_hops,
            injected_bytes: 0,
        }
    }

    /// Deliver a local access: SM in `stack` to its own HBM. Returns the
    /// time the request reaches the DRAM controller.
    #[inline]
    pub fn local_hop(&mut self, now: f64, stack: usize, bytes: u64) -> f64 {
        self.local[stack].transfer(now, bytes)
    }

    /// Deliver a remote message from `src` stack to `dst` stack: fold the
    /// busy-until transfer along the precomputed route, each hop starting
    /// when the previous one delivered. Under the degenerate
    /// fully-connected fabric this is exactly the frozen two-transfer
    /// chain (source egress, then destination ingress).
    #[inline]
    pub fn remote_hop(&mut self, now: f64, src: usize, dst: usize, bytes: u64) -> f64 {
        debug_assert_ne!(src, dst);
        self.injected_bytes += bytes;
        let i = src * self.num_stacks + dst;
        let lo = self.route_offsets[i] as usize;
        let hi = self.route_offsets[i + 1] as usize;
        let mut t = now;
        for h in lo..hi {
            let link = self.route_hops[h] as usize;
            t = self.fabric[link].transfer(t, bytes);
        }
        t
    }

    /// Deliver a host access to `stack`.
    #[inline]
    pub fn host_hop(&mut self, now: f64, stack: usize, bytes: u64) -> f64 {
        self.host[stack].transfer(now, bytes)
    }

    /// Total bytes injected into the stack-to-stack fabric (counted once
    /// per message, independent of route length — identical to the
    /// original per-egress accounting under the degenerate fabric).
    pub fn remote_bytes(&self) -> u64 {
        self.injected_bytes
    }

    /// Total bytes delivered over the per-stack host ports.
    pub fn host_bytes(&self) -> u64 {
        self.host.iter().map(|l| l.bytes_sent()).sum()
    }

    /// Host-port transfers that queued behind a busy port (contention
    /// between the host stream and itself/other traffic on that stack).
    pub fn host_port_stalls(&self) -> u64 {
        self.host.iter().map(|l| l.stalls()).sum()
    }

    /// The fabric shape this interconnect was built with.
    pub fn topology_kind(&self) -> TopologyKind {
        self.kind
    }

    // --- Cross-shard seam (see `crate::shard`). The sharded engine walks
    // routes hop by hop so a message can cross shard boundaries between
    // links; these accessors expose exactly the pieces `remote_hop`
    // composes, with identical timing arithmetic.

    /// Count one message injection into the fabric without transferring
    /// anything. The sharded engine charges the injection on the issuing
    /// side and then crosses each route link via [`Self::hop_transfer`]
    /// (possibly on other shards); `inject_remote` + per-link
    /// `hop_transfer` along the route is byte- and time-identical to one
    /// [`Self::remote_hop`] call.
    #[inline]
    pub fn inject_remote(&mut self, bytes: u64) {
        self.injected_bytes += bytes;
    }

    /// Transfer `bytes` over one fabric link by id, returning the
    /// delivery time ([`Link::transfer`] exactly — `remote_hop` is a fold
    /// of this along a route).
    #[inline]
    pub fn hop_transfer(&mut self, link: u32, now: f64, bytes: u64) -> f64 {
        self.fabric[link as usize].transfer(now, bytes)
    }

    /// The precomputed route from `src` to `dst` as fabric link ids in
    /// crossing order (empty iff `src == dst`).
    #[inline]
    pub fn route_of(&self, src: usize, dst: usize) -> &[u32] {
        let i = src * self.num_stacks + dst;
        &self.route_hops[self.route_offsets[i] as usize..self.route_offsets[i + 1] as usize]
    }

    /// The flattened route table `(offsets, hops)` — `route_of` for every
    /// ordered pair at once, for callers that need to walk routes while
    /// holding `&mut self` for the link servers (the sharded engine keeps
    /// its own copy for exactly that reason).
    pub fn routes(&self) -> (Vec<u32>, Vec<u32>) {
        (self.route_offsets.clone(), self.route_hops.clone())
    }

    /// Static descriptors of the fabric's directed links (the topology's
    /// `links()`, same indexing as the link servers).
    pub fn links_meta(&self) -> &[DirectedLink] {
        &self.link_meta
    }

    /// Conservative-lookahead bound for sharded simulation: the minimum
    /// first-link latency over every ordered stack pair whose endpoints
    /// live on different shards (`owner` maps stack id to shard). The
    /// first link of any route is the issuing side's egress, so a request
    /// issued at `now` cannot reach another shard before
    /// `now + returned bound`. Returns `+inf` when no pair crosses shards
    /// and `0.0` when some crossing route starts with a latency-free link
    /// (no usable lookahead — callers must fall back to sequential).
    pub fn min_cross_shard_latency(&self, owner: &[usize]) -> f64 {
        debug_assert_eq!(owner.len(), self.num_stacks);
        let mut bound = f64::INFINITY;
        for s in 0..self.num_stacks {
            for d in 0..self.num_stacks {
                if s == d || owner[s] == owner[d] {
                    continue;
                }
                if let Some(&first) = self.route_of(s, d).first() {
                    bound = bound.min(self.link_meta[first as usize].latency_cycles);
                }
            }
        }
        bound
    }

    /// Per-directed-link fabric counters. Empty under the degenerate
    /// fully-connected fabric, whose reports must stay byte-identical to
    /// the pre-fabric model; multi-hop fabrics report every link.
    pub fn link_stats(&self) -> Vec<LinkStat> {
        if self.kind == TopologyKind::FullyConnected {
            return Vec::new();
        }
        self.link_meta
            .iter()
            .zip(&self.fabric)
            .map(|(m, l)| LinkStat {
                from: m.from,
                to: m.to,
                bytes: l.bytes_sent(),
                stalls: l.stalls(),
                peak_window_bytes: l.peak_window_bytes(),
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> SystemConfig {
        SystemConfig::default()
    }

    fn cfg_with(kind: TopologyKind) -> SystemConfig {
        let mut c = cfg();
        c.topology = kind;
        c
    }

    #[test]
    fn link_latency_and_occupancy() {
        let mut l = Link::new(2.0, 10.0); // 2 B/cy, 10cy latency
        let t = l.transfer(0.0, 100);
        assert_eq!(t, 50.0 + 10.0);
        // Second transfer queues behind the first's occupancy (not latency).
        let t2 = l.transfer(0.0, 100);
        assert_eq!(t2, 100.0 + 10.0);
        assert!(l.mean_queue_delay() > 0.0);
    }

    #[test]
    fn remote_is_slower_than_local() {
        let c = cfg();
        let mut net = Interconnect::new(&c);
        let t_local = net.local_hop(0.0, 0, 128);
        let t_remote = net.remote_hop(0.0, 0, 1, 128);
        assert!(
            t_remote > 4.0 * t_local,
            "remote {t_remote} vs local {t_local}"
        );
    }

    #[test]
    fn remote_port_congests() {
        let c = cfg();
        let mut net = Interconnect::new(&c);
        // Many concurrent remote transfers from stack 0 queue on its egress.
        let mut last = 0.0f64;
        for _ in 0..64 {
            last = net.remote_hop(0.0, 0, 1, 128);
        }
        let single = Interconnect::new(&c).remote_hop(0.0, 0, 1, 128);
        assert!(last > 8.0 * single, "queuing must accumulate: {last} vs single {single}");
    }

    #[test]
    fn bandwidth_ratios_match_config() {
        let c = cfg();
        let net = Interconnect::new(&c);
        // local : host-per-stack : remote-per-stack = 256 : 32 : 4 GB/s.
        let u = |l: &Link| l.bytes_per_cycle;
        assert!((u(&net.local[0]) / u(&net.host[0]) - 8.0).abs() < 1e-9);
        // Fabric link 0 is stack 0's egress port under the degenerate
        // fully-connected topology.
        assert!((u(&net.host[0]) / u(&net.fabric[0]) - 8.0).abs() < 1e-9);
    }

    #[test]
    fn utilization_accounting() {
        let mut l = Link::new(1.0, 0.0);
        l.transfer(0.0, 500);
        assert!((l.utilization(1000.0) - 0.5).abs() < 1e-9);
        assert_eq!(l.bytes_sent(), 500);
    }

    #[test]
    fn stall_counting() {
        let mut l = Link::new(1.0, 0.0);
        assert_eq!(l.stalls(), 0);
        l.transfer(0.0, 100); // port free: no stall
        assert_eq!(l.stalls(), 0);
        l.transfer(0.0, 100); // port busy until t=100: stalls
        assert_eq!(l.stalls(), 1);
        l.transfer(500.0, 100); // port free again by t=500
        assert_eq!(l.stalls(), 1);
    }

    #[test]
    fn host_port_accounting() {
        let c = cfg();
        let mut net = Interconnect::new(&c);
        assert_eq!(net.host_bytes(), 0);
        net.host_hop(0.0, 0, 128);
        net.host_hop(0.0, 1, 128);
        net.host_hop(0.0, 0, 128); // queues behind the first stack-0 hop
        assert_eq!(net.host_bytes(), 3 * 128);
        assert_eq!(net.host_port_stalls(), 1);
    }

    #[test]
    fn peak_window_tracking() {
        let mut l = Link::with_window(1.0, 0.0, 100.0);
        // Window [0, 100): two transfers, 150 bytes total.
        l.transfer(0.0, 100);
        l.transfer(10.0, 50);
        // Window [200, 300): one transfer.
        l.transfer(250.0, 40);
        assert_eq!(l.peak_window_bytes(), 150);
        // A bigger window later becomes the new peak.
        l.transfer(300.0, 160);
        assert_eq!(l.peak_window_bytes(), 160);
        // Disabled tracking reports zero.
        let mut off = Link::new(1.0, 0.0);
        off.transfer(0.0, 1000);
        assert_eq!(off.peak_window_bytes(), 0);
    }

    #[test]
    fn window_tracking_never_changes_timing() {
        let mut a = Link::new(2.0, 7.0);
        let mut b = Link::with_window(2.0, 7.0, 64.0);
        let mut x = 0x1234_5678_u64;
        for _ in 0..200 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let now = (x >> 40) as f64;
            let bytes = 1 + (x & 0x3FF);
            assert_eq!(
                a.transfer(now, bytes).to_bits(),
                b.transfer(now, bytes).to_bits()
            );
        }
    }

    #[test]
    fn fully_connected_routes_are_egress_then_ingress() {
        let c = cfg();
        let topo = FullyConnected::new(&c);
        let n = c.num_stacks;
        for s in 0..n {
            for d in 0..n {
                if s == d {
                    assert!(topo.get_route(s, d).is_empty());
                } else {
                    assert_eq!(topo.get_route(s, d), &[s as u32, (n + d) as u32]);
                }
            }
        }
        assert_eq!(topo.links().len(), 2 * n);
    }

    #[test]
    fn line_routes_walk_every_intermediate_stack() {
        let c = cfg_with(TopologyKind::Line);
        let topo = Line::new(&c);
        let n = c.num_stacks;
        assert_eq!(topo.links().len(), 2 * (n - 1));
        // 0 -> n-1 crosses every rightward link in order.
        let right: Vec<u32> = (0..n - 1).map(|i| 2 * i as u32).collect();
        assert_eq!(topo.get_route(0, n - 1), &right[..]);
        // n-1 -> 0 crosses every leftward link.
        let left: Vec<u32> = (0..n - 1).rev().map(|i| 2 * i as u32 + 1).collect();
        assert_eq!(topo.get_route(n - 1, 0), &left[..]);
        // Endpoints match up along every route.
        for s in 0..n {
            for d in 0..n {
                let route = topo.get_route(s, d);
                assert_eq!(route.len(), s.abs_diff(d));
                let mut at = s;
                for &l in route {
                    let link = topo.links()[l as usize];
                    assert_eq!(link.from, at);
                    at = link.to;
                }
                assert_eq!(at, d);
            }
        }
    }

    #[test]
    fn ring_routes_take_shorter_direction() {
        let c = cfg_with(TopologyKind::Ring); // num_stacks = 4
        let topo = Ring::new(&c);
        let n = c.num_stacks;
        assert_eq!(topo.links().len(), 2 * n);
        // Adjacent: one clockwise hop.
        assert_eq!(topo.get_route(0, 1), &[0]);
        // Opposite side (tie): clockwise by convention.
        assert_eq!(topo.get_route(0, 2).len(), n / 2);
        assert_eq!(topo.get_route(0, 2), &[0, 1]);
        // Counter-clockwise is shorter for 0 -> 3.
        assert_eq!(topo.get_route(0, 3), &[n as u32]);
        // Every route is at most n/2 hops and endpoint-consistent.
        for s in 0..n {
            for d in 0..n {
                let route = topo.get_route(s, d);
                assert!(route.len() <= n / 2);
                let mut at = s;
                for &l in route {
                    let link = topo.links()[l as usize];
                    assert_eq!(link.from, at);
                    at = link.to;
                }
                assert_eq!(at, d);
            }
        }
    }

    #[test]
    fn mesh_routes_are_xy_order() {
        let mut c = cfg_with(TopologyKind::Mesh2d);
        c.num_stacks = 4; // auto 2x2
        let topo = Mesh2d::new(&c);
        // 2x2 mesh: 4 bidirectional channels = 8 directed links.
        assert_eq!(topo.links().len(), 8);
        for s in 0..4 {
            for d in 0..4 {
                let route = topo.get_route(s, d);
                let (r0, c0) = (s / 2, s % 2);
                let (r1, c1) = (d / 2, d % 2);
                assert_eq!(route.len(), r0.abs_diff(r1) + c0.abs_diff(c1));
                let mut at = s;
                for (i, &l) in route.iter().enumerate() {
                    let link = topo.links()[l as usize];
                    assert_eq!(link.from, at);
                    // XY: column moves strictly precede row moves.
                    let col_move = link.to.abs_diff(link.from) == 1;
                    if i > 0 && !col_move {
                        // Once a row move happens, no further column moves.
                        let rest = &route[i..];
                        assert!(rest.iter().all(|&m| {
                            let lm = topo.links()[m as usize];
                            lm.to.abs_diff(lm.from) != 1
                        }));
                    }
                    at = link.to;
                }
                assert_eq!(at, d);
            }
        }
    }

    #[test]
    fn mesh_auto_cols_is_near_square_divisor() {
        assert_eq!(mesh_auto_cols(1), 1);
        assert_eq!(mesh_auto_cols(2), 1);
        assert_eq!(mesh_auto_cols(4), 2);
        assert_eq!(mesh_auto_cols(6), 2);
        assert_eq!(mesh_auto_cols(8), 2);
        assert_eq!(mesh_auto_cols(9), 3);
        assert_eq!(mesh_auto_cols(12), 3);
        assert_eq!(mesh_auto_cols(16), 4);
    }

    #[test]
    fn multi_hop_pays_per_hop_latency() {
        let c = cfg_with(TopologyKind::Line);
        let mut net = Interconnect::new(&c);
        let n = c.num_stacks;
        let (bw, lat) = hop_params(&c);
        let t = net.remote_hop(0.0, 0, n - 1, 128);
        let expect = (n - 1) as f64 * (128.0 / bw + lat);
        assert!((t - expect).abs() < 1e-9, "{t} vs {expect}");
    }

    #[test]
    fn all_to_one_line_traffic_shows_hotspot_on_last_link() {
        let mut c = cfg_with(TopologyKind::Line);
        c.net_window_cycles = 1e9; // one window: peak == total
        let mut net = Interconnect::new(&c);
        let n = c.num_stacks;
        for src in 1..n {
            for _ in 0..32 {
                net.remote_hop(0.0, src, 0, 128);
            }
        }
        let stats = net.link_stats();
        // The 1 -> 0 link carries every message; the far links only their
        // own stack's share.
        let into0 = stats.iter().find(|l| l.from == 1 && l.to == 0).unwrap();
        assert_eq!(into0.bytes, 32 * 128 * (n as u64 - 1));
        let far = stats
            .iter()
            .find(|l| l.from == n - 1 && l.to == n - 2)
            .unwrap();
        assert_eq!(far.bytes, 32 * 128);
        assert!(into0.stalls > 0);
        assert_eq!(into0.peak_window_bytes, into0.bytes);
    }

    #[test]
    fn hop_transfer_chain_matches_remote_hop() {
        // inject_remote + per-link hop_transfer must be bit-identical to
        // one remote_hop call — that is the sharded engine's contract.
        for kind in [
            TopologyKind::FullyConnected,
            TopologyKind::Line,
            TopologyKind::Ring,
            TopologyKind::Mesh2d,
        ] {
            let c = cfg_with(kind);
            let n = c.num_stacks;
            let mut whole = Interconnect::new(&c);
            let mut split = Interconnect::new(&c);
            let mut x = 0x5EED_u64;
            for _ in 0..64 {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
                let s = (x >> 8) as usize % n;
                let d = (s + 1 + (x >> 16) as usize % (n - 1)) % n;
                let now = (x >> 48) as f64;
                let a = whole.remote_hop(now, s, d, 128);
                split.inject_remote(128);
                let route: Vec<u32> = split.route_of(s, d).to_vec();
                let mut t = now;
                for link in route {
                    t = split.hop_transfer(link, t, 128);
                }
                assert_eq!(a.to_bits(), t.to_bits(), "{kind:?} {s}->{d}");
                assert_eq!(whole.remote_bytes(), split.remote_bytes());
            }
        }
    }

    #[test]
    fn cross_shard_lookahead_bound() {
        // Degenerate fabric: first link is the egress carrying the full
        // remote latency.
        let c = cfg();
        let net = Interconnect::new(&c);
        let owner = [0usize, 0, 1, 1];
        let cyc = c.cycles_per_ns();
        let got = net.min_cross_shard_latency(&owner);
        assert!((got - c.remote_latency_ns * cyc).abs() < 1e-9);
        // One shard: no pair crosses, bound is +inf.
        assert!(net.min_cross_shard_latency(&[0, 0, 0, 0]).is_infinite());
        // Multi-hop fabric: the per-hop latency is the bound...
        let c2 = cfg_with(TopologyKind::Ring);
        let net2 = Interconnect::new(&c2);
        let got2 = net2.min_cross_shard_latency(&owner);
        assert!((got2 - c2.hop_latency_ns * c2.cycles_per_ns()).abs() < 1e-9);
        // ...and a zero-latency fabric yields no usable lookahead.
        let mut c3 = cfg_with(TopologyKind::Ring);
        c3.hop_latency_ns = 0.0;
        let net3 = Interconnect::new(&c3);
        assert_eq!(net3.min_cross_shard_latency(&owner), 0.0);
    }

    #[test]
    fn degenerate_fabric_reports_no_link_stats() {
        let c = cfg();
        let mut net = Interconnect::new(&c);
        net.remote_hop(0.0, 0, 1, 128);
        assert!(net.link_stats().is_empty());
        assert_eq!(net.remote_bytes(), 128);
        let c2 = cfg_with(TopologyKind::Ring);
        let mut net2 = Interconnect::new(&c2);
        net2.remote_hop(0.0, 0, 1, 128);
        assert_eq!(net2.link_stats().len(), 2 * c2.num_stacks);
        assert_eq!(net2.remote_bytes(), 128);
    }
}
