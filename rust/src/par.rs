//! Deterministic fork-join for the orchestration layer.
//!
//! Run-alone baselines and `[sweep]` points are independent deterministic
//! simulations: each job builds its own `VirtualMemory`, engine and
//! backends from scratch, shares nothing mutable, and produces the same
//! result no matter which thread runs it or when. [`parallel_map`] fans
//! such jobs out over `std::thread::scope` and hands the results back **in
//! job-index order**, so the caller's output — and therefore every report
//! byte — is identical to the sequential path (`tests/parallel_equiv.rs`
//! locks this in across thread counts and DRAM backends).
//!
//! The thread count comes from
//! [`SystemConfig::sim_threads`](crate::config::SystemConfig::sim_threads)
//! (CLI `--threads`): `0` means one thread per available core, `1` forces
//! the plain sequential loop (no threads are spawned at all), and any
//! other value caps the worker pool. Fan-outs may nest (a parallel sweep
//! whose points run parallel baselines); each level is bounded by its own
//! job count, so the worst case is points × baselines threads — fine for
//! the compute-bound, short-lived workers these jobs are.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

/// Resolve a configured thread count against a job count: `0` = one per
/// available core, otherwise the value itself; never more threads than
/// jobs, never fewer than one.
pub fn effective_threads(configured: usize, jobs: usize) -> usize {
    let t = if configured == 0 {
        std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1)
    } else {
        configured
    };
    // `t` is always >= 1 here, so capping by `jobs.max(1)` both bounds
    // the pool by the job count and keeps the floor of one worker.
    t.min(jobs.max(1))
}

/// Run `n` independent jobs `f(0) .. f(n-1)` across up to `threads`
/// workers (see [`effective_threads`]); returns the results in job-index
/// order, or the lowest-index error if any job failed.
///
/// # Contract
///
/// Jobs must be **independent** (no job reads state another writes) and
/// **deterministic in their index alone** — under those two rules the
/// output is bit-identical to the sequential loop, which `threads <= 1`
/// literally runs (no worker threads, no atomics). A panicking job
/// propagates its panic, exactly as the sequential loop would. After a
/// job fails, workers stop claiming new jobs (already-claimed ones
/// finish) — and because the atomic counter claims indices in order,
/// every job below the lowest failing index still completes, so the
/// returned error is deterministically the lowest-index one.
pub fn parallel_map<T, F>(threads: usize, n: usize, f: F) -> crate::Result<Vec<T>>
where
    T: Send,
    F: Fn(usize) -> crate::Result<T> + Sync,
{
    let threads = effective_threads(threads, n);
    if threads <= 1 {
        return (0..n).map(f).collect();
    }
    // Work-stealing by atomic counter: whichever worker is free claims
    // the next index. The claim order is racy; the *output* order is not,
    // because every result lands in its own index's slot.
    let next = AtomicUsize::new(0);
    let stop = AtomicBool::new(false);
    let slots: Vec<Mutex<Option<crate::Result<T>>>> =
        (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                if stop.load(Ordering::Relaxed) {
                    break;
                }
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(i);
                if r.is_err() {
                    stop.store(true, Ordering::Relaxed);
                }
                *slots[i].lock().expect("result slot poisoned") = Some(r);
            });
        }
    });
    // First error by job index, not by completion time. Slots above the
    // lowest failing index may be unfilled (workers stopped claiming);
    // everything below it is guaranteed complete, so the scan either
    // returns that error or a full result set.
    let mut out = Vec::with_capacity(n);
    for m in slots {
        match m.into_inner().expect("result slot poisoned") {
            Some(Ok(v)) => out.push(v),
            Some(Err(e)) => return Err(e),
            None => unreachable!("an unfilled slot implies an earlier error slot"),
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn effective_threads_resolution() {
        assert_eq!(effective_threads(1, 100), 1);
        assert_eq!(effective_threads(4, 100), 4);
        assert_eq!(effective_threads(4, 2), 2); // capped by jobs
        assert_eq!(effective_threads(7, 0), 1); // never zero
        assert!(effective_threads(0, 100) >= 1); // auto resolves to >= 1
    }

    #[test]
    fn results_come_back_in_index_order() {
        for threads in [1, 2, 8] {
            let out = parallel_map(threads, 64, |i| Ok(i * 10)).unwrap();
            assert_eq!(out, (0..64).map(|i| i * 10).collect::<Vec<_>>());
        }
    }

    #[test]
    fn zero_jobs_is_fine() {
        let out: Vec<usize> = parallel_map(8, 0, |i| Ok(i + 1)).unwrap();
        assert!(out.is_empty());
    }

    #[test]
    fn lowest_index_error_wins() {
        for threads in [1, 4] {
            let err = parallel_map(threads, 16, |i| {
                if i % 5 == 2 {
                    Err(anyhow::anyhow!("job {i} failed"))
                } else {
                    Ok(i)
                }
            })
            .unwrap_err();
            assert_eq!(err.to_string(), "job 2 failed");
        }
    }

    #[test]
    fn sequential_path_spawns_no_threads() {
        // threads = 1 must run inline on the caller's thread.
        let caller = std::thread::current().id();
        let out = parallel_map(1, 4, |i| {
            assert_eq!(std::thread::current().id(), caller);
            Ok(i)
        })
        .unwrap();
        assert_eq!(out, vec![0, 1, 2, 3]);
    }
}
