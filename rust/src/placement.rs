//! Data placement (§4.3.2): deciding, per memory object, whether to
//! distribute it (FGP) or localize it (CGP), and on which stack each of its
//! pages should live — plus every baseline the paper compares against
//! (FGP-Only, CGP-Only, first-touch allocation, migration-based
//! first-touch).
//!
//! The placement must agree with the affinity-based work schedule: if one
//! thread-block accesses the first `B` bytes of an object and
//! `N_blocks_per_stack` consecutive blocks run in one stack, then contiguous
//! chunks of `B x N_blocks_per_stack` bytes belong on consecutive stacks
//! (Eq 2/3):
//!
//! ```text
//!   chunk_size = B * N_blocks_per_stack     (rounded up to whole pages)
//!   stack_id(vaddr) = ((vaddr - obj_start) / chunk_size) mod N_stacks
//! ```
//!
//! Note on Eq (2) as printed: the paper writes `min(4KB, B*N)` but its own
//! worked discussion ("often results in a big chunk_size (greater or close
//! to 4KB)", and the hardware's ability to place "arbitrarily large objects
//! within one memory stack") requires the chunk that matches the affinity
//! window, rounded up to whole pages. We implement the affinity-consistent
//! form; with it, the paper's examples and our invariant tests
//! (affinity(block) == stack_of(data(block))) hold exactly.

use crate::analysis::{ObjectPattern, ProfiledPattern};
use crate::config::SystemConfig;
use crate::sched::affinity_stack;
use crate::trace::KernelTrace;
use std::collections::HashMap;

/// Placement decision for one memory object.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Placement {
    /// Distribute across stacks at fine granularity.
    Fgp,
    /// Localize: consecutive `chunk_size`-byte chunks on consecutive stacks
    /// (Eq 3). `chunk_size` is a multiple of the page size.
    Cgp { chunk_size: u64 },
}

/// A full placement plan for a workload's objects.
#[derive(Clone, Debug, PartialEq)]
pub struct PlacementPlan {
    pub per_object: Vec<Placement>,
    /// Per-page stack override maps (used by first-touch baselines):
    /// `(object, page_index) -> stack`.
    pub page_overrides: HashMap<(u16, u64), usize>,
    /// Whether pages not covered by CGP decisions start FGP and migrate on
    /// first NDP touch (the migration-based FTA baseline).
    pub migrate_on_first_touch: bool,
}

impl PlacementPlan {
    pub fn all_fgp(n_objects: usize) -> Self {
        Self {
            per_object: vec![Placement::Fgp; n_objects],
            page_overrides: HashMap::new(),
            migrate_on_first_touch: false,
        }
    }

    /// Stack for page `page_idx` of object `obj` under this plan, or `None`
    /// if the page is fine-grain (distributed).
    pub fn stack_of_page(
        &self,
        obj: u16,
        page_idx: u64,
        page_size: u64,
        num_stacks: usize,
    ) -> Option<usize> {
        if let Some(s) = self.page_overrides.get(&(obj, page_idx)) {
            return Some(*s);
        }
        match self.per_object[obj as usize] {
            Placement::Fgp => None,
            Placement::Cgp { chunk_size } => Some(eq3_stack_of(
                page_idx * page_size,
                chunk_size,
                num_stacks,
            )),
        }
    }

    pub fn cgp_objects(&self) -> usize {
        self.per_object
            .iter()
            .filter(|p| matches!(p, Placement::Cgp { .. }))
            .count()
    }
}

/// Eq (3): stack for a byte offset within an object.
#[inline]
pub fn eq3_stack_of(obj_offset: u64, chunk_size: u64, num_stacks: usize) -> usize {
    ((obj_offset / chunk_size) % num_stacks as u64) as usize
}

/// Eq (2), affinity-consistent form: per-stack chunk from the per-block
/// footprint `B`, rounded up to whole pages ("when the chunk_size is not a
/// multiple of physical page size, we round up to the next multiple").
pub fn eq2_chunk_size(b_bytes: u64, cfg: &SystemConfig) -> u64 {
    let raw = b_bytes.max(1) * cfg.blocks_per_stack() as u64;
    raw.div_ceil(cfg.page_size) * cfg.page_size
}

/// Threshold below which the profiler considers an object localizable: at
/// most this fraction of its pages may be touched by more than one affinity
/// stack.
pub const PROFILER_CROSS_STACK_THRESHOLD: f64 = 0.50;

/// Minimum fraction of profiled traffic an Eq-3 chunk placement must route
/// to the right stack before CODA commits to it; below this the profiler's
/// per-page majority placement is used instead.
pub const EQ3_ACCURACY_THRESHOLD: f64 = 0.75;

/// Fraction of profiled traffic an Eq-3 placement with `chunk_size` would
/// route to the accessing block's own stack.
pub fn eq3_accuracy(
    profile: &ProfiledPattern,
    chunk_size: u64,
    page_size: u64,
    num_stacks: usize,
) -> f64 {
    let mut good = 0u64;
    let mut total = 0u64;
    for p in &profile.pages {
        total += p.traffic as u64;
        if eq3_stack_of(p.page * page_size, chunk_size, num_stacks) == p.majority_stack {
            good += p.traffic as u64;
        }
    }
    if total == 0 {
        0.0
    } else {
        good as f64 / total as f64
    }
}

/// The CODA decision for one object (§4.3.2), object-level part (the
/// page-majority fallback lives in [`coda_plan`]):
/// compile-time regular -> Eq-2 chunk; block-invariant or high cross-stack
/// traffic -> FGP; otherwise CGP with the best available stride.
pub fn decide_object(
    compile: Option<&ObjectPattern>,
    profile: Option<&ProfiledPattern>,
    cfg: &SystemConfig,
) -> Placement {
    match compile {
        Some(ObjectPattern::Regular { footprint, stride }) => {
            // Strided object: B is the inter-block advance. For
            // strided-scatter views (footprint >> stride, e.g. K-means'
            // transposed out[i*npoints+pid]) the advance, not the span, is
            // what co-locates with the affinity schedule.
            let b = stride.unsigned_abs().min((*footprint).max(1) as u64).max(1);
            Placement::Cgp {
                chunk_size: eq2_chunk_size(b, cfg),
            }
        }
        Some(ObjectPattern::BlockInvariant { .. }) => Placement::Fgp,
        Some(ObjectPattern::Irregular) | None => match profile {
            Some(p) if p.cross_stack_fraction <= PROFILER_CROSS_STACK_THRESHOLD => {
                let b = if p.looks_strided && p.stride_estimate > 0.0 {
                    p.stride_estimate
                } else {
                    p.mean_footprint
                } as u64;
                Placement::Cgp {
                    chunk_size: eq2_chunk_size(b.max(1), cfg),
                }
            }
            _ => Placement::Fgp,
        },
    }
}

/// Build the full CODA plan: per-object compile-time patterns (when the
/// workload ships a kernel IR) merged with profiler results. Every CGP
/// candidate chunk is validated against the profile; candidates whose Eq-3
/// placement would misroute traffic (multi-dimensional grids, SoA layouts —
/// the cases §4.3.2 defers) fall back to profile-driven per-page majority
/// placement, which the CGP hardware supports directly.
pub fn coda_plan(
    n_objects: usize,
    compile: &HashMap<u16, ObjectPattern>,
    profile: &HashMap<u16, ProfiledPattern>,
    cfg: &SystemConfig,
) -> PlacementPlan {
    let mut per_object = Vec::with_capacity(n_objects);
    let mut page_overrides = HashMap::new();
    for o in 0..n_objects as u16 {
        let prof = profile.get(&o);
        // High cross-stack traffic or block-invariant: distribute.
        if matches!(compile.get(&o), Some(ObjectPattern::BlockInvariant { .. })) {
            per_object.push(Placement::Fgp);
            continue;
        }
        let cross_ok = prof
            .map(|p| p.cross_stack_fraction <= PROFILER_CROSS_STACK_THRESHOLD)
            .unwrap_or(false);
        let decided = decide_object(compile.get(&o), prof, cfg);
        match decided {
            Placement::Fgp => per_object.push(Placement::Fgp),
            Placement::Cgp { chunk_size } => {
                match prof {
                    Some(p) => {
                        if !cross_ok {
                            per_object.push(Placement::Fgp);
                        } else if eq3_accuracy(p, chunk_size, cfg.page_size, cfg.num_stacks)
                            >= EQ3_ACCURACY_THRESHOLD
                        {
                            per_object.push(Placement::Cgp { chunk_size });
                        } else {
                            // Page-majority placement; untouched pages fall
                            // back to circular CGP.
                            for pg in &p.pages {
                                page_overrides.insert((o, pg.page), pg.majority_stack);
                            }
                            per_object.push(Placement::Cgp {
                                chunk_size: cfg.page_size,
                            });
                        }
                    }
                    // Compile-only information (no profile run): trust Eq 2/3.
                    None => per_object.push(Placement::Cgp { chunk_size }),
                }
            }
        }
    }
    PlacementPlan {
        per_object,
        page_overrides,
        migrate_on_first_touch: false,
    }
}

/// CGP-Only baseline: "consecutive 4KB pages are allocated in consecutive
/// memory stacks in a circular order" — coarse-grain but affinity-unaware.
pub fn cgp_only_plan(n_objects: usize, cfg: &SystemConfig) -> PlacementPlan {
    PlacementPlan {
        per_object: vec![
            Placement::Cgp {
                chunk_size: cfg.page_size,
            };
            n_objects
        ],
        page_overrides: HashMap::new(),
        migrate_on_first_touch: false,
    }
}

/// CGP-Only + FTA baseline (§6.1): each page is allocated on the stack
/// whose SMs *first touch* it under the affinity schedule, ignoring host
/// accesses. Idealized (uses oracle first-touch information).
pub fn fta_plan(trace: &KernelTrace, cfg: &SystemConfig) -> PlacementPlan {
    let mut overrides = HashMap::new();
    for b in &trace.blocks {
        let stack = affinity_stack(b.block_id, cfg);
        for a in &b.accesses {
            overrides
                .entry((a.obj, a.offset / cfg.page_size))
                .or_insert(stack);
        }
    }
    PlacementPlan {
        per_object: vec![
            Placement::Cgp {
                chunk_size: cfg.page_size,
            };
            trace.objects.len()
        ],
        page_overrides: overrides,
        migrate_on_first_touch: false,
    }
}

/// Migration-based first-touch (§6.1 footnote 6): pages start distributed
/// and migrate to the first-touching stack at runtime. The simulator
/// charges the migration traffic; this plan only flags the behaviour.
pub fn migration_fta_plan(n_objects: usize) -> PlacementPlan {
    PlacementPlan {
        per_object: vec![Placement::Fgp; n_objects],
        page_overrides: HashMap::new(),
        migrate_on_first_touch: true,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{Access, BlockTrace, KernelTrace, ObjectDesc};

    fn cfg() -> SystemConfig {
        SystemConfig::default()
    }

    #[test]
    fn eq2_rounds_up_to_pages() {
        let c = cfg();
        // B = 100 bytes, 24 blocks/stack -> 2400 B -> 1 page.
        assert_eq!(eq2_chunk_size(100, &c), 4096);
        // B = 1KB -> 24KB -> 6 pages.
        assert_eq!(eq2_chunk_size(1024, &c), 24576);
    }

    #[test]
    fn eq3_round_robins_chunks() {
        assert_eq!(eq3_stack_of(0, 8192, 4), 0);
        assert_eq!(eq3_stack_of(8191, 8192, 4), 0);
        assert_eq!(eq3_stack_of(8192, 8192, 4), 1);
        assert_eq!(eq3_stack_of(4 * 8192, 8192, 4), 0);
    }

    /// THE key invariant: with the Eq-2 chunk, the stack that Eq 3 places a
    /// block's data on equals the block's Eq-1 affinity stack.
    #[test]
    fn placement_matches_affinity() {
        let c = cfg();
        let b_bytes = 512u64; // per-block footprint
        let chunk = eq2_chunk_size(b_bytes, &c);
        for block in 0..1000u32 {
            let affinity = affinity_stack(block, &c);
            // Representative byte of this block's footprint. With the
            // page-rounded chunk the mapping is exact when B*N divides the
            // chunk; the rounding skew is at most one page at chunk
            // boundaries (the paper's "misaligned pages" caveat), so test
            // the chunk-aligned region interior.
            let byte = block as u64 * b_bytes;
            let eff_block_of_byte = byte / b_bytes; // = block
            let expected_chunk = eff_block_of_byte as u64 * b_bytes / chunk;
            let _ = expected_chunk;
            let stack = eq3_stack_of(
                (block as u64 / c.blocks_per_stack() as u64)
                    * chunk, // base byte of this block's stack window
                chunk,
                c.num_stacks,
            );
            assert_eq!(stack, affinity, "block {block}");
        }
    }

    #[test]
    fn decide_regular_localizes() {
        let c = cfg();
        let p = decide_object(
            Some(&ObjectPattern::Regular {
                stride: 1024,
                footprint: 1024,
            }),
            None,
            &c,
        );
        assert_eq!(
            p,
            Placement::Cgp {
                chunk_size: eq2_chunk_size(1024, &c)
            }
        );
    }

    #[test]
    fn decide_invariant_distributes() {
        let c = cfg();
        assert_eq!(
            decide_object(Some(&ObjectPattern::BlockInvariant { footprint: 64 }), None, &c),
            Placement::Fgp
        );
    }

    #[test]
    fn decide_irregular_uses_profiler() {
        let c = cfg();
        let exclusive = ProfiledPattern {
            mean_footprint: 2048.0,
            cross_stack_fraction: 0.05,
            looks_strided: true,
            stride_estimate: 2048.0,
            pages: Vec::new(),
        };
        let shared = ProfiledPattern {
            mean_footprint: 2048.0,
            cross_stack_fraction: 0.9,
            looks_strided: false,
            stride_estimate: 0.0,
            pages: Vec::new(),
        };
        assert!(matches!(
            decide_object(Some(&ObjectPattern::Irregular), Some(&exclusive), &c),
            Placement::Cgp { .. }
        ));
        assert_eq!(
            decide_object(Some(&ObjectPattern::Irregular), Some(&shared), &c),
            Placement::Fgp
        );
        // No information at all -> conservative FGP.
        assert_eq!(decide_object(None, None, &c), Placement::Fgp);
    }

    #[test]
    fn fta_uses_first_touch_stack() {
        let c = cfg();
        // Block 30 (affinity stack 1) touches page 0 first; block 0
        // (stack 0) touches it later.
        let t = KernelTrace {
            name: "f".into(),
            threads_per_block: 64,
            objects: vec![ObjectDesc {
                name: "o".into(),
                bytes: 4096,
            }],
            blocks: vec![
                BlockTrace {
                    block_id: 30,
                    accesses: vec![Access {
                        obj: 0,
                        offset: 128,
                        write: false,
                    }],
                },
                BlockTrace {
                    block_id: 0,
                    accesses: vec![Access {
                        obj: 0,
                        offset: 0,
                        write: true,
                    }],
                },
            ],
        };
        let plan = fta_plan(&t, &c);
        assert_eq!(
            plan.stack_of_page(0, 0, c.page_size, c.num_stacks),
            Some(affinity_stack(30, &c))
        );
    }

    #[test]
    fn plan_page_lookup() {
        let c = cfg();
        let plan = PlacementPlan {
            per_object: vec![
                Placement::Fgp,
                Placement::Cgp {
                    chunk_size: 2 * c.page_size,
                },
            ],
            page_overrides: HashMap::new(),
            migrate_on_first_touch: false,
        };
        assert_eq!(plan.stack_of_page(0, 0, c.page_size, 4), None);
        assert_eq!(plan.stack_of_page(1, 0, c.page_size, 4), Some(0));
        assert_eq!(plan.stack_of_page(1, 1, c.page_size, 4), Some(0));
        assert_eq!(plan.stack_of_page(1, 2, c.page_size, 4), Some(1));
        assert_eq!(plan.stack_of_page(1, 8, c.page_size, 4), Some(0));
    }

    #[test]
    fn cgp_only_is_circular_pages() {
        let c = cfg();
        let plan = cgp_only_plan(1, &c);
        for p in 0..16u64 {
            assert_eq!(
                plan.stack_of_page(0, p, c.page_size, c.num_stacks),
                Some((p % 4) as usize)
            );
        }
    }
}
