//! Property-based testing support (the `proptest` crate is not vendored in
//! this environment). Provides a seeded case generator and a runner that
//! reports the failing seed/case for reproduction; used by the integration
//! tests to check coordinator/allocator invariants over randomized inputs.

use crate::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        Self {
            cases: 64,
            seed: 0xC0DA_7E57,
        }
    }
}

/// Run `prop` over `cases` generated inputs. `gen` draws one case from the
/// RNG. On failure, panics with the case index and seed so the exact case
/// can be replayed.
pub fn run_prop<T: std::fmt::Debug>(
    cfg: PropConfig,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cfg.cases {
        let mut rng = Rng::new(cfg.seed.wrapping_add(case as u64));
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed at case {case} (seed {:#x}): {msg}\ninput: {input:?}",
                cfg.seed.wrapping_add(case as u64)
            );
        }
    }
}

/// Draw helpers.
pub fn usize_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
    rng.range(lo as u64, hi as u64) as usize
}

pub fn pow2_in(rng: &mut Rng, lo_log2: u32, hi_log2: u32) -> u64 {
    1u64 << rng.range(lo_log2 as u64, hi_log2 as u64 + 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        run_prop(
            PropConfig {
                cases: 10,
                seed: 1,
            },
            |rng| rng.below(100),
            |x| {
                n += 1;
                if *x < 100 {
                    Ok(())
                } else {
                    Err("out of range".into())
                }
            },
        );
        assert_eq!(n, 10);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_case() {
        run_prop(
            PropConfig {
                cases: 5,
                seed: 2,
            },
            |rng| rng.below(10),
            |_| Err("always fails".into()),
        );
    }

    #[test]
    fn pow2_in_bounds() {
        let mut rng = Rng::new(3);
        for _ in 0..100 {
            let v = pow2_in(&mut rng, 7, 12);
            assert!(v.is_power_of_two());
            assert!((128..=4096).contains(&v));
        }
    }
}
