//! Result emission: JSON (machine-readable) and aligned-text/markdown
//! tables (the rows/series each paper figure reports). `serde`/`serde_json`
//! are not vendored in this environment, so the JSON writer is in-repo.

use crate::stats::RunReport;
use std::fmt::Write as _;

/// Minimal JSON value builder (output only).
#[derive(Clone, Debug)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Self {
        Json::Obj(Vec::new())
    }

    pub fn push(&mut self, key: &str, v: Json) -> &mut Self {
        if let Json::Obj(fields) = self {
            fields.push((key.to_string(), v));
        }
        self
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => {
                let _ = write!(out, "{b}");
            }
            Json::Num(n) => {
                if n.is_finite() {
                    // Integers render without a trailing .0 for readability.
                    if n.fract() == 0.0 && n.abs() < 9e15 {
                        let _ = write!(out, "{}", *n as i64);
                    } else {
                        let _ = write!(out, "{n}");
                    }
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for ch in s.chars() {
                    match ch {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<&RunReport> for Json {
    fn from(r: &RunReport) -> Self {
        let mut o = Json::obj();
        o.push("workload", Json::Str(r.workload.clone()))
            .push("mechanism", Json::Str(r.mechanism.clone()))
            .push("cycles", Json::Num(r.cycles))
            .push("local", Json::Num(r.accesses.local as f64))
            .push("remote", Json::Num(r.accesses.remote as f64))
            .push("l2_hits", Json::Num(r.accesses.l2_hits as f64))
            .push("remote_fraction", Json::Num(r.accesses.remote_fraction()))
            .push("remote_bytes", Json::Num(r.remote_bytes as f64))
            .push("mean_mem_latency", Json::Num(r.mean_mem_latency))
            .push("tlb_hit_rate", Json::Num(r.tlb_hit_rate))
            .push("row_hit_rate", Json::Num(r.row_hit_rate))
            .push("mem_backend", Json::Str(r.mem_backend.clone()))
            .push("bank_conflicts", Json::Num(r.bank_conflicts as f64))
            .push("refresh_stalls", Json::Num(r.refresh_stalls as f64))
            .push("cgp_pages", Json::Num(r.cgp_pages as f64))
            .push("fgp_pages", Json::Num(r.fgp_pages as f64))
            .push("migrated_pages", Json::Num(r.migrated_pages as f64))
            .push(
                "stack_bytes",
                Json::Arr(r.stack_bytes.iter().map(|&b| Json::Num(b as f64)).collect()),
            );
        // Per-command DRAM counters, only for the cycle-accurate backend:
        // fixed/bank runs carry none of these keys, so their JSON stays
        // byte-identical to the frozen pre-cycle output.
        if r.mem_backend == "cycle" {
            o.push("dram_row_hits", Json::Num(r.dram_row_hits as f64))
                .push("dram_row_misses", Json::Num(r.dram_row_misses as f64))
                .push("dram_acts", Json::Num(r.dram_acts as f64))
                .push("dram_precharges", Json::Num(r.dram_precharges as f64))
                .push("dram_wq_stalls", Json::Num(r.dram_wq_stalls as f64))
                .push("dram_faw_stalls", Json::Num(r.dram_faw_stalls as f64));
        }
        // Multiprogrammed/multi-kernel extras, only when populated.
        if !r.app_cycles.is_empty() {
            o.push(
                "app_cycles",
                Json::Arr(r.app_cycles.iter().map(|&c| Json::Num(c)).collect()),
            );
        }
        if !r.app_slowdown.is_empty() {
            o.push(
                "app_slowdown",
                Json::Arr(r.app_slowdown.iter().map(|&s| Json::Num(s)).collect()),
            )
            .push("weighted_speedup", Json::Num(r.weighted_speedup));
        }
        // Concurrent-host extras, only when a host stream actually ran.
        if r.accesses.host_total() > 0 || r.host_cycles > 0.0 {
            o.push("host", Json::Num(r.accesses.host as f64))
                .push("host_ddr", Json::Num(r.accesses.host_ddr as f64))
                .push("host_cycles", Json::Num(r.host_cycles))
                .push("host_slowdown", Json::Num(r.host_slowdown))
                .push("ndp_slowdown", Json::Num(r.ndp_slowdown))
                .push("host_bytes", Json::Num(r.host_bytes as f64))
                .push("host_ddr_bytes", Json::Num(r.host_ddr_bytes as f64))
                .push("host_port_stalls", Json::Num(r.host_port_stalls as f64))
                .push("host_bw_share", Json::Num(r.host_bw_share));
        }
        // Service-mode extras, only for open-loop [arrivals] runs: fixed
        // mixes carry no service block, so their JSON stays byte-identical
        // to the frozen pre-service output.
        if let Some(s) = &r.service {
            o.push("requests_offered", Json::Num(s.requests_offered as f64))
                .push(
                    "requests_completed",
                    Json::Num(s.requests_completed as f64),
                )
                .push(
                    "requests_incomplete",
                    Json::Num(s.requests_incomplete as f64),
                )
                .push("offered_rate", Json::Num(s.offered_rate))
                .push("achieved_rate", Json::Num(s.achieved_rate))
                .push("mean_response", Json::Num(s.mean_response))
                .push("max_response", Json::Num(s.max_response))
                .push("p50_response", Json::Num(s.p50_response))
                .push("p99_response", Json::Num(s.p99_response))
                .push("p999_response", Json::Num(s.p999_response));
        }
        // Translation extras, only when the hierarchical model ran
        // (`tlb_l1_entries > 0`): legacy flat-walk runs carry no xlate
        // block, so their JSON stays byte-identical to the frozen output.
        if let Some(x) = &r.xlate {
            o.push("xlate_l1_hit_rate", Json::Num(x.l1_hit_rate))
                .push("xlate_l2_hit_rate", Json::Num(x.l2_hit_rate))
                .push("walks", Json::Num(x.walks as f64))
                .push("walk_cycles", Json::Num(x.walk_cycles))
                .push("walk_queue_cycles", Json::Num(x.walk_queue_cycles))
                .push("walk_stall_share", Json::Num(x.walk_stall_share))
                .push("huge_pages", Json::Num(x.huge_pages as f64))
                .push("huge_coverage", Json::Num(x.huge_coverage));
        }
        // Fabric extras, only for multi-hop topologies: the degenerate
        // fully-connected fabric reports no link stats, so its JSON stays
        // byte-identical to the frozen pre-fabric output.
        if !r.link_stats.is_empty() {
            o.push("topology", Json::Str(r.topology.clone()))
                .push("net_window_cycles", Json::Num(r.net_window_cycles))
                .push(
                    "links",
                    Json::Arr(
                        r.link_stats
                            .iter()
                            .map(|l| {
                                let mut lo = Json::obj();
                                lo.push("from", Json::Num(l.from as f64))
                                    .push("to", Json::Num(l.to as f64))
                                    .push("bytes", Json::Num(l.bytes as f64))
                                    .push("stalls", Json::Num(l.stalls as f64))
                                    .push(
                                        "peak_window_bytes",
                                        Json::Num(l.peak_window_bytes as f64),
                                    )
                                    .push(
                                        "peak_bytes_per_cycle",
                                        Json::Num(if r.net_window_cycles > 0.0 {
                                            l.peak_window_bytes as f64 / r.net_window_cycles
                                        } else {
                                            0.0
                                        }),
                                    );
                                lo
                            })
                            .collect(),
                    ),
                );
        }
        // Sharded-engine extras, only when the run actually sharded
        // (`shard_stacks >= 2`): sequential runs and every degenerate
        // fallback carry none of these keys, so their JSON stays
        // byte-identical to the single-threaded output.
        if r.shard_stacks >= 2 {
            o.push("shard_stacks", Json::Num(r.shard_stacks as f64))
                .push("shard_windows", Json::Num(r.shard_windows as f64))
                .push("shard_msgs", Json::Num(r.shard_msgs as f64));
        }
        o
    }
}

/// Validate that `text` is one syntactically well-formed JSON value
/// (the RFC 8259 grammar, permissive only about leading zeros in
/// numbers; no semantic checks). The writer above is hand rolled, so the
/// test suite can assert every emitted report actually parses without an
/// external JSON dependency.
pub fn validate_json(text: &str) -> Result<(), String> {
    let b = text.as_bytes();
    let mut i = 0usize;
    fn skip_ws(b: &[u8], i: &mut usize) {
        while *i < b.len() && matches!(b[*i], b' ' | b'\t' | b'\n' | b'\r') {
            *i += 1;
        }
    }
    fn value(b: &[u8], i: &mut usize, depth: usize) -> Result<(), String> {
        if depth > 128 {
            return Err("nesting too deep".into());
        }
        skip_ws(b, i);
        match b.get(*i) {
            None => Err("unexpected end of input".into()),
            Some(b'{') => {
                *i += 1;
                skip_ws(b, i);
                if b.get(*i) == Some(&b'}') {
                    *i += 1;
                    return Ok(());
                }
                loop {
                    skip_ws(b, i);
                    string(b, i)?;
                    skip_ws(b, i);
                    if b.get(*i) != Some(&b':') {
                        return Err(format!("expected ':' at byte {i}"));
                    }
                    *i += 1;
                    value(b, i, depth + 1)?;
                    skip_ws(b, i);
                    match b.get(*i) {
                        Some(b',') => *i += 1,
                        Some(b'}') => {
                            *i += 1;
                            return Ok(());
                        }
                        _ => return Err(format!("expected ',' or '}}' at byte {i}")),
                    }
                }
            }
            Some(b'[') => {
                *i += 1;
                skip_ws(b, i);
                if b.get(*i) == Some(&b']') {
                    *i += 1;
                    return Ok(());
                }
                loop {
                    value(b, i, depth + 1)?;
                    skip_ws(b, i);
                    match b.get(*i) {
                        Some(b',') => *i += 1,
                        Some(b']') => {
                            *i += 1;
                            return Ok(());
                        }
                        _ => return Err(format!("expected ',' or ']' at byte {i}")),
                    }
                }
            }
            Some(b'"') => string(b, i),
            Some(b't') => literal(b, i, "true"),
            Some(b'f') => literal(b, i, "false"),
            Some(b'n') => literal(b, i, "null"),
            Some(c) if c.is_ascii_digit() || *c == b'-' => number(b, i),
            Some(c) => Err(format!("unexpected byte {:?} at {i}", *c as char)),
        }
    }
    fn literal(b: &[u8], i: &mut usize, word: &str) -> Result<(), String> {
        if b[*i..].starts_with(word.as_bytes()) {
            *i += word.len();
            Ok(())
        } else {
            Err(format!("bad literal at byte {i}"))
        }
    }
    fn string(b: &[u8], i: &mut usize) -> Result<(), String> {
        if b.get(*i) != Some(&b'"') {
            return Err(format!("expected string at byte {i}"));
        }
        *i += 1;
        while let Some(&c) = b.get(*i) {
            match c {
                b'"' => {
                    *i += 1;
                    return Ok(());
                }
                b'\\' => {
                    *i += 1;
                    match b.get(*i) {
                        Some(b'"' | b'\\' | b'/' | b'b' | b'f' | b'n' | b'r' | b't') => {
                            *i += 1
                        }
                        Some(b'u') => {
                            if b.len() < *i + 5
                                || !b[*i + 1..*i + 5].iter().all(u8::is_ascii_hexdigit)
                            {
                                return Err(format!("bad \\u escape at byte {i}"));
                            }
                            *i += 5;
                        }
                        _ => return Err(format!("bad escape at byte {i}")),
                    }
                }
                0x00..=0x1F => return Err(format!("raw control byte in string at {i}")),
                _ => *i += 1,
            }
        }
        Err("unterminated string".into())
    }
    fn number(b: &[u8], i: &mut usize) -> Result<(), String> {
        let start = *i;
        if b.get(*i) == Some(&b'-') {
            *i += 1;
        }
        let digits = |b: &[u8], i: &mut usize| {
            let s = *i;
            while *i < b.len() && b[*i].is_ascii_digit() {
                *i += 1;
            }
            *i > s
        };
        if !digits(b, i) {
            return Err(format!("bad number at byte {start}"));
        }
        if b.get(*i) == Some(&b'.') {
            *i += 1;
            if !digits(b, i) {
                return Err(format!("bad fraction at byte {start}"));
            }
        }
        if matches!(b.get(*i), Some(b'e' | b'E')) {
            *i += 1;
            if matches!(b.get(*i), Some(b'+' | b'-')) {
                *i += 1;
            }
            if !digits(b, i) {
                return Err(format!("bad exponent at byte {start}"));
            }
        }
        Ok(())
    }
    value(b, &mut i, 0)?;
    skip_ws(b, &mut i);
    if i != b.len() {
        return Err(format!("trailing bytes after value at byte {i}"));
    }
    Ok(())
}

/// A fixed-width text table (the shape each figure's harness prints).
#[derive(Debug, Default)]
pub struct Table {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize], out: &mut String| {
            for i in 0..ncols {
                if i > 0 {
                    out.push_str("  ");
                }
                let _ = write!(out, "{:<w$}", cells[i], w = widths[i]);
            }
            out.push('\n');
        };
        fmt_row(&self.header, &widths, &mut out);
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncols - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            fmt_row(row, &widths, &mut out);
        }
        out
    }
}

/// Format helpers used across benches.
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

pub fn pct(x: f64) -> String {
    format!("{:.1}%", 100.0 * x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_escaping() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(j.render(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn json_object_render() {
        let mut o = Json::obj();
        o.push("x", Json::Num(1.0))
            .push("y", Json::Arr(vec![Json::Num(2.5), Json::Null]));
        assert_eq!(o.render(), r#"{"x":1,"y":[2.5,null]}"#);
    }

    #[test]
    fn report_to_json_has_fields() {
        let r = RunReport {
            workload: "PR".into(),
            mechanism: "CODA".into(),
            cycles: 123.0,
            ..Default::default()
        };
        let s = Json::from(&r).render();
        assert!(s.contains(r#""workload":"PR""#));
        assert!(s.contains(r#""cycles":123"#));
    }

    #[test]
    fn multiprog_fields_render_only_when_populated() {
        let plain = Json::from(&RunReport::default()).render();
        assert!(!plain.contains("app_cycles"));
        assert!(!plain.contains("weighted_speedup"));
        let r = RunReport {
            app_cycles: vec![10.0, 20.0],
            app_slowdown: vec![1.0, 2.0],
            weighted_speedup: 1.5,
            ..Default::default()
        };
        let s = Json::from(&r).render();
        assert!(s.contains(r#""app_cycles":[10,20]"#));
        assert!(s.contains(r#""app_slowdown":[1,2]"#));
        assert!(s.contains(r#""weighted_speedup":1.5"#));
    }

    #[test]
    fn host_fields_render_only_when_host_ran() {
        let plain = Json::from(&RunReport::default()).render();
        assert!(!plain.contains("host_cycles"));
        assert!(!plain.contains("host_bw_share"));
        let r = RunReport {
            accesses: crate::stats::AccessStats {
                host: 100,
                host_ddr: 20,
                ..Default::default()
            },
            host_cycles: 500.0,
            host_slowdown: 1.25,
            ndp_slowdown: 1.5,
            host_bytes: 12800,
            host_ddr_bytes: 2560,
            host_port_stalls: 7,
            host_bw_share: 0.4,
            ..Default::default()
        };
        let s = Json::from(&r).render();
        assert!(s.contains(r#""host":100"#));
        assert!(s.contains(r#""host_ddr":20"#));
        assert!(s.contains(r#""host_cycles":500"#));
        assert!(s.contains(r#""host_slowdown":1.25"#));
        assert!(s.contains(r#""ndp_slowdown":1.5"#));
        assert!(s.contains(r#""host_port_stalls":7"#));
        assert!(s.contains(r#""host_bw_share":0.4"#));
    }

    #[test]
    fn dram_command_fields_render_only_for_cycle_backend() {
        // Both directions: fixed/bank reports never grow the keys (frozen
        // JSON), and a cycle report always carries them — even when zero.
        for backend in ["", "fixed", "bank"] {
            let r = RunReport {
                mem_backend: backend.into(),
                dram_acts: 99, // populated but suppressed: key is gated on backend
                ..Default::default()
            };
            let s = Json::from(&r).render();
            assert!(!s.contains("dram_acts"), "leaked under {backend:?}");
            assert!(!s.contains("dram_row_hits"));
            assert!(!s.contains("dram_wq_stalls"));
        }
        let r = RunReport {
            mem_backend: "cycle".into(),
            dram_row_hits: 10,
            dram_row_misses: 4,
            dram_acts: 5,
            dram_precharges: 2,
            dram_wq_stalls: 1,
            dram_faw_stalls: 3,
            ..Default::default()
        };
        let s = Json::from(&r).render();
        assert!(s.contains(r#""dram_row_hits":10"#));
        assert!(s.contains(r#""dram_row_misses":4"#));
        assert!(s.contains(r#""dram_acts":5"#));
        assert!(s.contains(r#""dram_precharges":2"#));
        assert!(s.contains(r#""dram_wq_stalls":1"#));
        assert!(s.contains(r#""dram_faw_stalls":3"#));
        validate_json(&s).unwrap();
    }

    #[test]
    fn service_fields_render_only_for_open_loop_runs() {
        let plain = Json::from(&RunReport::default()).render();
        assert!(!plain.contains("requests_offered"));
        assert!(!plain.contains("p99_response"));
        let r = RunReport {
            service: Some(crate::stats::ServiceStats {
                requests_offered: 1000,
                requests_completed: 990,
                requests_incomplete: 10,
                offered_rate: 0.5,
                achieved_rate: 0.495,
                mean_response: 80.0,
                max_response: 400.0,
                p50_response: 64.0,
                p99_response: 256.0,
                p999_response: 384.0,
            }),
            ..Default::default()
        };
        let s = Json::from(&r).render();
        assert!(s.contains(r#""requests_offered":1000"#));
        assert!(s.contains(r#""requests_completed":990"#));
        assert!(s.contains(r#""requests_incomplete":10"#));
        assert!(s.contains(r#""offered_rate":0.5"#));
        assert!(s.contains(r#""achieved_rate":0.495"#));
        assert!(s.contains(r#""mean_response":80"#));
        assert!(s.contains(r#""max_response":400"#));
        assert!(s.contains(r#""p50_response":64"#));
        assert!(s.contains(r#""p99_response":256"#));
        assert!(s.contains(r#""p999_response":384"#));
        validate_json(&s).unwrap();
    }

    #[test]
    fn xlate_fields_render_only_for_hierarchical_runs() {
        let plain = Json::from(&RunReport::default()).render();
        assert!(!plain.contains("xlate_l1_hit_rate"));
        assert!(!plain.contains("walk_stall_share"));
        assert!(!plain.contains("huge_coverage"));
        let r = RunReport {
            xlate: Some(crate::stats::XlateStats {
                l1_hits: 900,
                l1_misses: 100,
                l2_hits: 60,
                l2_misses: 40,
                walks: 40,
                l1_hit_rate: 0.9,
                l2_hit_rate: 0.6,
                walk_cycles: 16000.0,
                walk_queue_cycles: 2000.0,
                walk_stall_share: 0.05,
                huge_pages: 3,
                huge_coverage: 0.75,
            }),
            ..Default::default()
        };
        let s = Json::from(&r).render();
        assert!(s.contains(r#""xlate_l1_hit_rate":0.9"#));
        assert!(s.contains(r#""xlate_l2_hit_rate":0.6"#));
        assert!(s.contains(r#""walks":40"#));
        assert!(s.contains(r#""walk_cycles":16000"#));
        assert!(s.contains(r#""walk_queue_cycles":2000"#));
        assert!(s.contains(r#""walk_stall_share":0.05"#));
        assert!(s.contains(r#""huge_pages":3"#));
        assert!(s.contains(r#""huge_coverage":0.75"#));
        validate_json(&s).unwrap();
    }

    #[test]
    fn link_fields_render_only_for_multi_hop_fabrics() {
        let plain = Json::from(&RunReport::default()).render();
        assert!(!plain.contains("topology"));
        assert!(!plain.contains("links"));
        let r = RunReport {
            topology: "line".into(),
            net_window_cycles: 1000.0,
            link_stats: vec![
                crate::stats::LinkStat {
                    from: 0,
                    to: 1,
                    bytes: 4096,
                    stalls: 3,
                    peak_window_bytes: 2000,
                },
                crate::stats::LinkStat {
                    from: 1,
                    to: 0,
                    bytes: 128,
                    stalls: 0,
                    peak_window_bytes: 128,
                },
            ],
            ..Default::default()
        };
        let s = Json::from(&r).render();
        assert!(s.contains(r#""topology":"line""#));
        assert!(s.contains(r#""net_window_cycles":1000"#));
        assert!(s.contains(r#""from":0,"to":1,"bytes":4096,"stalls":3"#));
        assert!(s.contains(r#""peak_window_bytes":2000,"peak_bytes_per_cycle":2"#));
        validate_json(&s).unwrap();
    }

    #[test]
    fn shard_fields_render_only_for_sharded_runs() {
        // Sequential runs (0) and the 1-shard degenerate fallback keep the
        // frozen JSON shape; only a genuinely sharded run grows the keys.
        for seq in [0u64, 1] {
            let r = RunReport {
                shard_stacks: seq,
                shard_windows: 7, // populated but suppressed: gated on shards
                ..Default::default()
            };
            let s = Json::from(&r).render();
            assert!(!s.contains("shard_stacks"), "leaked at {seq}");
            assert!(!s.contains("shard_windows"));
            assert!(!s.contains("shard_msgs"));
        }
        let r = RunReport {
            shard_stacks: 4,
            shard_windows: 123,
            shard_msgs: 456,
            ..Default::default()
        };
        let s = Json::from(&r).render();
        assert!(s.contains(r#""shard_stacks":4"#));
        assert!(s.contains(r#""shard_windows":123"#));
        assert!(s.contains(r#""shard_msgs":456"#));
        validate_json(&s).unwrap();
    }

    #[test]
    fn validator_accepts_what_the_writer_emits() {
        let mut o = Json::obj();
        o.push("s", Json::Str("a\"b\\c\nd\u{1}".into()))
            .push("n", Json::Num(-1.5e-3))
            .push("i", Json::Num(42.0))
            .push("inf", Json::Num(f64::INFINITY))
            .push("b", Json::Bool(true))
            .push(
                "a",
                Json::Arr(vec![Json::Null, Json::Obj(vec![]), Json::Arr(vec![])]),
            );
        validate_json(&o.render()).unwrap();
        let r = RunReport {
            workload: "PR".into(),
            app_cycles: vec![1.0, 2.5],
            app_slowdown: vec![1.0],
            host_cycles: 3.0,
            stack_bytes: vec![1, 2],
            ..Default::default()
        };
        validate_json(&Json::from(&r).render()).unwrap();
    }

    #[test]
    fn validator_rejects_malformed_json() {
        for bad in [
            "",
            "{",
            "{\"a\":1,}",
            "[1 2]",
            "{\"a\" 1}",
            "\"unterminated",
            "01x",
            "1.2.3",
            "{\"a\":1} trailing",
            "nul",
            "{\"a\":\"\\q\"}",
        ] {
            assert!(validate_json(bad).is_err(), "accepted {bad:?}");
        }
        validate_json("123").unwrap();
        validate_json(" [1, -2.5e3, \"x\", null] ").unwrap();
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(&["x".into(), "1".into()]);
        t.row(&["longer".into(), "2".into()]);
        let s = t.render();
        assert!(s.contains("name"));
        assert!(s.lines().count() == 4);
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_rejects_bad_row() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
