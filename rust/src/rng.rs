//! Deterministic pseudo-random number generation.
//!
//! The `rand` crate is not vendored in this environment, so we implement the
//! two small generators the project needs: SplitMix64 (seeding) and
//! xoshiro256** (bulk generation). Both are well-studied, public-domain
//! algorithms; determinism across runs is a hard requirement for the
//! workload generators (Fig 3 classifications must be stable) and for the
//! property-test shrinker.

/// SplitMix64: used to expand a single `u64` seed into a xoshiro state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256**: the project-wide PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a seed; any seed (including 0) is valid.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift rejection method.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        // Unbiased bounded generation.
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo < hi, "empty range");
        lo + self.below(hi - lo)
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; simple and fine
    /// for workload synthesis).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-12 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with explicit mean and standard deviation.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// A power-law (discrete Pareto-ish) sample in `[1, max]`, exponent
    /// `alpha` > 1. Used for skewed graph degree distributions.
    pub fn power_law(&mut self, max: u64, alpha: f64) -> u64 {
        let u = self.f64().max(1e-12);
        let x = (1.0 - u * (1.0 - (max as f64).powf(1.0 - alpha))).powf(1.0 / (1.0 - alpha));
        (x as u64).clamp(1, max)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Choose a uniform element of a non-empty slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit");
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn f64_mean_close_to_half() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let sum: f64 = (0..n).map(|_| r.f64()).sum();
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_mean_and_std() {
        let mut r = Rng::new(5);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn power_law_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            let v = r.power_law(1000, 2.1);
            assert!((1..=1000).contains(&v));
        }
    }

    #[test]
    fn power_law_skewed() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let small = (0..n).filter(|_| r.power_law(1000, 2.5) <= 10).count();
        // A heavy-tailed distribution concentrates most mass near 1.
        assert!(small as f64 / n as f64 > 0.8);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(21);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
        assert_ne!(xs, (0..100).collect::<Vec<u32>>());
    }
}
