//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`, produced once by `make artifacts`) and executes
//! them from the Rust request path. Python never runs at execution time.
//!
//! The real execution path needs the `xla` bindings (PJRT CPU client + HLO
//! text round-trip), which are **not vendored** in this environment; they
//! sit behind the `xla` cargo feature. The default build exposes the same
//! API as a stub whose `load` fails with an actionable error, so every
//! caller (CLI, benches, integration tests) compiles unchanged and
//! degrades gracefully — tests that need real artifacts skip when
//! [`Runtime::load`] errors or [`Runtime::artifact_exists`] is false.
//!
//! Interchange is HLO *text*: jax >= 0.5 emits `HloModuleProto`s with
//! 64-bit instruction ids that the crate's pinned XLA (xla_extension
//! 0.5.1) rejects; the text parser reassigns ids and round-trips cleanly.
//! Modules are lowered with `return_tuple=True`, so results unwrap as
//! tuples.

use anyhow::Result;

/// A typed input tensor for [`Executable::run`].
pub enum Arg<'a> {
    F32(&'a [f32], &'a [usize]),
    I32(&'a [i32], &'a [usize]),
}

/// One PageRank sweep through the `pagerank_update` artifact.
pub fn run_pagerank(
    exe: &Executable,
    ranks: &[f32],
    inv_deg: &[f32],
    nbr_idx: &[i32],
    nbr_mask: &[f32],
    v: usize,
    k: usize,
) -> Result<Vec<f32>> {
    let out = exe.run(&[
        Arg::F32(ranks, &[v]),
        Arg::F32(inv_deg, &[v]),
        Arg::I32(nbr_idx, &[v, k]),
        Arg::F32(nbr_mask, &[v, k]),
    ])?;
    Ok(out.into_iter().next().expect("1-tuple"))
}

#[cfg(feature = "xla")]
mod imp {
    use super::Arg;
    use anyhow::{Context, Result};
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};

    /// A compiled, executable artifact.
    pub struct Executable {
        exe: xla::PjRtLoadedExecutable,
        pub name: String,
    }

    impl Executable {
        /// Execute with mixed f32/i32 inputs; returns each tuple output as
        /// flattened f32 (all our artifacts emit f32 outputs).
        pub fn run(&self, inputs: &[Arg<'_>]) -> Result<Vec<Vec<f32>>> {
            let literals: Vec<xla::Literal> = inputs
                .iter()
                .map(|arg| {
                    let (lit, dims) = match arg {
                        Arg::F32(data, dims) => (xla::Literal::vec1(data), *dims),
                        Arg::I32(data, dims) => (xla::Literal::vec1(data), *dims),
                    };
                    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                    lit.reshape(&dims_i64).context("reshape input")
                })
                .collect::<Result<_>>()?;
            let mut result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
                .to_literal_sync()
                .context("fetch result")?;
            let elems = result.decompose_tuple().context("decompose tuple")?;
            elems
                .into_iter()
                .map(|lit| lit.to_vec::<f32>().context("output to f32 vec"))
                .collect()
        }

        /// Execute with f32 tensor inputs `(data, dims)`; returns the
        /// flattened f32 elements of each tuple output.
        pub fn run_f32(&self, inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
            let args: Vec<Arg<'_>> = inputs
                .iter()
                .map(|(data, dims)| Arg::F32(data, dims))
                .collect();
            self.run(&args)
        }
    }

    /// The PJRT runtime: a CPU client plus a cache of compiled artifacts.
    pub struct Runtime {
        client: xla::PjRtClient,
        artifact_dir: PathBuf,
        cache: HashMap<String, Executable>,
    }

    impl Runtime {
        /// Create a CPU-backed runtime reading artifacts from `dir`.
        pub fn new(dir: impl AsRef<Path>) -> Result<Self> {
            let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
            Ok(Self {
                client,
                artifact_dir: dir.as_ref().to_path_buf(),
                cache: HashMap::new(),
            })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load (and cache) an artifact by stem, e.g. `"pagerank_update"`
        /// -> `artifacts/pagerank_update.hlo.txt`.
        pub fn load(&mut self, name: &str) -> Result<&Executable> {
            if !self.cache.contains_key(name) {
                let path = self.artifact_dir.join(format!("{name}.hlo.txt"));
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().context("artifact path not utf-8")?,
                )
                .with_context(|| format!("loading HLO text {path:?} (run `make artifacts`)"))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = self
                    .client
                    .compile(&comp)
                    .with_context(|| format!("compiling {name}"))?;
                self.cache.insert(
                    name.to_string(),
                    Executable {
                        exe,
                        name: name.to_string(),
                    },
                );
            }
            Ok(&self.cache[name])
        }

        /// Whether an artifact file exists (lets examples degrade
        /// gracefully with a "run make artifacts" hint).
        pub fn artifact_exists(&self, name: &str) -> bool {
            self.artifact_dir.join(format!("{name}.hlo.txt")).exists()
        }
    }
}

#[cfg(not(feature = "xla"))]
mod imp {
    use super::Arg;
    use anyhow::{bail, Result};
    use std::path::{Path, PathBuf};

    const DISABLED: &str = "PJRT execution disabled: built without the `xla` feature \
         (artifacts require `make artifacts` and `--features xla`)";

    /// Stub executable; [`Executable::run`] always errors. Instances cannot
    /// be constructed in a stub build, so the error paths are unreachable
    /// in practice — they exist to keep callers compiling.
    pub struct Executable {
        pub name: String,
    }

    impl Executable {
        pub fn run(&self, _inputs: &[Arg<'_>]) -> Result<Vec<Vec<f32>>> {
            bail!("{DISABLED}");
        }

        pub fn run_f32(&self, _inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
            bail!("{DISABLED}");
        }
    }

    /// Stub runtime: construction succeeds (so probing code can ask about
    /// artifacts), loading fails with an actionable message.
    pub struct Runtime {
        artifact_dir: PathBuf,
    }

    impl Runtime {
        pub fn new(dir: impl AsRef<Path>) -> Result<Self> {
            Ok(Self {
                artifact_dir: dir.as_ref().to_path_buf(),
            })
        }

        pub fn platform(&self) -> String {
            "cpu (stub; xla feature disabled)".to_string()
        }

        pub fn load(&mut self, _name: &str) -> Result<&Executable> {
            bail!("{DISABLED}; run `make artifacts` once the feature is enabled");
        }

        pub fn artifact_exists(&self, name: &str) -> bool {
            self.artifact_dir.join(format!("{name}.hlo.txt")).exists()
        }
    }
}

pub use imp::{Executable, Runtime};

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::{Path, PathBuf};

    fn artifact_dir() -> PathBuf {
        // Tests run from the crate root.
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn runtime_creates_cpu_client() {
        let rt = Runtime::new(artifact_dir()).unwrap();
        assert!(rt.platform().to_lowercase().contains("cpu"));
    }

    #[test]
    fn missing_artifact_is_a_clean_error() {
        let mut rt = Runtime::new(artifact_dir()).unwrap();
        let err = match rt.load("no_such_artifact") {
            Err(e) => e,
            Ok(_) => panic!("expected missing-artifact error"),
        };
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    // The artifact-dependent round-trip tests live in
    // rust/tests/integration.rs; they skip when the runtime is stubbed or
    // `make artifacts` has not run.
}
