//! Thread-block scheduling (§4.3.1).
//!
//! * **Baseline**: blocks dispatch in launch order to any SM with a free
//!   residency slot ("as soon as one thread-block retires, the next
//!   thread-block is scheduled to any available SM").
//! * **Affinity** (Eq 1): `affinity = (block_id / N_blocks_per_stack) mod
//!   N_stacks`; an SM only receives blocks whose affinity names its stack.
//! * **Affinity + work stealing** (the §4.3.1 optimization the paper
//!   sketches but does not evaluate): when a stack's queue drains, its SMs
//!   steal from the stack with the most remaining blocks.

use crate::config::SystemConfig;
use std::collections::VecDeque;

/// Eq (1): the affinity stack of a thread-block.
#[inline]
pub fn affinity_stack(block_id: u32, cfg: &SystemConfig) -> usize {
    (block_id as usize / cfg.blocks_per_stack()) % cfg.num_stacks
}

/// Scheduling policies the simulator supports.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// Any block to any available SM, in launch order.
    Baseline,
    /// Eq-1 affinity: blocks only run on SMs of their affinity stack.
    Affinity,
    /// Affinity, falling back to stealing when a stack runs dry.
    AffinityStealing,
}

impl Policy {
    /// Parse a CLI spelling; `None` for unknown names.
    pub fn parse(s: &str) -> Option<Self> {
        match s.trim() {
            "baseline" => Some(Self::Baseline),
            "affinity" => Some(Self::Affinity),
            "steal" | "stealing" | "affinity-stealing" => Some(Self::AffinityStealing),
            _ => None,
        }
    }
}

impl std::fmt::Display for Policy {
    /// Canonical CLI/spec spelling (round-trips through [`Policy::parse`]).
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Self::Baseline => "baseline",
            Self::Affinity => "affinity",
            Self::AffinityStealing => "steal",
        })
    }
}

/// Inter-application arbitration for multi-kernel runs: when several
/// co-resident kernels are eligible for a freed SM residency slot, the
/// fairness policy decides whose block gets it. (The block-level
/// [`Policy`] still decides *which* SMs an app's blocks may occupy.)
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum FairnessPolicy {
    /// Earliest-arrived app first (ties broken by app index).
    #[default]
    Fcfs,
    /// Rotate over eligible apps so each gets slots in turn.
    RoundRobin,
    /// App with the fewest dispatched blocks first (progress-based).
    LeastIssued,
}

impl FairnessPolicy {
    /// Parse a CLI/config spelling; `None` for unknown names.
    pub fn parse(s: &str) -> Option<Self> {
        match s.trim() {
            "fcfs" => Some(Self::Fcfs),
            "rr" | "round-robin" | "round_robin" => Some(Self::RoundRobin),
            "least" | "least-issued" | "least_issued" => Some(Self::LeastIssued),
            _ => None,
        }
    }
}

impl std::fmt::Display for FairnessPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Self::Fcfs => "fcfs",
            Self::RoundRobin => "rr",
            Self::LeastIssued => "least",
        })
    }
}

/// A work scheduler over a kernel launch of `num_blocks` blocks.
#[derive(Debug)]
pub struct Scheduler {
    policy: Policy,
    /// Per-stack FIFO of unscheduled blocks (Affinity*); single queue at
    /// index 0 for Baseline.
    queues: Vec<VecDeque<u32>>,
    remaining: usize,
    pub steals: u64,
}

impl Scheduler {
    pub fn new(policy: Policy, num_blocks: u32, cfg: &SystemConfig) -> Self {
        let mut queues = match policy {
            Policy::Baseline => vec![VecDeque::with_capacity(num_blocks as usize)],
            _ => vec![VecDeque::new(); cfg.num_stacks],
        };
        for b in 0..num_blocks {
            match policy {
                Policy::Baseline => queues[0].push_back(b),
                _ => queues[affinity_stack(b, cfg)].push_back(b),
            }
        }
        Self {
            policy,
            queues,
            remaining: num_blocks as usize,
            steals: 0,
        }
    }

    /// Blocks not yet dispatched.
    pub fn remaining(&self) -> usize {
        self.remaining
    }

    /// Pick the next block for an SM on `stack`. Returns `None` when no
    /// block is eligible (for Affinity, the stack's queue is empty; the SM
    /// idles even though other stacks may still have work — the load
    /// imbalance §6.7 measures).
    pub fn next_for(&mut self, stack: usize) -> Option<u32> {
        let picked = match self.policy {
            Policy::Baseline => self.queues[0].pop_front(),
            Policy::Affinity => self.queues[stack].pop_front(),
            Policy::AffinityStealing => self.queues[stack].pop_front().or_else(|| {
                // Steal from the most loaded stack.
                let victim = (0..self.queues.len())
                    .filter(|&s| s != stack)
                    .max_by_key(|&s| self.queues[s].len())?;
                if self.queues[victim].is_empty() {
                    return None;
                }
                self.steals += 1;
                // Steal from the tail: the tail blocks are furthest from
                // the victim's current locality frontier.
                self.queues[victim].pop_back()
            }),
        };
        if picked.is_some() {
            self.remaining -= 1;
        }
        picked
    }

    /// Whether all blocks have been dispatched.
    pub fn empty(&self) -> bool {
        self.remaining == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> SystemConfig {
        SystemConfig::default()
    }

    #[test]
    fn eq1_worked_example() {
        // Paper: N_blocks_per_stack = 24 with 4 SMs x 6 blocks. Blocks
        // 0..23 -> stack 0, 24..47 -> stack 1, ..., 96..119 -> stack 0.
        let c = cfg();
        assert_eq!(affinity_stack(0, &c), 0);
        assert_eq!(affinity_stack(23, &c), 0);
        assert_eq!(affinity_stack(24, &c), 1);
        assert_eq!(affinity_stack(95, &c), 3);
        assert_eq!(affinity_stack(96, &c), 0);
    }

    #[test]
    fn equal_share_per_stack() {
        // "When N is the number of memory stacks and T is the total number
        // of thread-blocks, T/N thread-blocks have the same affinity."
        let c = cfg();
        let t = 960u32;
        let mut counts = [0usize; 4];
        for b in 0..t {
            counts[affinity_stack(b, &c)] += 1;
        }
        assert_eq!(counts, [240, 240, 240, 240]);
    }

    #[test]
    fn baseline_dispatches_in_order_anywhere() {
        let c = cfg();
        let mut s = Scheduler::new(Policy::Baseline, 10, &c);
        assert_eq!(s.next_for(3), Some(0));
        assert_eq!(s.next_for(0), Some(1));
        assert_eq!(s.remaining(), 8);
    }

    #[test]
    fn affinity_respects_stacks() {
        let c = cfg();
        let mut s = Scheduler::new(Policy::Affinity, 96, &c);
        // Stack 2 only sees blocks 48..71.
        for expect in 48..72u32 {
            assert_eq!(s.next_for(2), Some(expect));
        }
        assert_eq!(s.next_for(2), None, "stack 2 ran dry; SM idles");
        assert!(!s.empty());
    }

    #[test]
    fn stealing_falls_back() {
        let c = cfg();
        let mut s = Scheduler::new(Policy::AffinityStealing, 48, &c);
        // Drain stack 0's own 24 blocks.
        for _ in 0..24 {
            assert!(s.next_for(0).is_some());
        }
        // Now steals from stack 1 (the only loaded one).
        let stolen = s.next_for(0).unwrap();
        assert!((24..48).contains(&stolen));
        assert_eq!(s.steals, 1);
        // Everything still dispatches exactly once.
        let mut seen = vec![false; 48];
        seen[stolen as usize] = true;
        for b in 0..24 {
            seen[b] = true;
        }
        while let Some(b) = s.next_for(1) {
            assert!(!seen[b as usize]);
            seen[b as usize] = true;
        }
        while let Some(b) = s.next_for(0) {
            assert!(!seen[b as usize]);
            seen[b as usize] = true;
        }
        assert!(s.empty());
        assert!(seen.iter().all(|&x| x));
    }

    #[test]
    fn affinity_with_eight_stacks() {
        let mut c = cfg();
        c.num_stacks = 8;
        assert_eq!(affinity_stack(24 * 8, &c), 0);
        assert_eq!(affinity_stack(24 * 7, &c), 7);
    }

    #[test]
    fn policy_and_fairness_parse() {
        assert_eq!(Policy::parse("affinity"), Some(Policy::Affinity));
        assert_eq!(Policy::parse("steal"), Some(Policy::AffinityStealing));
        assert_eq!(Policy::parse("nope"), None);
        assert_eq!(FairnessPolicy::parse("fcfs"), Some(FairnessPolicy::Fcfs));
        assert_eq!(FairnessPolicy::parse("rr"), Some(FairnessPolicy::RoundRobin));
        assert_eq!(
            FairnessPolicy::parse("least"),
            Some(FairnessPolicy::LeastIssued)
        );
        assert_eq!(FairnessPolicy::parse("zzz"), None);
        // Display round-trips through parse (the config loader relies on it).
        for f in [
            FairnessPolicy::Fcfs,
            FairnessPolicy::RoundRobin,
            FairnessPolicy::LeastIssued,
        ] {
            assert_eq!(FairnessPolicy::parse(&f.to_string()), Some(f));
        }
    }
}
