//! Spec lowering: a [`Session`] turns one [`ExperimentSpec`] into one
//! shared-engine run ([`crate::engine`]) and shapes the result into a
//! [`Report`].
//!
//! This module owns everything the legacy entry points used to implement
//! separately — the Fig-12 pinned dispatch, multi-kernel time-sharing with
//! arrivals and fairness, the CHoNDA host co-run, the single-kernel
//! coordinator pipeline (analysis → placement plan → mapped run), and the
//! run-alone baseline orchestration behind every slowdown number.
//! `Coordinator::run*`, `multiprog::run_mix/run_multi/run_hostmix` and
//! `host::run_host_sweep` are thin wrappers that construct a spec and call
//! in here; `tests/spec_equiv.rs` freezes their pre-redesign bodies as
//! oracles and proves the lowering cycle-identical (bit-exact f64) for
//! mechanisms × workloads × both DRAM backends.
//!
//! Lowering is deliberately *literal*: each dispatch mode reproduces its
//! historical pipeline exactly — same mapping order, same block dispatch
//! order, same report labels — because the equivalence guarantee is what
//! lets every caller migrate to specs without re-validating results.
//!
//! Run-alone baselines and `[sweep]` points are independent deterministic
//! simulations, so the session fans them out over [`crate::par`] worker
//! threads (config `sim_threads`, CLI `--threads`; `1` forces the
//! sequential loop) and collects results in deterministic order.
//! Parallelism shapes wall-clock time only: `tests/parallel_equiv.rs`
//! proves every report bit-identical across thread counts and backends.

use crate::addr::VirtualAddress;
use crate::analysis::{analyze_kernel, profile_trace, ObjectPattern};
use crate::config::SystemConfig;
use crate::coordinator::Mechanism;
use crate::engine::{
    AppCtx, BlockRef, BlockSource, Engine, EngineOptions, EngineRaw, HostStream,
};
use crate::gpu::{Sm, Topology};
use crate::multiprog::{home_of, MixPlacement};
use crate::par;
use crate::placement::{self, PlacementPlan};
use crate::report::Json;
use crate::rng::Rng;
use crate::sched::{affinity_stack, FairnessPolicy, Policy};
use crate::shard;
use crate::sim::{map_objects, KernelRun};
use crate::spec::{ArrivalKind, ArrivalSpec, Baselines, Dispatch, ExperimentSpec, WorkloadSel};
use crate::stats::{self, QuantileSketch, RunReport, ServiceStats};
use crate::trace::KernelTrace;
use crate::vm::VirtualMemory;
use crate::workloads::{suite, BuiltWorkload};
use anyhow::{bail, ensure};
use std::collections::{HashMap, VecDeque};

// ---------------------------------------------------------------------------
// Report.
// ---------------------------------------------------------------------------

/// What kind of traffic a [`SourceReport`] row describes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SourceKind {
    /// An NDP kernel (thread-blocks on the stacks' SMs).
    Ndp,
    /// The host-processor request stream.
    Host,
}

impl std::fmt::Display for SourceKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Self::Ndp => "ndp",
            Self::Host => "host",
        })
    }
}

/// Per-source outcome of a session run.
#[derive(Clone, Debug)]
pub struct SourceReport {
    pub kind: SourceKind,
    pub workload: String,
    /// Home stack (NDP kernels under pinned/shared dispatch).
    pub home: Option<usize>,
    /// Launch time in SM cycles.
    pub arrival: f64,
    /// Response cycles (completion − arrival; host: stream completion).
    pub cycles: f64,
    /// Slowdown vs the run-alone baseline, when one was computed
    /// (`None` under `baselines = none` and for solo kernel runs).
    pub slowdown: Option<f64>,
}

/// The structured result of one session run: the familiar [`RunReport`]
/// (every field the legacy entry points produced) plus the per-source
/// breakdown and the spec label. Derefs to [`RunReport`] so existing
/// report consumers keep working unchanged.
#[derive(Clone, Debug)]
pub struct Report {
    /// The spec's `name` label (sweep points get `key=value` appended).
    pub spec_name: Option<String>,
    /// One row per declared traffic source, NDP kernels first.
    pub sources: Vec<SourceReport>,
    /// The aggregate run report (superset semantics: identical to what
    /// the matching legacy entry point returned).
    pub run: RunReport,
}

impl std::ops::Deref for Report {
    type Target = RunReport;

    fn deref(&self) -> &RunReport {
        &self.run
    }
}

impl Report {
    /// JSON rendering: the [`RunReport`] object extended with `spec`
    /// (when the spec was named) and a `sources` array.
    pub fn to_json(&self) -> Json {
        let mut o = Json::from(&self.run);
        if let Some(name) = &self.spec_name {
            o.push("spec", Json::Str(name.clone()));
        }
        if !self.sources.is_empty() {
            o.push(
                "sources",
                Json::Arr(
                    self.sources
                        .iter()
                        .map(|s| {
                            let mut so = Json::obj();
                            so.push("kind", Json::Str(s.kind.to_string()))
                                .push("workload", Json::Str(s.workload.clone()))
                                .push(
                                    "home",
                                    match s.home {
                                        Some(h) => Json::Num(h as f64),
                                        None => Json::Null,
                                    },
                                )
                                .push("arrival", Json::Num(s.arrival))
                                .push("cycles", Json::Num(s.cycles));
                            if let Some(sd) = s.slowdown {
                                so.push("slowdown", Json::Num(sd));
                            }
                            so
                        })
                        .collect(),
                ),
            );
        }
        o
    }
}

// ---------------------------------------------------------------------------
// Placement planning (moved from `Coordinator`; it delegates here).
// ---------------------------------------------------------------------------

/// Build the per-object placement plan a mechanism uses for a workload:
/// compile-time symbolic analysis where IR exists, the §4.3.2 trace
/// profiler for the rest.
pub fn plan_for_mechanism(
    cfg: &SystemConfig,
    wl: &BuiltWorkload,
    mech: Mechanism,
) -> PlacementPlan {
    let n = wl.trace.objects.len();
    match mech {
        Mechanism::FgpOnly | Mechanism::FgpAffinity => PlacementPlan::all_fgp(n),
        Mechanism::CgpOnly => placement::cgp_only_plan(n, cfg),
        Mechanism::CgpFta => placement::fta_plan(&wl.trace, cfg),
        Mechanism::MigrationFta => placement::migration_fta_plan(n),
        Mechanism::Coda | Mechanism::CodaStealing => {
            let compile: HashMap<u16, ObjectPattern> = wl
                .ir
                .as_ref()
                .map(|ir| analyze_kernel(ir, &wl.env))
                .unwrap_or_default();
            // The profiler sees a trace sample, as a real profiling run
            // would.
            let profile =
                profile_trace(&wl.trace, cfg.page_size, |b| affinity_stack(b, cfg));
            placement::coda_plan(n, &compile, &profile, cfg)
        }
    }
}

/// Fraction of a workload's accesses that land on objects the plan
/// localizes (CGP or page-overridden) — the §6.4 no-degradation test.
fn localizable_traffic(wl: &BuiltWorkload, plan: &PlacementPlan) -> f64 {
    let mut per_obj = vec![0u64; wl.trace.objects.len()];
    for b in &wl.trace.blocks {
        for a in &b.accesses {
            per_obj[a.obj as usize] += 1;
        }
    }
    let total: u64 = per_obj.iter().sum();
    let localized: u64 = per_obj
        .iter()
        .enumerate()
        .filter(|(o, _)| !matches!(plan.per_object[*o], placement::Placement::Fgp))
        .map(|(_, n)| *n)
        .sum();
    if total == 0 {
        0.0
    } else {
        localized as f64 / total as f64
    }
}

// ---------------------------------------------------------------------------
// Workload resolution.
// ---------------------------------------------------------------------------

/// A resolved traffic-source workload: suite-built (owned) or borrowed
/// from the caller through the spec.
enum Wl<'x> {
    Owned(Box<BuiltWorkload>),
    Borrowed(&'x BuiltWorkload),
    RawTrace(&'x KernelTrace),
}

impl Wl<'_> {
    fn resolve<'x>(sel: &WorkloadSel<'x>, cfg: &SystemConfig) -> crate::Result<Wl<'x>> {
        Ok(match *sel {
            WorkloadSel::Named(n) => Wl::Owned(suite::build(n, cfg)?),
            WorkloadSel::Prebuilt(w) => Wl::Borrowed(w),
            WorkloadSel::Trace(t) => Wl::RawTrace(t),
        })
    }

    fn built(&self) -> crate::Result<&BuiltWorkload> {
        match self {
            Wl::Owned(b) => Ok(b),
            Wl::Borrowed(w) => Ok(w),
            Wl::RawTrace(_) => bail!(
                "a kernel source needs a built workload; bare traces are only \
                 valid for the host stream"
            ),
        }
    }

    fn trace(&self) -> &KernelTrace {
        match self {
            Wl::Owned(b) => &b.trace,
            Wl::Borrowed(w) => &w.trace,
            Wl::RawTrace(t) => t,
        }
    }

    fn name(&self) -> &str {
        match self {
            Wl::Owned(b) => b.name,
            Wl::Borrowed(w) => w.name,
            Wl::RawTrace(t) => &t.name,
        }
    }
}

// ---------------------------------------------------------------------------
// Block sources (moved from `multiprog`, parameterized by home stacks).
// ---------------------------------------------------------------------------

/// [`BlockSource`] reproducing the historical `run_mix` dispatch exactly:
/// app `i`'s blocks run only on its home stack's SMs, in launch order,
/// and a retiring block's slot refills from the same app.
struct PinnedSource {
    next_block: Vec<usize>,
    num_blocks: Vec<usize>,
    homes: Vec<usize>,
}

impl BlockSource for PinnedSource {
    fn seed(&mut self, topo: &Topology, place: &mut dyn FnMut(usize, usize, BlockRef)) {
        // Seed each app's home-stack SM slots.
        for app in 0..self.num_blocks.len() {
            let sms: Vec<usize> = topo.sms_of_stack(self.homes[app]).map(|s| s.id).collect();
            let capacity = sms.len() * topo.blocks_per_sm;
            for slot in 0..capacity {
                if self.next_block[app] >= self.num_blocks[app] {
                    break;
                }
                let b = self.next_block[app];
                self.next_block[app] += 1;
                place(
                    sms[slot % sms.len()],
                    slot / sms.len(),
                    BlockRef {
                        app: app as u32,
                        block: b as u32,
                    },
                );
            }
        }
    }

    fn refill(&mut self, _sm: Sm, retired: Option<BlockRef>, _now: f64) -> Option<BlockRef> {
        let app = retired?.app as usize;
        if self.next_block[app] < self.num_blocks[app] {
            let b = self.next_block[app];
            self.next_block[app] += 1;
            Some(BlockRef {
                app: app as u32,
                block: b as u32,
            })
        } else {
            None
        }
    }
}

/// [`BlockSource`] for multi-kernel scheduling: per-app FIFO block
/// queues, arrival times, home stacks, and the fairness arbiter.
struct SharedSource {
    queues: Vec<VecDeque<u32>>,
    arrival: Vec<f64>,
    home: Vec<usize>,
    policy: Policy,
    fairness: FairnessPolicy,
    issued: Vec<u64>,
    rr_cursor: usize,
}

impl SharedSource {
    fn new(
        launches: &[(usize, f64)], // (num_blocks, arrival) per app
        homes: &[usize],
        policy: Policy,
        fairness: FairnessPolicy,
        only_app: Option<usize>,
    ) -> Self {
        let queues = launches
            .iter()
            .enumerate()
            .map(|(i, &(n, _))| {
                if only_app.is_some_and(|o| o != i) {
                    VecDeque::new()
                } else {
                    (0..n as u32).collect()
                }
            })
            .collect();
        Self {
            queues,
            arrival: launches.iter().map(|&(_, t)| t).collect(),
            home: homes.to_vec(),
            policy,
            fairness,
            issued: vec![0; launches.len()],
            rr_cursor: 0,
        }
    }

    /// Apps with pending blocks that have arrived by `now` and whose
    /// blocks may run on `stack` under the block-level policy.
    fn eligible(&self, stack: usize, now: f64) -> Vec<usize> {
        let arrived: Vec<usize> = (0..self.queues.len())
            .filter(|&i| !self.queues[i].is_empty() && self.arrival[i] <= now)
            .collect();
        match self.policy {
            Policy::Baseline => arrived,
            Policy::Affinity => arrived
                .into_iter()
                .filter(|&i| self.home[i] == stack)
                .collect(),
            Policy::AffinityStealing => {
                let homed: Vec<usize> = arrived
                    .iter()
                    .copied()
                    .filter(|&i| self.home[i] == stack)
                    .collect();
                if homed.is_empty() {
                    arrived
                } else {
                    homed
                }
            }
        }
    }

    fn pick(&mut self, stack: usize, now: f64) -> Option<BlockRef> {
        let elig = self.eligible(stack, now);
        if elig.is_empty() {
            return None;
        }
        let app = match self.fairness {
            FairnessPolicy::Fcfs => elig.into_iter().min_by(|&a, &b| {
                self.arrival[a]
                    .partial_cmp(&self.arrival[b])
                    .expect("arrival times are finite")
                    .then(a.cmp(&b))
            })?,
            FairnessPolicy::RoundRobin => {
                let n = self.queues.len();
                (1..=n)
                    .map(|k| (self.rr_cursor + k) % n)
                    .find(|i| elig.contains(i))?
            }
            FairnessPolicy::LeastIssued => elig.into_iter().min_by_key(|&i| (self.issued[i], i))?,
        };
        self.rr_cursor = app;
        self.issued[app] += 1;
        let block = self.queues[app].pop_front()?;
        Some(BlockRef {
            app: app as u32,
            block,
        })
    }
}

impl BlockSource for SharedSource {
    fn seed(&mut self, topo: &Topology, place: &mut dyn FnMut(usize, usize, BlockRef)) {
        // Breadth-first over SMs, as in the single-kernel path; only
        // already-arrived apps participate at t=0.
        for slot in 0..topo.blocks_per_sm {
            for sm in &topo.sms {
                if let Some(br) = self.pick(sm.stack, 0.0) {
                    place(sm.id, slot, br);
                }
            }
        }
    }

    fn refill(&mut self, sm: Sm, _retired: Option<BlockRef>, now: f64) -> Option<BlockRef> {
        self.pick(sm.stack, now)
    }

    fn next_arrival_after(&self, now: f64) -> Option<f64> {
        self.queues
            .iter()
            .zip(&self.arrival)
            .filter(|(q, &t)| !q.is_empty() && t > now)
            .map(|(_, &t)| t)
            .fold(None, |m, t| {
                Some(match m {
                    None => t,
                    Some(m) => m.min(t),
                })
            })
    }
}

/// The deterministic interarrival generator behind an `[arrivals]`
/// stream. All randomness comes from [`crate::rng`], seeded from the
/// spec, so service runs replay bit-identically.
enum ArrivalGen {
    Poisson {
        rng: Rng,
        rate: f64,
    },
    /// `burst` back-to-back requests per arrival event; exponential gaps
    /// between events scaled so the long-run rate stays `rate`.
    Bursty {
        rng: Rng,
        rate: f64,
        burst: u64,
        left_in_burst: u64,
    },
    Trace {
        gaps: Vec<f64>,
        i: usize,
    },
}

impl ArrivalGen {
    fn new(a: &ArrivalSpec, default_seed: u64) -> Self {
        let rng = Rng::new(a.seed.unwrap_or(default_seed));
        match a.kind {
            ArrivalKind::Poisson => ArrivalGen::Poisson {
                rng,
                rate: a.rate.unwrap_or(0.0),
            },
            ArrivalKind::Bursty => ArrivalGen::Bursty {
                rng,
                rate: a.rate.unwrap_or(0.0),
                burst: a.burst.unwrap_or(4),
                left_in_burst: 0,
            },
            ArrivalKind::Trace => ArrivalGen::Trace {
                gaps: a.interarrivals.clone(),
                i: 0,
            },
        }
    }

    /// Gap to the next request. `1 - f64()` lies in (0, 1], so the log is
    /// finite and the gap non-negative.
    fn next_gap(&mut self) -> f64 {
        match self {
            ArrivalGen::Poisson { rng, rate } => -(1.0 - rng.f64()).ln() / *rate,
            ArrivalGen::Bursty {
                rng,
                rate,
                burst,
                left_in_burst,
            } => {
                if *left_in_burst > 0 {
                    *left_in_burst -= 1;
                    0.0
                } else {
                    *left_in_burst = *burst - 1;
                    -(1.0 - rng.f64()).ln() * *burst as f64 / *rate
                }
            }
            ArrivalGen::Trace { gaps, i } => {
                let g = gaps[*i];
                *i = (*i + 1) % gaps.len();
                g
            }
        }
    }
}

/// Per-stage progress of one in-flight request. Counters run *down*:
/// `to_dispatch` blocks still to hand to the engine, `to_retire`
/// retirements still to attribute, `waiting` unmet `after` edges.
struct StageState {
    to_dispatch: u32,
    to_retire: u32,
    waiting: u32,
}

/// One in-flight request: arrival stamp plus its stage DAG state.
struct ReqState {
    arrival: f64,
    stages: Vec<StageState>,
    /// Stages not yet complete; 0 = the request is done.
    live: u32,
}

/// [`BlockSource`] for service mode: an open-loop request stream lowered
/// onto the engine's arrival seam. Each admitted request instantiates
/// every kernel once as a *stage*; stages wired by `after` edges start
/// when their dependencies complete (arrival-on-completion), roots start
/// at the request's arrival. Blocks re-dispatch the kernel's template
/// trace per request (the engine keeps no per-block state, so the
/// exactly-once contract holds per pending unit).
///
/// Deliberate approximations, chosen to keep the source deterministic and
/// fixed-memory:
///
/// * **Global FCFS.** `policy`/`fairness` do not apply: any SM runs the
///   oldest ready stage's next block (homes still steer object
///   placement). Honoring affinity could strand completion-created work
///   on stacks with no armed arrival to wake them.
/// * **Oldest-first retirement attribution.** The engine does not say
///   which request's block retired, so retirements credit the oldest
///   outstanding dispatch of that kernel. Totals are exact; per-request
///   latency is approximate only when one kernel's blocks from different
///   requests overlap in flight.
/// * **Completion wake-up.** A stage readied by a completion is picked up
///   by the retiring slot immediately, and the source announces a
///   just-after-now wake through [`BlockSource::next_arrival_after`] so
///   the engine sweeps *other* idle slots too — a multi-block tail stage
///   fans out across the machine instead of serializing on the retiring
///   slot after the generator runs dry.
///
/// Memory is bounded by the max in-flight request count (slab slots are
/// recycled) plus the fixed-size [`QuantileSketch`] — an arbitrarily long
/// stream never accumulates per-request state.
struct ServiceSource {
    blocks_per_kernel: Vec<u32>,
    /// `dependents[k]` = stages with an `after` edge from `k`.
    dependents: Vec<Vec<u32>>,
    /// Number of `after` edges into each stage.
    dep_count: Vec<u32>,
    gen: ArrivalGen,
    /// The generator's pending arrival time (`None` = stream exhausted).
    next_arrival: Option<f64>,
    /// Hard dispatch stop: past this cycle nothing new is admitted or
    /// handed out; in-flight windows drain and the rest counts
    /// incomplete.
    duration: Option<f64>,
    max_requests: Option<u64>,
    offered: u64,
    completed: u64,
    /// Arrival time of the most recently admitted request: the stream's
    /// real span when the requests cap ends it before `duration`.
    last_arrival: f64,
    /// True when the requests cap (not the duration) ended the stream.
    capped: bool,
    /// Pending completion wake (a just-after-now time): announced via
    /// `next_arrival_after` so idle slots sweep for stages a completion
    /// readied (see the completion wake-up note above).
    wake: Option<f64>,
    /// Request slab + free list: slots recycle, so memory tracks the max
    /// in-flight count, not the stream length.
    reqs: Vec<ReqState>,
    free: Vec<usize>,
    /// Global FCFS queue of (request, stage) with blocks left to
    /// dispatch; the front entry stays until its blocks are exhausted.
    ready: VecDeque<(u32, u32)>,
    /// Per-kernel FIFO of request ids with outstanding dispatches, for
    /// retirement attribution.
    dispatched: Vec<VecDeque<u32>>,
    /// Streaming response-time percentiles (fixed memory).
    sketch: QuantileSketch,
    /// Worklist scratch for completion cascades (kept to avoid a per-
    /// completion allocation).
    scratch: Vec<u32>,
    /// This instance's residue class of the global arrival sequence: the
    /// sharded engine deals requests round-robin across shards (see
    /// [`Self::sharded`]); the sequential engine is the 0-of-1 identity.
    shard_index: u64,
    shard_count: u64,
    /// Arrivals *generated* so far (admitted here or by a peer shard).
    arr_seq: u64,
}

impl ServiceSource {
    fn new(
        blocks_per_kernel: Vec<u32>,
        after: &[Vec<usize>],
        a: &ArrivalSpec,
        default_seed: u64,
    ) -> Self {
        let n = blocks_per_kernel.len();
        let mut dependents = vec![Vec::new(); n];
        let mut dep_count = vec![0u32; n];
        for (i, deps) in after.iter().enumerate() {
            for &d in deps {
                dependents[d].push(i as u32);
                dep_count[i] += 1;
            }
        }
        let mut gen = ArrivalGen::new(a, default_seed);
        let first = gen.next_gap();
        Self {
            blocks_per_kernel,
            dependents,
            dep_count,
            gen,
            next_arrival: Some(first),
            duration: a.duration,
            max_requests: a.requests,
            offered: 0,
            completed: 0,
            last_arrival: 0.0,
            capped: false,
            wake: None,
            reqs: Vec::new(),
            free: Vec::new(),
            ready: VecDeque::new(),
            dispatched: vec![VecDeque::new(); n],
            sketch: QuantileSketch::new(),
            scratch: Vec::new(),
            shard_index: 0,
            shard_count: 1,
            arr_seq: 0,
        }
    }

    /// Restrict this source to shard `index` of `count`. Every shard
    /// runs the same deterministic generator (the RNGs stay in
    /// lockstep), but admits only the arrivals whose global sequence
    /// number falls in its residue class — residues partition the
    /// stream, so each request is admitted by exactly one shard and the
    /// shards' unions (offered, completed, response samples) reproduce
    /// the sequential stream's totals exactly.
    fn sharded(mut self, index: u64, count: u64) -> Self {
        debug_assert!(index < count);
        self.shard_index = index;
        self.shard_count = count;
        self
    }

    /// Admit every generated arrival due by `now`, so
    /// [`BlockSource::next_arrival_after`] only ever reports strictly-
    /// future generator times.
    ///
    /// Termination: every stream has a requests cap (loop iterations are
    /// bounded by `max_requests`) or a duration with gaps that make
    /// positive progress — Poisson/bursty rates are validated positive
    /// (a zero exponential gap needs an exact-zero rng draw, never a
    /// run of them), and a duration-only trace is validated to have a
    /// positive gap-cycle sum, so `next_arrival` eventually exceeds
    /// `min(now, duration)` and the loop exits.
    fn advance(&mut self, now: f64) {
        while let Some(t) = self.next_arrival {
            if t > now {
                break;
            }
            if self.duration.is_some_and(|d| t > d) {
                self.next_arrival = None;
                break;
            }
            // Deal the arrival to its shard by residue class (under the
            // sequential 0-of-1 identity every arrival is admitted, so
            // `arr_seq == offered` and the cap check is unchanged). The
            // cap counts *generated* arrivals so every shard ends the
            // stream at the same request.
            let i = self.arr_seq;
            self.arr_seq += 1;
            if i % self.shard_count == self.shard_index {
                self.admit(t);
            }
            if self.max_requests.is_some_and(|m| self.arr_seq >= m) {
                self.next_arrival = None;
                self.capped = true;
            } else {
                self.next_arrival = Some(t + self.gen.next_gap());
            }
        }
    }

    fn admit(&mut self, t: f64) {
        self.offered += 1;
        self.last_arrival = t;
        let id = match self.free.pop() {
            Some(id) => id,
            None => {
                self.reqs.push(ReqState {
                    arrival: 0.0,
                    stages: Vec::new(),
                    live: 0,
                });
                self.reqs.len() - 1
            }
        };
        let n = self.blocks_per_kernel.len();
        let req = &mut self.reqs[id];
        req.arrival = t;
        req.live = n as u32;
        req.stages.clear();
        for k in 0..n {
            req.stages.push(StageState {
                to_dispatch: self.blocks_per_kernel[k],
                to_retire: self.blocks_per_kernel[k],
                waiting: self.dep_count[k],
            });
        }
        for k in 0..n {
            if self.dep_count[k] == 0 {
                self.stage_ready(id, k, t);
            }
        }
    }

    /// A stage's dependencies are met: queue its blocks (or, for an
    /// empty-trace stage, complete it on the spot and cascade).
    fn stage_ready(&mut self, req: usize, k: usize, now: f64) {
        if self.reqs[req].stages[k].to_retire == 0 {
            self.stage_complete(req, k, now);
        } else {
            self.ready.push_back((req as u32, k as u32));
        }
    }

    /// Stage `first` of `req` completed at `now`: release dependents, and
    /// when the last stage finishes, record the response time and recycle
    /// the slab slot. Iterative worklist — a chain of empty stages must
    /// not recurse.
    fn stage_complete(&mut self, req: usize, first: usize, now: f64) {
        debug_assert!(self.scratch.is_empty());
        self.scratch.push(first as u32);
        while let Some(k) = self.scratch.pop() {
            let k = k as usize;
            self.reqs[req].live -= 1;
            // Take/restore the edge list so the loop can mutate the
            // disjoint request/queue state without aliasing it.
            let deps = std::mem::take(&mut self.dependents[k]);
            for &d in &deps {
                let st = &mut self.reqs[req].stages[d as usize];
                st.waiting -= 1;
                if st.waiting == 0 {
                    if st.to_retire == 0 {
                        self.scratch.push(d);
                    } else {
                        self.ready.push_back((req as u32, d));
                        // Announce a completion wake: idle slots must
                        // sweep for this stage's blocks rather than wait
                        // for a generator arrival that may never come.
                        self.wake = Some(just_after(now));
                    }
                }
            }
            self.dependents[k] = deps;
        }
        if self.reqs[req].live == 0 {
            self.completed += 1;
            self.sketch.record(now - self.reqs[req].arrival);
            self.free.push(req);
        }
    }

    /// Next block of the oldest ready stage (global FCFS).
    fn pop_ready(&mut self) -> Option<BlockRef> {
        let &(req, k) = self.ready.front()?;
        let total = self.blocks_per_kernel[k as usize];
        let st = &mut self.reqs[req as usize].stages[k as usize];
        let block = total - st.to_dispatch;
        st.to_dispatch -= 1;
        if st.to_dispatch == 0 {
            self.ready.pop_front();
        }
        self.dispatched[k as usize].push_back(req);
        Some(BlockRef { app: k, block })
    }
}

impl BlockSource for ServiceSource {
    fn seed(&mut self, topo: &Topology, place: &mut dyn FnMut(usize, usize, BlockRef)) {
        // Admit anything due at t=0 (a trace gap of 0, a burst head),
        // then fill slot-major like the shared mix.
        self.advance(0.0);
        'fill: for slot in 0..topo.blocks_per_sm {
            for sm in &topo.sms {
                match self.pop_ready() {
                    Some(br) => place(sm.id, slot, br),
                    None => break 'fill,
                }
            }
        }
    }

    fn refill(&mut self, _sm: Sm, retired: Option<BlockRef>, now: f64) -> Option<BlockRef> {
        if let Some(br) = retired {
            let k = br.app as usize;
            let req = self.dispatched[k]
                .pop_front()
                .expect("retirement without a matching dispatch")
                as usize;
            let st = &mut self.reqs[req].stages[k];
            st.to_retire -= 1;
            // All blocks dispatched before any retires within a request,
            // so to_retire reaching 0 implies to_dispatch already did.
            if st.to_retire == 0 {
                self.stage_complete(req, k, now);
            }
        }
        self.advance(now);
        if self.duration.is_some_and(|d| now > d) {
            return None;
        }
        self.pop_ready()
    }

    fn next_arrival_after(&self, now: f64) -> Option<f64> {
        let generated = self.next_arrival.filter(|&t| t > now);
        let wake = self.wake.filter(|&t| t > now);
        match (generated, wake) {
            (Some(g), Some(w)) => Some(g.min(w)),
            (g, w) => g.or(w),
        }
    }

    fn on_arrival(&mut self, now: f64) {
        if self.wake.is_some_and(|w| w <= now) {
            self.wake = None;
        }
        self.advance(now);
    }
}

/// The smallest representable time strictly after `t` (finite, `>= 0`):
/// completion wakes must honor the [`BlockSource`] strictly-future
/// arrival contract without displacing any real simulated event.
fn just_after(t: f64) -> f64 {
    f64::from_bits(t.to_bits() + 1)
}

/// One engine execution of a shared-dispatch layout: the NDP kernels in
/// `launches` (optionally restricted to `only_app`) co-running with an
/// optional host stream. Every shared/pinned baseline and co-run goes
/// through here, so they share the event-loop physics by construction.
#[allow(clippy::too_many_arguments)]
fn exec_shared(
    cfg: &SystemConfig,
    apps: &[&BuiltWorkload],
    app_bases: &[Vec<VirtualAddress>],
    launches: &[(usize, f64)],
    homes: &[usize],
    policy: Policy,
    fairness: FairnessPolicy,
    only_app: Option<usize>,
    host: Option<HostStream<'_>>,
    vm: &mut VirtualMemory,
) -> EngineRaw {
    let app_ctxs: Vec<AppCtx<'_>> = apps
        .iter()
        .zip(app_bases)
        .map(|(a, b)| AppCtx {
            trace: &a.trace,
            obj_base: b.as_slice(),
        })
        .collect();
    let opts = EngineOptions {
        // The multiprogrammed paths have never modelled the L2
        // filter; keeping it off preserves the historical cycles.
        l2_filter: false,
        migrate_on_first_touch: false,
    };
    // Shard the joint run when the plan allows it and the dispatch
    // decomposes by home stack: under `Affinity` an app's blocks run only
    // on its home stack's SMs, and every fairness decision except
    // round-robin depends only on that stack's own apps (the RR cursor is
    // machine-global state), so clearing foreign apps' queues hands each
    // shard exactly the sequential dispatch restricted to its stacks.
    // Solo baselines (`only_app`) stay sequential — they are the
    // run-alone oracle every slowdown number divides by.
    let host_active = host.is_some() && cfg.host_mlp > 0 && cfg.host_passes > 0;
    if only_app.is_none()
        && !apps.is_empty()
        && policy == Policy::Affinity
        && fairness != FairnessPolicy::RoundRobin
    {
        if let Some(plan) = shard::plan(cfg, &opts, host_active) {
            let (raw, _) = shard::ShardEngine {
                cfg,
                apps: app_ctxs,
                vm: &*vm,
                opts,
                host,
            }
            .run(&plan, |s| {
                let mut src = SharedSource::new(launches, homes, policy, fairness, only_app);
                for (i, q) in src.queues.iter_mut().enumerate() {
                    if plan.owner[homes[i]] != s {
                        q.clear();
                    }
                }
                src
            });
            return raw;
        }
    }
    let mut source = SharedSource::new(launches, homes, policy, fairness, only_app);
    Engine {
        cfg,
        apps: app_ctxs,
        vm,
        opts,
        host,
    }
    .run(&mut source)
}

// ---------------------------------------------------------------------------
// Session.
// ---------------------------------------------------------------------------

/// A validated, runnable experiment: the spec plus its fully-resolved
/// system configuration and dispatch mode.
pub struct Session<'a> {
    spec: ExperimentSpec<'a>,
    cfg: SystemConfig,
    dispatch: Dispatch,
    baselines: Baselines,
}

impl<'a> Session<'a> {
    /// Resolve `spec` against `base`: apply the `[system]` and host
    /// overrides, settle `auto` dispatch/baselines, and validate the spec
    /// shape. The config is re-validated only when the spec modified it —
    /// a pristine base config is the caller's responsibility, exactly as
    /// it was for the legacy entry points.
    pub fn new(base: SystemConfig, spec: ExperimentSpec<'a>) -> crate::Result<Session<'a>> {
        let mut cfg = base;
        for (k, v) in &spec.overrides {
            cfg.set(k, v)?;
        }
        let mut modified = !spec.overrides.is_empty();
        if let Some(h) = &spec.host {
            if let Some(m) = h.mlp {
                cfg.host_mlp = m;
            }
            if let Some(p) = h.passes {
                cfg.host_passes = p;
            }
            if let Some(f) = h.ddr_fraction {
                cfg.host_ddr_fraction = f;
            }
            modified |= h.mlp.is_some() || h.passes.is_some() || h.ddr_fraction.is_some();
        }
        if let Some(t) = &spec.topology {
            cfg.topology = t.kind;
            if let Some(c) = t.mesh_cols {
                cfg.mesh_cols = c;
            }
            if let Some(l) = t.hop_latency_ns {
                cfg.hop_latency_ns = l;
            }
            if let Some(b) = t.link_bw_gbs {
                cfg.link_bw_gbs = b;
            }
            if let Some(w) = t.window_cycles {
                cfg.net_window_cycles = w;
            }
            modified = true;
        }
        if modified {
            cfg.validate()?;
        }

        let dispatch = match spec.dispatch {
            Dispatch::Auto => {
                if spec.arrivals.is_none()
                    && spec.host.is_none()
                    && spec.kernels.len() == 1
                    && spec.kernels[0].mechanism.is_some()
                {
                    Dispatch::Kernel
                } else {
                    Dispatch::Shared
                }
            }
            d => d,
        };
        // Kernel and pinned dispatch never ran baselines historically, so
        // `auto` resolves to `none` there; an *explicit* solo/host-split
        // request on those dispatches is rejected below rather than
        // silently dropped.
        let baselines = match (spec.output.baselines, dispatch) {
            (Baselines::Auto, Dispatch::Kernel | Dispatch::Pinned) => Baselines::None,
            // Run-alone comparisons are meaningless against an open-loop
            // stream, so service mode never runs them.
            (Baselines::Auto, _) if spec.arrivals.is_some() => Baselines::None,
            (Baselines::Auto, _) => {
                if spec.host.is_some() {
                    Baselines::HostSplit
                } else {
                    Baselines::Solo
                }
            }
            (b, _) => b,
        };

        // Shape validation. A spec that cannot mean what it says is a
        // hard error — lowering never silently drops a field.
        for (i, k) in spec.kernels.iter().enumerate() {
            ensure!(
                !matches!(k.workload, WorkloadSel::Trace(_)),
                "kernel {i}: bare traces are only valid for the host stream"
            );
            ensure!(
                k.arrival >= 0.0 && k.arrival.is_finite(),
                "arrival time of app {i} must be a non-negative real, got {}",
                k.arrival
            );
            if let Some(h) = k.home {
                ensure!(
                    h < cfg.num_stacks,
                    "kernel {i}: home stack {h} out of range (num_stacks = {})",
                    cfg.num_stacks
                );
            }
            if spec.arrivals.is_none() {
                ensure!(
                    k.after.is_empty(),
                    "kernel {i}: after edges only apply under an [arrivals] \
                     service stream"
                );
            }
        }
        match dispatch {
            Dispatch::Kernel => {
                ensure!(
                    spec.kernels.len() == 1,
                    "kernel dispatch runs exactly one kernel, got {}",
                    spec.kernels.len()
                );
                ensure!(
                    spec.host.is_none(),
                    "kernel dispatch cannot co-run a host stream; use shared dispatch"
                );
                let k = &spec.kernels[0];
                ensure!(
                    k.arrival == 0.0 && k.home.is_none() && k.placement.is_none(),
                    "kernel dispatch takes its placement from the mechanism; \
                     arrival/home/placement overrides do not apply"
                );
                ensure!(
                    baselines == Baselines::None,
                    "kernel dispatch runs no baselines; remove the explicit \
                     baselines = {baselines} (or use shared dispatch)"
                );
            }
            Dispatch::Pinned => {
                ensure!(
                    spec.kernels.len() <= cfg.num_stacks,
                    "pinned dispatch pins one app per stack ({} apps > {} stacks); \
                     use shared dispatch for oversubscribed mixes",
                    spec.kernels.len(),
                    cfg.num_stacks
                );
                ensure!(
                    spec.host.is_none(),
                    "pinned dispatch cannot co-run a host stream; use shared dispatch"
                );
                let mut seen = vec![false; cfg.num_stacks];
                for (i, k) in spec.kernels.iter().enumerate() {
                    ensure!(
                        k.mechanism.is_none(),
                        "kernel {i}: mechanism only applies to kernel dispatch"
                    );
                    ensure!(
                        k.arrival == 0.0,
                        "pinned dispatch launches every app at t=0 (kernel {i} \
                         arrives at {}); use shared dispatch for staggered mixes",
                        k.arrival
                    );
                    let home = k.home.unwrap_or_else(|| home_of(i, &cfg));
                    ensure!(
                        !seen[home],
                        "pinned dispatch needs distinct home stacks (stack {home} \
                         is claimed twice)"
                    );
                    seen[home] = true;
                }
                ensure!(
                    baselines == Baselines::None,
                    "pinned dispatch runs no baselines; remove the explicit \
                     baselines = {baselines} (or use shared dispatch)"
                );
            }
            Dispatch::Shared => {
                ensure!(
                    !spec.kernels.is_empty() || spec.host.is_some(),
                    "an experiment needs at least one traffic source (an NDP \
                     kernel or a host stream)"
                );
                for (i, k) in spec.kernels.iter().enumerate() {
                    ensure!(
                        k.mechanism.is_none(),
                        "kernel {i}: mechanism only applies to kernel dispatch \
                         (use placement = fgp|cgp for mixes)"
                    );
                }
                ensure!(
                    !(baselines == Baselines::Solo && spec.host.is_some()),
                    "solo baselines compare NDP apps against each other and \
                     cannot apply to a host co-run; use host-split or none"
                );
            }
            Dispatch::Auto => unreachable!("dispatch was resolved above"),
        }
        if let Some(a) = &spec.arrivals {
            ensure!(
                dispatch == Dispatch::Shared,
                "[arrivals] service mode requires shared dispatch, not {dispatch}"
            );
            ensure!(
                !spec.kernels.is_empty(),
                "[arrivals] needs at least one [[kernel]] stage to instantiate \
                 per request"
            );
            ensure!(
                baselines == Baselines::None,
                "service mode runs no run-alone baselines; remove the explicit \
                 baselines = {baselines}"
            );
            for (i, k) in spec.kernels.iter().enumerate() {
                ensure!(
                    k.arrival == 0.0,
                    "kernel {i}: launch offsets (arrival = {}) do not mix with \
                     an open-loop stream; use after edges for staging",
                    k.arrival
                );
                for &d in &k.after {
                    ensure!(
                        d < i,
                        "kernel {i}: after edge {d} must point at an earlier \
                         kernel (stage DAGs are ordered)"
                    );
                }
            }
            match a.kind {
                ArrivalKind::Poisson | ArrivalKind::Bursty => {
                    let rate = a.rate.ok_or_else(|| {
                        anyhow::anyhow!("[arrivals] kind = {} needs a rate", a.kind)
                    })?;
                    ensure!(
                        rate.is_finite() && rate > 0.0,
                        "[arrivals] rate must be a positive real, got {rate}"
                    );
                    ensure!(
                        a.interarrivals.is_empty(),
                        "[arrivals] interarrivals only apply to kind = trace"
                    );
                }
                ArrivalKind::Trace => {
                    ensure!(
                        !a.interarrivals.is_empty(),
                        "[arrivals] kind = trace needs a non-empty interarrivals \
                         list"
                    );
                    for g in &a.interarrivals {
                        ensure!(
                            g.is_finite() && *g >= 0.0,
                            "[arrivals] interarrival gaps must be non-negative \
                             reals, got {g}"
                        );
                    }
                    // An all-zero gap list never advances the generator
                    // clock, so a duration-only stop condition would admit
                    // requests forever at t=0. A requests cap bounds that
                    // burst; without one the cycle sum must be positive.
                    ensure!(
                        a.requests.is_some()
                            || a.interarrivals.iter().sum::<f64>() > 0.0,
                        "[arrivals] a duration-bounded trace needs a positive \
                         interarrival sum (all-zero gaps would admit requests \
                         forever); add a requests cap or a positive gap"
                    );
                    ensure!(
                        a.rate.is_none(),
                        "[arrivals] rate does not apply to kind = trace"
                    );
                }
            }
            if a.kind != ArrivalKind::Bursty {
                ensure!(
                    a.burst.is_none(),
                    "[arrivals] burst only applies to kind = bursty"
                );
            }
            if let Some(b) = a.burst {
                ensure!(b >= 1, "[arrivals] burst must be at least 1");
            }
            ensure!(
                a.requests.is_some() || a.duration.is_some(),
                "[arrivals] needs a stop condition: requests and/or duration"
            );
            if let Some(d) = a.duration {
                ensure!(
                    d.is_finite() && d > 0.0,
                    "[arrivals] duration must be a positive real, got {d}"
                );
            }
            if let Some(n) = a.requests {
                ensure!(n >= 1, "[arrivals] requests must be at least 1");
            }
        }
        Ok(Session {
            spec,
            cfg,
            dispatch,
            baselines,
        })
    }

    /// The fully-resolved system configuration this session runs under.
    pub fn config(&self) -> &SystemConfig {
        &self.cfg
    }

    /// The resolved dispatch mode (`auto` settled).
    pub fn dispatch(&self) -> Dispatch {
        self.dispatch
    }

    /// Lower the spec and run it to completion.
    pub fn run(&self) -> crate::Result<Report> {
        match self.dispatch {
            Dispatch::Kernel => self.run_kernel(),
            Dispatch::Pinned => self.run_pinned(),
            Dispatch::Shared if self.spec.arrivals.is_some() => self.run_service(),
            Dispatch::Shared => self.run_shared(),
            Dispatch::Auto => unreachable!("dispatch was resolved in Session::new"),
        }
    }

    /// Default mix placement of kernel `i` (spec default + override).
    fn placement_of(&self, i: usize) -> MixPlacement {
        self.spec.kernels[i].placement.unwrap_or(self.spec.placement)
    }

    /// Home stack of kernel `i` (wraps round-robin unless overridden).
    fn home_stack(&self, i: usize) -> usize {
        self.spec.kernels[i]
            .home
            .unwrap_or_else(|| home_of(i, &self.cfg))
    }

    fn fairness(&self) -> FairnessPolicy {
        self.spec.fairness.unwrap_or(self.cfg.mix_fairness)
    }

    /// Map every kernel's objects into one shared physical memory
    /// (per-app virtual bases), each app on its home stack. Both joint
    /// runs and run-alone baselines use this, so physical layout — and
    /// therefore bank/row behaviour — is identical between them.
    fn map_kernels(
        &self,
        apps: &[&BuiltWorkload],
    ) -> crate::Result<(VirtualMemory, Vec<Vec<VirtualAddress>>)> {
        let cfg = &self.cfg;
        let mut vm = VirtualMemory::new(cfg);
        let mut app_bases: Vec<Vec<VirtualAddress>> = Vec::new();
        for (i, app) in apps.iter().enumerate() {
            let home = self.home_stack(i);
            let mut bases = Vec::new();
            for obj in &app.trace.objects {
                let pages = obj.bytes.div_ceil(cfg.page_size).max(1);
                let base = match self.placement_of(i) {
                    MixPlacement::FgpOnly => vm.map_fgp(pages)?,
                    MixPlacement::CgpLocal => vm.map_cgp(pages, |_| home)?,
                };
                bases.push(base);
            }
            app_bases.push(bases);
        }
        Ok((vm, app_bases))
    }

    /// Map the host stream's objects fine-grain *after* every kernel's
    /// (FGP is the host's preferred granularity, Fig 13). The joint run
    /// and the host-split baselines both call this right after
    /// [`Self::map_kernels`], so host physical pages land identically in
    /// every layout.
    fn map_host(
        &self,
        vm: &mut VirtualMemory,
        host_wl: Option<&Wl<'_>>,
    ) -> crate::Result<Vec<VirtualAddress>> {
        let mut bases = Vec::new();
        if let Some(h) = host_wl {
            let t = h.trace();
            bases.reserve(t.objects.len());
            for obj in &t.objects {
                let pages = obj.bytes.div_ceil(self.cfg.page_size).max(1);
                bases.push(vm.map_fgp(pages)?);
            }
        }
        Ok(bases)
    }

    /// The single-kernel coordinator pipeline: analysis-driven placement
    /// plan, §6.4 no-degradation fallback, mapped run with the L2 filter
    /// and (for migration baselines) first-touch page migration.
    fn run_kernel(&self) -> crate::Result<Report> {
        let cfg = &self.cfg;
        let k = &self.spec.kernels[0];
        let wl = Wl::resolve(&k.workload, cfg)?;
        let wl = wl.built()?;
        let mech = k.mechanism.unwrap_or(Mechanism::Coda);
        let mut plan = plan_for_mechanism(cfg, wl, mech);
        let mut policy = mech.policy();
        // §6.4's no-degradation guarantee: when nothing meaningful is
        // localizable, CODA's plan degenerates to the baseline's — all-FGP
        // placement with unrestricted scheduling — so sharing-dominated
        // workloads behave exactly like FGP-Only.
        if matches!(mech, Mechanism::Coda | Mechanism::CodaStealing)
            && localizable_traffic(wl, &plan) < 0.05
        {
            plan = PlacementPlan::all_fgp(wl.trace.objects.len());
            policy = Policy::Baseline;
        }
        let (mut vm, bases, cgp_pages, fgp_pages) = map_objects(cfg, &wl.trace, &plan)?;
        let mut report = KernelRun {
            cfg,
            trace: &wl.trace,
            vm: &mut vm,
            obj_base: &bases,
            policy,
            migrate_on_first_touch: plan.migrate_on_first_touch,
        }
        .run();
        report.mechanism = mech.name().into();
        report.cgp_pages = cgp_pages;
        report.fgp_pages = fgp_pages;
        Ok(Report {
            spec_name: self.spec.name.clone(),
            sources: vec![SourceReport {
                kind: SourceKind::Ndp,
                workload: wl.name.to_string(),
                home: None,
                arrival: 0.0,
                cycles: report.cycles,
                slowdown: None,
            }],
            run: report,
        })
    }

    /// The Fig-12 pinned mix: app `i` runs only on its home stack's SMs.
    fn run_pinned(&self) -> crate::Result<Report> {
        let cfg = &self.cfg;
        let wls: Vec<Wl<'_>> = self
            .spec
            .kernels
            .iter()
            .map(|k| Wl::resolve(&k.workload, cfg))
            .collect::<crate::Result<_>>()?;
        let apps: Vec<&BuiltWorkload> =
            wls.iter().map(|w| w.built()).collect::<crate::Result<_>>()?;
        let homes: Vec<usize> = (0..apps.len()).map(|i| self.home_stack(i)).collect();
        let (mut vm, app_bases) = self.map_kernels(&apps)?;
        let app_ctxs: Vec<AppCtx<'_>> = apps
            .iter()
            .zip(&app_bases)
            .map(|(a, b)| AppCtx {
                trace: &a.trace,
                obj_base: b.as_slice(),
            })
            .collect();
        let opts = EngineOptions {
            l2_filter: false,
            migrate_on_first_touch: false,
        };
        // Pinned dispatch decomposes perfectly by home stack, so a shard
        // plan (config `shard_stacks`) runs each stack group on its own
        // thread; each shard's source masks foreign apps by zeroing
        // their block counts. Stack-private mixes are bit-exact vs the
        // sequential engine (`tests/shard.rs` pins this).
        let raw = match shard::plan(cfg, &opts, false) {
            Some(plan) => {
                let (raw, _) = shard::ShardEngine {
                    cfg,
                    apps: app_ctxs,
                    vm: &vm,
                    opts,
                    host: None,
                }
                .run(&plan, |s| PinnedSource {
                    next_block: vec![0; apps.len()],
                    num_blocks: apps
                        .iter()
                        .enumerate()
                        .map(|(i, a)| {
                            if plan.owner[homes[i]] == s {
                                a.trace.blocks.len()
                            } else {
                                0
                            }
                        })
                        .collect(),
                    homes: homes.clone(),
                });
                raw
            }
            None => {
                let mut source = PinnedSource {
                    next_block: vec![0; apps.len()],
                    num_blocks: apps.iter().map(|a| a.trace.blocks.len()).collect(),
                    homes: homes.clone(),
                };
                Engine {
                    cfg,
                    apps: app_ctxs,
                    vm: &mut vm,
                    opts,
                    host: None,
                }
                .run(&mut source)
            }
        };
        let mut report = raw.to_report(
            cfg,
            apps.iter().map(|a| a.name).collect::<Vec<_>>().join("+"),
        );
        report.mechanism = format!("{:?}", self.spec.placement);
        report.app_cycles = raw.app_end.clone();
        let sources = apps
            .iter()
            .enumerate()
            .map(|(i, a)| SourceReport {
                kind: SourceKind::Ndp,
                workload: a.name.to_string(),
                home: Some(homes[i]),
                arrival: 0.0,
                cycles: raw.app_end[i],
                slowdown: None,
            })
            .collect();
        Ok(Report {
            spec_name: self.spec.name.clone(),
            sources,
            run: report,
        })
    }

    /// General shared dispatch: the multi-kernel mix (time-shared SMs,
    /// arrivals, fairness) optionally co-running the host stream, plus
    /// whichever run-alone baselines the spec requested.
    fn run_shared(&self) -> crate::Result<Report> {
        let cfg = &self.cfg;
        let policy = self.spec.policy;
        let fairness = self.fairness();
        let wls: Vec<Wl<'_>> = self
            .spec
            .kernels
            .iter()
            .map(|k| Wl::resolve(&k.workload, cfg))
            .collect::<crate::Result<_>>()?;
        let apps: Vec<&BuiltWorkload> =
            wls.iter().map(|w| w.built()).collect::<crate::Result<_>>()?;
        let arrivals: Vec<f64> = self.spec.kernels.iter().map(|k| k.arrival).collect();
        let homes: Vec<usize> = (0..apps.len()).map(|i| self.home_stack(i)).collect();
        let host_wl = match &self.spec.host {
            Some(h) => Some(Wl::resolve(&h.workload, cfg)?),
            None => None,
        };
        let host_active =
            host_wl.is_some() && cfg.host_mlp > 0 && cfg.host_passes > 0;

        // Shared physical layout: NDP apps first (identical to the
        // NDP-only layout), host objects after, fine-grain interleaved
        // (FGP is the host's preferred granularity, Fig 13).
        let (mut vm, app_bases) = self.map_kernels(&apps)?;
        let host_bases: Vec<VirtualAddress> = self.map_host(&mut vm, host_wl.as_ref())?;
        let launches: Vec<(usize, f64)> = apps
            .iter()
            .zip(&arrivals)
            .map(|(a, &t)| (a.trace.blocks.len(), t))
            .collect();
        let host_stream = if host_active {
            host_wl.as_ref().map(|h| HostStream {
                trace: h.trace(),
                obj_base: &host_bases,
            })
        } else {
            None
        };

        let shared = exec_shared(
            cfg,
            &apps,
            &app_bases,
            &launches,
            &homes,
            policy,
            fairness,
            None,
            host_stream,
            &mut vm,
        );
        let n = apps.len();
        // The dense zero-filled form is deliberate here: report rows have
        // a frozen shape (one entry per app, never-ran = 0.0) and the
        // slowdown helpers pin degenerate zeros to 1.0. Statistics over a
        // *stream* must use `ResponseTimes::completed()` instead — that
        // is what service mode's percentile sketch consumes.
        let resp = stats::response_times(&shared.app_end, &arrivals).zero_filled();

        // Labels. The host co-runner is only named when it actually
        // streamed (zero intensity must not claim a co-run it never
        // executed).
        let ndp_names = apps.iter().map(|a| a.name).collect::<Vec<_>>().join("+");
        // The hostmix flavor (label + degenerate-slowdown semantics) is
        // what run_hostmix always reported, even with no host declared.
        let hostmix_flavor =
            self.spec.host.is_some() || self.baselines == Baselines::HostSplit;
        let workload = match (
            if host_active { host_wl.as_ref() } else { None },
            ndp_names.is_empty(),
        ) {
            (Some(h), true) => format!("host:{}", h.name()),
            (Some(h), false) => format!("{ndp_names}|host:{}", h.name()),
            (None, _) => ndp_names,
        };
        let mut report = shared.to_report(cfg, workload);
        report.mechanism = if hostmix_flavor {
            format!("hostmix:{:?}+{policy:?}+{fairness}", self.spec.placement)
        } else {
            format!("{:?}+{policy:?}+{fairness}", self.spec.placement)
        };

        let mut app_slowdown: Option<Vec<f64>> = None;
        match self.baselines {
            Baselines::Solo => {
                // Run-alone baselines: identical mapping (all apps'
                // objects placed), only app i's blocks execute, so the
                // only delta is app-vs-app contention. Each baseline is
                // an independent deterministic simulation over its own
                // fresh (identical) layout, so the set fans out across
                // threads; collecting in app order keeps every derived
                // number bit-identical to the sequential path
                // (`tests/parallel_equiv.rs`).
                let launches_zero: Vec<(usize, f64)> =
                    launches.iter().map(|&(b, _)| (b, 0.0)).collect();
                let solo: Vec<f64> = par::parallel_map(self.cfg.sim_threads, n, |i| {
                    let (mut vm_i, bases_i) = self.map_kernels(&apps)?;
                    let raw = exec_shared(
                        cfg,
                        &apps,
                        &bases_i,
                        &launches_zero,
                        &homes,
                        policy,
                        fairness,
                        Some(i),
                        None,
                        &mut vm_i,
                    );
                    Ok(raw.app_end[i])
                })?;
                report.app_slowdown = stats::per_app_slowdown(&solo, &resp);
                report.weighted_speedup = stats::weighted_speedup(&solo, &resp);
                app_slowdown = Some(report.app_slowdown.clone());
            }
            Baselines::HostSplit => {
                // Each side vs itself running alone on the identical
                // layout, only when both sources actually ran (otherwise
                // the shared run *is* the run-alone case). The two sides
                // are independent simulations: each job re-maps the
                // identical layout into its own fresh `VirtualMemory`
                // (the allocator is deterministic and shared dispatch
                // never mutates the VM, so the fresh layout reproduces
                // the joint run's physical pages bit-for-bit) and the
                // pair fans out across threads.
                let both = host_active && !apps.is_empty();
                let (ndp_alone, host_alone) = if both {
                    let mut pair = par::parallel_map(self.cfg.sim_threads, 2, |i| {
                        let (mut vm_b, bases_b) = self.map_kernels(&apps)?;
                        Ok(if i == 0 {
                            exec_shared(
                                cfg, &apps, &bases_b, &launches, &homes, policy,
                                fairness, None, None, &mut vm_b,
                            )
                        } else {
                            // Host pages map after every kernel's,
                            // exactly as in the joint layout.
                            let host_bases_b =
                                self.map_host(&mut vm_b, host_wl.as_ref())?;
                            exec_shared(
                                cfg,
                                &[],
                                &[],
                                &[],
                                &[],
                                policy,
                                fairness,
                                None,
                                host_wl.as_ref().map(|h| HostStream {
                                    trace: h.trace(),
                                    obj_base: &host_bases_b,
                                }),
                                &mut vm_b,
                            )
                        })
                    })?;
                    let host_side = pair.pop();
                    (pair.pop(), host_side)
                } else {
                    (None, None)
                };
                let (ndp_sd, host_sd, app_sd, weighted) = match (&ndp_alone, &host_alone)
                {
                    (Some(na), Some(ha)) => {
                        let resp_alone =
                            stats::response_times(&na.app_end, &arrivals).zero_filled();
                        let ndp_sd = if na.end_time > 0.0 {
                            shared.end_time / na.end_time
                        } else {
                            1.0
                        };
                        let host_sd = if ha.host_end > 0.0 {
                            shared.host_end / ha.host_end
                        } else {
                            1.0
                        };
                        (
                            ndp_sd,
                            host_sd,
                            stats::per_app_slowdown(&resp_alone, &resp),
                            stats::weighted_speedup(&resp_alone, &resp),
                        )
                    }
                    // Only one source ran: nothing contended with it.
                    _ => (
                        if n > 0 { 1.0 } else { 0.0 },
                        if host_active { 1.0 } else { 0.0 },
                        vec![1.0; n],
                        n as f64,
                    ),
                };
                report.app_slowdown = app_sd;
                report.weighted_speedup = weighted;
                report.ndp_slowdown = ndp_sd;
                report.host_slowdown = host_sd;
                app_slowdown = Some(report.app_slowdown.clone());
            }
            Baselines::None => {}
            Baselines::Auto => unreachable!("baselines were resolved in Session::new"),
        }
        report.app_cycles = resp.clone();

        let mut sources: Vec<SourceReport> = apps
            .iter()
            .enumerate()
            .map(|(i, a)| SourceReport {
                kind: SourceKind::Ndp,
                workload: a.name.to_string(),
                home: Some(homes[i]),
                arrival: arrivals[i],
                cycles: resp[i],
                slowdown: app_slowdown.as_ref().map(|s| s[i]),
            })
            .collect();
        if let Some(h) = &host_wl {
            // The row is emitted whenever the spec declared a host (so
            // table shapes are stable), but a stream that never ran
            // (zero intensity) reports no slowdown rather than a phantom
            // 0.0 co-run figure.
            sources.push(SourceReport {
                kind: SourceKind::Host,
                workload: h.name().to_string(),
                home: None,
                arrival: 0.0,
                cycles: report.host_cycles,
                slowdown: (host_active && self.baselines != Baselines::None)
                    .then_some(report.host_slowdown),
            });
        }
        Ok(Report {
            spec_name: self.spec.name.clone(),
            sources,
            run: report,
        })
    }

    /// Service mode: the spec's kernels as an open-loop request stream
    /// ([`ServiceSource`]) instead of a fixed mix, optionally co-running
    /// the host stream. No run-alone baselines (an open-loop stream has
    /// no meaningful "alone" comparison); the report instead carries
    /// [`ServiceStats`] — throughput, offered vs achieved rate,
    /// incomplete-request count, and streaming response percentiles.
    fn run_service(&self) -> crate::Result<Report> {
        let cfg = &self.cfg;
        let a = self
            .spec
            .arrivals
            .as_ref()
            .expect("run_service requires [arrivals]");
        let wls: Vec<Wl<'_>> = self
            .spec
            .kernels
            .iter()
            .map(|k| Wl::resolve(&k.workload, cfg))
            .collect::<crate::Result<_>>()?;
        let apps: Vec<&BuiltWorkload> =
            wls.iter().map(|w| w.built()).collect::<crate::Result<_>>()?;
        let homes: Vec<usize> = (0..apps.len()).map(|i| self.home_stack(i)).collect();
        let host_wl = match &self.spec.host {
            Some(h) => Some(Wl::resolve(&h.workload, cfg)?),
            None => None,
        };
        let host_active = host_wl.is_some() && cfg.host_mlp > 0 && cfg.host_passes > 0;

        // Identical layout discipline to run_shared: kernel objects first
        // (per-kernel placement/home), host objects after, fine-grain.
        let (mut vm, app_bases) = self.map_kernels(&apps)?;
        let host_bases: Vec<VirtualAddress> = self.map_host(&mut vm, host_wl.as_ref())?;
        let host_stream = if host_active {
            host_wl.as_ref().map(|h| HostStream {
                trace: h.trace(),
                obj_base: &host_bases,
            })
        } else {
            None
        };
        let app_ctxs: Vec<AppCtx<'_>> = apps
            .iter()
            .zip(&app_bases)
            .map(|(w, b)| AppCtx {
                trace: &w.trace,
                obj_base: b.as_slice(),
            })
            .collect();
        let after: Vec<Vec<usize>> =
            self.spec.kernels.iter().map(|k| k.after.clone()).collect();
        let blocks: Vec<u32> = apps.iter().map(|w| w.trace.blocks.len() as u32).collect();
        let opts = EngineOptions {
            l2_filter: false,
            migrate_on_first_touch: false,
        };
        // Sharded service mode deals requests round-robin across shards
        // by arrival sequence number (every shard runs the generator in
        // lockstep and admits its residue class), so offered/completed
        // totals and the response sketch are exact; per-request
        // scheduling is shard-local rather than machine-global FCFS,
        // which is the statistical-equivalence regime.
        let (raw, source) = match shard::plan(cfg, &opts, host_stream.is_some()) {
            Some(plan) => {
                let (raw, shards) = shard::ShardEngine {
                    cfg,
                    apps: app_ctxs,
                    vm: &vm,
                    opts,
                    host: host_stream,
                }
                .run(&plan, |s| {
                    ServiceSource::new(blocks.clone(), &after, a, cfg.seed)
                        .sharded(s as u64, plan.shards as u64)
                });
                // Fold the per-shard streams back into one: counts sum,
                // the stream span is the latest admitted arrival, and the
                // sketch merges exactly (per-bucket counts add).
                let mut it = shards.into_iter();
                let mut merged = it.next().expect("plan() guarantees >= 2 shards");
                for s in it {
                    merged.offered += s.offered;
                    merged.completed += s.completed;
                    merged.last_arrival = merged.last_arrival.max(s.last_arrival);
                    merged.capped |= s.capped;
                    merged.sketch.merge(&s.sketch);
                }
                (raw, merged)
            }
            None => {
                let mut source = ServiceSource::new(blocks, &after, a, cfg.seed);
                let raw = Engine {
                    cfg,
                    apps: app_ctxs,
                    vm: &mut vm,
                    opts,
                    host: host_stream,
                }
                .run(&mut source);
                (raw, source)
            }
        };

        let ndp_names = apps.iter().map(|w| w.name).collect::<Vec<_>>().join("+");
        let workload = match if host_active { host_wl.as_ref() } else { None } {
            Some(h) => format!("{ndp_names}|host:{}", h.name()),
            None => ndp_names,
        };
        let mut report = raw.to_report(cfg, workload);
        report.mechanism = format!("service:{}+{:?}", a.kind, self.spec.placement);
        let incomplete = source.offered - source.completed;
        // Offered rate over the span the stream was actually open: the
        // last admitted arrival when the requests cap ended the stream
        // (a duration far past the cap must not understate the rate),
        // else the declared duration, else the simulated makespan. A
        // point burst (cap hit with every arrival at t=0) spans no time
        // and pins to 0.0. Achieved rate is over the time the run took.
        let horizon = if source.capped {
            source.last_arrival
        } else {
            a.duration.unwrap_or(report.cycles)
        };
        report.service = Some(ServiceStats {
            requests_offered: source.offered,
            requests_completed: source.completed,
            requests_incomplete: incomplete,
            offered_rate: if horizon > 0.0 {
                source.offered as f64 / horizon
            } else {
                0.0
            },
            achieved_rate: if report.cycles > 0.0 {
                source.completed as f64 / report.cycles
            } else {
                0.0
            },
            mean_response: source.sketch.mean(),
            max_response: source.sketch.max(),
            p50_response: source.sketch.quantile(0.50),
            p99_response: source.sketch.quantile(0.99),
            p999_response: source.sketch.quantile(0.999),
        });

        // One row per kernel *template* (not per request): its cycles are
        // the completion time of its last window across all requests.
        let mut sources: Vec<SourceReport> = apps
            .iter()
            .enumerate()
            .map(|(i, w)| SourceReport {
                kind: SourceKind::Ndp,
                workload: w.name.to_string(),
                home: Some(homes[i]),
                arrival: 0.0,
                cycles: raw.app_end[i],
                slowdown: None,
            })
            .collect();
        if let Some(h) = &host_wl {
            sources.push(SourceReport {
                kind: SourceKind::Host,
                workload: h.name().to_string(),
                home: None,
                arrival: 0.0,
                cycles: report.host_cycles,
                slowdown: None,
            });
        }
        Ok(Report {
            spec_name: self.spec.name.clone(),
            sources,
            run: report,
        })
    }

    /// Legacy host-sweep seam: run the spec's host stream over a layout
    /// the caller already mapped (`vm` + per-object `obj_base`), exactly
    /// as `host::run_host_sweep` always did. The spec must declare a host
    /// stream and no kernels.
    pub fn run_host_in(
        &self,
        vm: &mut VirtualMemory,
        obj_base: &[VirtualAddress],
    ) -> crate::Result<Report> {
        ensure!(
            self.spec.kernels.is_empty() && self.spec.host.is_some(),
            "run_host_in runs a host-only spec over an external layout"
        );
        let cfg = &self.cfg;
        let host_wl = Wl::resolve(&self.spec.host.as_ref().expect("checked").workload, cfg)?;
        let raw = exec_shared(
            cfg,
            &[],
            &[],
            &[],
            &[],
            self.spec.policy,
            self.fairness(),
            None,
            Some(HostStream {
                trace: host_wl.trace(),
                obj_base,
            }),
            vm,
        );
        let mut report = raw.to_report(cfg, host_wl.name().to_string());
        report.mechanism = "host".into();
        let sources = vec![SourceReport {
            kind: SourceKind::Host,
            workload: host_wl.name().to_string(),
            home: None,
            arrival: 0.0,
            cycles: report.host_cycles,
            slowdown: None,
        }];
        Ok(Report {
            spec_name: self.spec.name.clone(),
            sources,
            run: report,
        })
    }
}

/// Run a spec end to end, expanding its `[sweep]` section: one [`Report`]
/// per sweep value (a single report without one). Each sweep point reruns
/// the whole spec with `key = value` appended to its `[system]` overrides
/// and the point recorded in the report's `spec` label — this is what
/// makes parameter sweeps batchable from one file.
///
/// Sweep points are independent deterministic simulations, so they fan
/// out across threads (the base config's `sim_threads`; `1` forces the
/// sequential loop) and are collected in value order — the report list is
/// bit-identical to the sequential path regardless of thread count.
pub fn run_spec<'a>(
    base: &SystemConfig,
    spec: &ExperimentSpec<'a>,
) -> crate::Result<Vec<Report>> {
    match &spec.sweep {
        None => Ok(vec![Session::new(base.clone(), spec.clone())?.run()?]),
        Some(sw) => {
            // A spec-level `[system] sim_threads` override governs the
            // sweep expansion too, not just each point's inner baseline
            // fan-out (last occurrence wins, like `cfg.set`). A value
            // that does not parse falls back to the base config here and
            // surfaces as a hard error from each point's Session::new.
            let threads = spec
                .overrides
                .iter()
                .rev()
                .find(|(k, _)| k == "sim_threads")
                .and_then(|(_, v)| v.trim().parse().ok())
                .unwrap_or(base.sim_threads);
            par::parallel_map(threads, sw.values.len(), |i| {
                // Each job builds its own point spec from the value
                // index — deterministic in `i`, so one clone per job.
                let v = &sw.values[i];
                let mut point = spec.clone();
                point.sweep = None;
                point.overrides.push((sw.key.clone(), v.clone()));
                point.name = Some(match &spec.name {
                    Some(n) => format!("{n}[{}={v}]", sw.key),
                    None => format!("{}={v}", sw.key),
                });
                Session::new(base.clone(), point)?.run()
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::{HostSpec, KernelSpec, OutputSpec, SweepSpec};

    fn cfg() -> SystemConfig {
        SystemConfig::test_small()
    }

    #[test]
    fn auto_dispatch_and_baselines_resolve() {
        let k = ExperimentSpec::kernel(WorkloadSel::Named("NN"), Mechanism::Coda);
        let mut auto = k.clone();
        auto.dispatch = Dispatch::Auto;
        let s = Session::new(cfg(), auto).unwrap();
        assert_eq!(s.dispatch(), Dispatch::Kernel);
        let mix = ExperimentSpec::shared(
            vec![(WorkloadSel::Named("NN"), 0.0)],
            MixPlacement::CgpLocal,
            Policy::Affinity,
            FairnessPolicy::Fcfs,
        );
        let s = Session::new(cfg(), mix).unwrap();
        assert_eq!(s.dispatch(), Dispatch::Shared);
        assert_eq!(s.baselines, Baselines::Solo);
        let hm = ExperimentSpec::hostmix(
            vec![],
            Some(WorkloadSel::Named("NN")),
            MixPlacement::CgpLocal,
            Policy::Affinity,
            FairnessPolicy::Fcfs,
        );
        let mut hm_auto = hm;
        hm_auto.output.baselines = Baselines::Auto;
        let s = Session::new(cfg(), hm_auto).unwrap();
        assert_eq!(s.baselines, Baselines::HostSplit);
    }

    #[test]
    fn system_overrides_apply_and_validate() {
        let mut spec = ExperimentSpec::kernel(WorkloadSel::Named("NN"), Mechanism::Coda);
        spec.overrides.push(("mem_backend".into(), "bank".into()));
        let s = Session::new(cfg(), spec).unwrap();
        assert_eq!(
            s.config().mem_backend,
            crate::config::MemBackendKind::BankLevel
        );
        let mut cyc = ExperimentSpec::kernel(WorkloadSel::Named("NN"), Mechanism::Coda);
        cyc.overrides.push(("mem_backend".into(), "cycle".into()));
        cyc.overrides
            .push(("dram_row_policy".into(), "closed".into()));
        let s = Session::new(cfg(), cyc).unwrap();
        assert_eq!(
            s.config().mem_backend,
            crate::config::MemBackendKind::CycleAccurate
        );
        assert_eq!(
            s.config().dram_row_policy,
            crate::config::DramRowPolicy::Closed
        );
        let mut bad = ExperimentSpec::kernel(WorkloadSel::Named("NN"), Mechanism::Coda);
        bad.overrides.push(("num_stacks".into(), "3".into()));
        assert!(Session::new(cfg(), bad).is_err());
        let mut unknown = ExperimentSpec::kernel(WorkloadSel::Named("NN"), Mechanism::Coda);
        unknown.overrides.push(("warp_speed".into(), "9".into()));
        assert!(Session::new(cfg(), unknown).is_err());
    }

    #[test]
    fn host_overrides_apply_to_config() {
        let mut spec = ExperimentSpec::hostmix(
            vec![],
            Some(WorkloadSel::Named("NN")),
            MixPlacement::CgpLocal,
            Policy::Affinity,
            FairnessPolicy::Fcfs,
        );
        let h = spec.host.as_mut().unwrap();
        h.mlp = Some(8);
        h.passes = Some(3);
        h.ddr_fraction = Some(0.25);
        let s = Session::new(cfg(), spec).unwrap();
        assert_eq!(s.config().host_mlp, 8);
        assert_eq!(s.config().host_passes, 3);
        assert_eq!(s.config().host_ddr_fraction, 0.25);
    }

    #[test]
    fn topology_section_lowers_onto_config() {
        let mut spec = ExperimentSpec::kernel(WorkloadSel::Named("NN"), Mechanism::Coda);
        spec.topology = Some(crate::spec::TopologySpec {
            kind: crate::net::TopologyKind::Ring,
            mesh_cols: None,
            hop_latency_ns: Some(12.0),
            link_bw_gbs: Some(64.0),
            window_cycles: Some(4096.0),
        });
        let s = Session::new(cfg(), spec).unwrap();
        assert_eq!(s.config().topology, crate::net::TopologyKind::Ring);
        assert_eq!(s.config().hop_latency_ns, 12.0);
        assert_eq!(s.config().link_bw_gbs, 64.0);
        assert_eq!(s.config().net_window_cycles, 4096.0);
        // Lowered knobs go through config validation: a mesh whose column
        // count does not tile the stacks is rejected here, not at run time.
        let mut bad = ExperimentSpec::kernel(WorkloadSel::Named("NN"), Mechanism::Coda);
        bad.topology = Some(crate::spec::TopologySpec {
            kind: crate::net::TopologyKind::Mesh2d,
            mesh_cols: Some(3),
            hop_latency_ns: None,
            link_bw_gbs: None,
            window_cycles: None,
        });
        assert!(Session::new(cfg(), bad).is_err());
    }

    #[test]
    fn shape_validation_rejects_nonsense() {
        // Kernel dispatch with two kernels.
        let mut two = ExperimentSpec::kernel(WorkloadSel::Named("NN"), Mechanism::Coda);
        two.kernels.push(KernelSpec::new(WorkloadSel::Named("KM")));
        assert!(Session::new(cfg(), two).is_err());
        // Mechanism under shared dispatch.
        let mut mixed = ExperimentSpec::shared(
            vec![(WorkloadSel::Named("NN"), 0.0)],
            MixPlacement::CgpLocal,
            Policy::Affinity,
            FairnessPolicy::Fcfs,
        );
        mixed.kernels[0].mechanism = Some(Mechanism::Coda);
        assert!(Session::new(cfg(), mixed).is_err());
        // Negative arrival.
        let mut late = ExperimentSpec::shared(
            vec![(WorkloadSel::Named("NN"), -1.0)],
            MixPlacement::CgpLocal,
            Policy::Affinity,
            FairnessPolicy::Fcfs,
        );
        late.kernels[0].arrival = -1.0;
        assert!(Session::new(cfg(), late).is_err());
        // Home out of range.
        let mut far = ExperimentSpec::shared(
            vec![(WorkloadSel::Named("NN"), 0.0)],
            MixPlacement::CgpLocal,
            Policy::Affinity,
            FairnessPolicy::Fcfs,
        );
        far.kernels[0].home = Some(99);
        assert!(Session::new(cfg(), far).is_err());
        // Pinned with duplicate homes.
        let mut dup = ExperimentSpec::pinned(
            vec![WorkloadSel::Named("NN"), WorkloadSel::Named("KM")],
            MixPlacement::CgpLocal,
        );
        dup.kernels[1].home = Some(0);
        assert!(Session::new(cfg(), dup).is_err());
        // No sources at all.
        let empty = ExperimentSpec {
            dispatch: Dispatch::Shared,
            ..ExperimentSpec::default()
        };
        assert!(Session::new(cfg(), empty).is_err());
        // Solo baselines with a host co-run.
        let mut solo_host = ExperimentSpec::hostmix(
            vec![(WorkloadSel::Named("NN"), 0.0)],
            Some(WorkloadSel::Named("KM")),
            MixPlacement::CgpLocal,
            Policy::Affinity,
            FairnessPolicy::Fcfs,
        );
        solo_host.output.baselines = Baselines::Solo;
        assert!(Session::new(cfg(), solo_host).is_err());
        // Bare trace as a kernel workload.
        let t = crate::workloads::suite::build("NN", &cfg()).unwrap();
        let mut raw = ExperimentSpec::default();
        raw.dispatch = Dispatch::Shared;
        raw.kernels.push(KernelSpec::new(WorkloadSel::Trace(&t.trace)));
        assert!(Session::new(cfg(), raw).is_err());
        // Explicit baselines on dispatches that never run them must be a
        // hard error, not a silent drop...
        let mut kb = ExperimentSpec::kernel(WorkloadSel::Named("NN"), Mechanism::Coda);
        kb.output.baselines = Baselines::Solo;
        assert!(Session::new(cfg(), kb).is_err());
        let mut pb = ExperimentSpec::pinned(
            vec![WorkloadSel::Named("NN")],
            MixPlacement::CgpLocal,
        );
        pb.output.baselines = Baselines::HostSplit;
        assert!(Session::new(cfg(), pb).is_err());
        // ...while auto (and an explicit none) resolve to none there.
        let k_auto = ExperimentSpec::kernel(WorkloadSel::Named("NN"), Mechanism::Coda);
        assert_eq!(
            Session::new(cfg(), k_auto).unwrap().baselines,
            Baselines::None
        );
    }

    #[test]
    fn inactive_host_row_reports_no_slowdown() {
        // Declared host, zero intensity: the row stays (stable table
        // shape) but claims no co-run slowdown.
        let mut spec = ExperimentSpec::hostmix(
            vec![(WorkloadSel::Named("NN"), 0.0)],
            Some(WorkloadSel::Named("KM")),
            MixPlacement::CgpLocal,
            Policy::Affinity,
            FairnessPolicy::Fcfs,
        );
        spec.host.as_mut().unwrap().mlp = Some(0);
        let r = Session::new(cfg(), spec).unwrap().run().unwrap();
        let host_row = r.sources.last().unwrap();
        assert_eq!(host_row.kind, SourceKind::Host);
        assert_eq!(host_row.cycles, 0.0);
        assert!(host_row.slowdown.is_none());
    }

    #[test]
    fn baselines_none_skips_slowdowns() {
        let mut spec = ExperimentSpec::shared(
            vec![
                (WorkloadSel::Named("NN"), 0.0),
                (WorkloadSel::Named("KM"), 0.0),
            ],
            MixPlacement::CgpLocal,
            Policy::Affinity,
            FairnessPolicy::Fcfs,
        );
        spec.output = OutputSpec {
            baselines: Baselines::None,
            ..OutputSpec::default()
        };
        let r = Session::new(cfg(), spec).unwrap().run().unwrap();
        assert!(r.run.app_slowdown.is_empty());
        assert_eq!(r.run.weighted_speedup, 0.0);
        assert_eq!(r.sources.len(), 2);
        assert!(r.sources.iter().all(|s| s.slowdown.is_none()));
        assert!(r.run.cycles > 0.0);
        // The shared run itself is identical — only baselines are skipped.
        let full = ExperimentSpec::shared(
            vec![
                (WorkloadSel::Named("NN"), 0.0),
                (WorkloadSel::Named("KM"), 0.0),
            ],
            MixPlacement::CgpLocal,
            Policy::Affinity,
            FairnessPolicy::Fcfs,
        );
        let rf = Session::new(cfg(), full).unwrap().run().unwrap();
        assert_eq!(r.run.cycles.to_bits(), rf.run.cycles.to_bits());
        assert_eq!(rf.sources.len(), 2);
        assert!(rf.sources.iter().all(|s| s.slowdown.is_some()));
    }

    #[test]
    fn per_kernel_placement_and_home_overrides_work() {
        // Two kernels, one FGP one CGP-local on an overridden home: the
        // CGP kernel's traffic concentrates on its home stack.
        let mut spec = ExperimentSpec::shared(
            vec![
                (WorkloadSel::Named("NN"), 0.0),
                (WorkloadSel::Named("KM"), 0.0),
            ],
            MixPlacement::CgpLocal,
            Policy::Affinity,
            FairnessPolicy::Fcfs,
        );
        spec.kernels[0].placement = Some(MixPlacement::FgpOnly);
        spec.kernels[1].home = Some(3);
        let r = Session::new(cfg(), spec).unwrap().run().unwrap();
        assert_eq!(r.sources[0].home, Some(0));
        assert_eq!(r.sources[1].home, Some(3));
        // The FGP app generates remote traffic; the homed app does not.
        assert!(r.run.accesses.remote > 0);
        assert!(r.run.cycles > 0.0);
    }

    #[test]
    fn report_json_is_a_superset_of_runreport_json() {
        let mut spec = ExperimentSpec::hostmix(
            vec![(WorkloadSel::Named("NN"), 0.0)],
            Some(WorkloadSel::Named("KM")),
            MixPlacement::CgpLocal,
            Policy::Affinity,
            FairnessPolicy::Fcfs,
        );
        spec.name = Some("json-demo".into());
        spec.host = Some(HostSpec::new(WorkloadSel::Named("KM")));
        let r = Session::new(cfg(), spec).unwrap().run().unwrap();
        let s = r.to_json().render();
        crate::report::validate_json(&s).unwrap();
        // Everything the plain RunReport emits is still there...
        let plain = Json::from(&r.run).render();
        crate::report::validate_json(&plain).unwrap();
        assert!(s.starts_with(&plain[..plain.len() - 1]));
        // ...plus the session extras.
        assert!(s.contains("\"spec\":\"json-demo\""));
        assert!(s.contains("\"sources\":["));
        assert!(s.contains("\"kind\":\"host\""));
    }

    #[test]
    fn sweep_expands_to_one_report_per_value() {
        let mut spec = ExperimentSpec::kernel(WorkloadSel::Named("NN"), Mechanism::FgpOnly);
        spec.sweep = Some(SweepSpec {
            key: "remote_bw_gbs".into(),
            values: vec!["8".into(), "256".into()],
        });
        let reports = run_spec(&cfg(), &spec).unwrap();
        assert_eq!(reports.len(), 2);
        assert_eq!(reports[0].spec_name.as_deref(), Some("remote_bw_gbs=8"));
        assert_eq!(reports[1].spec_name.as_deref(), Some("remote_bw_gbs=256"));
        // Less remote bandwidth must cost cycles on an FGP run.
        assert!(reports[0].run.cycles > reports[1].run.cycles);
        // A bad sweep value surfaces as an error, not a silent skip.
        let mut bad = ExperimentSpec::kernel(WorkloadSel::Named("NN"), Mechanism::FgpOnly);
        bad.sweep = Some(SweepSpec {
            key: "remote_bw_gbs".into(),
            values: vec!["fast".into()],
        });
        assert!(run_spec(&cfg(), &bad).is_err());
    }

    /// A one-kernel KM service spec with the given arrivals section.
    fn service_spec(a: ArrivalSpec) -> ExperimentSpec<'static> {
        let mut spec = ExperimentSpec::shared(
            vec![(WorkloadSel::Named("KM"), 0.0)],
            MixPlacement::CgpLocal,
            Policy::Affinity,
            FairnessPolicy::Fcfs,
        );
        spec.arrivals = Some(a);
        spec
    }

    fn poisson(rate: f64, requests: u64) -> ArrivalSpec {
        ArrivalSpec {
            kind: ArrivalKind::Poisson,
            rate: Some(rate),
            requests: Some(requests),
            ..ArrivalSpec::default()
        }
    }

    #[test]
    fn service_spec_validation_rejects_nonsense() {
        // [arrivals] only lowers onto shared dispatch.
        let mut pinned =
            ExperimentSpec::pinned(vec![WorkloadSel::Named("KM")], MixPlacement::CgpLocal);
        pinned.arrivals = Some(poisson(0.001, 2));
        assert!(Session::new(cfg(), pinned).is_err());
        // A stream needs at least one kernel stage.
        let mut hostless = ExperimentSpec::hostmix(
            vec![],
            Some(WorkloadSel::Named("KM")),
            MixPlacement::CgpLocal,
            Policy::Affinity,
            FairnessPolicy::Fcfs,
        );
        hostless.arrivals = Some(poisson(0.001, 2));
        assert!(Session::new(cfg(), hostless).is_err());
        // Explicit run-alone baselines are meaningless against a stream.
        let mut solo = service_spec(poisson(0.001, 2));
        solo.output.baselines = Baselines::Solo;
        assert!(Session::new(cfg(), solo).is_err());
        // Launch offsets do not mix with generated arrivals.
        let mut late = service_spec(poisson(0.001, 2));
        late.kernels[0].arrival = 5.0;
        assert!(Session::new(cfg(), late).is_err());
        // After edges must point at an earlier kernel...
        let mut cyc = service_spec(poisson(0.001, 2));
        cyc.kernels[0].after = vec![0];
        assert!(Session::new(cfg(), cyc).is_err());
        // ...and only exist under a service stream.
        let mut stray = ExperimentSpec::shared(
            vec![
                (WorkloadSel::Named("KM"), 0.0),
                (WorkloadSel::Named("NN"), 0.0),
            ],
            MixPlacement::CgpLocal,
            Policy::Affinity,
            FairnessPolicy::Fcfs,
        );
        stray.kernels[1].after = vec![0];
        assert!(Session::new(cfg(), stray).is_err());
        // Poisson/bursty parameter errors.
        let mut no_rate = service_spec(poisson(0.001, 2));
        no_rate.arrivals.as_mut().unwrap().rate = None;
        assert!(Session::new(cfg(), no_rate).is_err());
        assert!(Session::new(cfg(), service_spec(poisson(0.0, 2))).is_err());
        let mut burst_on_poisson = service_spec(poisson(0.001, 2));
        burst_on_poisson.arrivals.as_mut().unwrap().burst = Some(4);
        assert!(Session::new(cfg(), burst_on_poisson).is_err());
        // Trace parameter errors.
        let empty_trace = service_spec(ArrivalSpec {
            kind: ArrivalKind::Trace,
            requests: Some(2),
            ..ArrivalSpec::default()
        });
        assert!(Session::new(cfg(), empty_trace).is_err());
        // A duration-only all-zero trace would admit requests forever at
        // t=0 (the generator clock never advances) — rejected up front.
        let zero_sum = service_spec(ArrivalSpec {
            kind: ArrivalKind::Trace,
            interarrivals: vec![0.0, 0.0],
            duration: Some(100.0),
            ..ArrivalSpec::default()
        });
        assert!(Session::new(cfg(), zero_sum).is_err());
        // ...but the same gap list is fine once a requests cap bounds it,
        // and a positive-sum list is fine with duration alone.
        let capped_zero_sum = service_spec(ArrivalSpec {
            kind: ArrivalKind::Trace,
            interarrivals: vec![0.0, 0.0],
            duration: Some(100.0),
            requests: Some(4),
            ..ArrivalSpec::default()
        });
        assert!(Session::new(cfg(), capped_zero_sum).is_ok());
        let positive_sum = service_spec(ArrivalSpec {
            kind: ArrivalKind::Trace,
            interarrivals: vec![0.0, 50.0],
            duration: Some(100.0),
            ..ArrivalSpec::default()
        });
        assert!(Session::new(cfg(), positive_sum).is_ok());
        // Some stop condition is mandatory (else the stream never ends).
        let mut endless = service_spec(poisson(0.001, 2));
        endless.arrivals.as_mut().unwrap().requests = None;
        assert!(Session::new(cfg(), endless).is_err());
    }

    #[test]
    fn service_run_reports_stream_stats_deterministically() {
        let run = || {
            Session::new(cfg(), service_spec(poisson(1e-5, 3)))
                .unwrap()
                .run()
                .unwrap()
        };
        let r = run();
        let svc = r.run.service.as_ref().expect("service stats");
        assert_eq!(svc.requests_offered, 3);
        assert_eq!(
            svc.requests_offered,
            svc.requests_completed + svc.requests_incomplete
        );
        // No duration cutoff: every admitted request drains to completion.
        assert_eq!(svc.requests_incomplete, 0);
        assert!(svc.achieved_rate > 0.0);
        assert!(svc.mean_response > 0.0);
        assert!(svc.p50_response <= svc.p99_response);
        assert!(svc.p99_response <= svc.p999_response);
        assert!(svc.p999_response <= svc.max_response);
        assert!(r.run.mechanism.starts_with("service:poisson"));
        // Stream runs carry no per-app baseline columns.
        assert!(r.run.app_slowdown.is_empty());
        assert!(r.sources.iter().all(|s| s.slowdown.is_none()));
        // Bit-identical replay: same spec, same seed, same report.
        let r2 = run();
        assert_eq!(r.run.cycles.to_bits(), r2.run.cycles.to_bits());
        assert_eq!(r.run.service, r2.run.service);
    }

    #[test]
    fn service_duration_cutoff_counts_incomplete_requests() {
        // Three back-to-back arrivals at t=0, a cutoff far before any
        // KM block can retire: nothing completes, everything counts.
        let spec = service_spec(ArrivalSpec {
            kind: ArrivalKind::Trace,
            interarrivals: vec![0.0],
            requests: Some(3),
            duration: Some(1.0),
            ..ArrivalSpec::default()
        });
        let r = Session::new(cfg(), spec).unwrap().run().unwrap();
        let svc = r.run.service.as_ref().expect("service stats");
        assert_eq!(svc.requests_offered, 3);
        assert_eq!(svc.requests_completed, 0);
        assert_eq!(svc.requests_incomplete, 3);
        // The requests cap ended the stream at t=0: a point burst spans
        // no time, so the rate pins to 0.0 rather than dividing by the
        // duration the stream never used.
        assert_eq!(svc.offered_rate, 0.0);
    }

    #[test]
    fn service_offered_rate_spans_the_capped_stream_not_the_duration() {
        // Arrivals at t=1,2,3,4; the cap ends the stream at t=4 while the
        // declared duration runs to 1000 — the offered rate must be
        // measured over the 4 cycles the stream was actually open
        // (4 requests / 4 cycles), not understated 250x by the duration.
        let spec = service_spec(ArrivalSpec {
            kind: ArrivalKind::Trace,
            interarrivals: vec![1.0],
            requests: Some(4),
            duration: Some(1000.0),
            ..ArrivalSpec::default()
        });
        let r = Session::new(cfg(), spec).unwrap().run().unwrap();
        let svc = r.run.service.as_ref().expect("service stats");
        assert_eq!(svc.requests_offered, 4);
        assert_eq!(svc.offered_rate, 1.0);
        // Duration-bounded end keeps the declared-horizon semantics: the
        // same trace runs out at t > 3 with only 3 requests admitted.
        let spec = service_spec(ArrivalSpec {
            kind: ArrivalKind::Trace,
            interarrivals: vec![1.0],
            duration: Some(3.5),
            ..ArrivalSpec::default()
        });
        let r = Session::new(cfg(), spec).unwrap().run().unwrap();
        let svc = r.run.service.as_ref().expect("service stats");
        assert_eq!(svc.requests_offered, 3);
        assert_eq!(svc.offered_rate, 3.0 / 3.5);
    }

    #[test]
    fn service_completion_readying_a_stage_announces_a_wake() {
        // Drive the source through the BlockSource seam directly: one
        // request, stage 0 (1 block) -> stage 1 (2 blocks, after = [0]).
        // When stage 0's retirement readies stage 1, the retiring slot
        // takes one block AND the source must announce a strictly-future
        // wake so the engine sweeps other idle slots for the second
        // block — otherwise a multi-block tail stage serializes.
        let a = ArrivalSpec {
            kind: ArrivalKind::Trace,
            interarrivals: vec![1.0],
            requests: Some(1),
            ..ArrivalSpec::default()
        };
        let mut s = ServiceSource::new(vec![1, 2], &[vec![], vec![0]], &a, 7);
        let sm = Sm { id: 0, stack: 0 };
        assert_eq!(s.next_arrival_after(0.0), Some(1.0));
        s.on_arrival(1.0);
        let b0 = s.refill(sm, None, 1.0).expect("stage 0 block");
        assert_eq!(b0.app, 0);
        // Stream is capped after the one request and stage 1 still waits
        // on its edge: nothing more to hand out, no arrival to report.
        assert!(s.refill(sm, None, 1.0).is_none());
        assert!(s.next_arrival_after(1.0).is_none());
        // Stage 0 retires at t=5: the retiring slot picks up stage 1's
        // first block and a just-after-now wake appears for the second.
        let b1 = s.refill(sm, Some(b0), 5.0).expect("stage 1 first block");
        assert_eq!(b1.app, 1);
        let wake = s.next_arrival_after(5.0).expect("completion wake");
        assert!(wake > 5.0 && wake < 5.0 + 1e-9);
        // The wake fires: an idle slot sweeps up the second block, and
        // the consumed wake is not re-announced.
        s.on_arrival(wake);
        let b2 = s.refill(sm, None, wake).expect("stage 1 second block");
        assert_eq!((b2.app, b2.block), (1, 1));
        assert!(s.next_arrival_after(wake).is_none());
        // Both stage-1 blocks retire: the request completes exactly once.
        assert!(s.refill(sm, Some(b1), 9.0).is_none());
        assert!(s.refill(sm, Some(b2), 10.0).is_none());
        assert_eq!(s.completed, 1);
        assert_eq!(s.sketch.count(), 1);
    }

    #[test]
    fn service_after_edges_stage_requests_as_dags() {
        let mut spec = ExperimentSpec::shared(
            vec![
                (WorkloadSel::Named("KM"), 0.0),
                (WorkloadSel::Named("KM"), 0.0),
            ],
            MixPlacement::CgpLocal,
            Policy::Affinity,
            FairnessPolicy::Fcfs,
        );
        spec.kernels[1].after = vec![0];
        spec.arrivals = Some(poisson(1e-5, 2));
        let r = Session::new(cfg(), spec).unwrap().run().unwrap();
        let svc = r.run.service.as_ref().expect("service stats");
        assert_eq!(svc.requests_completed, 2);
        // The chained spec serializes its two stages, so each response
        // is strictly longer than the single-stage request's.
        let flat = Session::new(cfg(), service_spec(poisson(1e-5, 2)))
            .unwrap()
            .run()
            .unwrap();
        let flat_svc = flat.run.service.as_ref().unwrap();
        assert!(svc.mean_response > flat_svc.mean_response);
        assert_eq!(r.sources.len(), 2);
    }
}
