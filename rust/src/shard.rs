//! Intra-run parallel simulation: the engine sharded by home stack.
//!
//! CODA's own thesis makes a single big run shardable: co-locating
//! computation with data means most NDP accesses are stack-private, so
//! the simulation state decomposes along the same boundary the hardware
//! does. This module partitions one [`crate::engine::Engine`] run into
//! per-shard event heaps, per-shard DRAM backends and per-shard fabric
//! link servers, and runs the shards on scoped threads under classic
//! **conservative-lookahead** synchronization:
//!
//! * Stacks partition contiguously across shards ([`ShardPlan::owner`]);
//!   an SM, its residency slots, its TLBs and its stack's DRAM all live
//!   on the owning shard. Every fabric link is owned by the shard that
//!   hands traffic onto it (`owner(from)`, or `owner(to)` for the
//!   fully-connected crossbar's ingress links); all host-side state (the
//!   host stream, the host ports, host-local DDR) lives on shard 0.
//! * The **lookahead** `L` is the fabric's minimum first-link latency
//!   over shard-crossing routes ([`Interconnect::min_cross_shard_latency`]),
//!   further bounded by the host-port latency when a host stream is
//!   active: a request issued at `t` cannot reach another shard before
//!   `t + L`, so every shard may safely simulate the window
//!   `[W, W + L)` where `W` is the global minimum pending event time.
//! * Cross-shard traffic crosses between rounds through per-shard
//!   **mailboxes**. Each message is stamped with its delivery time (the
//!   simulated instant it is ready at its next hop); the receiver turns
//!   it into an ordinary heap event at that time, so messages interleave
//!   with local events in deterministic time order. A barrier closes the
//!   round: the leader drains every outbox in shard order, computes the
//!   next window, and everyone advances together — the run's result is a
//!   pure function of the round structure, independent of thread timing.
//!
//! Response-side messages (a DRAM completion crossing back) may carry
//! stamps inside an already-simulated window. That is safe here: every
//! server in the simulation (links, DRAM banks) is a busy-until server
//! that accepts non-monotonic `now`, so a "late" message is still served
//! at its correct simulated time — the relaxation shows up only as a
//! different arbitration interleaving, which is exactly the regime the
//! statistical-equivalence harness covers (`tests/shard.rs`).
//!
//! **Bit-exactness.** When a shard's traffic never leaves it (the
//! stack-private CGP mixes CODA optimizes for), no messages exist and
//! each shard's heap pops in exactly the sequential order restricted to
//! that shard, so every merged counter — cycles, per-app cycles, access
//! counts, byte counts, DRAM stats — is bit-identical to the sequential
//! engine; only `mean_mem_latency` may differ in final bits (its sum
//! accumulates in shard order instead of global time order). Remote
//! round-trips whose two routes and serving stack are all shard-local
//! run inline through the exact sequential code path, too.
//!
//! **Fallbacks.** [`plan`] returns `None` — callers then run the
//! sequential engine, the bit-exactness oracle — for every degenerate
//! case: `shard_stacks = 1` (the default), fewer than 2 stacks or
//! resolved shards, zero lookahead (`hop_latency_ns = 0`), hierarchical
//! TLBs (`tlb_l1_entries > 0`: the walker pool is machine-global), and
//! first-touch migration (it mutates the page table mid-run).

use crate::addr::{large_page_mapper, AddressMapper};
use crate::config::SystemConfig;
use crate::engine::{
    key, line_hash, AppCtx, BlockRef, BlockSource, EngineOptions, EngineRaw, HostStream, TimeKey,
    HOST_DDR_SALT,
};
use crate::gpu::{Sm, Topology};
use crate::mem::{self, MemBackend, MemBackendImpl, MemStats};
use crate::net::Interconnect;
use crate::stats::{AccessStats, LinkStat};
use crate::vm::VirtualMemory;
use crate::xlate::TranslationUnit;
use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Barrier, Mutex};

/// How a run shards: the stack-to-shard map and the conservative
/// lookahead (in cycles) bounding each synchronization window.
#[derive(Clone, Debug)]
pub struct ShardPlan {
    /// Number of shards (>= 2; 1-shard plans lower to sequential).
    pub shards: usize,
    /// `owner[stack]` = index of the shard simulating that stack.
    pub owner: Vec<usize>,
    /// Window slack in cycles: a shard at global minimum time `W` may
    /// process every event strictly before `W + lookahead`. Always > 0
    /// and finite.
    pub lookahead: f64,
}

/// Resolve the sharding decision for one run, or `None` to take the
/// sequential path. `host_active` must reflect whether a host stream
/// will actually inject traffic (it tightens the lookahead to the
/// host-port latency).
pub fn plan(cfg: &SystemConfig, opts: &EngineOptions, host_active: bool) -> Option<ShardPlan> {
    if cfg.shard_stacks == 1 || cfg.num_stacks < 2 {
        return None;
    }
    // First-touch migration rewrites the shared page table mid-run; the
    // hierarchical translation unit owns a machine-global walker pool.
    // Both couple shards through state the partition cannot split.
    if opts.migrate_on_first_touch || cfg.tlb_l1_entries > 0 {
        return None;
    }
    let hw = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1);
    let want = if cfg.shard_stacks == 0 {
        cfg.num_stacks.min(hw)
    } else {
        cfg.shard_stacks
    };
    let shards = want.min(cfg.num_stacks);
    if shards < 2 {
        return None;
    }
    let n = cfg.num_stacks;
    // Contiguous balanced partition: neighbouring stacks share a shard,
    // which keeps line/ring/mesh neighbour traffic shard-local.
    let owner: Vec<usize> = (0..n).map(|s| s * shards / n).collect();
    let net = Interconnect::new(cfg);
    let mut lookahead = net.min_cross_shard_latency(&owner);
    if host_active {
        lookahead = lookahead.min(cfg.host_latency_ns * cfg.cycles_per_ns());
    }
    if !lookahead.is_finite() || lookahead <= 0.0 {
        return None;
    }
    Some(ShardPlan {
        shards,
        owner,
        lookahead,
    })
}

/// Which shard owns each fabric link: the shard that hands traffic onto
/// it — `owner(from)` for real source nodes, `owner(to)` for the
/// fully-connected crossbar's ingress links (their `from` is the
/// pseudo-node `num_stacks`).
fn link_owners(net: &Interconnect, owner: &[usize]) -> Vec<usize> {
    let n = owner.len();
    net.links_meta()
        .iter()
        .map(|l| if l.from < n { owner[l.from] } else { owner[l.to] })
        .collect()
}

// ---------------------------------------------------------------------------
// Cross-shard messages.
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Phase {
    /// Request walking the forward route toward the serving stack.
    Req,
    /// Response walking the return route back to the issuing stack.
    Rsp,
    /// Final completion time headed for the origin shard's pending entry.
    Resolve,
}

/// One cross-shard message. `time` is the **delivery-time stamp**: the
/// simulated instant the message is ready at its next hop (for
/// `Resolve`, the access's completion time). The receiver enqueues it as
/// a heap event at exactly that time, so link and DRAM servers observe
/// cross-shard traffic in deterministic time order, not arrival order.
#[derive(Clone, Copy, Debug)]
struct NetMsg {
    phase: Phase,
    /// Issuing stack (the SM side; unused for host requests).
    src: u32,
    /// Serving stack.
    dst: u32,
    /// Next hop index into the current route (forward route for `Req`,
    /// return route for `Rsp`).
    hop: u32,
    /// Shard owning the pending entry this access resolves into.
    origin: u32,
    /// Pending-arena index in the origin shard.
    pending: u32,
    bytes: u32,
    write: bool,
    /// Host-port request: no fabric route (the host port already carried
    /// it); served read-only at `dst`, then resolved straight to shard 0.
    host: bool,
    time: f64,
    paddr: u64,
}

/// An in-flight window with accesses outstanding on other shards.
#[derive(Clone, Copy, Debug)]
enum PendKind {
    Block {
        app: u32,
        block: u32,
        /// First access index of the *next* window.
        end: u32,
        sm: u32,
        slot: u32,
        issued: u32,
    },
    Host {
        /// First line index of the next host window.
        end_i: u64,
    },
}

#[derive(Clone, Copy, Debug)]
struct Pending {
    outstanding: u32,
    window_done: f64,
    /// The window's issue time (per-access latency accounting baseline).
    issue_now: f64,
    kind: PendKind,
}

// ---------------------------------------------------------------------------
// Shard-local events (the engine's packed encoding plus a message tag).
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
struct Ev(u64, u64);

enum EvKind {
    Window {
        app: u32,
        block: u32,
        next: u32,
        sm: u32,
        slot: u32,
    },
    Arrival,
    HostWindow { next: u64 },
    /// A mailbox message reaching its stamped delivery time (word 1 =
    /// message-arena index).
    Msg { idx: u32 },
}

impl Ev {
    const ARRIVAL_TAG: u64 = u64::MAX;
    const HOST_TAG: u64 = u64::MAX - 1;
    const MSG_TAG: u64 = u64::MAX - 2;

    const ARRIVAL: Ev = Ev(Self::ARRIVAL_TAG, 0);

    #[inline]
    fn window(app: u32, block: u32, next: u32, sm: u32, slot: u32) -> Ev {
        debug_assert!(sm < 1 << 16 && slot < 1 << 16, "sm/slot exceed 16 bits");
        debug_assert!(app < u32::MAX - 2, "app index collides with the tag space");
        Ev(
            ((app as u64) << 32) | block as u64,
            ((next as u64) << 32) | ((sm as u64) << 16) | slot as u64,
        )
    }

    #[inline]
    fn host(next: u64) -> Ev {
        Ev(Self::HOST_TAG, next)
    }

    #[inline]
    fn msg(idx: u32) -> Ev {
        Ev(Self::MSG_TAG, idx as u64)
    }

    #[inline]
    fn kind(self) -> EvKind {
        match self.0 {
            Self::ARRIVAL_TAG => EvKind::Arrival,
            Self::HOST_TAG => EvKind::HostWindow { next: self.1 },
            Self::MSG_TAG => EvKind::Msg { idx: self.1 as u32 },
            w0 => EvKind::Window {
                app: (w0 >> 32) as u32,
                block: w0 as u32,
                next: (self.1 >> 32) as u32,
                sm: ((self.1 >> 16) & 0xFFFF) as u32,
                slot: (self.1 & 0xFFFF) as u32,
            },
        }
    }
}

// ---------------------------------------------------------------------------
// Shared round state.
// ---------------------------------------------------------------------------

/// Barrier-round bookkeeping shared by every shard. All atomics are
/// `Relaxed`: the barrier itself is the synchronization point (its wait
/// establishes happens-before between everything written before it and
/// everything read after), so the atomics only need atomicity, not
/// ordering.
struct RoundState {
    barrier: Barrier,
    /// Per-*sender* outbox filled during a round: `(dest shard, msg)` in
    /// send order. The leader drains them in sender order, which makes
    /// message routing deterministic.
    outboxes: Vec<Mutex<Vec<(u32, NetMsg)>>>,
    /// Per-*receiver* inbox the leader fills between barriers.
    inboxes: Vec<Mutex<Vec<NetMsg>>>,
    /// Per-shard earliest pending event time as `f64` bits
    /// (`f64::INFINITY` = idle).
    next_min: Vec<AtomicU64>,
    /// Exclusive end of the current window, as `f64` bits.
    w_end: AtomicU64,
    done: AtomicBool,
    windows: AtomicU64,
    msgs: AtomicU64,
}

impl RoundState {
    fn new(shards: usize) -> Self {
        Self {
            barrier: Barrier::new(shards),
            outboxes: (0..shards).map(|_| Mutex::new(Vec::new())).collect(),
            inboxes: (0..shards).map(|_| Mutex::new(Vec::new())).collect(),
            next_min: (0..shards)
                .map(|_| AtomicU64::new(f64::INFINITY.to_bits()))
                .collect(),
            w_end: AtomicU64::new(0),
            done: AtomicBool::new(false),
            windows: AtomicU64::new(0),
            msgs: AtomicU64::new(0),
        }
    }
}

/// The leader's between-barriers step: route every outbox into the
/// destination inboxes (sender order), then derive the next window from
/// the published per-shard minima and the routed delivery stamps. When
/// everything is idle and nothing was routed, the run is over.
fn route_round(shared: &RoundState, lookahead: f64) {
    let mut routed_min = f64::INFINITY;
    let mut routed = 0u64;
    for ob in &shared.outboxes {
        let batch = std::mem::take(&mut *ob.lock().unwrap());
        for (dest, m) in batch {
            routed_min = routed_min.min(m.time);
            routed += 1;
            shared.inboxes[dest as usize].lock().unwrap().push(m);
        }
    }
    if routed > 0 {
        shared.msgs.fetch_add(routed, Ordering::Relaxed);
    }
    let mut w = routed_min;
    for nm in &shared.next_min {
        w = w.min(f64::from_bits(nm.load(Ordering::Relaxed)));
    }
    if w.is_finite() {
        shared.w_end.store((w + lookahead).to_bits(), Ordering::Relaxed);
        shared.windows.fetch_add(1, Ordering::Relaxed);
    } else {
        shared.done.store(true, Ordering::Relaxed);
    }
}

// ---------------------------------------------------------------------------
// The per-shard worker.
// ---------------------------------------------------------------------------

struct Worker<'a, S> {
    idx: usize,
    cfg: &'a SystemConfig,
    plan: &'a ShardPlan,
    apps: &'a [AppCtx<'a>],
    vm: &'a VirtualMemory,
    opts: EngineOptions,
    /// Full topology: `sms[id]` works for any global SM id.
    topo: Topology,
    /// The SMs this shard owns (global ids preserved), in global order.
    my_sms: Vec<Sm>,
    mapper: AddressMapper,
    huge_mapper: AddressMapper,
    net: Interconnect,
    /// Full-size backend vector; only owned stacks are ever touched.
    stacks: Vec<MemBackendImpl>,
    xl: TranslationUnit,
    last_app: Vec<u32>,
    link_owner: Vec<usize>,
    /// Shard-local copy of the route table, so route walks don't borrow
    /// `net` while the link servers are being driven.
    route_offsets: Vec<u32>,
    route_hops: Vec<u32>,
    /// Per ordered pair `(s, d)`: both directions' routes and the serving
    /// stack all live on this shard, so the whole round trip runs inline
    /// through the exact sequential code path.
    inline_pair: Vec<bool>,
    heap: BinaryHeap<Reverse<(TimeKey, Ev)>>,
    seq: u64,
    occupied: Vec<bool>,
    sm_free: Vec<f64>,
    armed: Option<f64>,
    source: S,
    pend: Vec<Pending>,
    pend_free: Vec<u32>,
    msg_arena: Vec<NetMsg>,
    msg_free: Vec<u32>,
    /// Messages sent this round, flushed to the outbox at round end.
    outbound: Vec<(u32, NetMsg)>,
    // Host stream (shard 0 only; `host_total = 0` elsewhere).
    host_stream: Option<HostStream<'a>>,
    host_starts: Vec<u64>,
    host_per_pass: u64,
    host_total: u64,
    host_ddr: Option<MemBackendImpl>,
    host_end: f64,
    host_obj: usize,
    // Counters.
    stats: AccessStats,
    latency_sum: f64,
    latency_n: u64,
    end_time: f64,
    app_end: Vec<f64>,
    // Hoisted invariants (mirrors the sequential engine).
    l2_threshold: u64,
    l2_hit_cycles: f64,
    host_ddr_threshold: u64,
    line: u64,
    page_shift: u32,
    mlp: usize,
    compute: f64,
    slots_per_sm: usize,
    flush_on_switch: bool,
}

impl<'a, S: BlockSource> Worker<'a, S> {
    #[allow(clippy::too_many_arguments)]
    fn new(
        idx: usize,
        cfg: &'a SystemConfig,
        plan: &'a ShardPlan,
        apps: &'a [AppCtx<'a>],
        vm: &'a VirtualMemory,
        opts: EngineOptions,
        host: Option<HostStream<'a>>,
        mut source: S,
    ) -> Self {
        let topo = Topology::new(cfg);
        let cyc = cfg.cycles_per_ns();
        let my_sms: Vec<Sm> = topo
            .sms
            .iter()
            .copied()
            .filter(|s| plan.owner[s.stack] == idx)
            .collect();
        let net = Interconnect::new(cfg);
        let link_owner = link_owners(&net, &plan.owner);
        let (route_offsets, route_hops) = net.routes();
        let n = cfg.num_stacks;
        let mut inline_pair = vec![false; n * n];
        for s in 0..n {
            for d in 0..n {
                if s == d || plan.owner[s] != idx {
                    continue;
                }
                inline_pair[s * n + d] = plan.owner[d] == idx
                    && net
                        .route_of(s, d)
                        .iter()
                        .chain(net.route_of(d, s))
                        .all(|&l| link_owner[l as usize] == idx);
            }
        }

        let line = cfg.line_size;
        // Host stream state lands whole on shard 0 (mirrors the
        // sequential engine's precomputation).
        let host = if idx == 0 { host } else { None };
        let (host_stream, host_starts, host_per_pass, host_total) = match host {
            Some(h) if cfg.host_mlp > 0 && cfg.host_passes > 0 => {
                let mut starts = Vec::with_capacity(h.trace.objects.len());
                let mut acc = 0u64;
                for o in &h.trace.objects {
                    starts.push(acc);
                    acc += o.bytes.div_ceil(line);
                }
                let total = acc.saturating_mul(cfg.host_passes);
                if total == 0 {
                    (None, Vec::new(), 0, 0)
                } else {
                    (Some(h), starts, acc, total)
                }
            }
            _ => (None, Vec::new(), 0, 0),
        };
        let host_ddr_threshold = (cfg.host_ddr_fraction * (1u64 << 32) as f64) as u64;
        let host_ddr = if host_stream.is_some() && host_ddr_threshold > 0 {
            Some(mem::make_host_ddr_impl(cfg))
        } else {
            None
        };

        let slots_per_sm = cfg.blocks_per_sm;
        let mut heap: BinaryHeap<Reverse<(TimeKey, Ev)>> =
            BinaryHeap::with_capacity(my_sms.len() * slots_per_sm * 2 + 2);
        let mut occupied = vec![false; topo.sms.len() * slots_per_sm];
        let mut seq = 0u64;

        // Seed through a *filtered* topology (owned SMs only, global ids
        // preserved): every source iterates `topo.sms` / `sms_of_stack`,
        // so each shard's seed is the sequential seed restricted to its
        // SMs, in the same relative order.
        let seed_topo = Topology {
            sms: my_sms.clone(),
            num_stacks: topo.num_stacks,
            sms_per_stack: topo.sms_per_stack,
            blocks_per_sm: topo.blocks_per_sm,
        };
        source.seed(&seed_topo, &mut |sm, slot, br| {
            debug_assert!(slot < slots_per_sm, "slot {slot} out of range");
            debug_assert!(!occupied[sm * slots_per_sm + slot], "slot seeded twice");
            occupied[sm * slots_per_sm + slot] = true;
            heap.push(Reverse((
                key(0.0, seq),
                Ev::window(br.app, br.block, 0, sm as u32, slot as u32),
            )));
            seq += 1;
        });
        let mut armed = None;
        if let Some(ta) = source.next_arrival_after(0.0) {
            if ta > 0.0 {
                heap.push(Reverse((key(ta, seq), Ev::ARRIVAL)));
                seq += 1;
                armed = Some(ta);
            }
        }
        if host_stream.is_some() {
            heap.push(Reverse((key(0.0, seq), Ev::host(0))));
            seq += 1;
        }

        Worker {
            idx,
            cfg,
            plan,
            apps,
            vm,
            opts,
            my_sms,
            mapper: AddressMapper::new(cfg),
            huge_mapper: large_page_mapper(cfg),
            net,
            stacks: mem::make_backends_impl(cfg),
            xl: TranslationUnit::new(cfg, topo.sms.len(), cyc),
            last_app: vec![u32::MAX; topo.sms.len()],
            link_owner,
            route_offsets,
            route_hops,
            inline_pair,
            heap,
            seq,
            occupied,
            sm_free: vec![0.0; topo.sms.len()],
            armed,
            source,
            pend: Vec::new(),
            pend_free: Vec::new(),
            msg_arena: Vec::new(),
            msg_free: Vec::new(),
            outbound: Vec::new(),
            host_stream,
            host_starts,
            host_per_pass,
            host_total,
            host_ddr,
            host_end: 0.0,
            host_obj: 0,
            stats: AccessStats::default(),
            latency_sum: 0.0,
            latency_n: 0,
            end_time: 0.0,
            app_end: vec![0.0; apps.len()],
            l2_threshold: (cfg.l2_hit_rate * u32::MAX as f64) as u64,
            l2_hit_cycles: cfg.l2_hit_ns * cyc,
            host_ddr_threshold,
            line,
            page_shift: cfg.page_size.trailing_zeros(),
            mlp: cfg.mlp_per_block,
            compute: cfg.compute_cycles_per_access as f64,
            slots_per_sm,
            flush_on_switch: cfg.tlb_flush_on_switch,
            topo,
        }
    }

    /// The barrier-round loop. Each round: the leader routes mailboxes
    /// and derives the window `[W, W + L)`; every shard then drains its
    /// inbox into the heap and processes all events strictly before the
    /// window end. The minimum-time event is always inside the window,
    /// so every finite round makes progress.
    fn run(&mut self, shared: &RoundState) {
        self.publish(shared);
        loop {
            shared.barrier.wait();
            if self.idx == 0 {
                route_round(shared, self.plan.lookahead);
            }
            shared.barrier.wait();
            if shared.done.load(Ordering::Relaxed) {
                break;
            }
            self.drain_inbox(shared);
            let w_end = f64::from_bits(shared.w_end.load(Ordering::Relaxed));
            self.process_until(w_end);
            self.flush_outbound(shared);
            self.publish(shared);
        }
        debug_assert_eq!(
            self.pend.len(),
            self.pend_free.len(),
            "shard {} ended with unresolved pending windows",
            self.idx
        );
        debug_assert_eq!(
            self.msg_arena.len(),
            self.msg_free.len(),
            "shard {} ended with undelivered messages",
            self.idx
        );
    }

    fn publish(&self, shared: &RoundState) {
        let t = self
            .heap
            .peek()
            .map(|Reverse((tk, _))| tk.time_bits())
            .unwrap_or(f64::INFINITY.to_bits());
        shared.next_min[self.idx].store(t, Ordering::Relaxed);
    }

    fn drain_inbox(&mut self, shared: &RoundState) {
        let batch = std::mem::take(&mut *shared.inboxes[self.idx].lock().unwrap());
        for m in batch {
            let idx = self.alloc_msg(m);
            self.heap.push(Reverse((key(m.time, self.seq), Ev::msg(idx))));
            self.seq += 1;
        }
    }

    fn flush_outbound(&mut self, shared: &RoundState) {
        if !self.outbound.is_empty() {
            shared.outboxes[self.idx]
                .lock()
                .unwrap()
                .append(&mut self.outbound);
        }
    }

    fn process_until(&mut self, w_end: f64) {
        while let Some(&Reverse((tk, ev))) = self.heap.peek() {
            let now = f64::from_bits(tk.time_bits());
            if now >= w_end {
                break;
            }
            self.heap.pop();
            match ev.kind() {
                EvKind::Arrival => self.on_arrival_event(now),
                EvKind::HostWindow { next } => self.process_host_window(now, next),
                EvKind::Window {
                    app,
                    block,
                    next,
                    sm,
                    slot,
                } => self.process_window(now, app, block, next, sm, slot),
                EvKind::Msg { idx } => {
                    let m = self.msg_arena[idx as usize];
                    self.msg_free.push(idx);
                    match m.phase {
                        Phase::Req => self.walk_req(m),
                        Phase::Rsp => self.walk_rsp(m),
                        Phase::Resolve => self.resolve(m.pending, m.time),
                    }
                }
            }
        }
    }

    fn push_ev(&mut self, t: f64, ev: Ev) {
        self.heap.push(Reverse((key(t, self.seq), ev)));
        self.seq += 1;
    }

    fn send(&mut self, dest: usize, msg: NetMsg) {
        debug_assert_ne!(dest, self.idx, "self-sends must resolve inline");
        self.outbound.push((dest as u32, msg));
    }

    fn alloc_pend(&mut self, p: Pending) -> u32 {
        if let Some(i) = self.pend_free.pop() {
            self.pend[i as usize] = p;
            i
        } else {
            self.pend.push(p);
            (self.pend.len() - 1) as u32
        }
    }

    fn alloc_msg(&mut self, m: NetMsg) -> u32 {
        if let Some(i) = self.msg_free.pop() {
            self.msg_arena[i as usize] = m;
            i
        } else {
            self.msg_arena.push(m);
            (self.msg_arena.len() - 1) as u32
        }
    }

    /// Mirror of the sequential arrival handler over this shard's SMs.
    fn on_arrival_event(&mut self, now: f64) {
        if self.armed != Some(now) {
            return; // superseded event: inert
        }
        self.armed = None;
        self.source.on_arrival(now);
        for slot in 0..self.slots_per_sm {
            for i in 0..self.my_sms.len() {
                let smo = self.my_sms[i];
                if self.occupied[smo.id * self.slots_per_sm + slot] {
                    continue;
                }
                if let Some(br) = self.source.refill(smo, None, now) {
                    self.occupied[smo.id * self.slots_per_sm + slot] = true;
                    self.push_ev(now, Ev::window(br.app, br.block, 0, smo.id as u32, slot as u32));
                }
            }
        }
        if let Some(ta) = self.source.next_arrival_after(now) {
            if ta > now {
                self.push_ev(ta, Ev::ARRIVAL);
                self.armed = Some(ta);
            }
        }
    }

    /// One window of a resident block. Local accesses and fully
    /// shard-local round trips run the exact sequential code path; an
    /// access whose route leaves the shard allocates a pending entry and
    /// ships a `Req`, and the window's retirement is deferred until the
    /// last outstanding access resolves.
    fn process_window(&mut self, now: f64, app: u32, block: u32, next: u32, sm: u32, slot: u32) {
        let actx = self.apps[app as usize];
        let smo = self.topo.sms[sm as usize];
        if self.flush_on_switch && self.last_app[smo.id] != app {
            if self.last_app[smo.id] != u32::MAX {
                self.xl.flush(smo.id);
            }
            self.last_app[smo.id] = app;
        }
        let blk = &actx.trace.blocks[block as usize];
        let begin = next as usize;
        let end = (begin + self.mlp).min(blk.accesses.len());
        let obj_base = actx.obj_base;
        let n = self.cfg.num_stacks;

        let mut window_done = now;
        let mut pend_idx: Option<u32> = None;
        for a in &blk.accesses[begin..end] {
            let va = obj_base[a.obj as usize] + a.offset;
            let vaddr = va.0;
            if self.opts.l2_filter {
                let vline = vaddr / self.line;
                if line_hash(vline) & 0xFFFF_FFFF < self.l2_threshold {
                    self.stats.l2_hits += 1;
                    window_done = window_done.max(now + self.l2_hit_cycles);
                    continue;
                }
            }
            let (t, pte) = self.xl.access(smo.id, now, va, self.vm);
            let paddr = (pte.ppn << self.page_shift) | (vaddr & (self.cfg.page_size - 1));
            let m = if pte.huge {
                &self.huge_mapper
            } else {
                &self.mapper
            };
            let dst = m.stack_of(paddr, pte.granularity);
            if dst == smo.stack {
                self.stats.local += 1;
                let t1 = self.net.local_hop(t, dst, self.line);
                let done = self.stacks[dst].access_rw(t1, paddr, self.line, a.write).done;
                self.latency_sum += done - now;
                self.latency_n += 1;
                window_done = window_done.max(done);
            } else if self.inline_pair[smo.stack * n + dst] {
                // Whole round trip shard-local: sequential hot path.
                self.stats.remote += 1;
                let t1 = self.net.remote_hop(t, smo.stack, dst, self.line);
                let t2 = self.stacks[dst].access_rw(t1, paddr, self.line, a.write).done;
                let done = self.net.remote_hop(t2, dst, smo.stack, self.line);
                self.latency_sum += done - now;
                self.latency_n += 1;
                window_done = window_done.max(done);
            } else {
                self.stats.remote += 1;
                self.net.inject_remote(self.line);
                let pi = match pend_idx {
                    Some(p) => p,
                    None => {
                        let p = self.alloc_pend(Pending {
                            outstanding: 0,
                            window_done: now,
                            issue_now: now,
                            kind: PendKind::Block {
                                app,
                                block,
                                end: end as u32,
                                sm,
                                slot,
                                issued: (end - begin) as u32,
                            },
                        });
                        pend_idx = Some(p);
                        p
                    }
                };
                self.pend[pi as usize].outstanding += 1;
                self.walk_req(NetMsg {
                    phase: Phase::Req,
                    src: smo.stack as u32,
                    dst: dst as u32,
                    hop: 0,
                    origin: self.idx as u32,
                    pending: pi,
                    bytes: self.line as u32,
                    write: a.write,
                    host: false,
                    time: t,
                    paddr,
                });
            }
        }
        match pend_idx {
            None => self.finish_block(window_done, app, block, end as u32, sm, slot, (end - begin) as u32),
            Some(pi) => {
                let p = &mut self.pend[pi as usize];
                p.window_done = p.window_done.max(window_done);
            }
        }
    }

    /// Retirement bookkeeping after a window's last access completed
    /// (immediately for fully-local windows, at the final `Resolve` for
    /// windows with cross-shard accesses) — the sequential engine's
    /// post-window block verbatim.
    #[allow(clippy::too_many_arguments)]
    fn finish_block(
        &mut self,
        window_done: f64,
        app: u32,
        block: u32,
        end: u32,
        sm: u32,
        slot: u32,
        issued: u32,
    ) {
        let smo = self.topo.sms[sm as usize];
        let issued = issued as f64;
        let c_start = window_done.max(self.sm_free[smo.id]);
        let t_next = c_start + self.compute * issued;
        self.sm_free[smo.id] = t_next;
        self.end_time = self.end_time.max(t_next);
        self.app_end[app as usize] = self.app_end[app as usize].max(t_next);

        let blk_len = self.apps[app as usize].trace.blocks[block as usize]
            .accesses
            .len();
        if (end as usize) < blk_len {
            self.push_ev(t_next, Ev::window(app, block, end, sm, slot));
        } else {
            match self.source.refill(smo, Some(BlockRef { app, block }), t_next) {
                Some(br) => self.push_ev(t_next, Ev::window(br.app, br.block, 0, sm, slot)),
                None => {
                    self.occupied[sm as usize * self.slots_per_sm + slot as usize] = false;
                }
            }
            if let Some(ta) = self.source.next_arrival_after(t_next) {
                if ta > t_next && self.armed.map_or(true, |t| ta < t) {
                    self.push_ev(ta, Ev::ARRIVAL);
                    self.armed = Some(ta);
                }
            }
        }
    }

    /// One host window (shard 0 only), mirroring the sequential handler;
    /// requests to stacks owned elsewhere ship as host `Req`s and the
    /// next window waits for the last of them.
    fn process_host_window(&mut self, now: f64, next: u64) {
        let hs = self.host_stream.expect("host event without a host stream");
        let end_i = (next + self.cfg.host_mlp as u64).min(self.host_total);
        let mut window_done = 0.0f64;
        let mut pend_idx: Option<u32> = None;
        for i in next..end_i {
            let j = i % self.host_per_pass;
            if j == 0 {
                self.host_obj = 0;
            }
            while self.host_obj + 1 < self.host_starts.len()
                && self.host_starts[self.host_obj + 1] <= j
            {
                self.host_obj += 1;
            }
            let va = hs.obj_base[self.host_obj] + (j - self.host_starts[self.host_obj]) * self.line;
            if self.host_ddr_threshold > 0
                && line_hash((va.0 / self.line) ^ HOST_DDR_SALT) & 0xFFFF_FFFF
                    < self.host_ddr_threshold
            {
                self.stats.host_ddr += 1;
                let done = self
                    .host_ddr
                    .as_mut()
                    .expect("host DDR backend")
                    .access(now, va.0, self.line)
                    .done;
                window_done = window_done.max(done);
                self.host_end = self.host_end.max(done);
            } else {
                let pte = self.vm.pte_of(va).expect("host access beyond mapped object");
                let paddr = (pte.ppn << self.page_shift) | (va.0 & (self.cfg.page_size - 1));
                let m = if pte.huge {
                    &self.huge_mapper
                } else {
                    &self.mapper
                };
                let dst = m.stack_of(paddr, pte.granularity);
                self.stats.host += 1;
                let t1 = self.net.host_hop(now, dst, self.line);
                if self.plan.owner[dst] == self.idx {
                    let done = self.stacks[dst].access(t1, paddr, self.line).done;
                    window_done = window_done.max(done);
                    self.host_end = self.host_end.max(done);
                } else {
                    let pi = match pend_idx {
                        Some(p) => p,
                        None => {
                            let p = self.alloc_pend(Pending {
                                outstanding: 0,
                                // The sequential host window folds from
                                // 0.0, not `now`.
                                window_done: 0.0,
                                issue_now: now,
                                kind: PendKind::Host { end_i },
                            });
                            pend_idx = Some(p);
                            p
                        }
                    };
                    self.pend[pi as usize].outstanding += 1;
                    // The host port already carried the request; it needs
                    // no fabric route, just the serving shard.
                    self.send(
                        self.plan.owner[dst],
                        NetMsg {
                            phase: Phase::Req,
                            src: 0,
                            dst: dst as u32,
                            hop: 0,
                            origin: self.idx as u32,
                            pending: pi,
                            bytes: self.line as u32,
                            write: false,
                            host: true,
                            time: t1,
                            paddr,
                        },
                    );
                }
            }
        }
        match pend_idx {
            None => {
                if end_i < self.host_total {
                    self.push_ev(window_done.max(now), Ev::host(end_i));
                }
            }
            Some(pi) => {
                let p = &mut self.pend[pi as usize];
                p.window_done = p.window_done.max(window_done);
            }
        }
    }

    /// Advance a request along its forward route. Owned links transfer
    /// inline; the first foreign link hands the message to that link's
    /// shard. At the serving stack the access runs and the response (or,
    /// for host requests, the resolve) heads back.
    fn walk_req(&mut self, mut msg: NetMsg) {
        if !msg.host {
            let n = self.cfg.num_stacks;
            let base = msg.src as usize * n + msg.dst as usize;
            let lo = self.route_offsets[base] as usize;
            let hi = self.route_offsets[base + 1] as usize;
            while (msg.hop as usize) < hi - lo {
                let link = self.route_hops[lo + msg.hop as usize];
                let owner = self.link_owner[link as usize];
                if owner != self.idx {
                    self.send(owner, msg);
                    return;
                }
                msg.time = self.net.hop_transfer(link, msg.time, msg.bytes as u64);
                msg.hop += 1;
            }
        }
        let dst = msg.dst as usize;
        if self.plan.owner[dst] != self.idx {
            // Route fully crossed but the endpoint lives elsewhere (the
            // final link belonged to the penultimate stack's shard).
            self.send(self.plan.owner[dst], msg);
            return;
        }
        let done = if msg.host {
            self.stacks[dst].access(msg.time, msg.paddr, msg.bytes as u64).done
        } else {
            self.stacks[dst]
                .access_rw(msg.time, msg.paddr, msg.bytes as u64, msg.write)
                .done
        };
        msg.time = done;
        if msg.host {
            msg.phase = Phase::Resolve;
            self.deliver_resolve(msg);
        } else {
            // Return injection + response walk: the second half of the
            // sequential `remote_hop(t2, dst, src)` round trip.
            self.net.inject_remote(msg.bytes as u64);
            msg.phase = Phase::Rsp;
            msg.hop = 0;
            self.walk_rsp(msg);
        }
    }

    /// Advance a response along the return route (`dst -> src`), then
    /// resolve into the origin shard's pending entry.
    fn walk_rsp(&mut self, mut msg: NetMsg) {
        let n = self.cfg.num_stacks;
        let base = msg.dst as usize * n + msg.src as usize;
        let lo = self.route_offsets[base] as usize;
        let hi = self.route_offsets[base + 1] as usize;
        while (msg.hop as usize) < hi - lo {
            let link = self.route_hops[lo + msg.hop as usize];
            let owner = self.link_owner[link as usize];
            if owner != self.idx {
                self.send(owner, msg);
                return;
            }
            msg.time = self.net.hop_transfer(link, msg.time, msg.bytes as u64);
            msg.hop += 1;
        }
        msg.phase = Phase::Resolve;
        self.deliver_resolve(msg);
    }

    fn deliver_resolve(&mut self, msg: NetMsg) {
        if msg.origin as usize == self.idx {
            self.resolve(msg.pending, msg.time);
        } else {
            self.send(msg.origin as usize, msg);
        }
    }

    /// One outstanding access of a pending window completed at `done`.
    fn resolve(&mut self, pi: u32, done: f64) {
        let p = &mut self.pend[pi as usize];
        debug_assert!(p.outstanding > 0, "resolve on a settled pending entry");
        p.outstanding -= 1;
        p.window_done = p.window_done.max(done);
        let issue_now = p.issue_now;
        let settled = p.outstanding == 0;
        let (window_done, kind) = (p.window_done, p.kind);
        match kind {
            PendKind::Block { .. } => {
                self.latency_sum += done - issue_now;
                self.latency_n += 1;
            }
            PendKind::Host { .. } => {
                self.host_end = self.host_end.max(done);
            }
        }
        if !settled {
            return;
        }
        self.pend_free.push(pi);
        match kind {
            PendKind::Block {
                app,
                block,
                end,
                sm,
                slot,
                issued,
            } => self.finish_block(window_done, app, block, end, sm, slot, issued),
            PendKind::Host { end_i } => {
                if end_i < self.host_total {
                    self.push_ev(window_done.max(issue_now), Ev::host(end_i));
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// The sharded engine front door.
// ---------------------------------------------------------------------------

/// The sharded counterpart of [`crate::engine::Engine`]: same inputs,
/// except the page table is taken by shared reference (sharded runs never
/// mutate it — [`plan`] refuses migration) and each shard gets its own
/// [`BlockSource`] from a factory instead of one `&mut` source.
pub struct ShardEngine<'a> {
    pub cfg: &'a SystemConfig,
    pub apps: Vec<AppCtx<'a>>,
    pub vm: &'a VirtualMemory,
    pub opts: EngineOptions,
    pub host: Option<HostStream<'a>>,
}

impl<'a> ShardEngine<'a> {
    /// Run to completion on `plan.shards` scoped threads. `make_source(i)`
    /// builds shard `i`'s source, pre-restricted to the work that shard
    /// owns (apps homed on its stacks; its residue of a request stream).
    /// Returns the merged counters plus every shard's source, so callers
    /// can fold source-side statistics (service-mode request accounting).
    pub fn run<S, F>(self, plan: &ShardPlan, make_source: F) -> (EngineRaw, Vec<S>)
    where
        S: BlockSource + Send,
        F: Fn(usize) -> S + Sync,
    {
        let ShardEngine {
            cfg,
            apps,
            vm,
            opts,
            host,
        } = self;
        assert!(
            !opts.migrate_on_first_touch,
            "sharded runs cannot migrate pages (plan() must reject this)"
        );
        let n_sms = Topology::new(cfg).sms.len();
        assert!(
            n_sms < 1 << 16 && cfg.blocks_per_sm < 1 << 16,
            "topology exceeds the packed event encoding (sm/slot must fit 16 bits)"
        );
        let shared = RoundState::new(plan.shards);
        let apps = &apps[..];
        let workers: Vec<Worker<'_, S>> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..plan.shards)
                .map(|i| {
                    let shared = &shared;
                    let make_source = &make_source;
                    scope.spawn(move || {
                        let mut w =
                            Worker::new(i, cfg, plan, apps, vm, opts, host, make_source(i));
                        w.run(shared);
                        w
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("shard worker panicked"))
                .collect()
        });
        let raw = merge(cfg, plan, &workers, &shared);
        (raw, workers.into_iter().map(|w| w.source).collect())
    }
}

/// Fold per-shard counters into one [`EngineRaw`]. Per-stack state
/// (DRAM stats, served bytes, row-hit rates, link counters) comes from
/// the owning shard; times are element-wise maxima; counts are sums.
fn merge<S>(
    cfg: &SystemConfig,
    plan: &ShardPlan,
    workers: &[Worker<'_, S>],
    shared: &RoundState,
) -> EngineRaw {
    let n = cfg.num_stacks;
    let mut stats = AccessStats::default();
    let mut end_time = 0.0f64;
    let napps = workers.first().map_or(0, |w| w.app_end.len());
    let mut app_end = vec![0.0f64; napps];
    let mut latency_sum = 0.0f64;
    let mut latency_n = 0u64;
    let mut tlb_hits = 0u64;
    let mut tlb_total = 0u64;
    for w in workers {
        stats.add(&w.stats);
        end_time = end_time.max(w.end_time);
        for (a, b) in app_end.iter_mut().zip(&w.app_end) {
            *a = a.max(*b);
        }
        latency_sum += w.latency_sum;
        latency_n += w.latency_n;
        let (h, t) = w.xl.hit_totals();
        tlb_hits += h;
        tlb_total += t;
    }
    let row_hit_rate = {
        let rates: Vec<f64> = (0..n)
            .map(|s| workers[plan.owner[s]].stacks[s].row_hit_rate())
            .collect();
        crate::stats::mean(&rates)
    };
    let mut mem_stats = MemStats::default();
    for s in 0..n {
        mem_stats.add(&workers[plan.owner[s]].stacks[s].stats());
    }
    // Each fabric link was only ever driven by its owning shard, so the
    // merged per-link counters come straight from the owner.
    let per_shard: Vec<Vec<LinkStat>> = workers.iter().map(|w| w.net.link_stats()).collect();
    let link_stats: Vec<LinkStat> = if per_shard[0].is_empty() {
        Vec::new()
    } else {
        let link_owner = link_owners(&workers[0].net, &plan.owner);
        (0..per_shard[0].len())
            .map(|l| per_shard[link_owner[l]][l])
            .collect()
    };
    EngineRaw {
        stats,
        end_time,
        app_end,
        mean_mem_latency: if latency_n == 0 {
            0.0
        } else {
            latency_sum / latency_n as f64
        },
        tlb_hit_rate: if tlb_total == 0 {
            0.0
        } else {
            tlb_hits as f64 / tlb_total as f64
        },
        row_hit_rate,
        stack_bytes: (0..n)
            .map(|s| workers[plan.owner[s]].stacks[s].bytes_served())
            .collect(),
        remote_bytes: workers.iter().map(|w| w.net.remote_bytes()).sum(),
        mem: mem_stats,
        migrated_pages: 0,
        host_end: workers[0].host_end,
        host_bytes: workers[0].net.host_bytes(),
        host_ddr_bytes: workers[0]
            .host_ddr
            .as_ref()
            .map(|d| d.bytes_served())
            .unwrap_or(0),
        host_port_stalls: workers[0].net.host_port_stalls(),
        link_stats,
        // Sharding requires the legacy translation model (per-SM state
        // only), which never reports hierarchical stats.
        xlate: None,
        shard_stacks: plan.shards as u64,
        shard_windows: shared.windows.load(Ordering::Relaxed),
        shard_msgs: shared.msgs.load(Ordering::Relaxed),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // An explicit shard count: `shard_stacks = 0` resolves against the
    // machine's core count, which would make these tests flaky on a
    // single-core runner.
    fn base_cfg() -> SystemConfig {
        let mut c = SystemConfig::default();
        c.shard_stacks = 2;
        c
    }

    #[test]
    fn plan_partitions_contiguously_and_balanced() {
        let mut c = base_cfg();
        c.num_stacks = 8;
        c.shard_stacks = 3;
        let p = plan(&c, &EngineOptions::default(), false).expect("plan");
        assert_eq!(p.shards, 3);
        assert_eq!(p.owner.len(), 8);
        // Contiguous and non-decreasing, every shard non-empty.
        for w in p.owner.windows(2) {
            assert!(w[1] == w[0] || w[1] == w[0] + 1);
        }
        for s in 0..3 {
            assert!(p.owner.iter().any(|&o| o == s), "shard {s} owns no stack");
        }
        assert!(p.lookahead > 0.0 && p.lookahead.is_finite());
        // Auto (0) resolves against the machine's cores: whether it
        // engages is machine-dependent, but an engaged plan never
        // exceeds the stack count.
        c.shard_stacks = 0;
        if let Some(auto) = plan(&c, &EngineOptions::default(), false) {
            assert!(auto.shards >= 2 && auto.shards <= c.num_stacks);
        }
    }

    #[test]
    fn plan_falls_back_on_degenerate_configs() {
        let opts = EngineOptions::default();
        // The default knob value is the sequential engine.
        let mut c = SystemConfig::default();
        assert_eq!(c.shard_stacks, 1);
        assert!(plan(&c, &opts, false).is_none());
        // A single stack cannot shard.
        c = base_cfg();
        c.num_stacks = 1;
        assert!(plan(&c, &opts, false).is_none());
        // An explicit shard cap of 1 is sequential even with the knob set.
        c = base_cfg();
        c.shard_stacks = 1;
        assert!(plan(&c, &opts, false).is_none());
        // Zero-latency multi-hop fabric: no usable lookahead.
        c = base_cfg();
        c.topology = crate::net::TopologyKind::Ring;
        c.hop_latency_ns = 0.0;
        assert!(plan(&c, &opts, false).is_none());
        // Hierarchical TLBs couple shards through the global walker pool.
        c = base_cfg();
        c.tlb_l1_entries = 16;
        assert!(plan(&c, &opts, false).is_none());
        // First-touch migration mutates the shared page table.
        c = base_cfg();
        let mig = EngineOptions {
            l2_filter: true,
            migrate_on_first_touch: true,
        };
        assert!(plan(&c, &mig, false).is_none());
    }

    #[test]
    fn host_latency_tightens_lookahead() {
        let mut c = base_cfg();
        // Host port latency below the fabric's first-hop latency.
        c.host_latency_ns = c.remote_latency_ns / 10.0;
        let cyc = c.cycles_per_ns();
        let without = plan(&c, &EngineOptions::default(), false).expect("plan");
        let with = plan(&c, &EngineOptions::default(), true).expect("plan");
        assert!(with.lookahead < without.lookahead);
        assert!((with.lookahead - c.host_latency_ns * cyc).abs() < 1e-9);
    }

    #[test]
    fn link_ownership_charges_the_handing_shard() {
        let c = base_cfg();
        let p = plan(&c, &EngineOptions::default(), false).expect("plan");
        let net = Interconnect::new(&c);
        let owners = link_owners(&net, &p.owner);
        let n = c.num_stacks;
        for (l, meta) in net.links_meta().iter().enumerate() {
            let expect = if meta.from < n {
                p.owner[meta.from]
            } else {
                p.owner[meta.to]
            };
            assert_eq!(owners[l], expect);
        }
    }

    #[test]
    fn shard_event_encoding_round_trips() {
        match Ev::msg(0xDEAD).kind() {
            EvKind::Msg { idx } => assert_eq!(idx, 0xDEAD),
            _ => panic!("msg decoded wrong"),
        }
        assert!(matches!(Ev::ARRIVAL.kind(), EvKind::Arrival));
        match Ev::window(3, 7, 11, 13, 2).kind() {
            EvKind::Window {
                app,
                block,
                next,
                sm,
                slot,
            } => assert_eq!((app, block, next, sm, slot), (3, 7, 11, 13, 2)),
            _ => panic!("window decoded wrong"),
        }
        match Ev::host(99).kind() {
            EvKind::HostWindow { next } => assert_eq!(next, 99),
            _ => panic!("host decoded wrong"),
        }
    }
}
