//! The NDP system simulator: the substrate standing in for the paper's
//! SST + MacSim + DRAMSim2 stack (DESIGN.md §2 documents the substitution).
//!
//! Discrete-event, bandwidth/latency/queuing-accurate at the granularity
//! the paper's conclusions live at: every memory access is routed through
//! the TLB, the dual-mode address mapping, and either the local crossbar +
//! HBM of its SM's stack or the remote ports + the owning stack's HBM.
//! Links and DRAM channels are busy-until servers, so hotspots queue.
//!
//! Thread-blocks issue their access streams in windows of `mlp_per_block`
//! outstanding requests, with `compute_cycles_per_access` of execution
//! charged per access — an SM-throughput model rather than a pipeline
//! model. Blocks occupy SM residency slots; when one retires, the
//! scheduler's policy picks the next (this is where Eq 1 bites).

use crate::addr::{AddressMapper, Granularity};
use crate::config::SystemConfig;
use crate::gpu::Topology;
use crate::mem::{self, MemBackend, MemStats};
use crate::net::Interconnect;
use crate::sched::{Policy, Scheduler};
use crate::stats::{AccessStats, RunReport};
use crate::trace::KernelTrace;
use crate::vm::{Tlb, VirtualMemory};
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Event key ordering by time (f64 bit-monotonic for non-negative values),
/// tie-broken by sequence number for determinism.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
struct TimeKey(u64, u64);

fn key(t: f64, seq: u64) -> TimeKey {
    debug_assert!(t >= 0.0);
    TimeKey(t.to_bits(), seq)
}

#[derive(Clone, Copy, Debug)]
struct SlotState {
    /// Index into `trace.blocks`.
    block_idx: u32,
    /// Next access offset within the block's stream.
    next_access: u32,
}

/// One simulated kernel execution.
pub struct KernelRun<'a> {
    pub cfg: &'a SystemConfig,
    pub trace: &'a KernelTrace,
    pub vm: &'a mut VirtualMemory,
    /// Base virtual address of each object (indexed by `Access::obj`).
    pub obj_base: &'a [u64],
    pub policy: Policy,
    /// Migrate FGP pages to the first-touching stack (migration-FTA).
    pub migrate_on_first_touch: bool,
}

/// Fast deterministic hash for the L2-filter decision (splitmix finalizer).
#[inline]
fn line_hash(x: u64) -> u64 {
    let mut z = x.wrapping_mul(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z ^ (z >> 31)
}

impl<'a> KernelRun<'a> {
    /// Execute the kernel and return the run report.
    pub fn run(self) -> RunReport {
        let cfg = self.cfg;
        let topo = Topology::new(cfg);
        let mapper = AddressMapper::new(cfg);
        let mut net = Interconnect::new(cfg);
        // DRAM timing is pluggable (fixed-latency vs bank-level); the
        // backend may only shape time, never which accesses occur.
        let mut stacks: Vec<Box<dyn MemBackend>> = mem::make_backends(cfg);
        let mut tlbs: Vec<Tlb> = (0..topo.sms.len())
            .map(|_| Tlb::new(cfg.tlb_entries))
            .collect();
        let mut sched = Scheduler::new(self.policy, self.trace.num_blocks(), cfg);

        // block_id -> index in trace.blocks (blocks may be listed in any order).
        let mut id_to_idx = vec![u32::MAX; self.trace.num_blocks() as usize];
        for (i, b) in self.trace.blocks.iter().enumerate() {
            id_to_idx[b.block_id as usize] = i as u32;
        }

        let cyc = cfg.cycles_per_ns();
        let l2_threshold = (self.cfg.l2_hit_rate * u32::MAX as f64) as u64;
        let l2_hit_cycles = cfg.l2_hit_ns * cyc;
        let tlb_miss_cycles = cfg.tlb_miss_ns * cyc;
        let line = cfg.line_size;
        let page_shift = cfg.page_size.trailing_zeros();
        let mlp = cfg.mlp_per_block as u32;
        let compute = cfg.compute_cycles_per_access as f64;

        let mut stats = AccessStats::default();
        let mut migrated: u64 = 0;
        let mut migrated_pages: Vec<bool> = vec![false; self.vm.mapped_pages() as usize];
        let mut latency_sum = 0.0f64;
        let mut latency_n: u64 = 0;
        let mut end_time = 0.0f64;
        let mut seq: u64 = 0;

        // (key, sm_index, slot_index) min-heap.
        let mut heap: BinaryHeap<Reverse<(TimeKey, u32, u32)>> = BinaryHeap::new();
        let slots_per_sm = cfg.blocks_per_sm;
        let mut slots: Vec<Option<SlotState>> = vec![None; topo.sms.len() * slots_per_sm];
        // Per-SM issue-bandwidth server: resident blocks share the SM's
        // execution resources, so their compute phases serialize.
        let mut sm_free: Vec<f64> = vec![0.0; topo.sms.len()];

        // Initial fill: breadth-first over SMs (hardware distributes blocks
        // across SMs before stacking occupancy on one).
        for slot in 0..slots_per_sm {
            for sm in &topo.sms {
                if let Some(bid) = sched.next_for(sm.stack) {
                    let idx = id_to_idx[bid as usize];
                    slots[sm.id * slots_per_sm + slot] = Some(SlotState {
                        block_idx: idx,
                        next_access: 0,
                    });
                    heap.push(Reverse((key(0.0, seq), sm.id as u32, slot as u32)));
                    seq += 1;
                }
            }
        }

        while let Some(Reverse((tk, sm_id, slot_id))) = heap.pop() {
            let now = f64::from_bits(tk.0);
            let sm = topo.sms[sm_id as usize];
            let slot_key = sm_id as usize * slots_per_sm + slot_id as usize;
            let Some(state) = slots[slot_key] else { continue };
            let block = &self.trace.blocks[state.block_idx as usize];
            let begin = state.next_access as usize;
            let end = (begin + mlp as usize).min(block.accesses.len());

            // Issue one window of accesses; the block stalls until the
            // slowest completes, then pays its compute debt.
            let mut window_done = now;
            for a in &block.accesses[begin..end] {
                let vaddr = self.obj_base[a.obj as usize] + a.offset;
                let vline = vaddr / line;
                // Stack-level L2 filter (deterministic per line).
                if line_hash(vline) & 0xFFFF_FFFF < l2_threshold {
                    stats.l2_hits += 1;
                    window_done = window_done.max(now + l2_hit_cycles);
                    continue;
                }
                // TLB + translation.
                let vpn = vaddr >> page_shift;
                let mut t = now;
                let pte = match tlbs[sm.id].lookup(vpn) {
                    Some(pte) => pte,
                    None => {
                        t += tlb_miss_cycles;
                        let pte = self
                            .vm
                            .pte_of(vaddr)
                            .expect("workload access beyond mapped object");
                        tlbs[sm.id].fill(vpn, pte);
                        pte
                    }
                };
                let mut paddr = (pte.ppn << page_shift) | (vaddr & (cfg.page_size - 1));
                let mut gran = pte.granularity;
                // Migration-based first touch: the first NDP access to an
                // FGP page pulls the whole page into the toucher's stack.
                if self.migrate_on_first_touch
                    && gran == Granularity::Fgp
                    && !migrated_pages[vpn as usize]
                {
                    migrated_pages[vpn as usize] = true;
                    if self.vm.migrate_to_cgp(vaddr, sm.stack).is_ok() {
                        migrated += 1;
                        // Page copy: page_size bytes arrive over the remote
                        // ingress port (3/4 of the stripes are remote).
                        let copy_bytes =
                            cfg.page_size * (cfg.num_stacks as u64 - 1) / cfg.num_stacks as u64;
                        t = net.remote_hop(t, (sm.stack + 1) % cfg.num_stacks, sm.stack, copy_bytes);
                        let pte = self.vm.pte_of(vaddr).unwrap();
                        tlbs[sm.id].fill(vpn, pte);
                        paddr = (pte.ppn << page_shift) | (vaddr & (cfg.page_size - 1));
                        gran = pte.granularity;
                    }
                }
                let dst = mapper.stack_of(paddr, gran);
                let done = if dst == sm.stack {
                    stats.local += 1;
                    let t1 = net.local_hop(t, dst, line);
                    stacks[dst].access(t1, paddr, line).done
                } else {
                    stats.remote += 1;
                    // Request out, serve at the owner, response back.
                    let t1 = net.remote_hop(t, sm.stack, dst, line);
                    let t2 = stacks[dst].access(t1, paddr, line).done;
                    net.remote_hop(t2, dst, sm.stack, line)
                };
                latency_sum += done - now;
                latency_n += 1;
                window_done = window_done.max(done);
            }
            let issued = (end - begin) as f64;
            // Compute occupies the SM serially across its resident blocks.
            let c_start = window_done.max(sm_free[sm.id]);
            let t_next = c_start + compute * issued;
            sm_free[sm.id] = t_next;
            end_time = end_time.max(t_next);

            if end < block.accesses.len() {
                slots[slot_key] = Some(SlotState {
                    block_idx: state.block_idx,
                    next_access: end as u32,
                });
                heap.push(Reverse((key(t_next, seq), sm_id, slot_id)));
                seq += 1;
            } else {
                // Block retires; pull the next one for this stack.
                match sched.next_for(sm.stack) {
                    Some(bid) => {
                        slots[slot_key] = Some(SlotState {
                            block_idx: id_to_idx[bid as usize],
                            next_access: 0,
                        });
                        heap.push(Reverse((key(t_next, seq), sm_id, slot_id)));
                        seq += 1;
                    }
                    None => slots[slot_key] = None,
                }
            }
        }

        let tlb_hits: u64 = tlbs.iter().map(|t| t.hits).sum();
        let tlb_total: u64 = tlbs.iter().map(|t| t.hits + t.misses).sum();
        let row_hit_rate = {
            let rates: Vec<f64> = stacks.iter().map(|s| s.row_hit_rate()).collect();
            crate::stats::mean(&rates)
        };
        let mut mem_stats = MemStats::default();
        for s in &stacks {
            mem_stats.add(&s.stats());
        }
        RunReport {
            workload: self.trace.name.clone(),
            mechanism: String::new(),
            cycles: end_time,
            accesses: stats,
            stack_bytes: stacks.iter().map(|s| s.bytes_served()).collect(),
            remote_bytes: net.remote_bytes(),
            mean_mem_latency: if latency_n == 0 {
                0.0
            } else {
                latency_sum / latency_n as f64
            },
            tlb_hit_rate: if tlb_total == 0 {
                0.0
            } else {
                tlb_hits as f64 / tlb_total as f64
            },
            row_hit_rate,
            mem_backend: cfg.mem_backend.to_string(),
            bank_conflicts: mem_stats.row_conflicts,
            refresh_stalls: mem_stats.refresh_stalls,
            cgp_pages: 0,
            fgp_pages: 0,
            migrated_pages: migrated,
        }
    }
}

/// Convenience: map a trace's objects into a fresh [`VirtualMemory`]
/// according to a placement plan; returns (vm, per-object base vaddrs,
/// cgp_pages, fgp_pages).
pub fn map_objects(
    cfg: &SystemConfig,
    trace: &KernelTrace,
    plan: &crate::placement::PlacementPlan,
) -> crate::Result<(VirtualMemory, Vec<u64>, u64, u64)> {
    let mut vm = VirtualMemory::new(cfg);
    let mut bases = Vec::with_capacity(trace.objects.len());
    let mut cgp_pages = 0u64;
    let mut fgp_pages = 0u64;
    for (i, obj) in trace.objects.iter().enumerate() {
        let pages = obj.bytes.div_ceil(cfg.page_size).max(1);
        // Mixed plans (page overrides) pick per page; object-level plans
        // pick once.
        let mut any_cgp = false;
        for p in 0..pages {
            if plan
                .stack_of_page(i as u16, p, cfg.page_size, cfg.num_stacks)
                .is_some()
            {
                any_cgp = true;
                break;
            }
        }
        if any_cgp {
            let base = vm.map_cgp(pages, |p| {
                plan.stack_of_page(i as u16, p, cfg.page_size, cfg.num_stacks)
                    .unwrap_or(((p) % cfg.num_stacks as u64) as usize)
            })?;
            cgp_pages += pages;
            bases.push(base);
        } else {
            let base = vm.map_fgp(pages)?;
            fgp_pages += pages;
            bases.push(base);
        }
    }
    Ok((vm, bases, cgp_pages, fgp_pages))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::{PlacementPlan, Placement};
    use crate::sched::affinity_stack;
    use crate::trace::{Access, BlockTrace, ObjectDesc};
    use std::collections::HashMap;

    fn cfg() -> SystemConfig {
        let mut c = SystemConfig::test_small();
        c.l2_hit_rate = 0.0; // make access counts exact for assertions
        c
    }

    /// A trace where each block touches its own contiguous 4KB slice.
    fn partitioned_trace(cfg: &SystemConfig, blocks: u32) -> KernelTrace {
        let per_block = cfg.page_size;
        let t_blocks = (0..blocks)
            .map(|b| BlockTrace {
                block_id: b,
                accesses: (0..per_block / cfg.line_size)
                    .map(|i| Access {
                        obj: 0,
                        offset: b as u64 * per_block + i * cfg.line_size,
                        write: i % 4 == 0,
                    })
                    .collect(),
            })
            .collect();
        KernelTrace {
            name: "partitioned".into(),
            threads_per_block: 256,
            objects: vec![ObjectDesc {
                name: "data".into(),
                bytes: blocks as u64 * per_block,
            }],
            blocks: t_blocks,
        }
    }

    fn run(
        cfg: &SystemConfig,
        trace: &KernelTrace,
        plan: &PlacementPlan,
        policy: Policy,
    ) -> RunReport {
        let (mut vm, bases, _, _) = map_objects(cfg, trace, plan).unwrap();
        KernelRun {
            cfg,
            trace,
            vm: &mut vm,
            obj_base: &bases,
            policy,
            migrate_on_first_touch: plan.migrate_on_first_touch,
        }
        .run()
    }

    #[test]
    fn fgp_spreads_accesses_quarter_local() {
        let c = cfg();
        let t = partitioned_trace(&c, 96);
        let plan = PlacementPlan::all_fgp(1);
        let r = run(&c, &t, &plan, Policy::Baseline);
        assert_eq!(r.accesses.ndp_total(), t.total_accesses());
        let lf = r.accesses.local_fraction();
        assert!((lf - 0.25).abs() < 0.02, "local fraction {lf}");
    }

    /// The paper's core claim in miniature: affinity schedule + Eq 2/3
    /// placement eliminates remote accesses for block-exclusive data.
    #[test]
    fn coda_placement_eliminates_remote() {
        let c = cfg();
        let t = partitioned_trace(&c, 96);
        let chunk = crate::placement::eq2_chunk_size(c.page_size, &c);
        let plan = PlacementPlan {
            per_object: vec![Placement::Cgp { chunk_size: chunk }],
            page_overrides: HashMap::new(),
            migrate_on_first_touch: false,
        };
        let r = run(&c, &t, &plan, Policy::Affinity);
        assert_eq!(r.accesses.remote, 0, "all accesses must be local");
        assert_eq!(r.accesses.local, t.total_accesses());
    }

    #[test]
    fn coda_is_faster_than_fgp_baseline() {
        let c = cfg();
        let t = partitioned_trace(&c, 192);
        let fgp = run(&c, &t, &PlacementPlan::all_fgp(1), Policy::Baseline);
        let chunk = crate::placement::eq2_chunk_size(c.page_size, &c);
        let coda_plan = PlacementPlan {
            per_object: vec![Placement::Cgp { chunk_size: chunk }],
            page_overrides: HashMap::new(),
            migrate_on_first_touch: false,
        };
        let coda = run(&c, &t, &coda_plan, Policy::Affinity);
        let speedup = coda.speedup_over(&fgp);
        assert!(speedup > 1.1, "speedup {speedup}");
        assert!(coda.remote_reduction_over(&fgp) > 0.9);
    }

    #[test]
    fn migration_fta_migrates_and_localizes() {
        let c = cfg();
        let t = partitioned_trace(&c, 24); // one stack's worth
        let mut plan = PlacementPlan::all_fgp(1);
        plan.migrate_on_first_touch = true;
        let r = run(&c, &t, &plan, Policy::Affinity);
        assert_eq!(r.migrated_pages, 24, "one page per block");
        // After migration the remaining accesses in each page are local.
        assert!(r.accesses.local_fraction() > 0.9);
    }

    #[test]
    fn determinism() {
        let c = cfg();
        let t = partitioned_trace(&c, 96);
        let plan = PlacementPlan::all_fgp(1);
        let a = run(&c, &t, &plan, Policy::Baseline);
        let b = run(&c, &t, &plan, Policy::Baseline);
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.accesses, b.accesses);
    }

    #[test]
    fn bank_backend_preserves_access_counts() {
        let fixed = cfg();
        let mut bank = cfg();
        bank.mem_backend = crate::config::MemBackendKind::BankLevel;
        let t = partitioned_trace(&fixed, 96);
        let plan = PlacementPlan::all_fgp(1);
        let rf = run(&fixed, &t, &plan, Policy::Baseline);
        let rb = run(&bank, &t, &plan, Policy::Baseline);
        assert_eq!(rf.accesses, rb.accesses, "backend leaked into placement");
        assert_eq!(rf.stack_bytes, rb.stack_bytes);
        assert_eq!(rb.mem_backend, "bank");
        assert_eq!(rf.mem_backend, "fixed");
        // Timing is allowed (expected) to differ.
        assert!(rb.cycles > 0.0);
        assert!((rb.cycles - rf.cycles).abs() > 1e-9);
    }

    #[test]
    fn l2_filter_reduces_dram_traffic() {
        let mut c = cfg();
        c.l2_hit_rate = 0.5;
        let t = partitioned_trace(&c, 48);
        let r = run(&c, &t, &PlacementPlan::all_fgp(1), Policy::Baseline);
        let total = t.total_accesses();
        assert!(r.accesses.l2_hits > total / 3);
        assert_eq!(r.accesses.ndp_total() + r.accesses.l2_hits, total);
    }

    #[test]
    fn remote_bandwidth_sensitivity_shape() {
        // Lower remote bandwidth must hurt an FGP run (Fig 10's premise).
        let mut slow = cfg();
        slow.remote_bw_gbs = 4.0;
        let mut fast = cfg();
        fast.remote_bw_gbs = 256.0;
        let t = partitioned_trace(&slow, 96);
        let plan = PlacementPlan::all_fgp(1);
        let r_slow = run(&slow, &t, &plan, Policy::Baseline);
        let r_fast = run(&fast, &t, &plan, Policy::Baseline);
        assert!(
            r_slow.cycles > 1.2 * r_fast.cycles,
            "slow {} vs fast {}",
            r_slow.cycles,
            r_fast.cycles
        );
    }

    #[test]
    fn affinity_stack_consistency_with_map_objects() {
        // Under the CODA plan every block's pages live on its affinity
        // stack (checked via translation, not simulation).
        let c = cfg();
        let t = partitioned_trace(&c, 96);
        let chunk = crate::placement::eq2_chunk_size(c.page_size, &c);
        let plan = PlacementPlan {
            per_object: vec![Placement::Cgp { chunk_size: chunk }],
            page_overrides: HashMap::new(),
            migrate_on_first_touch: false,
        };
        let (vm, bases, cgp, fgp) = map_objects(&c, &t, &plan).unwrap();
        assert!(cgp > 0 && fgp == 0);
        let mapper = AddressMapper::new(&c);
        for b in &t.blocks {
            let stack = affinity_stack(b.block_id, &c);
            for a in &b.accesses {
                let (p, g) = vm.translate(bases[a.obj as usize] + a.offset).unwrap();
                assert_eq!(g, Granularity::Cgp);
                assert_eq!(mapper.stack_of(p, g), stack, "block {}", b.block_id);
            }
        }
    }
}
