//! The NDP system simulator: the substrate standing in for the paper's
//! SST + MacSim + DRAMSim2 stack (DESIGN.md §2 documents the substitution).
//!
//! Discrete-event, bandwidth/latency/queuing-accurate at the granularity
//! the paper's conclusions live at: every memory access is routed through
//! the TLB, the dual-mode address mapping, and either the local crossbar +
//! HBM of its SM's stack or the remote ports + the owning stack's HBM.
//! Links and DRAM channels are busy-until servers, so hotspots queue.
//!
//! Thread-blocks issue their access streams in windows of `mlp_per_block`
//! outstanding requests, with `compute_cycles_per_access` of execution
//! charged per access — an SM-throughput model rather than a pipeline
//! model. Blocks occupy SM residency slots; when one retires, the
//! scheduler's policy picks the next (this is where Eq 1 bites).
//!
//! The event-loop physics live in the shared [`crate::engine`]; this
//! module is the single-kernel adapter: it wires a [`Scheduler`] up as
//! the engine's block source and shapes the raw counters into a
//! [`RunReport`]. `tests/differential` locks in that this path is
//! cycle-identical to the pre-refactor standalone loop. Since the
//! experiment-API redesign, [`crate::session`] drives this adapter for
//! every kernel-dispatch [`crate::spec::ExperimentSpec`].

use crate::addr::VirtualAddress;
use crate::config::SystemConfig;
use crate::engine::{AppCtx, BlockRef, BlockSource, Engine, EngineOptions};
use crate::gpu::{Sm, Topology};
use crate::sched::{Policy, Scheduler};
use crate::stats::RunReport;
use crate::trace::KernelTrace;
use crate::vm::VirtualMemory;

/// One simulated kernel execution.
pub struct KernelRun<'a> {
    pub cfg: &'a SystemConfig,
    pub trace: &'a KernelTrace,
    pub vm: &'a mut VirtualMemory,
    /// Base virtual address of each object (indexed by `Access::obj`).
    pub obj_base: &'a [VirtualAddress],
    pub policy: Policy,
    /// Migrate FGP pages to the first-touching stack (migration-FTA).
    pub migrate_on_first_touch: bool,
}

/// [`BlockSource`] over a single kernel launch: the [`Scheduler`] hands
/// out `block_id`s by stack affinity; this maps them to trace indices.
struct KernelSource {
    sched: Scheduler,
    /// block_id -> index in `trace.blocks` (blocks may be listed in any
    /// order).
    id_to_idx: Vec<u32>,
}

impl BlockSource for KernelSource {
    fn seed(&mut self, topo: &Topology, place: &mut dyn FnMut(usize, usize, BlockRef)) {
        // Initial fill: breadth-first over SMs (hardware distributes blocks
        // across SMs before stacking occupancy on one).
        for slot in 0..topo.blocks_per_sm {
            for sm in &topo.sms {
                if let Some(bid) = self.sched.next_for(sm.stack) {
                    place(
                        sm.id,
                        slot,
                        BlockRef {
                            app: 0,
                            block: self.id_to_idx[bid as usize],
                        },
                    );
                }
            }
        }
    }

    fn refill(&mut self, sm: Sm, _retired: Option<BlockRef>, _now: f64) -> Option<BlockRef> {
        self.sched.next_for(sm.stack).map(|bid| BlockRef {
            app: 0,
            block: self.id_to_idx[bid as usize],
        })
    }
}

impl<'a> KernelRun<'a> {
    /// Execute the kernel and return the run report.
    pub fn run(self) -> RunReport {
        let cfg = self.cfg;
        let num_blocks = self.trace.num_blocks();
        let mut id_to_idx = vec![u32::MAX; num_blocks as usize];
        for (i, b) in self.trace.blocks.iter().enumerate() {
            id_to_idx[b.block_id as usize] = i as u32;
        }
        let mut source = KernelSource {
            sched: Scheduler::new(self.policy, num_blocks, cfg),
            id_to_idx,
        };
        let raw = Engine {
            cfg,
            apps: vec![AppCtx {
                trace: self.trace,
                obj_base: self.obj_base,
            }],
            vm: self.vm,
            opts: EngineOptions {
                l2_filter: true,
                migrate_on_first_touch: self.migrate_on_first_touch,
            },
            host: None,
        }
        .run(&mut source);
        raw.to_report(cfg, self.trace.name.clone())
    }
}

/// Convenience: map a trace's objects into a fresh [`VirtualMemory`]
/// according to a placement plan; returns (vm, per-object base vaddrs,
/// cgp_pages, fgp_pages).
pub fn map_objects(
    cfg: &SystemConfig,
    trace: &KernelTrace,
    plan: &crate::placement::PlacementPlan,
) -> crate::Result<(VirtualMemory, Vec<VirtualAddress>, u64, u64)> {
    let mut vm = VirtualMemory::new(cfg);
    let mut bases = Vec::with_capacity(trace.objects.len());
    let mut cgp_pages = 0u64;
    let mut fgp_pages = 0u64;
    for (i, obj) in trace.objects.iter().enumerate() {
        let pages = obj.bytes.div_ceil(cfg.page_size).max(1);
        // Mixed plans (page overrides) pick per page; object-level plans
        // pick once.
        let mut any_cgp = false;
        for p in 0..pages {
            if plan
                .stack_of_page(i as u16, p, cfg.page_size, cfg.num_stacks)
                .is_some()
            {
                any_cgp = true;
                break;
            }
        }
        if any_cgp {
            let base = vm.map_cgp(pages, |p| {
                plan.stack_of_page(i as u16, p, cfg.page_size, cfg.num_stacks)
                    .unwrap_or(((p) % cfg.num_stacks as u64) as usize)
            })?;
            cgp_pages += pages;
            bases.push(base);
        } else {
            let base = vm.map_fgp(pages)?;
            fgp_pages += pages;
            bases.push(base);
        }
    }
    Ok((vm, bases, cgp_pages, fgp_pages))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::addr::{AddressMapper, Granularity};
    use crate::placement::{PlacementPlan, Placement};
    use crate::sched::affinity_stack;
    use crate::trace::{Access, BlockTrace, ObjectDesc};
    use std::collections::HashMap;

    fn cfg() -> SystemConfig {
        let mut c = SystemConfig::test_small();
        c.l2_hit_rate = 0.0; // make access counts exact for assertions
        c
    }

    /// A trace where each block touches its own contiguous 4KB slice.
    fn partitioned_trace(cfg: &SystemConfig, blocks: u32) -> KernelTrace {
        let per_block = cfg.page_size;
        let t_blocks = (0..blocks)
            .map(|b| BlockTrace {
                block_id: b,
                accesses: (0..per_block / cfg.line_size)
                    .map(|i| Access {
                        obj: 0,
                        offset: b as u64 * per_block + i * cfg.line_size,
                        write: i % 4 == 0,
                    })
                    .collect(),
            })
            .collect();
        KernelTrace {
            name: "partitioned".into(),
            threads_per_block: 256,
            objects: vec![ObjectDesc {
                name: "data".into(),
                bytes: blocks as u64 * per_block,
            }],
            blocks: t_blocks,
        }
    }

    fn run(
        cfg: &SystemConfig,
        trace: &KernelTrace,
        plan: &PlacementPlan,
        policy: Policy,
    ) -> RunReport {
        let (mut vm, bases, _, _) = map_objects(cfg, trace, plan).unwrap();
        KernelRun {
            cfg,
            trace,
            vm: &mut vm,
            obj_base: &bases,
            policy,
            migrate_on_first_touch: plan.migrate_on_first_touch,
        }
        .run()
    }

    #[test]
    fn fgp_spreads_accesses_quarter_local() {
        let c = cfg();
        let t = partitioned_trace(&c, 96);
        let plan = PlacementPlan::all_fgp(1);
        let r = run(&c, &t, &plan, Policy::Baseline);
        assert_eq!(r.accesses.ndp_total(), t.total_accesses());
        let lf = r.accesses.local_fraction();
        assert!((lf - 0.25).abs() < 0.02, "local fraction {lf}");
    }

    /// The paper's core claim in miniature: affinity schedule + Eq 2/3
    /// placement eliminates remote accesses for block-exclusive data.
    #[test]
    fn coda_placement_eliminates_remote() {
        let c = cfg();
        let t = partitioned_trace(&c, 96);
        let chunk = crate::placement::eq2_chunk_size(c.page_size, &c);
        let plan = PlacementPlan {
            per_object: vec![Placement::Cgp { chunk_size: chunk }],
            page_overrides: HashMap::new(),
            migrate_on_first_touch: false,
        };
        let r = run(&c, &t, &plan, Policy::Affinity);
        assert_eq!(r.accesses.remote, 0, "all accesses must be local");
        assert_eq!(r.accesses.local, t.total_accesses());
    }

    #[test]
    fn coda_is_faster_than_fgp_baseline() {
        let c = cfg();
        let t = partitioned_trace(&c, 192);
        let fgp = run(&c, &t, &PlacementPlan::all_fgp(1), Policy::Baseline);
        let chunk = crate::placement::eq2_chunk_size(c.page_size, &c);
        let coda_plan = PlacementPlan {
            per_object: vec![Placement::Cgp { chunk_size: chunk }],
            page_overrides: HashMap::new(),
            migrate_on_first_touch: false,
        };
        let coda = run(&c, &t, &coda_plan, Policy::Affinity);
        let speedup = coda.speedup_over(&fgp);
        assert!(speedup > 1.1, "speedup {speedup}");
        assert!(coda.remote_reduction_over(&fgp) > 0.9);
    }

    #[test]
    fn migration_fta_migrates_and_localizes() {
        let c = cfg();
        let t = partitioned_trace(&c, 24); // one stack's worth
        let mut plan = PlacementPlan::all_fgp(1);
        plan.migrate_on_first_touch = true;
        let r = run(&c, &t, &plan, Policy::Affinity);
        assert_eq!(r.migrated_pages, 24, "one page per block");
        // After migration the remaining accesses in each page are local.
        assert!(r.accesses.local_fraction() > 0.9);
    }

    #[test]
    fn determinism() {
        let c = cfg();
        let t = partitioned_trace(&c, 96);
        let plan = PlacementPlan::all_fgp(1);
        let a = run(&c, &t, &plan, Policy::Baseline);
        let b = run(&c, &t, &plan, Policy::Baseline);
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.accesses, b.accesses);
    }

    #[test]
    fn bank_backend_preserves_access_counts() {
        let fixed = cfg();
        let mut bank = cfg();
        bank.mem_backend = crate::config::MemBackendKind::BankLevel;
        let t = partitioned_trace(&fixed, 96);
        let plan = PlacementPlan::all_fgp(1);
        let rf = run(&fixed, &t, &plan, Policy::Baseline);
        let rb = run(&bank, &t, &plan, Policy::Baseline);
        assert_eq!(rf.accesses, rb.accesses, "backend leaked into placement");
        assert_eq!(rf.stack_bytes, rb.stack_bytes);
        assert_eq!(rb.mem_backend, "bank");
        assert_eq!(rf.mem_backend, "fixed");
        // Timing is allowed (expected) to differ.
        assert!(rb.cycles > 0.0);
        assert!((rb.cycles - rf.cycles).abs() > 1e-9);
    }

    #[test]
    fn l2_filter_reduces_dram_traffic() {
        let mut c = cfg();
        c.l2_hit_rate = 0.5;
        let t = partitioned_trace(&c, 48);
        let r = run(&c, &t, &PlacementPlan::all_fgp(1), Policy::Baseline);
        let total = t.total_accesses();
        assert!(r.accesses.l2_hits > total / 3);
        assert_eq!(r.accesses.ndp_total() + r.accesses.l2_hits, total);
    }

    #[test]
    fn remote_bandwidth_sensitivity_shape() {
        // Lower remote bandwidth must hurt an FGP run (Fig 10's premise).
        let mut slow = cfg();
        slow.remote_bw_gbs = 4.0;
        let mut fast = cfg();
        fast.remote_bw_gbs = 256.0;
        let t = partitioned_trace(&slow, 96);
        let plan = PlacementPlan::all_fgp(1);
        let r_slow = run(&slow, &t, &plan, Policy::Baseline);
        let r_fast = run(&fast, &t, &plan, Policy::Baseline);
        assert!(
            r_slow.cycles > 1.2 * r_fast.cycles,
            "slow {} vs fast {}",
            r_slow.cycles,
            r_fast.cycles
        );
    }

    #[test]
    fn affinity_stack_consistency_with_map_objects() {
        // Under the CODA plan every block's pages live on its affinity
        // stack (checked via translation, not simulation).
        let c = cfg();
        let t = partitioned_trace(&c, 96);
        let chunk = crate::placement::eq2_chunk_size(c.page_size, &c);
        let plan = PlacementPlan {
            per_object: vec![Placement::Cgp { chunk_size: chunk }],
            page_overrides: HashMap::new(),
            migrate_on_first_touch: false,
        };
        let (vm, bases, cgp, fgp) = map_objects(&c, &t, &plan).unwrap();
        assert!(cgp > 0 && fgp == 0);
        let mapper = AddressMapper::new(&c);
        for b in &t.blocks {
            let stack = affinity_stack(b.block_id, &c);
            for a in &b.accesses {
                let (p, g) = vm.translate(bases[a.obj as usize] + a.offset).unwrap();
                assert_eq!(g, Granularity::Cgp);
                assert_eq!(mapper.stack_of(p, g), stack, "block {}", b.block_id);
            }
        }
    }
}
