//! The declarative experiment API: one serializable [`ExperimentSpec`]
//! describes *any* run the simulator can perform — a single kernel under a
//! paper mechanism, a multi-kernel mix, concurrent host + NDP traffic, a
//! host-alone sweep, or a one-key parameter sweep over all of those.
//!
//! Historically each scenario grew its own entry point (`Coordinator::run`,
//! `multiprog::run_mix` / `run_multi` / `run_hostmix`,
//! `host::run_host_sweep`), each with its own signature, CLI command and
//! report subset. The spec collapses them into one shape — in the spirit
//! of NDPage (arXiv 2502.14220): tailor the *interface* to the access
//! pattern instead of multiplying special cases — and
//! [`crate::session::Session`] lowers any spec into one shared-engine run.
//! The legacy entry points survive as thin wrappers that construct a spec;
//! `tests/spec_equiv.rs` proves each wrapper cycle-identical (bit-exact
//! f64, both DRAM backends) to its frozen pre-redesign implementation.
//!
//! # TOML schema
//!
//! Specs serialize to the project's TOML subset (`coda run <spec.toml>`;
//! tokenized by [`crate::config::parse_toml_subset`]):
//!
//! ```toml
//! [experiment]
//! name = "nn-vs-host"     # optional label, echoed in the JSON report
//! dispatch = auto          # auto | kernel | pinned | shared
//! placement = cgp          # default mix placement: fgp | cgp
//! policy = affinity        # affinity | baseline | steal
//! fairness = rr            # fcfs | rr | least (default: system mix_fairness)
//!
//! [output]
//! format = table           # table | json
//! baselines = auto         # auto | none | solo | host-split
//!
//! [system]                 # any SystemConfig key, applied in order
//! mem_backend = bank
//! stack_capacity = 134217728
//!
//! [sweep]                  # optional: rerun the spec per value of one key
//! key = remote_bw_gbs
//! values = 8,32,128
//!
//! [arrivals]               # optional: open-loop service mode (shared dispatch)
//! kind = poisson           # poisson | bursty | trace
//! rate = 0.001             # requests per cycle (poisson/bursty)
//! requests = 10000         # stop offering after this many requests, and/or:
//! duration = 5000000       # hard stop: nothing dispatches past this cycle
//! # seed = 7               # arrival RNG seed (default: system seed)
//! # burst = 4              # bursty: requests per burst
//! # interarrivals = "100,250.5"   # trace: explicit gaps in cycles, cycled
//!
//! [[kernel]]               # one table per NDP kernel
//! workload = NN            # benchmark name (see `coda help`)
//! arrival = 0              # launch time in SM cycles
//! # placement = fgp        # per-kernel override of experiment.placement
//! # mechanism = coda       # kernel dispatch only: analysis-driven placement
//! # home = 2               # home-stack override (default: index % num_stacks)
//! # after = "0"            # service mode: stage DAG edges — this kernel
//! #                        # starts when the listed kernels complete
//!
//! [host]                   # optional concurrent host stream
//! workload = KM
//! mlp = 32                 # override system host_mlp for this stream
//! passes = 2
//! ddr_fraction = 0.25
//! ```
//!
//! # Dispatch modes
//!
//! * **kernel** — the single-kernel coordinator path: the kernel's
//!   `mechanism` picks an analysis-driven per-object placement plan and the
//!   matching scheduling policy (L2 filter and first-touch migration
//!   included). Requires exactly one kernel and no host stream.
//! * **pinned** — the paper's Fig 12 shape: at most one kernel per stack,
//!   app *i*'s blocks run only on its home stack's SMs, all launched at
//!   t=0.
//! * **shared** — general multi-kernel scheduling (SM time-sharing under
//!   `policy` + `fairness`, staggered arrivals, homes wrap) plus the
//!   optional host stream; this is the CHoNDA-style co-run.
//! * **auto** (default) — `kernel` when the spec is one kernel with a
//!   `mechanism` and no host, `shared` otherwise.

use crate::config::{parse_toml_subset, TomlItem};
use crate::coordinator::Mechanism;
use crate::multiprog::MixPlacement;
use crate::sched::{FairnessPolicy, Policy};
use crate::trace::KernelTrace;
use crate::workloads::BuiltWorkload;
use anyhow::{bail, Context};
use std::fmt::Write as _;

/// A traffic source's workload. TOML specs always name a suite benchmark;
/// the legacy API wrappers pass the caller's already-built workload (or,
/// for the host sweep, a bare trace) through unchanged so lowering is
/// bit-exact with the pre-spec entry points.
#[derive(Clone, Copy, Debug)]
pub enum WorkloadSel<'a> {
    /// A suite benchmark, resolved by `workloads::suite::build` at run time.
    Named(&'static str),
    /// A caller-owned workload, used as-is (API wrappers).
    Prebuilt(&'a BuiltWorkload),
    /// A bare access trace; only valid for the host stream, which never
    /// needs block structure or IR (the `run_host_sweep` wrapper).
    Trace(&'a KernelTrace),
}

impl<'a> WorkloadSel<'a> {
    /// Resolve a user-typed benchmark name against the suite registry
    /// (errors list the known names, as `suite::build` would).
    pub fn named(name: &str) -> crate::Result<WorkloadSel<'static>> {
        Ok(WorkloadSel::Named(ExperimentSpec::suite_name(name)?))
    }

    /// The workload's display name (suite name or trace name).
    pub fn name(&self) -> &str {
        match self {
            WorkloadSel::Named(n) => n,
            WorkloadSel::Prebuilt(w) => w.name,
            WorkloadSel::Trace(t) => &t.name,
        }
    }
}

impl PartialEq for WorkloadSel<'_> {
    /// Named selectors compare by name; borrowed ones by identity (two
    /// spec clones referring to the same built workload are equal).
    fn eq(&self, other: &Self) -> bool {
        match (self, other) {
            (WorkloadSel::Named(a), WorkloadSel::Named(b)) => a == b,
            (WorkloadSel::Prebuilt(a), WorkloadSel::Prebuilt(b)) => std::ptr::eq(*a, *b),
            (WorkloadSel::Trace(a), WorkloadSel::Trace(b)) => std::ptr::eq(*a, *b),
            _ => false,
        }
    }
}

/// One NDP kernel in the experiment.
#[derive(Clone, Debug, PartialEq)]
pub struct KernelSpec<'a> {
    pub workload: WorkloadSel<'a>,
    /// Launch time in SM cycles (0 = at simulation start).
    pub arrival: f64,
    /// Mix-placement override for this kernel's objects (default:
    /// the experiment-level `placement`).
    pub placement: Option<MixPlacement>,
    /// Kernel-dispatch only: the analysis-driven mechanism.
    pub mechanism: Option<Mechanism>,
    /// Home-stack override (default: kernel index % num_stacks).
    pub home: Option<usize>,
    /// Service mode only: indices of kernels this stage waits on within
    /// each request (a per-request DAG; edges must point at earlier
    /// kernels, so the list is acyclic by construction). Empty = a root
    /// stage that starts when the request arrives.
    pub after: Vec<usize>,
}

impl<'a> KernelSpec<'a> {
    pub fn new(workload: WorkloadSel<'a>) -> Self {
        Self {
            workload,
            arrival: 0.0,
            placement: None,
            mechanism: None,
            home: None,
            after: Vec::new(),
        }
    }
}

/// The optional concurrent host request stream.
#[derive(Clone, Debug, PartialEq)]
pub struct HostSpec<'a> {
    pub workload: WorkloadSel<'a>,
    /// Override of `SystemConfig::host_mlp` for this experiment.
    pub mlp: Option<usize>,
    /// Override of `SystemConfig::host_passes`.
    pub passes: Option<u64>,
    /// Override of `SystemConfig::host_ddr_fraction`.
    pub ddr_fraction: Option<f64>,
}

impl<'a> HostSpec<'a> {
    pub fn new(workload: WorkloadSel<'a>) -> Self {
        Self {
            workload,
            mlp: None,
            passes: None,
            ddr_fraction: None,
        }
    }
}

/// The optional `[topology]` section: which stack-to-stack fabric the
/// run simulates, plus its physical knobs. Lowered onto the
/// `SystemConfig` by [`crate::session::Session`] like `[host]`
/// overrides; omitting the section (or `kind = full`) keeps the frozen
/// degenerate fabric.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TopologySpec {
    pub kind: crate::net::TopologyKind,
    /// Override of `SystemConfig::mesh_cols`.
    pub mesh_cols: Option<usize>,
    /// Override of `SystemConfig::hop_latency_ns`.
    pub hop_latency_ns: Option<f64>,
    /// Override of `SystemConfig::link_bw_gbs`.
    pub link_bw_gbs: Option<f64>,
    /// Override of `SystemConfig::net_window_cycles`.
    pub window_cycles: Option<f64>,
}

impl TopologySpec {
    pub fn new(kind: crate::net::TopologyKind) -> Self {
        Self {
            kind,
            mesh_cols: None,
            hop_latency_ns: None,
            link_bw_gbs: None,
            window_cycles: None,
        }
    }
}

/// The interarrival process of an `[arrivals]` request stream.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ArrivalKind {
    /// Exponential gaps at `rate` requests per cycle.
    #[default]
    Poisson,
    /// `burst` back-to-back requests per arrival event; events spaced so
    /// the long-run rate is still `rate`.
    Bursty,
    /// Explicit gap list (`interarrivals`), cycled when exhausted.
    Trace,
}

impl ArrivalKind {
    pub fn parse(s: &str) -> Option<Self> {
        match s.trim() {
            "poisson" => Some(Self::Poisson),
            "bursty" => Some(Self::Bursty),
            "trace" => Some(Self::Trace),
            _ => None,
        }
    }
}

impl std::fmt::Display for ArrivalKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Self::Poisson => "poisson",
            Self::Bursty => "bursty",
            Self::Trace => "trace",
        })
    }
}

/// The optional `[arrivals]` section: run the spec's kernels as an
/// open-loop request stream (service mode) instead of a fixed mix. Each
/// request instantiates every kernel once, wired by the kernels' `after`
/// edges into a per-request DAG. [`crate::session::Session`] lowers this
/// onto the engine's arrival seam; see the module docs for the schema.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ArrivalSpec {
    pub kind: ArrivalKind,
    /// Target offered rate in requests per cycle (poisson/bursty).
    pub rate: Option<f64>,
    /// Stop offering after this many requests.
    pub requests: Option<u64>,
    /// Hard stop in cycles: past it nothing new dispatches and whatever
    /// is still in flight counts as incomplete.
    pub duration: Option<f64>,
    /// Arrival RNG seed (default: the system config's `seed`).
    pub seed: Option<u64>,
    /// Bursty: requests per burst (default 4).
    pub burst: Option<u64>,
    /// Trace: explicit interarrival gaps in cycles, cycled when exhausted.
    pub interarrivals: Vec<f64>,
}

/// How the session turns kernels into engine block dispatch (see the
/// module docs for the three concrete modes).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Dispatch {
    #[default]
    Auto,
    Kernel,
    Pinned,
    Shared,
}

impl Dispatch {
    pub fn parse(s: &str) -> Option<Self> {
        match s.trim() {
            "auto" => Some(Self::Auto),
            "kernel" => Some(Self::Kernel),
            "pinned" => Some(Self::Pinned),
            "shared" => Some(Self::Shared),
            _ => None,
        }
    }
}

impl std::fmt::Display for Dispatch {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Self::Auto => "auto",
            Self::Kernel => "kernel",
            Self::Pinned => "pinned",
            Self::Shared => "shared",
        })
    }
}

/// Which run-alone baselines the session executes to derive slowdowns.
/// Baseline runs cost extra simulations; batch sweeps can turn them off.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Baselines {
    /// Shared dispatch: `HostSplit` when a host stream is declared,
    /// `Solo` otherwise. Kernel/pinned dispatch run no baselines, so
    /// `auto` resolves to `None` there (and an explicit `solo` /
    /// `host-split` is rejected rather than silently dropped).
    #[default]
    Auto,
    /// No baseline runs: slowdown fields stay unset.
    None,
    /// Per-app solo runs (each kernel alone on the shared layout) — the
    /// `run_multi` semantics isolating app-vs-app interference.
    Solo,
    /// Each side vs itself alone (NDP mix without host, host without NDP)
    /// — the `run_hostmix` semantics isolating host interference.
    HostSplit,
}

impl Baselines {
    pub fn parse(s: &str) -> Option<Self> {
        match s.trim() {
            "auto" => Some(Self::Auto),
            "none" => Some(Self::None),
            "solo" => Some(Self::Solo),
            "host-split" | "host_split" => Some(Self::HostSplit),
            _ => None,
        }
    }
}

impl std::fmt::Display for Baselines {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Self::Auto => "auto",
            Self::None => "none",
            Self::Solo => "solo",
            Self::HostSplit => "host-split",
        })
    }
}

/// Report rendering the spec asks the CLI for (`--json` still wins).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum OutputFormat {
    #[default]
    Table,
    Json,
}

impl OutputFormat {
    pub fn parse(s: &str) -> Option<Self> {
        match s.trim() {
            "table" => Some(Self::Table),
            "json" => Some(Self::Json),
            _ => None,
        }
    }
}

impl std::fmt::Display for OutputFormat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Self::Table => "table",
            Self::Json => "json",
        })
    }
}

/// Requested outputs: rendering format and baseline policy.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct OutputSpec {
    pub format: OutputFormat,
    pub baselines: Baselines,
}

/// A one-key parameter sweep: the spec is rerun once per value with
/// `key = value` appended to its `[system]` overrides (what
/// `coda sweep` always did, now batchable from a file).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SweepSpec {
    pub key: String,
    pub values: Vec<String>,
}

/// The declarative experiment description. See the module docs for the
/// TOML schema and dispatch semantics; [`crate::session::Session`] is the
/// only consumer.
#[derive(Clone, Debug, PartialEq)]
pub struct ExperimentSpec<'a> {
    /// Optional label echoed in the report (`"spec"` in JSON).
    pub name: Option<String>,
    pub dispatch: Dispatch,
    /// Default mix placement for kernels without an override.
    pub placement: MixPlacement,
    /// Block-level scheduling policy (pinned/shared dispatch).
    pub policy: Policy,
    /// Inter-app fairness (default: the system config's `mix_fairness`).
    pub fairness: Option<FairnessPolicy>,
    /// `[system]` config overrides, applied in order over the base config.
    pub overrides: Vec<(String, String)>,
    pub kernels: Vec<KernelSpec<'a>>,
    pub host: Option<HostSpec<'a>>,
    /// Optional stack-to-stack fabric selection (`[topology]`).
    pub topology: Option<TopologySpec>,
    /// Optional open-loop request stream (`[arrivals]`): service mode.
    pub arrivals: Option<ArrivalSpec>,
    pub sweep: Option<SweepSpec>,
    pub output: OutputSpec,
}

impl Default for ExperimentSpec<'_> {
    fn default() -> Self {
        Self {
            name: None,
            dispatch: Dispatch::Auto,
            placement: MixPlacement::CgpLocal,
            policy: Policy::Affinity,
            fairness: None,
            overrides: Vec::new(),
            kernels: Vec::new(),
            host: None,
            topology: None,
            arrivals: None,
            sweep: None,
            output: OutputSpec::default(),
        }
    }
}

impl<'a> ExperimentSpec<'a> {
    /// Single-kernel coordinator run: `wl` under `mech` (what
    /// `Coordinator::run` / `coda run <BENCH>` launch).
    pub fn kernel(workload: WorkloadSel<'a>, mech: Mechanism) -> Self {
        let mut k = KernelSpec::new(workload);
        k.mechanism = Some(mech);
        Self {
            dispatch: Dispatch::Kernel,
            kernels: vec![k],
            ..Self::default()
        }
    }

    /// Fig-12 pinned mix: one kernel per stack, all at t=0 (the
    /// `multiprog::run_mix` shape).
    pub fn pinned(workloads: Vec<WorkloadSel<'a>>, placement: MixPlacement) -> Self {
        Self {
            dispatch: Dispatch::Pinned,
            placement,
            kernels: workloads.into_iter().map(KernelSpec::new).collect(),
            ..Self::default()
        }
    }

    /// Multi-kernel mix with time-shared SMs (the `multiprog::run_multi`
    /// shape): `launches` pairs each workload with its arrival cycle.
    pub fn shared(
        launches: Vec<(WorkloadSel<'a>, f64)>,
        placement: MixPlacement,
        policy: Policy,
        fairness: FairnessPolicy,
    ) -> Self {
        Self {
            dispatch: Dispatch::Shared,
            placement,
            policy,
            fairness: Some(fairness),
            kernels: launches
                .into_iter()
                .map(|(w, arrival)| {
                    let mut k = KernelSpec::new(w);
                    k.arrival = arrival;
                    k
                })
                .collect(),
            output: OutputSpec {
                baselines: Baselines::Solo,
                ..OutputSpec::default()
            },
            ..Self::default()
        }
    }

    /// CHoNDA-style co-run (the `multiprog::run_hostmix` shape): the NDP
    /// mix of [`Self::shared`] plus a concurrent host stream (which may be
    /// the only source).
    pub fn hostmix(
        launches: Vec<(WorkloadSel<'a>, f64)>,
        host: Option<WorkloadSel<'a>>,
        placement: MixPlacement,
        policy: Policy,
        fairness: FairnessPolicy,
    ) -> Self {
        let mut spec = Self::shared(launches, placement, policy, fairness);
        spec.host = host.map(HostSpec::new);
        spec.output.baselines = Baselines::HostSplit;
        spec
    }

    /// Host-alone sweep over a trace's objects (the `host::run_host_sweep`
    /// shape).
    pub fn host_sweep(trace: &'a KernelTrace) -> Self {
        let mut spec = Self::default();
        spec.dispatch = Dispatch::Shared;
        spec.host = Some(HostSpec::new(WorkloadSel::Trace(trace)));
        spec.output.baselines = Baselines::HostSplit;
        spec
    }

    /// Resolve a suite benchmark name to its `'static` spelling, so TOML
    /// specs share [`WorkloadSel::Named`] with the builders.
    fn suite_name(name: &str) -> crate::Result<&'static str> {
        crate::workloads::suite::ALL
            .iter()
            .map(|(n, _)| *n)
            .find(|n| *n == name.trim())
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown benchmark {name}; known: {:?}",
                    crate::workloads::suite::names()
                )
            })
    }

    /// Parse a spec from TOML-subset text (see the module docs for the
    /// schema). Unknown sections and keys are hard errors: a typo must not
    /// silently change an experiment.
    pub fn from_toml_str(text: &str) -> crate::Result<ExperimentSpec<'static>> {
        let doc = parse_toml_subset(text)?;
        // Header counts come from the tokenizer, independent of the
        // assignments: a `[[kernel]]` or `[host]` table with no keys
        // (e.g. a truncated file) must still fail the required-key
        // checks below instead of silently shrinking the experiment.
        let kernel_headers = doc.section_count("kernel");
        let host_headers = doc.section_count("host");
        anyhow::ensure!(host_headers <= 1, "at most one [host] section");
        let topology_headers = doc.section_count("topology");
        anyhow::ensure!(topology_headers <= 1, "at most one [topology] section");
        let arrivals_headers = doc.section_count("arrivals");
        anyhow::ensure!(arrivals_headers <= 1, "at most one [arrivals] section");
        let items = doc.items;
        let mut spec = ExperimentSpec::default();
        // Kernels accumulate per [[kernel]] instance; the workload key is
        // mandatory, so build through options and finalize below.
        let mut kernels: Vec<(Option<&'static str>, KernelSpec<'static>)> = Vec::new();
        let mut host: Option<HostSpec<'static>> = None;
        let mut host_name: Option<&'static str> = None;
        let mut topology: Option<TopologySpec> = None;
        let mut topology_kind: Option<crate::net::TopologyKind> = None;
        let mut arrivals: Option<ArrivalSpec> = None;
        let mut arrivals_kind: Option<ArrivalKind> = None;
        let mut sweep_key: Option<String> = None;
        let mut sweep_values: Option<Vec<String>> = None;
        for item in &items {
            let TomlItem {
                lineno,
                section,
                instance,
                key,
                value,
            } = item;
            let ctx = || format!("line {lineno}: [{section}] {key}");
            match section.as_str() {
                "experiment" => match key.as_str() {
                    "name" => spec.name = Some(value.clone()),
                    "dispatch" => {
                        spec.dispatch = Dispatch::parse(value).ok_or_else(|| {
                            anyhow::anyhow!(
                                "{}: expected auto|kernel|pinned|shared, got {value}",
                                ctx()
                            )
                        })?
                    }
                    "placement" => {
                        spec.placement = MixPlacement::parse(value).ok_or_else(|| {
                            anyhow::anyhow!("{}: expected fgp|cgp, got {value}", ctx())
                        })?
                    }
                    "policy" => {
                        spec.policy = Policy::parse(value).ok_or_else(|| {
                            anyhow::anyhow!(
                                "{}: expected affinity|baseline|steal, got {value}",
                                ctx()
                            )
                        })?
                    }
                    "fairness" => {
                        spec.fairness =
                            Some(FairnessPolicy::parse(value).ok_or_else(|| {
                                anyhow::anyhow!(
                                    "{}: expected fcfs|rr|least, got {value}",
                                    ctx()
                                )
                            })?)
                    }
                    _ => bail!("{}: unknown [experiment] key", ctx()),
                },
                "output" => match key.as_str() {
                    "format" => {
                        spec.output.format = OutputFormat::parse(value).ok_or_else(|| {
                            anyhow::anyhow!("{}: expected table|json, got {value}", ctx())
                        })?
                    }
                    "baselines" => {
                        spec.output.baselines = Baselines::parse(value).ok_or_else(|| {
                            anyhow::anyhow!(
                                "{}: expected auto|none|solo|host-split, got {value}",
                                ctx()
                            )
                        })?
                    }
                    _ => bail!("{}: unknown [output] key", ctx()),
                },
                // The system section is the flat SystemConfig namespace;
                // keys are validated when the session applies them.
                "system" => spec.overrides.push((key.clone(), value.clone())),
                "sweep" => match key.as_str() {
                    "key" => sweep_key = Some(value.clone()),
                    "values" => {
                        sweep_values = Some(
                            value
                                .split(',')
                                .map(|v| v.trim().to_string())
                                .filter(|v| !v.is_empty())
                                .collect(),
                        )
                    }
                    _ => bail!("{}: unknown [sweep] key", ctx()),
                },
                "kernel" => {
                    while kernels.len() <= *instance {
                        // Placeholder workload until the table names one.
                        kernels.push((None, KernelSpec::new(WorkloadSel::Named("PR"))));
                    }
                    let (wl, k) = &mut kernels[*instance];
                    match key.as_str() {
                        "workload" => *wl = Some(Self::suite_name(value)?),
                        "arrival" => {
                            k.arrival =
                                value.parse().with_context(|| {
                                    format!("{}: bad number {value}", ctx())
                                })?
                        }
                        "placement" => {
                            k.placement =
                                Some(MixPlacement::parse(value).ok_or_else(|| {
                                    anyhow::anyhow!(
                                        "{}: expected fgp|cgp, got {value}",
                                        ctx()
                                    )
                                })?)
                        }
                        "mechanism" => {
                            k.mechanism = Some(Mechanism::parse(value).ok_or_else(|| {
                                anyhow::anyhow!("{}: unknown mechanism {value}", ctx())
                            })?)
                        }
                        "home" => {
                            k.home = Some(value.parse().with_context(|| {
                                format!("{}: bad stack index {value}", ctx())
                            })?)
                        }
                        "after" => {
                            k.after = value
                                .split(',')
                                .map(|v| v.trim())
                                .filter(|v| !v.is_empty())
                                .map(|v| {
                                    v.parse().with_context(|| {
                                        format!("{}: bad kernel index {v}", ctx())
                                    })
                                })
                                .collect::<crate::Result<_>>()?
                        }
                        _ => bail!("{}: unknown [[kernel]] key", ctx()),
                    }
                }
                "host" => {
                    anyhow::ensure!(
                        *instance == 0,
                        "line {lineno}: at most one [host] section"
                    );
                    let h = host
                        .get_or_insert_with(|| HostSpec::new(WorkloadSel::Named("PR")));
                    match key.as_str() {
                        "workload" => host_name = Some(Self::suite_name(value)?),
                        "mlp" => {
                            h.mlp = Some(value.parse().with_context(|| {
                                format!("{}: bad count {value}", ctx())
                            })?)
                        }
                        "passes" => {
                            h.passes = Some(value.parse().with_context(|| {
                                format!("{}: bad count {value}", ctx())
                            })?)
                        }
                        "ddr_fraction" => {
                            h.ddr_fraction = Some(value.parse().with_context(|| {
                                format!("{}: bad fraction {value}", ctx())
                            })?)
                        }
                        _ => bail!("{}: unknown [host] key", ctx()),
                    }
                }
                "topology" => {
                    anyhow::ensure!(
                        *instance == 0,
                        "line {lineno}: at most one [topology] section"
                    );
                    let t = topology.get_or_insert_with(|| {
                        TopologySpec::new(crate::net::TopologyKind::FullyConnected)
                    });
                    match key.as_str() {
                        "kind" => {
                            topology_kind = Some(
                                crate::net::TopologyKind::parse(value).ok_or_else(
                                    || {
                                        anyhow::anyhow!(
                                            "{}: expected full|line|ring|mesh, got \
                                             {value}",
                                            ctx()
                                        )
                                    },
                                )?,
                            )
                        }
                        "mesh_cols" => {
                            t.mesh_cols = Some(value.parse().with_context(|| {
                                format!("{}: bad count {value}", ctx())
                            })?)
                        }
                        "hop_latency_ns" => {
                            t.hop_latency_ns = Some(value.parse().with_context(|| {
                                format!("{}: bad number {value}", ctx())
                            })?)
                        }
                        "link_bw_gbs" => {
                            t.link_bw_gbs = Some(value.parse().with_context(|| {
                                format!("{}: bad number {value}", ctx())
                            })?)
                        }
                        "window_cycles" => {
                            t.window_cycles = Some(value.parse().with_context(|| {
                                format!("{}: bad number {value}", ctx())
                            })?)
                        }
                        _ => bail!("{}: unknown [topology] key", ctx()),
                    }
                }
                "arrivals" => {
                    anyhow::ensure!(
                        *instance == 0,
                        "line {lineno}: at most one [arrivals] section"
                    );
                    let a = arrivals.get_or_insert_with(ArrivalSpec::default);
                    match key.as_str() {
                        "kind" => {
                            arrivals_kind =
                                Some(ArrivalKind::parse(value).ok_or_else(|| {
                                    anyhow::anyhow!(
                                        "{}: expected poisson|bursty|trace, got {value}",
                                        ctx()
                                    )
                                })?)
                        }
                        "rate" => {
                            a.rate = Some(value.parse().with_context(|| {
                                format!("{}: bad number {value}", ctx())
                            })?)
                        }
                        "requests" => {
                            a.requests = Some(value.parse().with_context(|| {
                                format!("{}: bad count {value}", ctx())
                            })?)
                        }
                        "duration" => {
                            a.duration = Some(value.parse().with_context(|| {
                                format!("{}: bad number {value}", ctx())
                            })?)
                        }
                        "seed" => {
                            a.seed = Some(value.parse().with_context(|| {
                                format!("{}: bad seed {value}", ctx())
                            })?)
                        }
                        "burst" => {
                            a.burst = Some(value.parse().with_context(|| {
                                format!("{}: bad count {value}", ctx())
                            })?)
                        }
                        "interarrivals" => {
                            a.interarrivals = value
                                .split(',')
                                .map(|v| v.trim())
                                .filter(|v| !v.is_empty())
                                .map(|v| {
                                    v.parse().with_context(|| {
                                        format!("{}: bad number {v}", ctx())
                                    })
                                })
                                .collect::<crate::Result<_>>()?
                        }
                        _ => bail!("{}: unknown [arrivals] key", ctx()),
                    }
                }
                "" => bail!(
                    "line {lineno}: key {key} outside a section (expected \
                     [experiment], [output], [system], [sweep], [topology], \
                     [arrivals], [[kernel]] or [host])"
                ),
                other => bail!("line {lineno}: unknown section [{other}]"),
            }
        }
        while kernels.len() < kernel_headers {
            // Key-less trailing tables: surface the missing-workload error.
            kernels.push((None, KernelSpec::new(WorkloadSel::Named("PR"))));
        }
        spec.kernels = kernels
            .into_iter()
            .enumerate()
            .map(|(i, (wl, mut k))| {
                let name =
                    wl.ok_or_else(|| anyhow::anyhow!("[[kernel]] #{i} missing workload"))?;
                k.workload = WorkloadSel::Named(name);
                Ok(k)
            })
            .collect::<crate::Result<_>>()?;
        if host_headers > 0 && host.is_none() {
            host = Some(HostSpec::new(WorkloadSel::Named("PR")));
        }
        if let Some(mut h) = host {
            let name = host_name
                .ok_or_else(|| anyhow::anyhow!("[host] section missing workload"))?;
            h.workload = WorkloadSel::Named(name);
            spec.host = Some(h);
        }
        if topology_headers > 0 && topology.is_none() {
            // Key-less [topology] table: surface the missing-kind error.
            topology = Some(TopologySpec::new(crate::net::TopologyKind::FullyConnected));
        }
        if let Some(mut t) = topology {
            t.kind = topology_kind
                .ok_or_else(|| anyhow::anyhow!("[topology] section missing kind"))?;
            spec.topology = Some(t);
        }
        if arrivals_headers > 0 && arrivals.is_none() {
            // Key-less [arrivals] table: surface the missing-kind error.
            arrivals = Some(ArrivalSpec::default());
        }
        if let Some(mut a) = arrivals {
            a.kind = arrivals_kind
                .ok_or_else(|| anyhow::anyhow!("[arrivals] section missing kind"))?;
            spec.arrivals = Some(a);
        }
        spec.sweep = match (sweep_key, sweep_values) {
            (None, None) => None,
            (Some(key), Some(values)) if !values.is_empty() => {
                Some(SweepSpec { key, values })
            }
            _ => bail!("[sweep] needs both key and a non-empty values list"),
        };
        Ok(spec)
    }

    /// Load a spec file.
    pub fn from_file(path: &str) -> crate::Result<ExperimentSpec<'static>> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading spec {path}"))?;
        Self::from_toml_str(&text).with_context(|| format!("parsing spec {path}"))
    }

    /// Serialize to TOML-subset text. Round-trips through
    /// [`Self::from_toml_str`] for specs whose workloads are
    /// [`WorkloadSel::Named`]; borrowed workloads serialize by name (the
    /// reparsed spec resolves them through the suite). The subset has no
    /// escape syntax, so free-text fields (`name`, override values) must
    /// not contain double quotes — the tokenizer rejects them at reparse
    /// rather than silently corrupting the value.
    pub fn to_toml_string(&self) -> String {
        let mut out = String::from("# CODA experiment spec\n[experiment]\n");
        if let Some(name) = &self.name {
            let _ = writeln!(out, "name = \"{name}\"");
        }
        let _ = writeln!(out, "dispatch = {}", self.dispatch);
        let _ = writeln!(out, "placement = {}", self.placement);
        let _ = writeln!(out, "policy = {}", self.policy);
        if let Some(f) = self.fairness {
            let _ = writeln!(out, "fairness = {f}");
        }
        out.push_str("\n[output]\n");
        let _ = writeln!(out, "format = {}", self.output.format);
        let _ = writeln!(out, "baselines = {}", self.output.baselines);
        if !self.overrides.is_empty() {
            out.push_str("\n[system]\n");
            for (k, v) in &self.overrides {
                let _ = writeln!(out, "{k} = {v}");
            }
        }
        if let Some(sw) = &self.sweep {
            out.push_str("\n[sweep]\n");
            let _ = writeln!(out, "key = {}", sw.key);
            let _ = writeln!(out, "values = \"{}\"", sw.values.join(","));
        }
        if let Some(t) = &self.topology {
            out.push_str("\n[topology]\n");
            let _ = writeln!(out, "kind = {}", t.kind);
            if let Some(c) = t.mesh_cols {
                let _ = writeln!(out, "mesh_cols = {c}");
            }
            if let Some(l) = t.hop_latency_ns {
                let _ = writeln!(out, "hop_latency_ns = {l}");
            }
            if let Some(b) = t.link_bw_gbs {
                let _ = writeln!(out, "link_bw_gbs = {b}");
            }
            if let Some(w) = t.window_cycles {
                let _ = writeln!(out, "window_cycles = {w}");
            }
        }
        if let Some(a) = &self.arrivals {
            out.push_str("\n[arrivals]\n");
            let _ = writeln!(out, "kind = {}", a.kind);
            if let Some(r) = a.rate {
                let _ = writeln!(out, "rate = {r}");
            }
            if let Some(n) = a.requests {
                let _ = writeln!(out, "requests = {n}");
            }
            if let Some(d) = a.duration {
                let _ = writeln!(out, "duration = {d}");
            }
            if let Some(s) = a.seed {
                let _ = writeln!(out, "seed = {s}");
            }
            if let Some(b) = a.burst {
                let _ = writeln!(out, "burst = {b}");
            }
            if !a.interarrivals.is_empty() {
                let gaps: Vec<String> =
                    a.interarrivals.iter().map(|g| g.to_string()).collect();
                let _ = writeln!(out, "interarrivals = \"{}\"", gaps.join(","));
            }
        }
        for k in &self.kernels {
            out.push_str("\n[[kernel]]\n");
            let _ = writeln!(out, "workload = {}", k.workload.name());
            let _ = writeln!(out, "arrival = {}", k.arrival);
            if let Some(p) = k.placement {
                let _ = writeln!(out, "placement = {p}");
            }
            if let Some(m) = k.mechanism {
                let _ = writeln!(out, "mechanism = {}", m.key());
            }
            if let Some(h) = k.home {
                let _ = writeln!(out, "home = {h}");
            }
            if !k.after.is_empty() {
                let deps: Vec<String> = k.after.iter().map(|d| d.to_string()).collect();
                let _ = writeln!(out, "after = \"{}\"", deps.join(","));
            }
        }
        if let Some(h) = &self.host {
            out.push_str("\n[host]\n");
            let _ = writeln!(out, "workload = {}", h.workload.name());
            if let Some(m) = h.mlp {
                let _ = writeln!(out, "mlp = {m}");
            }
            if let Some(p) = h.passes {
                let _ = writeln!(out, "passes = {p}");
            }
            if let Some(f) = h.ddr_fraction {
                let _ = writeln!(out, "ddr_fraction = {f}");
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_a_full_spec() {
        let text = r#"
[experiment]
name = "demo"
dispatch = shared
placement = fgp
policy = steal
fairness = least

[output]
format = json
baselines = none

[system]
mem_backend = bank
num_stacks = 8

[sweep]
key = remote_bw_gbs
values = 8, 32

[topology]
kind = ring
hop_latency_ns = 20
window_cycles = 4096

[[kernel]]
workload = NN
arrival = 1000
placement = cgp
home = 3

[[kernel]]
workload = KM

[host]
workload = DC
mlp = 16
passes = 2
ddr_fraction = 0.5
"#;
        let s = ExperimentSpec::from_toml_str(text).unwrap();
        assert_eq!(s.name.as_deref(), Some("demo"));
        assert_eq!(s.dispatch, Dispatch::Shared);
        assert_eq!(s.placement, MixPlacement::FgpOnly);
        assert_eq!(s.policy, Policy::AffinityStealing);
        assert_eq!(s.fairness, Some(FairnessPolicy::LeastIssued));
        assert_eq!(s.output.format, OutputFormat::Json);
        assert_eq!(s.output.baselines, Baselines::None);
        assert_eq!(
            s.overrides,
            vec![
                ("mem_backend".into(), "bank".into()),
                ("num_stacks".into(), "8".into())
            ]
        );
        assert_eq!(
            s.sweep,
            Some(SweepSpec {
                key: "remote_bw_gbs".into(),
                values: vec!["8".into(), "32".into()]
            })
        );
        assert_eq!(s.kernels.len(), 2);
        assert_eq!(s.kernels[0].workload.name(), "NN");
        assert_eq!(s.kernels[0].arrival, 1000.0);
        assert_eq!(s.kernels[0].placement, Some(MixPlacement::CgpLocal));
        assert_eq!(s.kernels[0].home, Some(3));
        assert_eq!(s.kernels[1].workload.name(), "KM");
        assert_eq!(s.kernels[1].arrival, 0.0);
        let h = s.host.as_ref().unwrap();
        assert_eq!(h.workload.name(), "DC");
        assert_eq!(h.mlp, Some(16));
        assert_eq!(h.passes, Some(2));
        assert_eq!(h.ddr_fraction, Some(0.5));
        let t = s.topology.as_ref().unwrap();
        assert_eq!(t.kind, crate::net::TopologyKind::Ring);
        assert_eq!(t.mesh_cols, None);
        assert_eq!(t.hop_latency_ns, Some(20.0));
        assert_eq!(t.link_bw_gbs, None);
        assert_eq!(t.window_cycles, Some(4096.0));
    }

    #[test]
    fn rejects_malformed_specs() {
        // Unknown section / key / values must be hard errors.
        assert!(ExperimentSpec::from_toml_str("[nope]\nx = 1\n").is_err());
        assert!(ExperimentSpec::from_toml_str("[experiment]\nnope = 1\n").is_err());
        assert!(ExperimentSpec::from_toml_str("top = 1\n").is_err());
        assert!(ExperimentSpec::from_toml_str("[experiment]\ndispatch = warp\n").is_err());
        assert!(ExperimentSpec::from_toml_str("[[kernel]]\narrival = 5\n").is_err());
        assert!(ExperimentSpec::from_toml_str("[[kernel]]\nworkload = NOPE\n").is_err());
        assert!(ExperimentSpec::from_toml_str("[host]\nmlp = 4\n").is_err());
        assert!(ExperimentSpec::from_toml_str("[sweep]\nkey = seed\n").is_err());
        assert!(
            ExperimentSpec::from_toml_str("[host]\nworkload = NN\n[host]\nworkload = KM\n")
                .is_err()
        );
        // [topology] needs a valid kind and known keys, at most once.
        assert!(ExperimentSpec::from_toml_str("[topology]\nkind = torus\n").is_err());
        assert!(ExperimentSpec::from_toml_str("[topology]\nmesh_cols = 2\n").is_err());
        assert!(ExperimentSpec::from_toml_str("[topology]\nkind = ring\nnope = 1\n").is_err());
        assert!(
            ExperimentSpec::from_toml_str("[topology]\nkind = ring\n[topology]\nkind = line\n")
                .is_err()
        );
        // [arrivals] needs a valid kind and known keys, at most once.
        assert!(ExperimentSpec::from_toml_str("[arrivals]\nkind = uniform\n").is_err());
        assert!(ExperimentSpec::from_toml_str("[arrivals]\nrate = 0.1\n").is_err());
        assert!(
            ExperimentSpec::from_toml_str("[arrivals]\nkind = poisson\nnope = 1\n").is_err()
        );
        assert!(ExperimentSpec::from_toml_str(
            "[arrivals]\nkind = poisson\n[arrivals]\nkind = trace\n"
        )
        .is_err());
        assert!(ExperimentSpec::from_toml_str(
            "[arrivals]\nkind = trace\ninterarrivals = \"10,x\"\n"
        )
        .is_err());
        assert!(
            ExperimentSpec::from_toml_str("[[kernel]]\nworkload = NN\nafter = \"z\"\n")
                .is_err()
        );
    }

    #[test]
    fn keyless_trailing_tables_are_errors_not_dropped() {
        // A truncated spec must fail loudly, not shrink the experiment.
        assert!(ExperimentSpec::from_toml_str("[[kernel]]\n").is_err());
        assert!(
            ExperimentSpec::from_toml_str("[[kernel]]\nworkload = NN\n[[kernel]]\n")
                .is_err()
        );
        assert!(ExperimentSpec::from_toml_str("[host]\n").is_err());
        assert!(ExperimentSpec::from_toml_str("[host]\n[host]\n").is_err());
        assert!(ExperimentSpec::from_toml_str("[topology]\n").is_err());
        assert!(ExperimentSpec::from_toml_str("[arrivals]\n").is_err());
    }

    #[test]
    fn parses_and_round_trips_arrivals() {
        let text = "\
[arrivals]
kind = bursty
rate = 0.05
requests = 1000
duration = 250000.5
seed = 9
burst = 4

[[kernel]]
workload = NN

[[kernel]]
workload = KM
after = \"0\"
";
        let s = ExperimentSpec::from_toml_str(text).unwrap();
        let a = s.arrivals.as_ref().unwrap();
        assert_eq!(a.kind, ArrivalKind::Bursty);
        assert_eq!(a.rate, Some(0.05));
        assert_eq!(a.requests, Some(1000));
        assert_eq!(a.duration, Some(250000.5));
        assert_eq!(a.seed, Some(9));
        assert_eq!(a.burst, Some(4));
        assert!(a.interarrivals.is_empty());
        assert_eq!(s.kernels[0].after, Vec::<usize>::new());
        assert_eq!(s.kernels[1].after, vec![0]);
        let reparsed = ExperimentSpec::from_toml_str(&s.to_toml_string()).unwrap();
        assert_eq!(reparsed, s);
        // Trace kind carries fractional gaps through the quoted list.
        let text = "[arrivals]\nkind = trace\ninterarrivals = \"100, 2.5, 30\"\n";
        let s = ExperimentSpec::from_toml_str(text).unwrap();
        assert_eq!(s.arrivals.as_ref().unwrap().interarrivals, vec![100.0, 2.5, 30.0]);
        let reparsed = ExperimentSpec::from_toml_str(&s.to_toml_string()).unwrap();
        assert_eq!(reparsed, s);
    }

    #[test]
    fn hash_inside_quoted_values_survives_round_trip() {
        let mut spec = ExperimentSpec::kernel(WorkloadSel::Named("NN"), Mechanism::Coda);
        spec.name = Some("a#b".into());
        let reparsed = ExperimentSpec::from_toml_str(&spec.to_toml_string()).unwrap();
        assert_eq!(reparsed.name.as_deref(), Some("a#b"));
        assert_eq!(reparsed, spec);
    }

    #[test]
    fn builders_shape_legacy_scenarios() {
        let k = ExperimentSpec::kernel(WorkloadSel::Named("PR"), Mechanism::Coda);
        assert_eq!(k.dispatch, Dispatch::Kernel);
        assert_eq!(k.kernels[0].mechanism, Some(Mechanism::Coda));
        let p = ExperimentSpec::pinned(
            vec![WorkloadSel::Named("NN"), WorkloadSel::Named("KM")],
            MixPlacement::FgpOnly,
        );
        assert_eq!(p.dispatch, Dispatch::Pinned);
        assert_eq!(p.kernels.len(), 2);
        let s = ExperimentSpec::shared(
            vec![(WorkloadSel::Named("NN"), 0.0), (WorkloadSel::Named("KM"), 5e3)],
            MixPlacement::CgpLocal,
            Policy::Affinity,
            FairnessPolicy::RoundRobin,
        );
        assert_eq!(s.output.baselines, Baselines::Solo);
        assert_eq!(s.kernels[1].arrival, 5e3);
        let h = ExperimentSpec::hostmix(
            vec![(WorkloadSel::Named("NN"), 0.0)],
            Some(WorkloadSel::Named("KM")),
            MixPlacement::CgpLocal,
            Policy::Affinity,
            FairnessPolicy::Fcfs,
        );
        assert_eq!(h.output.baselines, Baselines::HostSplit);
        assert_eq!(h.host.as_ref().unwrap().workload.name(), "KM");
    }

    #[test]
    fn toml_round_trip_preserves_named_specs() {
        let mut spec = ExperimentSpec::hostmix(
            vec![(WorkloadSel::Named("NN"), 0.0), (WorkloadSel::Named("KM"), 2500.0)],
            Some(WorkloadSel::Named("DC")),
            MixPlacement::FgpOnly,
            Policy::AffinityStealing,
            FairnessPolicy::RoundRobin,
        );
        spec.name = Some("rt".into());
        spec.overrides.push(("mem_backend".into(), "bank".into()));
        spec.sweep = Some(SweepSpec {
            key: "host_mlp".into(),
            values: vec!["8".into(), "64".into()],
        });
        spec.kernels[0].home = Some(1);
        spec.kernels[1].placement = Some(MixPlacement::CgpLocal);
        spec.host.as_mut().unwrap().passes = Some(3);
        spec.topology = Some(TopologySpec {
            kind: crate::net::TopologyKind::Mesh2d,
            mesh_cols: Some(2),
            hop_latency_ns: Some(15.0),
            link_bw_gbs: Some(48.0),
            window_cycles: Some(2048.0),
        });
        let reparsed = ExperimentSpec::from_toml_str(&spec.to_toml_string()).unwrap();
        assert_eq!(reparsed, spec);
    }
}
