//! Run statistics: the access counters behind Fig 9, cycle accounting
//! behind Fig 8/10/11/12/13/14, and small numeric helpers (geomean,
//! speedup) used by every bench harness.

/// Where simulated accesses were served.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct AccessStats {
    /// Served by the accessing SM's own stack.
    pub local: u64,
    /// Served by another stack over the Remote network.
    pub remote: u64,
    /// Issued by the host over the Host network.
    pub host: u64,
    /// Host accesses served by host-local DDR instead of the stacks
    /// (CHoNDA-style host memory; see `SystemConfig::host_ddr_fraction`).
    pub host_ddr: u64,
    /// Absorbed by the stack-level L2 before reaching DRAM.
    pub l2_hits: u64,
}

impl AccessStats {
    pub fn ndp_total(&self) -> u64 {
        self.local + self.remote
    }

    /// Fraction of NDP accesses that were remote (the Fig 9 metric).
    pub fn remote_fraction(&self) -> f64 {
        let t = self.ndp_total();
        if t == 0 {
            0.0
        } else {
            self.remote as f64 / t as f64
        }
    }

    pub fn local_fraction(&self) -> f64 {
        let t = self.ndp_total();
        if t == 0 {
            0.0
        } else {
            self.local as f64 / t as f64
        }
    }

    pub fn add(&mut self, other: &AccessStats) {
        self.local += other.local;
        self.remote += other.remote;
        self.host += other.host;
        self.host_ddr += other.host_ddr;
        self.l2_hits += other.l2_hits;
    }

    /// Host accesses issued, regardless of where they were served.
    pub fn host_total(&self) -> u64 {
        self.host + self.host_ddr
    }
}

/// Counters of one directed fabric link (multi-hop topologies only; the
/// degenerate fully-connected fabric reports none so its output stays
/// frozen). `from`/`to` are stack ids.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LinkStat {
    pub from: usize,
    pub to: usize,
    /// Bytes that crossed the link.
    pub bytes: u64,
    /// Transfers that found the link busy and queued.
    pub stalls: u64,
    /// Bytes of the link's busiest observation window (peak throughput
    /// = `peak_window_bytes / net_window_cycles`; averages understate
    /// bursty hotspots).
    pub peak_window_bytes: u64,
}

/// The result of simulating one workload under one mechanism.
#[derive(Clone, Debug, Default)]
pub struct RunReport {
    pub workload: String,
    pub mechanism: String,
    /// Simulated execution time in SM cycles.
    pub cycles: f64,
    pub accesses: AccessStats,
    /// Bytes served by each stack's DRAM (hotspot analysis).
    pub stack_bytes: Vec<u64>,
    /// Bytes crossing remote links.
    pub remote_bytes: u64,
    /// Mean memory access latency (cycles).
    pub mean_mem_latency: f64,
    /// TLB hit rate across all SMs.
    pub tlb_hit_rate: f64,
    /// DRAM row-buffer hit rate across stacks.
    pub row_hit_rate: f64,
    /// DRAM timing backend that produced the run ("fixed" / "bank").
    pub mem_backend: String,
    /// Row-buffer conflicts across stacks (bank-level backend; 0 for fixed).
    pub bank_conflicts: u64,
    /// Accesses delayed by DRAM refresh windows (bank-level backend).
    pub refresh_stalls: u64,
    /// Row-buffer hits across stacks (cycle backend; 0 otherwise).
    pub dram_row_hits: u64,
    /// Row-buffer misses (ACT into a closed row) across stacks (cycle
    /// backend; 0 otherwise).
    pub dram_row_misses: u64,
    /// ACT commands issued across stacks (cycle backend; 0 otherwise).
    pub dram_acts: u64,
    /// PRE commands issued, explicit + auto (cycle backend; 0 otherwise).
    pub dram_precharges: u64,
    /// Accesses stalled by a forced write-queue drain at the high
    /// watermark (cycle backend; 0 otherwise).
    pub dram_wq_stalls: u64,
    /// ACT commands delayed by the four-activate window (cycle backend;
    /// 0 otherwise).
    pub dram_faw_stalls: u64,
    /// Pages the mechanism placed coarse-grain.
    pub cgp_pages: u64,
    /// Pages the mechanism placed fine-grain.
    pub fgp_pages: u64,
    /// Pages migrated (migration-based baselines only).
    pub migrated_pages: u64,
    /// Multiprogrammed runs: per-app completion/response cycles.
    pub app_cycles: Vec<f64>,
    /// Multi-kernel runs: per-app slowdown vs running alone under the
    /// same placement (1.0 = no interference).
    pub app_slowdown: Vec<f64>,
    /// Multi-kernel runs: Σ T_alone/T_shared over apps (system
    /// throughput; equals the app count when there is no contention).
    pub weighted_speedup: f64,
    /// Concurrent-host runs: completion time of the host request stream
    /// (0.0 when no host traffic ran).
    pub host_cycles: f64,
    /// Concurrent-host runs: host completion vs the host running alone on
    /// the same physical layout (1.0 = NDP traffic cost the host nothing;
    /// 0.0 when no host stream ran or no baseline applies).
    pub host_slowdown: f64,
    /// Concurrent-host runs: NDP makespan vs the NDP mix running without
    /// the host stream (1.0 = host traffic cost the NDP side nothing; 0.0
    /// when no NDP kernels ran or no baseline applies).
    pub ndp_slowdown: f64,
    /// Bytes delivered to the host over the per-stack host ports.
    pub host_bytes: u64,
    /// Bytes served by host-local DDR (never touched the stacks).
    pub host_ddr_bytes: u64,
    /// Host-port transfers that queued behind a busy port.
    pub host_port_stalls: u64,
    /// Host share of all bytes the stack DRAMs served (per-source
    /// bandwidth split; the NDP side's share is `1.0 - host_bw_share`).
    pub host_bw_share: f64,
    /// Fabric topology of the run ("line" / "ring" / "mesh"); empty for
    /// the degenerate fully-connected fabric, whose reports are frozen.
    pub topology: String,
    /// Peak-throughput window length in cycles (0.0 unless `link_stats`
    /// is populated).
    pub net_window_cycles: f64,
    /// Per-directed-link fabric counters (empty under fully-connected).
    pub link_stats: Vec<LinkStat>,
    /// Open-loop service-mode results (`[arrivals]` specs only; `None`
    /// for fixed mixes, whose reports stay frozen).
    pub service: Option<ServiceStats>,
    /// Hierarchical address-translation results (`tlb_l1_entries > 0`
    /// only; `None` under the frozen legacy flat-walk model).
    pub xlate: Option<XlateStats>,
    /// Shards the run executed on (see [`crate::shard`]). `0` from the
    /// sequential engine; the `shard_*` fields only appear in JSON when
    /// this is >= 2, so unsharded reports stay byte-identical.
    pub shard_stacks: u64,
    /// Conservative time windows (barrier rounds) a sharded run took.
    pub shard_windows: u64,
    /// Cross-shard messages exchanged through the shard mailboxes.
    pub shard_msgs: u64,
}

impl RunReport {
    /// Speedup of this run relative to a baseline run of the same workload.
    /// Degenerate zero-work runs (either side reporting 0 cycles) pin to
    /// 1.0 instead of inf/NaN, matching `per_app_slowdown`'s convention.
    pub fn speedup_over(&self, baseline: &RunReport) -> f64 {
        if self.cycles > 0.0 && baseline.cycles > 0.0 {
            baseline.cycles / self.cycles
        } else {
            1.0
        }
    }

    /// Remote-access reduction vs a baseline (positive = fewer remote).
    pub fn remote_reduction_over(&self, baseline: &RunReport) -> f64 {
        if baseline.accesses.remote == 0 {
            return 0.0;
        }
        1.0 - self.accesses.remote as f64 / baseline.accesses.remote as f64
    }

    /// Imbalance of DRAM traffic across stacks: max/mean bytes. A
    /// zero-stack config has no traffic to be imbalanced, so the empty
    /// case reports 0.0 (no `.max().unwrap()` to trip over); all-zero
    /// traffic over a populated stack list still pins to 1.0.
    pub fn stack_imbalance(&self) -> f64 {
        let Some(&max) = self.stack_bytes.iter().max() else {
            return 0.0;
        };
        let max = max as f64;
        let mean =
            self.stack_bytes.iter().sum::<u64>() as f64 / self.stack_bytes.len() as f64;
        if mean == 0.0 {
            1.0
        } else {
            max / mean
        }
    }
}

/// Per-app response times with never-ran apps made explicit. An app whose
/// recorded completion precedes its arrival never ran; the old behavior
/// clamped it to a 0.0 response time, which silently corrupts any mean or
/// percentile computed over the set.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ResponseTimes {
    /// One entry per app: `Some(completion − arrival)` when the app ran
    /// (completion at exactly the arrival is a legitimate 0.0), `None`
    /// when it never completed.
    pub per_app: Vec<Option<f64>>,
}

impl ResponseTimes {
    /// Response times of the apps that completed, in app order.
    pub fn completed(&self) -> Vec<f64> {
        self.per_app.iter().filter_map(|r| *r).collect()
    }

    /// Number of apps that never completed.
    pub fn incomplete(&self) -> usize {
        self.per_app.iter().filter(|r| r.is_none()).count()
    }

    /// The historical dense form: never-ran apps as 0.0. Kept for report
    /// rows whose shape is frozen (a 0.0 feeds the degenerate→1.0 branch
    /// of `per_app_slowdown` exactly as before); statistics must use
    /// `completed()` instead.
    pub fn zero_filled(&self) -> Vec<f64> {
        self.per_app.iter().map(|r| r.unwrap_or(0.0)).collect()
    }
}

/// Per-app response times: completion − arrival, with never-ran apps
/// (completion strictly before arrival) reported as incomplete rather
/// than clamped to 0.0. The single definition every mix/host path shares.
pub fn response_times(app_end: &[f64], arrivals: &[f64]) -> ResponseTimes {
    assert_eq!(app_end.len(), arrivals.len(), "per-app length mismatch");
    ResponseTimes {
        per_app: app_end
            .iter()
            .zip(arrivals)
            .map(|(&end, &t)| (end >= t).then_some(end - t))
            .collect(),
    }
}

/// Results of one open-loop service-mode run: request accounting, rates,
/// and streaming response-time percentiles from a [`QuantileSketch`].
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ServiceStats {
    /// Requests the arrival process offered before its cutoff.
    pub requests_offered: u64,
    /// Requests whose every kernel stage completed.
    pub requests_completed: u64,
    /// Requests still in flight (or never admitted to an SM) when the
    /// run ended — saturation shows up here, not as phantom 0.0 latencies.
    pub requests_incomplete: u64,
    /// Requests offered per cycle over the span the stream was open: the
    /// last admitted arrival when the requests cap ended the stream, else
    /// the declared duration, else the simulated makespan. A point burst
    /// (cap hit with every arrival at t=0) pins to 0.0.
    pub offered_rate: f64,
    /// Requests completed per cycle of simulated time (sustained
    /// throughput; compare against `offered_rate` for saturation).
    pub achieved_rate: f64,
    /// Mean response time (arrival → last stage completion) in cycles,
    /// over completed requests only.
    pub mean_response: f64,
    /// Largest completed-request response time in cycles.
    pub max_response: f64,
    /// Streaming median response time in cycles (sketch, <1% rel. error).
    pub p50_response: f64,
    /// Streaming 99th-percentile response time in cycles.
    pub p99_response: f64,
    /// Streaming 99.9th-percentile response time in cycles.
    pub p999_response: f64,
}

/// Results of one run under the hierarchical translation model (see
/// [`crate::xlate`]): TLB level hit accounting, page-walk occupancy, and
/// huge-page coverage of the run's mappings.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct XlateStats {
    /// Accesses served by a split L1 TLB (either page size).
    pub l1_hits: u64,
    /// Accesses that missed both L1 TLBs.
    pub l1_misses: u64,
    /// L1 misses served by the unified L2 TLB.
    pub l2_hits: u64,
    /// Accesses that missed both levels and took a page walk.
    pub l2_misses: u64,
    /// Page walks performed (equals `l2_misses`; kept explicit so the
    /// JSON reads without cross-referencing).
    pub walks: u64,
    /// L1 hit rate: `l1_hits / (l1_hits + l1_misses)`.
    pub l1_hit_rate: f64,
    /// L2 hit rate over L1 misses: `l2_hits / (l2_hits + l2_misses)`.
    pub l2_hit_rate: f64,
    /// SM cycles spent in page-walk service (levels x `ptw_level_ns`).
    pub walk_cycles: f64,
    /// SM cycles accesses spent queued for a free walker slot — the
    /// bounded-walker occupancy cost, separate from walk service.
    pub walk_queue_cycles: f64,
    /// Walk service + queue cycles as a share of total SM execution
    /// cycles (makespan x SM count).
    pub walk_stall_share: f64,
    /// 2 MB huge-page frames the allocator promoted this run.
    pub huge_pages: u64,
    /// Fraction of mapped base pages covered by huge frames.
    pub huge_coverage: f64,
}

/// Base-2 exponent buckets in the sketch: covers magnitudes up to 2^63.
const SKETCH_EXPS: usize = 64;
/// Sub-buckets per octave: 128 mantissa slices ⇒ relative bucket width
/// 1/128, so a nearest-rank answer from bucket midpoints is within
/// ~1/256 (< 1%) of the exact value for inputs ≥ 1.0.
const SKETCH_SUBS: usize = 128;

/// Fixed-memory streaming quantile sketch over non-negative values
/// (cycle counts): log-spaced histogram of `SKETCH_EXPS × SKETCH_SUBS`
/// buckets — base-2 exponent × 128 mantissa slices, i.e. the top bits of
/// the f64 representation. State is ~64 KB regardless of stream length,
/// so millions of per-request response times never materialize as a
/// `Vec`. Values in `[0, 1)` collapse into bucket 0 (sub-cycle response
/// times are noise at simulator resolution); quantiles are clamped to
/// the observed min/max so degenerate streams stay exact.
#[derive(Clone, Debug)]
pub struct QuantileSketch {
    counts: Vec<u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for QuantileSketch {
    fn default() -> Self {
        Self::new()
    }
}

impl QuantileSketch {
    pub fn new() -> Self {
        QuantileSketch {
            counts: vec![0; SKETCH_EXPS * SKETCH_SUBS],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: 0.0,
        }
    }

    fn bucket_of(v: f64) -> usize {
        if v < 1.0 {
            return 0;
        }
        let bits = v.to_bits();
        let exp = ((((bits >> 52) & 0x7ff) as i64) - 1023).min(SKETCH_EXPS as i64 - 1) as usize;
        let sub = ((bits >> 45) & 0x7f) as usize;
        exp * SKETCH_SUBS + sub
    }

    /// Midpoint of a bucket's value range (the representative a quantile
    /// query reports).
    fn bucket_value(idx: usize) -> f64 {
        let (exp, sub) = (idx / SKETCH_SUBS, idx % SKETCH_SUBS);
        (1u64 << exp) as f64 * (1.0 + (sub as f64 + 0.5) / SKETCH_SUBS as f64)
    }

    /// Fold another sketch into this one (per-shard service streams merge
    /// into run-level percentiles — see [`crate::shard`]). Buckets,
    /// totals and extrema combine exactly: the merged sketch is
    /// indistinguishable from one that observed both streams.
    pub fn merge(&mut self, other: &QuantileSketch) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Record one observation. Negative or non-finite values clamp to 0.0.
    pub fn record(&mut self, v: f64) {
        let v = if v.is_finite() && v > 0.0 { v } else { 0.0 };
        self.counts[Self::bucket_of(v)] += 1;
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Nearest-rank quantile estimate for `q` in `[0, 1]` (0.0 on an
    /// empty sketch), clamped to the observed `[min, max]`.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (idx, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return Self::bucket_value(idx).clamp(self.min, self.max);
            }
        }
        self.max
    }
}

/// Per-app slowdown of a shared run vs run-alone baselines: shared/alone
/// per app. Degenerate apps (zero time on either side) report 1.0.
pub fn per_app_slowdown(alone: &[f64], shared: &[f64]) -> Vec<f64> {
    assert_eq!(alone.len(), shared.len(), "per-app length mismatch");
    alone
        .iter()
        .zip(shared)
        .map(|(&a, &s)| if a > 0.0 && s > 0.0 { s / a } else { 1.0 })
        .collect()
}

/// Weighted speedup (system throughput): Σᵢ T_aloneᵢ / T_sharedᵢ. Equals
/// the app count when co-running costs nothing; each contended app
/// contributes its reciprocal slowdown. Degenerate apps contribute 1.0.
pub fn weighted_speedup(alone: &[f64], shared: &[f64]) -> f64 {
    assert_eq!(alone.len(), shared.len(), "per-app length mismatch");
    alone
        .iter()
        .zip(shared)
        .map(|(&a, &s)| if a > 0.0 && s > 0.0 { a / s } else { 1.0 })
        .sum()
}

/// Geometric mean of positive values (the paper's cross-benchmark average).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let log_sum: f64 = xs.iter().map(|x| x.max(1e-12).ln()).sum();
    (log_sum / xs.len() as f64).exp()
}

/// Arithmetic mean.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Coefficient of variation sigma/mu (§6.4's graph-regularity metric).
pub fn coeff_of_variation(xs: &[f64]) -> f64 {
    let m = mean(xs);
    if m == 0.0 {
        0.0
    } else {
        stddev(xs) / m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fractions() {
        let s = AccessStats {
            local: 75,
            remote: 25,
            host: 10,
            host_ddr: 5,
            l2_hits: 0,
        };
        assert!((s.remote_fraction() - 0.25).abs() < 1e-12);
        assert!((s.local_fraction() - 0.75).abs() < 1e-12);
        assert_eq!(s.ndp_total(), 100);
        assert_eq!(s.host_total(), 15);
    }

    #[test]
    fn empty_fractions_are_zero() {
        let s = AccessStats::default();
        assert_eq!(s.remote_fraction(), 0.0);
    }

    #[test]
    fn speedup_and_reduction() {
        let base = RunReport {
            cycles: 200.0,
            accesses: AccessStats {
                remote: 100,
                ..Default::default()
            },
            ..Default::default()
        };
        let run = RunReport {
            cycles: 100.0,
            accesses: AccessStats {
                remote: 40,
                ..Default::default()
            },
            ..Default::default()
        };
        assert!((run.speedup_over(&base) - 2.0).abs() < 1e-12);
        assert!((run.remote_reduction_over(&base) - 0.6).abs() < 1e-12);
    }

    #[test]
    fn sketch_merge_equals_single_stream() {
        // Merging shard-local sketches must be indistinguishable from one
        // sketch that saw every observation.
        let mut whole = QuantileSketch::new();
        let mut parts = [QuantileSketch::new(), QuantileSketch::new(), QuantileSketch::new()];
        let mut x = 0xC0DA_u64;
        for i in 0..3000 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let v = 1.0 + (x >> 40) as f64 / 16.0;
            whole.record(v);
            parts[i % 3].record(v);
        }
        let mut merged = QuantileSketch::new();
        for p in &parts {
            merged.merge(p);
        }
        assert_eq!(merged.count(), whole.count());
        assert_eq!(merged.mean().to_bits(), whole.mean().to_bits());
        assert_eq!(merged.min().to_bits(), whole.min().to_bits());
        assert_eq!(merged.max().to_bits(), whole.max().to_bits());
        for q in [0.5, 0.99, 0.999] {
            assert_eq!(merged.quantile(q).to_bits(), whole.quantile(q).to_bits());
        }
        // Merging an empty sketch is the identity.
        let before = merged.quantile(0.5).to_bits();
        merged.merge(&QuantileSketch::new());
        assert_eq!(merged.quantile(0.5).to_bits(), before);
    }

    #[test]
    fn geomean_matches_hand_computation() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn cv_of_constant_is_zero() {
        assert_eq!(coeff_of_variation(&[3.0, 3.0, 3.0]), 0.0);
        assert!(coeff_of_variation(&[1.0, 100.0]) > 0.9);
    }

    #[test]
    fn response_times_make_never_ran_explicit() {
        // Third app: completion 0.0 precedes its arrival at 5.0 — it never
        // ran. The old behavior clamped it to a phantom 0.0 response time.
        let r = response_times(&[100.0, 50.0, 0.0], &[10.0, 0.0, 5.0]);
        assert_eq!(r.per_app, vec![Some(90.0), Some(50.0), None]);
        assert_eq!(r.completed(), vec![90.0, 50.0]);
        assert_eq!(r.incomplete(), 1);
        // The legacy dense form is unchanged for frozen report rows.
        assert_eq!(r.zero_filled(), vec![90.0, 50.0, 0.0]);
        // Completion exactly at arrival is a legitimate 0.0, not never-ran.
        let r = response_times(&[5.0], &[5.0]);
        assert_eq!(r.per_app, vec![Some(0.0)]);
        assert_eq!(r.incomplete(), 0);
    }

    #[test]
    fn degenerate_speedup_pins_to_one() {
        let zero = RunReport::default();
        let run = RunReport {
            cycles: 100.0,
            ..Default::default()
        };
        // Zero cycles on either side would divide to inf/NaN; pin to 1.0.
        assert_eq!(zero.speedup_over(&run), 1.0);
        assert_eq!(run.speedup_over(&zero), 1.0);
        assert_eq!(zero.speedup_over(&zero), 1.0);
    }

    #[test]
    fn degenerate_imbalance_and_bw_share_pin() {
        // Audit companion of the speedup guard: all-zero traffic over a
        // populated stack list pins to the no-imbalance value.
        let r = RunReport {
            stack_bytes: vec![0, 0, 0, 0],
            ..Default::default()
        };
        assert_eq!(r.stack_imbalance(), 1.0);
        // host_bw_share is a plain stored field; its zero-work default is
        // 0.0 by construction.
        assert_eq!(RunReport::default().host_bw_share, 0.0);
    }

    #[test]
    fn zero_stack_imbalance_is_zero_not_panic() {
        // Regression: an empty stack list used to funnel into
        // `.max().unwrap()`; a zero-stack config now reports 0.0
        // (nothing to be imbalanced) instead of the populated-but-idle
        // pin of 1.0.
        let r = RunReport::default();
        assert!(r.stack_bytes.is_empty());
        assert_eq!(r.stack_imbalance(), 0.0);
    }

    #[test]
    fn sketch_basics() {
        let mut s = QuantileSketch::new();
        assert!(s.is_empty());
        assert_eq!(s.quantile(0.5), 0.0);
        assert_eq!(s.mean(), 0.0);
        for v in [10.0, 20.0, 30.0, 40.0] {
            s.record(v);
        }
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 25.0).abs() < 1e-12);
        assert_eq!(s.min(), 10.0);
        assert_eq!(s.max(), 40.0);
        // Quantiles land within a bucket width of the exact answer and
        // never escape the observed range.
        let p50 = s.quantile(0.5);
        assert!((10.0..=40.0).contains(&p50));
        assert!((p50 - 20.0).abs() / 20.0 < 1.0 / 64.0);
        assert_eq!(s.quantile(1.0), 40.0);
    }

    #[test]
    fn sketch_clamps_junk_and_degenerate_streams_stay_exact() {
        let mut s = QuantileSketch::new();
        s.record(-5.0);
        s.record(f64::NAN);
        s.record(f64::INFINITY);
        assert_eq!(s.count(), 3);
        assert_eq!(s.max(), 0.0);
        assert_eq!(s.quantile(0.99), 0.0);
        // A constant stream reports the constant exactly (min/max clamp).
        let mut s = QuantileSketch::new();
        for _ in 0..100 {
            s.record(7.5);
        }
        assert_eq!(s.quantile(0.5), 7.5);
        assert_eq!(s.quantile(0.999), 7.5);
    }

    #[test]
    fn slowdown_and_weighted_speedup() {
        let alone = [100.0, 200.0, 0.0];
        let shared = [200.0, 200.0, 0.0];
        assert_eq!(per_app_slowdown(&alone, &shared), vec![2.0, 1.0, 1.0]);
        // 0.5 + 1.0 + 1.0
        assert!((weighted_speedup(&alone, &shared) - 2.5).abs() < 1e-12);
        // No contention: weighted speedup equals the app count.
        let same = [50.0, 60.0];
        assert!((weighted_speedup(&same, &same) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn imbalance() {
        let r = RunReport {
            stack_bytes: vec![100, 100, 100, 100],
            ..Default::default()
        };
        assert!((r.stack_imbalance() - 1.0).abs() < 1e-12);
        let r = RunReport {
            stack_bytes: vec![400, 0, 0, 0],
            ..Default::default()
        };
        assert!((r.stack_imbalance() - 4.0).abs() < 1e-12);
    }
}
