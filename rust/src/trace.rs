//! Memory access traces: the interchange format between workload
//! generators, the profiler, and the simulator, plus the page-sharing
//! analysis behind Fig 3 / Table 2 and a compact binary record/replay
//! format.
//!
//! Accesses are line-granularity (the generators coalesce per-warp
//! accesses) and object-relative: `(object, offset)` rather than virtual
//! addresses, so the same trace can be replayed under any placement.

use anyhow::{bail, Context};
use std::collections::HashMap;
use std::io::{Read, Write};

/// One line-granularity memory access, relative to a memory object.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Access {
    /// Index into the workload's object table.
    pub obj: u16,
    /// Byte offset within the object (line-aligned by generators).
    pub offset: u64,
    /// Store (true) or load (false).
    pub write: bool,
}

/// The accesses of one thread-block.
#[derive(Clone, Debug, Default)]
pub struct BlockTrace {
    pub block_id: u32,
    pub accesses: Vec<Access>,
}

/// A memory object (one `cudaMalloc` in the paper's Fig 7).
#[derive(Clone, Debug)]
pub struct ObjectDesc {
    pub name: String,
    pub bytes: u64,
}

/// A full kernel trace: objects + per-block access streams.
#[derive(Clone, Debug)]
pub struct KernelTrace {
    pub name: String,
    pub threads_per_block: u32,
    pub objects: Vec<ObjectDesc>,
    pub blocks: Vec<BlockTrace>,
}

impl KernelTrace {
    pub fn num_blocks(&self) -> u32 {
        self.blocks.len() as u32
    }

    pub fn total_accesses(&self) -> u64 {
        self.blocks.iter().map(|b| b.accesses.len() as u64).sum()
    }

    /// Total footprint in bytes.
    pub fn footprint(&self) -> u64 {
        self.objects.iter().map(|o| o.bytes).sum()
    }
}

/// Sharing histogram of Fig 3: how many thread-blocks touch each page.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SharingHistogram {
    /// Pages touched by exactly 1 thread-block.
    pub one_block: u64,
    /// Pages touched by exactly 2 thread-blocks.
    pub two_blocks: u64,
    /// Pages touched by 3..=16 thread-blocks.
    pub few_blocks: u64,
    /// Pages touched by >16 but not (almost) all blocks.
    pub many_blocks: u64,
    /// Pages touched by >=90% of all thread-blocks.
    pub all_blocks: u64,
    /// Pages whose accessing blocks all share one affinity stack.
    pub one_stack: u64,
    /// Total touched pages.
    pub total: u64,
}

impl SharingHistogram {
    pub fn fractions(&self) -> [f64; 5] {
        let t = self.total.max(1) as f64;
        [
            self.one_block as f64 / t,
            self.two_blocks as f64 / t,
            self.few_blocks as f64 / t,
            self.many_blocks as f64 / t,
            self.all_blocks as f64 / t,
        ]
    }
}

/// Workload category of Table 2.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Category {
    /// >90% of pages accessed by only one thread-block.
    BlockExclusive,
    /// >90% of pages accessed by one memory stack (multiple SMs, one stack).
    CoreExclusive,
    /// >60% of pages accessed by only one thread-block.
    BlockMajority,
    /// >60% of pages accessed by one memory stack.
    CoreMajority,
    /// Most pages accessed by more than one memory stack.
    Sharing,
}

impl std::fmt::Display for Category {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            Category::BlockExclusive => "block-exclusive",
            Category::CoreExclusive => "core-exclusive",
            Category::BlockMajority => "block-majority",
            Category::CoreMajority => "core-majority",
            Category::Sharing => "sharing",
        };
        f.write_str(s)
    }
}

/// Compute the Fig 3 sharing histogram for a kernel trace.
///
/// `affinity` maps a block id to its affinity stack (Eq 1); it determines
/// the `one_stack` statistic used for the core-exclusive classification.
pub fn sharing_histogram(
    trace: &KernelTrace,
    page_size: u64,
    affinity: impl Fn(u32) -> usize,
) -> SharingHistogram {
    // Per (object, page) -> set of accessing blocks, kept small: we only
    // need |set| and the stack-uniformity flag.
    #[derive(Clone)]
    struct PageInfo {
        blocks: u32,
        last_block: u32,
        second_block: u32,
        stack: usize,
        one_stack: bool,
        count_capped: u32,
    }
    let mut pages: HashMap<(u16, u64), PageInfo> = HashMap::new();
    for b in &trace.blocks {
        let stack = affinity(b.block_id);
        for a in &b.accesses {
            let key = (a.obj, a.offset / page_size);
            match pages.get_mut(&key) {
                None => {
                    pages.insert(
                        key,
                        PageInfo {
                            blocks: 1,
                            last_block: b.block_id,
                            second_block: u32::MAX,
                            stack,
                            one_stack: true,
                            count_capped: 1,
                        },
                    );
                }
                Some(p) => {
                    if p.last_block != b.block_id {
                        if p.second_block == u32::MAX || p.second_block == p.last_block {
                            p.second_block = p.last_block;
                        }
                        p.last_block = b.block_id;
                        p.blocks += 1;
                        p.count_capped = p.count_capped.saturating_add(1);
                    }
                    if p.stack != stack {
                        p.one_stack = false;
                    }
                }
            }
        }
    }
    // NOTE: blocks counts transitions of distinct block visits; generators
    // emit all of one block's accesses contiguously, so this equals the
    // number of distinct blocks (verified by tests).
    let total_blocks = trace.blocks.len() as u32;
    let mut h = SharingHistogram::default();
    for p in pages.values() {
        h.total += 1;
        if p.one_stack {
            h.one_stack += 1;
        }
        let n = p.blocks;
        if n == 1 {
            h.one_block += 1;
        } else if n == 2 {
            h.two_blocks += 1;
        } else if n as f64 >= 0.9 * total_blocks as f64 {
            h.all_blocks += 1;
        } else if n <= 16 {
            h.few_blocks += 1;
        } else {
            h.many_blocks += 1;
        }
    }
    h
}

/// Table 2 classification from the sharing histogram.
///
/// "Accessed by only one thread-block" counts the 1–2-block bucket (Fig 3
/// merges 1 and 2: a block's slice of an object rarely page-aligns, so the
/// page holding a boundary is inevitably touched by the neighbor block too;
/// the paper's >90% block-exclusive claims for BFS/NW only hold under that
/// reading). Categories are tested in Table 2's order.
pub fn classify(h: &SharingHistogram) -> Category {
    let t = h.total.max(1) as f64;
    let block_excl = (h.one_block + h.two_blocks) as f64 / t;
    let one_stack = h.one_stack as f64 / t;
    if block_excl > 0.9 {
        Category::BlockExclusive
    } else if one_stack > 0.9 {
        Category::CoreExclusive
    } else if block_excl > 0.6 {
        Category::BlockMajority
    } else if one_stack > 0.6 {
        Category::CoreMajority
    } else {
        Category::Sharing
    }
}

// ---------------------------------------------------------------------------
// Binary record/replay format
// ---------------------------------------------------------------------------

const MAGIC: &[u8; 8] = b"CODATRC1";

/// Serialize a kernel trace to a compact binary stream.
pub fn write_trace<W: Write>(w: &mut W, t: &KernelTrace) -> crate::Result<()> {
    w.write_all(MAGIC)?;
    write_str(w, &t.name)?;
    w.write_all(&t.threads_per_block.to_le_bytes())?;
    w.write_all(&(t.objects.len() as u32).to_le_bytes())?;
    for o in &t.objects {
        write_str(w, &o.name)?;
        w.write_all(&o.bytes.to_le_bytes())?;
    }
    w.write_all(&(t.blocks.len() as u32).to_le_bytes())?;
    for b in &t.blocks {
        w.write_all(&b.block_id.to_le_bytes())?;
        w.write_all(&(b.accesses.len() as u32).to_le_bytes())?;
        for a in &b.accesses {
            w.write_all(&a.obj.to_le_bytes())?;
            w.write_all(&a.offset.to_le_bytes())?;
            w.write_all(&[a.write as u8])?;
        }
    }
    Ok(())
}

/// Deserialize a kernel trace written by [`write_trace`].
pub fn read_trace<R: Read>(r: &mut R) -> crate::Result<KernelTrace> {
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic).context("trace header")?;
    if &magic != MAGIC {
        bail!("not a CODA trace (bad magic)");
    }
    let name = read_str(r)?;
    let threads_per_block = read_u32(r)?;
    let n_obj = read_u32(r)? as usize;
    let mut objects = Vec::with_capacity(n_obj);
    for _ in 0..n_obj {
        let name = read_str(r)?;
        let bytes = read_u64(r)?;
        objects.push(ObjectDesc { name, bytes });
    }
    let n_blocks = read_u32(r)? as usize;
    let mut blocks = Vec::with_capacity(n_blocks);
    for _ in 0..n_blocks {
        let block_id = read_u32(r)?;
        let n_acc = read_u32(r)? as usize;
        let mut accesses = Vec::with_capacity(n_acc);
        for _ in 0..n_acc {
            let mut obj = [0u8; 2];
            r.read_exact(&mut obj)?;
            let offset = read_u64(r)?;
            let mut wr = [0u8; 1];
            r.read_exact(&mut wr)?;
            accesses.push(Access {
                obj: u16::from_le_bytes(obj),
                offset,
                write: wr[0] != 0,
            });
        }
        blocks.push(BlockTrace {
            block_id,
            accesses,
        });
    }
    Ok(KernelTrace {
        name,
        threads_per_block,
        objects,
        blocks,
    })
}

fn write_str<W: Write>(w: &mut W, s: &str) -> crate::Result<()> {
    w.write_all(&(s.len() as u32).to_le_bytes())?;
    w.write_all(s.as_bytes())?;
    Ok(())
}

fn read_str<R: Read>(r: &mut R) -> crate::Result<String> {
    let n = read_u32(r)? as usize;
    if n > 1 << 20 {
        bail!("implausible string length {n}");
    }
    let mut buf = vec![0u8; n];
    r.read_exact(&mut buf)?;
    Ok(String::from_utf8(buf)?)
}

fn read_u32<R: Read>(r: &mut R) -> crate::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64<R: Read>(r: &mut R) -> crate::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_trace() -> KernelTrace {
        // Object 0: 4 pages. Blocks 0..4 each touch their own page; all
        // touch page 0 of object 1 (shared).
        let objects = vec![
            ObjectDesc {
                name: "priv".into(),
                bytes: 4 * 4096,
            },
            ObjectDesc {
                name: "shared".into(),
                bytes: 4096,
            },
        ];
        let blocks = (0..4u32)
            .map(|b| BlockTrace {
                block_id: b,
                accesses: vec![
                    Access {
                        obj: 0,
                        offset: b as u64 * 4096,
                        write: false,
                    },
                    Access {
                        obj: 0,
                        offset: b as u64 * 4096 + 128,
                        write: true,
                    },
                    Access {
                        obj: 1,
                        offset: 0,
                        write: false,
                    },
                ],
            })
            .collect();
        KernelTrace {
            name: "t".into(),
            threads_per_block: 64,
            objects,
            blocks,
        }
    }

    #[test]
    fn histogram_counts_exclusive_and_shared() {
        let t = mk_trace();
        let h = sharing_histogram(&t, 4096, |_| 0);
        assert_eq!(h.total, 5);
        assert_eq!(h.one_block, 4);
        // Shared page touched by 4/4 blocks >= 90% -> all_blocks.
        assert_eq!(h.all_blocks, 1);
        // With all blocks on stack 0, every page is one-stack.
        assert_eq!(h.one_stack, 5);
    }

    #[test]
    fn histogram_one_stack_depends_on_affinity() {
        let t = mk_trace();
        let h = sharing_histogram(&t, 4096, |b| (b % 4) as usize);
        assert_eq!(h.one_stack, 4, "only the private pages are one-stack");
    }

    #[test]
    fn classify_thresholds() {
        let mut h = SharingHistogram {
            one_block: 80,
            two_blocks: 15,
            total: 100,
            ..Default::default()
        };
        assert_eq!(classify(&h), Category::BlockExclusive);
        h.one_block = 55;
        h.two_blocks = 15;
        h.one_stack = 70;
        assert_eq!(classify(&h), Category::BlockMajority);
        h.one_block = 10;
        h.two_blocks = 0;
        h.one_stack = 95;
        assert_eq!(classify(&h), Category::CoreExclusive);
        h.one_stack = 65;
        assert_eq!(classify(&h), Category::CoreMajority);
        h.one_stack = 10;
        assert_eq!(classify(&h), Category::Sharing);
        // Core-exclusive wins over block-majority (Table 2's order): many
        // two-block pages that all stay within one stack.
        let h = SharingHistogram {
            one_block: 10,
            two_blocks: 60,
            one_stack: 95,
            total: 100,
            ..Default::default()
        };
        assert_eq!(classify(&h), Category::CoreExclusive);
    }

    #[test]
    fn trace_roundtrip() {
        let t = mk_trace();
        let mut buf = Vec::new();
        write_trace(&mut buf, &t).unwrap();
        let t2 = read_trace(&mut buf.as_slice()).unwrap();
        assert_eq!(t2.name, t.name);
        assert_eq!(t2.threads_per_block, t.threads_per_block);
        assert_eq!(t2.objects.len(), 2);
        assert_eq!(t2.objects[0].bytes, t.objects[0].bytes);
        assert_eq!(t2.blocks.len(), t.blocks.len());
        assert_eq!(t2.blocks[3].accesses, t.blocks[3].accesses);
    }

    #[test]
    fn trace_rejects_garbage() {
        let buf = b"NOTATRACE_____";
        assert!(read_trace(&mut buf.as_slice()).is_err());
    }
}
