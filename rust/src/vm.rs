//! Virtual memory: page table entries carrying the CODA granularity bit,
//! a TLB model, and an OS physical-page allocator that understands
//! **page-groups** (§4.2).
//!
//! The allocator is the "System Software Support" half of the paper's
//! hardware mechanism: a CGP occupies the space that N FGPs would have
//! occupied within one stack, so groups of N aligned pages must be uniformly
//! FGP or CGP, and may only switch modes while the whole group is free.
//! Allocating a coarse-grain page *on a specific stack* is the primitive the
//! data-placement algorithm (Eq 3) builds on.

use crate::addr::{AddressMapper, Granularity};
use crate::config::SystemConfig;
use anyhow::bail;
use std::collections::HashMap;

/// A page table entry: translation plus the CODA granularity bit (the paper
/// stores it in one of the x86 PTE reserved bits [11:9], §7.3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Pte {
    pub ppn: u64,
    pub granularity: Granularity,
}

/// Per-group allocator bookkeeping.
#[derive(Clone, Debug)]
struct GroupEntry {
    mode: Granularity,
    /// Bitmask of in-use pages within the group (bit i = page base+i).
    used: u64,
    /// Bumped whenever the group returns to the free pool; invalidates any
    /// stale entries in the mode-specific free pools.
    epoch: u32,
}

/// OS physical-page allocator with page-group-aware free lists.
///
/// Groups are materialized lazily: a fresh-group cursor covers
/// never-touched memory, and fully-freed groups recycle through
/// `free_groups`. Mode-specific pools (`fgp_pool`, per-stack `cgp_pools`)
/// hold individual free pages of groups already committed to a mode.
#[derive(Debug)]
pub struct PhysAllocator {
    group_len: u64,
    total_groups: u64,
    next_fresh: u64,
    free_groups: Vec<u64>,
    groups: HashMap<u64, GroupEntry>,
    /// Free FGP pages: (ppn, group_epoch).
    fgp_pool: Vec<(u64, u32)>,
    /// Free CGP pages per stack: (ppn, group_epoch).
    cgp_pools: Vec<Vec<(u64, u32)>>,
    mapper: AddressMapper,
    pages_allocated: u64,
}

impl PhysAllocator {
    pub fn new(cfg: &SystemConfig) -> Self {
        let mapper = AddressMapper::new(cfg);
        let total_pages = cfg.stack_capacity / cfg.page_size * cfg.num_stacks as u64;
        let group_len = cfg.num_stacks as u64;
        Self {
            group_len,
            total_groups: total_pages / group_len,
            next_fresh: 0,
            free_groups: Vec::new(),
            groups: HashMap::new(),
            fgp_pool: Vec::new(),
            cgp_pools: vec![Vec::new(); cfg.num_stacks],
            mapper,
            pages_allocated: 0,
        }
    }

    fn take_free_group(&mut self) -> Option<u64> {
        if let Some(g) = self.free_groups.pop() {
            return Some(g);
        }
        if self.next_fresh < self.total_groups {
            let g = self.next_fresh;
            self.next_fresh += 1;
            return Some(g);
        }
        None
    }

    fn commit_group(&mut self, g: u64, mode: Granularity) -> u32 {
        let epoch = self.groups.get(&g).map(|e| e.epoch).unwrap_or(0);
        self.groups.insert(
            g,
            GroupEntry {
                mode,
                used: 0,
                epoch,
            },
        );
        epoch
    }

    /// Pop a valid page from a pool, discarding entries invalidated by
    /// group recycling.
    fn pop_valid(groups: &HashMap<u64, GroupEntry>, pool: &mut Vec<(u64, u32)>, group_len: u64, mode: Granularity) -> Option<u64> {
        while let Some((ppn, epoch)) = pool.pop() {
            let g = ppn / group_len;
            if let Some(e) = groups.get(&g) {
                if e.epoch == epoch && e.mode == mode && e.used & (1 << (ppn % group_len)) == 0 {
                    return Some(ppn);
                }
            }
        }
        None
    }

    fn mark_used(&mut self, ppn: u64) {
        let g = ppn / self.group_len;
        let e = self.groups.get_mut(&g).expect("group committed");
        e.used |= 1 << (ppn % self.group_len);
        self.pages_allocated += 1;
    }

    /// Allocate one fine-grain page (striped across all stacks).
    pub fn alloc_fgp(&mut self) -> crate::Result<u64> {
        if let Some(ppn) = Self::pop_valid(&self.groups, &mut self.fgp_pool, self.group_len, Granularity::Fgp) {
            self.mark_used(ppn);
            return Ok(ppn);
        }
        let Some(g) = self.take_free_group() else {
            bail!("out of physical memory (FGP)");
        };
        let epoch = self.commit_group(g, Granularity::Fgp);
        let base = g * self.group_len;
        // Hand out page 0 now; pool the rest.
        for i in (1..self.group_len).rev() {
            self.fgp_pool.push((base + i, epoch));
        }
        self.mark_used(base);
        Ok(base)
    }

    /// Allocate one coarse-grain page resident entirely on `stack`.
    ///
    /// Within a CGP group with base PPN `B` (group-aligned), page `B+i` maps
    /// to stack `i`, so each group supplies exactly one page per stack.
    pub fn alloc_cgp(&mut self, stack: usize) -> crate::Result<u64> {
        if stack >= self.cgp_pools.len() {
            bail!("stack {stack} out of range");
        }
        if let Some(ppn) = Self::pop_valid(
            &self.groups,
            &mut self.cgp_pools[stack],
            self.group_len,
            Granularity::Cgp,
        ) {
            self.mark_used(ppn);
            return Ok(ppn);
        }
        let Some(g) = self.take_free_group() else {
            bail!("out of physical memory (CGP, stack {stack})");
        };
        let epoch = self.commit_group(g, Granularity::Cgp);
        let base = g * self.group_len;
        let mut target = None;
        for i in 0..self.group_len {
            let ppn = base + i;
            let s = self.mapper.stack_of_ppn_cgp(ppn);
            if s == stack && target.is_none() {
                target = Some(ppn);
            } else {
                self.cgp_pools[s].push((ppn, epoch));
            }
        }
        let ppn = target.expect("aligned group covers every stack exactly once");
        self.mark_used(ppn);
        Ok(ppn)
    }

    /// Free a page. When its whole group becomes free, the group may be
    /// re-committed to either mode by a later allocation (the paper's
    /// conversion rule).
    pub fn free(&mut self, ppn: u64) {
        let g = ppn / self.group_len;
        let Some(e) = self.groups.get_mut(&g) else {
            panic!("freeing page {ppn} of unknown group");
        };
        let bit = 1 << (ppn % self.group_len);
        assert!(e.used & bit != 0, "double free of ppn {ppn}");
        e.used &= !bit;
        self.pages_allocated -= 1;
        if e.used == 0 {
            e.epoch += 1; // invalidate pooled siblings
            self.free_groups.push(g);
        } else {
            // Return this single page to its mode pool.
            let epoch = e.epoch;
            match e.mode {
                Granularity::Fgp => self.fgp_pool.push((ppn, epoch)),
                Granularity::Cgp => {
                    let s = self.mapper.stack_of_ppn_cgp(ppn);
                    self.cgp_pools[s].push((ppn, epoch));
                }
            }
        }
    }

    /// Mode of the group a page belongs to (None if never allocated).
    pub fn group_mode(&self, ppn: u64) -> Option<Granularity> {
        self.groups.get(&(ppn / self.group_len)).map(|e| e.mode)
    }

    pub fn pages_allocated(&self) -> u64 {
        self.pages_allocated
    }
}

/// A flat per-workload virtual address space with CODA-aware translation.
#[derive(Debug)]
pub struct VirtualMemory {
    page_size: u64,
    page_shift: u32,
    table: Vec<Option<Pte>>, // indexed by VPN; dense per-workload space
    alloc: PhysAllocator,
    next_vpn: u64,
}

impl VirtualMemory {
    pub fn new(cfg: &SystemConfig) -> Self {
        Self {
            page_size: cfg.page_size,
            page_shift: cfg.page_size.trailing_zeros(),
            table: Vec::new(),
            alloc: PhysAllocator::new(cfg),
            next_vpn: 0,
        }
    }

    pub fn page_size(&self) -> u64 {
        self.page_size
    }

    fn push_pte(&mut self, pte: Pte) -> u64 {
        let vpn = self.next_vpn;
        self.next_vpn += 1;
        if self.table.len() <= vpn as usize {
            self.table.resize(vpn as usize + 1, None);
        }
        self.table[vpn as usize] = Some(pte);
        vpn
    }

    /// Map `n_pages` fine-grain pages; returns the base virtual address.
    pub fn map_fgp(&mut self, n_pages: u64) -> crate::Result<u64> {
        let base = self.next_vpn;
        for _ in 0..n_pages {
            let ppn = self.alloc.alloc_fgp()?;
            self.push_pte(Pte {
                ppn,
                granularity: Granularity::Fgp,
            });
        }
        Ok(base << self.page_shift)
    }

    /// Map `n_pages` coarse-grain pages; `stack_of_page(i)` names the target
    /// stack for the i-th page (this is where Eq 3 plugs in). Returns the
    /// base virtual address.
    pub fn map_cgp(
        &mut self,
        n_pages: u64,
        mut stack_of_page: impl FnMut(u64) -> usize,
    ) -> crate::Result<u64> {
        let base = self.next_vpn;
        for i in 0..n_pages {
            let ppn = self.alloc.alloc_cgp(stack_of_page(i))?;
            self.push_pte(Pte {
                ppn,
                granularity: Granularity::Cgp,
            });
        }
        Ok(base << self.page_shift)
    }

    /// Translate a virtual address. Returns (physical address, granularity).
    #[inline]
    pub fn translate(&self, vaddr: u64) -> Option<(u64, Granularity)> {
        let vpn = (vaddr >> self.page_shift) as usize;
        let pte = (*self.table.get(vpn)?)?;
        let off = vaddr & (self.page_size - 1);
        Some(((pte.ppn << self.page_shift) | off, pte.granularity))
    }

    /// The PTE for a virtual page (tests / migration).
    pub fn pte_of(&self, vaddr: u64) -> Option<Pte> {
        *self.table.get((vaddr >> self.page_shift) as usize)?
    }

    /// Remap one virtual page onto a freshly allocated CGP page on `stack`
    /// (used by the migration-based first-touch baseline, §6.1 fn.6).
    pub fn migrate_to_cgp(&mut self, vaddr: u64, stack: usize) -> crate::Result<()> {
        let vpn = (vaddr >> self.page_shift) as usize;
        let Some(Some(old)) = self.table.get(vpn).copied() else {
            bail!("migrating unmapped page");
        };
        let ppn = self.alloc.alloc_cgp(stack)?;
        self.table[vpn] = Some(Pte {
            ppn,
            granularity: Granularity::Cgp,
        });
        self.alloc.free(old.ppn);
        Ok(())
    }

    pub fn allocator(&self) -> &PhysAllocator {
        &self.alloc
    }

    /// Number of mapped virtual pages.
    pub fn mapped_pages(&self) -> u64 {
        self.next_vpn
    }
}

/// A set-associative TLB with LRU replacement, carrying the granularity bit
/// alongside each translation (Fig 5).
#[derive(Debug)]
pub struct Tlb {
    sets: Vec<Vec<(u64, Pte, u64)>>, // (vpn, pte, last_used)
    ways: usize,
    set_mask: u64,
    tick: u64,
    pub hits: u64,
    pub misses: u64,
}

impl Tlb {
    pub fn new(entries: usize) -> Self {
        let ways = 4.min(entries.max(1));
        let sets = (entries / ways).max(1).next_power_of_two();
        Self {
            sets: vec![Vec::with_capacity(ways); sets],
            ways,
            set_mask: sets as u64 - 1,
            tick: 0,
            hits: 0,
            misses: 0,
        }
    }

    /// Look up a VPN; on miss the caller walks the page table and calls
    /// [`Self::fill`]. Returns the cached PTE on hit.
    pub fn lookup(&mut self, vpn: u64) -> Option<Pte> {
        self.tick += 1;
        let set = &mut self.sets[(vpn & self.set_mask) as usize];
        if let Some(entry) = set.iter_mut().find(|e| e.0 == vpn) {
            entry.2 = self.tick;
            self.hits += 1;
            return Some(entry.1);
        }
        self.misses += 1;
        None
    }

    pub fn fill(&mut self, vpn: u64, pte: Pte) {
        self.tick += 1;
        let tick = self.tick;
        let ways = self.ways;
        let set = &mut self.sets[(vpn & self.set_mask) as usize];
        if let Some(entry) = set.iter_mut().find(|e| e.0 == vpn) {
            *entry = (vpn, pte, tick);
            return;
        }
        if set.len() < ways {
            set.push((vpn, pte, tick));
        } else {
            let lru = set
                .iter_mut()
                .min_by_key(|e| e.2)
                .expect("non-empty set");
            *lru = (vpn, pte, tick);
        }
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> SystemConfig {
        SystemConfig::test_small()
    }

    #[test]
    fn fgp_alloc_walks_groups() {
        let mut a = PhysAllocator::new(&cfg());
        let p0 = a.alloc_fgp().unwrap();
        assert_eq!(p0, 0);
        assert_eq!(a.group_mode(p0), Some(Granularity::Fgp));
        // Next three come from the same group's pool.
        let mut rest: Vec<u64> = (0..3).map(|_| a.alloc_fgp().unwrap()).collect();
        rest.sort_unstable();
        assert_eq!(rest, vec![1, 2, 3]);
    }

    #[test]
    fn cgp_alloc_targets_requested_stack() {
        let c = cfg();
        let mapper = AddressMapper::new(&c);
        let mut a = PhysAllocator::new(&c);
        for stack in [2usize, 0, 3, 1, 2, 2] {
            let ppn = a.alloc_cgp(stack).unwrap();
            assert_eq!(mapper.stack_of_ppn_cgp(ppn), stack);
            assert_eq!(a.group_mode(ppn), Some(Granularity::Cgp));
        }
    }

    #[test]
    fn group_modes_are_exclusive_until_freed() {
        let mut a = PhysAllocator::new(&cfg());
        let f = a.alloc_fgp().unwrap(); // commits group 0 to FGP
        let c0 = a.alloc_cgp(0).unwrap(); // must come from a different group
        assert_ne!(f / 4, c0 / 4, "FGP and CGP pages never share a group");
    }

    #[test]
    fn group_conversion_requires_fully_free() {
        let mut a = PhysAllocator::new(&cfg());
        // Fill group 0 as FGP.
        let pages: Vec<u64> = (0..4).map(|_| a.alloc_fgp().unwrap()).collect();
        assert!(pages.iter().all(|p| p / 4 == 0));
        // Free all 4 -> group recycles; a CGP allocation may now claim it.
        for p in pages {
            a.free(p);
        }
        let c = a.alloc_cgp(1).unwrap();
        assert_eq!(c / 4, 0, "recycled group reused in the other mode");
        assert_eq!(a.group_mode(c), Some(Granularity::Cgp));
    }

    #[test]
    fn stale_pool_entries_are_invalidated() {
        let mut a = PhysAllocator::new(&cfg());
        let f = a.alloc_fgp().unwrap(); // group 0 FGP; 3 siblings pooled
        a.free(f); // group 0 fully free; siblings stale
        let c = a.alloc_cgp(2).unwrap(); // may recycle group 0 as CGP
        assert_eq!(a.group_mode(c), Some(Granularity::Cgp));
        // FGP allocation must NOT return a stale group-0 sibling.
        let f2 = a.alloc_fgp().unwrap();
        assert_ne!(f2 / 4, c / 4);
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut a = PhysAllocator::new(&cfg());
        let p = a.alloc_fgp().unwrap();
        a.free(p);
        a.free(p);
    }

    #[test]
    fn allocator_exhaustion() {
        let mut c = cfg();
        c.stack_capacity = 4 * c.page_size; // 4 pages/stack -> 16 pages total
        let mut a = PhysAllocator::new(&c);
        for _ in 0..16 {
            a.alloc_fgp().unwrap();
        }
        assert!(a.alloc_fgp().is_err());
    }

    #[test]
    fn vm_translate_fgp_and_cgp() {
        let c = cfg();
        let mut vm = VirtualMemory::new(&c);
        let v_f = vm.map_fgp(2).unwrap();
        let v_c = vm.map_cgp(2, |_| 3).unwrap();
        let (p, g) = vm.translate(v_f + 100).unwrap();
        assert_eq!(g, Granularity::Fgp);
        assert_eq!(p & 0xFFF, 100);
        let (p, g) = vm.translate(v_c + 5000).unwrap();
        assert_eq!(g, Granularity::Cgp);
        assert_eq!(p & 0xFFF, 5000 & 0xFFF);
        let mapper = AddressMapper::new(&c);
        assert_eq!(mapper.stack_of(p, g), 3);
        assert!(vm.translate(1 << 40).is_none());
    }

    #[test]
    fn vm_migration_changes_stack_and_granularity() {
        let c = cfg();
        let mapper = AddressMapper::new(&c);
        let mut vm = VirtualMemory::new(&c);
        let v = vm.map_fgp(1).unwrap();
        assert_eq!(vm.pte_of(v).unwrap().granularity, Granularity::Fgp);
        vm.migrate_to_cgp(v, 2).unwrap();
        let (p, g) = vm.translate(v).unwrap();
        assert_eq!(g, Granularity::Cgp);
        assert_eq!(mapper.stack_of(p, g), 2);
    }

    #[test]
    fn tlb_hits_after_fill_and_lru_evicts() {
        let mut tlb = Tlb::new(8); // 4-way, 2 sets
        let pte = |ppn| Pte {
            ppn,
            granularity: Granularity::Fgp,
        };
        assert!(tlb.lookup(0).is_none());
        tlb.fill(0, pte(10));
        assert_eq!(tlb.lookup(0).unwrap().ppn, 10);
        // Fill one set (even vpns) beyond capacity; vpn 0 stays hot.
        for vpn in [2u64, 4, 6] {
            tlb.fill(vpn, pte(vpn));
            tlb.lookup(0);
        }
        tlb.fill(8, pte(8)); // evicts LRU (vpn 2)
        assert!(tlb.lookup(0).is_some());
        assert!(tlb.lookup(2).is_none());
        assert!(tlb.hit_rate() > 0.0);
    }
}
