//! Virtual memory: page table entries carrying the CODA granularity bit,
//! a TLB model, and an OS physical-page allocator that understands
//! **page-groups** (§4.2).
//!
//! The allocator is the "System Software Support" half of the paper's
//! hardware mechanism: a CGP occupies the space that N FGPs would have
//! occupied within one stack, so groups of N aligned pages must be uniformly
//! FGP or CGP, and may only switch modes while the whole group is free.
//! Allocating a coarse-grain page *on a specific stack* is the primitive the
//! data-placement algorithm (Eq 3) builds on.

use crate::addr::{large_page_mapper, AddressMapper, Granularity, PhysicalAddress, VirtualAddress};
use crate::config::SystemConfig;
use anyhow::bail;
use std::collections::HashMap;

/// Bytes in one huge page (§7.2 large pages; the x86 2 MB level).
pub const HUGE_PAGE_BYTES: u64 = 2 << 20;

/// A page table entry: translation plus the CODA granularity bit (the paper
/// stores it in one of the x86 PTE reserved bits [11:9], §7.3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Pte {
    pub ppn: u64,
    pub granularity: Granularity,
    /// Set on every base-page PTE covered by a 2 MB huge mapping. The page
    /// table stays dense at base-page granularity (the simulator's VPN
    /// indexing depends on it); the flag tells translation hardware that
    /// this VPN's frame is part of an aligned huge frame — the TLB may
    /// cache one entry for the whole frame and the page walk is one level
    /// shorter — and tells the engine to route the access through the
    /// huge-page mapper (stack bits above the 2 MB boundary).
    pub huge: bool,
}

/// Per-group allocator bookkeeping.
#[derive(Clone, Debug)]
struct GroupEntry {
    mode: Granularity,
    /// Bitmask of in-use pages within the group (bit i = page base+i).
    used: u64,
    /// Bumped whenever the group returns to the free pool; invalidates any
    /// stale entries in the mode-specific free pools.
    epoch: u32,
}

/// OS physical-page allocator with page-group-aware free lists.
///
/// Groups are materialized lazily: a fresh-group cursor covers
/// never-touched memory, and fully-freed groups recycle through
/// `free_groups`. Mode-specific pools (`fgp_pool`, per-stack `cgp_pools`)
/// hold individual free pages of groups already committed to a mode.
#[derive(Debug)]
pub struct PhysAllocator {
    group_len: u64,
    total_groups: u64,
    next_fresh: u64,
    free_groups: Vec<u64>,
    groups: HashMap<u64, GroupEntry>,
    /// Free FGP pages: (ppn, group_epoch).
    fgp_pool: Vec<(u64, u32)>,
    /// Free CGP pages per stack: (ppn, group_epoch).
    cgp_pools: Vec<Vec<(u64, u32)>>,
    /// Free 2 MB frames per stack (base PPN of the frame), carved from
    /// fresh memory by [`Self::alloc_huge_cgp`] but landing on a stack the
    /// caller didn't ask for.
    huge_pools: Vec<Vec<u64>>,
    mapper: AddressMapper,
    /// The §7.2 large-page mapper: stack selection from the bits above the
    /// 2 MB boundary, used to steer whole huge frames onto one stack.
    huge_mapper: AddressMapper,
    pages_allocated: u64,
}

impl PhysAllocator {
    pub fn new(cfg: &SystemConfig) -> Self {
        let mapper = AddressMapper::new(cfg);
        let total_pages = cfg.stack_capacity / cfg.page_size * cfg.num_stacks as u64;
        let group_len = cfg.num_stacks as u64;
        Self {
            group_len,
            total_groups: total_pages / group_len,
            next_fresh: 0,
            free_groups: Vec::new(),
            groups: HashMap::new(),
            fgp_pool: Vec::new(),
            cgp_pools: vec![Vec::new(); cfg.num_stacks],
            huge_pools: vec![Vec::new(); cfg.num_stacks],
            mapper,
            huge_mapper: large_page_mapper(cfg),
            pages_allocated: 0,
        }
    }

    fn take_free_group(&mut self) -> Option<u64> {
        if let Some(g) = self.free_groups.pop() {
            return Some(g);
        }
        if self.next_fresh < self.total_groups {
            let g = self.next_fresh;
            self.next_fresh += 1;
            return Some(g);
        }
        None
    }

    fn commit_group(&mut self, g: u64, mode: Granularity) -> u32 {
        let epoch = self.groups.get(&g).map(|e| e.epoch).unwrap_or(0);
        self.groups.insert(
            g,
            GroupEntry {
                mode,
                used: 0,
                epoch,
            },
        );
        epoch
    }

    /// Pop a valid page from a pool, discarding entries invalidated by
    /// group recycling.
    fn pop_valid(groups: &HashMap<u64, GroupEntry>, pool: &mut Vec<(u64, u32)>, group_len: u64, mode: Granularity) -> Option<u64> {
        while let Some((ppn, epoch)) = pool.pop() {
            let g = ppn / group_len;
            if let Some(e) = groups.get(&g) {
                if e.epoch == epoch && e.mode == mode && e.used & (1 << (ppn % group_len)) == 0 {
                    return Some(ppn);
                }
            }
        }
        None
    }

    fn mark_used(&mut self, ppn: u64) {
        let g = ppn / self.group_len;
        let e = self.groups.get_mut(&g).expect("group committed");
        e.used |= 1 << (ppn % self.group_len);
        self.pages_allocated += 1;
    }

    /// Allocate one fine-grain page (striped across all stacks).
    pub fn alloc_fgp(&mut self) -> crate::Result<u64> {
        if let Some(ppn) = Self::pop_valid(&self.groups, &mut self.fgp_pool, self.group_len, Granularity::Fgp) {
            self.mark_used(ppn);
            return Ok(ppn);
        }
        let Some(g) = self.take_free_group() else {
            bail!("out of physical memory (FGP)");
        };
        let epoch = self.commit_group(g, Granularity::Fgp);
        let base = g * self.group_len;
        // Hand out page 0 now; pool the rest.
        for i in (1..self.group_len).rev() {
            self.fgp_pool.push((base + i, epoch));
        }
        self.mark_used(base);
        Ok(base)
    }

    /// Allocate one coarse-grain page resident entirely on `stack`.
    ///
    /// Within a CGP group with base PPN `B` (group-aligned), page `B+i` maps
    /// to stack `i`, so each group supplies exactly one page per stack.
    pub fn alloc_cgp(&mut self, stack: usize) -> crate::Result<u64> {
        if stack >= self.cgp_pools.len() {
            bail!("stack {stack} out of range");
        }
        if let Some(ppn) = Self::pop_valid(
            &self.groups,
            &mut self.cgp_pools[stack],
            self.group_len,
            Granularity::Cgp,
        ) {
            self.mark_used(ppn);
            return Ok(ppn);
        }
        let Some(g) = self.take_free_group() else {
            bail!("out of physical memory (CGP, stack {stack})");
        };
        let epoch = self.commit_group(g, Granularity::Cgp);
        let base = g * self.group_len;
        let mut target = None;
        for i in 0..self.group_len {
            let ppn = base + i;
            let s = self.mapper.stack_of_ppn_cgp(ppn);
            if s == stack && target.is_none() {
                target = Some(ppn);
            } else {
                self.cgp_pools[s].push((ppn, epoch));
            }
        }
        let ppn = target.expect("aligned group covers every stack exactly once");
        self.mark_used(ppn);
        Ok(ppn)
    }

    /// Mark every page of group `g` as used under CGP mode (a huge frame
    /// consumes its groups whole; the per-page pools never see them).
    fn commit_group_full(&mut self, g: u64) {
        let epoch = self.groups.get(&g).map(|e| e.epoch).unwrap_or(0);
        let full = if self.group_len == 64 {
            u64::MAX
        } else {
            (1u64 << self.group_len) - 1
        };
        self.groups.insert(
            g,
            GroupEntry {
                mode: Granularity::Cgp,
                used: full,
                epoch,
            },
        );
        self.pages_allocated += self.group_len;
    }

    /// Allocate one naturally aligned 2 MB frame (`span_pages` base pages)
    /// resident entirely on `stack`; returns the frame's base PPN.
    ///
    /// Frames are carved from never-touched memory at frame alignment:
    /// the fresh-group cursor is rounded up (skipped groups recycle
    /// through `free_groups`, so no capacity is lost), and because under
    /// the large-page mapper consecutive huge frames cycle round-robin
    /// over the stacks, frames carved for the wrong stack pool up in
    /// `huge_pools` for later requests. `span_pages` must be a multiple
    /// of the group length (config validation guarantees it).
    pub fn alloc_huge_cgp(&mut self, stack: usize, span_pages: u64) -> crate::Result<u64> {
        if stack >= self.cgp_pools.len() {
            bail!("stack {stack} out of range");
        }
        debug_assert_eq!(span_pages % self.group_len, 0, "frame covers whole groups");
        if let Some(base) = self.huge_pools[stack].pop() {
            for k in 0..span_pages / self.group_len {
                self.commit_group_full(base / self.group_len + k);
            }
            return Ok(base);
        }
        let groups_per_frame = span_pages / self.group_len;
        loop {
            // Round the fresh cursor up to a frame boundary; skipped groups
            // stay allocatable as ordinary 4 KB groups.
            while self.next_fresh % groups_per_frame != 0
                && self.next_fresh < self.total_groups
            {
                self.free_groups.push(self.next_fresh);
                self.next_fresh += 1;
            }
            if self.next_fresh + groups_per_frame > self.total_groups {
                bail!("out of physical memory (huge frame, stack {stack})");
            }
            let base = self.next_fresh * self.group_len;
            self.next_fresh += groups_per_frame;
            let frame_stack = self.huge_mapper.stack_of_ppn_cgp(base / span_pages);
            if frame_stack == stack {
                for k in 0..groups_per_frame {
                    self.commit_group_full(base / self.group_len + k);
                }
                return Ok(base);
            }
            self.huge_pools[frame_stack].push(base);
        }
    }

    /// Free a page. When its whole group becomes free, the group may be
    /// re-committed to either mode by a later allocation (the paper's
    /// conversion rule).
    pub fn free(&mut self, ppn: u64) {
        let g = ppn / self.group_len;
        let Some(e) = self.groups.get_mut(&g) else {
            panic!("freeing page {ppn} of unknown group");
        };
        let bit = 1 << (ppn % self.group_len);
        assert!(e.used & bit != 0, "double free of ppn {ppn}");
        e.used &= !bit;
        self.pages_allocated -= 1;
        if e.used == 0 {
            e.epoch += 1; // invalidate pooled siblings
            self.free_groups.push(g);
        } else {
            // Return this single page to its mode pool.
            let epoch = e.epoch;
            match e.mode {
                Granularity::Fgp => self.fgp_pool.push((ppn, epoch)),
                Granularity::Cgp => {
                    let s = self.mapper.stack_of_ppn_cgp(ppn);
                    self.cgp_pools[s].push((ppn, epoch));
                }
            }
        }
    }

    /// Mode of the group a page belongs to (None if never allocated).
    pub fn group_mode(&self, ppn: u64) -> Option<Granularity> {
        self.groups.get(&(ppn / self.group_len)).map(|e| e.mode)
    }

    pub fn pages_allocated(&self) -> u64 {
        self.pages_allocated
    }
}

/// A flat per-workload virtual address space with CODA-aware translation.
#[derive(Debug)]
pub struct VirtualMemory {
    page_size: u64,
    page_shift: u32,
    table: Vec<Option<Pte>>, // indexed by VPN; dense per-workload space
    alloc: PhysAllocator,
    next_vpn: u64,
    /// Huge-page promotion enabled (`cfg.huge_pages` and the geometry
    /// supports it).
    huge_enabled: bool,
    /// Base pages per 2 MB frame ([`HUGE_PAGE_BYTES`] / page_size).
    huge_span: u64,
    /// 2 MB mappings created by promotion.
    huge_frames: u64,
    /// Base pages covered by huge mappings (huge_frames * huge_span).
    huge_covered: u64,
    /// Mapped (non-hole) base pages.
    mapped_count: u64,
}

impl VirtualMemory {
    pub fn new(cfg: &SystemConfig) -> Self {
        let huge_span = if cfg.page_size <= HUGE_PAGE_BYTES && HUGE_PAGE_BYTES % cfg.page_size == 0
        {
            HUGE_PAGE_BYTES / cfg.page_size
        } else {
            0
        };
        Self {
            page_size: cfg.page_size,
            page_shift: cfg.page_size.trailing_zeros(),
            table: Vec::new(),
            alloc: PhysAllocator::new(cfg),
            next_vpn: 0,
            huge_enabled: cfg.huge_pages && huge_span >= cfg.num_stacks as u64,
            huge_span,
            huge_frames: 0,
            huge_covered: 0,
            mapped_count: 0,
        }
    }

    pub fn page_size(&self) -> u64 {
        self.page_size
    }

    fn push_pte(&mut self, pte: Pte) -> u64 {
        let vpn = self.next_vpn;
        self.next_vpn += 1;
        if self.table.len() <= vpn as usize {
            self.table.resize(vpn as usize + 1, None);
        }
        self.table[vpn as usize] = Some(pte);
        self.mapped_count += 1;
        vpn
    }

    /// Advance past one unmapped VPN (alignment hole before a huge frame).
    fn push_hole(&mut self) {
        let vpn = self.next_vpn;
        self.next_vpn += 1;
        if self.table.len() <= vpn as usize {
            self.table.resize(vpn as usize + 1, None);
        }
    }

    /// Map `n_pages` fine-grain pages; returns the base virtual address.
    ///
    /// FGP regions are never huge-page candidates: fine-grain interleaving
    /// stripes each base page across every stack, so a 2 MB mapping would
    /// have no single stack to live on — the CGP/FGP tension the huge-page
    /// experiment measures.
    pub fn map_fgp(&mut self, n_pages: u64) -> crate::Result<VirtualAddress> {
        let base = self.next_vpn;
        for _ in 0..n_pages {
            let ppn = self.alloc.alloc_fgp()?;
            self.push_pte(Pte {
                ppn,
                granularity: Granularity::Fgp,
                huge: false,
            });
        }
        Ok(VirtualAddress(base << self.page_shift))
    }

    /// Map `n_pages` coarse-grain pages; `stack_of_page(i)` names the target
    /// stack for the i-th page (this is where Eq 3 plugs in). Returns the
    /// base virtual address.
    ///
    /// With huge pages on, aligned runs of [`Self::huge_span`] pages whose
    /// requested stacks agree are promoted to one 2 MB mapping (the base
    /// PTEs carry `huge` and a contiguous, frame-aligned PPN range); mixed
    /// or tail runs fall back to base pages. The plan callback may be
    /// probed more than once per page when checking run uniformity, so it
    /// must be a pure function of the page index (every caller's is).
    pub fn map_cgp(
        &mut self,
        n_pages: u64,
        mut stack_of_page: impl FnMut(u64) -> usize,
    ) -> crate::Result<VirtualAddress> {
        if !self.huge_enabled || n_pages < self.huge_span {
            let base = self.next_vpn;
            for i in 0..n_pages {
                let ppn = self.alloc.alloc_cgp(stack_of_page(i))?;
                self.push_pte(Pte {
                    ppn,
                    granularity: Granularity::Cgp,
                    huge: false,
                });
            }
            return Ok(VirtualAddress(base << self.page_shift));
        }
        // Align the region so promoted chunks are naturally aligned in
        // virtual space (huge TLB entries and the one-level-shorter walk
        // both assume VA alignment).
        while self.next_vpn % self.huge_span != 0 {
            self.push_hole();
        }
        let base = self.next_vpn;
        let mut i = 0;
        while i < n_pages {
            if n_pages - i >= self.huge_span {
                let stack0 = stack_of_page(i);
                if (1..self.huge_span).all(|k| stack_of_page(i + k) == stack0) {
                    let frame = self.alloc.alloc_huge_cgp(stack0, self.huge_span)?;
                    for k in 0..self.huge_span {
                        self.push_pte(Pte {
                            ppn: frame + k,
                            granularity: Granularity::Cgp,
                            huge: true,
                        });
                    }
                    self.huge_frames += 1;
                    self.huge_covered += self.huge_span;
                    i += self.huge_span;
                    continue;
                }
            }
            let ppn = self.alloc.alloc_cgp(stack_of_page(i))?;
            self.push_pte(Pte {
                ppn,
                granularity: Granularity::Cgp,
                huge: false,
            });
            i += 1;
        }
        Ok(VirtualAddress(base << self.page_shift))
    }

    /// Translate a virtual address. Returns (physical address, granularity).
    #[inline]
    pub fn translate(&self, vaddr: VirtualAddress) -> Option<(PhysicalAddress, Granularity)> {
        let vpn = (vaddr.0 >> self.page_shift) as usize;
        let pte = (*self.table.get(vpn)?)?;
        let off = vaddr.0 & (self.page_size - 1);
        Some((
            PhysicalAddress((pte.ppn << self.page_shift) | off),
            pte.granularity,
        ))
    }

    /// The PTE for a virtual page (the page-table walk's result; also used
    /// by tests and migration).
    pub fn pte_of(&self, vaddr: VirtualAddress) -> Option<Pte> {
        *self.table.get((vaddr.0 >> self.page_shift) as usize)?
    }

    /// Remap one virtual page onto a freshly allocated CGP page on `stack`
    /// (used by the migration-based first-touch baseline, §6.1 fn.6).
    pub fn migrate_to_cgp(&mut self, vaddr: VirtualAddress, stack: usize) -> crate::Result<()> {
        let vpn = (vaddr.0 >> self.page_shift) as usize;
        let Some(Some(old)) = self.table.get(vpn).copied() else {
            bail!("migrating unmapped page");
        };
        let ppn = self.alloc.alloc_cgp(stack)?;
        self.table[vpn] = Some(Pte {
            ppn,
            granularity: Granularity::Cgp,
            huge: false,
        });
        self.alloc.free(old.ppn);
        Ok(())
    }

    pub fn allocator(&self) -> &PhysAllocator {
        &self.alloc
    }

    /// Number of virtual pages the address space spans (engine bitmap
    /// sizing; includes alignment holes).
    pub fn mapped_pages(&self) -> u64 {
        self.next_vpn
    }

    /// 2 MB mappings created by promotion.
    pub fn huge_frames(&self) -> u64 {
        self.huge_frames
    }

    /// Fraction of mapped base pages covered by huge mappings (the report's
    /// huge-page coverage; 0 when promotion is off or nothing qualified).
    pub fn huge_coverage(&self) -> f64 {
        if self.mapped_count == 0 {
            0.0
        } else {
            self.huge_covered as f64 / self.mapped_count as f64
        }
    }
}

/// A set-associative TLB with LRU replacement, carrying the granularity bit
/// alongside each translation (Fig 5).
#[derive(Debug)]
pub struct Tlb {
    sets: Vec<Vec<(u64, Pte, u64)>>, // (vpn, pte, last_used)
    ways: usize,
    set_mask: u64,
    tick: u64,
    pub hits: u64,
    pub misses: u64,
}

impl Tlb {
    /// Build a TLB of exactly `entries` entries at up to 4-way
    /// associativity (the historical default). See [`Self::with_ways`] for
    /// the representability contract.
    pub fn new(entries: usize) -> Self {
        Self::with_ways(entries, 4)
    }

    /// Build a TLB of exactly `entries` entries, at the widest
    /// associativity `<= max_ways` that yields a power-of-two set count.
    ///
    /// The budget is honored exactly — the old constructor rounded
    /// `entries / ways` up to the next power of two, silently inflating
    /// e.g. a 48-entry request into a 64-entry TLB. Sizes with no
    /// `ways * 2^k` factorization under `max_ways` (e.g. 7) are a panic
    /// here; config validation rejects them first with a proper error.
    pub fn with_ways(entries: usize, max_ways: usize) -> Self {
        let entries = entries.max(1);
        let max_ways = max_ways.clamp(1, entries);
        let ways = (1..=max_ways)
            .rev()
            .find(|&w| entries % w == 0 && (entries / w).is_power_of_two())
            .unwrap_or_else(|| {
                panic!("TLB size {entries} not representable as ways*2^k with ways <= {max_ways}")
            });
        let sets = entries / ways;
        Self {
            sets: vec![Vec::with_capacity(ways); sets],
            ways,
            set_mask: sets as u64 - 1,
            tick: 0,
            hits: 0,
            misses: 0,
        }
    }

    /// Total entries this TLB can hold (`sets * ways`).
    pub fn capacity(&self) -> usize {
        self.sets.len() * self.ways
    }

    /// Drop every cached translation (address-space switch); the hit/miss
    /// counters survive — they describe the access stream, not the content.
    pub fn flush(&mut self) {
        for set in &mut self.sets {
            set.clear();
        }
    }

    /// Look up a VPN; on miss the caller walks the page table and calls
    /// [`Self::fill`]. Returns the cached PTE on hit.
    pub fn lookup(&mut self, vpn: u64) -> Option<Pte> {
        self.tick += 1;
        let set = &mut self.sets[(vpn & self.set_mask) as usize];
        if let Some(entry) = set.iter_mut().find(|e| e.0 == vpn) {
            entry.2 = self.tick;
            self.hits += 1;
            return Some(entry.1);
        }
        self.misses += 1;
        None
    }

    pub fn fill(&mut self, vpn: u64, pte: Pte) {
        self.tick += 1;
        let tick = self.tick;
        let ways = self.ways;
        let set = &mut self.sets[(vpn & self.set_mask) as usize];
        if let Some(entry) = set.iter_mut().find(|e| e.0 == vpn) {
            *entry = (vpn, pte, tick);
            return;
        }
        if set.len() < ways {
            set.push((vpn, pte, tick));
        } else {
            let lru = set
                .iter_mut()
                .min_by_key(|e| e.2)
                .expect("non-empty set");
            *lru = (vpn, pte, tick);
        }
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> SystemConfig {
        SystemConfig::test_small()
    }

    #[test]
    fn fgp_alloc_walks_groups() {
        let mut a = PhysAllocator::new(&cfg());
        let p0 = a.alloc_fgp().unwrap();
        assert_eq!(p0, 0);
        assert_eq!(a.group_mode(p0), Some(Granularity::Fgp));
        // Next three come from the same group's pool.
        let mut rest: Vec<u64> = (0..3).map(|_| a.alloc_fgp().unwrap()).collect();
        rest.sort_unstable();
        assert_eq!(rest, vec![1, 2, 3]);
    }

    #[test]
    fn cgp_alloc_targets_requested_stack() {
        let c = cfg();
        let mapper = AddressMapper::new(&c);
        let mut a = PhysAllocator::new(&c);
        for stack in [2usize, 0, 3, 1, 2, 2] {
            let ppn = a.alloc_cgp(stack).unwrap();
            assert_eq!(mapper.stack_of_ppn_cgp(ppn), stack);
            assert_eq!(a.group_mode(ppn), Some(Granularity::Cgp));
        }
    }

    #[test]
    fn group_modes_are_exclusive_until_freed() {
        let mut a = PhysAllocator::new(&cfg());
        let f = a.alloc_fgp().unwrap(); // commits group 0 to FGP
        let c0 = a.alloc_cgp(0).unwrap(); // must come from a different group
        assert_ne!(f / 4, c0 / 4, "FGP and CGP pages never share a group");
    }

    #[test]
    fn group_conversion_requires_fully_free() {
        let mut a = PhysAllocator::new(&cfg());
        // Fill group 0 as FGP.
        let pages: Vec<u64> = (0..4).map(|_| a.alloc_fgp().unwrap()).collect();
        assert!(pages.iter().all(|p| p / 4 == 0));
        // Free all 4 -> group recycles; a CGP allocation may now claim it.
        for p in pages {
            a.free(p);
        }
        let c = a.alloc_cgp(1).unwrap();
        assert_eq!(c / 4, 0, "recycled group reused in the other mode");
        assert_eq!(a.group_mode(c), Some(Granularity::Cgp));
    }

    #[test]
    fn stale_pool_entries_are_invalidated() {
        let mut a = PhysAllocator::new(&cfg());
        let f = a.alloc_fgp().unwrap(); // group 0 FGP; 3 siblings pooled
        a.free(f); // group 0 fully free; siblings stale
        let c = a.alloc_cgp(2).unwrap(); // may recycle group 0 as CGP
        assert_eq!(a.group_mode(c), Some(Granularity::Cgp));
        // FGP allocation must NOT return a stale group-0 sibling.
        let f2 = a.alloc_fgp().unwrap();
        assert_ne!(f2 / 4, c / 4);
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut a = PhysAllocator::new(&cfg());
        let p = a.alloc_fgp().unwrap();
        a.free(p);
        a.free(p);
    }

    #[test]
    fn allocator_exhaustion() {
        let mut c = cfg();
        c.stack_capacity = 4 * c.page_size; // 4 pages/stack -> 16 pages total
        let mut a = PhysAllocator::new(&c);
        for _ in 0..16 {
            a.alloc_fgp().unwrap();
        }
        assert!(a.alloc_fgp().is_err());
    }

    #[test]
    fn vm_translate_fgp_and_cgp() {
        let c = cfg();
        let mut vm = VirtualMemory::new(&c);
        let v_f = vm.map_fgp(2).unwrap();
        let v_c = vm.map_cgp(2, |_| 3).unwrap();
        let (p, g) = vm.translate(v_f + 100).unwrap();
        assert_eq!(g, Granularity::Fgp);
        assert_eq!(p.0 & 0xFFF, 100);
        let (p, g) = vm.translate(v_c + 5000).unwrap();
        assert_eq!(g, Granularity::Cgp);
        assert_eq!(p.0 & 0xFFF, 5000 & 0xFFF);
        let mapper = AddressMapper::new(&c);
        assert_eq!(mapper.stack_of(p, g), 3);
        assert!(vm.translate(VirtualAddress(1 << 40)).is_none());
    }

    #[test]
    fn vm_migration_changes_stack_and_granularity() {
        let c = cfg();
        let mapper = AddressMapper::new(&c);
        let mut vm = VirtualMemory::new(&c);
        let v = vm.map_fgp(1).unwrap();
        assert_eq!(vm.pte_of(v).unwrap().granularity, Granularity::Fgp);
        vm.migrate_to_cgp(v, 2).unwrap();
        let (p, g) = vm.translate(v).unwrap();
        assert_eq!(g, Granularity::Cgp);
        assert_eq!(mapper.stack_of(p, g), 2);
    }

    #[test]
    fn tlb_hits_after_fill_and_lru_evicts() {
        let mut tlb = Tlb::new(8); // 4-way, 2 sets
        let pte = |ppn| Pte {
            ppn,
            granularity: Granularity::Fgp,
            huge: false,
        };
        assert!(tlb.lookup(0).is_none());
        tlb.fill(0, pte(10));
        assert_eq!(tlb.lookup(0).unwrap().ppn, 10);
        // Fill one set (even vpns) beyond capacity; vpn 0 stays hot.
        for vpn in [2u64, 4, 6] {
            tlb.fill(vpn, pte(vpn));
            tlb.lookup(0);
        }
        tlb.fill(8, pte(8)); // evicts LRU (vpn 2)
        assert!(tlb.lookup(0).is_some());
        assert!(tlb.lookup(2).is_none());
        assert!(tlb.hit_rate() > 0.0);
    }

    #[test]
    fn tlb_honors_the_requested_budget() {
        // The old constructor rounded 48/4 = 12 sets up to 16, silently
        // building a 64-entry TLB; 48 must now mean 48 (3-way x 16 sets).
        assert_eq!(Tlb::new(48).capacity(), 48);
        // Historical geometries are preserved exactly (bit-exactness of
        // every existing run depends on it).
        for entries in [1usize, 2, 4, 8, 16, 32, 64, 128] {
            assert_eq!(Tlb::new(entries).capacity(), entries);
        }
        assert_eq!(Tlb::with_ways(512, 8).capacity(), 512);
    }

    #[test]
    #[should_panic(expected = "not representable")]
    fn tlb_rejects_non_representable_sizes() {
        let _ = Tlb::new(7); // no ways<=4 divides 7 into 2^k sets
    }

    #[test]
    fn tlb_flush_drops_translations_but_keeps_counters() {
        let mut tlb = Tlb::new(8);
        tlb.fill(
            3,
            Pte {
                ppn: 9,
                granularity: Granularity::Cgp,
                huge: false,
            },
        );
        assert!(tlb.lookup(3).is_some());
        let hits = tlb.hits;
        tlb.flush();
        assert!(tlb.lookup(3).is_none(), "flush must drop the entry");
        assert_eq!(tlb.hits, hits, "counters describe the stream, not content");
    }

    fn huge_cfg() -> SystemConfig {
        let mut c = cfg();
        c.huge_pages = true;
        c
    }

    #[test]
    fn cgp_runs_promote_to_huge_frames() {
        let c = huge_cfg();
        let span = HUGE_PAGE_BYTES / c.page_size; // 512 pages
        let mut vm = VirtualMemory::new(&c);
        let v = vm.map_cgp(span, |_| 2).unwrap();
        assert_eq!(vm.huge_frames(), 1);
        assert!((vm.huge_coverage() - 1.0).abs() < 1e-12);
        let pte = vm.pte_of(v).unwrap();
        assert!(pte.huge);
        assert_eq!(pte.granularity, Granularity::Cgp);
        // Frame-aligned, contiguous PPNs; the whole frame on stack 2 under
        // the large-page mapper.
        assert_eq!(pte.ppn % span, 0);
        let last = vm.pte_of(v + (span - 1) * c.page_size).unwrap();
        assert_eq!(last.ppn, pte.ppn + span - 1);
        let lm = large_page_mapper(&c);
        assert_eq!(lm.stack_of_ppn_cgp(pte.ppn / span), 2);
    }

    #[test]
    fn mixed_stack_runs_and_tails_stay_base_pages() {
        let c = huge_cfg();
        let span = HUGE_PAGE_BYTES / c.page_size;
        let mut vm = VirtualMemory::new(&c);
        // Per-page round-robin stacks: no uniform run, nothing promotes.
        let v = vm.map_cgp(span, |p| (p % 4) as usize).unwrap();
        assert_eq!(vm.huge_frames(), 0);
        assert_eq!(vm.huge_coverage(), 0.0);
        assert!(!vm.pte_of(v).unwrap().huge);
        // A uniform run with a tail promotes the aligned chunk only.
        let v2 = vm.map_cgp(span + 3, |_| 1).unwrap();
        assert_eq!(vm.huge_frames(), 1);
        assert!(vm.pte_of(v2).unwrap().huge);
        assert!(!vm.pte_of(v2 + span * c.page_size).unwrap().huge);
    }

    #[test]
    fn huge_off_and_fgp_are_untouched() {
        let c = cfg(); // huge_pages defaults off
        let mut vm = VirtualMemory::new(&c);
        let span = HUGE_PAGE_BYTES / c.page_size;
        let v = vm.map_cgp(span, |_| 0).unwrap();
        assert_eq!(vm.huge_frames(), 0);
        assert!(!vm.pte_of(v).unwrap().huge);
        // FGP never promotes even with huge pages on (striping fights 2 MB
        // frames — each base page spreads over every stack).
        let mut vm = VirtualMemory::new(&huge_cfg());
        let v = vm.map_fgp(span).unwrap();
        assert_eq!(vm.huge_frames(), 0);
        assert_eq!(vm.huge_coverage(), 0.0);
        assert!(!vm.pte_of(v).unwrap().huge);
    }

    #[test]
    fn huge_frame_allocator_steers_stacks_and_reuses_pool() {
        let mut c = huge_cfg();
        c.stack_capacity = 16 << 20; // 16 MB/stack: room for a few frames
        let span = HUGE_PAGE_BYTES / c.page_size;
        let lm = large_page_mapper(&c);
        let mut a = PhysAllocator::new(&c);
        // Asking for stack 3 first forces frames 0..3 into the pools.
        let f3 = a.alloc_huge_cgp(3, span).unwrap();
        assert_eq!(lm.stack_of_ppn_cgp(f3 / span), 3);
        // Stack 0's frame now comes from the pool (frame 0), not fresh.
        let f0 = a.alloc_huge_cgp(0, span).unwrap();
        assert_eq!(f0, 0);
        // Base-page allocation still works alongside frames.
        let p = a.alloc_cgp(1).unwrap();
        assert_eq!(AddressMapper::new(&c).stack_of_ppn_cgp(p), 1);
    }
}
