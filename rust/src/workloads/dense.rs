//! Dense / structured benchmarks (Rodinia + Parboil): KM, CFD-M, NN, GE,
//! SPMV, SAD, MM, NW, MG, DWT, HS3D, HS.
//!
//! Each generator executes the actual index arithmetic of the original
//! kernel (K-means' `in[pid*nfeatures+i]`, MM's tiled `A[i][k]*B[k][j]`,
//! stencils' halo reads, ...) so the page-sharing profile is emergent.
//! Regular kernels also ship a [`KernelIr`] so the compile-time symbolic
//! analysis runs end-to-end; GE's pivot broadcasts and MG's tree descent
//! are the irregular/profiled cases.

use super::{BuiltWorkload, Emitter};
use crate::analysis::{AccessExpr, Expr, KernelIr, ParamEnv};
use crate::config::SystemConfig;
use crate::rng::Rng;
use crate::trace::{BlockTrace, Category, KernelTrace, ObjectDesc};

fn mk_trace(
    name: &str,
    tpb: u32,
    objects: Vec<ObjectDesc>,
    blocks: Vec<BlockTrace>,
) -> KernelTrace {
    KernelTrace {
        name: name.into(),
        threads_per_block: tpb,
        objects,
        blocks,
    }
}

/// KM — K-means clustering, the paper's Fig 7 running example.
/// `in[pid*nfeatures+i]` (contiguous per block) and the transposed
/// `out[i*npoints+pid]` (strided; 4 consecutive blocks per page).
pub fn kmeans(cfg: &SystemConfig) -> BuiltWorkload {
    let tpb: u32 = 256;
    let npoints: u64 = 262_144;
    let nfeatures: u64 = 4;
    let nclusters: u64 = 8;
    let num_blocks = (npoints as u32).div_ceil(tpb);
    let mut blocks = Vec::with_capacity(num_blocks as usize);
    let mut em = Emitter::new(cfg.line_size);
    for b in 0..num_blocks as u64 {
        let p_lo = b * tpb as u64;
        let p_hi = (p_lo + tpb as u64).min(npoints);
        // in: contiguous [p_lo*F, p_hi*F) floats.
        em.touch(0, p_lo * nfeatures * 4, (p_hi - p_lo) * nfeatures * 4, false);
        // centroids: every block reads all K*F floats (shared).
        em.touch(2, 0, nclusters * nfeatures * 4, false);
        // out (transposed): for each feature i, a tpb-wide stripe.
        for i in 0..nfeatures {
            em.touch(1, (i * npoints + p_lo) * 4, (p_hi - p_lo) * 4, true);
        }
        // membership write: one int per point.
        em.touch(3, p_lo * 4, (p_hi - p_lo) * 4, true);
        blocks.push(BlockTrace {
            block_id: b as u32,
            accesses: em.take(),
        });
    }
    let objects = vec![
        ObjectDesc {
            name: "feature_flipped_d".into(),
            bytes: npoints * nfeatures * 4,
        },
        ObjectDesc {
            name: "feature_d".into(),
            bytes: npoints * nfeatures * 4,
        },
        ObjectDesc {
            name: "clusters".into(),
            bytes: nclusters * nfeatures * 4,
        },
        ObjectDesc {
            name: "membership".into(),
            bytes: npoints * 4,
        },
    ];
    // The Fig-7 kernel IR, verbatim: in[pid*nfeatures+i], out[i*npoints+pid].
    let ir = KernelIr {
        name: "kmeans".into(),
        accesses: vec![
            AccessExpr {
                object: 0,
                index: Expr::add(
                    Expr::mul(Expr::pid(), Expr::Param("nfeatures")),
                    Expr::Loop(0, Box::new(Expr::Param("nfeatures"))),
                ),
                elem_size: 4,
            },
            AccessExpr {
                object: 1,
                index: Expr::add(
                    Expr::mul(
                        Expr::Loop(0, Box::new(Expr::Param("nfeatures"))),
                        Expr::Param("npoints"),
                    ),
                    Expr::pid(),
                ),
                elem_size: 4,
            },
            AccessExpr {
                object: 2,
                index: Expr::add(
                    Expr::mul(
                        Expr::Loop(1, Box::new(Expr::Param("nclusters"))),
                        Expr::Param("nfeatures"),
                    ),
                    Expr::Loop(0, Box::new(Expr::Param("nfeatures"))),
                ),
                elem_size: 4,
            },
            AccessExpr {
                object: 3,
                index: Expr::pid(),
                elem_size: 4,
            },
        ],
    };
    BuiltWorkload {
        name: "KM",
        category: Category::CoreExclusive,
        trace: mk_trace("KM", tpb, objects, blocks),
        ir: Some(ir),
        env: ParamEnv::new(tpb as i64)
            .with("nfeatures", nfeatures as i64)
            .with("npoints", npoints as i64)
            .with("nclusters", nclusters as i64),
    }
}

/// NN — k-nearest neighbors: each thread one record (contiguous), one
/// query point broadcast.
pub fn nearest_neighbor(cfg: &SystemConfig) -> BuiltWorkload {
    let tpb: u32 = 256;
    let nrecords: u64 = 1_048_576;
    let rec_bytes: u64 = 8; // lat/lng pair
    let num_blocks = (nrecords as u32).div_ceil(tpb);
    let mut blocks = Vec::with_capacity(num_blocks as usize);
    let mut em = Emitter::new(cfg.line_size);
    for b in 0..num_blocks as u64 {
        let lo = b * tpb as u64;
        let hi = (lo + tpb as u64).min(nrecords);
        em.touch(0, lo * rec_bytes, (hi - lo) * rec_bytes, false);
        em.touch(2, 0, 8, false); // query point
        em.touch(1, lo * 4, (hi - lo) * 4, true); // distance write
        blocks.push(BlockTrace {
            block_id: b as u32,
            accesses: em.take(),
        });
    }
    let objects = vec![
        ObjectDesc {
            name: "records".into(),
            bytes: nrecords * rec_bytes,
        },
        ObjectDesc {
            name: "distances".into(),
            bytes: nrecords * 4,
        },
        ObjectDesc {
            name: "query".into(),
            bytes: 8,
        },
    ];
    let ir = KernelIr {
        name: "nn".into(),
        accesses: vec![
            AccessExpr {
                object: 0,
                index: Expr::pid(),
                elem_size: rec_bytes as u32,
            },
            AccessExpr {
                object: 1,
                index: Expr::pid(),
                elem_size: 4,
            },
            AccessExpr {
                object: 2,
                index: Expr::Const(0),
                elem_size: 8,
            },
        ],
    };
    BuiltWorkload {
        name: "NN",
        category: Category::CoreExclusive,
        trace: mk_trace("NN", tpb, objects, blocks),
        ir: Some(ir),
        env: ParamEnv::new(tpb as i64),
    }
}

/// SPMV — CSR sparse matrix-vector multiply, one row per thread. Row data
/// is fine enough that a page holds several blocks' rows (core-exclusive);
/// the x-vector gathers are shared.
pub fn spmv(cfg: &SystemConfig) -> BuiltWorkload {
    let tpb: u32 = 256;
    let rows: usize = 98_304;
    let g = super::graph::CsrGraph::generate(&super::graph::GraphSpec {
        num_vertices: rows,
        avg_degree: 8.0,
        degree_cv: 0.5,
        locality: 0.85,
        window: 1024,
        seed: cfg.seed ^ 0x59A7,
    });
    let num_blocks = (rows as u32).div_ceil(tpb);
    let mut blocks = Vec::with_capacity(num_blocks as usize);
    let mut em = Emitter::new(cfg.line_size);
    for b in 0..num_blocks {
        let lo = (b * tpb) as usize;
        let hi = ((b + 1) * tpb).min(rows as u32) as usize;
        em.touch(0, lo as u64 * 4, (hi - lo) as u64 * 4 + 4, false); // ptr
        for r in lo..hi {
            let (e0, e1) = (g.offsets[r] as u64, g.offsets[r + 1] as u64);
            if e1 > e0 {
                em.touch(1, e0 * 4, (e1 - e0) * 4, false); // indices
                em.touch(2, e0 * 4, (e1 - e0) * 4, false); // data
                for &c in g.neighbors(r) {
                    em.touch(3, c as u64 * 4, 4, false); // x[c] gather
                }
            }
            em.touch(4, r as u64 * 4, 4, true); // y[r]
        }
        blocks.push(BlockTrace {
            block_id: b,
            accesses: em.take(),
        });
    }
    let e = g.num_edges() as u64;
    let objects = vec![
        ObjectDesc {
            name: "row_ptr".into(),
            bytes: (rows as u64 + 1) * 4,
        },
        ObjectDesc {
            name: "col_idx".into(),
            bytes: e * 4,
        },
        ObjectDesc {
            name: "values".into(),
            bytes: e * 4,
        },
        ObjectDesc {
            name: "x".into(),
            bytes: rows as u64 * 4,
        },
        ObjectDesc {
            name: "y".into(),
            bytes: rows as u64 * 4,
        },
    ];
    BuiltWorkload {
        name: "SPMV",
        category: Category::CoreExclusive,
        trace: mk_trace("SPMV", tpb, objects, blocks),
        ir: None,
        env: ParamEnv::new(tpb as i64),
    }
}

/// MM — tiled dense matmul C[M,N] = A[M,K] x B[K,N], 64x64 tiles. A
/// row-band is shared by the 16 consecutive blocks of one tile row (one
/// stack, mostly); B is shared across all; C tiles are private.
pub fn matmul(cfg: &SystemConfig) -> BuiltWorkload {
    let tile: u64 = 64;
    // N = 512 keeps one tile-row's 8 blocks aligned inside a stack's
    // 24-block affinity window.
    let (m, n, k): (u64, u64, u64) = (3072, 512, 64);
    let grid_x = n / tile; // 8
    let grid_y = m / tile; // 48 -> 8x48 = 384 blocks (4 full waves)
    let tpb = (tile * tile / 16) as u32; // 256 threads, 16 elems each
    let mut blocks = Vec::with_capacity((grid_x * grid_y) as usize);
    let mut em = Emitter::new(cfg.line_size);
    for by in 0..grid_y {
        for bx in 0..grid_x {
            let bid = (by * grid_x + bx) as u32;
            // A row-band: rows [by*tile, (by+1)*tile), all K columns.
            for r in 0..tile {
                em.touch(0, ((by * tile + r) * k) * 4, k * 4, false);
            }
            // B col-band: K rows, columns [bx*tile ..). Strided: each row
            // of B contributes one tile-wide segment.
            for r in 0..k {
                em.touch(1, (r * n + bx * tile) * 4, tile * 4, false);
            }
            // C tile write, row segments.
            for r in 0..tile {
                em.touch(2, ((by * tile + r) * n + bx * tile) * 4, tile * 4, true);
            }
            blocks.push(BlockTrace {
                block_id: bid,
                accesses: em.take(),
            });
        }
    }
    let objects = vec![
        ObjectDesc {
            name: "A".into(),
            bytes: m * k * 4,
        },
        ObjectDesc {
            name: "B".into(),
            bytes: k * n * 4,
        },
        ObjectDesc {
            name: "C".into(),
            bytes: m * n * 4,
        },
    ];
    // IR: row-major C access C[(by*tile+r)*N + bx*tile + c]. Flattened
    // block id stride for C is tile*4 bytes per block along x and
    // tile*N*4 along y; with row-major flattening the per-block C
    // footprint advances tile*tile elements on average — expressible as a
    // blockIdx-affine index for the tile-contiguous C layout only. We keep
    // A/B/C as profiler-resolved (the 2-D grid case the paper defers:
    // "we focus on 2-D data structure ... leave 3-D for future work").
    BuiltWorkload {
        name: "MM",
        category: Category::CoreExclusive,
        trace: mk_trace("MM", tpb, objects, blocks),
        ir: None,
        env: ParamEnv::new(tpb as i64),
    }
}

/// GE — Gaussian elimination (Rodinia "gaussian", Fig 9's one benchmark
/// with no remote-access reduction): per iteration every block reads the
/// pivot row (broadcast) and updates its own rows below the pivot.
pub fn gaussian(cfg: &SystemConfig) -> BuiltWorkload {
    // Rodinia's Fan2 uses a 2-D grid: each block owns a (row-band x
    // column-band) tile. Pages stay within one stack (core-exclusive), but
    // the 2-D footprint breaks the 1-D inter-block stride assumption of
    // §4.3.2 — the analysis the paper defers ("we focus on 2-D data
    // structure... leave the extension for future work") — so CODA's
    // placement misaligns and GE sees no remote-access reduction (Fig 9's
    // one exception).
    let dim: u64 = 768; // matrix 768x768 f32 (rows = 6 pages per 8-row band)
    let band_rows: u64 = 8;
    let col_blocks: u64 = 4; // 96 bands x 4 = 384 blocks (4 full waves)
    let cols_per_block = dim / col_blocks;
    let bands = dim / band_rows; // 96
    let num_blocks = (bands * col_blocks) as u32; // 384, band-major
    let tpb = 256u32;
    let iterations = 24u64;
    let mut blocks: Vec<BlockTrace> = (0..num_blocks)
        .map(|b| BlockTrace {
            block_id: b,
            accesses: Vec::new(),
        })
        .collect();
    let mut em = Emitter::new(cfg.line_size);
    for it in 0..iterations {
        let pivot = it * (dim / iterations);
        for band in 0..bands {
            for cb in 0..col_blocks {
                let bid = band * col_blocks + cb;
                let c_lo = (cb * cols_per_block).max(pivot);
                let c_hi = (cb + 1) * cols_per_block;
                if c_lo >= c_hi {
                    continue;
                }
                // Pivot row segment for this block's columns.
                em.touch(0, (pivot * dim + c_lo) * 4, (c_hi - c_lo) * 4, false);
                // Update own tile rows strictly below the pivot.
                for r in band * band_rows..(band + 1) * band_rows {
                    if r > pivot {
                        em.touch(0, (r * dim + c_lo) * 4, (c_hi - c_lo) * 4, false);
                        em.touch(0, (r * dim + c_lo) * 4, (c_hi - c_lo) * 4, true);
                    }
                }
                blocks[bid as usize].accesses.extend(em.take());
            }
        }
    }
    let objects = vec![
        ObjectDesc {
            name: "matrix".into(),
            bytes: dim * dim * 4,
        },
        ObjectDesc {
            name: "multipliers".into(),
            bytes: dim * 4,
        },
    ];
    BuiltWorkload {
        name: "GE",
        category: Category::CoreExclusive,
        trace: mk_trace("GE", tpb, objects, blocks),
        ir: None,
        env: ParamEnv::new(tpb as i64),
    }
}

/// SAD — sum of absolute differences (Parboil): only 61 thread-blocks, the
/// Fig 14 load-imbalance case. Each block owns a band of the current
/// frame and reads an overlapping search window of the reference frame.
pub fn sad(cfg: &SystemConfig) -> BuiltWorkload {
    let width: u64 = 704;
    let height: u64 = 576;
    let band: u64 = height / 61 + 1; // ~10 rows per block
    let num_blocks = 61u32;
    let tpb = 256u32;
    let row_bytes = width; // 1 byte/pixel luma
    let mut blocks = Vec::with_capacity(num_blocks as usize);
    let mut em = Emitter::new(cfg.line_size);
    for b in 0..num_blocks as u64 {
        let r_lo = b * band;
        let r_hi = ((b + 1) * band).min(height);
        for r in r_lo..r_hi {
            em.touch(0, r * row_bytes, row_bytes, false); // cur frame band
        }
        // Reference window: +/- 16 rows around the band.
        let w_lo = r_lo.saturating_sub(16);
        let w_hi = (r_hi + 16).min(height);
        for r in w_lo..w_hi {
            em.touch(1, r * row_bytes, row_bytes, false);
        }
        // SAD results per macroblock (16x16): band/16 rows of mbs.
        let mb_row = width / 16;
        em.touch(2, (r_lo / 16) * mb_row * 4, band.div_ceil(16) * mb_row * 4, true);
        blocks.push(BlockTrace {
            block_id: b as u32,
            accesses: em.take(),
        });
    }
    let objects = vec![
        ObjectDesc {
            name: "cur_frame".into(),
            bytes: width * height,
        },
        ObjectDesc {
            name: "ref_frame".into(),
            bytes: width * height,
        },
        ObjectDesc {
            name: "sad_out".into(),
            bytes: (width / 16) * (height / 16) * 4 * 41, // 41 block types
        },
    ];
    BuiltWorkload {
        name: "SAD",
        category: Category::CoreExclusive,
        trace: mk_trace("SAD", tpb, objects, blocks),
        ir: None,
        env: ParamEnv::new(tpb as i64),
    }
}

/// CFD-M — unstructured-mesh Euler solver: each block owns a cell band and
/// reads neighbor cells across band boundaries (adjacent blocks, mostly
/// same stack).
pub fn cfd(cfg: &SystemConfig) -> BuiltWorkload {
    let ncells: u64 = 262_144;
    let vars: u64 = 5; // density, momentum x3, energy
    let tpb = 256u32;
    let num_blocks = (ncells as u32).div_ceil(tpb);
    let mut rng = Rng::new(cfg.seed ^ 0xCFD0);
    let mut blocks = Vec::with_capacity(num_blocks as usize);
    let mut em = Emitter::new(cfg.line_size);
    for b in 0..num_blocks as u64 {
        let lo = b * tpb as u64;
        let hi = (lo + tpb as u64).min(ncells);
        // Own cell variables (SoA: var-major planes).
        for v in 0..vars {
            em.touch(0, (v * ncells + lo) * 4, (hi - lo) * 4, false);
        }
        // Neighbor gathers: mesh locality — most neighbors within +/- 2*tpb.
        for _ in 0..(hi - lo) {
            // Structured-mesh neighbor bands: neighbors stay within one
            // block span of the owner band.
            let span = tpb as u64;
            let n = rng.range(lo.saturating_sub(span), (hi + span).min(ncells));
            em.touch(0, n * 4, 4, false); // density plane gather
        }
        // Flux writes.
        for v in 0..vars {
            em.touch(1, (v * ncells + lo) * 4, (hi - lo) * 4, true);
        }
        blocks.push(BlockTrace {
            block_id: b as u32,
            accesses: em.take(),
        });
    }
    let objects = vec![
        ObjectDesc {
            name: "variables".into(),
            bytes: ncells * vars * 4,
        },
        ObjectDesc {
            name: "fluxes".into(),
            bytes: ncells * vars * 4,
        },
    ];
    BuiltWorkload {
        name: "CFD",
        category: Category::CoreExclusive,
        trace: mk_trace("CFD", tpb, objects, blocks),
        ir: None,
        env: ParamEnv::new(tpb as i64),
    }
}

/// NW — Needleman-Wunsch with blocked (tile-contiguous) DP matrix layout:
/// each block owns one 64x64 tile plus halo row/col from its neighbors.
pub fn needleman_wunsch(cfg: &SystemConfig) -> BuiltWorkload {
    let tiles: u64 = 24; // 24x24 = 576 tiles (6 full 96-block waves)
    let tile_bytes: u64 = 128 * 128 * 4; // 64KB, 16 pages
    let tpb = 128u32;
    let num_blocks = (tiles * tiles) as u32;
    let mut blocks = Vec::with_capacity(num_blocks as usize);
    let mut em = Emitter::new(cfg.line_size);
    for ty in 0..tiles {
        for tx in 0..tiles {
            let bid = (ty * tiles + tx) as u32;
            let t = ty * tiles + tx;
            // Own DP tile: read + write.
            em.touch(0, t * tile_bytes, tile_bytes, false);
            em.touch(0, t * tile_bytes, tile_bytes, true);
            // Reference tile.
            em.touch(1, t * tile_bytes, tile_bytes, false);
            // Halo: the neighbor tiles' boundary strips. The blocked layout
            // stores each tile's south row and east column contiguously at
            // the tile's end (the standard halo-duplication optimization),
            // so both halo reads touch only the neighbor's last page.
            if ty > 0 {
                let north = (ty - 1) * tiles + tx;
                em.touch(0, north * tile_bytes + tile_bytes - 128 * 4, 128 * 4, false);
            }
            if tx > 0 {
                let west = ty * tiles + tx - 1;
                em.touch(0, west * tile_bytes + tile_bytes - 256 * 4, 128 * 4, false);
            }
            blocks.push(BlockTrace {
                block_id: bid,
                accesses: em.take(),
            });
        }
    }
    let objects = vec![
        ObjectDesc {
            name: "dp_matrix".into(),
            bytes: tiles * tiles * tile_bytes,
        },
        ObjectDesc {
            name: "reference".into(),
            bytes: tiles * tiles * tile_bytes,
        },
    ];
    BuiltWorkload {
        name: "NW",
        category: Category::BlockExclusive,
        trace: mk_trace("NW", tpb, objects, blocks),
        ir: None,
        env: ParamEnv::new(tpb as i64),
    }
}

/// MG — MUMmerGPU: private query batches + a shared suffix tree. Queries
/// are batched by genome region, so blocks of one stack mostly descend
/// into the same subtree region; the hot top levels are read by everyone.
/// Majority (but not >90%) of pages end up one-stack: core-majority.
pub fn mummer(cfg: &SystemConfig) -> BuiltWorkload {
    let nqueries: u64 = 98_304;
    let query_bytes: u64 = 16; // packed 64-mer
    let tree_nodes: u64 = 65_536;
    let node_bytes: u64 = 32;
    let tpb = 256u32;
    let num_blocks = (nqueries as u32).div_ceil(tpb);
    let mut rng = Rng::new(cfg.seed ^ 0x4975);
    let mut blocks = Vec::with_capacity(num_blocks as usize);
    let mut em = Emitter::new(cfg.line_size);
    // The hot band is the top quarter of the tree; per-stack regions
    // partition the remaining three quarters.
    let band_nodes = tree_nodes / 4;
    let region_len = (tree_nodes - band_nodes) / cfg.num_stacks as u64;
    for b in 0..num_blocks as u64 {
        let lo = b * tpb as u64;
        let hi = (lo + tpb as u64).min(nqueries);
        em.touch(0, lo * query_bytes, (hi - lo) * query_bytes, false);
        // Region of the tree this block's query batch descends into
        // (batches are region-sorted, aligned with the affinity stack).
        let region = crate::sched::affinity_stack(b as u32, cfg) as u64;
        for _ in lo..hi {
            // Hot root levels shared by everyone...
            em.touch(1, rng.below(64) * node_bytes, node_bytes, false);
            em.touch(1, rng.below(band_nodes) * node_bytes, node_bytes, false);
            // ...then the deep descent stays within the batch's region.
            for _ in 0..6 {
                let n = band_nodes + region * region_len + rng.below(region_len);
                em.touch(1, n * node_bytes, node_bytes, false);
            }
        }
        em.touch(2, lo * 8, (hi - lo) * 8, true); // match results
        blocks.push(BlockTrace {
            block_id: b as u32,
            accesses: em.take(),
        });
    }
    let objects = vec![
        ObjectDesc {
            name: "queries".into(),
            bytes: nqueries * query_bytes,
        },
        ObjectDesc {
            name: "suffix_tree".into(),
            bytes: tree_nodes * node_bytes,
        },
        ObjectDesc {
            name: "results".into(),
            bytes: nqueries * 8,
        },
    ];
    BuiltWorkload {
        name: "MG",
        category: Category::CoreMajority,
        trace: mk_trace("MG", tpb, objects, blocks),
        ir: None,
        env: ParamEnv::new(tpb as i64),
    }
}

/// DWT — discrete wavelet transform: the row pass owns row bands (pages
/// hold 4 consecutive blocks' rows — one stack), while the second-level
/// recursion over the LL subband (the top-left quarter) is read by every
/// block: majority one-stack.
pub fn dwt(cfg: &SystemConfig) -> BuiltWorkload {
    let width: u64 = 256;
    let height: u64 = 1024;
    let tpb = 256u32;
    let rows_per_block: u64 = 1;
    let num_blocks = (height / rows_per_block) as u32;
    let mut blocks = Vec::with_capacity(num_blocks as usize);
    let mut em = Emitter::new(cfg.line_size);
    for b in 0..num_blocks as u64 {
        let r = b * rows_per_block;
        // Row pass: read own row, write low/high coefficient halves.
        em.touch(0, r * width * 4, width * 4, false);
        em.touch(1, r * width * 4, width * 4, true);
        // Second-level pass over the LL subband (rows < height/2, cols <
        // width/2): sampled columns across the subband.
        let col = (b * 4) % (width / 2);
        for rr in (0..height / 2).step_by(4) {
            em.touch(1, (rr * width + col) * 4, 16, false);
        }
        blocks.push(BlockTrace {
            block_id: b as u32,
            accesses: em.take(),
        });
    }
    let objects = vec![
        ObjectDesc {
            name: "image".into(),
            bytes: width * height * 4,
        },
        ObjectDesc {
            name: "coeffs".into(),
            bytes: width * height * 4,
        },
    ];
    BuiltWorkload {
        name: "DWT",
        category: Category::CoreMajority,
        trace: mk_trace("DWT", tpb, objects, blocks),
        ir: None,
        env: ParamEnv::new(tpb as i64),
    }
}

/// HS3D — Hotspot3D: alternating-direction sweeps. The x-pass kernel owns
/// row bands, the y-pass kernel owns column bands of the same arrays, so
/// every page is touched by one row-block and many column-blocks — the
/// canonical sharing workload.
pub fn hotspot3d(cfg: &SystemConfig) -> BuiltWorkload {
    let nx: u64 = 512;
    let ny: u64 = 768;
    let rows_per_block: u64 = 2; // 384 blocks (4 full waves)
    let tpb = 256u32;
    let num_blocks = (ny / rows_per_block) as u32; // 384
    let mut blocks = Vec::with_capacity(num_blocks as usize);
    let mut em = Emitter::new(cfg.line_size);
    for b in 0..num_blocks as u64 {
        // X-pass: own row band (rows 2b, 2b+1) of temp + power; halo rows.
        let r_lo = b * rows_per_block;
        for r in r_lo..r_lo + rows_per_block {
            em.touch(0, r * nx * 4, nx * 4, false);
            em.touch(1, r * nx * 4, nx * 4, false);
            em.touch(2, r * nx * 4, nx * 4, true);
        }
        if r_lo > 0 {
            em.touch(0, (r_lo - 1) * nx * 4, nx * 4, false);
        }
        if r_lo + rows_per_block < ny {
            em.touch(0, (r_lo + rows_per_block) * nx * 4, nx * 4, false);
        }
        // Y-pass: own column band (cols 2b, 2b+1) across every row — these
        // touches land on every row-block's pages.
        let c = (b * rows_per_block) % nx;
        for r in 0..ny {
            em.touch(2, (r * nx + c) * 4, rows_per_block * 4, false);
            em.touch(0, (r * nx + c) * 4, rows_per_block * 4, true);
        }
        blocks.push(BlockTrace {
            block_id: b as u32,
            accesses: em.take(),
        });
    }
    let bytes = nx * ny * 4;
    let objects = vec![
        ObjectDesc {
            name: "temp_in".into(),
            bytes,
        },
        ObjectDesc {
            name: "power".into(),
            bytes,
        },
        ObjectDesc {
            name: "temp_out".into(),
            bytes,
        },
    ];
    BuiltWorkload {
        name: "HS3D",
        category: Category::Sharing,
        trace: mk_trace("HS3D", tpb, objects, blocks),
        ir: None,
        env: ParamEnv::new(tpb as i64),
    }
}

/// HS — hybrid sort: bucket scatter phase; bucket pages are written by
/// every block (sharing).
pub fn hybrid_sort(cfg: &SystemConfig) -> BuiltWorkload {
    let n: u64 = 1_048_576;
    let tpb = 256u32;
    let num_blocks = (n as u32).div_ceil(tpb) / 4; // 4 elems per thread
    let elems_per_block = n / num_blocks as u64;
    let mut rng = Rng::new(cfg.seed ^ 0x4501);
    let mut blocks = Vec::with_capacity(num_blocks as usize);
    let mut em = Emitter::new(cfg.line_size);
    for b in 0..num_blocks as u64 {
        let lo = b * elems_per_block;
        em.touch(0, lo * 4, elems_per_block * 4, false); // input sweep
        // Scatter into value-ordered buckets: target depends on the data,
        // uniform over the output.
        for _ in 0..elems_per_block / 8 {
            let dst = rng.below(n);
            em.touch(1, dst * 4, 32, true);
        }
        em.touch(2, 0, 1024 * 4, false); // bucket histogram (shared)
        blocks.push(BlockTrace {
            block_id: b as u32,
            accesses: em.take(),
        });
    }
    let objects = vec![
        ObjectDesc {
            name: "input".into(),
            bytes: n * 4,
        },
        ObjectDesc {
            name: "buckets".into(),
            bytes: n * 4,
        },
        ObjectDesc {
            name: "histogram".into(),
            bytes: 1024 * 4,
        },
    ];
    BuiltWorkload {
        name: "HS",
        category: Category::Sharing,
        trace: mk_trace("HS", tpb, objects, blocks),
        ir: None,
        env: ParamEnv::new(tpb as i64),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::affinity_stack;
    use crate::trace::{classify, sharing_histogram};

    fn check(wl: &BuiltWorkload, cfg: &SystemConfig) {
        let h = sharing_histogram(&wl.trace, cfg.page_size, |b| affinity_stack(b, cfg));
        assert_eq!(classify(&h), wl.category, "{}: {:?}", wl.name, h);
    }

    #[test]
    fn km_is_core_exclusive() {
        let cfg = SystemConfig::default();
        check(&kmeans(&cfg), &cfg);
    }

    #[test]
    fn nn_is_core_exclusive() {
        let cfg = SystemConfig::default();
        check(&nearest_neighbor(&cfg), &cfg);
    }

    #[test]
    fn mm_is_core_exclusive() {
        let cfg = SystemConfig::default();
        check(&matmul(&cfg), &cfg);
    }

    #[test]
    fn nw_is_block_exclusive() {
        let cfg = SystemConfig::default();
        check(&needleman_wunsch(&cfg), &cfg);
    }

    #[test]
    fn mg_is_core_majority() {
        let cfg = SystemConfig::default();
        check(&mummer(&cfg), &cfg);
    }

    #[test]
    fn hs3d_is_sharing() {
        let cfg = SystemConfig::default();
        check(&hotspot3d(&cfg), &cfg);
    }

    #[test]
    fn hs_is_sharing() {
        let cfg = SystemConfig::default();
        check(&hybrid_sort(&cfg), &cfg);
    }

    #[test]
    fn sad_has_61_blocks() {
        let cfg = SystemConfig::default();
        let wl = sad(&cfg);
        assert_eq!(wl.trace.num_blocks(), 61);
    }

    #[test]
    fn km_ir_matches_paper_b() {
        // The compile-time analysis over KM's IR must yield the paper's B
        // value: blockDim.x * nfeatures * sizeof(float).
        let cfg = SystemConfig::default();
        let wl = kmeans(&cfg);
        let res = crate::analysis::analyze_kernel(wl.ir.as_ref().unwrap(), &wl.env);
        match res[&0] {
            crate::analysis::ObjectPattern::Regular { stride, footprint } => {
                assert_eq!(stride, 256 * 4 * 4);
                assert!((footprint - 256 * 4 * 4).abs() <= 4);
            }
            ref p => panic!("{p:?}"),
        }
        // Centroids: block-invariant -> FGP.
        assert!(matches!(
            res[&2],
            crate::analysis::ObjectPattern::BlockInvariant { .. }
        ));
    }
}
