//! Synthetic graph generation with controlled regularity.
//!
//! The paper's graph inputs (GraphBIG real-world graphs, 59K–9M vertices)
//! are not available, so we synthesize CSR graphs whose two properties the
//! evaluation actually depends on are controllable:
//!
//! * **degree coefficient of variation** (sigma/mu of edges per
//!   thread-block, §6.4) — the regularity knob of Fig 11, and
//! * **neighbor locality** — how far neighbor ids stray from the source
//!   vertex, which determines how many neighbor-property reads leave the
//!   block's affinity stack.
//!
//! Regular real-world graphs (road networks, meshes) have low CV *and*
//! high locality; scale-free graphs (social networks) have high CV and low
//! locality; the generator couples both to one `GraphSpec`.

use crate::rng::Rng;

/// Compressed sparse row graph.
#[derive(Clone, Debug)]
pub struct CsrGraph {
    pub num_vertices: usize,
    /// `offsets[v]..offsets[v+1]` indexes `cols` (length V+1).
    pub offsets: Vec<u32>,
    /// Neighbor ids (length E).
    pub cols: Vec<u32>,
}

/// Generation parameters.
#[derive(Clone, Copy, Debug)]
pub struct GraphSpec {
    pub num_vertices: usize,
    pub avg_degree: f64,
    /// Target coefficient of variation of vertex degrees (0 = perfectly
    /// regular).
    pub degree_cv: f64,
    /// Fraction of neighbors drawn from a local window around the source
    /// (the rest are uniform over all vertices).
    pub locality: f64,
    /// Local window half-width in vertices.
    pub window: usize,
    pub seed: u64,
}

impl GraphSpec {
    /// A regular, high-locality graph (road-network-like).
    pub fn regular(num_vertices: usize, avg_degree: f64, seed: u64) -> Self {
        Self {
            num_vertices,
            avg_degree,
            degree_cv: 0.0,
            locality: 0.95,
            window: 512,
            seed,
        }
    }

    /// An irregular, low-locality graph (social-network-like).
    pub fn irregular(num_vertices: usize, avg_degree: f64, cv: f64, seed: u64) -> Self {
        Self {
            num_vertices,
            avg_degree,
            degree_cv: cv,
            locality: (0.95 - 0.4 * cv.min(2.0)).max(0.0),
            window: 512,
            seed,
        }
    }
}

impl CsrGraph {
    /// Generate a graph from a spec. Degrees are drawn from a clamped
    /// normal with the requested CV (CV >= ~1.5 switches to a power law for
    /// realistic heavy tails); neighbors mix a local window with uniform
    /// picks per `locality`.
    pub fn generate(spec: &GraphSpec) -> Self {
        let mut rng = Rng::new(spec.seed);
        let v = spec.num_vertices;
        let mut degrees = Vec::with_capacity(v);
        for _ in 0..v {
            let d = if spec.degree_cv < 1e-9 {
                spec.avg_degree
            } else if spec.degree_cv < 1.5 {
                rng.normal_ms(spec.avg_degree, spec.degree_cv * spec.avg_degree)
                    .max(0.0)
            } else {
                // Heavy tail: power law with alpha tuned so CV is large.
                rng.power_law((spec.avg_degree * 60.0) as u64, 2.0) as f64
            };
            degrees.push(d.round() as u32);
        }
        let mut offsets = Vec::with_capacity(v + 1);
        offsets.push(0u32);
        for d in &degrees {
            offsets.push(offsets.last().unwrap() + d);
        }
        let e = *offsets.last().unwrap() as usize;
        let mut cols = Vec::with_capacity(e);
        for src in 0..v {
            let d = degrees[src];
            for _ in 0..d {
                let dst = if rng.chance(spec.locality) {
                    let lo = src.saturating_sub(spec.window) as u64;
                    let hi = (src + spec.window).min(v - 1) as u64 + 1;
                    rng.range(lo, hi)
                } else {
                    rng.below(v as u64)
                };
                cols.push(dst as u32);
            }
        }
        Self {
            num_vertices: v,
            offsets,
            cols,
        }
    }

    pub fn num_edges(&self) -> usize {
        self.cols.len()
    }

    pub fn degree(&self, v: usize) -> u32 {
        self.offsets[v + 1] - self.offsets[v]
    }

    pub fn degrees(&self) -> Vec<u32> {
        (0..self.num_vertices).map(|v| self.degree(v)).collect()
    }

    pub fn neighbors(&self, v: usize) -> &[u32] {
        &self.cols[self.offsets[v] as usize..self.offsets[v + 1] as usize]
    }

    /// Measured coefficient of variation of vertex degrees.
    pub fn degree_cv(&self) -> f64 {
        let d: Vec<f64> = self.degrees().iter().map(|&x| x as f64).collect();
        crate::stats::coeff_of_variation(&d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn regular_graph_has_uniform_degree() {
        let g = CsrGraph::generate(&GraphSpec::regular(4096, 8.0, 1));
        assert_eq!(g.num_vertices, 4096);
        assert!(g.degrees().iter().all(|&d| d == 8));
        assert!(g.degree_cv() < 1e-9);
        assert_eq!(g.num_edges(), 4096 * 8);
    }

    #[test]
    fn irregular_graph_matches_requested_cv() {
        let g = CsrGraph::generate(&GraphSpec::irregular(8192, 8.0, 0.5, 2));
        let cv = g.degree_cv();
        assert!((cv - 0.5).abs() < 0.1, "cv={cv}");
    }

    #[test]
    fn heavy_tail_cv_is_large() {
        let g = CsrGraph::generate(&GraphSpec::irregular(8192, 8.0, 2.0, 3));
        assert!(g.degree_cv() > 1.0, "cv={}", g.degree_cv());
    }

    #[test]
    fn locality_keeps_neighbors_near() {
        let spec = GraphSpec::regular(8192, 8.0, 4);
        let g = CsrGraph::generate(&spec);
        let near = g
            .cols
            .iter()
            .enumerate()
            .filter(|(i, &dst)| {
                // Recover src by binary search over offsets.
                let src = g.offsets.partition_point(|&o| o as usize <= *i) - 1;
                (dst as i64 - src as i64).unsigned_abs() <= spec.window as u64
            })
            .count();
        let frac = near as f64 / g.num_edges() as f64;
        assert!(frac > 0.9, "local fraction {frac}");
    }

    #[test]
    fn neighbors_in_range() {
        let g = CsrGraph::generate(&GraphSpec::irregular(1000, 6.0, 1.0, 5));
        assert!(g.cols.iter().all(|&c| (c as usize) < 1000));
        assert_eq!(g.offsets.len(), 1001);
    }

    #[test]
    fn deterministic_generation() {
        let a = CsrGraph::generate(&GraphSpec::irregular(2048, 8.0, 1.0, 42));
        let b = CsrGraph::generate(&GraphSpec::irregular(2048, 8.0, 1.0, 42));
        assert_eq!(a.cols, b.cols);
        assert_eq!(a.offsets, b.offsets);
    }
}
