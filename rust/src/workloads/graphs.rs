//! Graph benchmarks (GraphBIG-derived): BFS, DC, PR, SSSP, BC, GC, CC, TC.
//!
//! All are vertex-centric CUDA-style kernels over CSR: one thread per
//! vertex, `tpb` threads per block, so block `b` owns vertices
//! `[b*tpb, (b+1)*tpb)`. The emitted accesses follow the real kernels'
//! index arithmetic: offset reads are contiguous (coalesced), neighbor-list
//! scans are contiguous per vertex, and neighbor-property reads are
//! data-dependent gathers — the access pattern the paper's compile-time
//! analysis cannot resolve and the profiler handles (§4.3.2).

use super::graph::{CsrGraph, GraphSpec};
use super::{BuiltWorkload, Emitter};
use crate::analysis::ParamEnv;
use crate::config::SystemConfig;
use crate::trace::{BlockTrace, Category, KernelTrace, ObjectDesc};

/// Which per-vertex work a graph kernel does; drives trace emission.
#[derive(Clone, Copy, Debug)]
struct GraphKernelShape {
    /// Reads the neighbor id list (cols) for each vertex.
    scan_edges: bool,
    /// Reads a property of each neighbor (gather) from object `gather_obj`.
    gather: bool,
    /// Reads a per-edge value array parallel to cols (SSSP weights).
    edge_values: bool,
    /// Writes a property of the owned vertex to object `write_obj`.
    write_own: bool,
    /// Fraction of vertices active (BFS frontier sweeps < 1.0).
    active_fraction: f64,
    /// Property element size in bytes.
    prop_bytes: u64,
}

/// Object ids shared by all graph kernels.
const OBJ_OFFSETS: u16 = 0;
const OBJ_COLS: u16 = 1;
const OBJ_PROP_READ: u16 = 2; // gathered neighbor property (e.g. rank[n])
const OBJ_PROP_WRITE: u16 = 3; // owned vertex property (e.g. next_rank[v])
const OBJ_EDGE_VALS: u16 = 4; // per-edge values (SSSP weights)

/// Deterministic per-vertex activity test (stable across runs/mechanisms).
fn active(v: usize, fraction: f64) -> bool {
    if fraction >= 1.0 {
        return true;
    }
    let mut z = (v as u64).wrapping_mul(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    (z >> 40) as f64 / (1u64 << 24) as f64 <= fraction
}

fn emit_graph_kernel(
    name: &str,
    g: &CsrGraph,
    tpb: u32,
    shape: GraphKernelShape,
    cfg: &SystemConfig,
) -> KernelTrace {
    let v = g.num_vertices;
    let num_blocks = (v as u32).div_ceil(tpb);
    let line = cfg.line_size;
    let mut blocks = Vec::with_capacity(num_blocks as usize);
    let mut em = Emitter::new(line);
    for b in 0..num_blocks {
        let v_lo = (b * tpb) as usize;
        let v_hi = ((b + 1) * tpb as u32).min(v as u32) as usize;
        for vtx in v_lo..v_hi {
            if !active(vtx, shape.active_fraction) {
                continue;
            }
            // offsets[v], offsets[v+1] — coalesced contiguous u32 reads.
            em.touch(OBJ_OFFSETS, vtx as u64 * 4, 8, false);
            let (e0, e1) = (g.offsets[vtx] as u64, g.offsets[vtx + 1] as u64);
            if shape.scan_edges && e1 > e0 {
                em.touch(OBJ_COLS, e0 * 4, (e1 - e0) * 4, false);
                if shape.edge_values {
                    em.touch(OBJ_EDGE_VALS, e0 * 4, (e1 - e0) * 4, false);
                }
            }
            if shape.gather {
                for &n in g.neighbors(vtx) {
                    em.touch(
                        OBJ_PROP_READ,
                        n as u64 * shape.prop_bytes,
                        shape.prop_bytes,
                        false,
                    );
                }
            }
            if shape.write_own {
                em.touch(
                    OBJ_PROP_WRITE,
                    vtx as u64 * shape.prop_bytes,
                    shape.prop_bytes,
                    true,
                );
            }
        }
        blocks.push(BlockTrace {
            block_id: b,
            accesses: em.take(),
        });
    }
    let e = g.num_edges() as u64;
    let objects = vec![
        ObjectDesc {
            name: "row_offsets".into(),
            bytes: (v as u64 + 1) * 4,
        },
        ObjectDesc {
            name: "col_indices".into(),
            bytes: e * 4,
        },
        ObjectDesc {
            name: "prop_read".into(),
            bytes: v as u64 * shape.prop_bytes,
        },
        ObjectDesc {
            name: "prop_write".into(),
            bytes: v as u64 * shape.prop_bytes,
        },
        ObjectDesc {
            name: "edge_vals".into(),
            bytes: if shape.edge_values { e * 4 } else { 4 },
        },
    ];
    KernelTrace {
        name: name.into(),
        threads_per_block: tpb,
        objects,
        blocks,
    }
}

fn build(
    name: &'static str,
    category: Category,
    g: &CsrGraph,
    tpb: u32,
    shape: GraphKernelShape,
    cfg: &SystemConfig,
) -> BuiltWorkload {
    BuiltWorkload {
        name,
        category,
        trace: emit_graph_kernel(name, g, tpb, shape, cfg),
        ir: None, // input-dependent: handled by the profiler path
        env: ParamEnv::new(tpb as i64),
    }
}

/// Default suite graph: mildly irregular, high locality (LDBC-like).
fn suite_graph(cfg: &SystemConfig) -> CsrGraph {
    CsrGraph::generate(&GraphSpec {
        num_vertices: 98_304,
        avg_degree: 8.0,
        degree_cv: 0.4,
        locality: 0.92,
        window: 768,
        seed: cfg.seed ^ 0x9A47,
    })
}

/// PR — PageRank: scan edges, gather neighbor ranks, write own next-rank.
pub fn pagerank(cfg: &SystemConfig) -> BuiltWorkload {
    pagerank_on(suite_graph(cfg), cfg)
}

/// PageRank over an arbitrary graph (Fig 11's sensitivity study).
pub fn pagerank_on(g: CsrGraph, cfg: &SystemConfig) -> BuiltWorkload {
    build(
        "PR",
        Category::BlockExclusive,
        &g,
        1024,
        GraphKernelShape {
            scan_edges: true,
            gather: true,
            edge_values: false,
            write_own: true,
            active_fraction: 1.0,
            prop_bytes: 4,
        },
        cfg,
    )
}

/// BFS — level sweep over ~40% frontier.
pub fn bfs(cfg: &SystemConfig) -> BuiltWorkload {
    build(
        "BFS",
        Category::BlockExclusive,
        &suite_graph(cfg),
        1024,
        GraphKernelShape {
            scan_edges: true,
            gather: true,
            edge_values: false,
            write_own: true,
            active_fraction: 0.4,
            prop_bytes: 4,
        },
        cfg,
    )
}

/// DC — degree centrality: offsets only, no gathers. The most exclusive
/// workload in the suite.
pub fn degree_centrality(cfg: &SystemConfig) -> BuiltWorkload {
    build(
        "DC",
        Category::BlockExclusive,
        &suite_graph(cfg),
        1024,
        GraphKernelShape {
            scan_edges: true,
            gather: false,
            edge_values: false,
            write_own: true,
            active_fraction: 1.0,
            prop_bytes: 4,
        },
        cfg,
    )
}

/// SSSP — Bellman-Ford sweep: edge weights + neighbor distance gathers.
pub fn sssp(cfg: &SystemConfig) -> BuiltWorkload {
    build(
        "SSSP",
        Category::BlockExclusive,
        &suite_graph(cfg),
        1024,
        GraphKernelShape {
            scan_edges: true,
            gather: true,
            edge_values: true,
            write_own: true,
            active_fraction: 0.6,
            prop_bytes: 4,
        },
        cfg,
    )
}

/// BC — betweenness centrality accumulation: very high locality graph
/// (dependency chains), gathers from the sigma/delta arrays.
pub fn betweenness(cfg: &SystemConfig) -> BuiltWorkload {
    let g = CsrGraph::generate(&GraphSpec {
        num_vertices: 98_304,
        avg_degree: 8.0,
        degree_cv: 0.3,
        locality: 0.97,
        window: 384,
        seed: cfg.seed ^ 0xBC01,
    });
    build(
        "BC",
        Category::BlockExclusive,
        &g,
        1024,
        GraphKernelShape {
            scan_edges: true,
            gather: true,
            edge_values: false,
            write_own: true,
            active_fraction: 1.0,
            prop_bytes: 4,
        },
        cfg,
    )
}

/// GC — greedy graph coloring: gather neighbor colors, write own color.
pub fn graph_coloring(cfg: &SystemConfig) -> BuiltWorkload {
    let g = CsrGraph::generate(&GraphSpec {
        num_vertices: 98_304,
        avg_degree: 8.0,
        degree_cv: 0.3,
        locality: 0.95,
        window: 512,
        seed: cfg.seed ^ 0x6C01,
    });
    build(
        "GC",
        Category::BlockExclusive,
        &g,
        1024,
        GraphKernelShape {
            scan_edges: true,
            gather: true,
            edge_values: false,
            write_own: true,
            active_fraction: 1.0,
            prop_bytes: 4,
        },
        cfg,
    )
}

/// CC — connected components with label propagation: low-locality gathers
/// over a sparser graph; the label array's pages are shared widely, which
/// is what demotes CC to block-majority in Table 2.
pub fn connected_components(cfg: &SystemConfig) -> BuiltWorkload {
    let g = CsrGraph::generate(&GraphSpec {
        num_vertices: 98_304,
        avg_degree: 4.0,
        degree_cv: 0.6,
        locality: 0.30,
        window: 2048,
        seed: cfg.seed ^ 0xCC01,
    });
    let mut wl = build(
        "CC",
        Category::BlockMajority,
        &g,
        256,
        GraphKernelShape {
            scan_edges: true,
            gather: true,
            edge_values: false,
            write_own: true,
            active_fraction: 1.0,
            prop_bytes: 8,
        },
        cfg,
    );
    wl.category = Category::BlockMajority;
    wl
}

/// TC — triangle counting: for each edge (v,u), scan u's neighbor list.
/// Every block reads edge pages all over the graph: the canonical sharing
/// workload.
pub fn triangle_count(cfg: &SystemConfig) -> BuiltWorkload {
    let g = CsrGraph::generate(&GraphSpec {
        num_vertices: 98_304,
        avg_degree: 8.0,
        degree_cv: 0.8,
        locality: 0.10,
        window: 4096,
        seed: cfg.seed ^ 0x7C01,
    });
    let tpb = 256u32;
    let line = cfg.line_size;
    let num_blocks = (g.num_vertices as u32).div_ceil(tpb);
    let mut blocks = Vec::with_capacity(num_blocks as usize);
    let mut em = Emitter::new(line);
    for b in 0..num_blocks {
        let v_lo = (b * tpb) as usize;
        let v_hi = ((b + 1) * tpb).min(g.num_vertices as u32) as usize;
        for vtx in v_lo..v_hi {
            em.touch(OBJ_OFFSETS, vtx as u64 * 4, 8, false);
            let (e0, e1) = (g.offsets[vtx] as u64, g.offsets[vtx + 1] as u64);
            if e1 > e0 {
                em.touch(OBJ_COLS, e0 * 4, (e1 - e0) * 4, false);
            }
            for &u in g.neighbors(vtx) {
                if (u as usize) <= vtx {
                    continue; // count each triangle once
                }
                // offsets[u], offsets[u+1] then u's neighbor list: the
                // remote-page scans that make TC a sharing workload.
                em.touch(OBJ_OFFSETS, u as u64 * 4, 8, false);
                let (f0, f1) = (g.offsets[u as usize] as u64, g.offsets[u as usize + 1] as u64);
                if f1 > f0 {
                    em.touch(OBJ_COLS, f0 * 4, (f1 - f0) * 4, false);
                }
            }
        }
        blocks.push(BlockTrace {
            block_id: b,
            accesses: em.take(),
        });
    }
    let trace = KernelTrace {
        name: "TC".into(),
        threads_per_block: tpb,
        objects: vec![
            ObjectDesc {
                name: "row_offsets".into(),
                bytes: (g.num_vertices as u64 + 1) * 4,
            },
            ObjectDesc {
                name: "col_indices".into(),
                bytes: g.num_edges() as u64 * 4,
            },
        ],
        blocks,
    };
    BuiltWorkload {
        name: "TC",
        category: Category::Sharing,
        trace,
        ir: None,
        env: ParamEnv::new(tpb as i64),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::affinity_stack;
    use crate::trace::{classify, sharing_histogram};

    fn check_category(wl: &BuiltWorkload, cfg: &SystemConfig) {
        let h = sharing_histogram(&wl.trace, cfg.page_size, |b| affinity_stack(b, cfg));
        let got = classify(&h);
        assert_eq!(
            got, wl.category,
            "{}: histogram {:?}",
            wl.name, h
        );
    }

    #[test]
    fn pr_is_block_exclusive() {
        let cfg = SystemConfig::default();
        check_category(&pagerank(&cfg), &cfg);
    }

    #[test]
    fn dc_is_block_exclusive() {
        let cfg = SystemConfig::default();
        check_category(&degree_centrality(&cfg), &cfg);
    }

    #[test]
    fn cc_is_block_majority() {
        let cfg = SystemConfig::default();
        check_category(&connected_components(&cfg), &cfg);
    }

    #[test]
    fn tc_is_sharing() {
        let cfg = SystemConfig::default();
        check_category(&triangle_count(&cfg), &cfg);
    }

    #[test]
    fn traces_are_deterministic() {
        let cfg = SystemConfig::default();
        let a = pagerank(&cfg);
        let b = pagerank(&cfg);
        assert_eq!(a.trace.total_accesses(), b.trace.total_accesses());
        assert_eq!(a.trace.blocks[0].accesses, b.trace.blocks[0].accesses);
    }

    #[test]
    fn accesses_stay_within_objects() {
        let cfg = SystemConfig::default();
        for wl in [pagerank(&cfg), sssp(&cfg), triangle_count(&cfg)] {
            for b in &wl.trace.blocks {
                for a in &b.accesses {
                    let sz = wl.trace.objects[a.obj as usize].bytes;
                    assert!(
                        a.offset < sz.div_ceil(cfg.line_size) * cfg.line_size,
                        "{}: obj {} off {} size {}",
                        wl.name,
                        a.obj,
                        a.offset,
                        sz
                    );
                }
            }
        }
    }
}
