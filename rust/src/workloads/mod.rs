//! The 20-benchmark evaluation suite (Table 2) as access-trace generators.
//!
//! Each benchmark runs the *actual indexing logic* of its GPU kernel over
//! real in-memory data structures (CSR graphs, dense matrices, feature
//! tables, frames) and emits the resulting line-granularity memory trace;
//! the page-sharing profile of Fig 3 is therefore emergent, not baked in.
//! Regular benchmarks additionally ship a kernel IR so the compile-time
//! symbolic analysis (§4.3.2) is exercised end-to-end; irregular ones rely
//! on the profiler path, as in the paper.

pub mod dense;
pub mod graph;
pub mod graphs;
pub mod suite;

use crate::analysis::{KernelIr, ParamEnv};
use crate::trace::{Access, Category, KernelTrace};

/// A fully generated benchmark: trace + (optional) compile-time IR.
#[derive(Clone, Debug)]
pub struct BuiltWorkload {
    pub name: &'static str,
    /// Table 2's ground-truth category for this benchmark.
    pub category: Category,
    pub trace: KernelTrace,
    /// Kernel IR for the compile-time analysis; `None` means the benchmark
    /// is input-dependent and uses the profiler (graph workloads).
    pub ir: Option<KernelIr>,
    pub env: ParamEnv,
}

impl BuiltWorkload {
    pub fn total_accesses(&self) -> u64 {
        self.trace.total_accesses()
    }
}

/// Warp-coalescing access emitter: contiguous touches within one cache
/// line collapse to a single access, mirroring GPU coalescing hardware.
#[derive(Debug)]
pub struct Emitter {
    line: u64,
    pub accesses: Vec<Access>,
    last: Option<(u16, u64, bool)>,
}

impl Emitter {
    pub fn new(line: u64) -> Self {
        Self {
            line,
            accesses: Vec::new(),
            last: None,
        }
    }

    /// Touch `len` bytes of `obj` starting at `byte_off`.
    pub fn touch(&mut self, obj: u16, byte_off: u64, len: u64, write: bool) {
        let first = byte_off / self.line;
        let last = (byte_off + len.max(1) - 1) / self.line;
        for l in first..=last {
            let key = (obj, l, write);
            if self.last == Some(key) {
                continue; // coalesced
            }
            self.last = Some(key);
            self.accesses.push(Access {
                obj,
                offset: l * self.line,
                write,
            });
        }
    }

    pub fn take(&mut self) -> Vec<Access> {
        self.last = None;
        std::mem::take(&mut self.accesses)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn emitter_coalesces_within_line() {
        let mut e = Emitter::new(128);
        for i in 0..32 {
            e.touch(0, i * 4, 4, false); // 32 floats in one line
        }
        assert_eq!(e.accesses.len(), 1);
        e.touch(0, 128, 4, false);
        assert_eq!(e.accesses.len(), 2);
    }

    #[test]
    fn emitter_spans_lines() {
        let mut e = Emitter::new(128);
        e.touch(0, 100, 200, true); // crosses two line boundaries
        assert_eq!(e.accesses.len(), 3);
        assert!(e.accesses.iter().all(|a| a.write));
    }

    #[test]
    fn emitter_distinguishes_read_write() {
        let mut e = Emitter::new(128);
        e.touch(0, 0, 4, false);
        e.touch(0, 0, 4, true);
        assert_eq!(e.accesses.len(), 2);
    }

    #[test]
    fn emitter_take_resets() {
        let mut e = Emitter::new(128);
        e.touch(0, 0, 4, false);
        let v = e.take();
        assert_eq!(v.len(), 1);
        e.touch(0, 0, 4, false);
        assert_eq!(e.accesses.len(), 1, "no stale coalescing across blocks");
    }
}
