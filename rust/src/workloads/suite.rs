//! The benchmark registry: the paper's 20 evaluated workloads (Table 2)
//! addressable by name, plus helpers to build the whole suite.

use super::{dense, graphs, BuiltWorkload};
use crate::config::SystemConfig;
use crate::trace::Category;
use anyhow::bail;

/// All 20 benchmark names in Table 2 order, with their paper categories.
pub const ALL: &[(&str, Category)] = &[
    // Block-exclusive
    ("BFS", Category::BlockExclusive),
    ("DC", Category::BlockExclusive),
    ("PR", Category::BlockExclusive),
    ("SSSP", Category::BlockExclusive),
    ("BC", Category::BlockExclusive),
    ("GC", Category::BlockExclusive),
    ("NW", Category::BlockExclusive),
    // Core-exclusive
    ("KM", Category::CoreExclusive),
    ("CFD", Category::CoreExclusive),
    ("NN", Category::CoreExclusive),
    ("GE", Category::CoreExclusive),
    ("SPMV", Category::CoreExclusive),
    ("SAD", Category::CoreExclusive),
    ("MM", Category::CoreExclusive),
    // Block-majority
    ("CC", Category::BlockMajority),
    // Core-majority
    ("MG", Category::CoreMajority),
    ("DWT", Category::CoreMajority),
    // Sharing
    ("TC", Category::Sharing),
    ("HS3D", Category::Sharing),
    ("HS", Category::Sharing),
];

/// Build a benchmark by name.
pub fn build(name: &str, cfg: &SystemConfig) -> crate::Result<Box<BuiltWorkload>> {
    let wl = match name {
        "BFS" => graphs::bfs(cfg),
        "DC" => graphs::degree_centrality(cfg),
        "PR" => graphs::pagerank(cfg),
        "SSSP" => graphs::sssp(cfg),
        "BC" => graphs::betweenness(cfg),
        "GC" => graphs::graph_coloring(cfg),
        "NW" => dense::needleman_wunsch(cfg),
        "KM" => dense::kmeans(cfg),
        "CFD" => dense::cfd(cfg),
        "NN" => dense::nearest_neighbor(cfg),
        "GE" => dense::gaussian(cfg),
        "SPMV" => dense::spmv(cfg),
        "SAD" => dense::sad(cfg),
        "MM" => dense::matmul(cfg),
        "CC" => graphs::connected_components(cfg),
        "MG" => dense::mummer(cfg),
        "DWT" => dense::dwt(cfg),
        "TC" => graphs::triangle_count(cfg),
        "HS3D" => dense::hotspot3d(cfg),
        "HS" => dense::hybrid_sort(cfg),
        _ => bail!("unknown benchmark {name}; known: {:?}", names()),
    };
    Ok(Box::new(wl))
}

/// All benchmark names.
pub fn names() -> Vec<&'static str> {
    ALL.iter().map(|(n, _)| *n).collect()
}

/// Names in one category.
pub fn names_in(cat: Category) -> Vec<&'static str> {
    ALL.iter()
        .filter(|(_, c)| *c == cat)
        .map(|(n, _)| *n)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_has_20_benchmarks() {
        assert_eq!(ALL.len(), 20);
        assert_eq!(names_in(Category::BlockExclusive).len(), 7);
        assert_eq!(names_in(Category::CoreExclusive).len(), 7);
        assert_eq!(names_in(Category::BlockMajority).len(), 1);
        assert_eq!(names_in(Category::CoreMajority).len(), 2);
        assert_eq!(names_in(Category::Sharing).len(), 3);
    }

    #[test]
    fn every_benchmark_builds() {
        let cfg = SystemConfig::default();
        for (name, cat) in ALL {
            let wl = build(name, &cfg).unwrap();
            assert_eq!(wl.name, *name);
            assert_eq!(wl.category, *cat, "{name}");
            assert!(wl.trace.num_blocks() > 0, "{name}");
            assert!(wl.total_accesses() > 0, "{name}");
        }
    }

    #[test]
    fn unknown_name_errors() {
        assert!(build("NOPE", &SystemConfig::default()).is_err());
    }
}
